"""AOT export: lower the L2 model (with L1 Pallas kernels) to HLO text.

Runs ONCE at build time (``make artifacts``); Python is never on the
request path.  For every model config and every bucket this writes one
``.hlo.txt`` file plus a ``<model>.params.npz`` with the backbone weights,
and a single ``manifest.json`` that tells the Rust runtime the model dims,
bucket lists, artifact paths, and exact input ordering.

Interchange format is HLO *text*, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .config import MODELS, ModelConfig

DECODE_INPUT_ORDER = ["params...", "bank_a_q", "bank_b_q", "bank_a_v", "bank_b_v",
                      "tokens", "k_win", "v_win", "ctx", "slot"]
PREFILL_INPUT_ORDER = ["params...", "bank_a_q", "bank_b_q", "bank_a_v", "bank_b_v",
                       "tokens", "true_len", "slot"]
DECODE_OUTPUTS = ["next_tokens[B]i32", "new_k[L,B,d]f32", "new_v[L,B,d]f32"]
PREFILL_OUTPUTS = ["k[L,S,d]f32", "v[L,S,d]f32", "next_token[]i32"]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _f32(*dims):
    return jax.ShapeDtypeStruct(dims, jnp.float32)


def _i32(*dims):
    return jax.ShapeDtypeStruct(dims, jnp.int32)


def _param_specs(cfg: ModelConfig):
    return [_f32(*shape) for shape in
            (M.param_shapes(cfg)[n] for n in M.param_names(cfg))]


def _bank_specs(cfg: ModelConfig):
    return [_f32(*M.bank_shapes(cfg)[n]) for n in M.BANK_NAMES]


def lower_decode(cfg: ModelConfig, batch: int, use_pallas: bool) -> str:
    n_params = len(M.param_names(cfg))

    def fn(*args):
        params = list(args[:n_params])
        banks = list(args[n_params:n_params + 4])
        tokens, k_win, v_win, ctx, slot = args[n_params + 4:]
        return M.decode_step(cfg, params, banks, tokens, k_win, v_win, ctx,
                             slot, use_pallas=use_pallas)

    L, d, W, B = cfg.n_layers, cfg.d_model, cfg.window, batch
    specs = (
        _param_specs(cfg)
        + _bank_specs(cfg)
        + [_i32(B), _f32(L, B, W, d), _f32(L, B, W, d), _i32(B), _i32(B)]
    )
    return to_hlo_text(jax.jit(fn).lower(*specs))


def lower_prefill(cfg: ModelConfig, seq: int, use_pallas: bool) -> str:
    n_params = len(M.param_names(cfg))

    def fn(*args):
        params = list(args[:n_params])
        banks = list(args[n_params:n_params + 4])
        tokens, true_len, slot = args[n_params + 4:]
        return M.prefill(cfg, params, banks, tokens, true_len, slot,
                         use_pallas=use_pallas)

    specs = _param_specs(cfg) + _bank_specs(cfg) + [_i32(seq), _i32(), _i32()]
    return to_hlo_text(jax.jit(fn).lower(*specs))


def export_model(cfg: ModelConfig, out_dir: str, use_pallas: bool) -> dict:
    t0 = time.time()
    params = M.init_params(cfg)
    params_file = f"{cfg.name}.params.npz"
    # Uncompressed zip on purpose: the Rust reader (xla::Literal::read_npz)
    # supports stored + deflate, and stored loads faster.
    np.savez(os.path.join(out_dir, params_file), **params)

    entry = {
        "config": cfg.to_dict(),
        "params_file": params_file,
        "param_names": M.param_names(cfg),
        "bank_names": list(M.BANK_NAMES),
        "bank_shapes": {k: list(v) for k, v in M.bank_shapes(cfg).items()},
        "input_order": {"decode": DECODE_INPUT_ORDER, "prefill": PREFILL_INPUT_ORDER},
        "outputs": {"decode": DECODE_OUTPUTS, "prefill": PREFILL_OUTPUTS},
        "use_pallas": use_pallas,
        "decode": {},
        "prefill": {},
    }
    for b in cfg.decode_buckets:
        path = f"{cfg.name}.decode_b{b}.hlo.txt"
        text = lower_decode(cfg, b, use_pallas)
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(text)
        entry["decode"][str(b)] = path
        print(f"  decode b={b:<4} -> {path} ({len(text)} chars)")
    for s in cfg.prefill_buckets:
        path = f"{cfg.name}.prefill_s{s}.hlo.txt"
        text = lower_prefill(cfg, s, use_pallas)
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(text)
        entry["prefill"][str(s)] = path
        print(f"  prefill s={s:<4} -> {path} ({len(text)} chars)")
    print(f"  [{cfg.name}] exported in {time.time() - t0:.1f}s")
    return entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", nargs="*", default=list(MODELS.keys()))
    ap.add_argument("--no-pallas", action="store_true",
                    help="lower the pure-jnp reference path instead of the "
                         "Pallas kernels (kernel-overhead ablation)")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"version": 1, "use_pallas": not args.no_pallas, "models": {}}
    for name in args.models:
        cfg = MODELS[name]
        print(f"exporting {name} ...")
        manifest["models"][name] = export_model(cfg, args.out_dir,
                                                use_pallas=not args.no_pallas)
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
