"""L2: the backbone "pico" transformer with multi-LoRA, in JAX.

Two entry points are AOT-lowered per bucket (see aot.py):

- ``decode_step``: one continuous-batching decode iteration for a padded
  batch of B requests.  The Rust engine gathers each request's KV window
  from its paged store, and this function appends the new token's K/V,
  runs sliding-window attention (L1 Pallas kernel), applies per-request
  LoRA via the SGMV kernel, and returns sampled next tokens plus the new
  K/V rows for the Rust side to write back into its pages.
- ``prefill``: processes one request's (padded) prompt, returning the full
  K/V to seed the paged cache plus the first generated token.

LoRA is applied to the q and v projections, the common choice in the LoRA
paper and what vLLM serves by default.  Positions are not encoded (NoPE):
positional fidelity is irrelevant to the serving dynamics under study and
keeps the kernels minimal (DESIGN.md §3.1).
"""

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .kernels.sgmv import sgmv
from .kernels.decode_attention import decode_attention
from .kernels.ref import sgmv_ref, decode_attention_ref

_EPS = 1e-6


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def param_names(cfg: ModelConfig) -> list:
    """Deterministic parameter order shared with the Rust runtime via the
    manifest.  The LM head is tied to the embedding."""
    names = ["embed"]
    for l in range(cfg.n_layers):
        names += [
            f"l{l}.ln1",
            f"l{l}.wq",
            f"l{l}.wk",
            f"l{l}.wv",
            f"l{l}.wo",
            f"l{l}.ln2",
            f"l{l}.w_up",
            f"l{l}.w_down",
        ]
    names.append("final_ln")
    return names


def param_shapes(cfg: ModelConfig) -> dict:
    d, m, v = cfg.d_model, cfg.mlp_dim, cfg.vocab
    shapes = {"embed": (v, d), "final_ln": (d,)}
    for l in range(cfg.n_layers):
        shapes[f"l{l}.ln1"] = (d,)
        shapes[f"l{l}.wq"] = (d, d)
        shapes[f"l{l}.wk"] = (d, d)
        shapes[f"l{l}.wv"] = (d, d)
        shapes[f"l{l}.wo"] = (d, d)
        shapes[f"l{l}.ln2"] = (d,)
        shapes[f"l{l}.w_up"] = (d, m)
        shapes[f"l{l}.w_down"] = (m, d)
    return shapes


def init_params(cfg: ModelConfig) -> dict:
    """Random backbone weights (numpy, float32), keyed by name."""
    rng = np.random.default_rng(cfg.seed)
    out = {}
    for name, shape in param_shapes(cfg).items():
        if name.endswith("ln1") or name.endswith("ln2") or name == "final_ln":
            out[name] = np.ones(shape, dtype=np.float32)
        else:
            out[name] = rng.normal(0.0, 0.05, size=shape).astype(np.float32)
    return out


def params_list(cfg: ModelConfig, params: dict) -> list:
    return [params[n] for n in param_names(cfg)]


def bank_shapes(cfg: ModelConfig) -> dict:
    """Adapter bank tensors: LoRA A/B for the q and v projections of every
    layer, stacked over layers and physical slots."""
    L, S, d, r = cfg.n_layers, cfg.slots, cfg.d_model, cfg.max_rank
    return {
        "bank_a_q": (L, S, d, r),
        "bank_b_q": (L, S, r, d),
        "bank_a_v": (L, S, d, r),
        "bank_b_v": (L, S, r, d),
    }


BANK_NAMES = ["bank_a_q", "bank_b_q", "bank_a_v", "bank_b_v"]


def zero_banks(cfg: ModelConfig) -> dict:
    return {k: np.zeros(v, dtype=np.float32) for k, v in bank_shapes(cfg).items()}


def make_adapter(cfg: ModelConfig, rank: int, seed: int) -> dict:
    """Synthetic LoRA weights for one adapter (per layer, q & v), padded to
    cfg.max_rank.  Scaled by alpha/rank with alpha = 2*rank (so the LoRA
    contribution magnitude is rank-independent, as for real adapters)."""
    assert rank <= cfg.max_rank
    rng = np.random.default_rng(seed)
    L, d, R = cfg.n_layers, cfg.d_model, cfg.max_rank
    out = {}
    for proj in ("q", "v"):
        a = np.zeros((L, d, R), dtype=np.float32)
        b = np.zeros((L, R, d), dtype=np.float32)
        a[:, :, :rank] = rng.normal(0.0, 0.02, size=(L, d, rank))
        # Real LoRA inits B to zero; we want non-trivial compute, so use a
        # small random B scaled like a trained adapter.
        b[:, :rank, :] = rng.normal(0.0, 0.02, size=(L, rank, d)) * (2.0)
        out[f"a_{proj}"] = a
        out[f"b_{proj}"] = b
    return out


# --------------------------------------------------------------------------
# Model blocks
# --------------------------------------------------------------------------

def _rms_norm(x, w):
    return x * w * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + _EPS)


def _unpack(cfg: ModelConfig, params: list) -> dict:
    return dict(zip(param_names(cfg), params))


def _insert_row(win, new, pos):
    """win [B, W, d]; new [B, d]; pos [B] — write new[b] at win[b, pos[b]]."""
    return jax.vmap(
        lambda w, n, p: jax.lax.dynamic_update_slice(w, n[None, :], (p, 0))
    )(win, new, pos)


def decode_step(
    cfg: ModelConfig,
    params: list,
    banks: list,
    tokens,  # [B] int32
    k_win,  # [L, B, W, d] float32 — last <=W-1 cached keys per request
    v_win,  # [L, B, W, d]
    ctx,  # [B] int32 — number of valid window entries (<= W-1)
    slot,  # [B] int32 — physical adapter slot (0 = zero adapter)
    *,
    use_pallas: bool = True,
):
    """One decode iteration.  Returns (next_tokens [B] i32,
    new_k [L, B, d], new_v [L, B, d])."""
    p = _unpack(cfg, params)
    a_q, b_q, a_v, b_v = banks
    B = tokens.shape[0]
    h = p["embed"][tokens]  # [B, d]
    nh, dh, W = cfg.n_heads, cfg.head_dim, cfg.window
    _sgmv = sgmv if use_pallas else (lambda x, a, b, i: sgmv_ref(x, a, b, i))
    _attn = (
        decode_attention
        if use_pallas
        else (lambda q, k, v, c: decode_attention_ref(q, k, v, c))
    )

    new_ks, new_vs = [], []
    for l in range(cfg.n_layers):
        x = _rms_norm(h, p[f"l{l}.ln1"])
        q = x @ p[f"l{l}.wq"] + _sgmv(x, a_q[l], b_q[l], slot)
        k_new = x @ p[f"l{l}.wk"]
        v_new = x @ p[f"l{l}.wv"] + _sgmv(x, a_v[l], b_v[l], slot)
        kw = _insert_row(k_win[l], k_new, ctx)  # [B, W, d]
        vw = _insert_row(v_win[l], v_new, ctx)
        attn = _attn(
            q.reshape(B, nh, dh),
            kw.reshape(B, W, nh, dh),
            vw.reshape(B, W, nh, dh),
            ctx + 1,
        )  # [B, nh*dh]
        h = h + attn @ p[f"l{l}.wo"]
        x2 = _rms_norm(h, p[f"l{l}.ln2"])
        h = h + jax.nn.silu(x2 @ p[f"l{l}.w_up"]) @ p[f"l{l}.w_down"]
        new_ks.append(k_new)
        new_vs.append(v_new)

    logits = _rms_norm(h, p["final_ln"]) @ p["embed"].T  # [B, V]
    next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return next_tokens, jnp.stack(new_ks), jnp.stack(new_vs)


def prefill(
    cfg: ModelConfig,
    params: list,
    banks: list,
    tokens,  # [S] int32, padded prompt
    true_len,  # [] int32, actual prompt length (<= S)
    slot,  # [] int32, physical adapter slot
    *,
    use_pallas: bool = True,
):
    """Process one request's prompt.  Returns (k [L, S, d], v [L, S, d],
    next_token [] i32).  Rows >= true_len of k/v are garbage (never read:
    the Rust side only copies the first true_len rows into its pages)."""
    p = _unpack(cfg, params)
    a_q, b_q, a_v, b_v = banks
    S = tokens.shape[0]
    nh, dh = cfg.n_heads, cfg.head_dim
    scale = 1.0 / (dh**0.5)
    h = p["embed"][tokens]  # [S, d]
    slot_vec = jnp.full((S,), slot, dtype=jnp.int32)
    _sgmv = sgmv if use_pallas else (lambda x, a, b, i: sgmv_ref(x, a, b, i))

    pos = jnp.arange(S)
    causal = pos[None, :] <= pos[:, None]  # [S(q), S(k)]
    valid = pos[None, :] < true_len
    mask = causal & valid

    ks, vs = [], []
    for l in range(cfg.n_layers):
        x = _rms_norm(h, p[f"l{l}.ln1"])
        q = x @ p[f"l{l}.wq"] + _sgmv(x, a_q[l], b_q[l], slot_vec)
        k = x @ p[f"l{l}.wk"]
        v = x @ p[f"l{l}.wv"] + _sgmv(x, a_v[l], b_v[l], slot_vec)
        qh = q.reshape(S, nh, dh)
        kh = k.reshape(S, nh, dh)
        vh = v.reshape(S, nh, dh)
        s = jnp.einsum("ihd,jhd->hij", qh, kh) * scale  # [h, S, S]
        s = jnp.where(mask[None, :, :], s, jnp.float32(-1e30))
        pw = jax.nn.softmax(s, axis=-1)
        attn = jnp.einsum("hij,jhd->ihd", pw, vh).reshape(S, nh * dh)
        h = h + attn @ p[f"l{l}.wo"]
        x2 = _rms_norm(h, p[f"l{l}.ln2"])
        h = h + jax.nn.silu(x2 @ p[f"l{l}.w_up"]) @ p[f"l{l}.w_down"]
        ks.append(k)
        vs.append(v)

    last = jnp.take(h, true_len - 1, axis=0)  # [d]
    logits = _rms_norm(last, p["final_ln"]) @ p["embed"].T
    next_token = jnp.argmax(logits).astype(jnp.int32)
    return jnp.stack(ks), jnp.stack(vs), next_token
