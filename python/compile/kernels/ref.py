"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: python/tests sweeps shapes and
random inputs (hypothesis) and asserts the Pallas kernels match these to
float tolerance.  They are also used by the ``--no-pallas`` AOT variant to
quantify kernel overhead end-to-end.
"""

import jax
import jax.numpy as jnp


def sgmv_ref(x, a_bank, b_bank, idx):
    """Reference for kernels.sgmv: per-row gathered low-rank product."""
    a = a_bank[idx]  # [B, d, r]
    b = b_bank[idx]  # [B, r, d]
    return jnp.einsum("bd,bdr,brk->bk", x, a, b)


def decode_attention_ref(q, k_win, v_win, ctx):
    """Reference for kernels.decode_attention: masked softmax attention."""
    B, h, dh = q.shape
    W = k_win.shape[1]
    scale = 1.0 / (dh**0.5)
    s = jnp.einsum("bhd,bwhd->bhw", q, k_win) * scale  # [B, h, W]
    w_idx = jnp.arange(W)[None, None, :]
    s = jnp.where(w_idx < ctx[:, None, None], s, jnp.float32(-1e30))
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhw,bwhd->bhd", p, v_win).reshape(B, h * dh)
