"""SGMV (segmented-gather matrix-vector) Pallas kernel.

This is the multi-adapter LoRA hot spot of the paper (Punica-style batched
adapter compute): every request in the batch carries an adapter index into
a weight bank, and its hidden state is pushed through that adapter's two
low-rank matrices.

Hardware adaptation (CUDA -> TPU, see DESIGN.md §2): the CUDA SGMV kernel
assigns warp groups to adapter segments and stages adapter weights in
shared memory.  On TPU the analog is: the bank is a VMEM-resident block
(full-array BlockSpec — it is small by construction: slots × d × r_max),
the grid walks batch rows, and each row performs a dynamic gather of its
adapter slab followed by two MXU-shaped matmuls.

Kernels MUST be lowered with ``interpret=True``: real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sgmv_kernel(idx_ref, x_ref, a_ref, b_ref, o_ref):
    """One grid step = one batch row.

    idx_ref: [1]        int32, adapter slot for this row
    x_ref:   [1, d]     activations for this row
    a_ref:   [S, d, r]  down-projection bank (full block, VMEM-resident)
    b_ref:   [S, r, d]  up-projection bank
    o_ref:   [1, d]     LoRA delta output
    """
    slot = idx_ref[0]
    x = x_ref[...]  # [1, d]
    # Dynamic gather of this row's adapter slab from the bank.
    a = pl.load(a_ref, (pl.dslice(slot, 1), slice(None), slice(None)))[0]  # [d, r]
    b = pl.load(b_ref, (pl.dslice(slot, 1), slice(None), slice(None)))[0]  # [r, d]
    xa = jnp.dot(x, a)  # [1, r]
    o_ref[...] = jnp.dot(xa, b)  # [1, d]


def sgmv(x, a_bank, b_bank, idx, *, interpret: bool = True):
    """Batched multi-adapter LoRA delta.

    Args:
      x:      [B, d] float32 activations.
      a_bank: [S, d, r] float32 bank of down-projections.
      b_bank: [S, r, d] float32 bank of up-projections.
      idx:    [B] int32 adapter slot per row (0 = reserved zero adapter).

    Returns:
      [B, d] float32: ``(x @ A[idx]) @ B[idx]`` per row.
    """
    B, d = x.shape
    S, d2, r = a_bank.shape
    assert d2 == d, (d2, d)
    assert b_bank.shape == (S, r, d), (b_bank.shape, (S, r, d))
    assert idx.shape == (B,), (idx.shape, B)
    return pl.pallas_call(
        _sgmv_kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((S, d, r), lambda i: (0, 0, 0)),
            pl.BlockSpec((S, r, d), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, d), x.dtype),
        interpret=interpret,
    )(idx, x, a_bank, b_bank)
