"""Decode-step attention Pallas kernel over a fixed KV window.

One grid step per request: masked softmax attention of the single new
query against the request's VMEM-resident KV window tile (the Rust KV
block manager gathers the last ``window`` tokens from its paged store into
this dense tile — the TPU analog of paged-attention reads).

``ctx`` is the number of *valid* entries in the window; positions >= ctx
are masked out.  Lowered with ``interpret=True`` (see sgmv.py).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(ctx_ref, q_ref, k_ref, v_ref, o_ref, *, scale):
    """ctx_ref [1] i32; q_ref [1,h,dh]; k_ref/v_ref [1,W,h,dh]; o_ref [1,h*dh]."""
    ctx = ctx_ref[0]
    q = q_ref[0]  # [h, dh]
    k = k_ref[0]  # [W, h, dh]
    v = v_ref[0]  # [W, h, dh]
    s = jnp.einsum("hd,whd->hw", q, k) * scale  # [h, W]
    w_idx = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(w_idx < ctx, s, jnp.float32(-1e30))
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("hw,whd->hd", p, v)  # [h, dh]
    o_ref[...] = o.reshape(1, -1)


def decode_attention(q, k_win, v_win, ctx, *, interpret: bool = True):
    """Single-token attention for a batch of decoding requests.

    Args:
      q:     [B, h, dh] new-token queries.
      k_win: [B, W, h, dh] key window (first ``ctx[b]`` rows valid).
      v_win: [B, W, h, dh] value window.
      ctx:   [B] int32 number of valid window entries per request.

    Returns:
      [B, h*dh] attention outputs.
    """
    B, h, dh = q.shape
    W = k_win.shape[1]
    assert k_win.shape == (B, W, h, dh)
    assert v_win.shape == (B, W, h, dh)
    assert ctx.shape == (B,)
    scale = 1.0 / (dh**0.5)
    import functools

    kern = functools.partial(_attn_kernel, scale=scale)
    return pl.pallas_call(
        kern,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1, h, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, W, h, dh), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, W, h, dh), lambda i: (i, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h * dh), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, h * dh), q.dtype),
        interpret=interpret,
    )(ctx, q, k_win, v_win)
