"""Model configurations for the AOT compile path.

Two "pico" backbone configurations stand in for the paper's two backbones
(Llama-3.1-8B-Instruct and Qwen2.5-7B-Instruct).  The paper's results never
depend on model quality, only on serving dynamics; see DESIGN.md §1.
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class ModelConfig:
    """Static architecture description shared by L1/L2/aot and (via the
    manifest) the Rust runtime."""

    name: str
    d_model: int
    n_layers: int
    n_heads: int
    head_dim: int
    vocab: int
    # Sliding attention window (tokens of KV visible to a decode step).
    window: int
    # Physical adapter-bank slots on the device.  Slot 0 is reserved as the
    # all-zero "no adapter" slot by the Rust side.
    slots: int
    # All adapters are zero-padded to this rank in the physical bank.
    max_rank: int
    mlp_mult: int
    seed: int
    # Decode executables are compiled per batch bucket, prefill per padded
    # sequence-length bucket.
    decode_buckets: tuple = (1, 2, 4, 8, 16, 32, 64)
    prefill_buckets: tuple = (32, 64, 128, 256)

    @property
    def mlp_dim(self) -> int:
        return self.d_model * self.mlp_mult

    def to_dict(self) -> dict:
        d = asdict(self)
        d["decode_buckets"] = list(self.decode_buckets)
        d["prefill_buckets"] = list(self.prefill_buckets)
        d["mlp_dim"] = self.mlp_dim
        return d


PICO_LLAMA = ModelConfig(
    name="pico-llama",
    d_model=128,
    n_layers=2,
    n_heads=4,
    head_dim=32,
    vocab=512,
    window=128,
    slots=64,
    max_rank=32,
    mlp_mult=4,
    seed=1234,
)

PICO_QWEN = ModelConfig(
    name="pico-qwen",
    d_model=160,
    n_layers=2,
    n_heads=5,
    head_dim=32,
    vocab=512,
    window=128,
    slots=64,
    max_rank=32,
    mlp_mult=4,
    seed=4321,
)

MODELS = {m.name: m for m in (PICO_LLAMA, PICO_QWEN)}


def tiny_config(**overrides) -> ModelConfig:
    """A very small config for fast unit tests."""
    base = dict(
        name="tiny",
        d_model=32,
        n_layers=2,
        n_heads=2,
        head_dim=16,
        vocab=64,
        window=16,
        slots=8,
        max_rank=8,
        mlp_mult=2,
        seed=7,
        decode_buckets=(1, 2, 4),
        prefill_buckets=(8, 16),
    )
    base.update(overrides)
    return ModelConfig(**base)
