"""Generate JAX ground-truth fixtures for the Rust reference backend.

The Rust crate's default backend (``rust/src/runtime/reference.rs``) is a
CPU port of the pico model (``compile/model.py``) with the pure-jnp kernel
semantics of ``compile/kernels/ref.py``.  This tool runs the *actual* JAX
implementations on a tiny configuration and dumps inputs + outputs to
``rust/tests/fixtures/reference_backend.json``; the conformance test
(``rust/tests/backend_conformance.rs``) replays them through the Rust port
and asserts numeric agreement.

Greedy-sampling fixtures are only emitted when the winning logit's margin
over the runner-up is comfortably above float32 noise, so the exact-token
assertions on the Rust side can never flake on near-ties; the seeds below
were chosen to satisfy that margin.

Run from the repository root (JAX required):

    python python/tools/gen_backend_fixtures.py
"""

import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), ".."))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from compile import model as M  # noqa: E402
from compile.config import tiny_config  # noqa: E402
from compile.kernels.ref import decode_attention_ref, sgmv_ref  # noqa: E402

# The margin (in logits) the winning token must have over the runner-up for
# the fixture to pin exact argmax equality.  f32 reassociation noise in the
# Rust port is ~1e-5 on this configuration; 5e-3 gives ~500x headroom.
MIN_LOGIT_GAP = 5e-3


def flat(a):
    return [float(x) for x in np.asarray(a, np.float32).reshape(-1)]


def ints(a):
    return [int(x) for x in np.asarray(a).reshape(-1)]


# --------------------------------------------------------------------------
# Instrumented forwards: identical math to model.decode_step / model.prefill
# (use_pallas=False path) but also returning the final logits, so the
# generator can verify the greedy-sampling margin.  Cross-checked against
# the real entry points below to guard against drift.
# --------------------------------------------------------------------------

def decode_logits(cfg, params, banks, tokens, k_win, v_win, ctx, slot):
    p = dict(zip(M.param_names(cfg), params))
    a_q, b_q, a_v, b_v = banks
    B = tokens.shape[0]
    nh, dh, W = cfg.n_heads, cfg.head_dim, cfg.window
    h = p["embed"][tokens]
    for l in range(cfg.n_layers):
        x = M._rms_norm(h, p[f"l{l}.ln1"])
        q = x @ p[f"l{l}.wq"] + sgmv_ref(x, a_q[l], b_q[l], slot)
        k_new = x @ p[f"l{l}.wk"]
        v_new = x @ p[f"l{l}.wv"] + sgmv_ref(x, a_v[l], b_v[l], slot)
        kw = M._insert_row(k_win[l], k_new, ctx)
        vw = M._insert_row(v_win[l], v_new, ctx)
        attn = decode_attention_ref(
            q.reshape(B, nh, dh),
            kw.reshape(B, W, nh, dh),
            vw.reshape(B, W, nh, dh),
            ctx + 1,
        )
        h = h + attn @ p[f"l{l}.wo"]
        x2 = M._rms_norm(h, p[f"l{l}.ln2"])
        h = h + jax.nn.silu(x2 @ p[f"l{l}.w_up"]) @ p[f"l{l}.w_down"]
    return M._rms_norm(h, p["final_ln"]) @ p["embed"].T


def prefill_logits(cfg, params, banks, tokens, true_len, slot):
    p = dict(zip(M.param_names(cfg), params))
    a_q, b_q, a_v, b_v = banks
    S = tokens.shape[0]
    nh, dh = cfg.n_heads, cfg.head_dim
    scale = 1.0 / (dh**0.5)
    h = p["embed"][tokens]
    slot_vec = jnp.full((S,), slot, dtype=jnp.int32)
    pos = jnp.arange(S)
    mask = (pos[None, :] <= pos[:, None]) & (pos[None, :] < true_len)
    for l in range(cfg.n_layers):
        x = M._rms_norm(h, p[f"l{l}.ln1"])
        q = x @ p[f"l{l}.wq"] + sgmv_ref(x, a_q[l], b_q[l], slot_vec)
        k = x @ p[f"l{l}.wk"]
        v = x @ p[f"l{l}.wv"] + sgmv_ref(x, a_v[l], b_v[l], slot_vec)
        s = jnp.einsum("ihd,jhd->hij", q.reshape(S, nh, dh), k.reshape(S, nh, dh)) * scale
        s = jnp.where(mask[None, :, :], s, jnp.float32(-1e30))
        pw = jax.nn.softmax(s, axis=-1)
        attn = jnp.einsum("hij,jhd->ihd", pw, v.reshape(S, nh, dh)).reshape(S, nh * dh)
        h = h + attn @ p[f"l{l}.wo"]
        x2 = M._rms_norm(h, p[f"l{l}.ln2"])
        h = h + jax.nn.silu(x2 @ p[f"l{l}.w_up"]) @ p[f"l{l}.w_down"]
    last = jnp.take(h, true_len - 1, axis=0)
    return M._rms_norm(last, p["final_ln"]) @ p["embed"].T


def logit_gap(logits):
    top2 = np.sort(np.asarray(logits, np.float32))[..., -2:]
    return float(np.min(top2[..., 1] - top2[..., 0]))


def main():
    cfg = tiny_config(
        name="tiny-fixture",
        d_model=16,
        n_heads=2,
        head_dim=8,
        vocab=32,
        window=8,
        slots=4,
        max_rank=4,
        mlp_mult=2,
        seed=20260731,
        decode_buckets=(1, 2, 4),
        prefill_buckets=(8,),
    )
    params = M.init_params(cfg)
    plist = M.params_list(cfg, params)

    # Two synthetic adapters in slots 1 and 2 (slot 0 stays the zero
    # adapter), exactly as the Rust side writes them via write_bank_slot.
    banks_np = M.zero_banks(cfg)
    adapters = {1: M.make_adapter(cfg, rank=2, seed=11), 2: M.make_adapter(cfg, rank=4, seed=12)}
    for slot, ad in adapters.items():
        for proj in ("q", "v"):
            banks_np[f"bank_a_{proj}"][:, slot] = ad[f"a_{proj}"]
            banks_np[f"bank_b_{proj}"][:, slot] = ad[f"b_{proj}"]
    banks = [banks_np[n] for n in M.BANK_NAMES]

    rng = np.random.default_rng(2)

    # ---- decode fixture -------------------------------------------------
    B = 4
    tokens = rng.integers(0, cfg.vocab, B).astype(np.int32)
    ctx = np.array([3, 0, 5, 7], np.int32)  # includes 0 and window-1
    slot = np.array([0, 2, 1, 2], np.int32)  # zero adapter + both slabs
    k_win = rng.normal(0, 0.5, (cfg.n_layers, B, cfg.window, cfg.d_model)).astype(np.float32)
    v_win = rng.normal(0, 0.5, (cfg.n_layers, B, cfg.window, cfg.d_model)).astype(np.float32)
    # Poison the invalid window region (position ctx is overwritten by the
    # step's K/V insert; positions > ctx are masked): any masking bug on
    # the Rust side produces wildly wrong outputs instead of subtle ones.
    for b in range(B):
        k_win[:, b, ctx[b]:, :] = 1e3
        v_win[:, b, ctx[b]:, :] = -1e3

    nt, nk, nv = M.decode_step(
        cfg, plist, banks, tokens, k_win, v_win, ctx, slot, use_pallas=False
    )
    logits = decode_logits(cfg, plist, banks, tokens, k_win, v_win, ctx, slot)
    assert ints(jnp.argmax(logits, axis=-1)) == ints(nt), "instrumented decode drifted"
    gap = logit_gap(logits)
    assert gap > MIN_LOGIT_GAP, f"decode logit gap {gap} too small; pick new seeds"

    decode_fx = {
        "bucket": B,
        "tokens": ints(tokens),
        "ctx": ints(ctx),
        "slot": ints(slot),
        "k_win": flat(k_win),
        "v_win": flat(v_win),
        "next_tokens": ints(nt),
        "new_k": flat(nk),
        "new_v": flat(nv),
        "min_logit_gap": gap,
    }

    # ---- prefill fixture ------------------------------------------------
    S, true_len, p_slot = 8, 5, 1
    p_tokens = np.zeros(S, np.int32)
    p_tokens[:true_len] = rng.integers(0, cfg.vocab, true_len)
    pk, pv, p_next = M.prefill(
        cfg, plist, banks, p_tokens, np.int32(true_len), np.int32(p_slot), use_pallas=False
    )
    p_logits = prefill_logits(cfg, plist, banks, p_tokens, true_len, p_slot)
    assert int(jnp.argmax(p_logits)) == int(p_next), "instrumented prefill drifted"
    p_gap = logit_gap(p_logits)
    assert p_gap > MIN_LOGIT_GAP, f"prefill logit gap {p_gap} too small; pick new seeds"

    prefill_fx = {
        "bucket": S,
        "true_len": true_len,
        "slot": p_slot,
        "tokens": ints(p_tokens),
        "k": flat(pk),
        "v": flat(pv),
        "next_token": int(p_next),
        "min_logit_gap": p_gap,
    }

    # ---- kernel micro-fixtures (straight from kernels/ref.py) -----------
    sg_B, sg_S, sg_d, sg_r = 3, 3, 8, 2
    sg_x = rng.normal(0, 1, (sg_B, sg_d)).astype(np.float32)
    sg_a = rng.normal(0, 0.3, (sg_S, sg_d, sg_r)).astype(np.float32)
    sg_b = rng.normal(0, 0.3, (sg_S, sg_r, sg_d)).astype(np.float32)
    sg_idx = np.array([0, 2, 1], np.int32)
    sg_out = sgmv_ref(sg_x, sg_a, sg_b, sg_idx)
    sgmv_fx = {
        "n_rows": sg_B,
        "n_slots": sg_S,
        "d": sg_d,
        "r": sg_r,
        "x": flat(sg_x),
        "a_bank": flat(sg_a),
        "b_bank": flat(sg_b),
        "idx": ints(sg_idx),
        "out": flat(sg_out),
    }

    at_B, at_h, at_dh, at_W = 2, 2, 4, 5
    at_q = rng.normal(0, 1, (at_B, at_h, at_dh)).astype(np.float32)
    at_k = rng.normal(0, 0.7, (at_B, at_W, at_h, at_dh)).astype(np.float32)
    at_v = rng.normal(0, 0.7, (at_B, at_W, at_h, at_dh)).astype(np.float32)
    at_ctx = np.array([2, 5], np.int32)  # valid-entry counts (partial + full)
    at_out = decode_attention_ref(at_q, at_k, at_v, at_ctx)
    attention_fx = {
        "n_rows": at_B,
        "n_heads": at_h,
        "head_dim": at_dh,
        "window": at_W,
        "q": flat(at_q),
        "k_win": flat(at_k),
        "v_win": flat(at_v),
        "ctx": ints(at_ctx),
        "out": flat(at_out),
    }

    meta_entry = {
        "config": cfg.to_dict(),
        "params_file": "",
        "param_names": M.param_names(cfg),
        "decode": {},
        "prefill": {},
        "use_pallas": False,
    }
    bank_slots = [
        {
            "slot": slot_id,
            "a_q": flat(ad["a_q"]),
            "b_q": flat(ad["b_q"]),
            "a_v": flat(ad["a_v"]),
            "b_v": flat(ad["b_v"]),
        }
        for slot_id, ad in sorted(adapters.items())
    ]

    fixture = {
        "generator": "python/tools/gen_backend_fixtures.py",
        "jax_version": jax.__version__,
        "meta": meta_entry,
        "params": [flat(p) for p in plist],
        "bank_slots": bank_slots,
        "decode": decode_fx,
        "prefill": prefill_fx,
        "sgmv": sgmv_fx,
        "attention": attention_fx,
    }

    out = os.path.join(
        os.path.dirname(os.path.abspath(__file__)),
        "..", "..", "rust", "tests", "fixtures", "reference_backend.json",
    )
    out = os.path.normpath(out)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(fixture, f, separators=(",", ":"))
    size_kb = os.path.getsize(out) / 1024
    print(f"wrote {out} ({size_kb:.0f} KiB; decode gap {gap:.4f}, prefill gap {p_gap:.4f})")


if __name__ == "__main__":
    main()
