"""L1 correctness: Pallas kernels vs pure-jnp oracles (hypothesis sweeps)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.sgmv import sgmv
from compile.kernels.decode_attention import decode_attention
from compile.kernels.ref import sgmv_ref, decode_attention_ref

jax.config.update("jax_platform_name", "cpu")


def _rand(rng, *shape):
    return rng.normal(0.0, 1.0, size=shape).astype(np.float32)


# ---------------------------------------------------------------------------
# SGMV
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 9),
    d=st.sampled_from([8, 32, 128]),
    r=st.sampled_from([4, 8, 32]),
    s=st.sampled_from([2, 8, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_sgmv_matches_ref(b, d, r, s, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, b, d)
    a_bank = _rand(rng, s, d, r)
    b_bank = _rand(rng, s, r, d)
    idx = rng.integers(0, s, size=b).astype(np.int32)
    got = sgmv(x, a_bank, b_bank, idx)
    want = sgmv_ref(x, a_bank, b_bank, idx)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_sgmv_zero_slot_is_identity_delta():
    """Slot 0 holds the reserved zero adapter: delta must be exactly 0."""
    rng = np.random.default_rng(0)
    x = _rand(rng, 4, 16)
    a_bank = _rand(rng, 4, 16, 8)
    b_bank = _rand(rng, 4, 8, 16)
    a_bank[0] = 0.0
    idx = np.zeros(4, dtype=np.int32)
    got = sgmv(x, a_bank, b_bank, idx)
    np.testing.assert_array_equal(np.asarray(got), np.zeros((4, 16), np.float32))


def test_sgmv_mixed_slots():
    """Different rows must read different bank slabs."""
    rng = np.random.default_rng(1)
    x = _rand(rng, 3, 8)
    a_bank = _rand(rng, 3, 8, 4)
    b_bank = _rand(rng, 3, 4, 8)
    idx = np.array([2, 0, 1], dtype=np.int32)
    got = np.asarray(sgmv(x, a_bank, b_bank, idx))
    for row, slot in enumerate(idx):
        want = x[row] @ a_bank[slot] @ b_bank[slot]
        np.testing.assert_allclose(got[row], want, rtol=1e-4, atol=1e-5)


def test_sgmv_rank_padding_equivalence():
    """Zero-padding the rank dimension must not change the product."""
    rng = np.random.default_rng(2)
    x = _rand(rng, 4, 16)
    a_small = _rand(rng, 2, 16, 4)
    b_small = _rand(rng, 2, 4, 16)
    a_pad = np.zeros((2, 16, 8), np.float32)
    b_pad = np.zeros((2, 8, 16), np.float32)
    a_pad[:, :, :4] = a_small
    b_pad[:, :4, :] = b_small
    idx = np.array([0, 1, 0, 1], dtype=np.int32)
    np.testing.assert_allclose(
        np.asarray(sgmv(x, a_pad, b_pad, idx)),
        np.asarray(sgmv(x, a_small, b_small, idx)),
        rtol=1e-4,
        atol=1e-5,
    )


# ---------------------------------------------------------------------------
# Decode attention
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 6),
    h=st.sampled_from([1, 2, 4]),
    dh=st.sampled_from([8, 16, 32]),
    w=st.sampled_from([4, 16, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_attention_matches_ref(b, h, dh, w, seed):
    rng = np.random.default_rng(seed)
    q = _rand(rng, b, h, dh)
    k = _rand(rng, b, w, h, dh)
    v = _rand(rng, b, w, h, dh)
    ctx = rng.integers(1, w + 1, size=b).astype(np.int32)
    got = decode_attention(q, k, v, ctx)
    want = decode_attention_ref(q, k, v, ctx)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_decode_attention_masks_stale_entries():
    """Entries at positions >= ctx must not influence the output."""
    rng = np.random.default_rng(3)
    q = _rand(rng, 2, 2, 8)
    k = _rand(rng, 2, 8, 2, 8)
    v = _rand(rng, 2, 8, 2, 8)
    ctx = np.array([3, 5], dtype=np.int32)
    base = np.asarray(decode_attention(q, k, v, ctx))
    k2, v2 = k.copy(), v.copy()
    k2[0, 3:] = 777.0
    v2[0, 3:] = -777.0
    k2[1, 5:] = 777.0
    v2[1, 5:] = -777.0
    poked = np.asarray(decode_attention(q, k2, v2, ctx))
    np.testing.assert_allclose(poked, base, rtol=1e-5, atol=1e-5)


def test_decode_attention_ctx_one_returns_v0():
    """With a single valid entry, attention output is exactly v[0]."""
    rng = np.random.default_rng(4)
    q = _rand(rng, 1, 2, 4)
    k = _rand(rng, 1, 4, 2, 4)
    v = _rand(rng, 1, 4, 2, 4)
    ctx = np.array([1], dtype=np.int32)
    got = np.asarray(decode_attention(q, k, v, ctx))
    np.testing.assert_allclose(got[0], v[0, 0].reshape(-1), rtol=1e-5, atol=1e-5)
