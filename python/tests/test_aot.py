"""AOT path: lowering produces parseable HLO text with the expected
parameter count; manifest writer round-trips."""

import json
import os
import re

import pytest


def _entry_param_count(text: str) -> int:
    """Nested computations (pallas interpret loops) carry their own
    parameters; the ENTRY computation has the largest parameter index."""
    return max(int(m) for m in re.findall(r"parameter\((\d+)\)", text)) + 1

from compile import aot, model as M
from compile.config import tiny_config

CFG = tiny_config()


def test_lower_decode_hlo_text():
    text = aot.lower_decode(CFG, batch=2, use_pallas=True)
    assert "HloModule" in text
    assert "ENTRY" in text
    # params + 4 banks + 5 dynamic inputs
    n_inputs = len(M.param_names(CFG)) + 4 + 5
    assert _entry_param_count(text) == n_inputs


def test_lower_prefill_hlo_text():
    text = aot.lower_prefill(CFG, seq=8, use_pallas=True)
    assert "HloModule" in text
    n_inputs = len(M.param_names(CFG)) + 4 + 3
    assert _entry_param_count(text) == n_inputs


def test_export_model_writes_manifest_entry(tmp_path):
    entry = aot.export_model(CFG, str(tmp_path), use_pallas=True)
    assert set(entry["decode"].keys()) == {str(b) for b in CFG.decode_buckets}
    assert set(entry["prefill"].keys()) == {str(s) for s in CFG.prefill_buckets}
    for rel in list(entry["decode"].values()) + list(entry["prefill"].values()):
        assert (tmp_path / rel).exists()
    assert (tmp_path / entry["params_file"]).exists()
    # json round-trip
    s = json.dumps(entry)
    assert json.loads(s)["config"]["d_model"] == CFG.d_model
