"""L2 correctness: model shapes, prefill/decode consistency, LoRA effect."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.config import tiny_config

jax.config.update("jax_platform_name", "cpu")

CFG = tiny_config()


@pytest.fixture(scope="module")
def setup():
    params = M.params_list(CFG, M.init_params(CFG))
    banks_d = M.zero_banks(CFG)
    # Put a real adapter into slot 1.
    ad = M.make_adapter(CFG, rank=4, seed=99)
    for proj in ("q", "v"):
        banks_d[f"bank_a_{proj}"][:, 1] = ad[f"a_{proj}"]
        banks_d[f"bank_b_{proj}"][:, 1] = ad[f"b_{proj}"]
    banks = [banks_d[n] for n in M.BANK_NAMES]
    return params, banks


def _decode(params, banks, tokens, k_win, v_win, ctx, slot, use_pallas=True):
    return M.decode_step(CFG, params, banks,
                         jnp.asarray(tokens, jnp.int32),
                         jnp.asarray(k_win), jnp.asarray(v_win),
                         jnp.asarray(ctx, jnp.int32),
                         jnp.asarray(slot, jnp.int32),
                         use_pallas=use_pallas)


def test_decode_shapes(setup):
    params, banks = setup
    B, L, W, d = 4, CFG.n_layers, CFG.window, CFG.d_model
    rng = np.random.default_rng(0)
    nxt, nk, nv = _decode(
        params, banks,
        rng.integers(0, CFG.vocab, B),
        rng.normal(size=(L, B, W, d)).astype(np.float32),
        rng.normal(size=(L, B, W, d)).astype(np.float32),
        rng.integers(1, W - 1, B),
        np.zeros(B, np.int32),
    )
    assert nxt.shape == (B,) and nxt.dtype == jnp.int32
    assert nk.shape == (L, B, d)
    assert nv.shape == (L, B, d)
    assert bool(jnp.all(nxt >= 0)) and bool(jnp.all(nxt < CFG.vocab))


def test_prefill_shapes(setup):
    params, banks = setup
    S, L, d = 16, CFG.n_layers, CFG.d_model
    rng = np.random.default_rng(1)
    k, v, nxt = M.prefill(CFG, params, banks,
                          jnp.asarray(rng.integers(0, CFG.vocab, S), jnp.int32),
                          jnp.asarray(9, jnp.int32), jnp.asarray(0, jnp.int32))
    assert k.shape == (L, S, d) and v.shape == (L, S, d)
    assert nxt.shape == () and nxt.dtype == jnp.int32


def test_pallas_and_ref_paths_agree(setup):
    """The AOT'd Pallas path and the pure-jnp path must be numerically equal
    (this is the end-to-end version of the kernel-vs-ref tests)."""
    params, banks = setup
    B, L, W, d = 3, CFG.n_layers, CFG.window, CFG.d_model
    rng = np.random.default_rng(2)
    args = (
        rng.integers(0, CFG.vocab, B),
        rng.normal(size=(L, B, W, d)).astype(np.float32),
        rng.normal(size=(L, B, W, d)).astype(np.float32),
        np.array([3, 1, 7]),
        np.array([1, 0, 1]),
    )
    n1, k1, v1 = _decode(params, banks, *args, use_pallas=True)
    n2, k2, v2 = _decode(params, banks, *args, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(n1), np.asarray(n2))
    np.testing.assert_allclose(np.asarray(k1), np.asarray(k2), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-4, atol=1e-5)


def test_prefill_then_decode_consistency(setup):
    """Decoding token t+1 after a prefill of length t must equal decoding it
    after a prefill of length t+1 computed the K/V for the same prefix —
    i.e. prefill K/V seeds the decode path correctly."""
    params, banks = setup
    L, W, d = CFG.n_layers, CFG.window, CFG.d_model
    rng = np.random.default_rng(3)
    S, t = 16, 6
    prompt = rng.integers(0, CFG.vocab, S).astype(np.int32)
    k, v, nxt = M.prefill(CFG, params, banks,
                          jnp.asarray(prompt), jnp.asarray(t, jnp.int32),
                          jnp.asarray(1, jnp.int32))
    # Feed the generated token through decode with the prefill K/V window.
    k_win = np.zeros((L, 1, W, d), np.float32)
    v_win = np.zeros((L, 1, W, d), np.float32)
    k_win[:, 0, :t] = np.asarray(k)[:, :t]
    v_win[:, 0, :t] = np.asarray(v)[:, :t]
    nxt2, nk, nv = _decode(params, banks, [int(nxt)], k_win, v_win, [t], [1])
    # Ground truth: prefill over the extended prompt of length t+1.
    ext = prompt.copy()
    ext[t] = int(nxt)
    k3, v3, nxt3 = M.prefill(CFG, params, banks,
                             jnp.asarray(ext), jnp.asarray(t + 1, jnp.int32),
                             jnp.asarray(1, jnp.int32))
    np.testing.assert_allclose(np.asarray(nk)[:, 0], np.asarray(k3)[:, t],
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(nv)[:, 0], np.asarray(v3)[:, t],
                               rtol=1e-3, atol=1e-4)
    assert int(nxt2[0]) == int(nxt3)


def test_adapter_changes_output(setup):
    """A non-zero adapter must actually change the computation vs slot 0."""
    params, banks = setup
    B, L, W, d = 2, CFG.n_layers, CFG.window, CFG.d_model
    rng = np.random.default_rng(4)
    base_args = (
        rng.integers(0, CFG.vocab, B),
        rng.normal(size=(L, B, W, d)).astype(np.float32),
        rng.normal(size=(L, B, W, d)).astype(np.float32),
        np.array([4, 4]),
    )
    _, k0, _ = _decode(params, banks, *base_args, np.array([0, 0]))
    _, k1, _ = _decode(params, banks, *base_args, np.array([1, 1]))
    assert not np.allclose(np.asarray(k0), np.asarray(k1))


def test_padding_rows_do_not_affect_outputs(setup):
    """Rust pads batches up to the bucket with dummy rows; real rows must be
    unaffected by what the padding rows contain."""
    params, banks = setup
    L, W, d = CFG.n_layers, CFG.window, CFG.d_model
    rng = np.random.default_rng(5)
    kw = rng.normal(size=(L, 2, W, d)).astype(np.float32)
    vw = rng.normal(size=(L, 2, W, d)).astype(np.float32)
    toks = rng.integers(0, CFG.vocab, 2)
    n_a, k_a, v_a = _decode(params, banks, toks, kw, vw, [3, 5], [1, 0])
    # Change everything about row 1 (the "padding" row).
    kw2, vw2 = kw.copy(), vw.copy()
    kw2[:, 1] = 123.0
    vw2[:, 1] = -9.0
    toks2 = toks.copy()
    toks2[1] = 0
    n_b, k_b, v_b = _decode(params, banks, toks2, kw2, vw2, [3, 1], [1, 0])
    assert int(n_a[0]) == int(n_b[0])
    np.testing.assert_allclose(np.asarray(k_a)[:, 0], np.asarray(k_b)[:, 0],
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(v_a)[:, 0], np.asarray(v_b)[:, 0],
                               rtol=1e-5, atol=1e-6)
