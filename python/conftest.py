import importlib.util
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

# Skip-not-fail dependency gating: the Python suite exercises the JAX/Pallas
# compile path, which is optional — the Rust tier-1 gate runs on the pure
# reference backend.  Entries must name the test *files* individually:
# pytest only consults collect_ignore during directory traversal, so a
# directory entry would not suppress an explicitly passed path like
# `pytest python/tests` (CI's invocation).  With every module ignored,
# that invocation collects nothing and exits 5, which CI maps to "skip".
collect_ignore = []
if importlib.util.find_spec("jax") is None:
    collect_ignore = [
        "tests/test_aot.py",
        "tests/test_kernels.py",
        "tests/test_model.py",
    ]
elif importlib.util.find_spec("hypothesis") is None:
    collect_ignore.append("tests/test_kernels.py")
