//! ROLLING-HORIZON REPLANNING WALKTHROUGH (DESIGN.md §7): serve a
//! drifting workload epoch-by-epoch and watch the placement adapt.
//!
//!   1. calibrate the Digital Twin and train the RF models (cached by the
//!      experiment context, same pipeline as `placement_pipeline`);
//!   2. build the burst-churn drift scenario the `drift` experiment uses,
//!      scaled to the calibrated backbone (heavy adapters retire
//!      mid-horizon, a lighter wave arrives later);
//!   3. run the horizon under three policies — plan-once static,
//!      migration-aware incremental replan, oracle-per-epoch — and compare
//!      GPU-epochs, migrations and feasibility;
//!   4. re-run the replanning loop under the latency objective
//!      (`MinLatency`) and show the GPU-epochs vs mean-ITL tradeoff the
//!      `drift` experiment quantifies epoch by epoch;
//!   5. swap the lockstep serving core for the event-driven
//!      continuous-batching core (DESIGN.md §12) on the same horizon and
//!      compare realized backlog, SLO goodput and KV-handoff bytes.
//!
//! ```sh
//! cargo run --release --example drift_replan
//! ```

use adapter_serving::cluster::epochs::{serve_horizon, HorizonBackend, ReplanPolicy};
use adapter_serving::cluster::{Core, RunOptions};
use adapter_serving::config::EngineConfig;
use adapter_serving::dt::LengthVariant;
use adapter_serving::experiments::drift::burst_churn;
use adapter_serving::experiments::{ExpContext, Scale};
use adapter_serving::placement::replan::ReplanParams;
use adapter_serving::placement::{MinGpus, MinLatency};

fn main() -> anyhow::Result<()> {
    let ctx = ExpContext::new(Scale::Quick);
    let model = "pico-llama";
    let (epochs, epoch_s, gpus) = (6usize, 5.0, 4usize);

    println!("[1/5] calibrating the twin + training the RF models (cached) ...");
    let mut rt = ctx.load_runtime(model)?;
    let calib = ctx.calibration(rt.as_mut())?;
    let est = ctx.trained_estimator(&calib)?;
    let base = EngineConfig { model: model.to_string(), ..Default::default() };
    let params = ReplanParams::from_calibration(&calib, epoch_s);
    println!(
        "      migration cost model: rank8 = {:.2} ms, rank32 = {:.2} ms",
        params.cost.load_s(8) * 1e3,
        params.cost.load_s(32) * 1e3
    );

    println!("[2/5] building the burst-churn drift scenario (scaled to this backbone) ...");
    let drift = burst_churn(epochs, epoch_s, &calib);
    for e in 0..epochs {
        let s = drift.epoch_spec(e);
        println!(
            "      epoch {e}: {} adapters, {:.0} tok/s incoming",
            s.adapters.len(),
            s.incoming_token_rate()
        );
    }

    // The unified horizon entry point: backend (twin/engine) x serving
    // core (lockstep/event) behind one signature.
    let twin = HorizonBackend::Twin { calib: &calib, variant: LengthVariant::Original };

    println!("[3/5] serving the horizon under each policy (twin, per-GPU parallel) ...");
    let cost = params.cost;
    let mut replan_min_gpus = None;
    for (name, policy) in [
        ("static", ReplanPolicy::Static),
        ("replan", ReplanPolicy::Replan(params.clone())),
        ("oracle", ReplanPolicy::Oracle(cost)),
    ] {
        let rep = serve_horizon(
            twin,
            &base,
            &drift,
            gpus,
            &est,
            &MinGpus,
            &policy,
            Core::Lockstep,
            RunOptions::new(),
        )?;
        let gpus_per_epoch: Vec<usize> = rep.per_epoch.iter().map(|r| r.gpus_used).collect();
        println!(
            "      {name:>6}: GPUs/epoch {gpus_per_epoch:?} → {} GPU-epochs, \
             {} migrations ({:.1} ms), {} infeasible, unserved {:.0} tok",
            rep.gpu_epochs,
            rep.total_migrations,
            rep.total_migration_cost_s * 1e3,
            rep.infeasible_epochs,
            rep.final_backlog_tokens
        );
        if name == "replan" {
            replan_min_gpus = Some(rep);
        }
    }

    println!("[4/5] the same replanning loop under each objective (GPUs vs ITL) ...");
    let replan_min_latency = serve_horizon(
        twin,
        &base,
        &drift,
        gpus,
        &est,
        &MinLatency,
        &ReplanPolicy::Replan(params.clone()),
        Core::Lockstep,
        RunOptions::new(),
    )?;
    let pairs = [
        ("min-gpus", replan_min_gpus.expect("replan ran in step 3")),
        ("min-latency", replan_min_latency),
    ];
    for (name, rep) in &pairs {
        println!(
            "      {name:>11}: {} GPU-epochs at {:.2} ms mean ITL ({} migrations)",
            rep.gpu_epochs,
            rep.mean_itl_s * 1e3,
            rep.total_migrations
        );
    }

    println!("[5/5] the same horizon on the event-driven core (`--core event`) ...");
    let event = serve_horizon(
        twin,
        &base,
        &drift,
        gpus,
        &est,
        &MinGpus,
        &ReplanPolicy::Replan(params.clone()),
        Core::EventDriven,
        RunOptions::new(),
    )?;
    let lockstep = &pairs[0].1;
    println!(
        "      lockstep: {} GPU-epochs, modeled backlog {:.0} tok at horizon end",
        lockstep.gpu_epochs, lockstep.final_backlog_tokens
    );
    println!(
        "      event:    {} GPU-epochs, realized backlog {:.0} tok, goodput {:.2} req/s \
         ({:.0}% SLO), {} KV bytes shipped across replans",
        event.gpu_epochs,
        event.final_backlog_tokens,
        event.mean_goodput_req_s,
        100.0 * event.slo_attainment,
        event.total_kv_handoff_bytes
    );
    println!("done — `adapterd experiment drift` writes this comparison to results/drift/");
    Ok(())
}
