//! END-TO-END DRIVER: the full data-driven pipeline on a real workload,
//! proving all layers compose (DESIGN.md §6, recorded in EXPERIMENTS.md):
//!
//!   1. load the AOT-compiled model (L1 Pallas kernels + L2 JAX graph)
//!      into the Rust PJRT runtime;
//!   2. calibrate the Digital Twin from engine micro-benchmarks;
//!   3. generate a training set with the DT;
//!   4. train the RF throughput/starvation models (halving grid search);
//!   5. run the greedy caching algorithm for a 4-GPU cluster;
//!   6. validate the allocation by SERVING IT on the real engine, and
//!      compare against MaxBase and Random baselines.
//!
//! ```sh
//! cargo run --release --example placement_pipeline
//! ```

use adapter_serving::cluster;
use adapter_serving::config::EngineConfig;
use adapter_serving::experiments::{ExpContext, Scale};
use adapter_serving::placement::{baselines, greedy};
use adapter_serving::runtime::Backend;
use adapter_serving::workload::WorkloadSpec;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let t0 = Instant::now();
    let ctx = ExpContext::new(Scale::Quick);
    let model = "pico-llama";

    println!("[1/6] loading the execution backend ({model}) ...");
    let mut rt: Box<dyn Backend> = ctx.load_runtime(model)?;
    println!(
        "      {} decode + {} prefill buckets available",
        rt.meta().decode_buckets.len(),
        rt.meta().prefill_buckets.len()
    );

    println!("[2/6] calibrating the Digital Twin ...");
    let calib = ctx.calibration(rt.as_mut())?;
    println!(
        "      Lat_load rank8={:.1}ms rank32={:.1}ms; decode table {} pts",
        calib.lat_load(8) * 1e3,
        calib.lat_load(32) * 1e3,
        calib.decode_pts.len()
    );

    println!("[3/6] generating the DT training set ...");
    let samples = ctx.dataset(&calib)?;
    let starved = samples.iter().filter(|s| s.starved).count();
    println!("      {} samples, {} starved ({:.0}%)", samples.len(), starved,
             100.0 * starved as f64 / samples.len() as f64);

    println!("[4/6] training RF models (successive halving, 5-fold CV) ...");
    let models = ctx.trained_models(&calib)?;

    println!("[5/6] greedy caching algorithm (Algorithms 1 & 2) ...");
    let adapters = WorkloadSpec::heterogeneous(128, &[8, 16, 32], &[0.15, 0.075, 0.0375], 21);
    let spec = WorkloadSpec::sharegpt_like(adapters.clone(), 12.0, 22);
    println!(
        "      workload: {} adapters, {:.0} tok/s incoming",
        adapters.len(),
        spec.incoming_token_rate()
    );
    let tp = Instant::now();
    let placement = greedy::place(&adapters, 4, &models)
        .map_err(|e| anyhow::anyhow!("placement failed: {e}"))?;
    println!(
        "      placed in {:.3}s → {} GPUs, A_max per GPU: {:?}",
        tp.elapsed().as_secs_f64(),
        placement.gpus_used(),
        placement.a_max
    );

    println!("[6/6] validating on the real serving engine (per-GPU parallel) ...");
    let base = EngineConfig { model: model.to_string(), ..Default::default() };
    let make = || ctx.load_runtime(model);
    let rep = cluster::run_on_engine(&make, &base, &placement, &spec)?;
    println!(
        "      Proposed: {} GPUs, {:.0} tok/s, itl {:.2} ms, feasible={}",
        rep.gpus_used,
        rep.total_throughput_tok_s,
        rep.itl_mean_s * 1e3,
        rep.feasible()
    );

    // Baselines for contrast.
    let tpr = 385.0;
    if let Ok(p) = baselines::max_base(&adapters, 4, 1200.0, tpr, false) {
        let r = cluster::run_on_engine(&make, &base, &p, &spec)?;
        println!(
            "      MaxBase : {} GPUs, {:.0} tok/s, feasible={}",
            r.gpus_used,
            r.total_throughput_tok_s,
            r.feasible()
        );
    }
    if let Ok(p) = baselines::random(&adapters, 4, 5) {
        let r = cluster::run_on_engine(&make, &base, &p, &spec)?;
        println!(
            "      Random  : {} GPUs, {:.0} tok/s, feasible={}",
            r.gpus_used,
            r.total_throughput_tok_s,
            r.feasible()
        );
    }
    println!("pipeline end-to-end in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
