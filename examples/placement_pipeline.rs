//! END-TO-END DRIVER: the full data-driven pipeline on a real workload
//! through the typed `Pipeline` API (DESIGN.md §6/§8, recorded in
//! EXPERIMENTS.md):
//!
//!   1. build a `Pipeline` for the backbone — every stage below is
//!      served from the content-hashed artifact store when its inputs
//!      are unchanged (`results/store/`);
//!   2. calibrate the Digital Twin from engine micro-benchmarks
//!      (`Calibrated`);
//!   3. generate a training set with the DT (`Dataset`);
//!   4. train the RF throughput/starvation models (`Trained`);
//!   5. run the caching greedy for a 4-GPU cluster (`Planned`) — the
//!      estimator and objective behind the planner are pluggable
//!      (`--estimator`/`--objective` on `adapterd pipeline`);
//!   6. validate the allocation by SERVING IT on the real engine, one
//!      backend per GPU in parallel, against MaxBase and Random
//!      baselines.
//!
//! ```sh
//! cargo run --release --example placement_pipeline
//! ```

use adapter_serving::cluster;
use adapter_serving::config::EngineConfig;
use adapter_serving::experiments::{ExpContext, Scale};
use adapter_serving::pipeline::Pipeline;
use adapter_serving::placement::baselines;
use adapter_serving::workload::WorkloadSpec;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let t0 = Instant::now();
    let ctx = ExpContext::new(Scale::Quick);
    let model = "pico-llama";

    println!("[1/6] building the typed pipeline for {model} ...");
    let pipe: Pipeline = ctx.pipeline(model).gpus(4);
    println!("      artifact store at {}", pipe.store().root().display());

    println!("[2/6] calibrating the Digital Twin ...");
    let calibrated = pipe.calibrate()?;
    let calib = &calibrated.calibration;
    println!(
        "      {}; Lat_load rank8={:.1}ms rank32={:.1}ms; decode table {} pts",
        if calibrated.cached { "cache hit" } else { "computed" },
        calib.lat_load(8) * 1e3,
        calib.lat_load(32) * 1e3,
        calib.decode_pts.len()
    );

    println!("[3/6] generating the DT training set ...");
    let dataset = pipe.dataset(&calibrated)?;
    let starved = dataset.samples.iter().filter(|s| s.starved).count();
    println!(
        "      {}; {} samples, {} starved ({:.0}%)",
        if dataset.cached { "cache hit" } else { "computed" },
        dataset.samples.len(),
        starved,
        100.0 * starved as f64 / dataset.samples.len() as f64
    );

    println!("[4/6] training RF models (successive halving, 5-fold CV) ...");
    let trained = pipe.train(&dataset)?;
    println!("      {}", if trained.cached { "cache hit" } else { "computed" });

    println!("[5/6] greedy caching algorithm (Algorithms 1 & 2) ...");
    let adapters = WorkloadSpec::heterogeneous(128, &[8, 16, 32], &[0.15, 0.075, 0.0375], 21);
    let spec = WorkloadSpec::sharegpt_like(adapters.clone(), 12.0, 22);
    println!(
        "      workload: {} adapters, {:.0} tok/s incoming",
        adapters.len(),
        spec.incoming_token_rate()
    );
    let tp = Instant::now();
    let planned = pipe
        .place(&trained, &adapters)
        .map_err(|e| anyhow::anyhow!("placement failed: {e}"))?;
    println!(
        "      placed in {:.3}s ({} objective, {} estimator) → {} GPUs, A_max per GPU: {:?}",
        tp.elapsed().as_secs_f64(),
        planned.objective,
        planned.estimator,
        planned.placement.gpus_used(),
        planned.placement.a_max
    );

    println!("[6/6] validating on the real serving engine (per-GPU parallel) ...");
    let base = EngineConfig { model: model.to_string(), ..Default::default() };
    // One pool serves the Proposed run and both baselines: backends are
    // constructed once per concurrent GPU and reused across validations.
    let opts = cluster::RunOptions::new().pool(ctx.backend_pool());
    let rep = cluster::serve_on_engine(&base, &planned.placement, &spec, opts)?;
    println!(
        "      Proposed: {} GPUs, {:.0} tok/s, itl {:.2} ms, feasible={}",
        rep.gpus_used,
        rep.total_throughput_tok_s,
        rep.itl_mean_s * 1e3,
        rep.feasible()
    );

    // Baselines for contrast.
    let tpr = 385.0;
    if let Ok(p) = baselines::max_base(&adapters, 4, 1200.0, tpr, false) {
        let r = cluster::serve_on_engine(&base, &p, &spec, opts)?;
        println!(
            "      MaxBase : {} GPUs, {:.0} tok/s, feasible={}",
            r.gpus_used,
            r.total_throughput_tok_s,
            r.feasible()
        );
    }
    if let Ok(p) = baselines::random(&adapters, 4, 5) {
        let r = cluster::serve_on_engine(&base, &p, &spec, opts)?;
        println!(
            "      Random  : {} GPUs, {:.0} tok/s, feasible={}",
            r.gpus_used,
            r.total_throughput_tok_s,
            r.feasible()
        );
    }
    println!("pipeline end-to-end in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
