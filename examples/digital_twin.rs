//! Digital Twin fidelity demo: calibrate the DT from engine micro-
//! benchmarks, then run engine and twin on the same workload trace and
//! compare throughput / ITL / TTFT (a single-scenario preview of Table 1).
//!
//! ```sh
//! cargo run --release --example digital_twin
//! ```

use adapter_serving::config::EngineConfig;
use adapter_serving::dt;
use adapter_serving::engine::Engine;
use adapter_serving::runtime::{load_backend, Manifest};
use adapter_serving::util::stats;
use adapter_serving::workload::WorkloadSpec;

fn main() -> anyhow::Result<()> {
    let mut rt = load_backend(&Manifest::default_dir(), "pico-llama")?;
    let base = EngineConfig::default();

    println!("calibrating digital twin (engine micro-benchmarks) ...");
    let calib = dt::calibrate(rt.as_mut(), &base, true)?;
    println!(
        "  Lat_model = ({:.3e}·B + {:.3e}·bucket + {:.3e}) · ({:.3e}·A_B + {:.3})",
        calib.k_backbone[0],
        calib.k_backbone[1],
        calib.k_backbone[2],
        calib.k_overhead[0],
        calib.k_overhead[1]
    );
    println!(
        "  Lat_load  = {:?}",
        calib
            .load_s_by_rank
            .iter()
            .map(|(r, s)| format!("rank{r}: {:.2}ms", s * 1e3))
            .collect::<Vec<_>>()
    );

    let mut engine_thr = vec![];
    let mut twin_thr = vec![];
    println!(
        "\n{:<22} {:>12} {:>11} {:>7} {:>9} {:>10}",
        "scenario", "engine tok/s", "twin tok/s", "err %", "eng wall", "twin wall"
    );
    for (n_adapters, rate) in [(8usize, 0.4f64), (16, 0.2), (32, 0.1), (64, 0.05)] {
        let adapters = WorkloadSpec::heterogeneous(n_adapters, &[8, 16], &[rate, rate / 2.0], 3);
        let spec = WorkloadSpec::sharegpt_like(adapters, 15.0, 21);
        let trace = spec.trace();
        let cfg = EngineConfig { a_max: n_adapters.min(32), s_max_rank: 16, ..Default::default() };

        let mut engine = Engine::new(cfg.clone(), rt.as_mut());
        let er = engine.run_trace(&spec, &trace)?;
        let erep = er.report.expect("engine feasible");

        let tr = dt::run_twin_trace(&cfg, &calib, &spec, &trace);
        let trep = tr.report.expect("twin feasible");

        if std::env::var("DT_DEBUG").is_ok() {
            // Measured vs predicted decode latency by batch size.
            let mut by_batch: std::collections::BTreeMap<usize, Vec<f64>> = Default::default();
            for r in er.profiler.decode_iters() {
                by_batch.entry(r.batch).or_default().push(r.exec_s);
            }
            for (b, ts) in &by_batch {
                let measured = adapter_serving::util::stats::mean(ts);
                let predicted = calib.lat_model(*b, calib.decode_bucket(*b), 2);
                println!(
                    "    batch {b:>3} n={:<5} measured {:.3}ms predicted {:.3}ms",
                    ts.len(),
                    measured * 1e3,
                    predicted * 1e3
                );
            }
            let pf: Vec<f64> =
                er.profiler.iters.iter().filter(|r| r.prefill).map(|r| r.exec_s).collect();
            println!(
                "    prefill iters={} mean={:.3}ms  decode iters={}",
                pf.len(),
                adapter_serving::util::stats::mean(&pf) * 1e3,
                er.profiler.decode_iters().count()
            );
        }
        let err = 100.0 * (erep.throughput_tok_s - trep.throughput_tok_s).abs()
            / ((erep.throughput_tok_s + trep.throughput_tok_s) / 2.0);
        println!(
            "{:<22} {:>12.1} {:>11.1} {:>7.2} {:>8.2}s {:>9.4}s",
            format!("A={n_adapters} rate={rate}"),
            erep.throughput_tok_s,
            trep.throughput_tok_s,
            err,
            er.wall_s,
            tr.wall_s
        );
        engine_thr.push(erep.throughput_tok_s);
        twin_thr.push(trep.throughput_tok_s);
    }
    println!(
        "\nthroughput SMAPE = {:.2}%  (paper Table 1 reports <= 5.08%)",
        stats::smape(&engine_thr, &twin_thr)
    );
    Ok(())
}
