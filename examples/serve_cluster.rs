//! Distributed serving demo: place a heterogeneous multi-LoRA workload on
//! a 4-GPU cluster with the greedy pipeline, route the requests per the
//! placement, and report per-GPU and aggregate serving metrics.
//!
//! ```sh
//! cargo run --release --example serve_cluster
//! ```

use adapter_serving::cluster;
use adapter_serving::config::EngineConfig;
use adapter_serving::experiments::{ExpContext, Scale};
use adapter_serving::placement::greedy;
use adapter_serving::workload::WorkloadSpec;

fn main() -> anyhow::Result<()> {
    let ctx = ExpContext::new(Scale::Quick);
    let model = "pico-llama";
    let mut rt = ctx.load_runtime(model)?;

    // Pipeline: calibrate → DT dataset → RF models (all cached in results/).
    let calib = ctx.calibration(rt.as_mut())?;
    let models = ctx.trained_models(&calib)?;

    // A mixed workload: 96 adapters across ranks and rates.
    let adapters = WorkloadSpec::heterogeneous(96, &[8, 16, 32], &[0.3, 0.15, 0.075, 0.0375], 11);
    let spec = WorkloadSpec::sharegpt_like(adapters.clone(), 12.0, 12);
    println!(
        "workload: {} adapters, {:.1} req/s, {:.0} tok/s incoming",
        adapters.len(),
        spec.total_rate(),
        spec.incoming_token_rate()
    );

    let placement = greedy::place(&adapters, 4, &models)
        .map_err(|e| anyhow::anyhow!("placement failed: {e}"))?;
    println!("greedy pipeline uses {} / 4 GPUs", placement.gpus_used());
    for g in 0..4 {
        let on = placement.adapters_on(g);
        if !on.is_empty() {
            println!("  gpu{g}: {} adapters, A_max={}", on.len(), placement.a_max[g]);
        }
    }

    let base = EngineConfig { model: model.to_string(), ..Default::default() };
    println!("serving (real engine per GPU, backends from the shared pool, in parallel) ...");
    let opts = cluster::RunOptions::new().pool(ctx.backend_pool());
    let rep = cluster::serve_on_engine(&base, &placement, &spec, opts)?;
    for (g, r) in rep.per_gpu.iter().enumerate() {
        if let Some(r) = r {
            println!("  gpu{g}: {}", r.summary());
        }
    }
    println!(
        "cluster: {:.0} tok/s total, itl {:.2} ms, ttft {:.1} ms, feasible={}",
        rep.total_throughput_tok_s,
        rep.itl_mean_s * 1e3,
        rep.ttft_mean_s * 1e3,
        rep.feasible()
    );
    Ok(())
}
