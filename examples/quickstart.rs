//! Quickstart: load the pico model (pure-Rust reference backend by
//! default; PJRT artifacts when built with `--features pjrt`), serve a
//! small multi-LoRA workload on one simulated GPU, and print the report.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use adapter_serving::config::EngineConfig;
use adapter_serving::engine::Engine;
use adapter_serving::runtime::{load_backend, Backend, Manifest};
use adapter_serving::workload::WorkloadSpec;

fn main() -> anyhow::Result<()> {
    let artifacts = Manifest::default_dir();
    println!("loading model pico-llama (artifacts dir: {}) ...", artifacts.display());
    let mut rt: Box<dyn Backend> = load_backend(&artifacts, "pico-llama")?;
    println!(
        "{} decode + {} prefill buckets (window={}, slots={})",
        rt.meta().decode_buckets.len(),
        rt.meta().prefill_buckets.len(),
        rt.meta().window,
        rt.meta().slots,
    );

    // 16 adapters, mixed ranks, ShareGPT-like lengths, 10 simulated seconds.
    let adapters = WorkloadSpec::heterogeneous(16, &[8, 16, 32], &[0.4, 0.2], 7);
    let spec = WorkloadSpec::sharegpt_like(adapters, 10.0, 42);
    println!(
        "workload: {} adapters, total rate {:.2} req/s, incoming {:.0} tok/s",
        spec.adapters.len(),
        spec.total_rate(),
        spec.incoming_token_rate()
    );

    let cfg = EngineConfig { a_max: 16, ..Default::default() };
    let mut engine = Engine::new(cfg, rt.as_mut());
    let result = engine.run(&spec)?;
    let report = result.report.expect("feasible configuration");
    println!("--- report ---");
    println!("{}", report.summary());
    println!(
        "engine wall time {:.2}s for {:.0}s simulated ({:.1}x)",
        result.wall_s,
        spec.horizon_s,
        spec.horizon_s / result.wall_s
    );
    println!(
        "profile: sched={:.3}s exec={:.3}s load={:.3}s over {} iterations",
        result.profiler.total_sched_s(),
        result.profiler.total_exec_s(),
        result.profiler.total_load_s(),
        result.profiler.iters.len()
    );
    Ok(())
}
