//! Differential suite: the greedy fleet planner ([`fleet::place`]) vs
//! the exact branch-and-bound oracle ([`exact::solve`]) on randomized
//! small instances — ≥200 seeded instances of ≤8 adapters × ≤3 GPU
//! classes, each run under both the GPU-count and the $/hr objective.
//!
//! What is asserted (and why each bound is a theorem for this setup,
//! not a tuned constant):
//!
//! * **Oracle dominance** — whenever greedy finds a plan, the oracle
//!   finds one too and never at higher cost.  Greedy consumes adapters
//!   in the same `priority_sorting` order the oracle branches over, and
//!   the analytic estimator ([`analytic::AnalyticGpu`]) is monotone
//!   (every prefix of a feasible group is feasible), so every greedy
//!   plan lies inside the oracle's search space.
//! * **Gap bound** — `greedy_cost / exact_cost ≤ price_spread ×
//!   greedy_gpus` where `price_spread = max/min unit cost`: greedy pays
//!   at most `gpus × max_cost`, the oracle at least `min_cost`.
//! * **Well-formedness** — both planners' outputs place every adapter
//!   exactly once, keep every GPU within its class's memory
//!   ([`MemoryConfig::kv_pool_tokens`]), use only testing-point
//!   `A_max` values, and respect per-class stock.
//! * The oracle never hits its node budget on these instance sizes.
//!
//! Violations are collected (not panicked on) so the full gap
//! distribution is printed before the final assertion — visible in the
//! captured output whenever the test fails.

#[path = "support/analytic.rs"]
mod analytic;

use adapter_serving::config::{FleetSpec, GpuTypeSpec, MemoryConfig};
use adapter_serving::placement::{
    exact, fleet, ExactLimits, FleetPlacement, MinCost, MinGpus, Objective, PerfEstimator,
    PlacementError, TESTING_POINTS,
};
use adapter_serving::util::rng::Rng;
use adapter_serving::workload::AdapterSpec;
use analytic::AnalyticGpu;

/// ISSUE floor is 200; a little headroom costs nothing at this size.
const INSTANCES: usize = 240;

/// One random instance: ≤8 adapters, ≤3 GPU classes with varied
/// memory/performance/price.  Per-class stock equals the adapter count,
/// so the oracle is never starved by stock alone and a greedy failure
/// reflects the planner, not an artificially tight fleet.
fn instance(rng: &mut Rng) -> (Vec<AdapterSpec>, FleetSpec, Vec<AnalyticGpu>) {
    let n = 1 + rng.below(8);
    let adapters: Vec<AdapterSpec> = (0..n)
        .map(|id| AdapterSpec {
            id,
            rank: *rng.choose(&[8, 16, 32]),
            rate: rng.range_f64(0.01, 1.2),
        })
        .collect();
    let n_types = 1 + rng.below(3);
    let mut entries = Vec::new();
    let mut ests = Vec::new();
    for t in 0..n_types {
        let perf_scale = *rng.choose(&[0.6, 1.0, 1.6, 2.4]);
        let mem = MemoryConfig {
            total_tokens: *rng.choose(&[4096, 8192, 16384]),
            ..Default::default()
        };
        ests.push(AnalyticGpu { mem: mem.clone(), perf_scale });
        let spec = GpuTypeSpec {
            name: format!("t{t}"),
            mem,
            cost_per_hour: *rng.choose(&[1.0, 1.5, 2.0, 3.0, 4.0]),
            perf_scale,
        };
        entries.push((spec, n));
    }
    (adapters, FleetSpec::new(entries), ests)
}

/// Plan cost under per-class `costs` (all-ones → GPU count).
fn plan_cost(fp: &FleetPlacement, costs: &[f64]) -> f64 {
    fp.placement
        .a_max
        .iter()
        .zip(&fp.gpu_type)
        .filter(|&(&a_max, _)| a_max > 0)
        .map(|(_, &t)| costs[t])
        .sum()
}

/// Well-formedness of a fleet plan; violations are recorded, not
/// panicked on, so the caller can print the gap distribution first.
fn check_plan(
    violations: &mut Vec<String>,
    tag: &str,
    which: &str,
    fp: &FleetPlacement,
    adapters: &[AdapterSpec],
    fleet: &FleetSpec,
) {
    if fp.placement.assignment.len() != adapters.len() {
        violations.push(format!(
            "{tag}: {which} placed {} of {} adapters",
            fp.placement.assignment.len(),
            adapters.len()
        ));
    }
    for a in adapters {
        if !fp.placement.assignment.contains_key(&a.id) {
            violations.push(format!("{tag}: {which} lost adapter {}", a.id));
        }
    }
    if fp.gpu_type.len() != fleet.total_gpus() {
        violations.push(format!(
            "{tag}: {which} typed {} GPU slots for a fleet of {}",
            fp.gpu_type.len(),
            fleet.total_gpus()
        ));
        return;
    }
    let mut used = vec![0usize; fleet.types.len()];
    for (g, (&a_max, &t)) in fp.placement.a_max.iter().zip(&fp.gpu_type).enumerate() {
        let on = fp.placement.adapters_on(g);
        if on.is_empty() {
            if a_max != 0 {
                violations.push(format!("{tag}: {which} gpu {g} idle but a_max={a_max}"));
            }
            continue;
        }
        used[t] += 1;
        if !TESTING_POINTS.contains(&a_max) {
            violations.push(format!(
                "{tag}: {which} gpu {g} a_max={a_max} is not a testing point"
            ));
            continue;
        }
        let s_max = on
            .iter()
            .filter_map(|id| adapters.iter().find(|a| a.id == *id))
            .map(|a| a.rank)
            .max()
            .unwrap_or(0);
        if fleet.types[t].mem.kv_pool_tokens(a_max, s_max).is_none() {
            violations.push(format!(
                "{tag}: {which} gpu {g} (class {t}) over memory at a_max={a_max}, s_max={s_max}"
            ));
        }
    }
    for (t, (&u, &stock)) in used.iter().zip(&fleet.counts).enumerate() {
        if u > stock {
            violations.push(format!("{tag}: {which} used {u} of class {t}, stock {stock}"));
        }
    }
}

#[test]
fn exact_oracle_dominates_greedy_on_random_fleets() {
    let mut rng = Rng::new(0xF1EE7);
    let limits = ExactLimits { max_nodes: 10_000_000 };
    let mut violations: Vec<String> = Vec::new();
    let mut gaps: Vec<f64> = Vec::new();
    let (mut both_ok, mut greedy_only_infeasible, mut both_infeasible) = (0usize, 0usize, 0usize);

    for case in 0..INSTANCES {
        let (adapters, fleet, ests) = instance(&mut rng);
        let est_refs: Vec<&dyn PerfEstimator> =
            ests.iter().map(|e| e as &dyn PerfEstimator).collect();
        let prices = fleet.prices();
        let unit = vec![1.0; fleet.types.len()];
        let arms: [(&str, &dyn Objective, &[f64]); 2] =
            [("min-gpus", &MinGpus, &unit), ("min-cost", &MinCost, &prices)];
        for (arm, objective, costs) in arms {
            let tag = format!(
                "case {case} [{arm}] (n={}, classes={})",
                adapters.len(),
                fleet.types.len()
            );
            let greedy_res = fleet::place(&adapters, &fleet, &est_refs, objective);
            let exact_res = exact::solve(&adapters, &fleet, &est_refs, costs, limits);
            match (greedy_res, exact_res) {
                (Ok(g), Ok(x)) => {
                    both_ok += 1;
                    check_plan(&mut violations, &tag, "greedy", &g, &adapters, &fleet);
                    check_plan(&mut violations, &tag, "exact", &x, &adapters, &fleet);
                    let (gc, xc) = (plan_cost(&g, costs), plan_cost(&x, costs));
                    if xc > gc + 1e-9 {
                        violations.push(format!(
                            "{tag}: oracle cost {xc:.3} exceeds greedy cost {gc:.3}"
                        ));
                    }
                    let spread = costs.iter().copied().fold(f64::MIN, f64::max)
                        / costs.iter().copied().fold(f64::MAX, f64::min);
                    let bound = spread * g.gpus_used() as f64;
                    let gap = gc / xc.max(1e-12);
                    if gap > bound + 1e-9 {
                        violations.push(format!(
                            "{tag}: gap {gap:.3} above provable bound {bound:.3} \
                             (spread {spread:.3} × {} greedy GPUs)",
                            g.gpus_used()
                        ));
                    }
                    gaps.push(gap);
                }
                (Err(_), Ok(x)) => {
                    // Alg. 1 commits nothing below the first testing
                    // point, so a dense burst it cannot serve on one GPU
                    // can starve greedy while the oracle splits it.
                    greedy_only_infeasible += 1;
                    check_plan(&mut violations, &tag, "exact", &x, &adapters, &fleet);
                }
                (Ok(_), Err(e)) => violations.push(format!(
                    "{tag}: greedy found a plan but the oracle failed with {e:?}"
                )),
                (Err(_), Err(e)) => {
                    if e == PlacementError::TimeLimit {
                        violations.push(format!("{tag}: oracle hit its node budget"));
                    }
                    both_infeasible += 1;
                }
            }
        }
    }

    gaps.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = gaps.iter().sum::<f64>() / gaps.len().max(1) as f64;
    let max = gaps.last().copied().unwrap_or(1.0);
    let at = |q: f64| gaps.get((q * gaps.len() as f64) as usize).copied().unwrap_or(1.0);
    let optimal = gaps.iter().filter(|&&g| g <= 1.0 + 1e-9).count();
    println!(
        "greedy-vs-exact over {INSTANCES} instances × 2 arms: \
         {both_ok} both feasible, {greedy_only_infeasible} greedy-only infeasible, \
         {both_infeasible} both infeasible"
    );
    println!(
        "gap distribution (greedy_cost / exact_cost): optimal {optimal}/{} \
         mean {mean:.3} p50 {:.3} p90 {:.3} p99 {:.3} max {max:.3}",
        gaps.len(),
        at(0.50),
        at(0.90),
        at(0.99)
    );
    assert!(
        2 * both_ok >= INSTANCES,
        "suite is near-vacuous: only {both_ok} of {} arms had both planners succeed",
        2 * INSTANCES
    );
    assert!(
        violations.is_empty(),
        "{} differential violations:\n{}",
        violations.len(),
        violations.join("\n")
    );
}
