//! Property-based tests (seeded harness in util::prop) over the system's
//! core invariants: memory conservation, scheduler admission soundness,
//! placement completeness, serialization round-trips, twin determinism,
//! and drift-workload epoch semantics (DESIGN.md §7).

#[path = "support/analytic.rs"]
mod analytic;

use adapter_serving::config::{EngineConfig, FleetSpec, GpuTypeSpec, MemoryConfig};
use adapter_serving::dt::{self, Calibration, LengthVariant};
use adapter_serving::engine::adapter_cache::SimAdapterCache;
use adapter_serving::engine::kv::KvLedger;
use adapter_serving::engine::request::Request;
use adapter_serving::engine::scheduler::{scan_admissions, AdmissionLimits};
use adapter_serving::placement::{
    exact, fleet, greedy, ExactLimits, MinCost, MinGpus, Objective, PerfEstimator,
    TESTING_POINTS,
};
use analytic::AnalyticGpu;
use adapter_serving::prop_assert;
use adapter_serving::util::json::Json;
use adapter_serving::util::prop::Prop;
use adapter_serving::util::rng::Rng;
use adapter_serving::workload::drift::DriftSpec;
use adapter_serving::workload::{AdapterSpec, WorkloadSpec};
use std::collections::VecDeque;

#[test]
fn kv_ledger_never_leaks_blocks() {
    Prop::new("kv ledger conservation").cases(48).check(|rng, size| {
        let mem = MemoryConfig { total_tokens: 16 * (8 + size * 4), ..Default::default() };
        let pool = mem.total_tokens;
        let mut ledger = KvLedger::new(mem, pool);
        let total = ledger.total_blocks();
        let mut live: Vec<usize> = vec![];
        for op in 0..200 {
            match rng.below(3) {
                0 => {
                    let id = op;
                    let tokens = 1 + rng.below(200);
                    if ledger.grow_to(id, tokens) {
                        if !live.contains(&id) {
                            live.push(id);
                        }
                    }
                }
                1 => {
                    let pick = rng.below(live.len().max(1)).min(live.len().saturating_sub(1));
                    if let Some(&id) = live.get(pick) {
                        let extra = 1 + rng.below(100);
                        let _ = ledger.grow_to(id, extra + 16);
                    }
                }
                _ => {
                    if !live.is_empty() {
                        let id = live.swap_remove(rng.below(live.len()));
                        ledger.release(id);
                    }
                }
            }
            let held: usize = live.iter().map(|&id| ledger.held_blocks(id)).sum();
            prop_assert!(
                held + ledger.free_blocks() == total,
                "leak: held {held} + free {} != total {total}",
                ledger.free_blocks()
            );
        }
        Ok(())
    });
}

#[test]
fn admission_scan_respects_all_caps() {
    Prop::new("admission caps").cases(48).check(|rng, size| {
        let n = 4 + size * 3;
        let a_max = 1 + rng.below(8);
        let max_running = 1 + rng.below(16);
        let mut requests: Vec<Request> = (0..n)
            .map(|i| {
                Request::new(i, rng.below(6), 8, 0.0, 8 + rng.below(64), 4 + rng.below(16))
            })
            .collect();
        let mut waiting: VecDeque<usize> = (0..n).collect();
        let mem = MemoryConfig { total_tokens: 2048, ..Default::default() };
        let mut ledger = KvLedger::new(mem, 2048);
        let mut cache = SimAdapterCache::new(a_max);
        let limits = AdmissionLimits { max_running, max_prefill_tokens: 512, unified: false };
        let res = scan_admissions(&mut waiting, &mut requests, &mut ledger, &mut cache, 0, limits);
        prop_assert!(res.admitted.len() <= max_running, "over running cap");
        prop_assert!(cache.resident_count() <= a_max, "over A_max");
        prop_assert!(
            res.admitted.len() + waiting.len() == n,
            "requests lost: {} + {} != {n}",
            res.admitted.len(),
            waiting.len()
        );
        // No admitted request is still waiting.
        for id in &res.admitted {
            prop_assert!(!waiting.contains(id), "request {id} both admitted and waiting");
        }
        // Admitted requests hold KV; waiting ones hold none.
        for id in &res.admitted {
            prop_assert!(ledger.held_blocks(*id) > 0, "admitted {id} without KV");
        }
        for id in &waiting {
            prop_assert!(ledger.held_blocks(*id) == 0, "waiting {id} holds KV");
        }
        Ok(())
    });
}

#[test]
fn priority_sorting_is_a_size_sorted_permutation() {
    Prop::new("priority sorting").cases(64).check(|rng, size| {
        let n = 1 + size * 2;
        let adapters: Vec<AdapterSpec> = (0..n)
            .map(|id| AdapterSpec {
                id,
                rank: *rng.choose(&[8, 16, 32]),
                rate: rng.range_f64(0.001, 2.0),
            })
            .collect();
        let sorted = greedy::priority_sorting(&adapters);
        prop_assert!(sorted.len() == n, "length changed");
        let mut ids: Vec<usize> = sorted.iter().map(|a| a.id).collect();
        ids.sort();
        prop_assert!(ids == (0..n).collect::<Vec<_>>(), "not a permutation");
        prop_assert!(
            sorted.windows(2).all(|w| w[0].rank >= w[1].rank),
            "sizes not descending"
        );
        Ok(())
    });
}

#[test]
fn twin_runs_are_deterministic() {
    Prop::new("twin determinism").cases(12).check(|rng, size| {
        let n = 4 + size;
        let adapters = WorkloadSpec::heterogeneous(n, &[8, 16], &[0.2, 0.1], rng.next_u64());
        let spec = WorkloadSpec::sharegpt_like(adapters, 8.0, rng.next_u64());
        let cfg = EngineConfig { a_max: n.min(16), s_max_rank: 16, ..Default::default() };
        let calib = Calibration::default();
        let a = dt::run_twin(&cfg, &calib, &spec, LengthVariant::Original);
        let b = dt::run_twin(&cfg, &calib, &spec, LengthVariant::Original);
        let (ra, rb) = (a.report.unwrap(), b.report.unwrap());
        prop_assert!(
            (ra.throughput_tok_s - rb.throughput_tok_s).abs() < 1e-9,
            "throughput diverged"
        );
        prop_assert!(ra.completed == rb.completed, "completed diverged");
        Ok(())
    });
}

#[test]
fn json_roundtrip_random_documents() {
    Prop::new("json roundtrip").cases(64).check(|rng, size| {
        fn gen(rng: &mut Rng, depth: usize) -> Json {
            match if depth > 2 { rng.below(4) } else { rng.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.bool(0.5)),
                2 => Json::Num((rng.range(-1_000_000, 1_000_000) as f64) / 64.0),
                3 => Json::Str(format!("s{}-\"quote\"\n{}", rng.below(100), rng.below(10))),
                4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth + 1)).collect()),
                _ => Json::Obj(
                    (0..rng.below(5))
                        .map(|i| (format!("k{i}"), gen(rng, depth + 1)))
                        .collect(),
                ),
            }
        }
        let doc = gen(rng, size.min(2));
        let pretty = Json::parse(&doc.pretty()).map_err(|e| e.to_string())?;
        let compact = Json::parse(&doc.to_string()).map_err(|e| e.to_string())?;
        prop_assert!(pretty == doc, "pretty roundtrip mismatch");
        prop_assert!(compact == doc, "compact roundtrip mismatch");
        Ok(())
    });
}

#[test]
fn workload_traces_are_reproducible_and_ordered() {
    Prop::new("trace invariants").cases(32).check(|rng, size| {
        let n = 1 + size;
        let adapters = WorkloadSpec::heterogeneous(n, &[8, 32], &[0.5, 0.05], rng.next_u64());
        let spec = WorkloadSpec::sharegpt_like(adapters, 20.0, rng.next_u64());
        let t1 = spec.trace();
        let t2 = spec.trace();
        prop_assert!(t1 == t2, "trace not deterministic");
        prop_assert!(
            t1.windows(2).all(|w| w[0].time_s <= w[1].time_s),
            "trace unsorted"
        );
        prop_assert!(
            t1.iter().all(|a| a.time_s < spec.horizon_s),
            "arrival beyond horizon"
        );
        Ok(())
    });
}

#[test]
fn drift_epochs_partition_horizon_deterministically_and_respect_lifetimes() {
    Prop::new("drift epoch semantics").cases(24).check(|rng, size| {
        let epochs = 2 + size % 6;
        let epoch_s = 1.0 + rng.f64() * 9.0;
        let d = DriftSpec::churn(
            size % 5,
            1 + size,
            &[8, 16, 32],
            &[0.05, 0.2, 0.8],
            epochs,
            epoch_s,
            rng.next_u64(),
        );
        // Determinism under the seed.
        let a = d.compile();
        let b = d.compile();
        prop_assert!(a.len() == epochs, "{} epochs compiled, expected {epochs}", a.len());
        for (e, (sa, sb)) in a.iter().zip(&b).enumerate() {
            prop_assert!(sa.trace() == sb.trace(), "epoch {e} not deterministic");
        }
        // Exact partition of the horizon.
        let total: f64 = a.iter().map(|s| s.horizon_s).sum();
        prop_assert!((total - d.horizon_s()).abs() < 1e-9, "partition leak: {total}");
        for (e, s) in a.iter().enumerate() {
            prop_assert!(
                s.trace().iter().all(|arr| arr.time_s >= 0.0 && arr.time_s < s.horizon_s),
                "epoch {e} arrival outside its window"
            );
            // Non-negative rates, and arrivals only for alive adapters.
            prop_assert!(s.adapters.iter().all(|ad| ad.rate >= 0.0), "negative rate");
            for p in &d.phases {
                let alive = p.active_in(e);
                if !alive {
                    prop_assert!(
                        !s.adapters.iter().any(|ad| ad.id == p.adapter.id),
                        "retired/unarrived adapter {} present in epoch {e}",
                        p.adapter.id
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn greedy_placement_assigns_each_adapter_once_with_valid_a_max() {
    // Analytic models via distilled trees (same approach as unit tests).
    use adapter_serving::ml::refine::FlatTree;
    use adapter_serving::ml::tree::{Criterion, Tree, TreeParams};
    use adapter_serving::ml::{MlModels, Predictor, N_FEATURES};
    let mut xs = vec![];
    let mut thr = vec![];
    let mut st = vec![];
    let mut rng = Rng::new(5);
    for _ in 0..3000 {
        let sum_rate = rng.range_f64(0.0, 40.0);
        let a_max = *rng.choose(&[8.0, 16.0, 32.0, 64.0, 96.0, 128.0]);
        let mut x = vec![0.0; N_FEATURES];
        x[1] = sum_rate;
        x[6] = a_max;
        xs.push(x);
        let cap = 1200.0 - 2.0 * a_max;
        thr.push((sum_rate * 96.0).min(cap));
        st.push((sum_rate * 96.0 > cap) as i32 as f64);
    }
    let models = MlModels {
        throughput: Predictor::Flat(FlatTree::compile(&Tree::fit(
            &xs,
            &thr,
            &TreeParams::default(),
        ))),
        starvation: Predictor::Flat(FlatTree::compile(&Tree::fit(
            &xs,
            &st,
            &TreeParams { criterion: Criterion::Gini, ..Default::default() },
        ))),
        scaler: None,
    };
    Prop::new("greedy placement completeness").cases(24).check(|rng, size| {
        let n = 2 + size * 2;
        let adapters: Vec<AdapterSpec> = (0..n)
            .map(|id| AdapterSpec {
                id,
                rank: *rng.choose(&[8, 16, 32]),
                rate: rng.range_f64(0.001, 0.08),
            })
            .collect();
        match greedy::place(&adapters, 4, &models) {
            Err(_) => Ok(()), // starvation is a legal outcome
            Ok(p) => {
                prop_assert!(p.assignment.len() == n, "missing assignments");
                for a in &adapters {
                    prop_assert!(p.assignment.contains_key(&a.id), "adapter {} lost", a.id);
                }
                for g in 0..4 {
                    if !p.adapters_on(g).is_empty() {
                        prop_assert!(
                            TESTING_POINTS.contains(&p.a_max[g]),
                            "a_max {} not a testing point",
                            p.a_max[g]
                        );
                    }
                }
                Ok(())
            }
        }
    });
}

/// Random adapters with small per-adapter rates (mostly feasible).
fn random_adapters(rng: &mut Rng, n: usize, max_rate: f64) -> Vec<AdapterSpec> {
    (0..n)
        .map(|id| AdapterSpec {
            id,
            rank: *rng.choose(&[8, 16, 32]),
            rate: rng.range_f64(0.001, max_rate),
        })
        .collect()
}

/// A random heterogeneous fleet plus its per-class analytic estimators.
fn random_fleet(rng: &mut Rng, n_types: usize, stock: usize) -> (FleetSpec, Vec<AnalyticGpu>) {
    let mut entries = Vec::new();
    let mut ests = Vec::new();
    for t in 0..n_types {
        let perf_scale = *rng.choose(&[0.6, 1.0, 1.6, 2.4]);
        let mem = MemoryConfig {
            total_tokens: *rng.choose(&[4096, 8192, 16384]),
            ..Default::default()
        };
        ests.push(AnalyticGpu { mem: mem.clone(), perf_scale });
        let spec = GpuTypeSpec {
            name: format!("t{t}"),
            mem,
            cost_per_hour: rng.range_f64(1.0, 5.0),
            perf_scale,
        };
        entries.push((spec, stock));
    }
    (FleetSpec::new(entries), ests)
}

#[test]
fn fleet_placement_places_once_within_type_memory_and_stock() {
    Prop::new("fleet placement invariants").cases(24).check(|rng, size| {
        let n = 2 + size * 2;
        let adapters = random_adapters(rng, n, 0.08);
        let (fleet_spec, ests) = random_fleet(rng, 1 + rng.below(3), 8);
        let est_refs: Vec<&dyn PerfEstimator> =
            ests.iter().map(|e| e as &dyn PerfEstimator).collect();
        for objective in [&MinGpus as &dyn Objective, &MinCost] {
            let fp = match fleet::place(&adapters, &fleet_spec, &est_refs, objective) {
                Err(_) => continue, // starvation is a legal outcome
                Ok(fp) => fp,
            };
            // Every adapter placed exactly once (map keys are unique).
            prop_assert!(fp.placement.assignment.len() == n, "missing assignments");
            for a in &adapters {
                prop_assert!(fp.placement.assignment.contains_key(&a.id), "adapter lost");
            }
            prop_assert!(
                fp.gpu_type.len() == fleet_spec.total_gpus(),
                "gpu_type covers the whole fleet"
            );
            let mut used = vec![0usize; fleet_spec.types.len()];
            for (g, (&a_max, &t)) in
                fp.placement.a_max.iter().zip(&fp.gpu_type).enumerate()
            {
                let on = fp.placement.adapters_on(g);
                if on.is_empty() {
                    continue;
                }
                used[t] += 1;
                prop_assert!(
                    TESTING_POINTS.contains(&a_max),
                    "a_max {a_max} not a testing point"
                );
                let s_max = on
                    .iter()
                    .filter_map(|id| adapters.iter().find(|a| a.id == *id))
                    .map(|a| a.rank)
                    .max()
                    .unwrap_or(0);
                // No GPU over its own class's memory.
                prop_assert!(
                    fleet_spec.types[t].mem.kv_pool_tokens(a_max, s_max).is_some(),
                    "gpu {g} (class {t}) over memory at a_max={a_max}"
                );
            }
            for (t, (&u, &stock)) in used.iter().zip(&fleet_spec.counts).enumerate() {
                prop_assert!(u <= stock, "class {t}: used {u} over stock {stock}");
            }
        }
        Ok(())
    });
}

#[test]
fn exact_fleet_cost_is_monotone_when_a_price_drops() {
    Prop::new("exact cost monotone in prices").cases(16).check(|rng, size| {
        let n = 1 + size % 6;
        let adapters = random_adapters(rng, n, 0.8);
        let (fleet_spec, ests) = random_fleet(rng, 2, n);
        let est_refs: Vec<&dyn PerfEstimator> =
            ests.iter().map(|e| e as &dyn PerfEstimator).collect();
        let cost_of = |fp: &fleet::FleetPlacement, prices: &[f64]| -> f64 {
            fp.used_by_type(&fleet_spec)
                .iter()
                .zip(prices)
                .map(|(&u, &p)| u as f64 * p)
                .sum()
        };
        let prices = fleet_spec.prices();
        let limits = ExactLimits::default();
        let before = match exact::solve(&adapters, &fleet_spec, &est_refs, &prices, limits) {
            Err(_) => return Ok(()), // infeasible either way
            Ok(fp) => cost_of(&fp, &prices),
        };
        // Drop one class's price; the optimum must not get dearer.
        let mut dropped = prices.clone();
        let t = rng.below(dropped.len());
        dropped[t] *= rng.range_f64(0.2, 0.9);
        let after = exact::solve(&adapters, &fleet_spec, &est_refs, &dropped, limits)
            .map(|fp| cost_of(&fp, &dropped))
            .map_err(|e| format!("feasible instance became infeasible: {e:?}"))?;
        prop_assert!(
            after <= before + 1e-9,
            "price drop raised the optimum: {before} -> {after}"
        );
        Ok(())
    });
}

#[test]
fn single_type_fleet_matches_homogeneous_greedy_bit_exact() {
    Prop::new("single-type fleet ≡ homogeneous greedy").cases(24).check(|rng, size| {
        let n = 2 + size * 2;
        let adapters = random_adapters(rng, n, 0.08);
        let est = AnalyticGpu { mem: MemoryConfig::default(), perf_scale: 1.0 };
        let gpus = 4;
        let homog = greedy::place(&adapters, gpus, &est);
        let fleet_spec = FleetSpec::single(GpuTypeSpec::catalog("a10g").unwrap(), gpus);
        let typed = fleet::place(&adapters, &fleet_spec, &[&est], &MinGpus);
        match (homog, typed) {
            (Ok(expected), Ok(fp)) => {
                prop_assert!(
                    fp.placement == expected,
                    "single-type fleet plan diverged from the homogeneous plan"
                );
                prop_assert!(fp.gpu_type == vec![0; gpus], "non-zero type on a single class");
            }
            (Err(a), Err(b)) => prop_assert!(a == b, "errors diverged: {a:?} vs {b:?}"),
            (a, b) => return Err(format!("feasibility diverged: {a:?} vs {b:?}")),
        }
        Ok(())
    });
}
