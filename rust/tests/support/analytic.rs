//! Shared analytic per-GPU-class estimator for the fleet differential
//! and property suites (not a test crate itself — included via
//! `#[path]` from `fleet_exact_diff.rs` and `prop_invariants.rs`).
//!
//! Closed-form throughput/feasibility so the greedy planner and the
//! branch-and-bound oracle consume identical, instantly-computable probe
//! data: a class of relative performance `perf_scale` serves
//! `perf_scale × (1000 − 2·A_max)` tok/s; a group starves when its
//! demand (Σrate × 96 tok/req) exceeds that or when `A_max` is below
//! the group size; memory feasibility is the real static-reservation
//! rule ([`MemoryConfig::kv_pool_tokens`]) under the class's memory.
//! Demand and size shrink when an adapter is removed, so every prefix
//! of a feasible group is feasible — which makes "the oracle's optimum
//! never costs more than the greedy plan" a theorem the differential
//! suite can assert outright.

#![allow(dead_code)]

use adapter_serving::config::MemoryConfig;
use adapter_serving::placement::{Estimate, PerfEstimator};
use adapter_serving::workload::AdapterSpec;

/// One GPU class's analytic performance/memory model.
pub struct AnalyticGpu {
    /// The class's memory configuration (drives the feasibility rule).
    pub mem: MemoryConfig,
    /// Relative performance multiplier (a10g-alike = 1.0).
    pub perf_scale: f64,
}

impl AnalyticGpu {
    /// Decode capacity (tok/s) at a given `A_max`.
    pub fn capacity(&self, a_max: usize) -> f64 {
        (self.perf_scale * (1000.0 - 2.0 * a_max as f64)).max(0.0)
    }
}

impl PerfEstimator for AnalyticGpu {
    fn estimate(&self, adapters: &[AdapterSpec], a_max: usize) -> Estimate {
        let s_max = adapters.iter().map(|a| a.rank).max().unwrap_or(8);
        let memory_error = self.mem.kv_pool_tokens(a_max, s_max).is_none();
        let demand: f64 = adapters.iter().map(|a| a.rate).sum::<f64>() * 96.0;
        let capacity = self.capacity(a_max);
        let starved = demand > capacity || a_max < adapters.len();
        Estimate { throughput_tok_s: demand.min(capacity), starved, memory_error }
    }

    fn name(&self) -> &'static str {
        "analytic-gpu"
    }
}
