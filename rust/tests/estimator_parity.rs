//! Estimator parity (the DESIGN.md §8 seam contract): on fixture
//! workloads with clear-cut verdicts, the ML estimator trained on
//! twin-generated data must agree with the Digital Twin queried directly
//! on feasibility (starvation and memory-error verdicts), and the
//! recorded oracle must replay the twin's throughput bit-for-bit.

use adapter_serving::config::EngineConfig;
use adapter_serving::dt::Calibration;
use adapter_serving::ml::{self, dataset::GridSpec};
use adapter_serving::placement::{
    plan, replan, replan_with_ledger, CachedEstimator, MinGpus, MlEstimator, OracleEstimator,
    PerfEstimator, TwinEstimator,
};
use adapter_serving::workload::{AdapterSpec, WorkloadSpec};

fn small_grid() -> GridSpec {
    GridSpec {
        sizes: vec![8, 16, 32],
        rates: vec![0.8, 0.2, 0.05, 0.0125],
        adapter_counts: vec![8, 16, 32, 64, 96, 128],
        a_max_values: vec![8, 16, 32, 64, 96, 128],
        horizon_s: 10.0,
        max_scenarios: 400,
        seed: 99,
    }
}

fn ml_estimator() -> MlEstimator {
    let calib = Calibration::default();
    let samples = ml::dataset::generate(&calib, &EngineConfig::default(), &small_grid(), 4);
    let rf = ml::ModelType::RandomForest;
    let (thr, _) = ml::train(&samples, ml::Task::Throughput, rf, true, 3);
    let (st, _) = ml::train(&samples, ml::Task::Starvation, rf, true, 3);
    MlEstimator::new(ml::MlModels { throughput: thr, starvation: st, scaler: None })
}

fn twin_estimator() -> TwinEstimator {
    TwinEstimator::new(Calibration::default(), EngineConfig::default()).horizon(10.0)
}

/// Fixture groups with clear-cut verdicts: `(group, a_max, feasible)`.
///
/// The cases sit far from the feasibility boundary (≈4x under / ≈35x
/// over the single-GPU ceiling, and a static reservation 2x over the
/// memory budget) so the learned verdict is not a coin flip.
fn fixtures() -> Vec<(Vec<AdapterSpec>, usize, bool)> {
    // Comfortably light: ~300 tok/s incoming vs ~1k tok/s capacity.
    let light = WorkloadSpec::heterogeneous(16, &[8, 16], &[0.05, 0.025], 7);
    // Hugely starved: ~77 req/s of demand on one GPU (rank 8 keeps the
    // static reservation healthy, so this is pure starvation).
    let heavy = WorkloadSpec::heterogeneous(128, &[8], &[0.8, 0.4], 23);
    // Memory error: 128 slots x rank 32 x 4 tok = 16384 > the 8192-token
    // GPU; the twin flags memory_error, the ML labels fold it into the
    // starvation verdict — both must call it infeasible.
    let oom: Vec<AdapterSpec> =
        (0..128).map(|id| AdapterSpec { id, rank: 32, rate: 0.05 }).collect();
    vec![(light, 16, true), (heavy, 96, false), (oom, 128, false)]
}

#[test]
fn ml_and_twin_agree_on_feasibility_verdicts() {
    let ml_est = ml_estimator();
    let twin = twin_estimator();
    for (i, (group, a_max, expect_feasible)) in fixtures().into_iter().enumerate() {
        let t = twin.estimate(&group, a_max);
        let m = ml_est.estimate(&group, a_max);
        assert_eq!(t.feasible(), expect_feasible, "fixture {i}: unexpected twin verdict {t:?}");
        assert_eq!(
            m.feasible(),
            t.feasible(),
            "fixture {i}: ml and twin disagree on feasibility (ml {m:?} vs twin {t:?})"
        );
    }
}

#[test]
fn oracle_replays_recorded_twin_estimates_exactly() {
    let twin = twin_estimator();
    let mut oracle = OracleEstimator::new();
    for (group, a_max, _) in fixtures() {
        oracle.record_from(&twin, &group, a_max);
    }
    for (i, (group, a_max, _)) in fixtures().into_iter().enumerate() {
        let t = twin.estimate(&group, a_max);
        let o = oracle.estimate(&group, a_max);
        assert_eq!(
            o.throughput_tok_s.to_bits(),
            t.throughput_tok_s.to_bits(),
            "fixture {i}: oracle must reproduce the twin throughput bit-for-bit"
        );
        assert_eq!(o.starved, t.starved, "fixture {i}");
        assert_eq!(o.memory_error, t.memory_error, "fixture {i}");
    }
}

#[test]
fn greedy_places_through_the_twin_estimator_directly() {
    // The DT-in-the-loop ablation: skip the ML stage entirely and let
    // Alg. 1 probe the twin (ms per probe instead of µs, no learning
    // error).
    let twin = twin_estimator().horizon(5.0);
    let adapters = WorkloadSpec::heterogeneous(16, &[8], &[0.05, 0.025], 9);
    let p = plan(&adapters, 4, &twin, &MinGpus).expect("light workload feasible via the DT");
    assert_eq!(p.assignment.len(), 16);
    assert!(p.gpus_used() >= 1);
}

#[test]
fn cached_twin_greedy_is_bit_identical_and_memoizes() {
    // The caching seam contract: memoizing the DT-in-the-loop probes must
    // not change a single bit of the planning outcome or the estimates.
    let twin = twin_estimator().horizon(5.0);
    let cached = CachedEstimator::wrap(twin_estimator().horizon(5.0));
    let adapters = WorkloadSpec::heterogeneous(24, &[8, 16], &[0.05, 0.025], 9);
    let p = plan(&adapters, 4, &twin, &MinGpus).expect("feasible via the DT");
    let pc = plan(&adapters, 4, &cached, &MinGpus).expect("feasible via the cached DT");
    assert_eq!(p, pc, "cached and uncached twin planning must agree exactly");
    // Even after planning warmed the memo, direct estimates replay the
    // uncached twin bit-for-bit.
    for a_max in [8usize, 16, 32] {
        let t = twin.estimate(&adapters, a_max);
        let c = cached.estimate(&adapters, a_max);
        assert_eq!(t.throughput_tok_s.to_bits(), c.throughput_tok_s.to_bits());
        assert_eq!(t.starved, c.starved);
        assert_eq!(t.memory_error, c.memory_error);
    }
    let stats = cached.stats();
    assert!(stats.hits > 0, "Alg. 1's adjacent probes must hit the memo: {stats:?}");
}

#[test]
fn parallel_probing_plans_and_replans_bit_identically_to_serial() {
    // The probe fan-out contract: fanning candidate probes over worker
    // threads must not change a single bit of the planning outcome, and
    // first-occurrence miss accounting keeps even the cache counters
    // identical to a serial pass.
    let adapters = WorkloadSpec::heterogeneous(32, &[8, 16], &[0.1, 0.05, 0.025], 13);
    let serial = CachedEstimator::wrap(twin_estimator().horizon(5.0)).probe_workers(1);
    let parallel = CachedEstimator::wrap(twin_estimator().horizon(5.0)).probe_workers(4);
    let ps = plan(&adapters, 4, &serial, &MinGpus).expect("feasible via serial probing");
    let pp = plan(&adapters, 4, &parallel, &MinGpus).expect("feasible via parallel probing");
    assert_eq!(ps, pp, "parallel probing changed the greedy plan");
    assert_eq!(serial.stats(), parallel.stats(), "fan-out must not change probe accounting");

    // Same contract through the incremental replanner: drift some rates
    // and repair the serial plan with both estimators.
    let mut moved = adapters.clone();
    for a in moved.iter_mut().filter(|a| a.id % 5 == 0) {
        a.rate *= 2.0;
    }
    let params = replan::ReplanParams::default();
    let rs = replan_with_ledger(Some(&ps), &moved, 4, &serial, &params, &MinGpus, None)
        .expect("serial replan");
    let rp = replan_with_ledger(Some(&ps), &moved, 4, &parallel, &params, &MinGpus, None)
        .expect("parallel replan");
    assert_eq!(rs.placement, rp.placement, "parallel probing changed the repaired placement");
    assert_eq!(rs.migrations, rp.migrations);
    assert_eq!(rs.migration_cost_s.to_bits(), rp.migration_cost_s.to_bits());
}
