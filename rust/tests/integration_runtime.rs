//! Integration tests over the execution backend and the serving engine.
//! They run against whatever backend `runtime::load_backend` selects: the
//! pure-Rust reference backend on a bare checkout, PJRT when the `pjrt`
//! feature is enabled and `make artifacts` has produced a manifest.

use adapter_serving::config::EngineConfig;
use adapter_serving::dt::{self, LengthVariant};
use adapter_serving::engine::Engine;
use adapter_serving::runtime::{load_backend, Backend, Manifest};
use adapter_serving::workload::{Arrival, WorkloadSpec};

/// Backends are not required to be Send (PJRT handles are not), so each
/// test loads its own instance.
fn runtime() -> Box<dyn Backend> {
    load_backend(&Manifest::default_dir(), "pico-llama").expect("backend load")
}

#[test]
fn decode_executes_all_buckets_with_sane_outputs() {
    let mut rt = runtime();
    let meta = rt.meta().clone();
    for &b in &[1usize, 2, 64] {
        let tokens = vec![3i32; b];
        let n = meta.n_layers * b * meta.window * meta.d_model;
        let k = vec![0.01f32; n];
        let v = vec![0.02f32; n];
        let ctx = vec![5i32; b];
        let slot = vec![0i32; b];
        let out = rt.decode(b, &tokens, &k, &v, &ctx, &slot).expect("decode");
        assert_eq!(out.next_tokens.len(), b);
        assert_eq!(out.new_k.len(), meta.n_layers * b * meta.d_model);
        assert!(out.next_tokens.iter().all(|&t| (0..meta.vocab as i32).contains(&t)));
        assert!(out.new_k.iter().all(|x| x.is_finite()));
    }
}

#[test]
fn identical_rows_produce_identical_outputs() {
    // Batch invariance: two identical requests in one batch must get the
    // same next token and K/V rows (checks slot/window indexing).
    let mut rt = runtime();
    let meta = rt.meta().clone();
    let b = 4usize;
    let (l, d, w) = (meta.n_layers, meta.d_model, meta.window);
    let mut k = vec![0f32; l * b * w * d];
    let mut v = vec![0f32; l * b * w * d];
    // Same window content for all rows.
    for li in 0..l {
        for row in 0..b {
            for t in 0..6 {
                for x in 0..d {
                    let idx = ((li * b + row) * w + t) * d + x;
                    k[idx] = (t * d + x) as f32 * 1e-3;
                    v[idx] = -(x as f32) * 1e-3;
                }
            }
        }
    }
    let out = rt
        .decode(b, &[7, 7, 7, 7], &k, &v, &[6, 6, 6, 6], &[0, 0, 0, 0])
        .expect("decode");
    for row in 1..b {
        assert_eq!(out.next_tokens[row], out.next_tokens[0]);
        for li in 0..l {
            let a0 = (li * b) * d;
            let ar = (li * b + row) * d;
            assert_eq!(out.new_k[a0..a0 + d], out.new_k[ar..ar + d]);
        }
    }
}

#[test]
fn prefill_roundtrip_through_runtime() {
    let mut rt = runtime();
    let meta = rt.meta().clone();
    let bucket = 32usize;
    let mut tokens = vec![0i32; bucket];
    for (i, t) in tokens.iter_mut().enumerate().take(10) {
        *t = (i % meta.vocab) as i32;
    }
    let out = rt.prefill(bucket, &tokens, 10, 0).expect("prefill");
    assert_eq!(out.k.len(), meta.n_layers * bucket * meta.d_model);
    assert!((0..meta.vocab as i32).contains(&out.next_token));
}

#[test]
fn engine_completes_requests_and_counts_tokens_exactly() {
    let mut rt = runtime();
    let adapters = vec![adapter_serving::workload::AdapterSpec { id: 0, rank: 8, rate: 0.0 }];
    let spec = WorkloadSpec::fixed_len(adapters, 40, 12, 1e9, 1);
    let trace: Vec<Arrival> = (0..6)
        .map(|i| Arrival {
            request_id: i,
            time_s: 0.0,
            adapter_id: 0,
            input_len: 40,
            output_len: 12,
        })
        .collect();
    let cfg = EngineConfig { a_max: 4, s_max_rank: 8, ..Default::default() };
    let mut engine = Engine::new(cfg, &mut rt);
    let res = engine.run_trace(&spec, &trace).expect("run");
    let rep = res.report.expect("feasible");
    assert_eq!(rep.completed, 6);
    assert_eq!(rep.input_tokens, 6 * 40);
    assert_eq!(rep.output_tokens, 6 * 12);
    assert!(rep.ttft_mean_s > 0.0);
}

#[test]
fn engine_preempts_and_recovers_under_memory_pressure() {
    let mut rt = runtime();
    let adapters = vec![adapter_serving::workload::AdapterSpec { id: 0, rank: 8, rate: 0.0 }];
    let mut spec = WorkloadSpec::fixed_len(adapters, 96, 64, 1e9, 1);
    // Tiny pool: 512 tokens → ~3 concurrent requests of 160 tokens.
    spec.horizon_s = 1e9;
    let trace: Vec<Arrival> = (0..8)
        .map(|i| Arrival {
            request_id: i,
            time_s: 0.0,
            adapter_id: 0,
            input_len: 96,
            output_len: 64,
        })
        .collect();
    let mut cfg = EngineConfig { a_max: 4, s_max_rank: 8, ..Default::default() };
    cfg.mem.total_tokens = 512;
    let mut engine = Engine::new(cfg, &mut rt);
    let res = engine.run_trace(&spec, &trace).expect("run");
    let rep = res.report.expect("feasible config");
    // All requests must still complete (preemption = recompute, not drop).
    assert_eq!(rep.completed, 8, "{}", rep.summary());
}

#[test]
fn engine_reports_memory_error_for_over_reservation() {
    let mut rt = runtime();
    let spec = WorkloadSpec::sharegpt_like(WorkloadSpec::homogeneous(4, 32, 0.1), 5.0, 1);
    let cfg = EngineConfig { a_max: 384, s_max_rank: 32, ..Default::default() };
    let mut engine = Engine::new(cfg, &mut rt);
    let res = engine.run(&spec).expect("run");
    assert!(res.memory_error);
    assert!(res.report.is_none());
}

#[test]
fn engine_and_twin_agree_on_feasibility_of_the_same_trace() {
    let mut rt = runtime();
    // Light load (~350 tok/s, well under capacity) so the *default*
    // calibration's pessimism cannot flip feasibility; exact-latency
    // agreement is covered by the table1 experiment with a fitted
    // calibration.
    let adapters = WorkloadSpec::heterogeneous(12, &[8, 16], &[0.1, 0.05], 9);
    let spec = WorkloadSpec::sharegpt_like(adapters, 8.0, 10);
    let trace = spec.trace();
    let cfg = EngineConfig { a_max: 12, s_max_rank: 16, ..Default::default() };
    let mut engine = Engine::new(cfg.clone(), &mut rt);
    let eres = engine.run_trace(&spec, &trace).expect("engine");
    let erep = eres.report.expect("feasible");
    // Prefer the fitted calibration when a prior `adapterd calibrate` /
    // bench run cached one; the built-in default is deliberately
    // pessimistic, so with it we only require feasibility agreement.
    let fitted = dt::Calibration::load_file(
        std::path::Path::new("results/calibration_pico-llama.json"),
        "pico-llama",
    );
    let calibrated = fitted.is_ok();
    let calib = fitted.unwrap_or_default();
    let tres = dt::run_twin_trace(&cfg, &calib, &spec, &trace);
    let trep = tres.report.expect("twin feasible");
    assert_eq!(erep.starved, trep.starved);
    if calibrated {
        // Same trace + calibrated latencies → same completion count.
        assert_eq!(erep.completed, trep.completed);
    }
}
