//! PJRT-free pipeline integration: Digital Twin → dataset → ML training →
//! greedy placement → twin validation, end to end with the built-in
//! default calibration (no artifacts required).

use adapter_serving::cluster;
use adapter_serving::config::EngineConfig;
use adapter_serving::dt::{Calibration, LengthVariant};
use adapter_serving::ml::{self, dataset::GridSpec, MlModels};
use adapter_serving::placement::{baselines, greedy, latency};
use adapter_serving::workload::WorkloadSpec;

fn small_grid() -> GridSpec {
    GridSpec {
        sizes: vec![8, 16, 32],
        rates: vec![0.8, 0.2, 0.05, 0.0125],
        adapter_counts: vec![8, 16, 32, 64, 96, 128],
        a_max_values: vec![8, 16, 32, 64, 96, 128],
        horizon_s: 10.0,
        max_scenarios: 400,
        seed: 99,
    }
}

fn trained_models(samples: &[ml::Sample]) -> MlModels {
    let (thr, _) = ml::train(samples, ml::Task::Throughput, ml::ModelType::RandomForest, true, 3);
    let (st, _) = ml::train(samples, ml::Task::Starvation, ml::ModelType::RandomForest, true, 3);
    MlModels { throughput: thr, starvation: st, scaler: None }
}

#[test]
fn dt_dataset_train_place_validate() {
    let calib = Calibration::default();
    let base = EngineConfig::default();
    let samples = ml::dataset::generate(&calib, &base, &small_grid(), 4);
    assert!(samples.len() >= 300);
    let starved = samples.iter().filter(|s| s.starved).count();
    assert!(starved > 0 && starved < samples.len(), "degenerate labels: {starved}");

    let models = trained_models(&samples);

    // Comfortably feasible workload (≈700 tok/s incoming vs ≈1 k tok/s per
    // GPU) → placement exists and validates on the twin.
    let adapters = WorkloadSpec::heterogeneous(48, &[8, 16], &[0.05, 0.025], 7);
    let spec = WorkloadSpec::sharegpt_like(adapters.clone(), 15.0, 8);
    let p = greedy::place(&adapters, 4, &models).expect("feasible placement");
    assert_eq!(p.assignment.len(), 48);
    let opts = cluster::RunOptions::new();
    let rep = cluster::serve_on_twin(&calib, &base, &p, &spec, LengthVariant::Original, opts);
    assert!(!rep.memory_error, "greedy placement must never OOM");
    // The greedy target: feasible serving on the used GPUs.
    assert!(
        !rep.starved,
        "greedy allocation starved: thr={:.0} gpus={}",
        rep.total_throughput_tok_s, rep.gpus_used
    );
}

#[test]
fn greedy_uses_fewer_gpus_than_latency_oriented_variants() {
    let calib = Calibration::default();
    let base = EngineConfig::default();
    let samples = ml::dataset::generate(&calib, &base, &small_grid(), 4);
    let models = trained_models(&samples);

    // Light workload: greedy should pack few GPUs; ProposedLat spreads.
    let adapters = WorkloadSpec::heterogeneous(24, &[8], &[0.05, 0.025], 17);
    let p_greedy = greedy::place(&adapters, 4, &models).expect("greedy");
    let p_lat = latency::place(&adapters, 4, &models).expect("latency");
    assert!(p_greedy.gpus_used() <= p_lat.gpus_used());
    assert_eq!(p_lat.gpus_used(), 4, "ProposedLat uses all GPUs by design");
}

#[test]
fn random_baseline_is_less_reliable_than_greedy() {
    let calib = Calibration::default();
    let base = EngineConfig::default();
    let samples = ml::dataset::generate(&calib, &base, &small_grid(), 4);
    let models = trained_models(&samples);

    // Moderately heavy workload with large adapters.
    let adapters = WorkloadSpec::heterogeneous(96, &[32], &[0.1, 0.05], 23);
    let spec = WorkloadSpec::sharegpt_like(adapters.clone(), 12.0, 24);

    // The hard guarantee the pipeline provides is avoiding *memory errors*
    // (OOM configurations are labelled starved with zero throughput in the
    // training data, a strong signal); starvation avoidance is statistical
    // with the quick training grid (see EXPERIMENTS.md Table 3 notes).
    let greedy_safe = match greedy::place(&adapters, 4, &models) {
        Ok(p) => {
            let opts = cluster::RunOptions::new();
            let rep =
                cluster::serve_on_twin(&calib, &base, &p, &spec, LengthVariant::Original, opts);
            !rep.memory_error
        }
        Err(_) => true, // declining is also a safe answer
    };
    assert!(greedy_safe, "greedy produced an OOM allocation");

    // Random with A_max up to the per-GPU count frequently over-reserves
    // rank-32 slots → memory errors; count failures over several seeds.
    let mut failures = 0;
    for seed in 0..6 {
        let p = baselines::random(&adapters, 4, seed).unwrap();
        let opts = cluster::RunOptions::new();
        let rep = cluster::serve_on_twin(&calib, &base, &p, &spec, LengthVariant::Original, opts);
        if !rep.feasible() {
            failures += 1;
        }
    }
    assert!(failures > 0, "expected Random to fail at least once over 6 seeds");
}
