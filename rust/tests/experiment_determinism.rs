//! Double-run bit-identity: the experiment harness must produce *byte
//! identical* CSV artifacts when run twice from cold caches.  This is the
//! end-to-end check behind the determinism contract (DESIGN.md §13) that
//! detlint enforces statically: no hash-order iteration, no wall-clock
//! reads and no ambient entropy may leak into results.
//!
//! Both experiments run twin-backed at quick scale on the reference
//! backend, and their CSVs carry no wall-clock columns (the waived
//! `plan_wall_s`-style accounting goes to stdout/summary only), so a full
//! byte compare is valid.

use adapter_serving::experiments::{self, ExpContext, Scale};
use std::path::PathBuf;

/// A fresh ExpContext writing under `target/tmp/<tag>-<pid>-<run>/`.
fn fresh_ctx(tag: &str, run: usize) -> (ExpContext, PathBuf) {
    let base = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/../target/tmp"));
    let dir = base.join(format!("{tag}-{}-{run}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create temp out_dir");
    let mut ctx = ExpContext::new(Scale::Quick);
    ctx.out_dir = dir.clone();
    (ctx, dir)
}

/// Run experiment `id` twice into independent cold-cache dirs and assert
/// the named CSV artifact is byte-identical across the runs.
fn assert_double_run_identical(id: &str, csv: &str) {
    let mut outputs = vec![];
    for run in 0..2 {
        let (ctx, dir) = fresh_ctx(id, run);
        experiments::run(id, &ctx).unwrap_or_else(|e| panic!("experiment {id} run {run}: {e}"));
        let path = dir.join(id).join(csv);
        let bytes =
            std::fs::read(&path).unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
        assert!(!bytes.is_empty(), "{id}/{csv} is empty");
        outputs.push((dir, bytes));
    }
    assert_eq!(
        outputs[0].1, outputs[1].1,
        "{id}/{csv} differs between two cold-cache runs — a nondeterministic \
         input (hash order, wall clock, ambient entropy) leaked into results; \
         run `cargo run -p detlint -- --check` and see DESIGN.md §13"
    );
    for (dir, _) in outputs {
        let _ = std::fs::remove_dir_all(dir);
    }
}

#[test]
fn fleet_experiment_is_bit_identical_across_runs() {
    assert_double_run_identical("fleet", "fleet.csv");
}

#[test]
fn fig11_experiment_is_bit_identical_across_runs() {
    assert_double_run_identical("fig11", "fig11.csv");
}
