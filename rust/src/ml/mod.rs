//! The ML learning phase (paper §6): from-scratch Random Forest, KNN and
//! SVM trained on Digital-Twin-generated data with successive-halving grid
//! search and 5-fold CV, plus the §6.1 refinement into interpretable
//! shallow trees with a compiled flat-array evaluator.

pub mod cv;
pub mod dataset;
pub mod features;
pub mod forest;
pub mod knn;
pub mod metrics;
pub mod model;
pub mod refine;
pub mod scaler;
pub mod svm;
pub mod train;
pub mod tree;

pub use dataset::{GridSpec, Sample};
pub use features::{features, FEATURE_NAMES, N_FEATURES};
pub use model::{load_models, save_models, MlModels, Predictor};
pub use train::{train, ModelType, Task};
