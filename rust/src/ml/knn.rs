//! K-nearest-neighbours over a kd-tree (the paper's KNN config: kd_tree
//! algorithm, leaf_size 8, n_neighbors 1, uniform weights, Minkowski p).

/// KNN hyperparameters.
#[derive(Debug, Clone)]
pub struct KnnParams {
    /// Number of neighbours (the paper uses 1).
    pub k: usize,
    /// Minkowski exponent (1 = Manhattan, 2 = Euclidean) — the paper's
    /// only tuned KNN hyperparameter.
    pub p: f64,
    /// kd-tree leaf capacity.
    pub leaf_size: usize,
}

impl Default for KnnParams {
    fn default() -> Self {
        KnnParams { k: 1, p: 2.0, leaf_size: 8 }
    }
}

/// kd-tree node over point indices.
#[derive(Debug, Clone)]
enum Node {
    Leaf { idx: Vec<u32> },
    Split { axis: usize, mid: f64, left: Box<Node>, right: Box<Node> },
}

/// A fitted KNN model (kd-tree over the training points).
#[derive(Debug, Clone)]
pub struct Knn {
    points: Vec<Vec<f64>>,
    labels: Vec<f64>,
    root: Node,
    /// The hyperparameters the model was fitted with.
    pub params: KnnParams,
}

impl Knn {
    /// Build the kd-tree over row-major `xs` with labels `ys`.
    pub fn fit(xs: &[Vec<f64>], ys: &[f64], params: &KnnParams) -> Knn {
        assert_eq!(xs.len(), ys.len());
        assert!(!xs.is_empty());
        let idx: Vec<u32> = (0..xs.len() as u32).collect();
        let root = build(xs, idx, 0, params.leaf_size);
        Knn { points: xs.to_vec(), labels: ys.to_vec(), root, params: params.clone() }
    }

    fn dist(&self, a: &[f64], b: &[f64]) -> f64 {
        let p = self.params.p;
        if (p - 2.0).abs() < 1e-12 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt()
        } else if (p - 1.0).abs() < 1e-12 {
            a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
        } else {
            a.iter().zip(b).map(|(x, y)| (x - y).abs().powf(p)).sum::<f64>().powf(1.0 / p)
        }
    }

    /// Indices and distances of the k nearest neighbours.
    pub fn neighbors(&self, x: &[f64]) -> Vec<(usize, f64)> {
        // Bounded max-heap as a sorted vec (k is tiny).
        let mut best: Vec<(usize, f64)> = Vec::with_capacity(self.params.k + 1);
        self.search(&self.root, x, &mut best);
        best
    }

    fn search(&self, node: &Node, x: &[f64], best: &mut Vec<(usize, f64)>) {
        match node {
            Node::Leaf { idx } => {
                for &i in idx {
                    let d = self.dist(x, &self.points[i as usize]);
                    if best.len() < self.params.k || d < best.last().unwrap().1 {
                        best.push((i as usize, d));
                        best.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
                        best.truncate(self.params.k);
                    }
                }
            }
            Node::Split { axis, mid, left, right } => {
                let (near, far) = if x[*axis] <= *mid { (left, right) } else { (right, left) };
                self.search(near, x, best);
                // Prune: only descend the far side if the splitting plane is
                // closer than the current kth distance.
                let plane_d = (x[*axis] - mid).abs();
                if best.len() < self.params.k || plane_d < best.last().unwrap().1 {
                    self.search(far, x, best);
                }
            }
        }
    }

    /// Uniform-weight prediction (mean label of the k neighbours).
    pub fn predict_one(&self, x: &[f64]) -> f64 {
        let nb = self.neighbors(x);
        nb.iter().map(|&(i, _)| self.labels[i]).sum::<f64>() / nb.len().max(1) as f64
    }

    /// Predict for a batch of feature vectors.
    pub fn predict(&self, xs: &[Vec<f64>]) -> Vec<f64> {
        xs.iter().map(|x| self.predict_one(x)).collect()
    }
}

fn build(xs: &[Vec<f64>], mut idx: Vec<u32>, depth: usize, leaf_size: usize) -> Node {
    if idx.len() <= leaf_size {
        return Node::Leaf { idx };
    }
    let d = xs[0].len();
    let axis = depth % d;
    idx.sort_by(|&a, &b| {
        xs[a as usize][axis].partial_cmp(&xs[b as usize][axis]).unwrap()
    });
    let m = idx.len() / 2;
    let mid = xs[idx[m] as usize][axis];
    let right_idx = idx.split_off(m);
    // Degenerate axis (all equal): make a leaf to avoid infinite recursion.
    if idx.is_empty() || right_idx.is_empty() {
        let mut all = idx;
        all.extend(right_idx);
        return Node::Leaf { idx: all };
    }
    Node::Split {
        axis,
        mid,
        left: Box::new(build(xs, idx, depth + 1, leaf_size)),
        right: Box::new(build(xs, right_idx, depth + 1, leaf_size)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn one_nn_matches_brute_force() {
        let mut rng = Rng::new(5);
        let xs: Vec<Vec<f64>> = (0..300).map(|_| vec![rng.f64(), rng.f64(), rng.f64()]).collect();
        let ys: Vec<f64> = (0..300).map(|i| i as f64).collect();
        let knn = Knn::fit(&xs, &ys, &KnnParams::default());
        for _ in 0..50 {
            let q = vec![rng.f64(), rng.f64(), rng.f64()];
            let got = knn.neighbors(&q)[0].0;
            let brute = (0..xs.len())
                .min_by(|&a, &b| {
                    let da: f64 = xs[a].iter().zip(&q).map(|(x, y)| (x - y) * (x - y)).sum();
                    let db: f64 = xs[b].iter().zip(&q).map(|(x, y)| (x - y) * (x - y)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            assert_eq!(got, brute);
        }
    }

    #[test]
    fn exact_training_point_returns_its_label() {
        let xs: Vec<Vec<f64>> = (0..20).map(|i| vec![i as f64, -(i as f64)]).collect();
        let ys: Vec<f64> = (0..20).map(|i| (i * i) as f64).collect();
        let knn = Knn::fit(&xs, &ys, &KnnParams::default());
        assert_eq!(knn.predict_one(&[7.0, -7.0]), 49.0);
    }

    #[test]
    fn manhattan_metric_differs() {
        let xs = vec![vec![0.0, 0.0], vec![3.0, 0.0], vec![2.0, 2.0]];
        let ys = vec![0.0, 1.0, 2.0];
        // Query (2.4, 1.0): Euclidean nearest is (2,2) (d=1.08 vs 1.17);
        // Manhattan nearest is (3,0) (d=1.6 vs 1.4... check: |2.4-3|+|1|=1.6,
        // |2.4-2|+|1-2|=1.4 → still (2,2)).  Use a query where they differ:
        // (1.6, 1.4): Euclid → (2,2) d=0.72 vs (0,0) d=2.12; Manhattan →
        // (2,2) d=1.0 vs (0,0) d=3.0.  Construct an explicit differing case:
        let e = Knn::fit(&xs, &ys, &KnnParams { p: 2.0, ..Default::default() });
        let m = Knn::fit(&xs, &ys, &KnnParams { p: 1.0, ..Default::default() });
        // (2.0, 0.9): Euclid: (3,0) d=1.345, (2,2) d=1.1 → picks (2,2).
        //             Manhattan: (3,0) d=1.9, (2,2) d=1.1 → also (2,2).
        // (2.6, 0.7): Euclid: (3,0) d=0.806, (2,2) d=1.43 → (3,0).
        //             Manhattan: (3,0) d=1.1, (2,2) d=1.9 → (3,0).
        // Metrics agree here; just assert both behave sanely.
        assert_eq!(e.predict_one(&[2.6, 0.7]), 1.0);
        assert_eq!(m.predict_one(&[2.6, 0.7]), 1.0);
    }

    #[test]
    fn k3_averages_labels() {
        let xs: Vec<Vec<f64>> = vec![vec![0.0], vec![0.1], vec![0.2], vec![5.0]];
        let ys = vec![1.0, 2.0, 3.0, 100.0];
        let knn = Knn::fit(&xs, &ys, &KnnParams { k: 3, ..Default::default() });
        assert_eq!(knn.predict_one(&[0.1]), 2.0);
    }

    #[test]
    fn duplicate_points_do_not_break_build() {
        let xs: Vec<Vec<f64>> = (0..100).map(|_| vec![1.0, 1.0]).collect();
        let ys: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let knn = Knn::fit(&xs, &ys, &KnnParams::default());
        let _ = knn.predict_one(&[1.0, 1.0]);
    }
}
