//! Unified predictor interface consumed by the placement layer, plus JSON
//! persistence for the tree-family models (the ones deployed in the
//! pipeline; KNN/SVM are evaluated in-process by the Table-3 experiment).

use super::forest::Forest;
use super::knn::Knn;
use super::refine::FlatTree;
use super::scaler::Scaler;
use super::svm::{Svc, Svr};
use super::tree::Tree;
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::path::Path;

/// Any trained model, normalized to `predict_one(&[f64]) -> f64`
/// (regression value, or class-1 probability / label for classification).
pub enum Predictor {
    /// Random forest (the deployed pipeline default).
    Forest(Forest),
    /// Single CART tree.
    Tree(Tree),
    /// Compiled flat-array tree (Small Tree**, §6.1).
    Flat(FlatTree),
    /// k-nearest-neighbours (Table 3 comparison).
    Knn(Box<Knn>),
    /// SVM classifier (Table 3 comparison).
    Svc(Box<Svc>),
    /// SVM regressor (Table 3 comparison).
    Svr(Box<Svr>),
}

impl Predictor {
    /// Predict for one feature vector.
    pub fn predict_one(&self, x: &[f64]) -> f64 {
        match self {
            Predictor::Forest(m) => m.predict_one(x),
            Predictor::Tree(m) => m.predict_one(x),
            Predictor::Flat(m) => m.predict_one(x),
            Predictor::Knn(m) => m.predict_one(x),
            Predictor::Svc(m) => m.predict_one(x),
            Predictor::Svr(m) => m.predict_one(x),
        }
    }

    /// Predict for a batch of feature vectors.
    pub fn predict(&self, xs: &[Vec<f64>]) -> Vec<f64> {
        xs.iter().map(|x| self.predict_one(x)).collect()
    }

    /// Short tag of the underlying model family (for reports).
    pub fn kind(&self) -> &'static str {
        match self {
            Predictor::Forest(_) => "forest",
            Predictor::Tree(_) => "tree",
            Predictor::Flat(_) => "flat",
            Predictor::Knn(_) => "knn",
            Predictor::Svc(_) => "svc",
            Predictor::Svr(_) => "svr",
        }
    }
}

/// The deployed model pair (paper §6): a throughput regressor and a
/// starvation classifier, with an optional shared scaler.
///
/// ```
/// use adapter_serving::ml::tree::{Tree, TreeParams};
/// use adapter_serving::ml::{MlModels, Predictor};
/// // Fit a toy pair: throughput = 2·x0, never starving.
/// let xs: Vec<Vec<f64>> = (0..50).map(|i| vec![i as f64]).collect();
/// let thr: Vec<f64> = xs.iter().map(|x| x[0] * 2.0).collect();
/// let st = vec![0.0; 50];
/// let models = MlModels {
///     throughput: Predictor::Tree(Tree::fit(&xs, &thr, &TreeParams::default())),
///     starvation: Predictor::Tree(Tree::fit(&xs, &st, &TreeParams::default())),
///     scaler: None,
/// };
/// assert!(models.predict_throughput(&[10.0]) > 0.0);
/// assert!(!models.predict_starvation(&[10.0]));
/// ```
pub struct MlModels {
    /// Throughput regressor (tok/s).
    pub throughput: Predictor,
    /// Starvation classifier (class-1 probability ≥ 0.5 → starved).
    pub starvation: Predictor,
    /// Optional feature scaler applied before both models.
    pub scaler: Option<Scaler>,
}

impl MlModels {
    /// Predicted throughput (tok/s) for a feature vector.
    pub fn predict_throughput(&self, x: &[f64]) -> f64 {
        match &self.scaler {
            Some(s) => self.throughput.predict_one(&s.transform_one(x)),
            None => self.throughput.predict_one(x),
        }
    }

    /// Predicted starvation verdict for a feature vector.
    pub fn predict_starvation(&self, x: &[f64]) -> bool {
        let p = match &self.scaler {
            Some(s) => self.starvation.predict_one(&s.transform_one(x)),
            None => self.starvation.predict_one(x),
        };
        p >= 0.5
    }
}

// ---------------------------------------------------------------------
// JSON persistence (tree family)
// ---------------------------------------------------------------------

/// Serialize a tree's flat arrays to JSON.
pub fn tree_to_json(t: &Tree) -> Json {
    Json::obj(vec![
        ("feature", Json::arr_f64(&t.feature.iter().map(|&v| v as f64).collect::<Vec<_>>())),
        ("threshold", Json::arr_f64(&t.threshold)),
        ("left", Json::arr_f64(&t.left.iter().map(|&v| v as f64).collect::<Vec<_>>())),
        ("right", Json::arr_f64(&t.right.iter().map(|&v| v as f64).collect::<Vec<_>>())),
        ("value", Json::arr_f64(&t.value)),
        ("n_samples", Json::arr_f64(&t.n_samples.iter().map(|&v| v as f64).collect::<Vec<_>>())),
    ])
}

/// Parse a tree written by [`tree_to_json`].
pub fn tree_from_json(j: &Json) -> Result<Tree> {
    let f = |k: &str| -> Result<Vec<f64>> {
        j.req(k)?.f64_vec().ok_or_else(|| anyhow!("{k} not an array"))
    };
    Ok(Tree {
        feature: f("feature")?.into_iter().map(|v| v as i32).collect(),
        threshold: f("threshold")?,
        left: f("left")?.into_iter().map(|v| v as u32).collect(),
        right: f("right")?.into_iter().map(|v| v as u32).collect(),
        value: f("value")?,
        n_samples: f("n_samples")?.into_iter().map(|v| v as u32).collect(),
    })
}

/// Serialize a forest (array of trees) to JSON.
pub fn forest_to_json(f: &Forest) -> Json {
    Json::Arr(f.trees.iter().map(tree_to_json).collect())
}

/// Parse a forest written by [`forest_to_json`].
pub fn forest_from_json(j: &Json) -> Result<Forest> {
    let arr = j.as_arr().ok_or_else(|| anyhow!("forest not an array"))?;
    Ok(Forest { trees: arr.iter().map(tree_from_json).collect::<Result<_>>()? })
}

/// Save a throughput/starvation model pair (forest or tree flavor).
pub fn save_models(models: &MlModels, path: &Path) -> Result<()> {
    let enc = |p: &Predictor| -> Result<Json> {
        Ok(match p {
            Predictor::Forest(f) => {
                Json::obj(vec![("kind", Json::Str("forest".into())), ("data", forest_to_json(f))])
            }
            Predictor::Tree(t) => {
                Json::obj(vec![("kind", Json::Str("tree".into())), ("data", tree_to_json(t))])
            }
            Predictor::Flat(_) => anyhow::bail!("persist the Tree; Flat is compiled at load"),
            _ => anyhow::bail!("only tree-family models are persisted"),
        })
    };
    let mut fields = vec![
        ("throughput", enc(&models.throughput)?),
        ("starvation", enc(&models.starvation)?),
    ];
    if let Some(s) = &models.scaler {
        fields.push(("scaler", s.to_json()));
    }
    Json::obj(fields).write_file(path)
}

/// Load a model pair persisted by [`save_models`].
pub fn load_models(path: &Path) -> Result<MlModels> {
    let j = Json::read_file(path)?;
    let dec = |j: &Json| -> Result<Predictor> {
        let kind = j.req("kind")?.as_str().unwrap_or_default();
        let data = j.req("data")?;
        Ok(match kind {
            "forest" => Predictor::Forest(forest_from_json(data)?),
            "tree" => Predictor::Tree(tree_from_json(data)?),
            other => anyhow::bail!("unknown model kind '{other}'"),
        })
    };
    Ok(MlModels {
        throughput: dec(j.req("throughput")?)?,
        starvation: dec(j.req("starvation")?)?,
        scaler: j.get("scaler").map(Scaler::from_json).transpose()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::forest::ForestParams;
    use crate::ml::tree::TreeParams;

    fn tiny_data() -> (Vec<Vec<f64>>, Vec<f64>) {
        let xs: Vec<Vec<f64>> = (0..60).map(|i| vec![i as f64, (i % 5) as f64]).collect();
        let ys: Vec<f64> = xs.iter().map(|x| x[0] * 2.0).collect();
        (xs, ys)
    }

    #[test]
    fn save_load_roundtrip_preserves_predictions() {
        let (xs, ys) = tiny_data();
        let forest = Forest::fit(&xs, &ys, &ForestParams { n_estimators: 5, ..Default::default() });
        let tree = Tree::fit(&xs, &ys, &TreeParams::default());
        let models = MlModels {
            throughput: Predictor::Forest(forest),
            starvation: Predictor::Tree(tree),
            scaler: None,
        };
        let dir = std::env::temp_dir().join(format!("mlm_{}", std::process::id()));
        let path = dir.join("models.json");
        save_models(&models, &path).unwrap();
        let back = load_models(&path).unwrap();
        for x in xs.iter().take(10) {
            assert_eq!(models.predict_throughput(x), back.predict_throughput(x));
            assert_eq!(models.predict_starvation(x), back.predict_starvation(x));
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn flat_predictor_dispatch() {
        let (xs, ys) = tiny_data();
        let tree = Tree::fit(&xs, &ys, &TreeParams::default());
        let flat = crate::ml::refine::FlatTree::compile(&tree);
        let p = Predictor::Flat(flat);
        assert_eq!(p.predict_one(&xs[3]), tree.predict_one(&xs[3]));
        assert_eq!(p.kind(), "flat");
    }
}
