//! The refinement phase (paper §6.1): distill the best-performing model
//! into a single shallow decision tree with a bounded number of decision
//! rules, then "compile" it into a framework-free flat-array evaluator —
//! our analog of the paper's plain-Python + Numba step.

use super::tree::{Criterion, Tree, TreeParams};

/// Distill: re-grow a single tree on (xs, teacher-labels) under a hard
/// rule budget.  The paper penalizes complexity during hyperparameter
/// optimization; with our best-first builder the budget is exact.
pub fn distill(
    xs: &[Vec<f64>],
    teacher_labels: &[f64],
    criterion: Criterion,
    max_rules: usize,
) -> Tree {
    Tree::fit(
        xs,
        teacher_labels,
        &TreeParams {
            criterion,
            max_leaves: Some(max_rules),
            min_samples_leaf: 2,
            ..Default::default()
        },
    )
}

/// The "compiled" evaluator (Small Tree** in Table 4): one cache-dense
/// record per node, a single sign-bit branch per level, and unchecked
/// indexing — no bounds checks or extra arrays on the hot loop.
#[derive(Debug, Clone, Default)]
pub struct FlatTree {
    /// Packed nodes: (feature|-1, threshold-or-value, left, right).
    nodes: Vec<FlatNode>,
}

#[derive(Debug, Clone, Copy)]
struct FlatNode {
    /// Split feature; negative marks a leaf (then `thr` holds the value).
    feature: i32,
    left: u32,
    right: u32,
    thr: f64,
}

impl FlatTree {
    /// Compile a fitted [`Tree`] into the flat evaluator.
    pub fn compile(t: &Tree) -> FlatTree {
        FlatTree {
            nodes: (0..t.feature.len())
                .map(|i| FlatNode {
                    feature: t.feature[i],
                    left: t.left[i],
                    right: t.right[i],
                    thr: if t.feature[i] < 0 { t.value[i] } else { t.threshold[i] },
                })
                .collect(),
        }
    }

    /// Predict for one feature vector (the Table 4 hot loop).
    #[inline]
    pub fn predict_one(&self, x: &[f64]) -> f64 {
        let mut node = 0usize;
        // SAFETY: indices were produced by Tree::fit and are in-bounds by
        // construction; x has N_FEATURES entries checked by the caller.
        unsafe {
            loop {
                let n = self.nodes.get_unchecked(node);
                if n.feature < 0 {
                    return n.thr;
                }
                node = if *x.get_unchecked(n.feature as usize) <= n.thr {
                    n.left as usize
                } else {
                    n.right as usize
                };
            }
        }
    }

    /// Predict for a batch of feature vectors.
    pub fn predict(&self, xs: &[Vec<f64>]) -> Vec<f64> {
        xs.iter().map(|x| self.predict_one(x)).collect()
    }

    /// Number of packed nodes.
    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn dataset() -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Rng::new(8);
        let xs: Vec<Vec<f64>> = (0..500)
            .map(|_| vec![rng.f64() * 10.0, rng.f64() * 10.0, rng.f64()])
            .collect();
        let ys: Vec<f64> =
            xs.iter().map(|x| x[0] * 2.0 + (x[1] > 5.0) as i32 as f64 * 10.0).collect();
        (xs, ys)
    }

    #[test]
    fn distilled_tree_respects_rule_budget() {
        let (xs, ys) = dataset();
        for budget in [8usize, 16, 32] {
            let t = distill(&xs, &ys, Criterion::Mse, budget);
            assert!(t.n_leaves() <= budget);
        }
    }

    #[test]
    fn flat_tree_matches_tree_exactly() {
        let (xs, ys) = dataset();
        let t = distill(&xs, &ys, Criterion::Mse, 32);
        let ft = FlatTree::compile(&t);
        for x in xs.iter().take(200) {
            assert_eq!(t.predict_one(x), ft.predict_one(x));
        }
    }

    #[test]
    fn more_rules_fit_better() {
        let (xs, ys) = dataset();
        let mse = |t: &Tree| -> f64 {
            xs.iter()
                .zip(&ys)
                .map(|(x, y)| (t.predict_one(x) - y) * (t.predict_one(x) - y))
                .sum::<f64>()
                / ys.len() as f64
        };
        let small = distill(&xs, &ys, Criterion::Mse, 4);
        let large = distill(&xs, &ys, Criterion::Mse, 64);
        assert!(mse(&large) <= mse(&small));
    }
}
