//! Evaluation metrics for the ML phase: macro-F1 (starvation detection)
//! and MSE/SMAPE (throughput regression; SMAPE lives in util::stats).

/// Macro-averaged F1 over binary labels in {0, 1}.
pub fn macro_f1(actual: &[f64], predicted: &[f64]) -> f64 {
    let f1_for = |positive: f64| -> f64 {
        let (mut tp, mut fp, mut fne) = (0.0, 0.0, 0.0);
        for (&a, &p) in actual.iter().zip(predicted) {
            let a = (a >= 0.5) as i32 as f64;
            let p = (p >= 0.5) as i32 as f64;
            if p == positive && a == positive {
                tp += 1.0;
            } else if p == positive && a != positive {
                fp += 1.0;
            } else if p != positive && a == positive {
                fne += 1.0;
            }
        }
        if tp == 0.0 {
            // No true positives: F1 is 0 unless the class is absent
            // entirely and never predicted (then it is vacuously perfect).
            if fp == 0.0 && fne == 0.0 {
                return 1.0;
            }
            return 0.0;
        }
        let prec = tp / (tp + fp);
        let rec = tp / (tp + fne);
        2.0 * prec * rec / (prec + rec)
    };
    (f1_for(1.0) + f1_for(0.0)) / 2.0
}

/// Fraction of matching binary labels (threshold 0.5).
pub fn accuracy(actual: &[f64], predicted: &[f64]) -> f64 {
    if actual.is_empty() {
        return 0.0;
    }
    actual
        .iter()
        .zip(predicted)
        .filter(|(a, p)| ((**a >= 0.5) as i32) == ((**p >= 0.5) as i32))
        .count() as f64
        / actual.len() as f64
}

/// Mean squared error.
pub fn mse(actual: &[f64], predicted: &[f64]) -> f64 {
    if actual.is_empty() {
        return 0.0;
    }
    actual.iter().zip(predicted).map(|(a, p)| (a - p) * (a - p)).sum::<f64>()
        / actual.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_predictions() {
        let y = vec![1.0, 0.0, 1.0, 0.0];
        assert_eq!(macro_f1(&y, &y), 1.0);
        assert_eq!(accuracy(&y, &y), 1.0);
        assert_eq!(mse(&y, &y), 0.0);
    }

    #[test]
    fn all_wrong_f1_zero() {
        let a = vec![1.0, 1.0, 0.0, 0.0];
        let p = vec![0.0, 0.0, 1.0, 1.0];
        assert_eq!(macro_f1(&a, &p), 0.0);
        assert_eq!(accuracy(&a, &p), 0.0);
    }

    #[test]
    fn imbalanced_majority_guess_penalized() {
        let a = vec![1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        let p = vec![0.0; 8];
        // Accuracy looks fine but macro-F1 exposes the missed positive.
        assert!(accuracy(&a, &p) > 0.8);
        assert!(macro_f1(&a, &p) < 0.5);
    }
}
