//! Support Vector Machines from scratch: SMO-trained SVC (binary
//! classification) and projected-gradient ε-SVR (regression), with linear /
//! RBF / polynomial / sigmoid kernels matching the paper's Appendix B grid.
//!
//! Intended for the dataset sizes the ML phase produces (10²-10³ training
//! rows after the halving schedule); kernels are evaluated on the fly.

use crate::util::rng::Rng;

/// SVM kernel (the paper's Appendix B candidate set).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Kernel {
    /// Plain dot product.
    Linear,
    /// Gaussian radial basis function.
    Rbf {
        /// Width parameter.
        gamma: f64,
    },
    /// Polynomial kernel `(γ·⟨a,b⟩ + c₀)^degree`.
    Poly {
        /// Scale of the dot product.
        gamma: f64,
        /// Polynomial degree.
        degree: f64,
        /// Constant offset.
        coef0: f64,
    },
    /// Sigmoid kernel `tanh(γ·⟨a,b⟩ + c₀)`.
    Sigmoid {
        /// Scale of the dot product.
        gamma: f64,
        /// Constant offset.
        coef0: f64,
    },
}

impl Kernel {
    /// Evaluate the kernel on two feature vectors.
    pub fn eval(&self, a: &[f64], b: &[f64]) -> f64 {
        let dot: f64 = a.iter().zip(b).map(|(x, y)| x * y).sum();
        match *self {
            Kernel::Linear => dot,
            Kernel::Rbf { gamma } => {
                let d2: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
                (-gamma * d2).exp()
            }
            Kernel::Poly { gamma, degree, coef0 } => (gamma * dot + coef0).powf(degree),
            Kernel::Sigmoid { gamma, coef0 } => (gamma * dot + coef0).tanh(),
        }
    }

    /// sklearn's gamma="scale": 1 / (d · Var(X)).
    pub fn scale_gamma(xs: &[Vec<f64>]) -> f64 {
        let d = xs[0].len();
        let n = xs.len() as f64;
        let mut var_sum = 0.0;
        for j in 0..d {
            let mean: f64 = xs.iter().map(|x| x[j]).sum::<f64>() / n;
            var_sum += xs.iter().map(|x| (x[j] - mean) * (x[j] - mean)).sum::<f64>() / n;
        }
        let v = var_sum / d as f64;
        if v < 1e-12 {
            1.0
        } else {
            1.0 / (d as f64 * v)
        }
    }
}

// ---------------------------------------------------------------------
// SVC (simplified SMO, Platt 1998 via the CS229 simplification)
// ---------------------------------------------------------------------

/// SVC hyperparameters.
#[derive(Debug, Clone)]
pub struct SvcParams {
    /// Box constraint (regularization strength).
    pub c: f64,
    /// The kernel.
    pub kernel: Kernel,
    /// KKT violation tolerance.
    pub tol: f64,
    /// SMO passes without progress before stopping.
    pub max_passes: usize,
    /// Seed for the SMO partner choice.
    pub seed: u64,
}

impl Default for SvcParams {
    fn default() -> Self {
        SvcParams { c: 1.0, kernel: Kernel::Rbf { gamma: 0.5 }, tol: 1e-3, max_passes: 5, seed: 0 }
    }
}

/// A fitted SVM binary classifier.
#[derive(Debug, Clone)]
pub struct Svc {
    support: Vec<Vec<f64>>,
    alpha_y: Vec<f64>,
    b: f64,
    kernel: Kernel,
}

impl Svc {
    /// Labels in {0, 1} (mapped internally to ±1).
    pub fn fit(xs: &[Vec<f64>], ys01: &[f64], p: &SvcParams) -> Svc {
        let n = xs.len();
        let ys: Vec<f64> = ys01.iter().map(|&y| if y >= 0.5 { 1.0 } else { -1.0 }).collect();
        // Degenerate single-class data: constant classifier.
        if ys.iter().all(|&y| y > 0.0) || ys.iter().all(|&y| y < 0.0) {
            return Svc { support: vec![], alpha_y: vec![], b: ys[0], kernel: p.kernel };
        }
        let mut alpha = vec![0.0f64; n];
        let mut b = 0.0f64;
        let mut rng = Rng::new(p.seed ^ 0x53C0);
        // Cache kernel rows lazily is overkill at our sizes; precompute K.
        let k_mat: Vec<Vec<f64>> = (0..n)
            .map(|i| (0..n).map(|j| p.kernel.eval(&xs[i], &xs[j])).collect())
            .collect();
        let f = |alpha: &[f64], b: f64, i: usize| -> f64 {
            let mut s = b;
            for j in 0..n {
                if alpha[j] != 0.0 {
                    s += alpha[j] * ys[j] * k_mat[i][j];
                }
            }
            s
        };
        let mut passes = 0;
        let mut iters = 0;
        while passes < p.max_passes && iters < 200 {
            iters += 1;
            let mut changed = 0;
            for i in 0..n {
                let ei = f(&alpha, b, i) - ys[i];
                if (ys[i] * ei < -p.tol && alpha[i] < p.c) || (ys[i] * ei > p.tol && alpha[i] > 0.0)
                {
                    let mut j = rng.below(n - 1);
                    if j >= i {
                        j += 1;
                    }
                    let ej = f(&alpha, b, j) - ys[j];
                    let (ai_old, aj_old) = (alpha[i], alpha[j]);
                    let (lo, hi) = if ys[i] != ys[j] {
                        ((aj_old - ai_old).max(0.0), (p.c + aj_old - ai_old).min(p.c))
                    } else {
                        ((ai_old + aj_old - p.c).max(0.0), (ai_old + aj_old).min(p.c))
                    };
                    if lo >= hi {
                        continue;
                    }
                    let eta = 2.0 * k_mat[i][j] - k_mat[i][i] - k_mat[j][j];
                    if eta >= 0.0 {
                        continue;
                    }
                    let mut aj = aj_old - ys[j] * (ei - ej) / eta;
                    aj = aj.clamp(lo, hi);
                    if (aj - aj_old).abs() < 1e-5 {
                        continue;
                    }
                    let ai = ai_old + ys[i] * ys[j] * (aj_old - aj);
                    alpha[i] = ai;
                    alpha[j] = aj;
                    let b1 = b - ei
                        - ys[i] * (ai - ai_old) * k_mat[i][i]
                        - ys[j] * (aj - aj_old) * k_mat[i][j];
                    let b2 = b - ej
                        - ys[i] * (ai - ai_old) * k_mat[i][j]
                        - ys[j] * (aj - aj_old) * k_mat[j][j];
                    b = if ai > 0.0 && ai < p.c {
                        b1
                    } else if aj > 0.0 && aj < p.c {
                        b2
                    } else {
                        (b1 + b2) / 2.0
                    };
                    changed += 1;
                }
            }
            if changed == 0 {
                passes += 1;
            } else {
                passes = 0;
            }
        }
        let mut support = vec![];
        let mut alpha_y = vec![];
        for i in 0..n {
            if alpha[i].abs() > 1e-9 {
                support.push(xs[i].clone());
                alpha_y.push(alpha[i] * ys[i]);
            }
        }
        Svc { support, alpha_y, b, kernel: p.kernel }
    }

    /// Signed distance to the separating surface.
    pub fn decision(&self, x: &[f64]) -> f64 {
        let mut s = self.b;
        for (sv, ay) in self.support.iter().zip(&self.alpha_y) {
            s += ay * self.kernel.eval(sv, x);
        }
        s
    }

    /// Predict class in {0, 1}.
    pub fn predict_one(&self, x: &[f64]) -> f64 {
        (self.decision(x) >= 0.0) as i32 as f64
    }

    /// Predict classes for a batch of feature vectors.
    pub fn predict(&self, xs: &[Vec<f64>]) -> Vec<f64> {
        xs.iter().map(|x| self.predict_one(x)).collect()
    }

    /// Number of support vectors kept.
    pub fn n_support(&self) -> usize {
        self.support.len()
    }
}

// ---------------------------------------------------------------------
// ε-SVR via projected gradient ascent on the dual
// ---------------------------------------------------------------------

/// ε-SVR hyperparameters.
#[derive(Debug, Clone)]
pub struct SvrParams {
    /// Box constraint (regularization strength).
    pub c: f64,
    /// Width of the insensitive tube.
    pub epsilon: f64,
    /// The kernel.
    pub kernel: Kernel,
    /// Coordinate-descent sweeps.
    pub iters: usize,
    /// Nominal learning rate (scaled by the kernel diagonal).
    pub lr: f64,
}

impl Default for SvrParams {
    fn default() -> Self {
        SvrParams { c: 10.0, epsilon: 0.1, kernel: Kernel::Rbf { gamma: 0.5 }, iters: 300, lr: 0.1 }
    }
}

/// A fitted SVM regressor.
#[derive(Debug, Clone)]
pub struct Svr {
    support: Vec<Vec<f64>>,
    beta: Vec<f64>, // alpha - alpha*
    b: f64,
    kernel: Kernel,
}

impl Svr {
    /// Fit on row-major `xs` (n × d) and targets `ys`.
    pub fn fit(xs: &[Vec<f64>], ys: &[f64], p: &SvrParams) -> Svr {
        let n = xs.len();
        // K + 1 absorbs the bias term (equivalent to an appended constant
        // feature), which lets us drop the Σβ = 0 equality constraint and
        // solve the box-constrained dual by exact coordinate descent.
        let k_mat: Vec<Vec<f64>> = (0..n)
            .map(|i| (0..n).map(|j| p.kernel.eval(&xs[i], &xs[j]) + 1.0).collect())
            .collect();
        // Dual over beta_i = alpha_i - alpha_i* ∈ [-C, C]:
        // max  -0.5 βᵀKβ + βᵀy - ε·Σ|β|
        let mut beta = vec![0.0f64; n];
        // Lipschitz-ish step from the kernel diagonal.
        let diag_max = (0..n).map(|i| k_mat[i][i]).fold(1e-9, f64::max);
        let step = p.lr / diag_max;
        for _ in 0..p.iters {
            // Coordinate-wise proximal gradient sweep.
            for i in 0..n {
                let mut g = ys[i];
                for j in 0..n {
                    if beta[j] != 0.0 {
                        g -= k_mat[i][j] * beta[j];
                    }
                }
                g += k_mat[i][i] * beta[i]; // exclude own contribution
                // Closed-form coordinate update with soft threshold at ε.
                let denom = k_mat[i][i].max(1e-9);
                let raw = g;
                let bnew = if raw > p.epsilon {
                    (raw - p.epsilon) / denom
                } else if raw < -p.epsilon {
                    (raw + p.epsilon) / denom
                } else {
                    0.0
                };
                beta[i] = bnew.clamp(-p.c, p.c);
            }
            let _ = step;
        }
        // Bias is absorbed by the +1 kernel offset: f(x) = Σβ(K(x,·)+1),
        // so the explicit intercept equals Σβ.
        let b = beta.iter().sum::<f64>();
        let mut support = vec![];
        let mut sbeta = vec![];
        for i in 0..n {
            if beta[i].abs() > 1e-9 {
                support.push(xs[i].clone());
                sbeta.push(beta[i]);
            }
        }
        Svr { support, beta: sbeta, b, kernel: p.kernel }
    }

    /// Predict the regression value for one feature vector.
    pub fn predict_one(&self, x: &[f64]) -> f64 {
        let mut s = self.b;
        for (sv, bt) in self.support.iter().zip(&self.beta) {
            s += bt * self.kernel.eval(sv, x);
        }
        s
    }

    /// Predict for a batch of feature vectors.
    pub fn predict(&self, xs: &[Vec<f64>]) -> Vec<f64> {
        xs.iter().map(|x| self.predict_one(x)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn svc_separates_linear_data() {
        let mut rng = Rng::new(6);
        let mut xs = vec![];
        let mut ys = vec![];
        for _ in 0..120 {
            let x = vec![rng.normal(), rng.normal()];
            ys.push((x[0] + x[1] > 0.0) as i32 as f64);
            xs.push(x);
        }
        let params = SvcParams { kernel: Kernel::Linear, c: 10.0, ..Default::default() };
        let svc = Svc::fit(&xs, &ys, &params);
        let acc: f64 = xs
            .iter()
            .zip(&ys)
            .map(|(x, y)| (svc.predict_one(x) == *y) as i32 as f64)
            .sum::<f64>()
            / ys.len() as f64;
        assert!(acc > 0.93, "acc={acc}");
    }

    #[test]
    fn svc_rbf_handles_circle() {
        let mut rng = Rng::new(7);
        let mut xs = vec![];
        let mut ys = vec![];
        for _ in 0..160 {
            let x = vec![rng.normal(), rng.normal()];
            let r2 = x[0] * x[0] + x[1] * x[1];
            ys.push((r2 < 1.0) as i32 as f64);
            xs.push(x);
        }
        let svc = Svc::fit(
            &xs,
            &ys,
            &SvcParams { kernel: Kernel::Rbf { gamma: 1.0 }, c: 10.0, ..Default::default() },
        );
        let acc: f64 = xs
            .iter()
            .zip(&ys)
            .map(|(x, y)| (svc.predict_one(x) == *y) as i32 as f64)
            .sum::<f64>()
            / ys.len() as f64;
        assert!(acc > 0.85, "acc={acc}");
    }

    #[test]
    fn svc_single_class_is_constant() {
        let xs = vec![vec![1.0], vec![2.0]];
        let ys = vec![1.0, 1.0];
        let svc = Svc::fit(&xs, &ys, &SvcParams::default());
        assert_eq!(svc.predict_one(&[5.0]), 1.0);
    }

    #[test]
    fn svr_fits_linear_function() {
        let xs: Vec<Vec<f64>> = (0..60).map(|i| vec![i as f64 / 10.0]).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 * x[0] + 1.0).collect();
        let svr = Svr::fit(
            &xs,
            &ys,
            &SvrParams { kernel: Kernel::Linear, c: 100.0, epsilon: 0.05, iters: 500, lr: 0.1 },
        );
        let mae: f64 = xs
            .iter()
            .zip(&ys)
            .map(|(x, y)| (svr.predict_one(x) - y).abs())
            .sum::<f64>()
            / ys.len() as f64;
        // ε-SVR tolerates errors up to ~ε inside the tube plus boundary
        // effects at the domain edges; mean error is the right check.
        assert!(mae < 0.2, "mae {mae}");
    }

    #[test]
    fn svr_rbf_fits_sine() {
        let xs: Vec<Vec<f64>> = (0..80).map(|i| vec![i as f64 * 0.1]).collect();
        let ys: Vec<f64> = xs.iter().map(|x| x[0].sin()).collect();
        let svr = Svr::fit(
            &xs,
            &ys,
            &SvrParams {
                kernel: Kernel::Rbf { gamma: 2.0 },
                c: 50.0,
                epsilon: 0.02,
                iters: 300,
                lr: 0.1,
            },
        );
        let mae: f64 = xs
            .iter()
            .zip(&ys)
            .map(|(x, y)| (svr.predict_one(x) - y).abs())
            .sum::<f64>()
            / ys.len() as f64;
        assert!(mae < 0.12, "mae={mae}");
    }

    #[test]
    fn scale_gamma_positive() {
        let xs = vec![vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 0.0]];
        assert!(Kernel::scale_gamma(&xs) > 0.0);
    }
}
