//! Cross-validation and hyperparameter search: 5-fold CV and successive
//! halving (the paper uses scikit-learn's HalvingGridSearchCV).

use crate::util::rng::Rng;

/// Deterministic shuffled k-fold index split.
pub fn kfold(n: usize, folds: usize, seed: u64) -> Vec<(Vec<usize>, Vec<usize>)> {
    let folds = folds.clamp(2, n.max(2));
    let mut idx: Vec<usize> = (0..n).collect();
    Rng::new(seed ^ 0xF01D).shuffle(&mut idx);
    let mut out = Vec::with_capacity(folds);
    for f in 0..folds {
        let test: Vec<usize> = idx.iter().copied().skip(f).step_by(folds).collect();
        let test_set: std::collections::BTreeSet<usize> = test.iter().copied().collect();
        let train: Vec<usize> = idx.iter().copied().filter(|i| !test_set.contains(i)).collect();
        out.push((train, test));
    }
    out
}

/// Mean CV score of one candidate on a subsample of the data.
/// `fit_score(train_x, train_y, test_x, test_y)` returns a score where
/// higher is better.
fn cv_score<F>(
    xs: &[Vec<f64>],
    ys: &[f64],
    sample: &[usize],
    folds: usize,
    seed: u64,
    fit_score: &F,
) -> f64
where
    F: Fn(&[Vec<f64>], &[f64], &[Vec<f64>], &[f64]) -> f64,
{
    let mut total = 0.0;
    let splits = kfold(sample.len(), folds, seed);
    for (train, test) in &splits {
        let tx: Vec<Vec<f64>> = train.iter().map(|&i| xs[sample[i]].clone()).collect();
        let ty: Vec<f64> = train.iter().map(|&i| ys[sample[i]]).collect();
        let vx: Vec<Vec<f64>> = test.iter().map(|&i| xs[sample[i]].clone()).collect();
        let vy: Vec<f64> = test.iter().map(|&i| ys[sample[i]]).collect();
        if tx.is_empty() || vx.is_empty() {
            continue;
        }
        total += fit_score(&tx, &ty, &vx, &vy);
    }
    total / splits.len() as f64
}

/// Successive-halving grid search (HalvingGridSearchCV analog): all
/// candidates start on a small subsample; each rung keeps the top
/// `1/factor` and multiplies the sample size by `factor`, until one
/// candidate remains or the full dataset is reached.  Returns the best
/// candidate index and its final CV score.
pub fn halving_search<P, F>(
    xs: &[Vec<f64>],
    ys: &[f64],
    candidates: &[P],
    folds: usize,
    factor: usize,
    min_samples: usize,
    seed: u64,
    fit_score: F,
) -> (usize, f64)
where
    F: Fn(&[Vec<f64>], &[f64], &[Vec<f64>], &[f64], &P) -> f64,
{
    assert!(!candidates.is_empty());
    let n = xs.len();
    let factor = factor.max(2);
    let mut alive: Vec<usize> = (0..candidates.len()).collect();
    // Rungs needed to eliminate down to one candidate.
    let rungs = (candidates.len() as f64).log(factor as f64).ceil() as u32;
    let mut sample_size = (n / factor.pow(rungs) as usize).max(min_samples).min(n);
    let mut rng = Rng::new(seed ^ 0x4A1F);
    let mut perm: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut perm);
    let mut best = (alive[0], f64::NEG_INFINITY);

    loop {
        let sample: Vec<usize> = perm.iter().copied().take(sample_size).collect();
        let mut scored: Vec<(usize, f64)> = alive
            .iter()
            .map(|&c| {
                let s = cv_score(xs, ys, &sample, folds, seed, &|tx: &[Vec<f64>],
                                                                 ty: &[f64],
                                                                 vx: &[Vec<f64>],
                                                                 vy: &[f64]| {
                    fit_score(tx, ty, vx, vy, &candidates[c])
                });
                (c, s)
            })
            .collect();
        scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        best = scored[0];
        if scored.len() == 1 || sample_size >= n {
            return best;
        }
        let keep = (scored.len() / factor).max(1);
        alive = scored.into_iter().take(keep).map(|(c, _)| c).collect();
        sample_size = (sample_size * factor).min(n);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kfold_partitions_everything() {
        let splits = kfold(103, 5, 1);
        assert_eq!(splits.len(), 5);
        let mut seen = vec![false; 103];
        for (train, test) in &splits {
            assert_eq!(train.len() + test.len(), 103);
            for &i in test {
                assert!(!seen[i], "index {i} in two test folds");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn halving_finds_the_good_candidate() {
        // Candidates are "prediction constants"; data says 7.0 is right.
        let xs: Vec<Vec<f64>> = (0..200).map(|i| vec![i as f64]).collect();
        let ys = vec![7.0; 200];
        let candidates = vec![0.0, 3.0, 7.0, 10.0, -5.0, 6.0];
        let (best, _) = halving_search(
            &xs,
            &ys,
            &candidates,
            3,
            2,
            8,
            42,
            |_tx, _ty, _vx, vy, &c| {
                // score = negative MSE of the constant predictor c
                -vy.iter().map(|y| (y - c) * (y - c)).sum::<f64>() / vy.len() as f64
            },
        );
        assert_eq!(candidates[best], 7.0);
    }

    #[test]
    fn halving_single_candidate() {
        let xs: Vec<Vec<f64>> = (0..20).map(|i| vec![i as f64]).collect();
        let ys = vec![1.0; 20];
        let (best, _) = halving_search(&xs, &ys, &[42.0], 3, 2, 4, 1, |_, _, _, _, _| 0.0);
        assert_eq!(best, 0);
    }
}
