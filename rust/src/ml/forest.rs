//! Random Forest (bagged CART ensemble with feature subsampling).

use super::tree::{Tree, TreeParams};
use crate::util::rng::Rng;

/// Forest hyperparameters.
#[derive(Debug, Clone)]
pub struct ForestParams {
    /// Number of trees in the ensemble.
    pub n_estimators: usize,
    /// Per-tree hyperparameters.
    pub tree: TreeParams,
    /// Bootstrap sample fraction.
    pub subsample: f64,
    /// Bagging seed (per-tree seeds derive from it).
    pub seed: u64,
}

impl Default for ForestParams {
    fn default() -> Self {
        ForestParams { n_estimators: 64, tree: TreeParams::default(), subsample: 1.0, seed: 0 }
    }
}

/// A fitted random forest.
#[derive(Debug, Clone, Default)]
pub struct Forest {
    /// The fitted trees (predictions are averaged).
    pub trees: Vec<Tree>,
}

impl Forest {
    /// Fit on row-major `xs` (n × d) and labels `ys`.
    pub fn fit(xs: &[Vec<f64>], ys: &[f64], params: &ForestParams) -> Forest {
        let n = xs.len();
        let mut rng = Rng::new(params.seed ^ 0xF0_4E57);
        let d = xs[0].len();
        let mut trees = Vec::with_capacity(params.n_estimators);
        for t in 0..params.n_estimators {
            // Bootstrap resample.
            let m = ((n as f64 * params.subsample) as usize).max(1);
            let mut bx = Vec::with_capacity(m);
            let mut by = Vec::with_capacity(m);
            for _ in 0..m {
                let i = rng.below(n);
                bx.push(xs[i].clone());
                by.push(ys[i]);
            }
            let mut tp = params.tree.clone();
            tp.seed = params.seed ^ (t as u64).wrapping_mul(0x9E3779B97F4A7C15);
            // sklearn default max_features for RF regression is all; for
            // classification sqrt.  Honour whatever the caller set, default
            // to sqrt(d) which works well for both here.
            if tp.max_features.is_none() {
                tp.max_features = Some(((d as f64).sqrt().ceil() as usize).max(1));
            }
            trees.push(Tree::fit(&bx, &by, &tp));
        }
        Forest { trees }
    }

    /// Mean over trees (probability for classification labels in {0,1}).
    pub fn predict_one(&self, x: &[f64]) -> f64 {
        if self.trees.is_empty() {
            return 0.0;
        }
        self.trees.iter().map(|t| t.predict_one(x)).sum::<f64>() / self.trees.len() as f64
    }

    /// Predict for a batch of feature vectors.
    pub fn predict(&self, xs: &[Vec<f64>]) -> Vec<f64> {
        xs.iter().map(|x| self.predict_one(x)).collect()
    }

    /// Total decision-rule count (Table 4's complexity measure).
    pub fn n_rules(&self) -> usize {
        self.trees.iter().map(Tree::n_leaves).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::tree::Criterion;

    #[test]
    fn forest_beats_constant_on_nonlinear_target() {
        let mut rng = Rng::new(3);
        let xs: Vec<Vec<f64>> = (0..400).map(|_| vec![rng.f64() * 4.0, rng.f64() * 4.0]).collect();
        let ys: Vec<f64> = xs.iter().map(|x| (x[0] * x[1]).sin() + x[0]).collect();
        let f = Forest::fit(
            &xs,
            &ys,
            &ForestParams { n_estimators: 30, ..Default::default() },
        );
        let preds = f.predict(&xs);
        let mse: f64 =
            preds.iter().zip(&ys).map(|(p, y)| (p - y) * (p - y)).sum::<f64>() / ys.len() as f64;
        let var: f64 = {
            let m = ys.iter().sum::<f64>() / ys.len() as f64;
            ys.iter().map(|y| (y - m) * (y - m)).sum::<f64>() / ys.len() as f64
        };
        assert!(mse < 0.25 * var, "mse={mse} var={var}");
    }

    #[test]
    fn classification_probability_in_unit_interval() {
        let mut rng = Rng::new(4);
        let xs: Vec<Vec<f64>> = (0..200).map(|_| vec![rng.f64()]).collect();
        let ys: Vec<f64> = xs.iter().map(|x| (x[0] > 0.5) as i32 as f64).collect();
        let f = Forest::fit(
            &xs,
            &ys,
            &ForestParams {
                n_estimators: 16,
                tree: TreeParams { criterion: Criterion::Gini, ..Default::default() },
                ..Default::default()
            },
        );
        for x in &xs {
            let p = f.predict_one(x);
            assert!((0.0..=1.0).contains(&p));
        }
        assert!(f.predict_one(&[0.95]) > 0.8);
        assert!(f.predict_one(&[0.05]) < 0.2);
    }

    #[test]
    fn deterministic_given_seed() {
        let xs: Vec<Vec<f64>> = (0..50).map(|i| vec![i as f64]).collect();
        let ys: Vec<f64> = (0..50).map(|i| (i % 7) as f64).collect();
        let p = ForestParams { n_estimators: 5, seed: 9, ..Default::default() };
        let a = Forest::fit(&xs, &ys, &p).predict(&xs);
        let b = Forest::fit(&xs, &ys, &p).predict(&xs);
        assert_eq!(a, b);
    }
}
