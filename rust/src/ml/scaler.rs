//! Feature standardization (zero mean, unit variance) — required by the
//! SVM and KNN models; trees are scale-invariant but tolerate it.

use crate::util::json::Json;
use crate::util::stats;

/// Per-feature standardization parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct Scaler {
    /// Per-feature means.
    pub mean: Vec<f64>,
    /// Per-feature standard deviations (floored at 1e-12).
    pub std: Vec<f64>,
}

impl Scaler {
    /// Fit means and deviations on row-major `xs`.
    pub fn fit(xs: &[Vec<f64>]) -> Scaler {
        let d = xs[0].len();
        let mut mean = vec![0.0; d];
        let mut std = vec![0.0; d];
        for j in 0..d {
            let col: Vec<f64> = xs.iter().map(|x| x[j]).collect();
            mean[j] = stats::mean(&col);
            std[j] = stats::std(&col).max(1e-12);
        }
        Scaler { mean, std }
    }

    /// Standardize one feature vector.
    pub fn transform_one(&self, x: &[f64]) -> Vec<f64> {
        x.iter()
            .enumerate()
            .map(|(j, v)| (v - self.mean[j]) / self.std[j])
            .collect()
    }

    /// Standardize a batch of feature vectors.
    pub fn transform(&self, xs: &[Vec<f64>]) -> Vec<Vec<f64>> {
        xs.iter().map(|x| self.transform_one(x)).collect()
    }

    /// Serialize for model persistence.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mean", Json::arr_f64(&self.mean)),
            ("std", Json::arr_f64(&self.std)),
        ])
    }

    /// Parse a scaler written by [`Scaler::to_json`].
    pub fn from_json(j: &Json) -> anyhow::Result<Scaler> {
        Ok(Scaler {
            mean: j.req("mean")?.f64_vec().ok_or_else(|| anyhow::anyhow!("mean"))?,
            std: j.req("std")?.f64_vec().ok_or_else(|| anyhow::anyhow!("std"))?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standardizes_columns() {
        let xs = vec![vec![1.0, 10.0], vec![3.0, 20.0], vec![5.0, 30.0]];
        let s = Scaler::fit(&xs);
        let t = s.transform(&xs);
        for j in 0..2 {
            let col: Vec<f64> = t.iter().map(|x| x[j]).collect();
            assert!(stats::mean(&col).abs() < 1e-12);
            assert!((stats::std(&col) - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn constant_column_does_not_divide_by_zero() {
        let xs = vec![vec![7.0], vec![7.0]];
        let s = Scaler::fit(&xs);
        let t = s.transform_one(&[7.0]);
        assert!(t[0].is_finite());
    }

    #[test]
    fn json_roundtrip() {
        let s = Scaler { mean: vec![1.0, 2.0], std: vec![0.5, 4.0] };
        assert_eq!(Scaler::from_json(&s.to_json()).unwrap(), s);
    }
}
