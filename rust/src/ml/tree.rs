//! CART decision trees (regression and binary classification), built from
//! scratch: scikit-learn is unavailable offline, and the refinement phase
//! (§6.1) needs full control over tree complexity anyway.
//!
//! Binary classification is handled through the same machinery with labels
//! in {0, 1} and leaf values = class-1 probability (the starvation task is
//! binary).

use crate::util::rng::Rng;

/// Split quality criterion.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Criterion {
    /// Variance reduction (regression; sklearn "squared_error").
    Mse,
    /// Gini impurity (binary labels).
    Gini,
    /// Shannon entropy (binary labels).
    Entropy,
}

impl Criterion {
    fn impurity(&self, sum: f64, sum_sq: f64, n: f64) -> f64 {
        if n <= 0.0 {
            return 0.0;
        }
        let mean = sum / n;
        match self {
            Criterion::Mse => (sum_sq / n - mean * mean).max(0.0),
            Criterion::Gini => 2.0 * mean * (1.0 - mean),
            Criterion::Entropy => {
                let p = mean.clamp(1e-12, 1.0 - 1e-12);
                -(p * p.log2() + (1.0 - p) * (1.0 - p).log2())
            }
        }
    }
}

/// Hyperparameters (sklearn-compatible subset used in Appendix B).
#[derive(Debug, Clone)]
pub struct TreeParams {
    /// Split quality criterion.
    pub criterion: Criterion,
    /// Depth cap (None = unbounded).
    pub max_depth: Option<usize>,
    /// Minimum samples required to split a node.
    pub min_samples_split: usize,
    /// Minimum samples required in each child.
    pub min_samples_leaf: usize,
    /// Number of features considered per split (None = all); RF sets this
    /// to sqrt/log2 of the feature count.
    pub max_features: Option<usize>,
    /// Maximum number of leaves (best-first growth); the refinement phase
    /// uses this to cap the rule count.
    pub max_leaves: Option<usize>,
    /// Seed for feature subsampling.
    pub seed: u64,
}

impl Default for TreeParams {
    fn default() -> Self {
        TreeParams {
            criterion: Criterion::Mse,
            max_depth: None,
            min_samples_split: 2,
            min_samples_leaf: 1,
            max_features: None,
            max_leaves: None,
            seed: 0,
        }
    }
}

/// Array-encoded binary tree.  `feature < 0` marks a leaf whose prediction
/// is `value`.  This flat layout *is* the runtime representation — also the
/// basis of the "compiled" Small Tree** evaluator (the paper's Numba step).
#[derive(Debug, Clone, Default)]
pub struct Tree {
    /// Split feature per node (−1 marks a leaf).
    pub feature: Vec<i32>,
    /// Split threshold per node (`x[f] ≤ t` goes left).
    pub threshold: Vec<f64>,
    /// Left child index per node.
    pub left: Vec<u32>,
    /// Right child index per node.
    pub right: Vec<u32>,
    /// Leaf prediction (mean label / class-1 probability) per node.
    pub value: Vec<f64>,
    /// Training samples that reached each node.
    pub n_samples: Vec<u32>,
}

struct BuildNode {
    idx: Vec<u32>,
    depth: usize,
    node: usize,
    impurity: f64,
}

impl Tree {
    /// Number of nodes (inner + leaves).
    pub fn n_nodes(&self) -> usize {
        self.feature.len()
    }

    /// Leaves = decision rules in the paper's complexity measure (§6.1).
    pub fn n_leaves(&self) -> usize {
        self.feature.iter().filter(|&&f| f < 0).count()
    }

    /// Maximum root-to-leaf depth.
    pub fn depth(&self) -> usize {
        fn rec(t: &Tree, node: usize) -> usize {
            if t.feature[node] < 0 {
                0
            } else {
                1 + rec(t, t.left[node] as usize).max(rec(t, t.right[node] as usize))
            }
        }
        if self.feature.is_empty() {
            0
        } else {
            rec(self, 0)
        }
    }

    /// Predict for one feature vector (root-to-leaf walk).
    pub fn predict_one(&self, x: &[f64]) -> f64 {
        let mut node = 0usize;
        loop {
            let f = self.feature[node];
            if f < 0 {
                return self.value[node];
            }
            node = if x[f as usize] <= self.threshold[node] {
                self.left[node] as usize
            } else {
                self.right[node] as usize
            };
        }
    }

    /// Predict for a batch of feature vectors.
    pub fn predict(&self, xs: &[Vec<f64>]) -> Vec<f64> {
        xs.iter().map(|x| self.predict_one(x)).collect()
    }

    /// Extract human-readable decision rules (Appendix C interpretability).
    pub fn rules(&self, feature_names: &[&str]) -> Vec<String> {
        let mut out = Vec::new();
        fn rec(
            t: &Tree,
            node: usize,
            path: &mut Vec<String>,
            names: &[&str],
            out: &mut Vec<String>,
        ) {
            if t.feature[node] < 0 {
                let cond = if path.is_empty() { "true".to_string() } else { path.join(" ∧ ") };
                out.push(format!("{cond} → {:.4}", t.value[node]));
                return;
            }
            let f = t.feature[node] as usize;
            let name = names.get(f).copied().unwrap_or("x?");
            path.push(format!("{name} ≤ {:.4}", t.threshold[node]));
            rec(t, t.left[node] as usize, path, names, out);
            path.pop();
            path.push(format!("{name} > {:.4}", t.threshold[node]));
            rec(t, t.right[node] as usize, path, names, out);
            path.pop();
        }
        if !self.feature.is_empty() {
            rec(self, 0, &mut Vec::new(), feature_names, &mut out);
        }
        out
    }

    /// Fit a tree on row-major `xs` (n × d) and labels `ys`.
    pub fn fit(xs: &[Vec<f64>], ys: &[f64], params: &TreeParams) -> Tree {
        assert_eq!(xs.len(), ys.len());
        assert!(!xs.is_empty(), "empty training set");
        let d = xs[0].len();
        let mut t = Tree::default();
        let mut rng = Rng::new(params.seed ^ 0x7EE5);
        let root_idx: Vec<u32> = (0..xs.len() as u32).collect();
        let root_imp = node_impurity(&root_idx, ys, params.criterion);
        t.push_leaf(&root_idx, ys);
        // Best-first frontier (needed for max_leaves semantics).
        let mut frontier = vec![BuildNode { idx: root_idx, depth: 0, node: 0, impurity: root_imp }];
        let mut leaves = 1usize;

        while let Some(pos) = best_frontier_node(&frontier) {
            if let Some(maxl) = params.max_leaves {
                if leaves >= maxl {
                    break;
                }
            }
            let cand = frontier.swap_remove(pos);
            if cand.idx.len() < params.min_samples_split
                || params.max_depth.is_some_and(|md| cand.depth >= md)
                || cand.impurity <= 1e-12
            {
                continue; // stays a leaf
            }
            let Some(split) = best_split(xs, ys, &cand.idx, d, params, &mut rng) else {
                continue;
            };
            // Materialize children.
            let (li, ri) = partition(xs, &cand.idx, split.feature, split.threshold);
            let l_imp = node_impurity(&li, ys, params.criterion);
            let r_imp = node_impurity(&ri, ys, params.criterion);
            let l_node = t.push_leaf(&li, ys);
            let r_node = t.push_leaf(&ri, ys);
            t.feature[cand.node] = split.feature as i32;
            t.threshold[cand.node] = split.threshold;
            t.left[cand.node] = l_node as u32;
            t.right[cand.node] = r_node as u32;
            leaves += 1; // one leaf became two
            let depth = cand.depth + 1;
            frontier.push(BuildNode { idx: li, depth, node: l_node, impurity: l_imp });
            frontier.push(BuildNode { idx: ri, depth, node: r_node, impurity: r_imp });
        }
        t
    }

    fn push_leaf(&mut self, idx: &[u32], ys: &[f64]) -> usize {
        let n = idx.len().max(1);
        let mean = idx.iter().map(|&i| ys[i as usize]).sum::<f64>() / n as f64;
        self.feature.push(-1);
        self.threshold.push(0.0);
        self.left.push(0);
        self.right.push(0);
        self.value.push(mean);
        self.n_samples.push(idx.len() as u32);
        self.feature.len() - 1
    }
}

fn node_impurity(idx: &[u32], ys: &[f64], crit: Criterion) -> f64 {
    let n = idx.len() as f64;
    let sum: f64 = idx.iter().map(|&i| ys[i as usize]).sum();
    let sum_sq: f64 = idx.iter().map(|&i| ys[i as usize] * ys[i as usize]).sum();
    crit.impurity(sum, sum_sq, n)
}

/// Pick the frontier node with the largest weighted impurity (best-first).
fn best_frontier_node(frontier: &[BuildNode]) -> Option<usize> {
    if frontier.is_empty() {
        return None;
    }
    frontier
        .iter()
        .enumerate()
        .max_by(|(_, a), (_, b)| {
            let wa = a.impurity * a.idx.len() as f64;
            let wb = b.impurity * b.idx.len() as f64;
            wa.partial_cmp(&wb).unwrap()
        })
        .map(|(i, _)| i)
}

struct Split {
    feature: usize,
    threshold: f64,
    gain: f64,
}

fn best_split(
    xs: &[Vec<f64>],
    ys: &[f64],
    idx: &[u32],
    d: usize,
    params: &TreeParams,
    rng: &mut Rng,
) -> Option<Split> {
    let n = idx.len() as f64;
    let parent = node_impurity(idx, ys, params.criterion);
    let mut features: Vec<usize> = (0..d).collect();
    if let Some(mf) = params.max_features {
        rng.shuffle(&mut features);
        features.truncate(mf.clamp(1, d));
    }
    let mut best: Option<Split> = None;
    let mut vals: Vec<(f64, f64)> = Vec::with_capacity(idx.len());
    for &f in &features {
        vals.clear();
        vals.extend(idx.iter().map(|&i| (xs[i as usize][f], ys[i as usize])));
        vals.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        // Prefix sums over the sorted order: O(n) split scan.
        let (mut ls, mut lq, mut ln) = (0.0f64, 0.0f64, 0.0f64);
        let total_s: f64 = vals.iter().map(|v| v.1).sum();
        let total_q: f64 = vals.iter().map(|v| v.1 * v.1).sum();
        for w in 0..vals.len() - 1 {
            ls += vals[w].1;
            lq += vals[w].1 * vals[w].1;
            ln += 1.0;
            if vals[w].0 == vals[w + 1].0 {
                continue; // can't split between equal values
            }
            let rn = n - ln;
            if (ln as usize) < params.min_samples_leaf || (rn as usize) < params.min_samples_leaf {
                continue;
            }
            let imp = (ln / n) * params.criterion.impurity(ls, lq, ln)
                + (rn / n) * params.criterion.impurity(total_s - ls, total_q - lq, rn);
            let gain = parent - imp;
            // Zero-gain splits are allowed (sklearn semantics): XOR-like
            // targets need an uninformative first split before the children
            // become separable.  Termination is still guaranteed by the
            // min-samples checks and shrinking partitions.
            if gain > best.as_ref().map_or(-1e-12, |b| b.gain) {
                best = Some(Split {
                    feature: f,
                    threshold: (vals[w].0 + vals[w + 1].0) / 2.0,
                    gain,
                });
            }
        }
    }
    best
}

fn partition(xs: &[Vec<f64>], idx: &[u32], f: usize, thr: f64) -> (Vec<u32>, Vec<u32>) {
    let mut l = Vec::new();
    let mut r = Vec::new();
    for &i in idx {
        if xs[i as usize][f] <= thr {
            l.push(i);
        } else {
            r.push(i);
        }
    }
    (l, r)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xor_data() -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut xs = vec![];
        let mut ys = vec![];
        for a in 0..2 {
            for b in 0..2 {
                for _ in 0..10 {
                    xs.push(vec![a as f64, b as f64]);
                    ys.push(((a ^ b) == 1) as i32 as f64);
                }
            }
        }
        (xs, ys)
    }

    #[test]
    fn fits_xor_exactly() {
        let (xs, ys) = xor_data();
        let params = TreeParams { criterion: Criterion::Gini, ..Default::default() };
        let t = Tree::fit(&xs, &ys, &params);
        for (x, y) in xs.iter().zip(&ys) {
            assert_eq!(t.predict_one(x) >= 0.5, *y >= 0.5);
        }
        assert!(t.n_leaves() >= 4);
    }

    #[test]
    fn regression_recovers_step_function() {
        let xs: Vec<Vec<f64>> = (0..100).map(|i| vec![i as f64]).collect();
        let ys: Vec<f64> = (0..100).map(|i| if i < 50 { 1.0 } else { 5.0 }).collect();
        let t = Tree::fit(&xs, &ys, &TreeParams::default());
        assert!((t.predict_one(&[10.0]) - 1.0).abs() < 1e-9);
        assert!((t.predict_one(&[90.0]) - 5.0).abs() < 1e-9);
        assert_eq!(t.n_leaves(), 2);
    }

    #[test]
    fn max_leaves_caps_rule_count() {
        let mut rng = Rng::new(1);
        let xs: Vec<Vec<f64>> = (0..200).map(|_| vec![rng.f64(), rng.f64(), rng.f64()]).collect();
        let ys: Vec<f64> = xs.iter().map(|x| x[0] * 3.0 + x[1] * x[2]).collect();
        for maxl in [4usize, 8, 16] {
            let t = Tree::fit(
                &xs,
                &ys,
                &TreeParams { max_leaves: Some(maxl), ..Default::default() },
            );
            assert!(t.n_leaves() <= maxl, "{} > {maxl}", t.n_leaves());
        }
    }

    #[test]
    fn max_depth_respected() {
        let mut rng = Rng::new(2);
        let xs: Vec<Vec<f64>> = (0..200).map(|_| vec![rng.f64(), rng.f64()]).collect();
        let ys: Vec<f64> = xs.iter().map(|x| x[0] + x[1]).collect();
        let t = Tree::fit(&xs, &ys, &TreeParams { max_depth: Some(3), ..Default::default() });
        assert!(t.depth() <= 3);
    }

    #[test]
    fn min_samples_leaf_respected() {
        let (xs, ys) = xor_data();
        let t = Tree::fit(
            &xs,
            &ys,
            &TreeParams { criterion: Criterion::Gini, min_samples_leaf: 15, ..Default::default() },
        );
        assert!(t.n_samples.iter().zip(&t.feature).all(|(&n, &f)| f >= 0 || n >= 15));
    }

    #[test]
    fn rules_cover_all_leaves() {
        let (xs, ys) = xor_data();
        let params = TreeParams { criterion: Criterion::Gini, ..Default::default() };
        let t = Tree::fit(&xs, &ys, &params);
        let rules = t.rules(&["a", "b"]);
        assert_eq!(rules.len(), t.n_leaves());
        assert!(rules.iter().all(|r| r.contains('→')));
    }

    #[test]
    fn constant_labels_yield_single_leaf() {
        let xs: Vec<Vec<f64>> = (0..10).map(|i| vec![i as f64]).collect();
        let ys = vec![2.5; 10];
        let t = Tree::fit(&xs, &ys, &TreeParams::default());
        assert_eq!(t.n_leaves(), 1);
        assert_eq!(t.predict_one(&[3.0]), 2.5);
    }
}
