//! Feature engineering for the ML phase (paper §6): the feature vector
//! characterizes the workload and the GPU configuration.

use crate::util::stats;
use crate::workload::AdapterSpec;

/// Feature order is part of the trained-model contract.
pub const FEATURE_NAMES: [&str; 7] = [
    "n_adapters",
    "sum_rate",
    "std_rate",
    "max_size",
    "mean_size",
    "std_size",
    "a_max",
];

/// Number of features (the trained-model input arity).
pub const N_FEATURES: usize = FEATURE_NAMES.len();

/// Build the 7-feature vector for an adapter set under a given `A_max`.
pub fn features(adapters: &[AdapterSpec], a_max: usize) -> Vec<f64> {
    let rates: Vec<f64> = adapters.iter().map(|a| a.rate).collect();
    let sizes: Vec<f64> = adapters.iter().map(|a| a.rank as f64).collect();
    vec![
        adapters.len() as f64,
        rates.iter().sum(),
        stats::std(&rates),
        stats::max(&sizes).max(0.0),
        stats::mean(&sizes),
        stats::std(&sizes),
        a_max as f64,
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn feature_vector_shape_and_values() {
        let ads = vec![
            AdapterSpec { id: 0, rank: 8, rate: 0.1 },
            AdapterSpec { id: 1, rank: 32, rate: 0.3 },
        ];
        let f = features(&ads, 16);
        assert_eq!(f.len(), N_FEATURES);
        assert_eq!(f[0], 2.0); // count
        assert!((f[1] - 0.4).abs() < 1e-12); // sum rate
        assert_eq!(f[3], 32.0); // max size
        assert_eq!(f[4], 20.0); // mean size
        assert_eq!(f[6], 16.0); // a_max
    }

    #[test]
    fn empty_set_is_all_zero_except_amax() {
        let f = features(&[], 8);
        assert_eq!(f, vec![0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 8.0]);
    }
}
