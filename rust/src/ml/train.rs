//! Training orchestration: the paper's ML learning phase.  For each task
//! (throughput regression, starvation classification) and each model type
//! (KNN, RF, SVM), run successive-halving grid search with 5-fold CV over
//! the Appendix-B hyperparameter grids, and return the fitted best model.

use super::cv::halving_search;
use super::dataset::Sample;
use super::forest::{Forest, ForestParams};
use super::knn::{Knn, KnnParams};
use super::metrics::{macro_f1, mse};
use super::model::Predictor;
use super::scaler::Scaler;
use super::svm::{Kernel, Svc, SvcParams, Svr, SvrParams};
use super::tree::{Criterion, TreeParams};

/// Prediction task (the deployed pair trains one model per task).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    /// Throughput regression (tok/s).
    Throughput,
    /// Starvation binary classification.
    Starvation,
}

/// Model family to grid-search (Table 3 compares all three).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelType {
    /// k-nearest-neighbours.
    Knn,
    /// Random forest (the deployed choice).
    RandomForest,
    /// Support vector machine.
    Svm,
}

impl ModelType {
    /// Short display name (Table 3 rows).
    pub fn name(&self) -> &'static str {
        match self {
            ModelType::Knn => "KNN",
            ModelType::RandomForest => "RF",
            ModelType::Svm => "SVM",
        }
    }
}

/// Extract the label column for `task`.
pub fn labels(samples: &[Sample], task: Task) -> Vec<f64> {
    samples
        .iter()
        .map(|s| match task {
            Task::Throughput => s.throughput,
            Task::Starvation => s.starved as i32 as f64,
        })
        .collect()
}

/// Extract the feature matrix.
pub fn xs(samples: &[Sample]) -> Vec<Vec<f64>> {
    samples.iter().map(|s| s.x.clone()).collect()
}

/// CV score: negative MSE for regression, macro-F1 for classification.
fn score(task: Task, actual: &[f64], predicted: &[f64]) -> f64 {
    match task {
        Task::Throughput => -mse(actual, predicted),
        Task::Starvation => macro_f1(actual, predicted),
    }
}

/// Train one model type on one task with halving grid search; returns the
/// fitted predictor (on all data) and the best CV score.
pub fn train(
    samples: &[Sample],
    task: Task,
    model: ModelType,
    quick: bool,
    seed: u64,
) -> (Predictor, f64) {
    let xs_all = xs(samples);
    let ys = labels(samples, task);
    let scaler = Scaler::fit(&xs_all);
    let xs_std = scaler.transform(&xs_all);
    let folds = 5;
    let factor = 3;
    let min_samples = 64;

    match model {
        ModelType::RandomForest => {
            // Appendix B grid (subset when quick).
            let mut grid = vec![];
            let n_estimators: &[usize] = if quick { &[32, 128] } else { &[32, 128, 256] };
            let max_depths: &[Option<usize>] =
                if quick { &[None, Some(10)] } else { &[None, Some(5), Some(10), Some(20)] };
            let min_leaf: &[usize] = if quick { &[1, 5] } else { &[1, 2, 5, 10] };
            for &ne in n_estimators {
                for &md in max_depths {
                    for &ml in min_leaf {
                        grid.push(ForestParams {
                            n_estimators: ne,
                            tree: TreeParams {
                                criterion: match task {
                                    Task::Throughput => Criterion::Mse,
                                    Task::Starvation => Criterion::Gini,
                                },
                                max_depth: md,
                                min_samples_leaf: ml,
                                ..Default::default()
                            },
                            subsample: 1.0,
                            seed,
                        });
                    }
                }
            }
            let (best, sc) = halving_search(
                &xs_all,
                &ys,
                &grid,
                folds,
                factor,
                min_samples,
                seed,
                |tx, ty, vx, vy, p| {
                    let f = Forest::fit(tx, ty, p);
                    score(task, vy, &f.predict(vx))
                },
            );
            (Predictor::Forest(Forest::fit(&xs_all, &ys, &grid[best])), sc)
        }
        ModelType::Knn => {
            // Paper: fixed n_neighbors=1, leaf_size=8, kd_tree; tune p.
            let grid = vec![
                KnnParams { k: 1, p: 1.0, leaf_size: 8 },
                KnnParams { k: 1, p: 2.0, leaf_size: 8 },
            ];
            let (best, sc) = halving_search(
                &xs_std,
                &ys,
                &grid,
                folds,
                factor,
                min_samples,
                seed,
                |tx, ty, vx, vy, p| {
                    let m = Knn::fit(tx, ty, p);
                    score(task, vy, &m.predict(vx))
                },
            );
            (Predictor::Knn(Box::new(Knn::fit(&xs_std, &ys, &grid[best]))), sc)
        }
        ModelType::Svm => {
            // SVM cost scales quadratically; cap the training subset.
            let cap = if quick { 400 } else { 1200 };
            let take = xs_std.len().min(cap);
            let xs_sub = &xs_std[..take];
            let ys_sub = &ys[..take];
            let gamma = Kernel::scale_gamma(xs_sub);
            let cs: &[f64] = if quick { &[1.0, 100.0] } else { &[0.1, 1.0, 10.0, 100.0, 1000.0] };
            match task {
                Task::Starvation => {
                    let mut grid = vec![];
                    for &c in cs {
                        for kernel in [Kernel::Linear, Kernel::Rbf { gamma }] {
                            grid.push(SvcParams { c, kernel, ..Default::default() });
                        }
                    }
                    let (best, sc) = halving_search(
                        xs_sub,
                        ys_sub,
                        &grid,
                        folds,
                        factor,
                        min_samples,
                        seed,
                        |tx, ty, vx, vy, p| {
                            let m = Svc::fit(tx, ty, p);
                            score(task, vy, &m.predict(vx))
                        },
                    );
                    (Predictor::Svc(Box::new(Svc::fit(xs_sub, ys_sub, &grid[best]))), sc)
                }
                Task::Throughput => {
                    // Normalize the target too (SVR epsilon is scale-bound).
                    let y_scale = ys_sub.iter().fold(1e-9f64, |m, &y| m.max(y.abs()));
                    let ys_n: Vec<f64> = ys_sub.iter().map(|y| y / y_scale).collect();
                    let mut grid = vec![];
                    for &c in cs {
                        for kernel in [Kernel::Linear, Kernel::Rbf { gamma }] {
                            for eps in [0.01, 0.05] {
                                grid.push(SvrParams {
                                    c,
                                    epsilon: eps,
                                    kernel,
                                    ..Default::default()
                                });
                            }
                        }
                    }
                    let (best, sc) = halving_search(
                        xs_sub,
                        &ys_n,
                        &grid,
                        folds,
                        factor,
                        min_samples,
                        seed,
                        |tx, ty, vx, vy, p| {
                            let m = Svr::fit(tx, ty, p);
                            score(task, vy, &m.predict(vx))
                        },
                    );
                    // Refit and wrap with the y re-scaling baked into a
                    // forest-free closure is not possible in the enum; we
                    // instead refit on unnormalized labels with scaled C.
                    let mut p = grid[best].clone();
                    p.c *= y_scale;
                    p.epsilon *= y_scale;
                    (Predictor::Svr(Box::new(Svr::fit(xs_sub, ys_sub, &p))), sc)
                }
            }
        }
    }
}

/// The scaler matching `train`'s preprocessing for KNN/SVM predictors.
pub fn fitted_scaler(samples: &[Sample]) -> Scaler {
    Scaler::fit(&xs(samples))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::features::N_FEATURES;
    use crate::util::rng::Rng;

    /// Synthetic dataset shaped like the real one: throughput saturates in
    /// sum_rate, starvation when demand exceeds a capacity that shrinks
    /// with a_max.
    fn synthetic(n: usize, seed: u64) -> Vec<Sample> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let n_ad = rng.range(8, 256) as f64;
                let sum_rate = rng.range_f64(0.1, 30.0);
                let mean_size = *rng.choose(&[8.0, 16.0, 32.0]);
                let a_max = rng.range(8, 256) as f64;
                let capacity = 1200.0 - 2.0 * a_max * mean_size / 32.0;
                let demand = sum_rate * 96.0;
                let thr = demand.min(capacity).max(0.0);
                let starved = demand > capacity;
                let mut x = vec![0.0; N_FEATURES];
                x[0] = n_ad;
                x[1] = sum_rate;
                x[2] = rng.f64();
                x[3] = mean_size;
                x[4] = mean_size;
                x[5] = 0.0;
                x[6] = a_max;
                Sample { x, throughput: thr, starved, memory_error: false }
            })
            .collect()
    }

    #[test]
    fn rf_regression_learns_saturation() {
        let data = synthetic(600, 1);
        let (m, _) = train(&data, Task::Throughput, ModelType::RandomForest, true, 7);
        let test = synthetic(100, 2);
        let pred: Vec<f64> = test.iter().map(|s| m.predict_one(&s.x)).collect();
        let actual: Vec<f64> = test.iter().map(|s| s.throughput).collect();
        let sm = crate::util::stats::smape(&actual, &pred);
        assert!(sm < 20.0, "smape={sm}");
    }

    #[test]
    fn rf_starvation_classifier_accurate() {
        let data = synthetic(600, 3);
        let (m, _) = train(&data, Task::Starvation, ModelType::RandomForest, true, 7);
        let test = synthetic(150, 4);
        let pred: Vec<f64> = test.iter().map(|s| m.predict_one(&s.x)).collect();
        let actual: Vec<f64> = test.iter().map(|s| s.starved as i32 as f64).collect();
        let f1 = macro_f1(&actual, &pred);
        assert!(f1 > 0.8, "f1={f1}");
    }

    #[test]
    fn knn_trains_and_predicts() {
        let data = synthetic(300, 5);
        let (m, _) = train(&data, Task::Starvation, ModelType::Knn, true, 7);
        // KNN predictor consumes *standardized* features.
        let sc = fitted_scaler(&data);
        let x = sc.transform_one(&data[0].x);
        let p = m.predict_one(&x);
        assert!((0.0..=1.0).contains(&p));
    }
}
