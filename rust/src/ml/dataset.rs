//! Training-set generation by sweeping the Digital Twin across workload ×
//! device configurations (paper §8.3): Cartesian combinations of three
//! adapter sizes and three arrival rates, swept over adapter counts and
//! `A_max`, simulated with Poisson arrivals and mean request lengths.

use super::features::{features, FEATURE_NAMES};
use crate::config::EngineConfig;
use crate::dt::{self, Calibration, LengthVariant};
use crate::util::csv::Table;
use crate::util::rng::Rng;
use crate::util::threadpool::parallel_map;
use crate::workload::{AdapterSpec, WorkloadSpec};
use std::path::Path;

/// One training sample.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    /// Feature vector ([`FEATURE_NAMES`] order).
    pub x: Vec<f64>,
    /// DT-simulated throughput label (tok/s).
    pub throughput: f64,
    /// DT-simulated starvation label.
    pub starved: bool,
    /// Static reservation exceeded GPU memory (labelled starved too, with
    /// zero throughput, so the classifier learns to reject these configs).
    pub memory_error: bool,
}

/// Sweep specification.
#[derive(Debug, Clone)]
pub struct GridSpec {
    /// Adapter size (rank) candidate set.
    pub sizes: Vec<usize>,
    /// Arrival rate candidate set (req/s).
    pub rates: Vec<f64>,
    /// Adapter counts swept.
    pub adapter_counts: Vec<usize>,
    /// `A_max` values swept.
    pub a_max_values: Vec<usize>,
    /// Simulated horizon per scenario (s).
    pub horizon_s: f64,
    /// Cap on the number of scenarios (deterministically subsampled).
    pub max_scenarios: usize,
    /// Sweep seed (scenario subsampling + per-scenario workloads).
    pub seed: u64,
}

impl GridSpec {
    /// Paper §8.3 grid, subsampled for this testbed's CPU budget.
    pub fn paper(quick: bool) -> GridSpec {
        GridSpec {
            sizes: vec![8, 16, 32],
            rates: vec![3.2, 1.6, 0.8, 0.4, 0.1, 0.05, 0.025, 0.0125, 0.00625, 0.003125],
            adapter_counts: vec![8, 16, 32, 64, 96, 128, 160, 192, 256, 320, 384],
            a_max_values: vec![8, 16, 32, 64, 96, 128, 160, 192, 256, 320, 384],
            horizon_s: if quick { 20.0 } else { 40.0 },
            max_scenarios: if quick { 1500 } else { 8000 },
            seed: 0xDA7A,
        }
    }
}

/// Combinations with replacement of exactly 3 elements.
fn combos3<T: Copy>(set: &[T]) -> Vec<[T; 3]> {
    let mut out = vec![];
    for i in 0..set.len() {
        for j in i..set.len() {
            for k in j..set.len() {
                out.push([set[i], set[j], set[k]]);
            }
        }
    }
    out
}

/// Generate the dataset by running the DT (Mean length variant) per
/// scenario, in parallel.
pub fn generate(
    calib: &Calibration,
    base_cfg: &EngineConfig,
    grid: &GridSpec,
    workers: usize,
) -> Vec<Sample> {
    let size_combos = combos3(&grid.sizes);
    let rate_combos = combos3(&grid.rates);
    let mut scenarios: Vec<(usize, [usize; 3], [f64; 3], usize, u64)> = vec![];
    let mut tag = 0u64;
    for &n in &grid.adapter_counts {
        for sc in &size_combos {
            for rc in &rate_combos {
                for &a_max in &grid.a_max_values {
                    // A_max above the adapter count is meaningless in vLLM.
                    if a_max > n {
                        continue;
                    }
                    scenarios.push((n, *sc, *rc, a_max, tag));
                    tag += 1;
                }
            }
        }
    }
    let mut rng = Rng::new(grid.seed);
    rng.shuffle(&mut scenarios);
    scenarios.truncate(grid.max_scenarios);

    let calib = calib.clone();
    let base = base_cfg.clone();
    let horizon = grid.horizon_s;
    let seed = grid.seed;
    parallel_map(scenarios, workers, move |(n, sizes, rates, a_max, tag)| {
        let mut arng = Rng::new(seed ^ tag.wrapping_mul(0x9E3779B97F4A7C15));
        let adapters: Vec<AdapterSpec> = (0..n)
            .map(|id| AdapterSpec {
                id,
                rank: *arng.choose(&sizes),
                rate: *arng.choose(&rates),
            })
            .collect();
        let s_max = adapters.iter().map(|a| a.rank).max().unwrap_or(8);
        let mut cfg = base.clone();
        cfg.a_max = a_max;
        cfg.s_max_rank = s_max;
        let spec = WorkloadSpec::sharegpt_like(adapters.clone(), horizon, seed ^ tag);
        let res = dt::run_twin(&cfg, &calib, &spec, LengthVariant::Mean);
        let x = features(&adapters, a_max);
        match res.report {
            Some(rep) => Sample {
                x,
                throughput: rep.throughput_tok_s,
                starved: rep.starved,
                memory_error: false,
            },
            None => Sample { x, throughput: 0.0, starved: true, memory_error: true },
        }
    })
}

/// Persist samples as CSV (feature columns + labels).
pub fn save(samples: &[Sample], path: &Path) -> anyhow::Result<()> {
    let mut cols: Vec<&str> = FEATURE_NAMES.to_vec();
    cols.extend(["throughput", "starved", "memory_error"]);
    let mut t = Table::new(&cols);
    for s in samples {
        let mut row: Vec<String> = s.x.iter().map(|v| format!("{v}")).collect();
        row.push(format!("{}", s.throughput));
        row.push(format!("{}", s.starved as i32));
        row.push(format!("{}", s.memory_error as i32));
        t.push(row);
    }
    t.write_file(path)
}

/// Load a dataset written by [`save`].
pub fn load(path: &Path) -> anyhow::Result<Vec<Sample>> {
    let t = Table::read_file(path)?;
    let nf = FEATURE_NAMES.len();
    let thr = t.f64_col("throughput")?;
    let st = t.f64_col("starved")?;
    let me = t.f64_col("memory_error")?;
    let mut out = Vec::with_capacity(t.rows.len());
    for (i, row) in t.rows.iter().enumerate() {
        let x: Vec<f64> = row[..nf]
            .iter()
            .map(|c| c.parse::<f64>().unwrap_or(0.0))
            .collect();
        out.push(Sample {
            x,
            throughput: thr[i],
            starved: st[i] >= 0.5,
            memory_error: me[i] >= 0.5,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn combos3_counts() {
        // C(3+2, 3) = 10 combinations with replacement of 3 from 3.
        assert_eq!(combos3(&[1, 2, 3]).len(), 10);
        assert_eq!(combos3(&[1, 2]).len(), 4);
    }

    #[test]
    fn generate_small_grid() {
        let grid = GridSpec {
            sizes: vec![8, 32],
            rates: vec![0.2, 0.05],
            adapter_counts: vec![8, 16],
            a_max_values: vec![8, 16],
            horizon_s: 5.0,
            max_scenarios: 12,
            seed: 3,
        };
        let samples = generate(&Calibration::default(), &EngineConfig::default(), &grid, 2);
        assert_eq!(samples.len(), 12);
        assert!(samples.iter().all(|s| s.x.len() == FEATURE_NAMES.len()));
        assert!(samples.iter().any(|s| s.throughput > 0.0));
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join(format!("ds_test_{}", std::process::id()));
        let path = dir.join("ds.csv");
        let samples = vec![
            Sample { x: vec![1.0; 7], throughput: 100.0, starved: false, memory_error: false },
            Sample { x: vec![2.0; 7], throughput: 0.0, starved: true, memory_error: true },
        ];
        save(&samples, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back, samples);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn deterministic_given_seed() {
        let grid = GridSpec {
            sizes: vec![8],
            rates: vec![0.1],
            adapter_counts: vec![8],
            a_max_values: vec![8],
            horizon_s: 3.0,
            max_scenarios: 3,
            seed: 7,
        };
        let a = generate(&Calibration::default(), &EngineConfig::default(), &grid, 2);
        let b = generate(&Calibration::default(), &EngineConfig::default(), &grid, 1);
        assert_eq!(a, b);
    }
}
