//! Drifting / phased workloads for the rolling-horizon re-placement loop
//! (DESIGN.md §7).
//!
//! The paper's pipeline computes one placement for one static
//! [`WorkloadSpec`]; production adapter traffic drifts: request rates ramp
//! and oscillate diurnally, and adapters appear and retire as products
//! launch and sunset.  A [`DriftSpec`] describes such a horizon as a
//! sequence of `epochs` equal-length windows and compiles each epoch into
//! an ordinary [`WorkloadSpec`] with a deterministic per-epoch seed, so
//! every layer built for static workloads (engine, twin, placement,
//! cluster) can be driven epoch-by-epoch without modification.
//!
//! Invariants (enforced by the property tests in this module and in
//! `tests/prop_invariants.rs`):
//!
//! - compilation is deterministic given the seed;
//! - the epoch windows partition the horizon exactly
//!   (`epochs · epoch_s == horizon_s`, arrivals stay inside their epoch);
//! - modulated rates never go negative;
//! - a retired adapter receives no arrivals in any epoch at or after its
//!   retirement.

use super::{AdapterSpec, WorkloadSpec};
use crate::util::rng::Rng;

/// Multiplicative rate modulation applied on top of every phase's base
/// rate, evaluated per epoch.
#[derive(Debug, Clone, PartialEq)]
pub enum RateDrift {
    /// Rates are constant across the horizon.
    None,
    /// Linear ramp of the rate multiplier from `from` (first epoch) to
    /// `to` (last epoch), evaluated at the epoch midpoint.
    Ramp {
        /// Multiplier at the start of the horizon.
        from: f64,
        /// Multiplier at the end of the horizon.
        to: f64,
    },
    /// Diurnal modulation: `1 + amplitude · sin(2π · (e / period + phase))`
    /// where `e` is the epoch index.
    Diurnal {
        /// Peak deviation from the base rate (0.3 = ±30%).
        amplitude: f64,
        /// Full oscillation period, in epochs.
        period_epochs: f64,
        /// Phase offset in fractions of a period.
        phase: f64,
    },
}

impl RateDrift {
    /// Rate multiplier for `epoch` of `epochs`, clamped to be non-negative
    /// (a ramp to a negative multiplier bottoms out at zero traffic).
    pub fn factor(&self, epoch: usize, epochs: usize) -> f64 {
        let f = match *self {
            RateDrift::None => 1.0,
            RateDrift::Ramp { from, to } => {
                let t = (epoch as f64 + 0.5) / epochs.max(1) as f64;
                from + (to - from) * t
            }
            RateDrift::Diurnal { amplitude, period_epochs, phase } => {
                let x = epoch as f64 / period_epochs.max(1e-9) + phase;
                1.0 + amplitude * (2.0 * std::f64::consts::PI * x).sin()
            }
        };
        f.max(0.0)
    }
}

/// One adapter's lifetime inside the horizon: active in epochs
/// `[arrive_epoch, retire_epoch)`.
#[derive(Debug, Clone, PartialEq)]
pub struct AdapterPhase {
    /// The adapter (id, rank, *base* rate before drift modulation).
    pub adapter: AdapterSpec,
    /// First epoch (inclusive) in which the adapter receives traffic.
    pub arrive_epoch: usize,
    /// First epoch (exclusive bound) in which the adapter is retired; use
    /// `usize::MAX` (or any value ≥ `epochs`) for "never retires".
    pub retire_epoch: usize,
}

impl AdapterPhase {
    /// Whether the adapter is active (receives arrivals) in `epoch`.
    pub fn active_in(&self, epoch: usize) -> bool {
        epoch >= self.arrive_epoch && epoch < self.retire_epoch
    }
}

/// A drifting workload over a rolling horizon of equal-length epochs.
///
/// ```
/// use adapter_serving::workload::drift::DriftSpec;
/// use adapter_serving::workload::WorkloadSpec;
/// let adapters = WorkloadSpec::homogeneous(8, 8, 0.2);
/// let drift = DriftSpec::ramp(adapters, 0.5, 1.5, 4, 10.0, 7);
/// let specs = drift.compile();
/// assert_eq!(specs.len(), 4);
/// // The ramp raises traffic across the horizon.
/// assert!(specs[0].total_rate() < specs[3].total_rate());
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct DriftSpec {
    /// Every adapter that ever exists in the horizon, with its lifetime.
    pub phases: Vec<AdapterPhase>,
    /// Rate modulation shared by all phases.
    pub drift: RateDrift,
    /// Number of equal-length epochs in the horizon.
    pub epochs: usize,
    /// Simulated duration of one epoch (seconds).
    pub epoch_s: f64,
    /// Master seed; per-epoch seeds are derived deterministically from it.
    pub seed: u64,
}

impl DriftSpec {
    /// No drift at all: every adapter alive for the whole horizon at a
    /// constant rate (the degenerate case where replanning is a no-op).
    pub fn steady(adapters: Vec<AdapterSpec>, epochs: usize, epoch_s: f64, seed: u64) -> DriftSpec {
        DriftSpec {
            phases: adapters
                .into_iter()
                .map(|adapter| AdapterPhase { adapter, arrive_epoch: 0, retire_epoch: usize::MAX })
                .collect(),
            drift: RateDrift::None,
            epochs,
            epoch_s,
            seed,
        }
    }

    /// Linear rate ramp over the whole adapter set (no churn).
    pub fn ramp(
        adapters: Vec<AdapterSpec>,
        from: f64,
        to: f64,
        epochs: usize,
        epoch_s: f64,
        seed: u64,
    ) -> DriftSpec {
        let base = DriftSpec::steady(adapters, epochs, epoch_s, seed);
        DriftSpec { drift: RateDrift::Ramp { from, to }, ..base }
    }

    /// Diurnal rate modulation over the whole adapter set (no churn).
    pub fn diurnal(
        adapters: Vec<AdapterSpec>,
        amplitude: f64,
        period_epochs: f64,
        epochs: usize,
        epoch_s: f64,
        seed: u64,
    ) -> DriftSpec {
        DriftSpec {
            drift: RateDrift::Diurnal { amplitude, period_epochs, phase: 0.0 },
            ..DriftSpec::steady(adapters, epochs, epoch_s, seed)
        }
    }

    /// Adapter-churn workload: `n_base` adapters (ids `0..n_base`) alive
    /// for the whole horizon, plus `n_churn` adapters that appear at a
    /// random epoch and retire after a random lifetime of at most half the
    /// horizon.  Ranks and rates are sampled uniformly from the given sets
    /// (the §8.2 Cartesian methodology).  Fully deterministic given `seed`.
    #[allow(clippy::too_many_arguments)]
    pub fn churn(
        n_base: usize,
        n_churn: usize,
        ranks: &[usize],
        rates: &[f64],
        epochs: usize,
        epoch_s: f64,
        seed: u64,
    ) -> DriftSpec {
        let mut rng = Rng::new(seed ^ 0xD21F7);
        let mut phases: Vec<AdapterPhase> = (0..n_base)
            .map(|id| AdapterPhase {
                adapter: AdapterSpec { id, rank: *rng.choose(ranks), rate: *rng.choose(rates) },
                arrive_epoch: 0,
                retire_epoch: usize::MAX,
            })
            .collect();
        let max_life = (epochs / 2).max(1);
        for i in 0..n_churn {
            let arrive = rng.below(epochs.max(1));
            let life = 1 + rng.below(max_life);
            phases.push(AdapterPhase {
                adapter: AdapterSpec {
                    id: n_base + i,
                    rank: *rng.choose(ranks),
                    rate: *rng.choose(rates),
                },
                arrive_epoch: arrive,
                retire_epoch: (arrive + life).min(epochs),
            });
        }
        DriftSpec { phases, drift: RateDrift::None, epochs, epoch_s, seed }
    }

    /// Total simulated horizon (seconds): the epochs partition it exactly.
    pub fn horizon_s(&self) -> f64 {
        self.epochs as f64 * self.epoch_s
    }

    /// Absolute start time of `epoch` within the horizon (seconds).
    pub fn epoch_start_s(&self, epoch: usize) -> f64 {
        epoch as f64 * self.epoch_s
    }

    /// The adapters active in `epoch`, with drift-modulated rates.
    pub fn adapters_at(&self, epoch: usize) -> Vec<AdapterSpec> {
        let f = self.drift.factor(epoch, self.epochs);
        self.phases
            .iter()
            .filter(|p| p.active_in(epoch))
            .map(|p| AdapterSpec {
                id: p.adapter.id,
                rank: p.adapter.rank,
                rate: (p.adapter.rate * f).max(0.0),
            })
            .collect()
    }

    /// Compile `epoch` into an ordinary [`WorkloadSpec`] covering
    /// `[epoch_start_s(epoch), epoch_start_s(epoch + 1))`, with a seed
    /// derived deterministically from the master seed and the epoch index.
    pub fn epoch_spec(&self, epoch: usize) -> WorkloadSpec {
        let seed = self.seed ^ (epoch as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15);
        WorkloadSpec::sharegpt_like(self.adapters_at(epoch), self.epoch_s, seed)
    }

    /// Compile the whole horizon: one [`WorkloadSpec`] per epoch.
    pub fn compile(&self) -> Vec<WorkloadSpec> {
        (0..self.epochs).map(|e| self.epoch_spec(e)).collect()
    }

    /// The union workload: every adapter that is ever active, at its *peak*
    /// drift-modulated rate.  This is what a static (plan-once) deployment
    /// must provision for, and the baseline the drift experiment compares
    /// replanning against.
    pub fn union_adapters(&self) -> Vec<AdapterSpec> {
        let mut out: Vec<AdapterSpec> = Vec::new();
        for p in &self.phases {
            let last = p.retire_epoch.min(self.epochs);
            if p.arrive_epoch >= last {
                continue;
            }
            let peak_factor = (p.arrive_epoch..last)
                .map(|e| self.drift.factor(e, self.epochs))
                .fold(0.0, f64::max);
            out.push(AdapterSpec {
                id: p.adapter.id,
                rank: p.adapter.rank,
                rate: (p.adapter.rate * peak_factor).max(0.0),
            });
        }
        out.sort_by_key(|a| a.id);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::Prop;

    #[test]
    fn epoch_specs_are_deterministic() {
        let d = DriftSpec::churn(8, 16, &[8, 16], &[0.1, 0.2], 6, 5.0, 42);
        let a = d.compile();
        let b = d.compile();
        assert_eq!(a.len(), 6);
        for (sa, sb) in a.iter().zip(&b) {
            assert_eq!(sa.adapters, sb.adapters);
            assert_eq!(sa.trace(), sb.trace());
        }
    }

    #[test]
    fn epochs_partition_horizon_exactly() {
        let d = DriftSpec::steady(WorkloadSpec::homogeneous(4, 8, 0.5), 5, 7.0, 1);
        assert!((d.horizon_s() - 35.0).abs() < 1e-12);
        let total: f64 = d.compile().iter().map(|s| s.horizon_s).sum();
        assert!((total - d.horizon_s()).abs() < 1e-9);
        for (e, s) in d.compile().iter().enumerate() {
            assert!((d.epoch_start_s(e + 1) - d.epoch_start_s(e) - s.horizon_s).abs() < 1e-12);
            assert!(s.trace().iter().all(|a| a.time_s >= 0.0 && a.time_s < s.horizon_s));
        }
    }

    #[test]
    fn ramp_modulates_rates_monotonically() {
        let d = DriftSpec::ramp(WorkloadSpec::homogeneous(4, 8, 1.0), 0.5, 2.0, 4, 5.0, 3);
        let rates: Vec<f64> =
            (0..4).map(|e| d.adapters_at(e).iter().map(|a| a.rate).sum()).collect();
        assert!(rates.windows(2).all(|w| w[0] < w[1]), "{rates:?}");
    }

    #[test]
    fn diurnal_oscillates_around_base() {
        let d = DriftSpec::diurnal(WorkloadSpec::homogeneous(2, 8, 1.0), 0.5, 4.0, 8, 5.0, 3);
        let rates: Vec<f64> =
            (0..8).map(|e| d.adapters_at(e).iter().map(|a| a.rate).sum()).collect();
        let max = rates.iter().cloned().fold(0.0, f64::max);
        let min = rates.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max > 2.0 && min < 2.0, "{rates:?}");
    }

    #[test]
    fn union_covers_every_phase_at_peak_rate() {
        let d = DriftSpec::churn(4, 8, &[8], &[0.1], 6, 5.0, 9);
        let union = d.union_adapters();
        assert_eq!(union.len(), 12);
        let ids: Vec<usize> = union.iter().map(|a| a.id).collect();
        assert_eq!(ids, (0..12).collect::<Vec<_>>());
    }

    #[test]
    fn prop_epoch_traces_deterministic_under_seed() {
        Prop::new("drift determinism").cases(24).check(|rng, size| {
            let epochs = 2 + size % 6;
            let d = DriftSpec::churn(
                1 + size,
                size,
                &[8, 16, 32],
                &[0.05, 0.1, 0.4],
                epochs,
                4.0,
                rng.next_u64(),
            );
            let d2 = d.clone();
            for e in 0..epochs {
                prop_assert!(
                    d.epoch_spec(e).trace() == d2.epoch_spec(e).trace(),
                    "epoch {e} trace not deterministic"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn prop_rates_stay_non_negative_through_ramps() {
        Prop::new("drift non-negative rates").cases(48).check(|rng, size| {
            let from = rng.range_f64(-1.0, 2.0);
            let to = rng.range_f64(-2.0, 2.0);
            let epochs = 1 + size % 8;
            let d = DriftSpec::ramp(
                WorkloadSpec::homogeneous(1 + size % 5, 8, 0.5),
                from,
                to,
                epochs,
                3.0,
                rng.next_u64(),
            );
            for e in 0..epochs {
                for a in d.adapters_at(e) {
                    prop_assert!(a.rate >= 0.0, "negative rate {} in epoch {e}", a.rate);
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_retired_adapters_get_no_arrivals() {
        Prop::new("churned-out adapters silent").cases(24).check(|rng, size| {
            let epochs = 2 + size % 6;
            let d = DriftSpec::churn(
                size % 4,
                2 + size,
                &[8, 16],
                &[0.5, 1.0],
                epochs,
                4.0,
                rng.next_u64(),
            );
            for e in 0..epochs {
                let active: std::collections::BTreeSet<usize> =
                    d.phases.iter().filter(|p| p.active_in(e)).map(|p| p.adapter.id).collect();
                for arr in d.epoch_spec(e).trace() {
                    prop_assert!(
                        active.contains(&arr.adapter_id),
                        "adapter {} got an arrival in epoch {e} outside its lifetime",
                        arr.adapter_id
                    );
                }
            }
            // Specifically: after retire_epoch, never again.
            for p in &d.phases {
                for e in p.retire_epoch.min(epochs)..epochs {
                    prop_assert!(
                        !d.adapters_at(e).iter().any(|a| a.id == p.adapter.id),
                        "adapter {} active after retirement",
                        p.adapter.id
                    );
                }
            }
            Ok(())
        });
    }
}
