//! Request length distributions.

use crate::util::rng::Rng;

/// Distribution of request input/output token lengths.
#[derive(Debug, Clone, PartialEq)]
pub enum LengthDist {
    /// Every request has exactly this length.
    Fixed(usize),
    /// Lognormal parameterized by its *target* mean and coefficient of
    /// variation, clipped to [min, max].
    LogNormal {
        /// Target (pre-clip) mean length.
        mean: f64,
        /// Coefficient of variation.
        cv: f64,
        /// Lower clip (tokens).
        min: usize,
        /// Upper clip (tokens).
        max: usize,
    },
    /// Uniform over `[lo, hi]` inclusive.
    Uniform {
        /// Lower bound (tokens).
        lo: usize,
        /// Upper bound (tokens).
        hi: usize,
    },
}

impl LengthDist {
    /// Draw one length.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        match *self {
            LengthDist::Fixed(n) => n,
            LengthDist::LogNormal { mean, cv, min, max } => {
                let (mu, sigma) = lognormal_params(mean, cv);
                (rng.lognormal(mu, sigma).round() as usize).clamp(min, max)
            }
            LengthDist::Uniform { lo, hi } => rng.range(lo as i64, hi as i64) as usize,
        }
    }

    /// Unclipped analytic mean.
    pub fn mean(&self) -> f64 {
        match *self {
            LengthDist::Fixed(n) => n as f64,
            LengthDist::LogNormal { mean, .. } => mean,
            LengthDist::Uniform { lo, hi } => (lo + hi) as f64 / 2.0,
        }
    }

    /// Mean after clipping (estimated numerically once): this is what a
    /// production operator would measure and feed to the DT "Mean" variant.
    pub fn mean_clipped(&self) -> f64 {
        match *self {
            LengthDist::LogNormal { mean, cv, min, max } => {
                let (mu, sigma) = lognormal_params(mean, cv);
                let mut rng = Rng::new(0x11EA5);
                let n = 4096;
                let s: f64 = (0..n)
                    .map(|_| {
                        (rng.lognormal(mu, sigma).round()).clamp(min as f64, max as f64)
                    })
                    .sum();
                s / n as f64
            }
            _ => self.mean(),
        }
    }
}

/// Underlying (mu, sigma) for a lognormal with the given mean and CV.
fn lognormal_params(mean: f64, cv: f64) -> (f64, f64) {
    let sigma2 = (1.0 + cv * cv).ln();
    let mu = mean.ln() - sigma2 / 2.0;
    (mu, sigma2.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_is_fixed() {
        let mut rng = Rng::new(1);
        assert_eq!(LengthDist::Fixed(42).sample(&mut rng), 42);
        assert_eq!(LengthDist::Fixed(42).mean(), 42.0);
    }

    #[test]
    fn lognormal_mean_close_to_target() {
        let d = LengthDist::LogNormal { mean: 200.0, cv: 0.5, min: 1, max: 100_000 };
        let mut rng = Rng::new(2);
        let n = 100_000;
        let m: f64 = (0..n).map(|_| d.sample(&mut rng) as f64).sum::<f64>() / n as f64;
        assert!((m - 200.0).abs() < 5.0, "mean={m}");
    }

    #[test]
    fn clipping_respected() {
        let d = LengthDist::LogNormal { mean: 250.0, cv: 1.0, min: 10, max: 64 };
        let mut rng = Rng::new(3);
        for _ in 0..1000 {
            let v = d.sample(&mut rng);
            assert!((10..=64).contains(&v));
        }
        assert!(d.mean_clipped() <= 64.0);
    }

    #[test]
    fn uniform_in_range() {
        let d = LengthDist::Uniform { lo: 5, hi: 9 };
        let mut rng = Rng::new(4);
        for _ in 0..100 {
            assert!((5..=9).contains(&d.sample(&mut rng)));
        }
    }
}
