//! Workload model: adapters, request-length distributions, arrival
//! processes and trace generation.
//!
//! A workload (paper §4) is "the required adapters, their sizes, and their
//! request arrival rates", plus request length characteristics.  Traces are
//! fully deterministic given the seed.  [`drift`] extends the static model
//! with phased/drifting horizons for the rolling re-placement loop
//! (DESIGN.md §7).

pub mod arrivals;
pub mod drift;
pub mod lengths;

pub use arrivals::{ArrivalModel, UnpredictableParams};
pub use lengths::LengthDist;

use crate::util::rng::Rng;

/// One adapter to serve: identity, LoRA rank ("size") and mean arrival rate.
#[derive(Debug, Clone, PartialEq)]
pub struct AdapterSpec {
    /// Stable adapter identity (routing key across the whole pipeline).
    pub id: usize,
    /// LoRA rank — the paper's adapter "size".
    pub rank: usize,
    /// Mean request arrival rate (req/s).
    pub rate: f64,
}

/// A complete workload description (paper §4): adapters, request-length
/// marginals, the arrival process and the simulated horizon.  Traces are
/// fully deterministic given `seed`.
///
/// ```
/// use adapter_serving::workload::WorkloadSpec;
/// let adapters = WorkloadSpec::homogeneous(4, 8, 0.5);
/// let spec = WorkloadSpec::sharegpt_like(adapters, 10.0, 42);
/// let trace = spec.trace();
/// assert_eq!(trace, spec.trace()); // deterministic
/// assert!(trace.windows(2).all(|w| w[0].time_s <= w[1].time_s));
/// ```
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// The adapters receiving traffic.
    pub adapters: Vec<AdapterSpec>,
    /// Prompt-length distribution (tokens).
    pub input_len: LengthDist,
    /// Generation-length distribution (tokens).
    pub output_len: LengthDist,
    /// The arrival process shared by all adapters.
    pub arrival: ArrivalModel,
    /// Simulated duration (the paper runs 1 h per configuration; we default
    /// to a compressed horizon — see DESIGN.md §1).
    pub horizon_s: f64,
    /// Trace seed; every derived stream (per-adapter arrivals, lengths)
    /// forks deterministically from it.
    pub seed: u64,
}

/// One request arrival in a generated trace.
#[derive(Debug, Clone, PartialEq)]
pub struct Arrival {
    /// Position in the time-sorted trace (also the engine's request id).
    pub request_id: usize,
    /// Arrival time within the horizon (s).
    pub time_s: f64,
    /// The adapter this request targets.
    pub adapter_id: usize,
    /// Prompt length (tokens).
    pub input_len: usize,
    /// Generation budget (tokens).
    pub output_len: usize,
}

impl WorkloadSpec {
    /// ShareGPT-like length marginals (mean 250 in / 231 out), the paper's
    /// §8.1 data source, clipped to the engine's compiled buckets.
    pub fn sharegpt_like(adapters: Vec<AdapterSpec>, horizon_s: f64, seed: u64) -> Self {
        WorkloadSpec {
            adapters,
            input_len: LengthDist::LogNormal { mean: 250.0, cv: 0.55, min: 8, max: 256 },
            output_len: LengthDist::LogNormal { mean: 231.0, cv: 0.55, min: 4, max: 512 },
            arrival: ArrivalModel::Poisson,
            horizon_s,
            seed,
        }
    }

    /// Fixed-length variant (used by the §5.1 profiling experiments).
    pub fn fixed_len(
        adapters: Vec<AdapterSpec>,
        input_len: usize,
        output_len: usize,
        horizon_s: f64,
        seed: u64,
    ) -> Self {
        WorkloadSpec {
            adapters,
            input_len: LengthDist::Fixed(input_len),
            output_len: LengthDist::Fixed(output_len),
            arrival: ArrivalModel::Poisson,
            horizon_s,
            seed,
        }
    }

    /// Homogeneous adapter set: `n` adapters of the same rank and rate.
    pub fn homogeneous(n: usize, rank: usize, rate: f64) -> Vec<AdapterSpec> {
        (0..n).map(|id| AdapterSpec { id, rank, rate }).collect()
    }

    /// Heterogeneous adapter set: ranks and rates sampled uniformly from
    /// the given sets (paper §8.2 Cartesian methodology).
    pub fn heterogeneous(n: usize, ranks: &[usize], rates: &[f64], seed: u64) -> Vec<AdapterSpec> {
        let mut rng = Rng::new(seed ^ 0xADA97E55);
        (0..n)
            .map(|id| AdapterSpec {
                id,
                rank: *rng.choose(ranks),
                rate: *rng.choose(rates),
            })
            .collect()
    }

    /// Aggregate request rate over all adapters (req/s).
    pub fn total_rate(&self) -> f64 {
        self.adapters.iter().map(|a| a.rate).sum()
    }

    /// Expected incoming token rate (input+output tokens per second) — the
    /// denominator of the paper's starvation criterion.
    pub fn incoming_token_rate(&self) -> f64 {
        self.total_rate() * (self.input_len.mean() + self.output_len.mean())
    }

    /// Generate the full arrival trace, sorted by time.
    pub fn trace(&self) -> Vec<Arrival> {
        let mut rng = Rng::new(self.seed);
        let mut arrivals: Vec<Arrival> = Vec::new();
        for a in &self.adapters {
            let mut arng = rng.fork(a.id as u64 + 1);
            let times = self.arrival.sample_times(a.rate, self.horizon_s, &mut arng);
            for t in times {
                arrivals.push(Arrival {
                    request_id: 0, // assigned after sorting
                    time_s: t,
                    adapter_id: a.id,
                    input_len: 0,
                    output_len: 0,
                });
            }
        }
        arrivals.sort_by(|a, b| a.time_s.partial_cmp(&b.time_s).unwrap());
        let mut lrng = rng.fork(0xBEEF);
        for (i, arr) in arrivals.iter_mut().enumerate() {
            arr.request_id = i;
            arr.input_len = self.input_len.sample(&mut lrng);
            arr.output_len = self.output_len.sample(&mut lrng);
        }
        arrivals
    }

    /// The same trace with every request length replaced by the workload
    /// mean — the Digital Twin's "Mean" input variant (Table 1).
    pub fn trace_mean_lengths(&self) -> Vec<Arrival> {
        let mut t = self.trace();
        let mi = self.input_len.mean_clipped() as usize;
        let mo = self.output_len.mean_clipped() as usize;
        for a in &mut t {
            a.input_len = mi.max(1);
            a.output_len = mo.max(1);
        }
        t
    }

    /// Restrict to a subset of adapters (used by placement validation:
    /// each GPU serves the adapters assigned to it).
    pub fn subset(&self, adapter_ids: &[usize], seed: u64) -> WorkloadSpec {
        let set: std::collections::BTreeSet<usize> = adapter_ids.iter().copied().collect();
        WorkloadSpec {
            adapters: self.adapters.iter().filter(|a| set.contains(&a.id)).cloned().collect(),
            input_len: self.input_len.clone(),
            output_len: self.output_len.clone(),
            arrival: self.arrival.clone(),
            horizon_s: self.horizon_s,
            seed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_sorted_and_deterministic() {
        let spec = WorkloadSpec::sharegpt_like(WorkloadSpec::homogeneous(8, 8, 0.5), 30.0, 42);
        let t1 = spec.trace();
        let t2 = spec.trace();
        assert_eq!(t1, t2);
        assert!(t1.windows(2).all(|w| w[0].time_s <= w[1].time_s));
        assert!(t1.iter().enumerate().all(|(i, a)| a.request_id == i));
    }

    #[test]
    fn poisson_rate_approximately_right() {
        let spec = WorkloadSpec::sharegpt_like(WorkloadSpec::homogeneous(20, 8, 1.0), 100.0, 7);
        let t = spec.trace();
        // 20 adapters × 1 req/s × 100 s = 2000 expected
        let n = t.len() as f64;
        assert!((n - 2000.0).abs() < 200.0, "n={n}");
    }

    #[test]
    fn lengths_within_bounds() {
        let spec = WorkloadSpec::sharegpt_like(WorkloadSpec::homogeneous(4, 8, 2.0), 20.0, 3);
        for a in spec.trace() {
            assert!((8..=256).contains(&a.input_len));
            assert!((4..=512).contains(&a.output_len));
        }
    }

    #[test]
    fn mean_variant_has_constant_lengths() {
        let spec = WorkloadSpec::sharegpt_like(WorkloadSpec::homogeneous(4, 8, 2.0), 20.0, 3);
        let t = spec.trace_mean_lengths();
        assert!(t.windows(2).all(|w| w[0].input_len == w[1].input_len));
        assert!(t.windows(2).all(|w| w[0].output_len == w[1].output_len));
    }

    #[test]
    fn subset_filters_adapters() {
        let spec = WorkloadSpec::sharegpt_like(WorkloadSpec::homogeneous(10, 8, 0.5), 10.0, 1);
        let sub = spec.subset(&[2, 5], 99);
        assert_eq!(sub.adapters.len(), 2);
        assert!(sub.trace().iter().all(|a| a.adapter_id == 2 || a.adapter_id == 5));
    }

    #[test]
    fn heterogeneous_uses_given_sets() {
        let ads = WorkloadSpec::heterogeneous(50, &[8, 16, 32], &[0.1, 0.2], 5);
        assert!(ads.iter().all(|a| [8, 16, 32].contains(&a.rank)));
        assert!(ads.iter().all(|a| [0.1, 0.2].contains(&a.rate)));
        // With 50 draws we should see more than one rank.
        let first = ads[0].rank;
        assert!(ads.iter().any(|a| a.rank != first));
    }

    #[test]
    fn incoming_token_rate_matches_means() {
        let spec = WorkloadSpec::fixed_len(WorkloadSpec::homogeneous(2, 8, 0.5), 100, 50, 10.0, 1);
        assert!((spec.incoming_token_rate() - 1.0 * 150.0).abs() < 1e-9);
    }
}
