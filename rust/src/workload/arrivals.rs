//! Arrival processes: predictable (Poisson) and the paper's unpredictable
//! regime-switching traffic (§8.2 "Unpredictable arrivals").

use crate::util::rng::Rng;

/// Parameters of the unpredictable regime-switching process: every
/// `switch_interval_s` each adapter independently re-draws its inter-arrival
/// distribution (Poisson vs lognormal) and multiplies or divides its rate by
/// two, clipped to [min_rate, max_rate].
#[derive(Debug, Clone, PartialEq)]
pub struct UnpredictableParams {
    /// Seconds between regime re-draws.
    pub switch_interval_s: f64,
    /// Lower clip for the drifting rate (req/s).
    pub min_rate: f64,
    /// Upper clip for the drifting rate (req/s).
    pub max_rate: f64,
    /// CV of the lognormal inter-arrival regime (Poisson has CV 1).
    pub lognormal_cv: f64,
}

impl Default for UnpredictableParams {
    fn default() -> Self {
        UnpredictableParams {
            switch_interval_s: 5.0,
            min_rate: 0.0125,
            max_rate: 6.4,
            lognormal_cv: 1.6,
        }
    }
}

/// The arrival process shared by every adapter in a workload.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalModel {
    /// Stationary Poisson per adapter — the paper's predictable long-term
    /// pattern assumption.
    Poisson,
    /// Non-stationary regime-switching traffic (paper Fig. 9).
    Unpredictable(UnpredictableParams),
}

impl ArrivalModel {
    /// Sample arrival times in [0, horizon) for one adapter with base rate
    /// `rate` (req/s).
    pub fn sample_times(&self, rate: f64, horizon_s: f64, rng: &mut Rng) -> Vec<f64> {
        match self {
            ArrivalModel::Poisson => poisson_times(rate, 0.0, horizon_s, rng),
            ArrivalModel::Unpredictable(p) => unpredictable_times(rate, horizon_s, p, rng),
        }
    }
}

fn poisson_times(rate: f64, start: f64, end: f64, rng: &mut Rng) -> Vec<f64> {
    let mut out = vec![];
    if rate <= 0.0 {
        return out;
    }
    let mut t = start + rng.exp(rate);
    while t < end {
        out.push(t);
        t += rng.exp(rate);
    }
    out
}

/// Lognormal-renewal arrivals with mean inter-arrival 1/rate and given CV.
fn lognormal_times(rate: f64, cv: f64, start: f64, end: f64, rng: &mut Rng) -> Vec<f64> {
    let mut out = vec![];
    if rate <= 0.0 {
        return out;
    }
    let mean_gap = 1.0 / rate;
    let sigma2 = (1.0 + cv * cv).ln();
    let mu = mean_gap.ln() - sigma2 / 2.0;
    let sigma = sigma2.sqrt();
    let mut t = start + rng.lognormal(mu, sigma);
    while t < end {
        out.push(t);
        t += rng.lognormal(mu, sigma);
    }
    out
}

fn unpredictable_times(
    base_rate: f64,
    horizon_s: f64,
    p: &UnpredictableParams,
    rng: &mut Rng,
) -> Vec<f64> {
    let mut out = vec![];
    let mut rate = base_rate;
    let mut t0 = 0.0;
    while t0 < horizon_s {
        let t1 = (t0 + p.switch_interval_s).min(horizon_s);
        // Re-draw regime for this window.
        let use_lognormal = rng.bool(0.5);
        if rng.bool(0.5) {
            rate *= 2.0;
        } else {
            rate /= 2.0;
        }
        rate = rate.clamp(p.min_rate, p.max_rate);
        let times = if use_lognormal {
            lognormal_times(rate, p.lognormal_cv, t0, t1, rng)
        } else {
            poisson_times(rate, t0, t1, rng)
        };
        out.extend(times);
        t0 = t1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_count_matches_rate() {
        let mut rng = Rng::new(1);
        let times = ArrivalModel::Poisson.sample_times(2.0, 1000.0, &mut rng);
        let n = times.len() as f64;
        assert!((n - 2000.0).abs() < 150.0, "n={n}");
        assert!(times.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn zero_rate_yields_no_arrivals() {
        let mut rng = Rng::new(2);
        assert!(ArrivalModel::Poisson.sample_times(0.0, 100.0, &mut rng).is_empty());
    }

    #[test]
    fn unpredictable_rate_clipped() {
        let p = UnpredictableParams { min_rate: 0.5, max_rate: 1.0, ..Default::default() };
        let mut rng = Rng::new(3);
        // Even with many doublings the realized rate cannot exceed max_rate.
        let times = ArrivalModel::Unpredictable(p).sample_times(1.0, 500.0, &mut rng);
        let rate = times.len() as f64 / 500.0;
        assert!(rate <= 1.3, "rate={rate}");
        assert!(rate >= 0.3, "rate={rate}");
    }

    #[test]
    fn unpredictable_within_horizon_and_sorted() {
        let mut rng = Rng::new(4);
        let times = ArrivalModel::Unpredictable(UnpredictableParams::default())
            .sample_times(1.0, 60.0, &mut rng);
        assert!(times.iter().all(|&t| (0.0..60.0).contains(&t)));
        assert!(times.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn lognormal_renewal_mean_gap() {
        let mut rng = Rng::new(5);
        let times = lognormal_times(4.0, 1.2, 0.0, 2000.0, &mut rng);
        let rate = times.len() as f64 / 2000.0;
        assert!((rate - 4.0).abs() < 0.4, "rate={rate}");
    }
}
