//! The Digital Twin: a discrete-event emulation of the serving engine in
//! which every measured latency is replaced by a predictive-model estimate.
//!
//! The twin executes the *same* scheduler policy code as the engine
//! ([`crate::engine::scheduler`]) over the same simulated memory state
//! ([`KvLedger`], [`SimAdapterCache`]) — exactly the paper's design where
//! the DT "reproduces system behavior through simplified yet structurally
//! analogous logic" with "lightweight predictive performance models [for]
//! the most computationally intensive operations" (§5).  Fidelity error
//! therefore comes from latency prediction and (in the Mean variant) from
//! request-length abstraction, which is what Table 1 quantifies.

use super::perf_model::Calibration;
use crate::config::EngineConfig;
use crate::engine::adapter_cache::SimAdapterCache;
use crate::engine::kv::KvLedger;
use crate::engine::metrics::{MetricsCollector, Report};
use crate::engine::request::{ReqState, Request};
use crate::engine::scheduler::{grow_or_preempt, scan_admissions, AdmissionLimits};
use crate::workload::{Arrival, WorkloadSpec};
use std::collections::VecDeque;
use std::time::Instant;

/// Which request lengths the twin receives (Table 1 variants).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LengthVariant {
    /// Exact per-request input/output lengths (as observed in the system).
    Original,
    /// Workload-average lengths (the information available in practice,
    /// and what the ML dataset generation uses).
    Mean,
}

/// Result of a twin run.
pub struct TwinResult {
    /// Serving report; `None` on memory error.
    pub report: Option<Report>,
    /// Static reservation exceeded GPU memory (infeasible configuration).
    pub memory_error: bool,
    /// Wall-clock seconds the simulation itself took (Table 2).
    pub wall_s: f64,
    /// Simulated iterations executed.
    pub iterations: usize,
}

/// Run the Digital Twin for `spec` under engine configuration `cfg`.
pub fn run(
    cfg: &EngineConfig,
    calib: &Calibration,
    spec: &WorkloadSpec,
    variant: LengthVariant,
) -> TwinResult {
    let trace = match variant {
        LengthVariant::Original => spec.trace(),
        LengthVariant::Mean => spec.trace_mean_lengths(),
    };
    run_trace(cfg, calib, spec, &trace)
}

/// Run the twin over an explicit trace (the DT input interface: arrival
/// time, adapter, size, input length and expected output length per
/// request — paper §5).
pub fn run_trace(
    cfg: &EngineConfig,
    calib: &Calibration,
    spec: &WorkloadSpec,
    trace: &[Arrival],
) -> TwinResult {
    // detlint: allow(wall-clock) — reported `wall_s` is measurement only; it never feeds simulated state
    #[allow(clippy::disallowed_methods)]
    let wall0 = Instant::now();
    let Some(pool) = cfg.kv_pool_tokens() else {
        return TwinResult {
            report: None,
            memory_error: true,
            wall_s: wall0.elapsed().as_secs_f64(),
            iterations: 0,
        };
    };

    // Lookup-only (never iterated), so hash order is not observable.
    #[allow(clippy::disallowed_types)]
    let rank_of: std::collections::HashMap<usize, usize> =
        spec.adapters.iter().map(|a| (a.id, a.rank)).collect();
    let mut requests: Vec<Request> = trace
        .iter()
        .map(|a| {
            Request::new(
                a.request_id,
                a.adapter_id,
                rank_of.get(&a.adapter_id).copied().unwrap_or(0),
                a.time_s,
                a.input_len,
                a.output_len,
            )
        })
        .collect();

    let mut waiting: VecDeque<usize> = VecDeque::new();
    let mut prefill_queue: VecDeque<usize> = VecDeque::new();
    let mut running: Vec<usize> = Vec::new();
    let mut ledger = KvLedger::new(cfg.mem.clone(), pool);
    let mut cache = SimAdapterCache::new(cfg.a_max);
    let mut metrics = MetricsCollector::default();
    let max_running = cfg.max_num_seqs.min(calib.max_decode_bucket());
    let limits = AdmissionLimits {
        max_running,
        max_prefill_tokens: 1024,
        unified: cfg.mem.unified,
    };
    let adapters_total = spec.adapters.len();
    let max_prefill = calib.max_prefill_bucket();

    let mut sim_time = 0.0f64;
    let mut next_arrival = 0usize;
    let mut iterations = 0usize;

    while sim_time < spec.horizon_s {
        iterations += 1;
        // detlint: allow(panic-path) — `trace` is indexed within its own recorded length
        while next_arrival < trace.len() && trace[next_arrival].time_s <= sim_time {
            let a = &trace[next_arrival];
            metrics.on_arrival(a.input_len, a.output_len);
            waiting.push_back(a.request_id);
            next_arrival += 1;
        }

        // Scheduler (predicted cost instead of measured).
        let batch_now = running.len();
        let a_b_now = distinct_adapters(&running, &requests);
        let pending_now = waiting.len();
        let adm = scan_admissions(
            &mut waiting,
            &mut requests,
            &mut ledger,
            &mut cache,
            running.len() + prefill_queue.len(),
            limits,
        );
        let sched_s = calib.lat_sched(batch_now, pending_now, a_b_now, adapters_total);

        // Swap-ins: predicted load latency.
        let mut load_s = 0.0;
        for ev in &adm.loads {
            load_s += calib.lat_load(ev.rank);
            metrics.swap_ins += 1;
        }
        prefill_queue.extend(adm.admitted.iter().copied());

        if let Some(id) = prefill_queue.pop_front() {
            // detlint: allow(panic-path) — `requests` is the request arena; ids are indices it issued itself
            let r = &mut requests[id];
            let prompt_len = (r.input_len + r.generated).min(max_prefill);
            let bucket = calib.prefill_bucket(prompt_len.max(1));
            let exec_s = calib.lat_prefill(bucket);
            sim_time += sched_s + load_s + exec_s + calib.iter_overhead_s;
            let first_time = r.first_token_s.is_none();
            r.generated += 1;
            r.context_len += 1;
            r.state = ReqState::Running;
            r.first_token_s.get_or_insert(sim_time);
            r.token_times.push(sim_time);
            let input_len = r.input_len;
            if first_time {
                metrics.on_prefill(input_len, sim_time);
            }
            metrics.on_decode_tokens(1, sim_time);
            running.push(id);
            finish_if_done(
                id,
                sim_time,
                &mut requests,
                &mut running,
                &mut ledger,
                &mut cache,
                &mut metrics,
            );
        } else if !running.is_empty() {
            let preempted = grow_or_preempt(
                &mut running,
                &mut requests,
                &mut ledger,
                &mut cache,
                limits.unified,
            );
            for id in preempted {
                metrics.preemptions += 1;
                waiting.push_front(id);
            }
            if running.is_empty() {
                sim_time += sched_s + load_s + 1e-4;
                continue;
            }
            let batch = running.len();
            let a_b = distinct_adapters(&running, &requests);
            let exec_s = calib.lat_model(batch, calib.decode_bucket(batch), a_b);
            sim_time += sched_s + load_s + exec_s + calib.iter_overhead_s;
            let ids = running.clone();
            for &id in &ids {
                // detlint: allow(panic-path) — `requests` is the request arena; ids are indices it issued itself
                let r = &mut requests[id];
                r.generated += 1;
                r.context_len += 1;
                r.token_times.push(sim_time);
            }
            metrics.on_decode_tokens(ids.len(), sim_time);
            for id in ids {
                finish_if_done(
                    id,
                    sim_time,
                    &mut requests,
                    &mut running,
                    &mut ledger,
                    &mut cache,
                    &mut metrics,
                );
            }
        } else {
            match trace.get(next_arrival).map(|a| a.time_s) {
                Some(t) if t < spec.horizon_s => sim_time += (t - sim_time).max(0.0) + 1e-6,
                _ => break,
            }
        }
        metrics.sample_queues(sim_time, running.len() + prefill_queue.len(), waiting.len());
    }

    let report = metrics.report(spec.horizon_s, spec.incoming_token_rate());
    TwinResult {
        report: Some(report),
        memory_error: false,
        wall_s: wall0.elapsed().as_secs_f64(),
        iterations,
    }
}

fn distinct_adapters(running: &[usize], requests: &[Request]) -> usize {
    running
        .iter()
        // detlint: allow(panic-path) — `requests` is the request arena; ids are indices it issued itself
        .filter(|&&id| requests[id].rank > 0)
        .map(|&id| requests[id].adapter_id)
        .collect::<std::collections::BTreeSet<_>>()
        .len()
}

#[allow(clippy::too_many_arguments)]
fn finish_if_done(
    id: usize,
    t: f64,
    requests: &mut [Request],
    running: &mut Vec<usize>,
    ledger: &mut KvLedger,
    cache: &mut SimAdapterCache,
    metrics: &mut MetricsCollector,
) {
    // detlint: allow(panic-path) — `requests` is the request arena; ids are indices it issued itself
    if !requests[id].is_done() {
        return;
    }
    // detlint: allow(panic-path) — `requests` is the request arena; ids are indices it issued itself
    let r = &mut requests[id];
    r.state = ReqState::Finished;
    r.finish_s = Some(t);
    let (ttft, itl) = (r.ttft(), r.itl_mean());
    let (adapter, rank) = (r.adapter_id, r.rank);
    ledger.release(id);
    if rank > 0 {
        cache.release(adapter);
    }
    running.retain(|&x| x != id);
    metrics.on_finish(ttft, itl);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::WorkloadSpec;

    fn quick_spec(n: usize, rate: f64) -> WorkloadSpec {
        WorkloadSpec::fixed_len(WorkloadSpec::homogeneous(n, 8, rate), 64, 32, 20.0, 5)
    }

    #[test]
    fn twin_serves_light_workload_without_starvation() {
        let cfg = EngineConfig { a_max: 16, ..Default::default() };
        let calib = Calibration::default();
        let res = run(&cfg, &calib, &quick_spec(8, 0.2), LengthVariant::Original);
        let rep = res.report.unwrap();
        assert!(rep.completed > 0, "completed {}", rep.completed);
        assert!(!rep.starved, "{}", rep.summary());
        assert!(res.wall_s < 2.0);
    }

    #[test]
    fn twin_detects_starvation_under_overload() {
        let cfg = EngineConfig { a_max: 8, ..Default::default() };
        let calib = Calibration::default();
        // 256 adapters at 0.5 req/s ≈ 12k tok/s incoming — far beyond capacity.
        let res = run(&cfg, &calib, &quick_spec(256, 0.5), LengthVariant::Original);
        let rep = res.report.unwrap();
        assert!(rep.starved, "{}", rep.summary());
    }

    #[test]
    fn twin_reports_memory_error_for_over_reservation() {
        let mut cfg = EngineConfig::default();
        cfg.a_max = 384;
        cfg.s_max_rank = 32;
        let res = run(&cfg, &Calibration::default(), &quick_spec(8, 0.1), LengthVariant::Original);
        assert!(res.memory_error);
        assert!(res.report.is_none());
    }

    #[test]
    fn mean_variant_close_to_original_for_fixed_lengths() {
        // With Fixed length dists the two variants see identical traces.
        let cfg = EngineConfig { a_max: 16, ..Default::default() };
        let calib = Calibration::default();
        let spec = quick_spec(8, 0.2);
        let a = run(&cfg, &calib, &spec, LengthVariant::Original).report.unwrap();
        let b = run(&cfg, &calib, &spec, LengthVariant::Mean).report.unwrap();
        assert!((a.throughput_tok_s - b.throughput_tok_s).abs() < 1e-9);
    }

    #[test]
    fn throughput_increases_with_adapters_before_saturation() {
        // s_max_rank must match the workload's max rank (8): at rank 32 the
        // default pool cannot hold 64 reserved slots (a real memory error).
        let cfg = EngineConfig { a_max: 64, s_max_rank: 8, ..Default::default() };
        let calib = Calibration::default();
        let t8 = run(&cfg, &calib, &quick_spec(8, 0.2), LengthVariant::Original)
            .report
            .unwrap()
            .throughput_tok_s;
        let t32 = run(&cfg, &calib, &quick_spec(32, 0.2), LengthVariant::Original)
            .report
            .unwrap()
            .throughput_tok_s;
        assert!(t32 > t8 * 2.0, "t8={t8} t32={t32}");
    }
}
