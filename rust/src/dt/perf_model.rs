//! The Digital Twin's predictive performance models (paper Eq. 1):
//!
//! ```text
//! Mem_max(A_max, S_max)      → T_max            (shared KvLedger math)
//! Lat_sched(B, R_P, A_B, A)  = K1·B + K2·R_P + K3·R_P·A_B/A
//! Lat_load(S)                = L_S              (profiled per rank)
//! Lat_model(B, A_B)          = (K4·B + K5)·(K6·A_B + K7)
//! ```
//!
//! plus a prefill latency model (linear in the padded bucket length) that
//! the paper folds into its model component but we keep explicit because
//! our engine schedules prefills as separate iterations.
//!
//! All constants are parameterized from engine profiling data by
//! [`crate::dt::calibrate`].

use crate::util::json::Json;
use std::collections::BTreeMap;

/// Calibrated constants for one (backbone model, hardware) pair.
#[derive(Debug, Clone, PartialEq)]
pub struct Calibration {
    /// The backbone model these constants were fitted for.
    pub model: String,
    /// Scheduler: K1·B + K2·R_P + K3·R_P·(A_B/A) + bias (seconds).
    pub k_sched: [f64; 4],
    /// Backbone decode: K4a·B + K4b·bucket + K5 (seconds).  The paper's
    /// model is linear in B alone; our engine executes bucketed batches
    /// (CUDA-graph style), so per-request costs (window gather, readback)
    /// scale with B while padded compute scales with the bucket — a
    /// refinement of the same analytical form (§3.2 of the paper notes
    /// such refinements are expected per deployment).
    pub k_backbone: [f64; 3],
    /// Adapter overhead multiplier: K6·A_B + K7 (dimensionless).
    pub k_overhead: [f64; 2],
    /// Swap-in latency per rank (seconds), profiled.
    pub load_s_by_rank: BTreeMap<usize, f64>,
    /// Prefill: P0·bucket + P1 (seconds over padded length).
    pub k_prefill: [f64; 2],
    /// Fixed per-iteration engine overhead outside sched/exec (seconds).
    pub iter_overhead_s: f64,
    /// Compiled batch buckets of the engine (latency steps with the bucket,
    /// CUDA-graph style; the DT evaluates Lat_model at the bucketed batch).
    pub decode_buckets: Vec<usize>,
    /// Compiled prefill buckets (padded prompt lengths).
    pub prefill_buckets: Vec<usize>,
    /// Profiled decode latency points (batch → seconds), piecewise-linear
    /// interpolated.  Like the paper's `Mem_max`, a profiled table "proved
    /// more straightforward and equally accurate" than the analytical form
    /// on this testbed, whose bucketed executables have latency cliffs the
    /// K4·B+K5 line cannot express.  Empty → fall back to the linear fit.
    pub decode_pts: Vec<(f64, f64)>,
    /// Profiled prefill latency points (padded bucket → seconds).
    pub prefill_pts: Vec<(f64, f64)>,
}

fn pts_json(pts: &[(f64, f64)]) -> Json {
    Json::Arr(pts.iter().map(|&(x, y)| Json::arr_f64(&[x, y])).collect())
}

fn pts_from_json(j: Option<&Json>) -> Vec<(f64, f64)> {
    j.and_then(Json::as_arr)
        .map(|arr| {
            arr.iter()
                .filter_map(|p| {
                    let v = p.f64_vec()?;
                    (v.len() == 2).then(|| (v[0], v[1]))
                })
                .collect()
        })
        .unwrap_or_default()
}

/// Piecewise-linear interpolation over sorted (x, y) points; clamps to the
/// end slopes outside the profiled range.
fn interp(pts: &[(f64, f64)], x: f64) -> f64 {
    match pts.len() {
        0 => 0.0,
        1 => pts[0].1,
        _ => {
            if x <= pts[0].0 {
                return pts[0].1;
            }
            for w in pts.windows(2) {
                if x <= w[1].0 {
                    let t = (x - w[0].0) / (w[1].0 - w[0].0);
                    return w[0].1 + t * (w[1].1 - w[0].1);
                }
            }
            // Extrapolate with the final segment's slope.
            // detlint: allow(panic-path) — `pts` is indexed within its own recorded length
            let (a, b) = (pts[pts.len() - 2], pts[pts.len() - 1]);
            let slope = (b.1 - a.1) / (b.0 - a.0);
            (b.1 + slope * (x - b.0)).max(0.0)
        }
    }
}

impl Calibration {
    /// Smallest decode bucket that fits `batch` (engine-identical).
    pub fn decode_bucket(&self, batch: usize) -> usize {
        self.decode_buckets
            .iter()
            .copied()
            .find(|&b| b >= batch)
            .unwrap_or_else(|| self.decode_buckets.last().copied().unwrap_or(batch))
    }

    /// Smallest prefill bucket that fits `len` (engine-identical).
    pub fn prefill_bucket(&self, len: usize) -> usize {
        self.prefill_buckets
            .iter()
            .copied()
            .find(|&s| s >= len)
            .unwrap_or_else(|| self.prefill_buckets.last().copied().unwrap_or(len))
    }

    /// Largest compiled decode bucket (the engine's batch-size cap).
    pub fn max_decode_bucket(&self) -> usize {
        self.decode_buckets.last().copied().unwrap_or(64)
    }

    /// Largest compiled prefill bucket (prompt-length cap).
    pub fn max_prefill_bucket(&self) -> usize {
        self.prefill_buckets.last().copied().unwrap_or(256)
    }

    /// Derive the calibration for a GPU class that runs `perf_scale`×
    /// faster than the hardware this calibration was profiled on: every
    /// latency constant and profiled latency point is divided by the
    /// scale, while dimensionless terms (the adapter-overhead multiplier)
    /// and the engine's compiled bucket grid are unchanged.  This is the
    /// standard single-factor hardware model — good enough for a fleet
    /// whose classes differ mainly in raw step speed, and exactly what a
    /// per-class profiling run would replace (DESIGN.md §11).  A scale of
    /// 1.0 returns a bit-identical calibration (x/1.0 == x in IEEE-754),
    /// which the single-type fleet parity tests rely on.
    pub fn scaled(&self, perf_scale: f64) -> Calibration {
        assert!(perf_scale > 0.0, "perf_scale must be positive");
        let s = |x: f64| x / perf_scale;
        Calibration {
            model: self.model.clone(),
            k_sched: self.k_sched.map(s),
            k_backbone: self.k_backbone.map(s),
            k_overhead: self.k_overhead, // dimensionless multiplier
            load_s_by_rank: self.load_s_by_rank.iter().map(|(&r, &v)| (r, s(v))).collect(),
            k_prefill: self.k_prefill.map(s),
            iter_overhead_s: s(self.iter_overhead_s),
            decode_buckets: self.decode_buckets.clone(),
            prefill_buckets: self.prefill_buckets.clone(),
            decode_pts: self.decode_pts.iter().map(|&(x, y)| (x, s(y))).collect(),
            prefill_pts: self.prefill_pts.iter().map(|&(x, y)| (x, s(y))).collect(),
        }
    }
}

impl Calibration {
    /// Scheduler latency estimate (paper's Lat_sched).
    pub fn lat_sched(&self, batch: usize, pending: usize, a_b: usize, a: usize) -> f64 {
        let frac = if a == 0 { 0.0 } else { a_b as f64 / a as f64 };
        (self.k_sched[0] * batch as f64
            + self.k_sched[1] * pending as f64
            + self.k_sched[2] * pending as f64 * frac
            + self.k_sched[3])
            .max(0.0)
    }

    /// Decode-step latency estimate (paper's Lat_model): profiled backbone
    /// latency (table, falling back to the linear fit), multiplied by the
    /// adapter-count overhead.
    pub fn lat_model(&self, batch: usize, bucket: usize, a_b: usize) -> f64 {
        let backbone = if self.decode_pts.is_empty() {
            self.k_backbone[0] * batch as f64
                + self.k_backbone[1] * bucket as f64
                + self.k_backbone[2]
        } else {
            interp(&self.decode_pts, batch as f64)
        };
        let overhead = if a_b == 0 {
            1.0
        } else {
            (self.k_overhead[0] * a_b as f64 + self.k_overhead[1]).max(1.0)
        };
        (backbone * overhead).max(0.0)
    }

    /// Swap-in latency estimate (paper's Lat_load), interpolating between
    /// profiled ranks.
    pub fn lat_load(&self, rank: usize) -> f64 {
        if self.load_s_by_rank.is_empty() {
            return 0.0;
        }
        if let Some(&v) = self.load_s_by_rank.get(&rank) {
            return v;
        }
        // Linear interpolation / extrapolation on the profiled points.
        let pts: Vec<(f64, f64)> =
            self.load_s_by_rank.iter().map(|(&r, &s)| (r as f64, s)).collect();
        if pts.len() == 1 {
            return pts[0].1 * rank as f64 / pts[0].0.max(1.0);
        }
        let (lo, hi) = pts
            .windows(2)
            .find(|w| rank as f64 <= w[1].0)
            .map(|w| (w[0], w[1]))
            // detlint: allow(panic-path) — `pts` is indexed within its own recorded length
            .unwrap_or((pts[pts.len() - 2], pts[pts.len() - 1]));
        let t = (rank as f64 - lo.0) / (hi.0 - lo.0);
        (lo.1 + t * (hi.1 - lo.1)).max(0.0)
    }

    /// Prefill latency estimate for a padded bucket length.
    pub fn lat_prefill(&self, bucket: usize) -> f64 {
        if self.prefill_pts.is_empty() {
            (self.k_prefill[0] * bucket as f64 + self.k_prefill[1]).max(0.0)
        } else {
            interp(&self.prefill_pts, bucket as f64)
        }
    }

    // ------------------------------------------------------------------

    /// Serialize to the calibration JSON format.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("k_sched", Json::arr_f64(&self.k_sched)),
            ("k_backbone", Json::arr_f64(&self.k_backbone)),
            ("k_overhead", Json::arr_f64(&self.k_overhead)),
            (
                "load_s_by_rank",
                Json::Obj(
                    self.load_s_by_rank
                        .iter()
                        .map(|(k, v)| (k.to_string(), Json::Num(*v)))
                        .collect(),
                ),
            ),
            ("k_prefill", Json::arr_f64(&self.k_prefill)),
            ("iter_overhead_s", Json::Num(self.iter_overhead_s)),
            (
                "decode_buckets",
                Json::arr_f64(&self.decode_buckets.iter().map(|&b| b as f64).collect::<Vec<_>>()),
            ),
            (
                "prefill_buckets",
                Json::arr_f64(&self.prefill_buckets.iter().map(|&b| b as f64).collect::<Vec<_>>()),
            ),
            ("decode_pts", pts_json(&self.decode_pts)),
            ("prefill_pts", pts_json(&self.prefill_pts)),
        ])
    }

    /// Parse a calibration written by [`Calibration::to_json`].
    pub fn from_json(j: &Json) -> anyhow::Result<Calibration> {
        let arr = |k: &str, n: usize| -> anyhow::Result<Vec<f64>> {
            let v = j.req(k)?.f64_vec().ok_or_else(|| anyhow::anyhow!("{k} not array"))?;
            anyhow::ensure!(v.len() == n, "{k} wrong arity");
            Ok(v)
        };
        let mut load = BTreeMap::new();
        if let Some(obj) = j.req("load_s_by_rank")?.as_obj() {
            for (k, v) in obj {
                load.insert(k.parse::<usize>()?, v.as_f64().unwrap_or(0.0));
            }
        }
        let ks = arr("k_sched", 4)?;
        let kb = arr("k_backbone", 3)?;
        let ko = arr("k_overhead", 2)?;
        let kp = arr("k_prefill", 2)?;
        Ok(Calibration {
            model: j.req("model")?.as_str().unwrap_or_default().to_string(),
            k_sched: [ks[0], ks[1], ks[2], ks[3]],
            k_backbone: [kb[0], kb[1], kb[2]],
            k_overhead: [ko[0], ko[1]],
            load_s_by_rank: load,
            k_prefill: [kp[0], kp[1]],
            iter_overhead_s: j.get("iter_overhead_s").and_then(Json::as_f64).unwrap_or(0.0),
            decode_buckets: j
                .get("decode_buckets")
                .and_then(Json::usize_vec)
                .unwrap_or_else(|| vec![1, 2, 4, 8, 16, 32, 64]),
            prefill_buckets: j
                .get("prefill_buckets")
                .and_then(Json::usize_vec)
                .unwrap_or_else(|| vec![32, 64, 128, 256]),
            decode_pts: pts_from_json(j.get("decode_pts")),
            prefill_pts: pts_from_json(j.get("prefill_pts")),
        })
    }

    /// Load a calibration file (either a single calibration or a map keyed
    /// by model name).
    pub fn load_file(path: &std::path::Path, model: &str) -> anyhow::Result<Calibration> {
        let j = Json::read_file(path)?;
        // File may hold one calibration or a map keyed by model.
        if j.get("model").is_some() {
            Calibration::from_json(&j)
        } else {
            Calibration::from_json(j.req(model)?)
        }
    }
}

/// A reasonable default (used by unit tests and as a fallback): values in
/// the ballpark of the measured engine on this container.
impl Default for Calibration {
    fn default() -> Self {
        Calibration {
            model: "pico-llama".into(),
            k_sched: [2e-8, 3e-8, 5e-8, 2e-6],
            k_backbone: [6e-5, 1.0e-3, 1.2e-3],
            k_overhead: [1e-3, 1.05],
            load_s_by_rank: [(8, 0.006), (16, 0.009), (32, 0.015)].into_iter().collect(),
            k_prefill: [3.5e-5, 2e-3],
            iter_overhead_s: 2e-6,
            decode_buckets: vec![1, 2, 4, 8, 16, 32, 64],
            prefill_buckets: vec![32, 64, 128, 256],
            decode_pts: vec![],
            prefill_pts: vec![],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lat_model_monotone_in_batch_and_adapters() {
        let c = Calibration::default();
        assert!(c.lat_model(8, 8, 1) < c.lat_model(32, 32, 1));
        assert!(c.lat_model(32, 32, 1) <= c.lat_model(32, 32, 16));
        assert!(c.lat_model(4, 4, 0) > 0.0);
        // Padding costs: same batch, larger bucket → slower.
        assert!(c.lat_model(8, 8, 1) < c.lat_model(8, 16, 1));
    }

    #[test]
    fn lat_load_interpolates() {
        let c = Calibration::default();
        let l8 = c.lat_load(8);
        let l16 = c.lat_load(16);
        let l12 = c.lat_load(12);
        assert!(l8 < l12 && l12 < l16);
        // Exact table hits.
        assert_eq!(c.lat_load(32), c.load_s_by_rank[&32]);
    }

    #[test]
    fn sched_term_scales_with_pending_fraction() {
        let c = Calibration::default();
        let cheap = c.lat_sched(8, 100, 1, 100);
        let costly = c.lat_sched(8, 100, 100, 100);
        assert!(costly > cheap);
    }

    #[test]
    fn json_roundtrip() {
        let c = Calibration::default();
        let c2 = Calibration::from_json(&c.to_json()).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn scaled_divides_latencies_and_keeps_structure() {
        let c = Calibration::default();
        let fast = c.scaled(2.0);
        assert_eq!(fast.lat_model(8, 8, 0), c.lat_model(8, 8, 0) / 2.0);
        assert_eq!(fast.lat_load(16), c.lat_load(16) / 2.0);
        assert_eq!(fast.lat_prefill(64), c.lat_prefill(64) / 2.0);
        // The adapter-overhead multiplier is dimensionless: unchanged.
        assert_eq!(fast.k_overhead, c.k_overhead);
        // Bucket grids are compile-time properties of the engine, not
        // hardware speed: unchanged.
        assert_eq!(fast.decode_buckets, c.decode_buckets);
        assert_eq!(fast.prefill_buckets, c.prefill_buckets);
        // Unit scale is bit-identical (single-type fleet parity).
        assert_eq!(c.scaled(1.0), c);
    }
}
