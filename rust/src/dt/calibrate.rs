//! Digital-Twin parameterization: the "lightweight parameterization phase
//! based on a small set of benchmarking experiments executed on the target
//! hardware and model configuration" (paper §4).
//!
//! Fits the Eq. 1 constants from engine profiling micro-benchmarks:
//! 1. backbone decode latency vs batch bucket          → K4, K5
//! 2. decode latency vs distinct adapters in the batch → K6, K7
//! 3. scheduler wall time vs (B, R_P, R_P·A_B/A)       → K1..K3 + bias
//! 4. swap-in latency per rank                         → L_S table
//! 5. prefill latency vs padded bucket                 → P0, P1

use super::perf_model::Calibration;
use crate::config::EngineConfig;
use crate::engine::Engine;
use crate::runtime::Backend;
use crate::util::stats;
use crate::workload::{AdapterSpec, WorkloadSpec};
use anyhow::Result;
use std::collections::BTreeMap;

/// Run the calibration suite against the engine.  `fast` trims repetitions
/// (used by tests and the quick experiment scale).
pub fn calibrate(
    rt: &mut dyn Backend,
    base_cfg: &EngineConfig,
    fast: bool,
) -> Result<Calibration> {
    let meta = rt.meta().clone();
    let decode_buckets = meta.decode_buckets.clone();
    let prefill_buckets = meta.prefill_buckets.clone();
    let out_tokens = if fast { 24 } else { 80 };

    // ---- 1. Backbone latency vs batch --------------------------------
    // Saturate the engine with backbone-only (rank 0) requests pinned to
    // each bucket size and average the decode-step wall time.
    // (batch, bucket, latency) points: full-bucket batches plus off-bucket
    // batches so the per-request and per-bucket-slot terms are separable.
    let mut pts_b: Vec<(f64, f64, f64)> = Vec::new();
    let mut prefill_pts: Vec<(f64, f64)> = Vec::new();
    // Input lengths cycle across the prefill buckets so the prefill model
    // gets coverage from the same runs.
    let input_cycle: Vec<usize> =
        prefill_buckets.iter().map(|&s| (s * 7 / 8).max(1)).collect();
    let mut batch_sizes: Vec<usize> = decode_buckets.clone();
    // Off-bucket points (3/4 of each bucket where distinct).
    for &b in &decode_buckets {
        let off = (b * 3 / 4).max(1);
        if !batch_sizes.contains(&off) {
            batch_sizes.push(off);
        }
    }
    // Dense small-batch coverage: real workloads spend most iterations at
    // small batches, where the bucket-1→2 latency cliff dominates.
    for extra in [2usize, 6, 10, 24] {
        if !batch_sizes.contains(&extra) {
            batch_sizes.push(extra);
        }
    }
    batch_sizes.sort();
    batch_sizes.dedup();
    for &b in &batch_sizes {
        if fast && ![1usize, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64].contains(&b) {
            continue;
        }
        let adapters: Vec<AdapterSpec> =
            (0..b).map(|id| AdapterSpec { id, rank: 0, rate: 0.0 }).collect();
        let spec = WorkloadSpec::fixed_len(adapters, 64, out_tokens, 1e9, 11);
        // One request per adapter, all arriving at t=0.
        let trace: Vec<_> = (0..b)
            .map(|i| crate::workload::Arrival {
                request_id: i,
                time_s: 0.0,
                adapter_id: i,
                // detlint: allow(panic-path) — `input_cycle` is indexed within its own recorded length
                input_len: input_cycle[i % input_cycle.len()],
                output_len: out_tokens,
            })
            .collect();
        let mut cfg = base_cfg.clone();
        cfg.a_max = b.max(1);
        cfg.max_num_seqs = b;
        let bucket = decode_buckets.iter().copied().find(|&x| x >= b).unwrap_or(b);
        let profile = run_trace_collect(&mut *rt, &cfg, &spec, &trace)?;
        let decode_ts: Vec<f64> = profile
            .iter()
            .filter(|r| !r.prefill && r.batch == b)
            .map(|r| r.exec_s)
            .collect();
        if !decode_ts.is_empty() {
            pts_b.push((b as f64, bucket as f64, stats::mean(&decode_ts)));
        }
        for r in profile.iter().filter(|r| r.prefill && r.prefill_bucket > 0) {
            prefill_pts.push((r.prefill_bucket as f64, r.exec_s));
        }
    }
    anyhow::ensure!(pts_b.len() >= 3, "backbone calibration needs >=3 points");
    let rows_b: Vec<Vec<f64>> = pts_b.iter().map(|p| vec![p.0, p.1, 1.0]).collect();
    let ys_b: Vec<f64> = pts_b.iter().map(|p| p.2).collect();
    let beta_b = stats::least_squares(&rows_b, &ys_b);
    let (k4a, k4b, k5) = (beta_b[0], beta_b[1], beta_b[2]);

    // ---- 2. Adapter-count overhead at fixed batch ---------------------
    let fixed_b = *decode_buckets
        .iter()
        .find(|&&b| b >= 32)
        // detlint: allow(panic-path) — `decode_buckets` is indexed within its own recorded length
        .unwrap_or(&decode_buckets[decode_buckets.len() - 1]);
    // Denominator must be the backbone latency at exactly the same batch.
    let backbone_at_b = pts_b
        .iter()
        .find(|p| p.0 == fixed_b as f64)
        .map(|p| p.2)
        .unwrap_or(k4a * fixed_b as f64 + k4b * fixed_b as f64 + k5);
    let mut pts_a: Vec<(f64, f64)> = Vec::new();
    for a_b in [1usize, 2, 4, 8, 16, 32] {
        if a_b > fixed_b {
            break;
        }
        if fast && ![1usize, 4, 16, 32].contains(&a_b) {
            continue;
        }
        let adapters: Vec<AdapterSpec> =
            (0..a_b).map(|id| AdapterSpec { id, rank: 8, rate: 0.0 }).collect();
        let spec = WorkloadSpec::fixed_len(adapters, 64, out_tokens, 1e9, 13);
        // fixed_b requests spread round-robin across the adapters, with the
        // same input-length mix as the backbone runs (apples to apples).
        let trace: Vec<_> = (0..fixed_b)
            .map(|i| crate::workload::Arrival {
                request_id: i,
                time_s: 0.0,
                adapter_id: i % a_b,
                // detlint: allow(panic-path) — `input_cycle` is indexed within its own recorded length
                input_len: input_cycle[i % input_cycle.len()],
                output_len: out_tokens,
            })
            .collect();
        let mut cfg = base_cfg.clone();
        cfg.a_max = a_b.max(1);
        cfg.max_num_seqs = fixed_b;
        let profile = run_trace_collect(&mut *rt, &cfg, &spec, &trace)?;
        let ts: Vec<f64> = profile
            .iter()
            .filter(|r| !r.prefill && r.batch == fixed_b && r.adapters_in_batch == a_b)
            .map(|r| r.exec_s)
            .collect();
        if !ts.is_empty() {
            pts_a.push((a_b as f64, stats::mean(&ts) / backbone_at_b));
        }
    }
    let (k7, k6) = if pts_a.len() >= 2 {
        stats::linreg(
            &pts_a.iter().map(|p| p.0).collect::<Vec<_>>(),
            &pts_a.iter().map(|p| p.1).collect::<Vec<_>>(),
        )
    } else {
        (1.0, 0.0)
    };

    // ---- 3. Scheduler constants ---------------------------------------
    // A busy heterogeneous run with a large pending queue and a small
    // A_max maximizes the Fig.-7 scan term.
    let n_adapters = if fast { 48 } else { 128 };
    let adapters = WorkloadSpec::heterogeneous(n_adapters, &[8, 16], &[0.4, 0.2], 17);
    let spec = WorkloadSpec::sharegpt_like(adapters, if fast { 4.0 } else { 12.0 }, 17);
    let mut cfg = base_cfg.clone();
    cfg.a_max = 16;
    let mut engine = Engine::new(cfg, &mut *rt);
    let res = engine.run(&spec)?;
    let mut rows: Vec<Vec<f64>> = Vec::new();
    let mut ys: Vec<f64> = Vec::new();
    for r in res.profiler.iters.iter() {
        let frac = if r.adapters_total == 0 {
            0.0
        } else {
            r.adapters_in_batch as f64 / r.adapters_total as f64
        };
        rows.push(vec![r.batch as f64, r.pending as f64, r.pending as f64 * frac, 1.0]);
        ys.push(r.sched_s);
    }
    let k_sched = if rows.len() >= 8 {
        let beta = stats::least_squares(&rows, &ys);
        [beta[0].max(0.0), beta[1].max(0.0), beta[2].max(0.0), beta[3].max(0.0)]
    } else {
        [0.0, 0.0, 0.0, 1e-6]
    };

    // ---- 4. Swap-in latency per rank -----------------------------------
    let mut load_s_by_rank: BTreeMap<usize, f64> = BTreeMap::new();
    for rank in [8usize, 16, 32] {
        let n = if fast { 12 } else { 24 };
        let adapters: Vec<AdapterSpec> =
            (0..n).map(|id| AdapterSpec { id, rank, rate: 0.0 }).collect();
        let spec = WorkloadSpec::fixed_len(adapters, 32, 4, 1e9, 19);
        // Sequential requests over distinct adapters with A_max=2 force a
        // swap for nearly every request.
        let trace: Vec<_> = (0..n)
            .map(|i| crate::workload::Arrival {
                request_id: i,
                time_s: i as f64 * 1e-3,
                adapter_id: i,
                input_len: 32,
                output_len: 4,
            })
            .collect();
        let mut cfg = base_cfg.clone();
        cfg.a_max = 2;
        let profile_events = {
            let mut engine = Engine::new(cfg, &mut *rt);
            let res = engine.run_trace(&spec, &trace)?;
            res.profiler.load_events
        };
        let totals: Vec<f64> = profile_events
            .iter()
            .filter(|(r, _, _)| *r == rank)
            .map(|(_, m, u)| m + u)
            .collect();
        if !totals.is_empty() {
            load_s_by_rank.insert(rank, stats::mean(&totals));
        }
    }

    // ---- 5. Prefill model ----------------------------------------------
    let (p1, p0) = if prefill_pts.len() >= 2 {
        stats::linreg(
            &prefill_pts.iter().map(|p| p.0).collect::<Vec<_>>(),
            &prefill_pts.iter().map(|p| p.1).collect::<Vec<_>>(),
        )
    } else {
        (2e-3, 3e-5)
    };

    // Profiled tables (preferred over the analytical fits at runtime).
    let decode_table: Vec<(f64, f64)> = pts_b.iter().map(|p| (p.0, p.2)).collect();
    let mut prefill_by_bucket: BTreeMap<u64, Vec<f64>> = BTreeMap::new();
    for &(bkt, t) in &prefill_pts {
        prefill_by_bucket.entry(bkt as u64).or_default().push(t);
    }
    let prefill_table: Vec<(f64, f64)> = prefill_by_bucket
        .iter()
        .map(|(&bkt, ts)| (bkt as f64, stats::mean(ts)))
        .collect();

    Ok(Calibration {
        model: meta.name.clone(),
        k_sched,
        k_backbone: [k4a.max(0.0), k4b.max(0.0), k5.max(0.0)],
        k_overhead: [k6.max(0.0), k7.max(0.5)],
        load_s_by_rank,
        k_prefill: [p0.max(0.0), p1.max(0.0)],
        iter_overhead_s: 0.0,
        decode_buckets,
        prefill_buckets,
        decode_pts: decode_table,
        prefill_pts: prefill_table,
    })
}

/// Run the engine over an explicit trace and return the iteration records.
fn run_trace_collect(
    rt: &mut dyn Backend,
    cfg: &EngineConfig,
    spec: &WorkloadSpec,
    trace: &[crate::workload::Arrival],
) -> Result<Vec<crate::engine::profiler::IterRecord>> {
    let mut engine = Engine::new(cfg.clone(), rt);
    let res = engine.run_trace(spec, trace)?;
    Ok(res.profiler.iters)
}
