//! The Digital Twin of the LLM-adapter serving engine (paper §5): the same
//! continuous-batching, KV-allocation and adapter-swap state machine, with
//! measured latencies replaced by the four calibrated predictive models.

pub mod calibrate;
pub mod perf_model;
pub mod twin;

pub use calibrate::calibrate;
pub use perf_model::Calibration;
pub use twin::{run as run_twin, run_trace as run_twin_trace, LengthVariant, TwinResult};
