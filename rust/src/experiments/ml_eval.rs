//! ML-phase evaluation (paper §8.3): Table 3 (KNN/RF/SVM accuracy and
//! inference latency against real-system executions) and Table 4 (the
//! refinement phase: RF → Small Tree → Small Tree**).

use super::common::{print_table, validation_runs, write_csv, ExpContext};
use crate::engine::metrics::ReportSchema;
use crate::ml::{
    self, features,
    metrics::macro_f1,
    refine::{distill, FlatTree},
    train::{fitted_scaler, train, xs as xs_of, ModelType, Task},
    Predictor,
};
use crate::util::stats;
use anyhow::Result;
use std::time::Instant;

/// Mean per-prediction latency in milliseconds.
fn bench_predict(p: &Predictor, xs: &[Vec<f64>], reps: usize) -> f64 {
    // Table 3 reports measured inference latency; experiments::* is on
    // detlint's wall-clock allowlist.
    #[allow(clippy::disallowed_methods)]
    let t0 = Instant::now();
    let mut sink = 0.0;
    for _ in 0..reps {
        for x in xs {
            sink += p.predict_one(x);
        }
    }
    std::hint::black_box(sink);
    ReportSchema::ms_from_s(t0.elapsed().as_secs_f64()) / (reps * xs.len()) as f64
}

/// Table 3: accuracy and inference time of KNN / RF / SVM on both tasks.
pub fn table3(ctx: &ExpContext) -> Result<()> {
    let dir = ctx.exp_dir("table3");
    let mut rows = vec![];
    for model in &ctx.models {
        let mut rt = ctx.load_runtime(model)?;
        let calib = ctx.calibration(&mut rt)?;
        let samples = ctx.dataset(&calib)?;
        let scenarios = validation_runs(ctx, &mut rt)?;
        let scaler = fitted_scaler(&samples);

        // Ground truth from the engine runs.
        let eval_x: Vec<Vec<f64>> = scenarios
            .iter()
            .map(|sc| features(&sc.adapters(), sc.a_max))
            .collect();
        let eval_x_std = scaler.transform(&eval_x);
        let thr_actual: Vec<f64> = scenarios.iter().map(|s| s.throughput).collect();
        let st_actual: Vec<f64> = scenarios.iter().map(|s| s.starved as i32 as f64).collect();

        for mt in [ModelType::Knn, ModelType::RandomForest, ModelType::Svm] {
            eprintln!("[table3] training {} {} ...", model, mt.name());
            let (thr_m, _) = train(&samples, Task::Throughput, mt, ctx.scale.is_quick(), 7);
            let (st_m, _) = train(&samples, Task::Starvation, mt, ctx.scale.is_quick(), 7);
            // KNN/SVM consume standardized features.
            let (xt, xs_used): (&[Vec<f64>], &[Vec<f64>]) = match mt {
                ModelType::RandomForest => (&eval_x, &eval_x),
                _ => (&eval_x_std, &eval_x_std),
            };
            let thr_pred: Vec<f64> = xt.iter().map(|x| thr_m.predict_one(x)).collect();
            let st_pred: Vec<f64> = xs_used.iter().map(|x| st_m.predict_one(x)).collect();
            let smape = stats::smape(&thr_actual, &thr_pred);
            let f1 = macro_f1(&st_actual, &st_pred);
            let t_thr = bench_predict(&thr_m, xt, 20);
            let t_st = bench_predict(&st_m, xs_used, 20);
            println!(
                "  table3 {model} {}: thr SMAPE={smape:.2}% ({t_thr:.3}ms)  starvation F1={f1:.2} ({t_st:.3}ms)",
                mt.name()
            );
            rows.push(vec![
                model.clone(),
                mt.name().to_string(),
                format!("{smape:.2}"),
                format!("{t_thr:.4}"),
                format!("{f1:.3}"),
                format!("{t_st:.4}"),
            ]);
        }
    }
    print_table(
        "Table 3 — ML models vs real-system executions (paper: SMAPE 4.39-7.46%, F1 0.93-0.99, <0.3ms except SVM)",
        &["model", "estimator", "thr SMAPE %", "thr time ms", "starv F1", "starv time ms"],
        &rows,
    );
    write_csv(
        &dir,
        "table3.csv",
        &["model", "estimator", "smape", "thr_time_ms", "f1", "st_time_ms"],
        &rows,
    )?;
    Ok(())
}

/// Table 4: the refinement phase (Small Tree / Small Tree**).
pub fn table4(ctx: &ExpContext) -> Result<()> {
    let dir = ctx.exp_dir("table4");
    let mut rows = vec![];
    for model in &ctx.models {
        let mut rt = ctx.load_runtime(model)?;
        let calib = ctx.calibration(&mut rt)?;
        let samples = ctx.dataset(&calib)?;
        let scenarios = validation_runs(ctx, &mut rt)?;
        let models = ctx.trained_models(&calib)?;

        let eval_x: Vec<Vec<f64>> =
            scenarios.iter().map(|sc| features(&sc.adapters(), sc.a_max)).collect();
        let thr_actual: Vec<f64> = scenarios.iter().map(|s| s.throughput).collect();
        let st_actual: Vec<f64> = scenarios.iter().map(|s| s.starved as i32 as f64).collect();

        // Teacher predictions over the training inputs for distillation.
        let train_x = xs_of(&samples);
        let teach_thr: Vec<f64> = train_x.iter().map(|x| models.predict_throughput(x)).collect();
        let teach_st: Vec<f64> =
            train_x.iter().map(|x| models.predict_starvation(x) as i32 as f64).collect();
        let small_thr = distill(&train_x, &teach_thr, ml::tree::Criterion::Mse, 32);
        let small_st = distill(&train_x, &teach_st, ml::tree::Criterion::Gini, 16);
        let flat_thr = FlatTree::compile(&small_thr);
        let flat_st = FlatTree::compile(&small_st);

        // Interpretable rules (Appendix C analog).
        let rules = small_st.rules(&ml::FEATURE_NAMES);
        std::fs::write(dir.join(format!("rules_starvation_{model}.txt")), rules.join("\n"))?;
        let rules_t = small_thr.rules(&ml::FEATURE_NAMES);
        std::fs::write(dir.join(format!("rules_throughput_{model}.txt")), rules_t.join("\n"))?;

        let variants: Vec<(&str, Predictor, Predictor, usize, usize)> = vec![
            (
                "RF",
                // Reload to own a second copy for benching.
                Predictor::Forest(match &models.throughput {
                    Predictor::Forest(f) => f.clone(),
                    _ => unreachable!(),
                }),
                Predictor::Forest(match &models.starvation {
                    Predictor::Forest(f) => f.clone(),
                    _ => unreachable!(),
                }),
                match &models.throughput {
                    Predictor::Forest(f) => f.n_rules(),
                    _ => 0,
                },
                match &models.starvation {
                    Predictor::Forest(f) => f.n_rules(),
                    _ => 0,
                },
            ),
            (
                "Small Tree",
                Predictor::Tree(small_thr.clone()),
                Predictor::Tree(small_st.clone()),
                small_thr.n_leaves(),
                small_st.n_leaves(),
            ),
            (
                "Small Tree**",
                Predictor::Flat(flat_thr),
                Predictor::Flat(flat_st),
                small_thr.n_leaves(),
                small_st.n_leaves(),
            ),
        ];
        for (name, thr_p, st_p, rules_thr, rules_st) in variants {
            let thr_pred: Vec<f64> = eval_x.iter().map(|x| thr_p.predict_one(x)).collect();
            let st_pred: Vec<f64> = eval_x.iter().map(|x| st_p.predict_one(x)).collect();
            let smape = stats::smape(&thr_actual, &thr_pred);
            let f1 = macro_f1(&st_actual, &st_pred);
            let reps = if name == "RF" { 20 } else { 2000 };
            let t_thr = bench_predict(&thr_p, &eval_x, reps);
            let t_st = bench_predict(&st_p, &eval_x, reps);
            println!(
                "  table4 {model} {name}: rules={rules_thr} SMAPE={smape:.2}% ({:.6}ms)  F1={f1:.2} ({:.6}ms)",
                t_thr, t_st
            );
            rows.push(vec![
                model.clone(),
                name.to_string(),
                rules_thr.to_string(),
                format!("{smape:.2}"),
                format!("{t_thr:.6}"),
                rules_st.to_string(),
                format!("{f1:.3}"),
                format!("{t_st:.6}"),
            ]);
        }
    }
    print_table(
        "Table 4 — refinement phase (paper: 32/16 rules, ~+6.7% SMAPE, -0.025 F1, up to 2120x faster inference)",
        &[
            "model",
            "variant",
            "thr rules",
            "thr SMAPE %",
            "thr time ms",
            "st rules",
            "st F1",
            "st time ms",
        ],
        &rows,
    );
    write_csv(
        &dir,
        "table4.csv",
        &["model", "variant", "thr_rules", "smape", "thr_time_ms", "st_rules", "f1", "st_time_ms"],
        &rows,
    )?;
    Ok(())
}
