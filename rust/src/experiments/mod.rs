//! Experiment harness: one entry per table/figure of the paper's
//! evaluation (see DESIGN.md §5 for the index).  Each experiment prints
//! its rows and writes CSVs under `results/<id>/`.

pub mod caching;
pub mod common;
pub mod drift;
pub mod dt_eval;
pub mod fleet;
pub mod ml_eval;
pub mod profiling;

pub use common::{EstimatorChoice, ExpContext, Scale};

use anyhow::Result;

/// An experiment entry point: renders one paper artifact into
/// `results/<id>/`.
type ExpFn = fn(&ExpContext) -> Result<()>;

/// (id, paper artifact, runner)
pub const REGISTRY: &[(&str, &str, ExpFn)] = &[
    ("fig1", "Fig. 1 — the adapter caching problem (throughput vs adapters)", profiling::fig1),
    (
        "fig4",
        "Fig. 4 — memory overhead: batch/throughput vs loaded adapters; ITL vs batch",
        profiling::fig4,
    ),
    ("fig5", "Fig. 5 — compute overhead vs adapters in batch", profiling::fig5),
    ("fig6", "Fig. 6 — adapter load time relative to request latency", profiling::fig6),
    ("fig7", "Fig. 7 — scheduler time share vs (adapters, A_max)", profiling::fig7),
    ("table1", "Tables 1+2 — Digital Twin fidelity and cost", dt_eval::table1),
    ("fig8", "Fig. 8 — DT & ML vs engine across adapter counts", dt_eval::fig8),
    ("fig9", "Fig. 9 — unpredictable arrivals; queue dynamics", dt_eval::fig9),
    ("table3", "Table 3 — ML model accuracy and inference time", ml_eval::table3),
    ("table4", "Table 4 — refinement phase (Small Tree / Small Tree**)", ml_eval::table4),
    ("fig10", "Fig. 10 — single-GPU placement vs baselines", caching::fig10),
    ("fig11", "Fig. 11 — GPUs required on a 4-GPU system", caching::fig11),
    ("table5", "Table 5 — placement algorithm runtimes", caching::table5),
    ("fig12", "Fig. 12 — Proposed vs dLoRA vs ProposedLat", caching::fig12),
    ("figa13", "Fig. A.13 — S-LoRA unified-memory mode", caching::figa13),
    (
        "drift",
        "GPUs & ITL over time under churn: {static,replan,oracle} x {min-gpus,min-latency}",
        drift::drift,
    ),
    (
        "fleet",
        "$/hr, GPUs & ITL over time on a heterogeneous fleet: min-gpus vs min-cost",
        fleet::fleet,
    ),
];

/// Run experiment `id` (or every experiment with `"all"`).
pub fn run(id: &str, ctx: &ExpContext) -> Result<()> {
    if id == "all" {
        for (name, desc, f) in REGISTRY {
            println!("\n########## {name}: {desc}");
            f(ctx)?;
        }
        return Ok(());
    }
    let (_, desc, f) = REGISTRY
        .iter()
        .find(|(name, _, _)| *name == id)
        .ok_or_else(|| anyhow::anyhow!("unknown experiment '{id}' (see list-experiments)"))?;
    println!("########## {id}: {desc}");
    f(ctx)
}

#[cfg(test)]
mod tests {
    use super::REGISTRY;

    /// Doc-drift guard: the `list-experiments` registry and the DESIGN.md
    /// §5 experiment index must stay in sync, id for id, in order.
    #[test]
    fn design_md_experiment_index_matches_registry() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../DESIGN.md");
        let md = std::fs::read_to_string(path).expect("DESIGN.md readable");
        let section = md
            .split("## §5")
            .nth(1)
            .expect("DESIGN.md has a §5 section")
            .split("\n## §")
            .next()
            .unwrap();
        let doc_ids: Vec<&str> = section
            .lines()
            .filter_map(|l| {
                let l = l.trim();
                let cell = l.strip_prefix('|')?.split('|').next()?.trim();
                if cell.is_empty() || cell == "id" || cell.starts_with('-') {
                    return None;
                }
                Some(cell)
            })
            .collect();
        let registry_ids: Vec<&str> = REGISTRY.iter().map(|(id, _, _)| *id).collect();
        assert_eq!(
            doc_ids, registry_ids,
            "DESIGN.md §5 experiment table is out of sync with experiments::REGISTRY — \
             update the table (and §7 if the experiment is drift-related) when adding \
             or removing experiments"
        );
    }
}
