//! The drift experiment (DESIGN.md §5/§7/§8): GPUs *and* ITL over time
//! under workload drift — static provisioning vs migration-aware
//! replanning vs an oracle that replans from scratch every epoch, each
//! control loop run under both placement objectives (`MinGpus` vs
//! `MinLatency`, the paper's §8.4.4 comparison extended over time).
//!
//! Scenario: a burst-churn workload.  A light base adapter population
//! lives for the whole horizon; a heavy burst population retires a third
//! of the way in, and a second, lighter wave arrives mid-horizon.  A
//! static deployment must provision the union peak for every epoch; the
//! incremental replanner sheds (and re-adds) GPUs as demand drifts; the
//! latency objective holds the cluster spread and buys lower ITL for more
//! GPU-epochs.  Regenerates `results/drift/drift.csv` + `summary.json`.

use super::common::{
    backbone_max_tok_s, print_table, tokens_per_request, write_csv, write_summary,
    EstimatorChoice, ExpContext,
};
use crate::cluster::epochs::{serve_horizon, DriftReport, HorizonBackend, ReplanPolicy};
use crate::cluster::{Core, RunOptions};
use crate::config::EngineConfig;
use crate::dt::{Calibration, LengthVariant};
use crate::engine::metrics::ReportSchema;
use crate::placement::replan::ReplanParams;
use crate::placement::{MinGpus, MinLatency, Objective, PerfEstimator};
use crate::util::json::Json;
use crate::workload::drift::{AdapterPhase, DriftSpec, RateDrift};
use crate::workload::{AdapterSpec, WorkloadSpec};
use anyhow::Result;

/// Deterministic burst-churn scenario, scaled to the calibrated backbone
/// ([`backbone_max_tok_s`] — used so the burst needs >1 GPU everywhere
/// without exceeding the 4-GPU cluster):
/// 16 base adapters for the whole horizon (~8% of one GPU's decode
/// ceiling), 96 heavy burst adapters (~100% of one ceiling in aggregate —
/// more than one GPU can actually serve, well under four) retiring at
/// `epochs/3 + 1`, and 24 light adapters (~6%) arriving after the burst
/// clears.  Public so `examples/drift_replan.rs` drives the same scenario.
pub fn burst_churn(epochs: usize, epoch_s: f64, calib: &Calibration) -> DriftSpec {
    let bb = backbone_max_tok_s(calib);
    let tpr = tokens_per_request(&WorkloadSpec::sharegpt_like(vec![], 1.0, 0));
    let base_rate = 0.08 * bb / (16.0 * tpr);
    let burst_rate = 1.0 * bb / (96.0 * tpr);
    let wave_rate = 0.06 * bb / (24.0 * tpr);
    let mut phases: Vec<AdapterPhase> = (0..16)
        .map(|id| AdapterPhase {
            adapter: AdapterSpec { id, rank: 8, rate: base_rate },
            arrive_epoch: 0,
            retire_epoch: usize::MAX,
        })
        .collect();
    let burst_until = epochs / 3 + 1;
    for i in 0..96 {
        phases.push(AdapterPhase {
            adapter: AdapterSpec { id: 16 + i, rank: 8, rate: burst_rate },
            arrive_epoch: 0,
            retire_epoch: burst_until,
        });
    }
    for i in 0..24 {
        phases.push(AdapterPhase {
            adapter: AdapterSpec { id: 112 + i, rank: 8, rate: wave_rate },
            arrive_epoch: (burst_until + 1).min(epochs),
            retire_epoch: usize::MAX,
        });
    }
    DriftSpec { phases, drift: RateDrift::None, epochs, epoch_s, seed: 0xD21F }
}

fn epoch_status(r: &crate::cluster::epochs::EpochRecord) -> &'static str {
    if !r.planned {
        "unplanned"
    } else if r.memory_error {
        "oom"
    } else if r.starved {
        "starved"
    } else {
        "ok"
    }
}

/// "Fig. D" (beyond-paper artifact): GPUs and ITL over time, static vs
/// replan vs oracle-per-epoch on a churn workload, under the
/// GPU-minimizing and the ITL-minimizing objective.  `--estimator twin`
/// runs the whole policy grid DT-in-the-loop: one probe-cached twin
/// estimator is shared across every planning pass of every
/// (objective, policy) pair and its memos persist in the pipeline
/// artifact store, so repeated drift runs warm-start.
pub fn drift(ctx: &ExpContext) -> Result<()> {
    let dir = ctx.exp_dir("drift");
    // Single-backbone experiment (like figa13): honour `--model`, default
    // to pico-llama.
    let model = ctx.models.first().map(String::as_str).unwrap_or("pico-llama");
    let gpus = 4;
    let mut rt = ctx.load_runtime(model)?;
    let calib = ctx.calibration(&mut rt)?;
    // The estimator seam: the trained ML pair (deployed path) or the
    // probe-cached Digital Twin (`--estimator twin`), which skips the
    // dataset/training stages it never consults.
    let ml_est = match ctx.estimator {
        EstimatorChoice::Ml => Some(ctx.trained_estimator(&calib)?),
        EstimatorChoice::Twin => None,
    };
    let twin_est = match ctx.estimator {
        EstimatorChoice::Ml => None,
        EstimatorChoice::Twin => Some(ctx.twin_probe_estimator(&calib)?),
    };
    let est: &dyn PerfEstimator = match (&ml_est, &twin_est) {
        (Some(ml), _) => ml as &dyn PerfEstimator,
        (_, Some((twin, _))) => twin as &dyn PerfEstimator,
        _ => unreachable!("one estimator is always constructed"),
    };
    let epochs = if ctx.scale.is_quick() { 6 } else { 8 };
    let epoch_s = ctx.horizon() / 2.0;
    let spec = burst_churn(epochs, epoch_s, &calib);
    let base = EngineConfig { model: model.to_string(), ..Default::default() };
    let params = ReplanParams::from_calibration(&calib, epoch_s);
    // Twin at quick scale (fidelity pinned by table1), engine at full.
    // The event-driven core is a twin-side simulation, so `--core event`
    // forces the twin backend at any scale.
    let core = ctx.core;
    let on_engine = !ctx.scale.is_quick() && core == Core::Lockstep;

    let cost = params.cost;
    let objectives: Vec<(&str, &dyn Objective)> =
        vec![("min_gpus", &MinGpus), ("min_latency", &MinLatency)];
    let policies: Vec<(&str, ReplanPolicy)> = vec![
        ("static", ReplanPolicy::Static),
        ("replan", ReplanPolicy::Replan(params)),
        ("oracle", ReplanPolicy::Oracle(cost)),
    ];
    let mut rows = vec![];
    let mut reports: Vec<(String, DriftReport)> = vec![];
    for (oname, objective) in &objectives {
        for (pname, policy) in &policies {
            let backend = if on_engine {
                HorizonBackend::Engine
            } else {
                HorizonBackend::Twin { calib: &calib, variant: LengthVariant::Original }
            };
            let opts = if on_engine {
                RunOptions::new().pool(ctx.backend_pool())
            } else {
                RunOptions::new()
            };
            let rep =
                serve_horizon(backend, &base, &spec, gpus, est, *objective, policy, core, opts)?;
            for r in &rep.per_epoch {
                let mut row = vec![oname.to_string(), pname.to_string()];
                row.extend(r.csv_cells());
                row.push(epoch_status(r).to_string());
                rows.push(row);
            }
            println!(
                "  drift {oname}/{pname}: {} GPU-epochs, mean ITL {:.2} ms, {} migrations \
                 ({:.1} ms), {} infeasible epochs, {} groups re-probed / {} ledger-reused, \
                 goodput {:.2} req/s at {:.0}% SLO attainment",
                rep.gpu_epochs,
                ReportSchema::ms_from_s(rep.mean_itl_s),
                rep.total_migrations,
                ReportSchema::ms_from_s(rep.total_migration_cost_s),
                rep.infeasible_epochs,
                rep.total_groups_reprobed,
                rep.total_groups_reused,
                rep.mean_goodput_req_s,
                100.0 * rep.slo_attainment
            );
            reports.push((format!("{oname}/{pname}"), rep));
        }
    }

    // Persist the probe memos of the DT-in-the-loop path and report the
    // hit rate (the CI smoke gates on it: planning the whole grid through
    // the shared cache must answer most probes without a DT simulation).
    if let Some((twin, path)) = &twin_est {
        twin.save_memos(path)?;
        let s = twin.stats();
        println!(
            "  drift: probe cache {} hits / {} misses ({:.1}% hit rate), \
             {} memos persisted ({} warm-started)",
            s.hits,
            s.misses,
            100.0 * s.hit_rate(),
            s.entries,
            s.warm
        );
    }

    print_table(
        "drift — GPUs and ITL over time: {static,replan,oracle} x {min_gpus,min_latency}",
        &[
            "objective",
            "policy",
            "epoch",
            "adapters",
            "gpus",
            "migrations",
            "mig_cost_ms",
            "plan_ms",
            "throughput",
            "incoming",
            "itl_ms",
            "backlog",
            "reprobed",
            "reused",
            "goodput",
            "slo_att",
            "ttft_ms",
            "kv_bytes",
            "status",
        ],
        &rows,
    );
    // The CSV header comes from the shared column registry, so the drift
    // and fleet emitters cannot silently diverge from the schema.
    write_csv(&dir, "drift.csv", &ReportSchema::drift_header(), &rows)?;

    let mut fields: Vec<(&str, Json)> = vec![
        ("epochs", Json::Num(epochs as f64)),
        ("epoch_s", Json::Num(epoch_s)),
        ("gpus", Json::Num(gpus as f64)),
        ("backend", Json::Str(if on_engine { "engine" } else { "twin" }.into())),
        ("core", Json::Str(core.name().into())),
        ("estimator", Json::Str(est.name().into())),
    ];
    if let Some((twin, _)) = &twin_est {
        let s = twin.stats();
        fields.push((
            "probe_cache",
            Json::obj(vec![
                ("hits", Json::Num(s.hits as f64)),
                ("misses", Json::Num(s.misses as f64)),
                ("hit_rate", Json::Num(s.hit_rate())),
                ("entries", Json::Num(s.entries as f64)),
                ("warm_started", Json::Num(s.warm as f64)),
            ]),
        ));
    }
    for (oname, _) in &objectives {
        let mut policy_fields: Vec<(&str, Json)> = vec![];
        for (pname, _) in &policies {
            let key = format!("{oname}/{pname}");
            let Some((_, rep)) = reports.iter().find(|(n, _)| *n == key) else {
                continue;
            };
            policy_fields.push((
                *pname,
                Json::obj(vec![
                    ("gpu_epochs", Json::Num(rep.gpu_epochs as f64)),
                    ("migrations", Json::Num(rep.total_migrations as f64)),
                    ("migration_cost_s", Json::Num(rep.total_migration_cost_s)),
                    ("infeasible_epochs", Json::Num(rep.infeasible_epochs as f64)),
                    ("mean_throughput_tok_s", Json::Num(rep.mean_throughput_tok_s)),
                    ("mean_itl_s", Json::Num(rep.mean_itl_s)),
                    ("final_backlog_tokens", Json::Num(rep.final_backlog_tokens)),
                    ("groups_reprobed", Json::Num(rep.total_groups_reprobed as f64)),
                    ("groups_reused", Json::Num(rep.total_groups_reused as f64)),
                    ("mean_goodput_req_s", Json::Num(rep.mean_goodput_req_s)),
                    ("slo_attainment", Json::Num(rep.slo_attainment)),
                    ("kv_handoff_bytes", Json::Num(rep.total_kv_handoff_bytes as f64)),
                ]),
            ));
        }
        fields.push((*oname, Json::obj(policy_fields)));
    }
    let find = |key: &str| reports.iter().find(|(n, _)| n == key).map(|(_, r)| r);
    if let (Some(stat), Some(repl)) = (find("min_gpus/static"), find("min_gpus/replan")) {
        let saved = stat.gpu_epochs as f64 - repl.gpu_epochs as f64;
        println!(
            "  drift: replan saves {saved} GPU-epochs vs static ({:.0}%), feasible={}",
            100.0 * saved / stat.gpu_epochs.max(1) as f64,
            repl.feasible()
        );
        fields.push(("replan_saves_gpu_epochs", Json::Num(saved)));
    }
    if let (Some(rg), Some(rl)) = (find("min_gpus/replan"), find("min_latency/replan")) {
        println!(
            "  drift: replan objectives — min_gpus {} GPU-epochs at {:.2} ms mean ITL vs \
             min_latency {} GPU-epochs at {:.2} ms mean ITL",
            rg.gpu_epochs,
            ReportSchema::ms_from_s(rg.mean_itl_s),
            rl.gpu_epochs,
            ReportSchema::ms_from_s(rl.mean_itl_s)
        );
        fields.push((
            "replan_objective_tradeoff",
            Json::obj(vec![
                ("min_gpus_gpu_epochs", Json::Num(rg.gpu_epochs as f64)),
                ("min_gpus_mean_itl_s", Json::Num(rg.mean_itl_s)),
                ("min_latency_gpu_epochs", Json::Num(rl.gpu_epochs as f64)),
                ("min_latency_mean_itl_s", Json::Num(rl.mean_itl_s)),
            ]),
        ));
    }
    write_summary(&dir, fields)?;
    println!("drift: wrote {}", dir.display());
    Ok(())
}
