//! Digital-Twin evaluation (paper §8.2): Table 1 (fidelity SMAPE under
//! predictable and unpredictable arrivals, Original vs Mean lengths),
//! Table 2 (DT execution time / resources), Fig. 8 (DT vs engine curves),
//! Fig. 9 (unpredictable traces and queue dynamics).

use super::common::{peak_rss_mb, print_table, validation_runs, write_csv, ExpContext};
use crate::config::EngineConfig;
use crate::dt::{self, LengthVariant};
use crate::engine::Engine;
use crate::placement::PerfEstimator;
use crate::util::stats;
use crate::workload::{ArrivalModel, UnpredictableParams, WorkloadSpec};
use anyhow::Result;

/// Table 1 + Table 2 (they share the scenario runs).
pub fn table1(ctx: &ExpContext) -> Result<()> {
    let dir = ctx.exp_dir("table1");
    let mut table_rows = vec![];
    let mut t2_rows = vec![];
    let mut csv_rows = vec![];
    for model in &ctx.models {
        let mut rt = ctx.load_runtime(model)?;
        let calib = ctx.calibration(&mut rt)?;
        let scenarios = validation_runs(ctx, &mut rt)?;

        // -------- Predictable arrivals --------
        type Acc6 = (Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>);
        let mut acc: std::collections::BTreeMap<&str, Acc6> = Default::default();
        let mut twin_walls = vec![];
        let mut engine_walls = vec![];
        for sc in &scenarios {
            if sc.throughput <= 0.0 {
                continue; // memory-error scenarios have no metrics to compare
            }
            let spec = sc.spec(ctx.horizon());
            let trace = spec.trace();
            let cfg = sc.config(model);
            for (variant, key) in
                [(LengthVariant::Original, "Original"), (LengthVariant::Mean, "Mean")]
            {
                let trace_v = match variant {
                    LengthVariant::Original => trace.clone(),
                    LengthVariant::Mean => spec.trace_mean_lengths(),
                };
                let res = dt::run_twin_trace(&cfg, &calib, &spec, &trace_v);
                if key == "Original" {
                    twin_walls.push(res.wall_s);
                    engine_walls.push(sc.engine_wall_s);
                }
                if let Some(rep) = res.report {
                    let e = acc.entry(key).or_default();
                    e.0.push(sc.throughput);
                    e.1.push(rep.throughput_tok_s);
                    e.2.push(sc.itl_s);
                    e.3.push(rep.itl_mean_s);
                    e.4.push(sc.ttft_s);
                    e.5.push(rep.ttft_mean_s);
                }
            }
        }
        for key in ["Original", "Mean"] {
            let (ta, tp, ia, ip, fa, fp) = &acc[key];
            let row = vec![
                model.clone(),
                key.to_string(),
                "predictable".to_string(),
                format!("{:.2}", stats::smape(ta, tp)),
                format!("{:.2}", stats::smape(ia, ip)),
                format!("{:.2}", stats::smape(fa, fp)),
            ];
            table_rows.push(row.clone());
            csv_rows.push(row);
        }

        // -------- Unpredictable arrivals --------
        let mut acc_u: std::collections::BTreeMap<&str, Acc6> = Default::default();
        let counts: Vec<usize> =
            if ctx.scale.is_quick() { vec![32, 64] } else { vec![32, 64, 128] };
        for (i, &n) in counts.iter().enumerate() {
            let adapters = WorkloadSpec::homogeneous(n, 8, 0.1);
            let mut spec = WorkloadSpec::sharegpt_like(adapters, ctx.horizon(), 3000 + i as u64);
            spec.arrival = ArrivalModel::Unpredictable(UnpredictableParams {
                switch_interval_s: spec.horizon_s / 12.0,
                ..Default::default()
            });
            let trace = spec.trace();
            let cfg = EngineConfig {
                model: model.clone(),
                a_max: 32,
                s_max_rank: 8,
                ..Default::default()
            };
            let mut engine = Engine::new(cfg.clone(), &mut rt);
            let eres = engine.run_trace(&spec, &trace)?;
            let Some(erep) = eres.report else { continue };
            for (variant, key) in
                [(LengthVariant::Original, "Original"), (LengthVariant::Mean, "Mean")]
            {
                let trace_v = match variant {
                    LengthVariant::Original => trace.clone(),
                    LengthVariant::Mean => spec.trace_mean_lengths(),
                };
                let res = dt::run_twin_trace(&cfg, &calib, &spec, &trace_v);
                if let Some(rep) = res.report {
                    let e = acc_u.entry(key).or_default();
                    e.0.push(erep.throughput_tok_s);
                    e.1.push(rep.throughput_tok_s);
                    e.2.push(erep.itl_mean_s);
                    e.3.push(rep.itl_mean_s);
                    e.4.push(erep.ttft_mean_s);
                    e.5.push(rep.ttft_mean_s);
                }
            }
        }
        for key in ["Original", "Mean"] {
            if let Some((ta, tp, ia, ip, fa, fp)) = acc_u.get(key) {
                let row = vec![
                    model.clone(),
                    key.to_string(),
                    "unpredictable".to_string(),
                    format!("{:.2}", stats::smape(ta, tp)),
                    format!("{:.2}", stats::smape(ia, ip)),
                    format!("{:.2}", stats::smape(fa, fp)),
                ];
                table_rows.push(row.clone());
                csv_rows.push(row);
            }
        }

        // -------- Table 2: DT time & resources --------
        let speedups: Vec<f64> = twin_walls
            .iter()
            .zip(&engine_walls)
            .map(|(t, e)| e / t.max(1e-9))
            .collect();
        t2_rows.push(vec![
            model.clone(),
            format!("{:.4} ± {:.4}", stats::mean(&twin_walls), stats::std(&twin_walls)),
            format!("{:.1} ± {:.1}", stats::mean(&engine_walls), stats::std(&engine_walls)),
            format!("{:.0}x", stats::mean(&speedups)),
            format!("{:.0}", peak_rss_mb()),
        ]);
    }
    print_table(
        "Table 1 — Digital Twin fidelity (SMAPE %, lower is better; paper: thr<=5.08, ITL<=9.87, TTFT<=21.49)",
        &["model", "req-lengths", "arrivals", "thr SMAPE", "ITL SMAPE", "TTFT SMAPE"],
        &table_rows,
    );
    write_csv(
        &dir,
        "table1.csv",
        &["model", "req_lengths", "arrivals", "smape_thr", "smape_itl", "smape_ttft"],
        &csv_rows,
    )?;
    print_table(
        "Table 2 — DT execution time & resources (paper: ~39s for 1h horizon, ~90x, ~200MB)",
        &["model", "twin wall (s)", "engine wall (s)", "speedup", "proc peak RSS (MB)"],
        &t2_rows,
    );
    write_csv(
        &ctx.exp_dir("table2"),
        "table2.csv",
        &["model", "twin_wall_s", "engine_wall_s", "speedup", "peak_rss_mb"],
        &t2_rows,
    )?;
    Ok(())
}

/// Fig. 8: engine vs twin (and ML) throughput/ITL/TTFT as the number of
/// adapters grows.
pub fn fig8(ctx: &ExpContext) -> Result<()> {
    let dir = ctx.exp_dir("fig8");
    let model = "pico-qwen";
    let mut rt = ctx.load_runtime(model)?;
    let calib = ctx.calibration(&mut rt)?;
    let est = ctx.trained_estimator(&calib)?;
    let counts: Vec<usize> =
        if ctx.scale.is_quick() { vec![8, 16, 32, 64] } else { vec![8, 16, 32, 64, 96, 128, 192] };
    let mut rows = vec![];
    for rate in [0.1f64, 0.05] {
        for &n in &counts {
            let adapters = WorkloadSpec::heterogeneous(n, &[8, 16], &[rate], 500 + n as u64);
            let spec = WorkloadSpec::sharegpt_like(adapters.clone(), ctx.horizon(), 600 + n as u64);
            let trace = spec.trace();
            let cfg = EngineConfig {
                model: model.to_string(),
                a_max: n.min(64),
                s_max_rank: 16,
                ..Default::default()
            };
            let mut engine = Engine::new(cfg.clone(), &mut rt);
            let eres = engine.run_trace(&spec, &trace)?;
            let erep = eres.report.unwrap();
            let tres = dt::run_twin_trace(&cfg, &calib, &spec, &spec.trace_mean_lengths());
            let trep = tres.report.unwrap();
            let ml_thr = est.estimate(&adapters, cfg.a_max).throughput_tok_s;
            println!(
                "  fig8 rate={rate} A={n}: engine={:.0} twin={:.0} ml={:.0} tok/s",
                erep.throughput_tok_s, trep.throughput_tok_s, ml_thr
            );
            rows.push(vec![
                format!("{rate}"),
                n.to_string(),
                format!("{:.1}", erep.throughput_tok_s),
                format!("{:.1}", trep.throughput_tok_s),
                format!("{:.1}", ml_thr),
                format!("{:.5}", erep.itl_mean_s),
                format!("{:.5}", trep.itl_mean_s),
                format!("{:.4}", erep.ttft_mean_s),
                format!("{:.4}", trep.ttft_mean_s),
            ]);
        }
    }
    write_csv(
        &dir,
        "fig8.csv",
        &[
            "rate",
            "n_adapters",
            "thr_engine",
            "thr_twin",
            "thr_ml",
            "itl_engine",
            "itl_twin",
            "ttft_engine",
            "ttft_twin",
        ],
        &rows,
    )?;
    println!("fig8: wrote {}", dir.display());
    Ok(())
}

/// Fig. 9: unpredictable arrival traces (left) and running/waiting queue
/// dynamics, engine vs twin (right).
pub fn fig9(ctx: &ExpContext) -> Result<()> {
    let dir = ctx.exp_dir("fig9");
    let model = "pico-llama";
    let mut rt = ctx.load_runtime(model)?;
    let calib = ctx.calibration(&mut rt)?;
    let n = 32;
    let adapters = WorkloadSpec::heterogeneous(n, &[8], &[1.6, 0.8, 0.4], 900);
    let mut spec = WorkloadSpec::sharegpt_like(adapters, ctx.horizon() * 2.0, 901);
    spec.arrival = ArrivalModel::Unpredictable(UnpredictableParams {
        switch_interval_s: spec.horizon_s / 12.0,
        ..Default::default()
    });
    let trace = spec.trace();
    // Left panel: arrival rate per time bin for a few sampled adapters.
    let bins = 24usize;
    let bin_w = spec.horizon_s / bins as f64;
    let mut arr_rows = vec![];
    for &aid in &[0usize, 7, 19] {
        for b in 0..bins {
            let t0 = b as f64 * bin_w;
            let cnt = trace
                .iter()
                .filter(|a| a.adapter_id == aid && a.time_s >= t0 && a.time_s < t0 + bin_w)
                .count();
            arr_rows.push(vec![
                aid.to_string(),
                format!("{:.2}", t0 + bin_w / 2.0),
                format!("{:.3}", cnt as f64 / bin_w),
            ]);
        }
    }
    write_csv(&dir, "fig9_arrivals.csv", &["adapter", "time_s", "rate_req_s"], &arr_rows)?;

    // Right panel: running/waiting over time, engine vs twin.
    let cfg =
        EngineConfig { model: model.to_string(), a_max: 32, s_max_rank: 8, ..Default::default() };
    let mut engine = Engine::new(cfg.clone(), &mut rt);
    let eres = engine.run_trace(&spec, &trace)?;
    let tres = dt::run_twin_trace(&cfg, &calib, &spec, &trace);
    let mut q_rows = vec![];
    // Engine metrics are inside RunResult's report; queue traces come from
    // the collectors — subsample to ~200 points each.
    type Samples<'a> = &'a [crate::engine::metrics::QueueSample];
    let dump = |rows: &mut Vec<Vec<String>>, who: &str, samples: Samples<'_>| {
        let step = (samples.len() / 200).max(1);
        for s in samples.iter().step_by(step) {
            rows.push(vec![
                who.to_string(),
                format!("{:.3}", s.time_s),
                s.running.to_string(),
                s.waiting.to_string(),
            ]);
        }
    };
    // The report doesn't expose the queue trace; re-derive from metrics —
    // the collectors store it in the RunResult report? They do not, so the
    // engine/twin expose it via the profiler-side sample list instead.
    let _ = &eres;
    let _ = &tres;
    // Fall back: rerun twin with trace sampling through its metrics report.
    // (Queue traces are written by the metric collectors into the reports.)
    if let Some(r) = &eres.report {
        dump(&mut q_rows, "engine", &r.queue_trace);
    }
    if let Some(r) = &tres.report {
        dump(&mut q_rows, "twin", &r.queue_trace);
    }
    write_csv(&dir, "fig9_queues.csv", &["who", "time_s", "running", "waiting"], &q_rows)?;
    println!("fig9: wrote {} ({} queue samples)", dir.display(), q_rows.len());
    Ok(())
}
