//! Shared infrastructure for the experiment harness: the cached pipeline
//! stages (now delegated to [`crate::pipeline::Pipeline`] and its
//! artifact store) and the validation-scenario suite reused by Tables
//! 1-4.

use crate::cluster::Core;
use crate::config::EngineConfig;
use crate::dt::Calibration;
use crate::engine::Engine;
use crate::ml::{self, MlModels, Predictor, Sample};
use crate::pipeline::Pipeline;
use crate::placement::{CachedEstimator, MlEstimator};
use crate::runtime::{self, Backend, BackendPool, Manifest};
use crate::util::cli::Args;
use crate::util::csv::Table;
use crate::util::json::Json;
use crate::workload::{AdapterSpec, WorkloadSpec};
use anyhow::Result;
use std::path::PathBuf;
use std::sync::OnceLock;

pub use crate::pipeline::{EstimatorChoice, Scale};

/// Shared experiment state: scale, output/artifact dirs, and the cached
/// pipeline stages (calibration → dataset → trained models).
pub struct ExpContext {
    /// Quick (CI) or full (paper-scale) sweeps.
    pub scale: Scale,
    /// Where `results/<id>/` artifacts are written.
    pub out_dir: PathBuf,
    /// AOT artifact directory for backend loading.
    pub artifacts: PathBuf,
    /// Worker threads for parallel sweeps.
    pub workers: usize,
    /// Backbone models the experiment iterates over.
    pub models: Vec<String>,
    /// Which estimator backs placement in estimator-generic experiments
    /// (`drift`): the trained ML pair (default) or the Digital Twin
    /// directly (`--estimator twin`, probe-cached).
    pub estimator: EstimatorChoice,
    /// Which serving core drives epoch horizons (`--core event` switches
    /// the drift experiment to the event-driven continuous-batching
    /// simulation; DESIGN.md §12).
    pub core: Core,
    /// Lazily-created engine-backend pool shared by every engine-path
    /// serving run this context drives.
    pool: OnceLock<BackendPool>,
}

impl ExpContext {
    /// A context with default dirs (`results/`, `$ADAPTER_SERVING_ARTIFACTS`).
    pub fn new(scale: Scale) -> ExpContext {
        ExpContext {
            scale,
            out_dir: PathBuf::from("results"),
            artifacts: Manifest::default_dir(),
            workers: crate::util::threadpool::default_workers(),
            models: vec!["pico-llama".into(), "pico-qwen".into()],
            estimator: EstimatorChoice::Ml,
            core: Core::Lockstep,
            pool: OnceLock::new(),
        }
    }

    /// The model-keyed backend pool the cluster runners check per-GPU
    /// backends out of (one pool per context, created on first use).
    pub fn backend_pool(&self) -> &BackendPool {
        self.pool.get_or_init(|| BackendPool::new(self.artifacts.clone()))
    }

    /// `results/<id>/`, created on first use.
    pub fn exp_dir(&self, id: &str) -> PathBuf {
        let d = self.out_dir.join(id);
        std::fs::create_dir_all(&d).ok();
        d
    }

    /// Short horizon used for engine/twin runs (the paper runs 1 h; see
    /// DESIGN.md §1 on horizon compression).
    pub fn horizon(&self) -> f64 {
        match self.scale {
            Scale::Quick => 10.0,
            Scale::Full => 40.0,
        }
    }

    /// Load the execution backend for `model` (see
    /// [`runtime::load_backend`] for the selection order).
    pub fn load_runtime(&self, model: &str) -> Result<Box<dyn Backend>> {
        runtime::load_backend(&self.artifacts, model)
    }

    /// A context from common CLI args: `--scale`, `--out`, `--model`,
    /// `--estimator`, `--core` (shared by the `drift` and `experiment`
    /// subcommands).
    pub fn from_args(args: &Args) -> Result<ExpContext> {
        let mut ctx = ExpContext::new(Scale::parse(args.get_or("scale", "quick")));
        if let Some(out) = args.get("out") {
            ctx.out_dir = PathBuf::from(out);
        }
        if let Some(m) = args.get("model") {
            ctx.models = vec![m.to_string()];
        }
        ctx.estimator = EstimatorChoice::parse(args.get_or("estimator", "ml"))?;
        ctx.core = Core::parse(args.get_or("core", "lockstep"))?;
        Ok(ctx)
    }

    // ------------------------------------------------------------------
    // Cached pipeline stages (delegated to the typed pipeline and its
    // content-hashed artifact store under `<out_dir>/store/`)
    // ------------------------------------------------------------------

    /// The typed pipeline for one backbone, configured like this context.
    pub fn pipeline(&self, model: &str) -> Pipeline {
        Pipeline::for_model(model)
            .scale(self.scale)
            .out_dir(self.out_dir.clone())
            .artifacts_dir(self.artifacts.clone())
            .workers(self.workers)
            .fast_calibration(self.scale.is_quick())
    }

    /// Calibration, cached in the artifact store.
    pub fn calibration(&self, rt: &mut dyn Backend) -> Result<Calibration> {
        let model = rt.meta().name.clone();
        Ok(self.pipeline(&model).calibrate_with(rt)?.calibration)
    }

    /// DT-generated training set, cached in the artifact store.
    pub fn dataset(&self, calib: &Calibration) -> Result<Vec<Sample>> {
        let pipe = self.pipeline(&calib.model).calibration(calib.clone());
        let calibrated = pipe.calibrate()?;
        Ok(pipe.dataset(&calibrated)?.samples)
    }

    /// Best RF model pair, cached in the artifact store.
    pub fn trained_models(&self, calib: &Calibration) -> Result<MlModels> {
        let pipe = self.pipeline(&calib.model).calibration(calib.clone());
        let calibrated = pipe.calibrate()?;
        if let Some(trained) = pipe.train_cached(&calibrated)? {
            return Ok(trained.models);
        }
        let dataset = pipe.dataset(&calibrated)?;
        Ok(pipe.train(&dataset)?.models)
    }

    /// The trained model pair behind the [`MlEstimator`] seam — what the
    /// placement call sites consume.
    pub fn trained_estimator(&self, calib: &Calibration) -> Result<MlEstimator> {
        Ok(MlEstimator::new(self.trained_models(calib)?))
    }

    /// The refined (Small Tree**) pair behind the [`MlEstimator`] seam.
    pub fn refined_estimator(&self, calib: &Calibration) -> Result<MlEstimator> {
        Ok(MlEstimator::new(self.refined_models(calib)?))
    }

    /// The DT-in-the-loop estimator, probe-cached and warm-started from
    /// the pipeline artifact store ([`Pipeline::probe_cached_twin`]).
    /// Returns the estimator and the store path its memos must be
    /// persisted back to once the caller's planning passes are done
    /// ([`CachedEstimator::save_memos`]).
    pub fn twin_probe_estimator(
        &self,
        calib: &Calibration,
    ) -> Result<(CachedEstimator, PathBuf)> {
        self.pipeline(&calib.model).probe_cached_twin(calib)
    }

    /// The refined (Small Tree**) model pair for ProposedFast.
    pub fn refined_models(&self, calib: &Calibration) -> Result<MlModels> {
        let samples = self.dataset(calib)?;
        let models = self.trained_models(calib)?;
        let xs = ml::train::xs(&samples);
        // Distill from the RF teacher's predictions (knowledge distillation).
        let t_thr: Vec<f64> = xs.iter().map(|x| models.predict_throughput(x)).collect();
        let t_st: Vec<f64> = xs
            .iter()
            .map(|x| models.predict_starvation(x) as i32 as f64)
            .collect();
        let small_thr = ml::refine::distill(&xs, &t_thr, ml::tree::Criterion::Mse, 32);
        let small_st = ml::refine::distill(&xs, &t_st, ml::tree::Criterion::Gini, 16);
        Ok(MlModels {
            throughput: Predictor::Flat(ml::refine::FlatTree::compile(&small_thr)),
            starvation: Predictor::Flat(ml::refine::FlatTree::compile(&small_st)),
            scaler: None,
        })
    }
}

// ----------------------------------------------------------------------
// Validation scenarios (paper §8.2 grid) shared by Tables 1-4
// ----------------------------------------------------------------------

/// One validation scenario: spec parameters + (cached) engine ground truth.
#[derive(Debug, Clone)]
pub struct ValScenario {
    /// Adapter count of the scenario.
    pub n_adapters: usize,
    /// Size (rank) candidate set.
    pub sizes: Vec<usize>,
    /// Rate candidate set (req/s).
    pub rates: Vec<f64>,
    /// The engine's `A_max` for this scenario.
    pub a_max: usize,
    /// Scenario seed (adapters + trace derive from it).
    pub seed: u64,
    /// Measured engine throughput (tok/s).
    pub throughput: f64,
    /// Measured mean inter-token latency (s).
    pub itl_s: f64,
    /// Measured mean time-to-first-token (s).
    pub ttft_s: f64,
    /// Whether the engine run starved.
    pub starved: bool,
    /// Wall-clock of the engine run (s) — the Table 2 cost baseline.
    pub engine_wall_s: f64,
}

impl ValScenario {
    /// The scenario's heterogeneous adapter population.
    pub fn adapters(&self) -> Vec<AdapterSpec> {
        WorkloadSpec::heterogeneous(self.n_adapters, &self.sizes, &self.rates, self.seed)
    }

    /// The scenario's workload over `horizon` seconds.
    pub fn spec(&self, horizon: f64) -> WorkloadSpec {
        WorkloadSpec::sharegpt_like(self.adapters(), horizon, self.seed ^ 0x77)
    }

    /// The engine configuration the scenario runs under.
    pub fn config(&self, model: &str) -> EngineConfig {
        EngineConfig {
            model: model.to_string(),
            a_max: self.a_max,
            s_max_rank: *self.sizes.iter().max().unwrap(),
            ..Default::default()
        }
    }
}

/// The §8.2 scenario grid: Cartesian product of size sets and rate regimes
/// over adapter counts, A_max co-varied.
fn scenario_grid(quick: bool) -> Vec<(usize, Vec<usize>, Vec<f64>, usize)> {
    let size_sets: Vec<Vec<usize>> = vec![vec![8, 16, 32], vec![8, 16]];
    let rate_sets: Vec<Vec<f64>> = vec![vec![1.6, 0.8, 0.4], vec![0.1, 0.05, 0.025]];
    let counts: Vec<usize> =
        if quick { vec![8, 32, 96] } else { vec![8, 16, 32, 64, 96, 128, 192, 256, 384] };
    let mut out = vec![];
    for sizes in &size_sets {
        for rates in &rate_sets {
            for &n in &counts {
                // High-rate regimes saturate far earlier; skip huge counts.
                if rates[0] > 1.0 && n > 96 {
                    continue;
                }
                let a_max = n.min(if rates[0] > 1.0 { 32 } else { 96 });
                out.push((n, sizes.clone(), rates.clone(), a_max));
            }
        }
    }
    out
}

/// Run (or load from cache) the engine ground-truth for the validation
/// scenarios of one model.
pub fn validation_runs(ctx: &ExpContext, rt: &mut dyn Backend) -> Result<Vec<ValScenario>> {
    let model = rt.meta().name.clone();
    let path = ctx.out_dir.join(format!("validation_{model}.csv"));
    if path.exists() {
        return load_validation(&path);
    }
    let mut out = vec![];
    let grid = scenario_grid(ctx.scale.is_quick());
    for (i, (n, sizes, rates, a_max)) in grid.into_iter().enumerate() {
        let mut sc = ValScenario {
            n_adapters: n,
            sizes,
            rates,
            a_max,
            seed: 1000 + i as u64,
            throughput: 0.0,
            itl_s: 0.0,
            ttft_s: 0.0,
            starved: false,
            engine_wall_s: 0.0,
        };
        let spec = sc.spec(ctx.horizon());
        let cfg = sc.config(&model);
        eprintln!(
            "[validation {}] scenario {i}: A={n} sizes={:?} rates={:?} a_max={a_max}",
            model, sc.sizes, sc.rates
        );
        let mut engine = Engine::new(cfg, &mut *rt);
        let res = engine.run(&spec)?;
        match res.report {
            Some(rep) => {
                sc.throughput = rep.throughput_tok_s;
                sc.itl_s = rep.itl_mean_s;
                sc.ttft_s = rep.ttft_mean_s;
                sc.starved = rep.starved;
                sc.engine_wall_s = res.wall_s;
            }
            None => {
                sc.throughput = 0.0;
                sc.starved = true;
                sc.engine_wall_s = res.wall_s;
            }
        }
        out.push(sc);
    }
    save_validation(&out, &path)?;
    Ok(out)
}

fn save_validation(scs: &[ValScenario], path: &std::path::Path) -> Result<()> {
    let mut t = Table::new(&[
        "n_adapters", "sizes", "rates", "a_max", "seed", "throughput", "itl_s", "ttft_s",
        "starved", "engine_wall_s",
    ]);
    for s in scs {
        t.push(vec![
            s.n_adapters.to_string(),
            s.sizes.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(" "),
            s.rates.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(" "),
            s.a_max.to_string(),
            s.seed.to_string(),
            s.throughput.to_string(),
            s.itl_s.to_string(),
            s.ttft_s.to_string(),
            (s.starved as i32).to_string(),
            s.engine_wall_s.to_string(),
        ]);
    }
    t.write_file(path)
}

fn load_validation(path: &std::path::Path) -> Result<Vec<ValScenario>> {
    let t = Table::read_file(path)?;
    let mut out = vec![];
    for row in &t.rows {
        out.push(ValScenario {
            n_adapters: row[0].parse()?,
            sizes: row[1].split_whitespace().map(|x| x.parse().unwrap()).collect(),
            rates: row[2].split_whitespace().map(|x| x.parse().unwrap()).collect(),
            a_max: row[3].parse()?,
            seed: row[4].parse()?,
            throughput: row[5].parse()?,
            itl_s: row[6].parse()?,
            ttft_s: row[7].parse()?,
            starved: row[8].parse::<i32>()? != 0,
            engine_wall_s: row[9].parse()?,
        });
    }
    Ok(out)
}

/// Rough single-GPU decode ceiling implied by a calibration: the best
/// bucket's tokens per second at zero adapter overhead.  The MaxBase
/// provisioning metric (Fig. 10/11) and the drift-scenario scale both
/// derive from this single definition.
pub fn backbone_max_tok_s(calib: &Calibration) -> f64 {
    calib
        .decode_buckets
        .iter()
        .map(|&b| b as f64 / calib.lat_model(b, b, 0).max(1e-9))
        .fold(1.0, f64::max)
}

/// Mean tokens per request (clipped input + output means) of a workload.
pub fn tokens_per_request(spec: &WorkloadSpec) -> f64 {
    spec.input_len.mean_clipped() + spec.output_len.mean_clipped()
}

/// Pretty table printer for report rows.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let widths: Vec<usize> = header
        .iter()
        .enumerate()
        .map(|(i, h)| rows.iter().map(|r| r[i].len()).chain([h.len()]).max().unwrap_or(4))
        .collect();
    let line = |cells: Vec<String>| {
        let s: Vec<String> =
            cells.iter().zip(&widths).map(|(c, w)| format!("{c:>w$}", w = w)).collect();
        println!("  {}", s.join("  "));
    };
    line(header.iter().map(|s| s.to_string()).collect());
    for r in rows {
        line(r.clone());
    }
}

/// Write rows to CSV under the experiment dir.
pub fn write_csv(
    dir: &std::path::Path,
    name: &str,
    header: &[&str],
    rows: &[Vec<String>],
) -> Result<()> {
    let mut t = Table::new(header);
    for r in rows {
        t.push(r.clone());
    }
    t.write_file(&dir.join(name))
}

/// Rough measure of current process peak RSS (MB) from /proc.
pub fn peak_rss_mb() -> f64 {
    if let Ok(s) = std::fs::read_to_string("/proc/self/status") {
        for line in s.lines() {
            if let Some(rest) = line.strip_prefix("VmHWM:") {
                if let Some(kb) = rest.trim().split_whitespace().next() {
                    return kb.parse::<f64>().unwrap_or(0.0) / 1024.0;
                }
            }
        }
    }
    0.0
}

/// JSON summary writer (EXPERIMENTS.md sources these).
pub fn write_summary(dir: &std::path::Path, fields: Vec<(&str, Json)>) -> Result<()> {
    Json::obj(fields).write_file(&dir.join("summary.json"))
}
