//! Caching-decision evaluation (paper §8.4): Fig. 10 (single GPU),
//! Fig. 11 (4-GPU efficiency), Table 5 (placement runtimes), Fig. 12
//! (dLoRA + ProposedLat comparison), Fig. A.13 (S-LoRA mode).

use super::common::{print_table, write_csv, ExpContext};
use crate::cluster;
use crate::config::EngineConfig;
use crate::dt::LengthVariant;
use crate::engine::Engine;
use crate::engine::metrics::ReportSchema;
use crate::placement::{baselines, dlora, greedy, latency, PlacementResult};
use crate::workload::{AdapterSpec, WorkloadSpec};
use anyhow::Result;
use std::time::Instant;

/// Scenario families from §8.4: rate regime × size regime.
fn rates_of(kind: &str) -> Vec<f64> {
    match kind {
        "high" => vec![2.4, 1.2, 0.6, 0.3, 0.15],
        "low" => vec![0.075, 0.0375, 0.01875, 0.009375, 0.0046875],
        _ => vec![0.6, 0.3, 0.15, 0.075, 0.0375],
    }
}

fn sizes_of(kind: &str) -> Vec<usize> {
    match kind {
        "high" => vec![32],
        "low" => vec![8],
        _ => vec![8, 16, 32],
    }
}

fn scenario(n: usize, rates: &str, sizes: &str, seed: u64) -> Vec<AdapterSpec> {
    WorkloadSpec::heterogeneous(n, &sizes_of(sizes), &rates_of(rates), seed)
}

/// Estimate the backbone's max throughput (for MaxBase) from calibration.
fn backbone_max_tok_s(ctx: &ExpContext, rt: &mut dyn crate::runtime::Backend) -> Result<f64> {
    let calib = ctx.calibration(rt)?;
    Ok(super::common::backbone_max_tok_s(&calib))
}

/// Mean tokens per request under the ShareGPT-like length model.
fn tokens_per_request(spec: &WorkloadSpec) -> f64 {
    super::common::tokens_per_request(spec)
}

/// Validate a placement result; returns row fields
/// (gpus_used, throughput, itl, status) where status ∈ {ok, starved, oom,
/// infeasible, timelimit}.
fn validate(
    ctx: &ExpContext,
    rt: &mut dyn crate::runtime::Backend,
    base: &EngineConfig,
    res: &PlacementResult,
    spec: &WorkloadSpec,
    on_engine: bool,
) -> Result<(String, String, String, String)> {
    match res {
        Err(crate::placement::PlacementError::TimeLimit) => {
            Ok(("-".into(), "-".into(), "-".into(), "timelimit".into()))
        }
        Err(_) => Ok(("-".into(), "-".into(), "-".into(), "infeasible".into())),
        Ok(p) => {
            let rep = if on_engine {
                // Per-GPU backends checked out of the context's shared
                // pool (reused across every scenario of the experiment).
                let opts = cluster::RunOptions::new().pool(ctx.backend_pool());
                cluster::serve_on_engine(base, p, spec, opts)?
            } else {
                let calib = ctx.calibration(&mut *rt)?;
                let opts = cluster::RunOptions::new();
                cluster::serve_on_twin(&calib, base, p, spec, LengthVariant::Original, opts)
            };
            let status = if rep.memory_error {
                "oom"
            } else if rep.starved {
                "starved"
            } else {
                "ok"
            };
            Ok((
                rep.gpus_used.to_string(),
                format!("{:.1}", rep.total_throughput_tok_s),
                format!("{:.3}", ReportSchema::ms_from_s(rep.itl_mean_s)),
                status.into(),
            ))
        }
    }
}

/// Fig. 10: single-GPU achieved throughput and configured A_max for the
/// Proposed pipeline vs MaxBase/MaxBase*, two scenarios × two models.
pub fn fig10(ctx: &ExpContext) -> Result<()> {
    let dir = ctx.exp_dir("fig10");
    let mut rows = vec![];
    let counts: Vec<usize> = if ctx.scale.is_quick() {
        vec![8, 16, 32, 64, 96]
    } else {
        vec![8, 16, 32, 64, 96, 128, 160, 192]
    };
    // Allocations validated on the real engine at full scale, on the twin
    // at quick scale (the twin's fidelity is established by table1).
    let on_engine = !ctx.scale.is_quick();
    for model in &ctx.models {
        let mut rt = ctx.load_runtime(model)?;
        let calib = ctx.calibration(&mut rt)?;
        let est = ctx.trained_estimator(&calib)?;
        let bb = backbone_max_tok_s(ctx, &mut rt)?;
        for (rates, sizes) in [("low", "low"), ("low", "high")] {
            for &n in &counts {
                let adapters = scenario(n, rates, sizes, 40 + n as u64);
                let spec =
                    WorkloadSpec::sharegpt_like(adapters.clone(), ctx.horizon(), 41 + n as u64);
                let tpr = tokens_per_request(&spec);
                let base = EngineConfig { model: model.clone(), ..Default::default() };
                for (method, res) in [
                    ("Proposed", greedy::place(&adapters, 1, &est)),
                    ("MaxBase", baselines::max_base(&adapters, 1, bb, tpr, false)),
                    ("MaxBase*", baselines::max_base(&adapters, 1, bb, tpr, true)),
                ] {
                    let a_max = res.as_ref().map(|p| p.a_max[0]).unwrap_or(0);
                    let (g, thr, itl, status) =
                        validate(ctx, &mut rt, &base, &res, &spec, on_engine)?;
                    println!(
                        "  fig10 {model} {rates}-rate/{sizes}-size A={n} {method}: thr={thr} a_max={a_max} {status}"
                    );
                    rows.push(vec![
                        model.clone(),
                        format!("{rates}-rate/{sizes}-size"),
                        n.to_string(),
                        method.to_string(),
                        thr,
                        a_max.to_string(),
                        status,
                        g,
                        itl,
                    ]);
                }
            }
        }
    }
    write_csv(
        &dir,
        "fig10.csv",
        &[
            "model",
            "scenario",
            "n_adapters",
            "method",
            "throughput",
            "a_max",
            "status",
            "gpus",
            "itl_ms",
        ],
        &rows,
    )?;
    println!("fig10: wrote {}", dir.display());
    Ok(())
}

/// Fig. 11: GPUs required on a 4-GPU system across heterogeneous workloads.
pub fn fig11(ctx: &ExpContext) -> Result<()> {
    let dir = ctx.exp_dir("fig11");
    let gpus = 4;
    let mut rows = vec![];
    let scenarios: Vec<(&str, &str, Vec<usize>)> = vec![
        (
            "low",
            "low",
            if ctx.scale.is_quick() {
                vec![16, 64, 160, 320]
            } else {
                vec![16, 32, 64, 96, 128, 192, 256, 320, 384]
            },
        ),
        (
            "mixed",
            "mixed",
            if ctx.scale.is_quick() {
                vec![16, 48, 96, 160]
            } else {
                vec![16, 32, 64, 96, 128, 160, 192, 256]
            },
        ),
        (
            "low",
            "high",
            if ctx.scale.is_quick() { vec![16, 48, 96] } else { vec![16, 32, 64, 96, 128, 160] },
        ),
        (
            "mixed",
            "low",
            if ctx.scale.is_quick() {
                vec![16, 48, 96, 160]
            } else {
                vec![16, 32, 64, 96, 128, 192, 256]
            },
        ),
    ];
    // Validation on the twin for the sweep (engine at full scale).
    let on_engine = !ctx.scale.is_quick();
    for (si, (rates, sizes, counts)) in scenarios.iter().enumerate() {
        let model = if si < 2 { "pico-qwen" } else { "pico-llama" };
        let mut rt = ctx.load_runtime(model)?;
        let calib = ctx.calibration(&mut rt)?;
        let est = ctx.trained_estimator(&calib)?;
        let fast = ctx.refined_estimator(&calib)?;
        let bb = backbone_max_tok_s(ctx, &mut rt)?;
        for &n in counts {
            let adapters = scenario(n, rates, sizes, 70 + n as u64);
            let spec = WorkloadSpec::sharegpt_like(adapters.clone(), ctx.horizon(), 71 + n as u64);
            let tpr = tokens_per_request(&spec);
            let base = EngineConfig { model: model.to_string(), ..Default::default() };
            for (method, res) in [
                ("Proposed", greedy::place(&adapters, gpus, &est)),
                ("ProposedFast", greedy::place(&adapters, gpus, &fast)),
                ("MaxBase", baselines::max_base(&adapters, gpus, bb, tpr, false)),
                ("MaxBase*", baselines::max_base(&adapters, gpus, bb, tpr, true)),
                ("Random", baselines::random(&adapters, gpus, 7 + n as u64)),
            ] {
                let (g, thr, itl, status) =
                    validate(ctx, &mut rt, &base, &res, &spec, on_engine)?;
                println!(
                    "  fig11 s{si} ({model},{rates}-rate/{sizes}-size) A={n} {method}: gpus={g} {status}"
                );
                rows.push(vec![
                    si.to_string(),
                    model.to_string(),
                    format!("{rates}-rate/{sizes}-size"),
                    n.to_string(),
                    method.to_string(),
                    g,
                    thr,
                    itl,
                    status,
                ]);
            }
        }
    }
    write_csv(
        &dir,
        "fig11.csv",
        &[
            "scenario",
            "model",
            "family",
            "n_adapters",
            "method",
            "gpus_used",
            "throughput",
            "itl_ms",
            "status",
        ],
        &rows,
    )?;
    println!("fig11: wrote {}", dir.display());
    Ok(())
}

/// Table 5: execution time of the placement algorithms (1 and 4 GPUs).
pub fn table5(ctx: &ExpContext) -> Result<()> {
    let dir = ctx.exp_dir("table5");
    let mut rows = vec![];
    for model in &ctx.models {
        let mut rt = ctx.load_runtime(model)?;
        let calib = ctx.calibration(&mut rt)?;
        let est = ctx.trained_estimator(&calib)?;
        let fast = ctx.refined_estimator(&calib)?;
        let bb = backbone_max_tok_s(ctx, &mut rt)?;
        let n = 192;
        let adapters = scenario(n, "mixed", "mixed", 99);
        let spec = WorkloadSpec::sharegpt_like(adapters.clone(), 10.0, 99);
        let tpr = tokens_per_request(&spec);
        let time_it = |f: &dyn Fn() -> PlacementResult| -> f64 {
            // Table 2 planner-latency measurement; experiments::* is on
            // detlint's wall-clock allowlist.
            #[allow(clippy::disallowed_methods)]
            let t0 = Instant::now();
            let reps = 5;
            for _ in 0..reps {
                let _ = std::hint::black_box(f());
            }
            t0.elapsed().as_secs_f64() / reps as f64
        };
        for gpus in [1usize, 4] {
            let mut add = |method: &str, t: f64| {
                rows.push(vec![
                    model.clone(),
                    gpus.to_string(),
                    method.to_string(),
                    format!("{:.3e}", t),
                ]);
            };
            add("Proposed", time_it(&|| greedy::place(&adapters, gpus, &est)));
            if gpus == 4 {
                add("ProposedFast", time_it(&|| greedy::place(&adapters, gpus, &fast)));
                add("Random", time_it(&|| baselines::random(&adapters, gpus, 3)));
                add(
                    "dLoRAProactive",
                    time_it(&|| dlora::place(&adapters, gpus, &dlora::DloraParams::default())),
                );
            }
            add("MaxBase", time_it(&|| baselines::max_base(&adapters, gpus, bb, tpr, false)));
            add("MaxBase*", time_it(&|| baselines::max_base(&adapters, gpus, bb, tpr, true)));
        }
    }
    print_table(
        "Table 5 — placement runtimes (s); paper: Proposed ~2s, ProposedFast ~1-2ms, dLoRA ~0.02-0.15s",
        &["model", "gpus", "method", "time_s"],
        &rows,
    );
    write_csv(&dir, "table5.csv", &["model", "gpus", "method", "time_s"], &rows)?;
    Ok(())
}

/// Fig. 12: Proposed vs dLoRA vs ProposedLat on a 4-GPU system.
pub fn fig12(ctx: &ExpContext) -> Result<()> {
    let dir = ctx.exp_dir("fig12");
    let gpus = 4;
    let model = "pico-qwen";
    let mut rt = ctx.load_runtime(model)?;
    let calib = ctx.calibration(&mut rt)?;
    let est = ctx.trained_estimator(&calib)?;
    let mut rows = vec![];
    let on_engine = !ctx.scale.is_quick();
    let scenarios: Vec<(&str, &str, Vec<usize>)> = vec![
        (
            "mixed",
            "mixed",
            if ctx.scale.is_quick() {
                vec![16, 48, 96, 192, 320]
            } else {
                vec![16, 32, 64, 96, 128, 192, 256, 320, 384]
            },
        ),
        (
            "high",
            "low",
            if ctx.scale.is_quick() { vec![4, 8, 16, 24] } else { vec![4, 8, 12, 16, 24, 32] },
        ),
    ];
    for (si, (rates, sizes, counts)) in scenarios.iter().enumerate() {
        for &n in counts {
            let adapters = scenario(n, rates, sizes, 120 + n as u64);
            let spec = WorkloadSpec::sharegpt_like(adapters.clone(), ctx.horizon(), 121 + n as u64);
            let base = EngineConfig { model: model.to_string(), ..Default::default() };
            // dLoRA gets a budget that fails at large adapter counts on
            // this testbed, reproducing the paper's time-limit behaviour.
            let dl_params = dlora::DloraParams {
                time_limit_s: if ctx.scale.is_quick() { 0.25 } else { 2.0 },
                ..Default::default()
            };
            for (method, res) in [
                ("Proposed", greedy::place(&adapters, gpus, &est)),
                ("dLoRAProactive", dlora::place(&adapters, gpus, &dl_params)),
                ("ProposedLat", latency::place(&adapters, gpus, &est)),
            ] {
                let (g, thr, itl, status) = validate(ctx, &mut rt, &base, &res, &spec, on_engine)?;
                println!("  fig12 s{si} A={n} {method}: gpus={g} thr={thr} itl={itl}ms {status}");
                rows.push(vec![
                    si.to_string(),
                    format!("{rates}-rate/{sizes}-size"),
                    n.to_string(),
                    method.to_string(),
                    g,
                    thr,
                    itl,
                    status,
                ]);
            }
        }
    }
    write_csv(
        &dir,
        "fig12.csv",
        &[
            "scenario",
            "family",
            "n_adapters",
            "method",
            "gpus_used",
            "throughput",
            "itl_ms",
            "status",
        ],
        &rows,
    )?;
    println!("fig12: wrote {}", dir.display());
    Ok(())
}

/// Fig. A.13: S-LoRA-style unified memory — throughput vs adapters under
/// varying rates, size 32, fixed request lengths.
pub fn figa13(ctx: &ExpContext) -> Result<()> {
    let dir = ctx.exp_dir("figa13");
    let mut rt = ctx.load_runtime("pico-llama")?;
    let counts: Vec<usize> =
        if ctx.scale.is_quick() { vec![8, 16, 32, 64] } else { vec![8, 16, 32, 48, 64, 96, 128] };
    let mut rows = vec![];
    let rates: Vec<f64> = if ctx.scale.is_quick() { vec![1.6, 0.4] } else { vec![1.6, 0.8, 0.4] };
    for rate in rates {
        for &n in &counts {
            let adapters = WorkloadSpec::homogeneous(n, 32, rate / 16.0);
            let spec = WorkloadSpec::fixed_len(adapters, 250, 231, ctx.horizon(), 130 + n as u64);
            let mut cfg = EngineConfig {
                model: "pico-llama".into(),
                a_max: n,
                s_max_rank: 32,
                ..Default::default()
            };
            cfg.mem.unified = true; // S-LoRA: no static reservation
            let mut engine = Engine::new(cfg, &mut rt);
            let res = engine.run(&spec)?;
            let (thr, starved) = res
                .report
                .map(|r| (r.throughput_tok_s, r.starved))
                .unwrap_or((0.0, true));
            let tag = if starved { " STARVED" } else { "" };
            println!("  figa13 rate={rate} A={n}: thr={thr:.0}{tag}");
            rows.push(vec![
                format!("{rate}"),
                n.to_string(),
                format!("{thr:.1}"),
                (starved as i32).to_string(),
            ]);
        }
    }
    write_csv(&dir, "figa13.csv", &["rate", "n_adapters", "throughput", "starved"], &rows)?;
    println!("figa13: wrote {}", dir.display());
    Ok(())
}
