//! The fleet experiment (DESIGN.md §5/§11): $/hr, GPUs and ITL over
//! time on a heterogeneous two-type fleet, under the GPU-minimizing and
//! the cost-minimizing objective.
//!
//! Scenario: the same burst-churn workload as the drift experiment
//! ([`super::drift::burst_churn`]), re-planned from scratch every epoch
//! on a fleet of catalog a10g and a100 GPUs (the a100 is faster but,
//! per probed throughput per dollar, usually the worse buy — the
//! Mélange-style heterogeneity tradeoff).  Every epoch is planned
//! DT-in-the-loop through the per-type probe caches and validated on the
//! fleet twin ([`crate::cluster::serve_on_twin_fleet`]), so the table
//! shows rental cost next to the GPUs and ITL it buys.  Regenerates
//! `results/fleet/fleet.csv` + `summary.json`.

use super::common::{print_table, write_csv, write_summary, ExpContext};
use super::drift::burst_churn;
use crate::config::{FleetSpec, GpuTypeSpec};
use crate::engine::metrics::ReportSchema;
use crate::placement::{MinCost, MinGpus, Objective};
use crate::util::json::Json;
use anyhow::Result;

/// The experiment's two-class fleet: enough a10g stock to serve the
/// burst alone, plus a pool of faster a100s the cost objective must
/// weigh by throughput per dollar.
fn two_type_fleet() -> FleetSpec {
    let a10g = GpuTypeSpec::catalog("a10g").expect("a10g in catalog");
    let a100 = GpuTypeSpec::catalog("a100").expect("a100 in catalog");
    FleetSpec::new(vec![(a10g, 4), (a100, 2)])
}

/// "$/hr over time" on a typed fleet: per-epoch cost, GPU mix and ITL
/// for `min_gpus` vs `min_cost`, DT-in-the-loop with per-type probe
/// caches persisted in the pipeline artifact store.
pub fn fleet(ctx: &ExpContext) -> Result<()> {
    let dir = ctx.exp_dir("fleet");
    let model = ctx.models.first().map(String::as_str).unwrap_or("pico-llama");
    let mut rt = ctx.load_runtime(model)?;
    let calib = ctx.calibration(&mut rt)?;
    let fleet_spec = two_type_fleet();
    let epochs = if ctx.scale.is_quick() { 6 } else { 8 };
    let epoch_s = ctx.horizon() / 2.0;
    let scenario = burst_churn(epochs, epoch_s, &calib);

    let arms: Vec<(&str, Box<dyn Objective>)> =
        vec![("min_gpus", Box::new(MinGpus)), ("min_cost", Box::new(MinCost))];
    let mut rows = vec![];
    let mut summaries: Vec<(&str, Json)> = vec![];
    let mut mean_costs: Vec<(&str, f64)> = vec![];
    let (mut probe_hits, mut probe_misses) = (0u64, 0u64);
    for (oname, objective) in arms {
        let pipe = ctx
            .pipeline(model)
            .calibration(calib.clone())
            .fleet(fleet_spec.clone())
            .boxed_objective(objective);
        let calibrated = pipe.calibrate()?;
        let (mut cost_sum, mut gpu_epochs, mut itl_sum, mut served) = (0.0, 0usize, 0.0, 0usize);
        for epoch in 0..epochs {
            let spec = scenario.epoch_spec(epoch);
            let planned = match pipe.place_on_twin(&calibrated, &spec.adapters) {
                Ok(p) => p,
                Err(e) => {
                    let mut row =
                        vec![oname.to_string(), epoch.to_string(), spec.adapters.len().to_string()];
                    // One "-" per metric column between the labels and status.
                    row.extend(
                        (0..ReportSchema::fleet_header().len() - 4).map(|_| "-".to_string()),
                    );
                    row.push(format!("infeasible: {e}"));
                    rows.push(row);
                    continue;
                }
            };
            if let Some(s) = planned.probe_cache {
                probe_hits += s.hits;
                probe_misses += s.misses;
            }
            let f = planned.fleet.as_ref().expect("fleet pipelines report fleet facets");
            let mix: Vec<String> = fleet_spec
                .types
                .iter()
                .zip(&f.used_by_type)
                .filter(|&(_, &n)| n > 0)
                .map(|(ty, &n)| format!("{}x{n}", ty.name))
                .collect();
            let validated = pipe.validate_with(&calib, &planned, &spec)?;
            let rep = &validated.report;
            cost_sum += f.cost_per_hour;
            gpu_epochs += rep.gpus_used;
            itl_sum += rep.itl_mean_s;
            served += 1;
            let mut row = vec![
                oname.to_string(),
                epoch.to_string(),
                spec.adapters.len().to_string(),
                rep.gpus_used.to_string(),
                mix.join("+"),
                format!("{:.2}", f.cost_per_hour),
                format!("{:.1}", rep.total_throughput_tok_s),
                format!("{:.3}", ReportSchema::ms_from_s(rep.itl_mean_s)),
            ];
            row.extend(ReportSchema::slo_cells(
                rep.goodput_req_s,
                rep.slo_attainment,
                rep.ttft_mean_s,
                rep.kv_handoff_bytes,
            ));
            row.push(if rep.feasible() { "ok" } else { "degraded" }.to_string());
            rows.push(row);
        }
        let mean_cost = cost_sum / served.max(1) as f64;
        let mean_itl = itl_sum / served.max(1) as f64;
        println!(
            "  fleet {oname}: {gpu_epochs} GPU-epochs at ${mean_cost:.2}/hr mean rental, \
             mean ITL {:.2} ms ({served}/{epochs} epochs feasible)",
            ReportSchema::ms_from_s(mean_itl)
        );
        mean_costs.push((oname, mean_cost));
        summaries.push((
            oname,
            Json::obj(vec![
                ("gpu_epochs", Json::Num(gpu_epochs as f64)),
                ("mean_cost_per_hour", Json::Num(mean_cost)),
                ("mean_itl_s", Json::Num(mean_itl)),
                ("feasible_epochs", Json::Num(served as f64)),
            ]),
        ));
    }

    println!(
        "  fleet: probe cache {probe_hits} hits / {probe_misses} misses across both objectives"
    );
    // Header from the shared column registry (`engine::metrics`), same
    // source as the drift CSV's.
    let header = ReportSchema::fleet_header();
    print_table("fleet — $/hr, GPUs and ITL over time: min_gpus vs min_cost", &header, &rows);
    write_csv(&dir, "fleet.csv", &header, &rows)?;

    let mut fields: Vec<(&str, Json)> = vec![
        ("epochs", Json::Num(epochs as f64)),
        ("epoch_s", Json::Num(epoch_s)),
        ("fleet", fleet_spec.to_json()),
        (
            "probe_cache",
            Json::obj(vec![
                ("hits", Json::Num(probe_hits as f64)),
                ("misses", Json::Num(probe_misses as f64)),
            ]),
        ),
    ];
    fields.extend(summaries);
    if let (Some(&(_, mg)), Some(&(_, mc))) = (
        mean_costs.iter().find(|(n, _)| *n == "min_gpus"),
        mean_costs.iter().find(|(n, _)| *n == "min_cost"),
    ) {
        println!(
            "  fleet: min_cost rents ${mc:.2}/hr vs min_gpus ${mg:.2}/hr \
             ({:+.1}% cost)",
            100.0 * (mc - mg) / mg.max(1e-9)
        );
        fields.push(("min_cost_saves_per_hour", Json::Num(mg - mc)));
    }
    write_summary(&dir, fields)?;
    println!("fleet: wrote {}", dir.display());
    Ok(())
}
