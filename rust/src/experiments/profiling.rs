//! §2.3 + §5.1 profiling experiments: Fig. 1 (the adapter caching problem),
//! Fig. 4 (memory overhead & ITL vs batch), Fig. 5 (compute overhead),
//! Fig. 6 (loading time), Fig. 7 (scheduler overhead).

use super::common::{write_csv, ExpContext};
use crate::config::EngineConfig;
use crate::engine::Engine;
use crate::engine::metrics::ReportSchema;
use crate::util::stats;
use crate::workload::{AdapterSpec, Arrival, WorkloadSpec};
use anyhow::Result;

/// Fig. 1: throughput vs number of served adapters under (a) adapter sizes,
/// (b) arrival rates, (c) A_max settings.  Crosses (memory errors) are
/// reported as `oom`.
pub fn fig1(ctx: &ExpContext) -> Result<()> {
    let dir = ctx.exp_dir("fig1");
    let mut rt = ctx.load_runtime("pico-llama")?;
    let counts: Vec<usize> = if ctx.scale.is_quick() {
        vec![8, 48, 96, 128]
    } else {
        vec![8, 16, 32, 48, 64, 96, 128, 160, 192]
    };
    let mut rows = vec![];
    let mut run = |panel: &str,
                   label: String,
                   n: usize,
                   rank: usize,
                   rate: f64,
                   a_max: usize,
                   rt: &mut dyn crate::runtime::Backend|
     -> Result<()> {
        let adapters = WorkloadSpec::homogeneous(n, rank, rate);
        let spec = WorkloadSpec::sharegpt_like(adapters, ctx.horizon(), 42 + n as u64);
        let cfg = EngineConfig {
            model: "pico-llama".into(),
            a_max,
            s_max_rank: rank,
            ..Default::default()
        };
        let mut engine = Engine::new(cfg, rt);
        let res = engine.run(&spec)?;
        let (thr, starved, oom) = match res.report {
            Some(r) => (r.throughput_tok_s, r.starved, false),
            None => (0.0, false, true),
        };
        println!(
            "  fig1[{panel}] {label} A={n}: thr={thr:.0} tok/s{}{}",
            if starved { " STARVED" } else { "" },
            if oom { " OOM" } else { "" }
        );
        rows.push(vec![
            panel.to_string(),
            label,
            n.to_string(),
            format!("{thr:.1}"),
            (starved as i32).to_string(),
            (oom as i32).to_string(),
        ]);
        Ok(())
    };

    // (a) adapter sizes at fixed rate; A_max = N (paper's setting).
    for rank in [8usize, 16, 32] {
        for &n in &counts {
            run("size", format!("size={rank}"), n, rank, 0.05, n, &mut rt)?;
        }
    }
    // (b) arrival rates at fixed size 8.
    for rate in [0.1f64, 0.05, 0.025] {
        for &n in &counts {
            run("rate", format!("rate={rate}"), n, 8, rate, n, &mut rt)?;
        }
    }
    // (c) A_max settings at fixed size 8 / rate 0.05.
    for a_max in [32usize, 96, 160] {
        for &n in &counts {
            run("amax", format!("amax={a_max}"), n, 8, 0.05, a_max.min(n), &mut rt)?;
        }
    }
    write_csv(
        &dir,
        "fig1.csv",
        &["panel", "line", "n_adapters", "throughput", "starved", "oom"],
        &rows,
    )?;
    println!("fig1: wrote {}", dir.join("fig1.csv").display());
    Ok(())
}

/// Fig. 4: oversaturated backbone-only serving with idle loaded adapters:
/// achieved batch size and throughput vs loaded adapters (A_max·S_max
/// reservation), plus ITL vs batch size.
pub fn fig4(ctx: &ExpContext) -> Result<()> {
    let dir = ctx.exp_dir("fig4");
    let mut rows = vec![];
    let mut itl_rows = vec![];
    let loaded: Vec<usize> = if ctx.scale.is_quick() {
        vec![0, 64, 128]
    } else {
        vec![0, 16, 32, 64, 96, 128, 160, 192, 256]
    };
    let models: Vec<String> =
        if ctx.scale.is_quick() { vec!["pico-llama".into()] } else { ctx.models.clone() };
    for model in &models {
        let mut rt = ctx.load_runtime(model)?;
        for rank in [8usize, 32] {
            for &a in &loaded {
                // Backbone-only oversaturation: requests all arrive at t=0.
                let n_req = if ctx.scale.is_quick() { 80 } else { 128 };
                let adapters = vec![AdapterSpec { id: 0, rank: 0, rate: 0.0 }];
                let spec = WorkloadSpec::fixed_len(adapters, 128, 48, 1e9, 5);
                let trace: Vec<Arrival> = (0..n_req)
                    .map(|i| Arrival {
                        request_id: i,
                        time_s: 0.0,
                        adapter_id: 0,
                        input_len: 128,
                        output_len: if ctx.scale.is_quick() { 24 } else { 48 },
                    })
                    .collect();
                let cfg = EngineConfig {
                    model: model.clone(),
                    a_max: a,
                    s_max_rank: rank,
                    ..Default::default()
                };
                if cfg.kv_pool_tokens().is_none() {
                    println!("  fig4 {model} rank={rank} loaded={a}: OOM");
                    rows.push(vec![
                        model.clone(),
                        rank.to_string(),
                        a.to_string(),
                        "0".into(),
                        "0".into(),
                        "1".into(),
                    ]);
                    continue;
                }
                let mut engine = Engine::new(cfg, &mut rt);
                let res = engine.run_trace(&spec, &trace)?;
                let decode: Vec<&crate::engine::profiler::IterRecord> =
                    res.profiler.decode_iters().collect();
                let mean_batch =
                    stats::mean(&decode.iter().map(|r| r.batch as f64).collect::<Vec<_>>());
                let max_batch = decode.iter().map(|r| r.batch).max().unwrap_or(0);
                let thr = res.report.as_ref().map(|r| {
                    (r.input_tokens + r.output_tokens) as f64
                        / res.profiler.iters.last().map(|i| i.sim_time_s).unwrap_or(1.0)
                });
                println!(
                    "  fig4 {model} rank={rank} loaded={a}: batch mean={mean_batch:.1} max={max_batch} thr={:.0}",
                    thr.unwrap_or(0.0)
                );
                rows.push(vec![
                    model.clone(),
                    rank.to_string(),
                    a.to_string(),
                    format!("{max_batch}"),
                    format!("{:.1}", thr.unwrap_or(0.0)),
                    "0".into(),
                ]);
                // ITL vs batch points from the same run.
                let mut by_batch: std::collections::BTreeMap<usize, Vec<f64>> = Default::default();
                for r in &decode {
                    by_batch.entry(r.batch).or_default().push(r.exec_s);
                }
                for (b, ts) in by_batch {
                    itl_rows.push(vec![
                        model.clone(),
                        rank.to_string(),
                        b.to_string(),
                        format!("{:.6}", stats::mean(&ts)),
                    ]);
                }
            }
        }
    }
    write_csv(
        &dir,
        "fig4_batch_throughput.csv",
        &["model", "rank", "loaded_adapters", "max_batch", "throughput", "oom"],
        &rows,
    )?;
    write_csv(&dir, "fig4_itl_vs_batch.csv", &["model", "rank", "batch", "itl_s"], &itl_rows)?;
    println!("fig4: wrote {}", dir.display());
    Ok(())
}

/// Fig. 5: throughput slowdown and ITL overhead vs number of distinct
/// adapters in a fixed-size batch, relative to backbone-only.
pub fn fig5(ctx: &ExpContext) -> Result<()> {
    let dir = ctx.exp_dir("fig5");
    let mut rt = ctx.load_runtime("pico-llama")?;
    let fixed_b = 32usize;
    let out_tokens = if ctx.scale.is_quick() { 32 } else { 96 };
    let mut baseline_itl = 0.0f64;
    let mut rows = vec![];
    for rank in [0usize, 8, 16, 32] {
        let counts: Vec<usize> =
            if rank == 0 { vec![1] } else { vec![1, 2, 4, 8, 16, 32] };
        for a_b in counts {
            let adapters: Vec<AdapterSpec> = if rank == 0 {
                vec![AdapterSpec { id: 0, rank: 0, rate: 0.0 }]
            } else {
                (0..a_b).map(|id| AdapterSpec { id, rank, rate: 0.0 }).collect()
            };
            let spec = WorkloadSpec::fixed_len(adapters, 64, out_tokens, 1e9, 9);
            let trace: Vec<Arrival> = (0..fixed_b)
                .map(|i| Arrival {
                    request_id: i,
                    time_s: 0.0,
                    adapter_id: if rank == 0 { 0 } else { i % a_b },
                    input_len: 64,
                    output_len: out_tokens,
                })
                .collect();
            let cfg = EngineConfig {
                model: "pico-llama".into(),
                a_max: a_b.max(1),
                s_max_rank: rank.max(8),
                max_num_seqs: fixed_b,
                ..Default::default()
            };
            let mut engine = Engine::new(cfg, &mut rt);
            let res = engine.run_trace(&spec, &trace)?;
            let ts: Vec<f64> = res
                .profiler
                .decode_iters()
                .filter(|r| r.batch == fixed_b)
                .map(|r| r.exec_s)
                .collect();
            let itl = stats::mean(&ts);
            if rank == 0 {
                baseline_itl = itl;
                println!("  fig5 backbone-only: itl={:.3}ms", ReportSchema::ms_from_s(itl));
                continue;
            }
            let itl_overhead = itl / baseline_itl.max(1e-12);
            let slowdown = itl_overhead; // tokens/step fixed → slowdown = ITL ratio
            println!(
                "  fig5 rank={rank} A_B={a_b}: itl={:.3}ms overhead={:.3}x",
                ReportSchema::ms_from_s(itl),
                itl_overhead
            );
            rows.push(vec![
                rank.to_string(),
                a_b.to_string(),
                format!("{:.6}", itl),
                format!("{:.4}", itl_overhead),
                format!("{:.4}", slowdown),
            ]);
        }
    }
    write_csv(
        &dir,
        "fig5.csv",
        &["rank", "adapters_in_batch", "itl_s", "itl_overhead", "throughput_slowdown"],
        &rows,
    )?;
    println!("fig5: wrote {}", dir.display());
    Ok(())
}

/// Fig. 6: adapter loading time relative to request latency, per rank,
/// request length and storage tier (CPU vs disk).
pub fn fig6(ctx: &ExpContext) -> Result<()> {
    let dir = ctx.exp_dir("fig6");
    let mut rt = ctx.load_runtime("pico-llama")?;
    let base = EngineConfig { model: "pico-llama".into(), ..Default::default() };
    let calib = ctx.calibration(&mut rt)?;
    // TPOT at a typical single-request decode.
    let mut rows = vec![];
    for (in_len, out_len) in [(32usize, 32usize), (128, 128), (256, 512)] {
        let tpot = calib.lat_model(1, 1, 1);
        let req_latency = tpot * (out_len.saturating_sub(1)) as f64;
        for rank in [8usize, 16, 32] {
            for disk in [false, true] {
                let load = calib.lat_load(rank)
                    * if disk { base.load_disk_mult } else { 1.0 };
                let rel = 100.0 * load / (req_latency + load).max(1e-12);
                println!(
                    "  fig6 rank={rank} len={in_len}/{out_len} {}: load={:.2}ms = {rel:.2}% of request",
                    if disk { "disk" } else { "cpu" },
                    ReportSchema::ms_from_s(load)
                );
                rows.push(vec![
                    rank.to_string(),
                    in_len.to_string(),
                    out_len.to_string(),
                    if disk { "disk" } else { "cpu" }.to_string(),
                    format!("{:.6}", load),
                    format!("{:.6}", req_latency),
                    format!("{rel:.3}"),
                ]);
            }
        }
    }
    write_csv(
        &dir,
        "fig6.csv",
        &[
            "rank",
            "input_len",
            "output_len",
            "storage",
            "load_s",
            "request_latency_s",
            "relative_pct",
        ],
        &rows,
    )?;
    println!("fig6: wrote {}", dir.display());
    Ok(())
}

/// Fig. 7: scheduler time share vs number of adapters and A_max.
pub fn fig7(ctx: &ExpContext) -> Result<()> {
    let dir = ctx.exp_dir("fig7");
    let mut rt = ctx.load_runtime("pico-llama")?;
    let mut rows = vec![];
    let counts: Vec<usize> =
        if ctx.scale.is_quick() { vec![64, 192] } else { vec![64, 128, 256, 384] };
    for &n in &counts {
        for a_max in [8usize, 32, 128] {
            if a_max > n {
                continue;
            }
            // Overload with a large pending queue.
            let adapters = WorkloadSpec::homogeneous(n, 8, 0.4);
            let spec = WorkloadSpec::sharegpt_like(adapters, ctx.horizon() / 2.0, 77);
            let cfg = EngineConfig {
                model: "pico-llama".into(),
                a_max,
                s_max_rank: 8,
                ..Default::default()
            };
            let mut engine = Engine::new(cfg, &mut rt);
            let res = engine.run(&spec)?;
            let total_sched = res.profiler.total_sched_s();
            let total_step: f64 = res
                .profiler
                .iters
                .iter()
                .map(|r| r.sched_s + r.exec_s + r.load_s)
                .sum();
            let share = 100.0 * total_sched / total_step.max(1e-12);
            println!("  fig7 A={n} a_max={a_max}: scheduler {share:.3}% of step time");
            rows.push(vec![n.to_string(), a_max.to_string(), format!("{share:.4}")]);
        }
    }
    write_csv(&dir, "fig7.csv", &["n_adapters", "a_max", "sched_share_pct"], &rows)?;
    println!("fig7: wrote {}", dir.display());
    Ok(())
}
