//! Model-keyed pool of execution backends.
//!
//! Constructing a [`Backend`](super::Backend) means materializing a whole
//! model — synthesized backbone weights and an adapter bank for the
//! reference backend, a client + compiled executables on PJRT.  The
//! cluster runners fan one serving run out per GPU, and the epoch runner
//! does that once per epoch, so the naive pattern ("build a fresh backend
//! inside every worker") rebuilds the same model `gpus × epochs` times
//! per horizon.  [`BackendPool`] replaces it with check-out/check-in:
//!
//! - [`BackendPool::checkout`] hands an idle backend for the model out of
//!   the pool, constructing one only when none is idle (first epoch, or
//!   more concurrent GPUs than ever before);
//! - the returned [`PooledBackend`] guard checks the backend back in on
//!   drop, so a horizon constructs **at most `gpus` backends total**
//!   instead of `gpus` per epoch;
//! - [`BackendPool::created`] / [`BackendPool::reused`] expose the
//!   construction/reuse counters the epoch-runner tests and reports gate
//!   on.
//!
//! Reuse is sound because a backend's mutable state is exactly the host
//! adapter bank: every serving run begins by writing the bank slots for
//! its own adapters and uploading them, so stale slots from a previous
//! checkout are never read.  Pooled backends must be `Send` (they cross
//! worker threads between checkouts); see
//! [`load_send_backend`](super::load_send_backend) for why PJRT backends
//! do not qualify yet.

use super::Backend;
use anyhow::Result;
use std::collections::BTreeMap;
use std::ops::{Deref, DerefMut};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Constructs a backend for a model name (the pool's miss path).
type Factory = Box<dyn Fn(&str) -> Result<Box<dyn Backend + Send>> + Send + Sync>;

/// A thread-safe pool of idle backends keyed by model identity.
///
/// ```
/// use adapter_serving::runtime::{BackendPool, Manifest};
/// # fn main() -> anyhow::Result<()> {
/// let pool = BackendPool::new(Manifest::default_dir());
/// {
///     let rt = pool.checkout("pico-llama")?; // constructs
///     assert!(rt.meta().d_model > 0);
/// } // drop returns it to the pool
/// let _rt = pool.checkout("pico-llama")?; // reuses the same backend
/// assert_eq!((pool.created(), pool.reused()), (1, 1));
/// # Ok(())
/// # }
/// ```
pub struct BackendPool {
    factory: Factory,
    idle: Mutex<BTreeMap<String, Vec<Box<dyn Backend + Send>>>>,
    created: AtomicUsize,
    reused: AtomicUsize,
}

impl BackendPool {
    /// A pool whose miss path loads backends from `artifacts_dir` via
    /// [`super::load_send_backend`] (the standard selection order minus
    /// the thread-pinned PJRT path).
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> BackendPool {
        let dir = artifacts_dir.into();
        BackendPool::with_factory(Box::new(move |model| super::load_send_backend(&dir, model)))
    }

    /// A pool with an explicit construction function (tests, custom
    /// backends).
    pub fn with_factory(factory: Factory) -> BackendPool {
        BackendPool {
            factory,
            idle: Mutex::new(BTreeMap::new()),
            created: AtomicUsize::new(0),
            reused: AtomicUsize::new(0),
        }
    }

    /// Check a backend for `model` out of the pool, constructing one only
    /// when no idle backend for that model exists.
    pub fn checkout(&self, model: &str) -> Result<PooledBackend<'_>> {
        let idle = self.idle.lock().unwrap().get_mut(model).and_then(Vec::pop);
        let backend = match idle {
            Some(b) => {
                self.reused.fetch_add(1, Ordering::Relaxed);
                b
            }
            None => {
                let b = (self.factory)(model)?;
                self.created.fetch_add(1, Ordering::Relaxed);
                b
            }
        };
        Ok(PooledBackend { pool: self, model: model.to_string(), backend: Some(backend) })
    }

    /// Backends constructed so far (pool misses).
    pub fn created(&self) -> usize {
        self.created.load(Ordering::Relaxed)
    }

    /// Checkouts served by an already-constructed backend (pool hits).
    pub fn reused(&self) -> usize {
        self.reused.load(Ordering::Relaxed)
    }

    /// Idle backends currently checked in, across all models.
    pub fn idle_count(&self) -> usize {
        self.idle.lock().unwrap().values().map(Vec::len).sum()
    }
}

impl std::fmt::Debug for BackendPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BackendPool")
            .field("created", &self.created())
            .field("reused", &self.reused())
            .field("idle", &self.idle_count())
            .finish()
    }
}

/// A checked-out backend; returns itself to the pool on drop.
pub struct PooledBackend<'p> {
    pool: &'p BackendPool,
    model: String,
    backend: Option<Box<dyn Backend + Send>>,
}

impl Deref for PooledBackend<'_> {
    type Target = dyn Backend + Send;

    fn deref(&self) -> &Self::Target {
        self.backend.as_deref().expect("present until drop")
    }
}

impl DerefMut for PooledBackend<'_> {
    fn deref_mut(&mut self) -> &mut Self::Target {
        self.backend.as_deref_mut().expect("present until drop")
    }
}

impl Drop for PooledBackend<'_> {
    fn drop(&mut self) {
        if let Some(b) = self.backend.take() {
            self.pool
                .idle
                .lock()
                .unwrap()
                .entry(std::mem::take(&mut self.model))
                .or_default()
                .push(b);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{load_send_backend, Manifest};

    fn counting_pool() -> BackendPool {
        BackendPool::with_factory(Box::new(|model| {
            load_send_backend(&Manifest::default_dir(), model)
        }))
    }

    #[test]
    fn checkout_reuses_checked_in_backends() {
        let pool = counting_pool();
        {
            let a = pool.checkout("pico-llama").unwrap();
            let b = pool.checkout("pico-llama").unwrap();
            assert!(a.meta().d_model > 0 && b.meta().d_model > 0);
            assert_eq!(pool.created(), 2, "two concurrent checkouts need two backends");
        }
        assert_eq!(pool.idle_count(), 2);
        let _c = pool.checkout("pico-llama").unwrap();
        assert_eq!(pool.created(), 2, "a checked-in backend is reused");
        assert_eq!(pool.reused(), 1);
        assert_eq!(pool.idle_count(), 1);
    }

    #[test]
    fn models_pool_independently() {
        let pool = counting_pool();
        drop(pool.checkout("pico-llama").unwrap());
        let _q = pool.checkout("pico-qwen").unwrap();
        assert_eq!(pool.created(), 2, "different model identity misses the pool");
        assert_eq!(pool.idle_count(), 1, "the llama backend stays idle");
    }

    #[test]
    fn checkout_works_across_worker_threads() {
        let pool = counting_pool();
        // Same shape as the cluster runners: checkout inside scoped
        // worker threads, check-in on drop, reuse on the next wave.
        for _ in 0..3 {
            std::thread::scope(|scope| {
                for _ in 0..2 {
                    scope.spawn(|| {
                        let mut rt = pool.checkout("pico-llama").unwrap();
                        assert!(rt.upload_bank().is_ok());
                    });
                }
            });
        }
        assert!(pool.created() <= 2, "created {} > 2 workers", pool.created());
        assert!(pool.reused() >= 4);
    }

    #[test]
    fn unknown_model_errors_and_pool_stays_clean() {
        let pool = counting_pool();
        assert!(pool.checkout("no-such-model").is_err());
        assert_eq!(pool.created(), 0);
        assert_eq!(pool.idle_count(), 0);
    }
}
