//! PJRT runtime backend (cargo feature `pjrt`): loads the AOT artifacts
//! produced by `python/compile/aot.py` and executes them from the Rust
//! request path.
//!
//! Flow: `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute_b`.
//!
//! Backbone parameters and the adapter bank are *persistent device
//! buffers*; per-step inputs (tokens, KV windows, context lengths, slot
//! indices) are uploaded per call.  Python never runs here.
//!
//! The `xla` dependency resolves to the in-tree `rust/xla-stub` crate by
//! default, which keeps this module type-checked while reporting at
//! runtime that the native PJRT build is not vendored (DESIGN.md §2.3).

use super::manifest::{Manifest, ModelMeta};
use super::{check_decode_args, write_bank_slot_host, Backend, DecodeOut, PrefillOut};
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;
use xla::{FromRawBytes, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

/// A loaded model: compiled executables per bucket plus persistent device
/// state (backbone params, adapter bank).
pub struct PjrtBackend {
    meta: ModelMeta,
    client: PjRtClient,
    /// Backbone parameters, in manifest order, resident on device.
    params: Vec<PjRtBuffer>,
    /// Compiled decode executables keyed by batch bucket (ascending).
    decode: BTreeMap<usize, PjRtLoadedExecutable>,
    /// Compiled prefill executables keyed by sequence bucket (ascending).
    prefill: BTreeMap<usize, PjRtLoadedExecutable>,
    /// Host-side adapter bank (4 tensors, see ModelMeta::bank_dims).
    bank_host: [Vec<f32>; 4],
    /// Device-resident adapter bank.
    bank_dev: Option<[PjRtBuffer; 4]>,
    bank_dirty: bool,
}

impl PjrtBackend {
    /// Load one model from the artifact directory, compiling all buckets.
    pub fn load(artifacts_dir: &Path, model: &str) -> Result<PjrtBackend> {
        let manifest = Manifest::load(artifacts_dir)?;
        Self::load_with_manifest(&manifest, model)
    }

    /// Load one model from an already-parsed manifest.
    pub fn load_with_manifest(manifest: &Manifest, model: &str) -> Result<PjrtBackend> {
        let meta = manifest
            .models
            .get(model)
            .ok_or_else(|| anyhow!("model '{model}' not in manifest"))?
            .clone();
        let client = PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;

        // Backbone params from npz, uploaded once.
        let names: Vec<&str> = meta.param_names.iter().map(|s| s.as_str()).collect();
        let params_path = manifest.dir.join(&meta.params_file);
        let literals = Literal::read_npz_by_name(&params_path, &(), &names)
            .map_err(|e| anyhow!("reading {}: {e}", params_path.display()))?;
        let mut params = Vec::with_capacity(literals.len());
        for lit in &literals {
            params.push(
                client
                    .buffer_from_host_literal(None, lit)
                    .map_err(|e| anyhow!("uploading params: {e}"))?,
            );
        }

        let mut decode = BTreeMap::new();
        for (&b, rel) in &meta.decode_artifacts {
            decode.insert(b, compile_hlo(&client, &manifest.dir.join(rel))?);
        }
        let mut prefill = BTreeMap::new();
        for (&s, rel) in &meta.prefill_artifacts {
            prefill.insert(s, compile_hlo(&client, &manifest.dir.join(rel))?);
        }

        let bank_host = [
            vec![0f32; meta.bank_a_len()],
            vec![0f32; meta.bank_b_len()],
            vec![0f32; meta.bank_a_len()],
            vec![0f32; meta.bank_b_len()],
        ];
        let mut rt = PjrtBackend {
            meta,
            client,
            params,
            decode,
            prefill,
            bank_host,
            bank_dev: None,
            bank_dirty: true,
        };
        rt.upload_bank()?;
        Ok(rt)
    }
}

impl Backend for PjrtBackend {
    fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    /// Smallest compiled decode bucket that fits `batch`.
    fn decode_bucket(&self, batch: usize) -> Option<usize> {
        self.decode.range(batch..).next().map(|(&b, _)| b)
    }

    /// Largest compiled decode bucket (engine batch-size cap).
    fn max_decode_bucket(&self) -> usize {
        self.decode.keys().next_back().copied().unwrap_or(0)
    }

    /// Smallest compiled prefill bucket that fits `len`.
    fn prefill_bucket(&self, len: usize) -> Option<usize> {
        self.prefill.range(len..).next().map(|(&s, _)| s)
    }

    fn max_prefill_bucket(&self) -> usize {
        self.prefill.keys().next_back().copied().unwrap_or(0)
    }

    fn write_bank_slot(
        &mut self,
        slot: usize,
        a_q: &[f32],
        b_q: &[f32],
        a_v: &[f32],
        b_v: &[f32],
    ) -> Result<()> {
        write_bank_slot_host(&mut self.bank_host, &self.meta, slot, a_q, b_q, a_v, b_v)?;
        self.bank_dirty = true;
        Ok(())
    }

    /// Re-upload the host bank to the device if dirty.  Returns true if an
    /// upload actually happened (the engine charges this as swap-in cost).
    fn upload_bank(&mut self) -> Result<bool> {
        if !self.bank_dirty && self.bank_dev.is_some() {
            return Ok(false);
        }
        let m = &self.meta;
        let a_dims = [m.n_layers, m.slots, m.d_model, m.max_rank];
        let b_dims = [m.n_layers, m.slots, m.max_rank, m.d_model];
        let up = |data: &[f32], dims: &[usize]| -> Result<PjRtBuffer> {
            self.client
                .buffer_from_host_buffer(data, dims, None)
                .map_err(|e| anyhow!("bank upload: {e}"))
        };
        self.bank_dev = Some([
            up(&self.bank_host[0], &a_dims)?,
            up(&self.bank_host[1], &b_dims)?,
            up(&self.bank_host[2], &a_dims)?,
            up(&self.bank_host[3], &b_dims)?,
        ]);
        self.bank_dirty = false;
        Ok(true)
    }

    /// Execute one decode step on the bucket that fits `tokens.len()`.
    /// All slices are padded to the chosen bucket by the caller's engine;
    /// this method checks exact arity against the bucket.
    fn decode(
        &mut self,
        bucket: usize,
        tokens: &[i32],
        k_win: &[f32],
        v_win: &[f32],
        ctx: &[i32],
        slot: &[i32],
    ) -> Result<DecodeOut> {
        check_decode_args(&self.meta, bucket, tokens, k_win, v_win, ctx, slot)?;
        let (l, d, w) = (self.meta.n_layers, self.meta.d_model, self.meta.window);
        self.upload_bank()?;
        let exe = self
            .decode
            .get(&bucket)
            .ok_or_else(|| anyhow!("no decode bucket {bucket}"))?;

        let c = &self.client;
        let up_f32 = |data: &[f32], dims: &[usize]| c.buffer_from_host_buffer(data, dims, None);
        let up_i32 = |data: &[i32], dims: &[usize]| c.buffer_from_host_buffer(data, dims, None);
        let dyn_bufs = [
            up_i32(tokens, &[bucket]).map_err(|e| anyhow!("tokens: {e}"))?,
            up_f32(k_win, &[l, bucket, w, d]).map_err(|e| anyhow!("k_win: {e}"))?,
            up_f32(v_win, &[l, bucket, w, d]).map_err(|e| anyhow!("v_win: {e}"))?,
            up_i32(ctx, &[bucket]).map_err(|e| anyhow!("ctx: {e}"))?,
            up_i32(slot, &[bucket]).map_err(|e| anyhow!("slot: {e}"))?,
        ];
        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(self.params.len() + 9);
        args.extend(self.params.iter());
        args.extend(self.bank_dev.as_ref().unwrap().iter());
        args.extend(dyn_bufs.iter());

        let result = exe
            .execute_b(&args)
            .map_err(|e| anyhow!("decode execute: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("decode readback: {e}"))?;
        let (t0, t1, t2) = lit.to_tuple3().map_err(|e| anyhow!("decode tuple: {e}"))?;
        Ok(DecodeOut {
            next_tokens: t0.to_vec::<i32>().map_err(|e| anyhow!("{e}"))?,
            new_k: t1.to_vec::<f32>().map_err(|e| anyhow!("{e}"))?,
            new_v: t2.to_vec::<f32>().map_err(|e| anyhow!("{e}"))?,
        })
    }

    /// Execute a prefill on the bucket that fits `tokens.len()` (already
    /// padded by the caller).
    fn prefill(
        &mut self,
        bucket: usize,
        tokens: &[i32],
        true_len: usize,
        slot: i32,
    ) -> Result<PrefillOut> {
        anyhow::ensure!(tokens.len() == bucket, "tokens len");
        anyhow::ensure!(true_len >= 1 && true_len <= bucket, "true_len");
        self.upload_bank()?;
        let exe = self
            .prefill
            .get(&bucket)
            .ok_or_else(|| anyhow!("no prefill bucket {bucket}"))?;
        let c = &self.client;
        let dyn_bufs = [
            c.buffer_from_host_buffer(tokens, &[bucket], None)
                .map_err(|e| anyhow!("tokens: {e}"))?,
            c.buffer_from_host_buffer(&[true_len as i32], &[], None)
                .map_err(|e| anyhow!("true_len: {e}"))?,
            c.buffer_from_host_buffer(&[slot], &[], None)
                .map_err(|e| anyhow!("slot: {e}"))?,
        ];
        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(self.params.len() + 7);
        args.extend(self.params.iter());
        args.extend(self.bank_dev.as_ref().unwrap().iter());
        args.extend(dyn_bufs.iter());

        let result = exe
            .execute_b(&args)
            .map_err(|e| anyhow!("prefill execute: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("prefill readback: {e}"))?;
        let (t0, t1, t2) = lit.to_tuple3().map_err(|e| anyhow!("prefill tuple: {e}"))?;
        Ok(PrefillOut {
            k: t0.to_vec::<f32>().map_err(|e| anyhow!("{e}"))?,
            v: t1.to_vec::<f32>().map_err(|e| anyhow!("{e}"))?,
            next_token: t2.to_vec::<i32>().map_err(|e| anyhow!("{e}"))?[0],
        })
    }
}

fn compile_hlo(client: &PjRtClient, path: &Path) -> Result<PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .with_context(|| format!("compiling {}", path.display()))
}
