//! Pluggable execution backends for the serving engine.
//!
//! The engine consumes the model through the [`Backend`] trait: persistent
//! backbone/adapter-bank state, bucketed `prefill`/`decode` entry points,
//! and bucket introspection derived from the artifact [`Manifest`].  Two
//! implementations exist:
//!
//! - [`reference`] (default) — a pure-Rust CPU port of the pico model
//!   (`python/compile/model.py` + `kernels/ref.py` semantics: bucketed
//!   execution, persistent param/bank state, greedy sampling).  Zero
//!   external native dependencies; works from a bare checkout.
//! - [`pjrt`] (cargo feature `pjrt`) — the PJRT CPU client executing the
//!   AOT-compiled HLO artifacts produced by `python/compile/aot.py`.
//!
//! Backend selection ([`load_backend`]): the `ADAPTER_SERVING_BACKEND` env
//! var (`reference`/`pjrt`) wins; otherwise PJRT is used when the feature
//! is compiled in and an artifact manifest exists, else the reference
//! backend (from the manifest's config when present, from the built-in
//! pico configs otherwise).
//!
//! The cluster layer does not construct backends directly: it checks them
//! out of a model-keyed [`BackendPool`] ([`pool`]), so repeated
//! validations and epoch horizons reuse loaded model state instead of
//! rebuilding one backend per GPU per call — free for the reference
//! backend, the prerequisite for PJRT compiled-executable reuse.

pub mod manifest;
pub mod pool;
pub mod reference;

#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use manifest::{Manifest, ModelMeta};
pub use pool::{BackendPool, PooledBackend};
pub use reference::ReferenceBackend;

#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;

use anyhow::{anyhow, Result};
use std::path::Path;

/// Outputs of one decode step.
pub struct DecodeOut {
    /// Sampled next token per (padded) batch row.
    pub next_tokens: Vec<i32>,
    /// New key rows, `[L, B, d]` flattened row-major.
    pub new_k: Vec<f32>,
    /// New value rows, `[L, B, d]` flattened row-major.
    pub new_v: Vec<f32>,
}

/// Outputs of one prefill call.
pub struct PrefillOut {
    /// Keys for the prompt, `[L, S, d]` flattened row-major.
    pub k: Vec<f32>,
    /// Values for the prompt, `[L, S, d]` flattened row-major.
    pub v: Vec<f32>,
    /// First generated token.
    pub next_token: i32,
}

/// The execution surface the engine consumes: one loaded model with
/// persistent device state (backbone params, adapter bank) and bucketed
/// prefill/decode execution.
///
/// Implementations are single-GPU by construction; the cluster layer runs
/// one backend instance per simulated GPU (paper §8.1 deployment model).
pub trait Backend {
    /// Static model description (dims, buckets, bank geometry).
    fn meta(&self) -> &ModelMeta;

    /// Write one adapter's (padded) weights into physical slot `slot` of
    /// the host bank.  `a_q`/`b_q`/`a_v`/`b_v` have per-layer shapes
    /// `[d, r]` / `[r, d]` flattened, stacked over layers.
    fn write_bank_slot(
        &mut self,
        slot: usize,
        a_q: &[f32],
        b_q: &[f32],
        a_v: &[f32],
        b_v: &[f32],
    ) -> Result<()>;

    /// Publish host-bank writes to the execution state.  Returns true if
    /// an upload actually happened (the engine charges this as swap-in
    /// cost), false if the bank was already clean.
    fn upload_bank(&mut self) -> Result<bool>;

    /// Execute one decode step on `bucket` (the caller pads the batch).
    fn decode(
        &mut self,
        bucket: usize,
        tokens: &[i32],
        k_win: &[f32],
        v_win: &[f32],
        ctx: &[i32],
        slot: &[i32],
    ) -> Result<DecodeOut>;

    /// Execute one prefill on `bucket` (the caller pads the prompt).
    fn prefill(
        &mut self,
        bucket: usize,
        tokens: &[i32],
        true_len: usize,
        slot: i32,
    ) -> Result<PrefillOut>;

    /// Smallest available decode bucket that fits `batch`.
    fn decode_bucket(&self, batch: usize) -> Option<usize> {
        self.meta().decode_buckets.iter().copied().find(|&b| b >= batch)
    }

    /// Largest available decode bucket (engine batch-size cap).
    fn max_decode_bucket(&self) -> usize {
        self.meta().decode_buckets.last().copied().unwrap_or(0)
    }

    /// Smallest available prefill bucket that fits `len`.
    fn prefill_bucket(&self, len: usize) -> Option<usize> {
        self.meta().prefill_buckets.iter().copied().find(|&s| s >= len)
    }

    /// Largest available prefill bucket (prompt-length cap).
    fn max_prefill_bucket(&self) -> usize {
        self.meta().prefill_buckets.last().copied().unwrap_or(0)
    }
}

/// The validated `ADAPTER_SERVING_BACKEND` request (empty = automatic).
fn requested_backend() -> Result<String> {
    let requested = std::env::var("ADAPTER_SERVING_BACKEND").unwrap_or_default();
    if !matches!(requested.as_str(), "" | "reference" | "pjrt") {
        return Err(anyhow!(
            "unrecognized ADAPTER_SERVING_BACKEND='{requested}' \
             (expected 'reference' or 'pjrt')"
        ));
    }
    Ok(requested)
}

/// The reference backend for `model`, from the manifest's config when one
/// exists and from the built-in pico configs otherwise.
fn load_reference(artifacts_dir: &Path, model: &str) -> Result<ReferenceBackend> {
    let meta = if artifacts_dir.join("manifest.json").exists() {
        let manifest = Manifest::load(artifacts_dir)?;
        manifest
            .models
            .get(model)
            .cloned()
            .ok_or_else(|| anyhow!("model '{model}' not in manifest"))?
    } else {
        ModelMeta::builtin(model).ok_or_else(|| {
            anyhow!(
                "model '{model}' has no built-in config and no artifact \
                 manifest exists at {}",
                artifacts_dir.display()
            )
        })?
    };
    ReferenceBackend::try_new(meta)
}

/// Load the backend for `model`, honoring `ADAPTER_SERVING_BACKEND`.
/// See the module docs for the selection order.
pub fn load_backend(artifacts_dir: &Path, model: &str) -> Result<Box<dyn Backend>> {
    let requested = requested_backend()?;

    #[cfg(feature = "pjrt")]
    {
        if requested != "reference" && artifacts_dir.join("manifest.json").exists() {
            return Ok(Box::new(PjrtBackend::load(artifacts_dir, model)?));
        }
    }
    if requested == "pjrt" {
        return Err(anyhow!(
            "ADAPTER_SERVING_BACKEND=pjrt needs a build with `--features pjrt` \
             and an artifact manifest in {}",
            artifacts_dir.display()
        ));
    }

    Ok(Box::new(load_reference(artifacts_dir, model)?))
}

/// [`load_backend`] for contexts that keep backends alive across worker
/// threads — the [`BackendPool`]'s factory.  Only `Send` backends
/// qualify: the reference backend is plain host memory and moves freely,
/// while PJRT device handles are pinned to the thread that created them.
/// Any selection that would resolve to PJRT — an explicit
/// `ADAPTER_SERVING_BACKEND=pjrt`, or automatic selection on a
/// `pjrt`-feature build with a manifest present — is therefore an error
/// here rather than a silent fallback to a different backend than
/// [`load_backend`] would pick; set `ADAPTER_SERVING_BACKEND=reference`
/// to pool the reference backend explicitly (pooled PJRT needs the
/// compiled-executable cache — see ROADMAP).
pub fn load_send_backend(artifacts_dir: &Path, model: &str) -> Result<Box<dyn Backend + Send>> {
    let requested = requested_backend()?;
    if requested == "pjrt" {
        return Err(anyhow!(
            "ADAPTER_SERVING_BACKEND=pjrt cannot serve a backend pool: PJRT \
             handles are pinned to their creating thread (unset the override \
             or use the per-thread factory path)"
        ));
    }
    #[cfg(feature = "pjrt")]
    {
        if requested.is_empty() && artifacts_dir.join("manifest.json").exists() {
            return Err(anyhow!(
                "automatic backend selection would pick PJRT here (manifest in {}), \
                 but pooled execution needs Send backends; set \
                 ADAPTER_SERVING_BACKEND=reference to pool the reference backend",
                artifacts_dir.display()
            ));
        }
    }
    Ok(Box::new(load_reference(artifacts_dir, model)?))
}

/// Shared host-bank slot write (layout `[L, S, d, r]` / `[L, S, r, d]`;
/// the slab for `(layer, slot)` is contiguous).  Used by every backend.
pub(crate) fn write_bank_slot_host(
    bank: &mut [Vec<f32>; 4],
    meta: &ModelMeta,
    slot: usize,
    a_q: &[f32],
    b_q: &[f32],
    a_v: &[f32],
    b_v: &[f32],
) -> Result<()> {
    anyhow::ensure!(slot < meta.slots, "slot {slot} out of range ({})", meta.slots);
    let a_layer = meta.d_model * meta.max_rank;
    let b_layer = meta.max_rank * meta.d_model;
    anyhow::ensure!(a_q.len() == meta.n_layers * a_layer, "a_q size");
    anyhow::ensure!(b_q.len() == meta.n_layers * b_layer, "b_q size");
    anyhow::ensure!(a_v.len() == meta.n_layers * a_layer, "a_v size");
    anyhow::ensure!(b_v.len() == meta.n_layers * b_layer, "b_v size");
    for l in 0..meta.n_layers {
        let a_off = (l * meta.slots + slot) * a_layer;
        let b_off = (l * meta.slots + slot) * b_layer;
        bank[0][a_off..a_off + a_layer].copy_from_slice(&a_q[l * a_layer..(l + 1) * a_layer]);
        bank[1][b_off..b_off + b_layer].copy_from_slice(&b_q[l * b_layer..(l + 1) * b_layer]);
        bank[2][a_off..a_off + a_layer].copy_from_slice(&a_v[l * a_layer..(l + 1) * a_layer]);
        bank[3][b_off..b_off + b_layer].copy_from_slice(&b_v[l * b_layer..(l + 1) * b_layer]);
    }
    Ok(())
}

/// Arity checks shared by the backends' `decode` implementations.
pub(crate) fn check_decode_args(
    meta: &ModelMeta,
    bucket: usize,
    tokens: &[i32],
    k_win: &[f32],
    v_win: &[f32],
    ctx: &[i32],
    slot: &[i32],
) -> Result<()> {
    let (l, d, w) = (meta.n_layers, meta.d_model, meta.window);
    anyhow::ensure!(tokens.len() == bucket, "tokens len");
    anyhow::ensure!(ctx.len() == bucket && slot.len() == bucket, "ctx/slot len");
    anyhow::ensure!(k_win.len() == l * bucket * w * d, "k_win len");
    anyhow::ensure!(v_win.len() == l * bucket * w * d, "v_win len");
    Ok(())
}
