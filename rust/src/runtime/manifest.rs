//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime (model dims, bucket lists, artifact paths, input ordering).

use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Parsed per-model manifest entry.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    /// Model name (manifest key, e.g. "pico-llama").
    pub name: String,
    /// Embedding width.
    pub d_model: usize,
    /// Transformer layer count.
    pub n_layers: usize,
    /// Attention heads.
    pub n_heads: usize,
    /// Per-head dimension.
    pub head_dim: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// Sliding attention window (tokens).
    pub window: usize,
    /// Physical adapter bank slots (slot 0 = zero adapter).
    pub slots: usize,
    /// Maximum LoRA rank the bank is padded for.
    pub max_rank: usize,
    /// Hidden width of the MLP block (config.mlp_dim; defaults to 4·d).
    pub mlp_dim: usize,
    /// Backbone init seed (the reference backend synthesizes its own
    /// deterministic weights from this when no params file is present).
    pub seed: u64,
    /// Compiled decode batch buckets, ascending.
    pub decode_buckets: Vec<usize>,
    /// Compiled prefill (padded prompt) buckets, ascending.
    pub prefill_buckets: Vec<usize>,
    /// Deterministic parameter order (matches `python/compile/model.py`).
    pub param_names: Vec<String>,
    /// Path of the `.params.npz` weights file (empty for built-ins).
    pub params_file: String,
    /// Decode HLO artifact path per bucket.
    pub decode_artifacts: BTreeMap<usize, String>,
    /// Prefill HLO artifact path per bucket.
    pub prefill_artifacts: BTreeMap<usize, String>,
    /// Whether the artifacts were compiled with the Pallas kernels.
    pub use_pallas: bool,
}

impl ModelMeta {
    /// Built-in configurations mirroring `python/compile/config.py`, so the
    /// reference backend serves the pico models from a bare checkout (no
    /// `make artifacts` required).  Returns `None` for unknown model names.
    pub fn builtin(name: &str) -> Option<ModelMeta> {
        let (d_model, n_heads, seed) = match name {
            "pico-llama" => (128usize, 4usize, 1234u64),
            "pico-qwen" => (160, 5, 4321),
            _ => return None,
        };
        let n_layers = 2;
        Some(ModelMeta {
            name: name.to_string(),
            d_model,
            n_layers,
            n_heads,
            head_dim: 32,
            vocab: 512,
            window: 128,
            slots: 64,
            max_rank: 32,
            mlp_dim: 4 * d_model,
            seed,
            decode_buckets: vec![1, 2, 4, 8, 16, 32, 64],
            prefill_buckets: vec![32, 64, 128, 256],
            param_names: Self::default_param_names(n_layers),
            params_file: String::new(),
            decode_artifacts: BTreeMap::new(),
            prefill_artifacts: BTreeMap::new(),
            use_pallas: false,
        })
    }

    /// The deterministic parameter order of `python/compile/model.py`.
    pub fn default_param_names(n_layers: usize) -> Vec<String> {
        let mut names = vec!["embed".to_string()];
        for l in 0..n_layers {
            for suffix in ["ln1", "wq", "wk", "wv", "wo", "ln2", "w_up", "w_down"] {
                names.push(format!("l{l}.{suffix}"));
            }
        }
        names.push("final_ln".to_string());
        names
    }

    /// Elements of one A-bank tensor `[L, S, d, r]`.
    pub fn bank_a_len(&self) -> usize {
        self.n_layers * self.slots * self.d_model * self.max_rank
    }

    /// Elements of one B-bank tensor `[L, S, r, d]`.
    pub fn bank_b_len(&self) -> usize {
        self.bank_a_len()
    }

    /// Host KV bytes per token (both k and v, all layers) — real bytes, as
    /// opposed to the simulated-GPU token ledger.
    pub fn kv_f32_per_token(&self) -> usize {
        2 * self.n_layers * self.d_model
    }

    /// Parse one manifest entry (public so fixture-driven tests can build
    /// backend configurations from manifest-shaped JSON).
    pub fn from_json(name: &str, j: &Json) -> Result<ModelMeta> {
        let cfg = j.req("config")?;
        let get = |k: &str| -> Result<usize> {
            cfg.req(k)?
                .as_usize()
                .ok_or_else(|| anyhow!("config.{k} not a number"))
        };
        let d_model = get("d_model")?;
        let artifacts = |key: &str| -> Result<BTreeMap<usize, String>> {
            let obj = j
                .req(key)?
                .as_obj()
                .ok_or_else(|| anyhow!("{key} not an object"))?;
            let mut out = BTreeMap::new();
            for (k, v) in obj {
                out.insert(
                    k.parse::<usize>().map_err(|_| anyhow!("bad bucket {k}"))?,
                    v.as_str().ok_or_else(|| anyhow!("bad path"))?.to_string(),
                );
            }
            Ok(out)
        };
        Ok(ModelMeta {
            name: name.to_string(),
            d_model,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            head_dim: get("head_dim")?,
            vocab: get("vocab")?,
            window: get("window")?,
            slots: get("slots")?,
            max_rank: get("max_rank")?,
            mlp_dim: cfg.get("mlp_dim").and_then(Json::as_usize).unwrap_or(4 * d_model),
            seed: cfg.get("seed").and_then(Json::as_usize).unwrap_or(0) as u64,
            decode_buckets: cfg
                .req("decode_buckets")?
                .usize_vec()
                .ok_or_else(|| anyhow!("decode_buckets"))?,
            prefill_buckets: cfg
                .req("prefill_buckets")?
                .usize_vec()
                .ok_or_else(|| anyhow!("prefill_buckets"))?,
            param_names: j
                .req("param_names")?
                .as_arr()
                .ok_or_else(|| anyhow!("param_names"))?
                .iter()
                .map(|v| v.as_str().unwrap_or_default().to_string())
                .collect(),
            params_file: j
                .req("params_file")?
                .as_str()
                .ok_or_else(|| anyhow!("params_file"))?
                .to_string(),
            decode_artifacts: artifacts("decode")?,
            prefill_artifacts: artifacts("prefill")?,
            use_pallas: j.get("use_pallas").and_then(Json::as_bool).unwrap_or(true),
        })
    }
}

/// The whole artifact directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// The directory `manifest.json` was loaded from.
    pub dir: PathBuf,
    /// Per-model entries, keyed by model name.
    pub models: BTreeMap<String, ModelMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let j = Json::read_file(&dir.join("manifest.json"))?;
        let models_j = j
            .req("models")?
            .as_obj()
            .ok_or_else(|| anyhow!("models not an object"))?;
        let mut models = BTreeMap::new();
        for (name, entry) in models_j {
            models.insert(name.clone(), ModelMeta::from_json(name, entry)?);
        }
        Ok(Manifest { dir: dir.to_path_buf(), models })
    }

    /// Default artifact dir: `$ADAPTER_SERVING_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("ADAPTER_SERVING_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn example_entry() -> Json {
        Json::parse(
            r#"{
              "config": {"d_model": 128, "n_layers": 2, "n_heads": 4,
                         "head_dim": 32, "vocab": 512, "window": 128,
                         "slots": 64, "max_rank": 32,
                         "decode_buckets": [1, 2], "prefill_buckets": [32]},
              "param_names": ["embed", "final_ln"],
              "params_file": "m.params.npz",
              "decode": {"1": "d1.hlo.txt", "2": "d2.hlo.txt"},
              "prefill": {"32": "p32.hlo.txt"},
              "use_pallas": true
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_model_meta() {
        let m = ModelMeta::from_json("pico", &example_entry()).unwrap();
        assert_eq!(m.d_model, 128);
        assert_eq!(m.decode_artifacts[&2], "d2.hlo.txt");
        assert_eq!(m.bank_a_len(), 2 * 64 * 128 * 32);
        assert_eq!(m.kv_f32_per_token(), 2 * 2 * 128);
    }

    #[test]
    fn mlp_dim_defaults_to_four_d() {
        let m = ModelMeta::from_json("pico", &example_entry()).unwrap();
        assert_eq!(m.mlp_dim, 4 * 128);
    }

    #[test]
    fn builtin_matches_python_config() {
        let m = ModelMeta::builtin("pico-llama").unwrap();
        assert_eq!((m.d_model, m.n_heads, m.seed), (128, 4, 1234));
        assert_eq!(m.param_names.len(), 2 + 8 * m.n_layers);
        assert_eq!(m.param_names[0], "embed");
        assert_eq!(m.param_names.last().unwrap(), "final_ln");
        let q = ModelMeta::builtin("pico-qwen").unwrap();
        assert_eq!((q.d_model, q.n_heads), (160, 5));
        assert!(ModelMeta::builtin("nope").is_none());
    }

    #[test]
    fn missing_key_is_error() {
        let mut j = example_entry();
        if let Json::Obj(m) = &mut j {
            m.remove("params_file");
        }
        assert!(ModelMeta::from_json("pico", &j).is_err());
    }
}
