//! The default pure-Rust execution backend: a CPU port of the pico
//! transformer (`python/compile/model.py`) with the pure-jnp kernel
//! semantics of `python/compile/kernels/ref.py`.
//!
//! Semantics mirrored exactly (conformance-tested against JAX-generated
//! fixtures in `rust/tests/backend_conformance.rs`):
//!
//! - bucketed execution: decode/prefill always compute the full padded
//!   bucket, so latency scales with the bucket (CUDA-graph style), which
//!   is what the Digital Twin's `K4·B + K5·bucket` model calibrates to;
//! - persistent state: backbone params and the `[L, S, d, r]` adapter bank
//!   live in the backend across calls; slot 0 is the all-zero adapter;
//! - per-request LoRA on the q and v projections via the gathered low-rank
//!   product (`sgmv_ref`), sliding-window masked attention for decode,
//!   causal+valid masked attention for prefill, greedy (argmax) sampling
//!   with first-index tie-breaking like `jnp.argmax`.
//!
//! Backbone weights are synthesized deterministically from the manifest
//! seed (serving dynamics never depend on weight values, only on compute
//! shape); [`ReferenceBackend::with_params`] accepts explicit weights for
//! conformance testing.

use super::manifest::ModelMeta;
use super::{check_decode_args, write_bank_slot_host, Backend, DecodeOut, PrefillOut};
use crate::util::rng::Rng;
use anyhow::Result;

const EPS: f32 = 1e-6;

/// Per-call scratch buffers for the residual/MLP half of a layer.
struct Scratch {
    proj: Vec<f32>,
    x2: Vec<f32>,
    up: Vec<f32>,
    down: Vec<f32>,
}

impl Scratch {
    fn new(d: usize, m: usize) -> Scratch {
        Scratch { proj: vec![0f32; d], x2: vec![0f32; d], up: vec![0f32; m], down: vec![0f32; d] }
    }
}

/// Pure-Rust model state.
pub struct ReferenceBackend {
    meta: ModelMeta,
    /// Backbone parameters, flattened row-major, in manifest order.
    params: Vec<Vec<f32>>,
    /// Adapter bank `[a_q, b_q, a_v, b_v]` with layouts
    /// `[L, S, d, r]` / `[L, S, r, d]`.
    bank: [Vec<f32>; 4],
    bank_dirty: bool,
}

impl ReferenceBackend {
    /// Build the backend with synthesized deterministic weights.  Panics
    /// on an internally inconsistent meta; callers handling untrusted
    /// manifests use [`ReferenceBackend::try_new`].
    pub fn new(meta: ModelMeta) -> ReferenceBackend {
        Self::try_new(meta).expect("model meta is internally consistent")
    }

    /// Fallible [`ReferenceBackend::new`]: returns Err for metas whose
    /// dimensions are inconsistent (e.g. `d_model != n_heads * head_dim`).
    pub fn try_new(meta: ModelMeta) -> Result<ReferenceBackend> {
        let params = synth_params(&meta);
        Self::with_params(meta, params)
    }

    /// Build the backend from explicit parameters in manifest order
    /// (`embed`, per-layer `ln1,wq,wk,wv,wo,ln2,w_up,w_down`, `final_ln`).
    pub fn with_params(meta: ModelMeta, params: Vec<Vec<f32>>) -> Result<ReferenceBackend> {
        let (d, m, v, nl) = (meta.d_model, meta.mlp_dim, meta.vocab, meta.n_layers);
        anyhow::ensure!(params.len() == 2 + 8 * nl, "expected {} param tensors", 2 + 8 * nl);
        anyhow::ensure!(params[0].len() == v * d, "embed shape");
        anyhow::ensure!(params[1 + 8 * nl].len() == d, "final_ln shape");
        for l in 0..nl {
            let base = 1 + 8 * l;
            let want = [d, d * d, d * d, d * d, d * d, d, d * m, m * d];
            for (i, &len) in want.iter().enumerate() {
                anyhow::ensure!(params[base + i].len() == len, "layer {l} tensor {i} shape");
            }
        }
        anyhow::ensure!(d == meta.n_heads * meta.head_dim, "d_model != n_heads*head_dim");
        let bank = [
            vec![0f32; meta.bank_a_len()],
            vec![0f32; meta.bank_b_len()],
            vec![0f32; meta.bank_a_len()],
            vec![0f32; meta.bank_b_len()],
        ];
        Ok(ReferenceBackend { meta, params, bank, bank_dirty: true })
    }

    fn embed(&self) -> &[f32] {
        &self.params[0]
    }

    fn final_ln(&self) -> &[f32] {
        &self.params[1 + 8 * self.meta.n_layers]
    }

    /// Per-layer tensor accessor; `which` indexes
    /// ln1, wq, wk, wv, wo, ln2, w_up, w_down.
    fn layer(&self, l: usize, which: usize) -> &[f32] {
        &self.params[1 + 8 * l + which]
    }

    /// LoRA slab for `(kind, layer, slot)` where kind indexes
    /// a_q, b_q, a_v, b_v.
    fn bank_slab(&self, kind: usize, l: usize, slot: usize) -> &[f32] {
        let per = self.meta.d_model * self.meta.max_rank;
        let off = (l * self.meta.slots + slot) * per;
        &self.bank[kind][off..off + per]
    }

    /// Projection half of one transformer layer: per-row RMS-norm and
    /// q/k/v projections (q and v with the row's LoRA slab) into the
    /// `*_all` buffers.  The attention + residual/MLP half runs per row in
    /// the caller, which owns the window layout.
    #[allow(clippy::too_many_arguments)]
    fn run_layer(
        &self,
        l: usize,
        slot_of_row: &dyn Fn(usize) -> usize,
        h: &[f32],
        rows: usize,
        q_all: &mut [f32],
        k_all: &mut [f32],
        v_all: &mut [f32],
    ) {
        let (d, r) = (self.meta.d_model, self.meta.max_rank);
        let mut x = vec![0f32; d];
        for row in 0..rows {
            let hb = &h[row * d..(row + 1) * d];
            rms_norm(hb, self.layer(l, 0), &mut x);
            let s = slot_of_row(row);
            let q = &mut q_all[row * d..(row + 1) * d];
            matvec(&x, self.layer(l, 1), d, d, q);
            sgmv_ref(&x, self.bank_slab(0, l, s), self.bank_slab(1, l, s), d, r, q);
            let k = &mut k_all[row * d..(row + 1) * d];
            matvec(&x, self.layer(l, 2), d, d, k);
            let v = &mut v_all[row * d..(row + 1) * d];
            matvec(&x, self.layer(l, 3), d, d, v);
            sgmv_ref(&x, self.bank_slab(2, l, s), self.bank_slab(3, l, s), d, r, v);
        }
    }

    /// Residual attention-output + MLP half of a layer for one row.
    /// `s` is caller-owned scratch: this runs inside the timed hot loop
    /// the virtual clock charges, so it must not hit the allocator.
    fn finish_row(&self, l: usize, attn: &[f32], h: &mut [f32], s: &mut Scratch) {
        let (d, m) = (self.meta.d_model, self.meta.mlp_dim);
        matvec(attn, self.layer(l, 4), d, d, &mut s.proj);
        for (hi, pi) in h.iter_mut().zip(&s.proj) {
            *hi += pi;
        }
        rms_norm(h, self.layer(l, 5), &mut s.x2);
        matvec(&s.x2, self.layer(l, 6), d, m, &mut s.up);
        for u in s.up.iter_mut() {
            *u = silu(*u);
        }
        matvec(&s.up, self.layer(l, 7), m, d, &mut s.down);
        for (hi, di) in h.iter_mut().zip(&s.down) {
            *hi += di;
        }
    }

    /// Greedy sampling: argmax over tied-embedding logits, first max wins
    /// (matching `jnp.argmax`).
    fn sample(&self, h: &[f32]) -> i32 {
        let (d, v) = (self.meta.d_model, self.meta.vocab);
        let mut x = vec![0f32; d];
        rms_norm(h, self.final_ln(), &mut x);
        let embed = self.embed();
        let mut best = f32::NEG_INFINITY;
        let mut arg = 0usize;
        for t in 0..v {
            let row = &embed[t * d..(t + 1) * d];
            let logit: f32 = x.iter().zip(row).map(|(a, b)| a * b).sum();
            if logit > best {
                best = logit;
                arg = t;
            }
        }
        arg as i32
    }
}

impl Backend for ReferenceBackend {
    fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    fn write_bank_slot(
        &mut self,
        slot: usize,
        a_q: &[f32],
        b_q: &[f32],
        a_v: &[f32],
        b_v: &[f32],
    ) -> Result<()> {
        write_bank_slot_host(&mut self.bank, &self.meta, slot, a_q, b_q, a_v, b_v)?;
        self.bank_dirty = true;
        Ok(())
    }

    fn upload_bank(&mut self) -> Result<bool> {
        // The host bank *is* the execution state; "upload" just tracks the
        // dirty bit so the engine's swap-in accounting stays meaningful.
        let uploaded = self.bank_dirty;
        self.bank_dirty = false;
        Ok(uploaded)
    }

    fn decode(
        &mut self,
        bucket: usize,
        tokens: &[i32],
        k_win: &[f32],
        v_win: &[f32],
        ctx: &[i32],
        slot: &[i32],
    ) -> Result<DecodeOut> {
        let meta = &self.meta;
        check_decode_args(meta, bucket, tokens, k_win, v_win, ctx, slot)?;
        let (nl, d, w) = (meta.n_layers, meta.d_model, meta.window);
        let (nh, dh) = (meta.n_heads, meta.head_dim);
        for row in 0..bucket {
            anyhow::ensure!(
                (0..meta.vocab as i32).contains(&tokens[row]),
                "token out of vocab"
            );
            anyhow::ensure!((0..meta.slots as i32).contains(&slot[row]), "slot out of range");
            anyhow::ensure!((0..w as i32).contains(&ctx[row]), "ctx out of window");
        }

        let embed = self.embed();
        let mut h = vec![0f32; bucket * d];
        for row in 0..bucket {
            let t = tokens[row] as usize;
            h[row * d..(row + 1) * d].copy_from_slice(&embed[t * d..(t + 1) * d]);
        }

        let mut new_k = vec![0f32; nl * bucket * d];
        let mut new_v = vec![0f32; nl * bucket * d];
        let mut q_all = vec![0f32; bucket * d];
        let mut k_all = vec![0f32; bucket * d];
        let mut v_all = vec![0f32; bucket * d];
        let mut win_k = vec![0f32; w * d];
        let mut win_v = vec![0f32; w * d];
        let mut attn = vec![0f32; d];
        let mut scratch = Scratch::new(d, meta.mlp_dim);

        for l in 0..nl {
            let slot_of = |row: usize| slot[row] as usize;
            self.run_layer(l, &slot_of, &h, bucket, &mut q_all, &mut k_all, &mut v_all);
            for row in 0..bucket {
                let n = ctx[row] as usize;
                // Window = the n cached rows followed by this step's K/V
                // (model.py `_insert_row` at position ctx, attend ctx+1).
                let src = (l * bucket + row) * w * d;
                win_k[..n * d].copy_from_slice(&k_win[src..src + n * d]);
                win_v[..n * d].copy_from_slice(&v_win[src..src + n * d]);
                let k_new = &k_all[row * d..(row + 1) * d];
                let v_new = &v_all[row * d..(row + 1) * d];
                win_k[n * d..(n + 1) * d].copy_from_slice(k_new);
                win_v[n * d..(n + 1) * d].copy_from_slice(v_new);
                attention_ref(
                    &q_all[row * d..(row + 1) * d],
                    &win_k[..(n + 1) * d],
                    &win_v[..(n + 1) * d],
                    nh,
                    dh,
                    n + 1,
                    &mut attn,
                );
                self.finish_row(l, &attn, &mut h[row * d..(row + 1) * d], &mut scratch);
                let out = (l * bucket + row) * d;
                new_k[out..out + d].copy_from_slice(k_new);
                new_v[out..out + d].copy_from_slice(v_new);
            }
        }

        let next_tokens: Vec<i32> =
            (0..bucket).map(|row| self.sample(&h[row * d..(row + 1) * d])).collect();
        Ok(DecodeOut { next_tokens, new_k, new_v })
    }

    fn prefill(
        &mut self,
        bucket: usize,
        tokens: &[i32],
        true_len: usize,
        slot: i32,
    ) -> Result<PrefillOut> {
        let meta = &self.meta;
        anyhow::ensure!(tokens.len() == bucket, "tokens len");
        anyhow::ensure!(true_len >= 1 && true_len <= bucket, "true_len");
        anyhow::ensure!((0..meta.slots as i32).contains(&slot), "slot out of range");
        for &t in tokens {
            anyhow::ensure!((0..meta.vocab as i32).contains(&t), "token out of vocab");
        }
        let (nl, d) = (meta.n_layers, meta.d_model);
        let (nh, dh) = (meta.n_heads, meta.head_dim);
        let s = bucket;

        let embed = self.embed();
        let mut h = vec![0f32; s * d];
        for (row, &t) in tokens.iter().enumerate() {
            h[row * d..(row + 1) * d].copy_from_slice(&embed[t as usize * d..(t as usize + 1) * d]);
        }

        let mut out_k = vec![0f32; nl * s * d];
        let mut out_v = vec![0f32; nl * s * d];
        let mut q_all = vec![0f32; s * d];
        let mut k_all = vec![0f32; s * d];
        let mut v_all = vec![0f32; s * d];
        let mut attn = vec![0f32; d];
        let mut scratch = Scratch::new(d, meta.mlp_dim);

        for l in 0..nl {
            let slot_of = |_row: usize| slot as usize;
            self.run_layer(l, &slot_of, &h, s, &mut q_all, &mut k_all, &mut v_all);
            for row in 0..s {
                // Causal & valid mask: keys j with j <= row and j < true_len.
                // true_len >= 1 guarantees at least one valid key per row.
                let n = (row + 1).min(true_len);
                attention_ref(
                    &q_all[row * d..(row + 1) * d],
                    &k_all[..n * d],
                    &v_all[..n * d],
                    nh,
                    dh,
                    n,
                    &mut attn,
                );
                self.finish_row(l, &attn, &mut h[row * d..(row + 1) * d], &mut scratch);
            }
            let base = l * s * d;
            out_k[base..base + s * d].copy_from_slice(&k_all);
            out_v[base..base + s * d].copy_from_slice(&v_all);
        }

        let last = true_len - 1;
        let next_token = self.sample(&h[last * d..(last + 1) * d]);
        Ok(PrefillOut { k: out_k, v: out_v, next_token })
    }
}

// ----------------------------------------------------------------------
// Kernel oracles (ports of python/compile/kernels/ref.py; public so the
// conformance tests can exercise them against JAX-generated fixtures)
// ----------------------------------------------------------------------

/// `out += (x · a) · b` for one row: the per-row gathered low-rank product
/// of `kernels.ref.sgmv_ref`.  `a` is `[d, r]`, `b` is `[r, d]`, flattened.
pub fn sgmv_ref(x: &[f32], a: &[f32], b: &[f32], d: usize, r: usize, out: &mut [f32]) {
    let mut t = vec![0f32; r];
    for i in 0..d {
        let xi = x[i];
        if xi != 0.0 {
            let row = &a[i * r..(i + 1) * r];
            for (tj, aj) in t.iter_mut().zip(row) {
                *tj += xi * aj;
            }
        }
    }
    for (j, &tj) in t.iter().enumerate() {
        if tj != 0.0 {
            let row = &b[j * d..(j + 1) * d];
            for (oi, bi) in out.iter_mut().zip(row) {
                *oi += tj * bi;
            }
        }
    }
}

/// Masked softmax attention for one query row over `n` valid window
/// entries: the semantics of `kernels.ref.decode_attention_ref`.  `q` is
/// `[n_heads*head_dim]`; `win_k`/`win_v` hold `n` contiguous rows of the
/// same layout; `out` (same length as `q`) is overwritten.
pub fn attention_ref(
    q: &[f32],
    win_k: &[f32],
    win_v: &[f32],
    n_heads: usize,
    head_dim: usize,
    n: usize,
    out: &mut [f32],
) {
    let d = n_heads * head_dim;
    debug_assert!(win_k.len() >= n * d && win_v.len() >= n * d);
    let scale = 1.0 / (head_dim as f32).sqrt();
    let mut scores = vec![0f32; n];
    for hh in 0..n_heads {
        let q_h = &q[hh * head_dim..(hh + 1) * head_dim];
        let mut max = f32::NEG_INFINITY;
        for (j, sj) in scores.iter_mut().enumerate() {
            let k_h = &win_k[j * d + hh * head_dim..j * d + (hh + 1) * head_dim];
            let dot: f32 = q_h.iter().zip(k_h).map(|(a, b)| a * b).sum();
            *sj = dot * scale;
            if *sj > max {
                max = *sj;
            }
        }
        let mut denom = 0f32;
        for sj in scores.iter_mut() {
            *sj = (*sj - max).exp();
            denom += *sj;
        }
        let o = &mut out[hh * head_dim..(hh + 1) * head_dim];
        o.fill(0.0);
        for (j, &p) in scores.iter().enumerate() {
            let wgt = p / denom;
            let v_h = &win_v[j * d + hh * head_dim..j * d + (hh + 1) * head_dim];
            for (oi, vi) in o.iter_mut().zip(v_h) {
                *oi += wgt * vi;
            }
        }
    }
}

/// `out = x · w` with `w` row-major `[d_in, d_out]` (overwrites `out`).
fn matvec(x: &[f32], w: &[f32], d_in: usize, d_out: usize, out: &mut [f32]) {
    out.fill(0.0);
    for i in 0..d_in {
        let xi = x[i];
        if xi != 0.0 {
            let row = &w[i * d_out..(i + 1) * d_out];
            for (oj, wj) in out.iter_mut().zip(row) {
                *oj += xi * wj;
            }
        }
    }
}

/// RMS norm: `out = x * w / sqrt(mean(x^2) + eps)` (model.py `_rms_norm`).
fn rms_norm(x: &[f32], w: &[f32], out: &mut [f32]) {
    let mean: f32 = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (mean + EPS).sqrt();
    for ((o, &xi), &wi) in out.iter_mut().zip(x).zip(w) {
        *o = xi * wi * inv;
    }
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Deterministic backbone weights in manifest parameter order: norm
/// weights are ones, projection matrices N(0, 0.05) from the model seed
/// (weight *values* never affect serving dynamics, only compute shape).
fn synth_params(meta: &ModelMeta) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(meta.seed ^ name_seed(&meta.name) ^ 0x5EED_BACC);
    let (d, m, v) = (meta.d_model, meta.mlp_dim, meta.vocab);
    let mut normal = |n: usize| -> Vec<f32> {
        (0..n).map(|_| (rng.normal() * 0.05) as f32).collect()
    };
    let mut out = vec![normal(v * d)];
    for _ in 0..meta.n_layers {
        out.push(vec![1f32; d]); // ln1
        out.push(normal(d * d)); // wq
        out.push(normal(d * d)); // wk
        out.push(normal(d * d)); // wv
        out.push(normal(d * d)); // wo
        out.push(vec![1f32; d]); // ln2
        out.push(normal(d * m)); // w_up
        out.push(normal(m * d)); // w_down
    }
    out.push(vec![1f32; d]); // final_ln
    out
}

fn name_seed(name: &str) -> u64 {
    // FNV-1a over the model name, so pico-llama and pico-qwen get
    // independent weight streams even with equal manifest seeds.
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1_0000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_meta() -> ModelMeta {
        let mut m = ModelMeta::builtin("pico-llama").unwrap();
        m.d_model = 32;
        m.n_heads = 2;
        m.head_dim = 16;
        m.vocab = 64;
        m.window = 16;
        m.slots = 4;
        m.max_rank = 4;
        m.mlp_dim = 64;
        m.decode_buckets = vec![1, 2, 4];
        m.prefill_buckets = vec![8, 16];
        m
    }

    fn adapter_slab(meta: &ModelMeta, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let a: Vec<f32> = (0..meta.n_layers * meta.d_model * meta.max_rank)
            .map(|_| (rng.normal() * 0.02) as f32)
            .collect();
        let b: Vec<f32> = (0..meta.n_layers * meta.max_rank * meta.d_model)
            .map(|_| (rng.normal() * 0.02) as f32)
            .collect();
        (a, b)
    }

    #[test]
    fn decode_is_deterministic_and_shaped() {
        let meta = tiny_meta();
        let mut rt = ReferenceBackend::new(meta.clone());
        let b = 2usize;
        let n = meta.n_layers * b * meta.window * meta.d_model;
        let k = vec![0.01f32; n];
        let v = vec![0.02f32; n];
        let o1 = rt.decode(b, &[3, 5], &k, &v, &[4, 4], &[0, 0]).unwrap();
        let o2 = rt.decode(b, &[3, 5], &k, &v, &[4, 4], &[0, 0]).unwrap();
        assert_eq!(o1.next_tokens, o2.next_tokens);
        assert_eq!(o1.new_k, o2.new_k);
        assert_eq!(o1.new_k.len(), meta.n_layers * b * meta.d_model);
        assert!(o1.next_tokens.iter().all(|&t| (0..meta.vocab as i32).contains(&t)));
        assert!(o1.new_k.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn zero_slot_equals_backbone_only() {
        // Writing an adapter into slot 1 must not change slot-0 rows.
        let meta = tiny_meta();
        let mut rt = ReferenceBackend::new(meta.clone());
        let n = meta.n_layers * 2 * meta.window * meta.d_model;
        let (k, v) = (vec![0.01f32; n], vec![0.02f32; n]);
        let before = rt.decode(2, &[7, 7], &k, &v, &[3, 3], &[0, 0]).unwrap();
        let (a, b) = adapter_slab(&meta, 9);
        rt.write_bank_slot(1, &a, &b, &a, &b).unwrap();
        rt.upload_bank().unwrap();
        let after = rt.decode(2, &[7, 7], &k, &v, &[3, 3], &[0, 1]).unwrap();
        // Row 0 still on the zero adapter: bit-identical.
        assert_eq!(before.next_tokens[0], after.next_tokens[0]);
        let d = meta.d_model;
        assert_eq!(before.new_v[..d], after.new_v[..d]);
        // Row 1 now runs through the LoRA path: its V projection changes.
        assert_ne!(before.new_v[d..2 * d], after.new_v[d..2 * d]);
    }

    #[test]
    fn identical_rows_identical_outputs() {
        let meta = tiny_meta();
        let mut rt = ReferenceBackend::new(meta.clone());
        let b = 4usize;
        let n = meta.n_layers * b * meta.window * meta.d_model;
        let mut k = vec![0f32; n];
        for (i, x) in k.iter_mut().enumerate() {
            *x = ((i % 97) as f32) * 1e-3;
        }
        // Same window content for every row.
        let (nl, w, d) = (meta.n_layers, meta.window, meta.d_model);
        let mut kk = vec![0f32; n];
        let mut vv = vec![0f32; n];
        for l in 0..nl {
            for row in 0..b {
                for j in 0..w * d {
                    kk[(l * b + row) * w * d + j] = k[l * w * d + j];
                    vv[(l * b + row) * w * d + j] = -k[l * w * d + j];
                }
            }
        }
        let out = rt.decode(b, &[9, 9, 9, 9], &kk, &vv, &[6, 6, 6, 6], &[0, 0, 0, 0]).unwrap();
        for row in 1..b {
            assert_eq!(out.next_tokens[row], out.next_tokens[0]);
            for l in 0..nl {
                let a0 = (l * b) * d;
                let ar = (l * b + row) * d;
                assert_eq!(out.new_k[a0..a0 + d], out.new_k[ar..ar + d]);
            }
        }
    }

    #[test]
    fn prefill_then_decode_matches_longer_prefill() {
        // The first decode step after a prefill of length n must agree
        // with a prefill of the (n+1)-token prompt: decode attention over
        // the full cached history is causal attention at position n.
        let meta = tiny_meta();
        let mut rt = ReferenceBackend::new(meta.clone());
        let (nl, d) = (meta.n_layers, meta.d_model);
        let bucket = 8usize;
        let n = 5usize;
        let prompt = [3i32, 14, 9, 1, 60];
        let mut padded = vec![0i32; bucket];
        padded[..n].copy_from_slice(&prompt);
        let pre = rt.prefill(bucket, &padded, n, 0).unwrap();

        // Seed the decode window from the prefill K/V ([L, S, d] layout).
        let w = meta.window;
        let mut k_win = vec![0f32; nl * w * d];
        let mut v_win = vec![0f32; nl * w * d];
        for l in 0..nl {
            for t in 0..n {
                let src = (l * bucket + t) * d;
                let dst = (l * w + t) * d;
                k_win[dst..dst + d].copy_from_slice(&pre.k[src..src + d]);
                v_win[dst..dst + d].copy_from_slice(&pre.v[src..src + d]);
            }
        }
        let dec =
            rt.decode(1, &[pre.next_token], &k_win, &v_win, &[n as i32], &[0]).unwrap();

        // Longer prefill over prompt + generated token.
        let mut padded2 = vec![0i32; bucket];
        padded2[..n].copy_from_slice(&prompt);
        padded2[n] = pre.next_token;
        let pre2 = rt.prefill(bucket, &padded2, n + 1, 0).unwrap();
        assert_eq!(dec.next_tokens[0], pre2.next_token);
        for l in 0..nl {
            let from_dec = &dec.new_k[l * d..(l + 1) * d];
            let from_pre = &pre2.k[(l * bucket + n) * d..(l * bucket + n) * d + d];
            for (a, b) in from_dec.iter().zip(from_pre) {
                assert!((a - b).abs() < 1e-4, "k row mismatch: {a} vs {b}");
            }
        }
    }

    #[test]
    fn bucket_introspection_follows_meta() {
        let rt = ReferenceBackend::new(tiny_meta());
        assert_eq!(rt.decode_bucket(3), Some(4));
        assert_eq!(rt.decode_bucket(5), None);
        assert_eq!(rt.max_decode_bucket(), 4);
        assert_eq!(rt.prefill_bucket(8), Some(8));
        assert_eq!(rt.max_prefill_bucket(), 16);
    }

    #[test]
    fn rejects_malformed_inputs() {
        let meta = tiny_meta();
        let mut rt = ReferenceBackend::new(meta.clone());
        let n = meta.n_layers * meta.window * meta.d_model;
        let (k, v) = (vec![0f32; n], vec![0f32; n]);
        assert!(rt.decode(1, &[0, 0], &k, &v, &[0], &[0]).is_err()); // tokens len
        assert!(rt.decode(1, &[0], &k, &v, &[0], &[99]).is_err()); // bad slot
        assert!(rt.prefill(8, &[0i32; 8], 0, 0).is_err()); // true_len 0
    }
}
