//! Typed configuration system (JSON-backed).
//!
//! Three config families:
//! - [`MemoryConfig`] — the simulated-GPU memory ledger (DESIGN.md §3.2);
//! - [`EngineConfig`] — one serving-engine instance ("one GPU");
//! - [`ClusterConfig`] — a multi-GPU deployment.
//!
//! Workload configuration lives in [`crate::workload`].

use crate::util::json::Json;
use std::path::Path;

/// Simulated GPU memory, expressed in KV-token units the way the paper
/// reasons about it: adapter weights ("A_max · S_max") and request KV cache
/// compete for the same budget.
#[derive(Debug, Clone, PartialEq)]
pub struct MemoryConfig {
    /// KV-token capacity with zero adapters loaded (T0).
    pub total_tokens: usize,
    /// KV block granularity (vLLM paged-attention block).
    pub block_tokens: usize,
    /// Token-equivalents consumed per unit of adapter rank.
    pub rank_token_cost: f64,
    /// S-LoRA mode (Appendix A): no static adapter region; adapter weights
    /// and KV share one pool and are charged dynamically per loaded adapter.
    pub unified: bool,
}

impl Default for MemoryConfig {
    fn default() -> Self {
        MemoryConfig {
            total_tokens: 8192,
            block_tokens: 16,
            rank_token_cost: 4.0,
            unified: false,
        }
    }
}

impl MemoryConfig {
    /// Token-equivalents reserved by one adapter of `rank`.
    pub fn adapter_tokens(&self, rank: usize) -> f64 {
        rank as f64 * self.rank_token_cost
    }

    /// KV pool (in tokens) left after statically reserving `a_max` slots of
    /// `s_max_rank`-sized adapters, vLLM-style.  `None` = memory error
    /// (reservation exceeds the GPU).
    pub fn kv_pool_tokens(&self, a_max: usize, s_max_rank: usize) -> Option<usize> {
        let reserve = a_max as f64 * self.adapter_tokens(s_max_rank);
        let total = self.total_tokens as f64;
        if reserve >= total {
            None
        } else {
            Some((total - reserve) as usize)
        }
    }

    /// Serialize to the JSON config format.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("total_tokens", Json::Num(self.total_tokens as f64)),
            ("block_tokens", Json::Num(self.block_tokens as f64)),
            ("rank_token_cost", Json::Num(self.rank_token_cost)),
            ("unified", Json::Bool(self.unified)),
        ])
    }

    /// Parse from JSON; absent keys fall back to the defaults.
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let d = MemoryConfig::default();
        Ok(MemoryConfig {
            total_tokens: j.get("total_tokens").and_then(Json::as_usize).unwrap_or(d.total_tokens),
            block_tokens: j.get("block_tokens").and_then(Json::as_usize).unwrap_or(d.block_tokens),
            rank_token_cost: j
                .get("rank_token_cost")
                .and_then(Json::as_f64)
                .unwrap_or(d.rank_token_cost),
            unified: j.get("unified").and_then(Json::as_bool).unwrap_or(d.unified),
        })
    }
}

/// One serving-engine instance ("one GPU").
#[derive(Debug, Clone, PartialEq)]
pub struct EngineConfig {
    /// Backbone model name (must exist in the artifact manifest).
    pub model: String,
    /// Max simultaneously loaded adapters (the paper's A_max).
    pub a_max: usize,
    /// Per-adapter memory footprint cap as a rank (the paper's S_max);
    /// vLLM reserves this uniformly for every slot.
    pub s_max_rank: usize,
    /// The simulated-GPU memory ledger configuration.
    pub mem: MemoryConfig,
    /// vLLM's max_num_seqs: cap on requests in the running batch.  Also
    /// bounded by the largest compiled decode bucket.
    pub max_num_seqs: usize,
    /// Modeled CPU→GPU adapter transfer time per unit rank (ms); the real
    /// device-bank re-upload cost is measured and added on top.
    pub load_ms_per_rank: f64,
    /// Disk→GPU multiplier over CPU→GPU (paper Fig. 6: ~1.7x).
    pub load_disk_mult: f64,
    /// Whether adapters are preloaded in CPU memory (vs loaded from disk).
    pub preload_cpu: bool,
    /// Engine-instance seed (per-GPU seeds are derived from it).
    pub seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            model: "pico-llama".to_string(),
            a_max: 32,
            s_max_rank: 32,
            mem: MemoryConfig::default(),
            max_num_seqs: 64,
            load_ms_per_rank: 0.35,
            load_disk_mult: 1.7,
            preload_cpu: true,
            seed: 1,
        }
    }
}

impl EngineConfig {
    /// KV pool after the static adapter reservation; `None` = memory error.
    pub fn kv_pool_tokens(&self) -> Option<usize> {
        if self.mem.unified {
            Some(self.mem.total_tokens)
        } else {
            self.mem.kv_pool_tokens(self.a_max, self.s_max_rank)
        }
    }

    /// Serialize to the JSON config format.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("a_max", Json::Num(self.a_max as f64)),
            ("s_max_rank", Json::Num(self.s_max_rank as f64)),
            ("mem", self.mem.to_json()),
            ("max_num_seqs", Json::Num(self.max_num_seqs as f64)),
            ("load_ms_per_rank", Json::Num(self.load_ms_per_rank)),
            ("load_disk_mult", Json::Num(self.load_disk_mult)),
            ("preload_cpu", Json::Bool(self.preload_cpu)),
            ("seed", Json::Num(self.seed as f64)),
        ])
    }

    /// Parse from JSON; absent keys fall back to the defaults.
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let d = EngineConfig::default();
        Ok(EngineConfig {
            model: j.get("model").and_then(Json::as_str).unwrap_or(&d.model).to_string(),
            a_max: j.get("a_max").and_then(Json::as_usize).unwrap_or(d.a_max),
            s_max_rank: j.get("s_max_rank").and_then(Json::as_usize).unwrap_or(d.s_max_rank),
            mem: j.get("mem").map(MemoryConfig::from_json).transpose()?.unwrap_or_default(),
            max_num_seqs: j.get("max_num_seqs").and_then(Json::as_usize).unwrap_or(d.max_num_seqs),
            load_ms_per_rank: j
                .get("load_ms_per_rank")
                .and_then(Json::as_f64)
                .unwrap_or(d.load_ms_per_rank),
            load_disk_mult: j
                .get("load_disk_mult")
                .and_then(Json::as_f64)
                .unwrap_or(d.load_disk_mult),
            preload_cpu: j.get("preload_cpu").and_then(Json::as_bool).unwrap_or(d.preload_cpu),
            seed: j.get("seed").and_then(Json::as_f64).unwrap_or(d.seed as f64) as u64,
        })
    }

    /// Load a config file written by [`EngineConfig::save`].
    pub fn load(path: &Path) -> anyhow::Result<Self> {
        Self::from_json(&Json::read_file(path)?)
    }

    /// Persist the config as JSON.
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        self.to_json().write_file(path)
    }
}

/// One GPU class in a heterogeneous fleet: its memory ledger, rental
/// price, and performance scale relative to the base calibration
/// (DESIGN.md §11).  `perf_scale` is the factor by which this class
/// executes faster than the hardware the base [`crate::dt`] calibration
/// was profiled on (1.0 = identical); the pipeline derives the class's
/// calibration by scaling the base constants.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuTypeSpec {
    /// Catalog name of the class (tags artifacts, reports and CSVs).
    pub name: String,
    /// The class's simulated-GPU memory ledger (capacity differs by class).
    pub mem: MemoryConfig,
    /// Rental price in $/hr — what the `MinCost` objective minimizes.
    pub cost_per_hour: f64,
    /// Compute speed relative to the base-calibration hardware (>0).
    pub perf_scale: f64,
}

impl GpuTypeSpec {
    /// Built-in class profiles (stand-ins for common inference GPUs; the
    /// memory budgets are in the same KV-token units as [`MemoryConfig`]).
    /// `a10g` is deliberately identical to the homogeneous default — a
    /// single-`a10g` fleet must reproduce today's plans bit-identically.
    pub fn catalog(name: &str) -> Option<GpuTypeSpec> {
        let (mem_tokens, cost, perf) = match name {
            "a10g" => (8192, 1.21, 1.0),
            "a100" => (16384, 4.10, 2.4),
            "h100" => (24576, 6.98, 4.2),
            _ => return None,
        };
        Some(GpuTypeSpec {
            name: name.to_string(),
            mem: MemoryConfig { total_tokens: mem_tokens, ..Default::default() },
            cost_per_hour: cost,
            perf_scale: perf,
        })
    }

    /// The per-GPU engine configuration of this class: `base` with the
    /// class's memory ledger swapped in.
    pub fn engine_config(&self, base: &EngineConfig) -> EngineConfig {
        EngineConfig { mem: self.mem.clone(), ..base.clone() }
    }

    /// Serialize to the JSON config format.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("mem", self.mem.to_json()),
            ("cost_per_hour", Json::Num(self.cost_per_hour)),
            ("perf_scale", Json::Num(self.perf_scale)),
        ])
    }

    /// Parse from JSON (absent memory keys fall back to the defaults).
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        Ok(GpuTypeSpec {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("GpuTypeSpec needs a name"))?
                .to_string(),
            mem: j.get("mem").map(MemoryConfig::from_json).transpose()?.unwrap_or_default(),
            cost_per_hour: j.get("cost_per_hour").and_then(Json::as_f64).unwrap_or(1.0),
            perf_scale: j.get("perf_scale").and_then(Json::as_f64).unwrap_or(1.0),
        })
    }
}

/// A typed fleet: which GPU classes are available and how many of each,
/// in declaration order (type indices are stable and deterministic).
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSpec {
    /// The GPU classes, in declaration order (index = type index).
    pub types: Vec<GpuTypeSpec>,
    /// Available GPU count per class (same order as `types`).
    pub counts: Vec<usize>,
}

impl FleetSpec {
    /// A fleet from `(class, count)` entries.
    pub fn new(entries: Vec<(GpuTypeSpec, usize)>) -> FleetSpec {
        let (types, counts) = entries.into_iter().unzip();
        FleetSpec { types, counts }
    }

    /// A single-class fleet — the homogeneous special case every typed
    /// code path must reproduce bit-identically.
    pub fn single(ty: GpuTypeSpec, count: usize) -> FleetSpec {
        FleetSpec { types: vec![ty], counts: vec![count] }
    }

    /// Total GPUs across every class.
    pub fn total_gpus(&self) -> usize {
        self.counts.iter().sum()
    }

    /// Per-class $/hr prices, in type-index order.
    pub fn prices(&self) -> Vec<f64> {
        self.types.iter().map(|t| t.cost_per_hour).collect()
    }

    /// Parse a CLI fleet spec: comma-separated `name:count` entries with
    /// an optional `@price` override, e.g. `a10g:4,a100:2` or
    /// `a10g:4@0.9,h100:1`.  Names resolve via [`GpuTypeSpec::catalog`].
    pub fn parse(spec: &str) -> anyhow::Result<FleetSpec> {
        let mut entries = vec![];
        for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
            let (head, price) = match part.split_once('@') {
                Some((h, p)) => (h, Some(p.parse::<f64>()?)),
                None => (part, None),
            };
            let (name, count) = head
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("fleet entry '{part}' is not name:count"))?;
            let mut ty = GpuTypeSpec::catalog(name.trim())
                .ok_or_else(|| anyhow::anyhow!("unknown GPU type '{name}' (a10g|a100|h100)"))?;
            if let Some(p) = price {
                ty.cost_per_hour = p;
            }
            let count: usize = count.trim().parse()?;
            if count == 0 {
                anyhow::bail!("fleet entry '{part}' has zero GPUs");
            }
            entries.push((ty, count));
        }
        if entries.is_empty() {
            anyhow::bail!("empty fleet spec (expected e.g. a10g:4,a100:2)");
        }
        Ok(FleetSpec::new(entries))
    }

    /// Serialize to the JSON config format.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "fleet",
            Json::Arr(
                self.types
                    .iter()
                    .zip(&self.counts)
                    .map(|(t, &c)| {
                        Json::obj(vec![
                            ("name", Json::Str(t.name.clone())),
                            ("mem", t.mem.to_json()),
                            ("cost_per_hour", Json::Num(t.cost_per_hour)),
                            ("perf_scale", Json::Num(t.perf_scale)),
                            ("count", Json::Num(c as f64)),
                        ])
                    })
                    .collect(),
            ),
        )])
    }

    /// Parse from JSON written by [`FleetSpec::to_json`].
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let arr = j
            .get("fleet")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("FleetSpec needs a fleet array"))?;
        let mut entries = vec![];
        for e in arr {
            let ty = GpuTypeSpec::from_json(e)?;
            let count = e.get("count").and_then(Json::as_usize).unwrap_or(1);
            entries.push((ty, count));
        }
        Ok(FleetSpec::new(entries))
    }
}

/// A multi-GPU deployment: `gpus` engines sharing one compiled model.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Number of simulated GPUs (one engine instance each).
    pub gpus: usize,
    /// The per-GPU engine configuration.
    pub engine: EngineConfig,
}

impl ClusterConfig {
    /// Bundle a GPU count with its per-GPU engine configuration.
    pub fn new(gpus: usize, engine: EngineConfig) -> Self {
        ClusterConfig { gpus, engine }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_pool_shrinks_with_a_max() {
        let m = MemoryConfig::default();
        let p0 = m.kv_pool_tokens(0, 32).unwrap();
        let p32 = m.kv_pool_tokens(32, 32).unwrap();
        assert_eq!(p0, m.total_tokens);
        assert_eq!(p32, m.total_tokens - (32.0 * 32.0 * m.rank_token_cost) as usize);
        assert!(p32 < p0);
    }

    #[test]
    fn memory_error_when_over_reserved() {
        let m = MemoryConfig::default();
        // 8192 tokens; 384 slots × rank32 × 4 = 49152 > 8192 → error
        assert!(m.kv_pool_tokens(384, 32).is_none());
    }

    #[test]
    fn unified_mode_has_no_static_reservation() {
        let mut e = EngineConfig::default();
        e.mem.unified = true;
        e.a_max = 10_000;
        assert_eq!(e.kv_pool_tokens(), Some(e.mem.total_tokens));
    }

    #[test]
    fn json_roundtrip() {
        let mut e = EngineConfig::default();
        e.a_max = 96;
        e.mem.unified = true;
        let j = e.to_json();
        let e2 = EngineConfig::from_json(&j).unwrap();
        assert_eq!(e, e2);
    }

    #[test]
    fn fleet_parse_catalog_and_price_override() {
        let f = FleetSpec::parse("a10g:4,a100:2@3.5").unwrap();
        assert_eq!(f.types.len(), 2);
        assert_eq!(f.counts, vec![4, 2]);
        assert_eq!(f.total_gpus(), 6);
        assert_eq!(f.types[0].name, "a10g");
        assert_eq!(f.types[1].cost_per_hour, 3.5);
        assert!(f.types[1].mem.total_tokens > f.types[0].mem.total_tokens);
        assert!(FleetSpec::parse("v100:2").is_err());
        assert!(FleetSpec::parse("a10g:0").is_err());
        assert!(FleetSpec::parse("").is_err());
    }

    #[test]
    fn fleet_json_roundtrip() {
        let f = FleetSpec::parse("h100:1@5.0,a10g:3").unwrap();
        let f2 = FleetSpec::from_json(&f.to_json()).unwrap();
        assert_eq!(f, f2);
    }

    #[test]
    fn a10g_matches_homogeneous_default() {
        // The a10g class must be indistinguishable from the homogeneous
        // default so single-type fleets reproduce pre-fleet plans.
        let ty = GpuTypeSpec::catalog("a10g").unwrap();
        assert_eq!(ty.mem, MemoryConfig::default());
        assert_eq!(ty.perf_scale, 1.0);
        assert_eq!(ty.engine_config(&EngineConfig::default()), EngineConfig::default());
    }
}
