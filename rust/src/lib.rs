//! # adapter-serving
//!
//! Reproduction of *"Data-Driven Optimization of GPU efficiency for
//! Distributed LLM-Adapter Serving"* (Agulló et al., 2026) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! - [`runtime`] — PJRT CPU client loading AOT-compiled HLO artifacts;
//! - [`engine`] — the vLLM-like multi-LoRA continuous-batching serving
//!   engine (the paper's "real system" stand-in);
//! - [`dt`] — the Digital Twin and its four predictive performance models;
//! - [`ml`] — from-scratch ML (RF/KNN/SVM + refinement) trained on DT data;
//! - [`placement`] — the greedy adapter-caching algorithm and baselines;
//! - [`cluster`] — multi-GPU routing driven by placement decisions;
//! - [`experiments`] — regenerates every table and figure of the paper.
//!
//! See DESIGN.md for the system inventory and the per-experiment index.

pub mod cluster;
pub mod config;
pub mod dt;
pub mod engine;
pub mod experiments;
pub mod ml;
pub mod placement;
pub mod runtime;
pub mod util;
pub mod workload;
