//! # adapter-serving
//!
//! Reproduction of *"Data-Driven Optimization of GPU efficiency for
//! Distributed LLM-Adapter Serving"* (Agulló et al., 2026) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! - [`runtime`] — pluggable execution backends behind [`runtime::Backend`]:
//!   the default pure-Rust reference model, plus the PJRT CPU client for
//!   AOT-compiled HLO artifacts (cargo feature `pjrt`);
//! - [`engine`] — the vLLM-like multi-LoRA continuous-batching serving
//!   engine (the paper's "real system" stand-in);
//! - [`dt`] — the Digital Twin and its four predictive performance models;
//! - [`ml`] — from-scratch ML (RF/KNN/SVM + refinement) trained on DT data;
//! - [`placement`] — the greedy adapter-caching algorithm, baselines, and
//!   the migration-aware incremental replanner ([`placement::replan`]),
//!   generic over the [`placement::PerfEstimator`] and
//!   [`placement::Objective`] trait seams;
//! - [`pipeline`] — the typed end-to-end pipeline
//!   (`Calibrated → Dataset → Trained → Planned → Validated`) over an
//!   on-disk content-hashed artifact store (DESIGN.md §8);
//! - [`cluster`] — multi-GPU routing driven by placement decisions, with
//!   per-GPU validation runs parallelized over the thread pool, plus the
//!   rolling-horizon epoch runner ([`cluster::epochs`], DESIGN.md §7) and
//!   the event-driven continuous-batching core ([`cluster::events`],
//!   DESIGN.md §12);
//! - [`experiments`] — regenerates every table and figure of the paper.
//!
//! The three-layer public API is *workload* ([`workload::WorkloadSpec`],
//! [`workload::drift::DriftSpec`]) → *placement* ([`placement::Placement`])
//! → *cluster* ([`cluster::serve_on_engine`] / [`cluster::serve_on_twin`],
//! both driven by [`cluster::RunOptions`], and the rolling-horizon
//! [`cluster::epochs::serve_horizon`] with its [`cluster::Core`]
//! selector); [`pipeline::Pipeline`] drives
//! the data-driven chain that produces the placement in the first place.
//! The [`prelude`] re-exports this surface for one-line imports.
//!
//! See DESIGN.md for the system inventory, the backend feature matrix and
//! the per-experiment index; `#![warn(missing_docs)]` plus the CI docs job
//! (`cargo doc --no-deps` under `RUSTDOCFLAGS="-D warnings"`) keep this
//! surface documented.

#![warn(missing_docs)]
// Numeric hot loops (runtime::reference, ml) index several parallel slices
// by design, and the execution surfaces mirror fixed multi-tensor kernel
// signatures; these style lints fight both patterns.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments, clippy::manual_memcpy)]

pub mod cluster;
pub mod config;
pub mod dt;
pub mod engine;
pub mod experiments;
pub mod ml;
pub mod pipeline;
pub mod placement;
pub mod runtime;
pub mod util;
pub mod workload;

/// One-line import of the pipeline-facing surface: planning seams
/// ([`placement::PerfEstimator`], [`placement::Objective`] and their
/// stock implementations), the typed pipeline, and the cluster runners'
/// options struct.
///
/// ```
/// use adapter_serving::prelude::*;
/// let opts = RunOptions::new().workers(1);
/// assert_eq!(MinGpus.name(), "min-gpus");
/// assert_eq!(opts.workers, 1);
/// ```
pub mod prelude {
    pub use crate::cluster::{Core, RunOptions};
    pub use crate::pipeline::Pipeline;
    pub use crate::placement::{
        CachedEstimator, Estimate, MinGpus, MinLatency, Objective, PerfEstimator, Placement,
        ProbeQuery, TwinEstimator,
    };
    pub use crate::workload::WorkloadSpec;
}
