//! The end-to-end placement pipeline as a typed, composable API
//! (DESIGN.md §8).
//!
//! The paper's contribution is a *pipeline* — engine profiling → Digital
//! Twin → distilled ML models → greedy placement → validation — and this
//! module makes that chain a first-class object instead of disk-stitched
//! CLI subcommands.  A [`Pipeline`] is configured once through a builder
//! and then driven stage by stage; every stage consumes the previous
//! stage's *typed* output, so the compiler enforces the ordering:
//!
//! ```text
//! Pipeline::for_model(..) ─calibrate()→ Calibrated ─dataset()→ Dataset
//!     ─train()→ Trained ─place()→ Planned ─validate()→ Validated
//! ```
//!
//! The three expensive stages are backed by an on-disk [`ArtifactStore`]
//! keyed by a content [`fingerprint`] of each stage's inputs (backbone
//! model + grid + scale + upstream fingerprint), so repeated runs reuse
//! calibrations, datasets and trained models and any input change misses
//! the cache.  The DT-in-the-loop placement path persists a fourth
//! artifact: its twin probe memos
//! ([`CachedEstimator`](crate::placement::CachedEstimator)), chained on
//! the calibration's content fingerprint, so repeated
//! `adapterd pipeline`/`drift` runs warm-start instead of re-simulating
//! every probe.  Placement consumes the pluggable
//! [`PerfEstimator`](crate::placement::PerfEstimator) /
//! [`Objective`](crate::placement::Objective) seams, selected with
//! [`Pipeline::estimator`] and [`Pipeline::objective`].
//!
//! `adapterd pipeline` drives [`Pipeline::run`] from the CLI; the
//! per-stage subcommands (`calibrate`, `dataset`, `train`, `place`) are
//! thin wrappers over the same stage methods.

pub mod store;

pub use store::{fingerprint, ArtifactStore};

use crate::cluster::{self, ClusterReport};
use crate::config::{EngineConfig, FleetSpec, GpuTypeSpec};
use crate::dt::{self, Calibration, LengthVariant};
use crate::ml::{self, GridSpec, MlModels, Sample};
use crate::placement::{
    fleet as fleet_placement, plan, CacheStats, CachedEstimator, MinGpus, Objective,
    PerfEstimator, Placement, TwinEstimator, TypedEstimator, UNTYPED_GPU,
};
use crate::runtime::{self, Backend, BackendPool, Manifest};
use crate::workload::{AdapterSpec, WorkloadSpec};
use anyhow::Result;
use std::path::PathBuf;
use std::sync::OnceLock;

/// Probe-memo LRU bound shared by every DT-in-the-loop estimator the
/// pipeline constructs.  Bounded so a full-scale sweep cannot outgrow
/// memory; ~256k entries is far beyond any single pipeline's probe
/// footprint, so the bound never alters small-run behavior or warm
/// starts.
const PROBE_MEMO_CAPACITY: usize = 262_144;

/// Pipeline/experiment scale selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// Minutes-scale runs used by `cargo bench` and CI.
    Quick,
    /// The full sweeps (hours on this CPU).
    Full,
}

impl Scale {
    /// Parse a `--scale` CLI value ("full" → Full, everything else Quick).
    pub fn parse(s: &str) -> Scale {
        if s.eq_ignore_ascii_case("full") {
            Scale::Full
        } else {
            Scale::Quick
        }
    }

    /// Whether this is the quick (CI) scale.
    pub fn is_quick(&self) -> bool {
        matches!(self, Scale::Quick)
    }

    /// Tag used in artifact fingerprints.
    fn tag(&self) -> &'static str {
        if self.is_quick() {
            "quick"
        } else {
            "full"
        }
    }
}

/// Which [`PerfEstimator`](crate::placement::PerfEstimator) backs the
/// placement stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EstimatorChoice {
    /// The trained ML model pair (the paper's deployed configuration).
    #[default]
    Ml,
    /// The Digital Twin queried directly (slower, learning-error-free).
    Twin,
}

impl EstimatorChoice {
    /// Parse a CLI `--estimator` value (shared by `adapterd pipeline`,
    /// `place` and the experiment harness).
    pub fn parse(s: &str) -> Result<EstimatorChoice> {
        match s {
            "ml" => Ok(EstimatorChoice::Ml),
            "twin" => Ok(EstimatorChoice::Twin),
            other => Err(anyhow::anyhow!("unknown --estimator '{other}' (ml|twin)")),
        }
    }
}

/// Output of the calibration stage.
pub struct Calibrated {
    /// The calibrated Digital-Twin constants.
    pub calibration: Calibration,
    /// Content fingerprint of the calibration (keys downstream stages).
    pub fingerprint: u64,
    /// Whether the stage was served from the artifact store (or from an
    /// injected calibration) instead of being computed.
    pub cached: bool,
}

/// Output of the dataset-generation stage.
pub struct Dataset {
    /// The calibration the samples were simulated under.
    pub calibration: Calibration,
    /// DT-generated training samples.
    pub samples: Vec<Sample>,
    /// Input fingerprint of the stage (model + grid + scale + upstream).
    pub fingerprint: u64,
    /// Whether the stage was served from the artifact store.
    pub cached: bool,
}

/// Output of the training stage.
pub struct Trained {
    /// The calibration the training data came from.
    pub calibration: Calibration,
    /// The trained throughput/starvation model pair.
    pub models: MlModels,
    /// Input fingerprint of the stage.
    pub fingerprint: u64,
    /// Whether the stage was served from the artifact store.
    pub cached: bool,
}

/// One GPU class's calibration artifact of a fleet pipeline: the base
/// calibration rescaled by the class's relative performance
/// ([`Calibration::scaled`]) and cached in the artifact store per type.
pub struct TypeCalibrated {
    /// The GPU class name ([`GpuTypeSpec::name`]).
    pub name: String,
    /// The class's Digital-Twin calibration.
    pub calibration: Calibration,
    /// Whether this class's artifact was served from the store.
    pub cached: bool,
}

/// Fleet facets of a [`Planned`] stage (fleet pipelines only).
pub struct FleetPlan {
    /// The fleet the planner ran against.
    pub spec: FleetSpec,
    /// Type index (into [`FleetSpec::types`]) of every GPU slot.
    pub gpu_type: Vec<usize>,
    /// Hourly rental cost of the used GPUs under the fleet's prices.
    pub cost_per_hour: f64,
    /// Used-GPU count per type, in type-index order.
    pub used_by_type: Vec<usize>,
    /// Per-type calibration stage outputs, in type-index order.
    pub calibrations: Vec<TypeCalibrated>,
}

/// Output of the placement stage.
pub struct Planned {
    /// The placement decision.
    pub placement: Placement,
    /// Tag of the objective that ranked it.
    pub objective: &'static str,
    /// Tag of the estimator that validated it.
    pub estimator: &'static str,
    /// GPU budget the planner ran against.
    pub gpus: usize,
    /// Probe-cache counters of the placement stage (DT-in-the-loop paths
    /// only: the twin estimator's probes are memoized and persisted in
    /// the artifact store; `None` for the µs-per-probe ML estimator).
    /// Fleet pipelines report the sum over the per-type caches.
    pub probe_cache: Option<CacheStats>,
    /// Fleet facets when the pipeline planned over a typed fleet
    /// ([`Pipeline::fleet`]); `None` for homogeneous runs.
    pub fleet: Option<FleetPlan>,
}

/// Output of the validation stage.
pub struct Validated {
    /// Aggregated serving report of the placement under the workload.
    pub report: ClusterReport,
    /// Whether validation ran on the real engine (vs the Digital Twin).
    pub on_engine: bool,
}

/// All five stage outputs of one [`Pipeline::run`].
pub struct PipelineRun {
    /// Calibration stage output.
    pub calibrated: Calibrated,
    /// Dataset stage output.
    pub dataset: Dataset,
    /// Training stage output.
    pub trained: Trained,
    /// Placement stage output.
    pub planned: Planned,
    /// Validation stage output.
    pub validated: Validated,
}

/// The typed end-to-end pipeline: builder-configured, stage-typed,
/// artifact-cached (module docs above; DESIGN.md §8).
///
/// ```
/// use adapter_serving::dt::Calibration;
/// use adapter_serving::ml::GridSpec;
/// use adapter_serving::pipeline::{Pipeline, Scale};
/// use adapter_serving::placement::MinLatency;
/// use adapter_serving::workload::WorkloadSpec;
/// # fn main() -> anyhow::Result<()> {
/// let dir = std::env::temp_dir().join(format!("pipe_doc_{}", std::process::id()));
/// std::fs::remove_dir_all(&dir).ok();
/// let pipe = Pipeline::for_model("pico-llama")
///     .scale(Scale::Quick)
///     .out_dir(&dir)
///     .calibration(Calibration::default()) // inject → no engine profiling
///     .grid(GridSpec {
///         sizes: vec![8],
///         rates: vec![0.2, 0.05],
///         adapter_counts: vec![8, 16],
///         a_max_values: vec![8, 16],
///         horizon_s: 3.0,
///         max_scenarios: 24,
///         seed: 3,
///     })
///     .objective(MinLatency)
///     .gpus(2);
/// let calibrated = pipe.calibrate()?; // typed stage outputs:
/// let dataset = pipe.dataset(&calibrated)?; // Calibrated → Dataset
/// let trained = pipe.train(&dataset)?; // Dataset → Trained
/// let spec = WorkloadSpec::sharegpt_like(WorkloadSpec::homogeneous(8, 8, 0.05), 5.0, 7);
/// match pipe.place(&trained, &spec.adapters) {
///     // Trained → Planned → Validated.
///     Ok(planned) => {
///         let validated = pipe.validate(&trained, &planned, &spec)?;
///         assert!(validated.report.gpus_used >= 1);
///     }
///     // With a 24-sample toy grid the starvation verdict is statistical;
///     // declining is a legal answer.
///     Err(e) => println!("toy-grid models declined the workload: {e}"),
/// }
/// assert!(pipe.dataset(&pipe.calibrate()?)?.cached, "second run reuses the store");
/// std::fs::remove_dir_all(&dir).ok();
/// # Ok(())
/// # }
/// ```
pub struct Pipeline {
    model: String,
    scale: Scale,
    out_dir: PathBuf,
    store_dir: Option<PathBuf>,
    artifacts: PathBuf,
    workers: usize,
    gpus: usize,
    fleet: Option<FleetSpec>,
    grid: Option<GridSpec>,
    calibration: Option<Calibration>,
    fast_calibration: bool,
    estimator: EstimatorChoice,
    objective: Box<dyn Objective>,
    validate_on_engine: bool,
    pool: OnceLock<BackendPool>,
}

impl Pipeline {
    /// A pipeline for one backbone model with the default configuration:
    /// quick scale, `results/` output (store under `results/store/`),
    /// fast calibration, ML estimator, [`MinGpus`] objective, 4 GPUs,
    /// twin validation.
    pub fn for_model(model: &str) -> Pipeline {
        Pipeline {
            model: model.to_string(),
            scale: Scale::Quick,
            out_dir: PathBuf::from("results"),
            store_dir: None,
            artifacts: Manifest::default_dir(),
            workers: crate::util::threadpool::default_workers(),
            gpus: 4,
            fleet: None,
            grid: None,
            calibration: None,
            fast_calibration: true,
            estimator: EstimatorChoice::Ml,
            objective: Box::new(MinGpus),
            validate_on_engine: false,
            pool: OnceLock::new(),
        }
    }

    /// Set the pipeline scale (selects the default grid and train budget).
    pub fn scale(mut self, scale: Scale) -> Pipeline {
        self.scale = scale;
        self
    }

    /// Set the output root; the artifact store lives under `<dir>/store`
    /// unless [`Pipeline::store_dir`] overrides it.
    pub fn out_dir(mut self, dir: impl Into<PathBuf>) -> Pipeline {
        self.out_dir = dir.into();
        self
    }

    /// Override the artifact-store directory.
    pub fn store_dir(mut self, dir: impl Into<PathBuf>) -> Pipeline {
        self.store_dir = Some(dir.into());
        self
    }

    /// Set the AOT artifact directory used to load execution backends.
    pub fn artifacts_dir(mut self, dir: impl Into<PathBuf>) -> Pipeline {
        self.artifacts = dir.into();
        self
    }

    /// Set the worker-thread count for parallel sweeps.
    pub fn workers(mut self, workers: usize) -> Pipeline {
        self.workers = workers.max(1);
        self
    }

    /// Set the GPU budget the placement stage plans against.
    pub fn gpus(mut self, gpus: usize) -> Pipeline {
        self.gpus = gpus.max(1);
        self
    }

    /// Plan over a typed heterogeneous fleet instead of `gpus` identical
    /// GPUs (DESIGN.md §11).  Fleet placement is DT-in-the-loop: each
    /// class gets a probe-cached twin estimator under its own calibration
    /// and memory config, regardless of [`Pipeline::estimator`] (per-type
    /// ML model pairs are future work).  Validation runs on the Digital
    /// Twin with each GPU simulated under its class's calibration.
    pub fn fleet(mut self, fleet: FleetSpec) -> Pipeline {
        self.gpus = fleet.total_gpus().max(1);
        self.fleet = Some(fleet);
        self
    }

    /// Override the dataset sweep grid (default:
    /// [`GridSpec::paper`] at the pipeline scale).
    pub fn grid(mut self, grid: GridSpec) -> Pipeline {
        self.grid = Some(grid);
        self
    }

    /// Inject a known calibration: the calibrate stage returns it directly
    /// (no backend, no profiling) and downstream stages key off its
    /// content fingerprint.
    pub fn calibration(mut self, calibration: Calibration) -> Pipeline {
        self.calibration = Some(calibration);
        self
    }

    /// Whether the calibration suite runs its fast subset (default true).
    pub fn fast_calibration(mut self, fast: bool) -> Pipeline {
        self.fast_calibration = fast;
        self
    }

    /// Select which estimator backs the placement stage.
    pub fn estimator(mut self, choice: EstimatorChoice) -> Pipeline {
        self.estimator = choice;
        self
    }

    /// Select the placement objective (default [`MinGpus`]).
    pub fn objective(self, objective: impl Objective + 'static) -> Pipeline {
        self.boxed_objective(Box::new(objective))
    }

    /// [`Pipeline::objective`] for an already-boxed objective (e.g. one
    /// parsed from a CLI flag).
    pub fn boxed_objective(mut self, objective: Box<dyn Objective>) -> Pipeline {
        self.objective = objective;
        self
    }

    /// Validate on the real engine instead of the Digital Twin.
    pub fn validate_on_engine(mut self, on_engine: bool) -> Pipeline {
        self.validate_on_engine = on_engine;
        self
    }

    /// The artifact store this pipeline reads and writes.
    pub fn store(&self) -> ArtifactStore {
        ArtifactStore::new(
            self.store_dir.clone().unwrap_or_else(|| self.out_dir.join("store")),
        )
    }

    /// The engine-backend pool behind every validation this pipeline
    /// runs, created lazily over the configured artifact directory.
    /// Model-keyed, so repeated [`Pipeline::validate`] calls reuse loaded
    /// backends instead of constructing one per GPU per call.
    pub fn backend_pool(&self) -> &BackendPool {
        self.pool.get_or_init(|| BackendPool::new(self.artifacts.clone()))
    }

    // ------------------------------------------------------------------
    // Stage internals
    // ------------------------------------------------------------------

    fn base_config(&self) -> EngineConfig {
        EngineConfig { model: self.model.clone(), ..Default::default() }
    }

    fn grid_spec(&self) -> GridSpec {
        self.grid.clone().unwrap_or_else(|| GridSpec::paper(self.scale.is_quick()))
    }

    /// Content fingerprint of a calibration (canonical Debug rendering;
    /// exact because the JSON round-trip preserves every f64 bit).
    fn calibration_fingerprint(c: &Calibration) -> u64 {
        let rendered = format!("{c:?}");
        fingerprint(["calibration-content", rendered.as_str()])
    }

    fn calibrate_input_fingerprint(&self) -> u64 {
        // The backend behind the profiling run is an input: a calibration
        // measured on the reference backend must not be served as a cache
        // hit for a PJRT run (or for different AOT artifacts).
        let backend =
            std::env::var("ADAPTER_SERVING_BACKEND").unwrap_or_else(|_| "auto".to_string());
        fingerprint([
            "calibrate".to_string(),
            self.model.clone(),
            if self.fast_calibration { "fast" } else { "full" }.to_string(),
            format!("backend={backend}"),
            format!("artifacts={}", self.artifacts.display()),
        ])
    }

    fn dataset_fingerprint(&self, c: &Calibrated) -> u64 {
        fingerprint([
            "dataset".to_string(),
            self.model.clone(),
            self.scale.tag().to_string(),
            format!("{:?}", self.grid_spec()),
            format!("{:016x}", c.fingerprint),
        ])
    }

    fn train_fingerprint(&self, dataset_fp: u64) -> u64 {
        fingerprint([
            "train".to_string(),
            self.model.clone(),
            self.scale.tag().to_string(),
            "rf-seed7".to_string(),
            format!("{dataset_fp:016x}"),
        ])
    }

    fn probe_fingerprint(&self, calibration: &Calibration) -> u64 {
        // Chained on the calibration *content* fingerprint like every
        // other stage, plus every remaining twin query parameter — the
        // probe horizon/seed and the full engine-config template
        // (canonical Debug rendering, like the calibration): memo keys
        // carry only the group and `A_max`, so everything else that
        // changes what a probe would answer must re-key the artifact.
        fingerprint([
            "probes".to_string(),
            self.model.clone(),
            "twin".to_string(),
            format!("horizon={}", TwinEstimator::DEFAULT_HORIZON_S),
            format!("seed={:x}", TwinEstimator::DEFAULT_SEED),
            format!("{:?}", self.base_config()),
            format!("{:016x}", Self::calibration_fingerprint(calibration)),
        ])
    }

    /// Store path of the persisted twin probe memos keyed to
    /// `calibration` — the artifact that warm-starts repeated
    /// DT-in-the-loop runs (`adapterd pipeline`/`drift`
    /// `--estimator twin`).
    pub fn probe_memo_path(&self, calibration: &Calibration) -> PathBuf {
        self.store().path("probes", &self.model, self.probe_fingerprint(calibration), "csv")
    }

    /// [`Pipeline::probe_fingerprint`] with a gpu-type dimension: the
    /// class name, ordinal and its (memory-specific) engine config are
    /// inputs, so two classes sharing one scaled calibration still key
    /// separate artifacts.
    fn probe_fingerprint_typed(
        &self,
        calibration: &Calibration,
        ty: &GpuTypeSpec,
        type_index: usize,
    ) -> u64 {
        fingerprint([
            "probes".to_string(),
            self.model.clone(),
            format!("gpu_type={}#{type_index}", ty.name),
            "twin".to_string(),
            format!("horizon={}", TwinEstimator::DEFAULT_HORIZON_S),
            format!("seed={:x}", TwinEstimator::DEFAULT_SEED),
            format!("{:?}", ty.engine_config(&self.base_config())),
            format!("{:016x}", Self::calibration_fingerprint(calibration)),
        ])
    }

    /// Store path of one fleet class's twin probe memos (`calibration`
    /// is the class's *scaled* calibration).
    pub fn probe_memo_path_typed(
        &self,
        calibration: &Calibration,
        ty: &GpuTypeSpec,
        type_index: usize,
    ) -> PathBuf {
        let fp = self.probe_fingerprint_typed(calibration, ty, type_index);
        self.store().path("probes", &format!("{}-{}", self.model, ty.name), fp, "csv")
    }

    fn type_calibration_fingerprint(&self, base_content_fp: u64, ty: &GpuTypeSpec) -> u64 {
        fingerprint([
            "calibrate-type".to_string(),
            self.model.clone(),
            ty.name.clone(),
            format!("perf_scale={:016x}", ty.perf_scale.to_bits()),
            format!("{base_content_fp:016x}"),
        ])
    }

    /// Fleet calibration stage: one artifact per GPU class, keyed on the
    /// base calibration's content fingerprint plus the class's name and
    /// exact `perf_scale` bits.  A class whose artifact is stored loads
    /// it (`cached: true`); otherwise the class's calibration is derived
    /// via [`Calibration::scaled`] and persisted.
    pub fn calibrate_fleet(
        &self,
        calibration: &Calibration,
        fleet: &FleetSpec,
    ) -> Result<Vec<TypeCalibrated>> {
        let base_fp = Self::calibration_fingerprint(calibration);
        let store = self.store();
        store.ensure_dir()?;
        let mut out = Vec::with_capacity(fleet.types.len());
        for ty in &fleet.types {
            let fp = self.type_calibration_fingerprint(base_fp, ty);
            let model_tag = format!("{}-{}", self.model, ty.name);
            let path = store.path("calibration", &model_tag, fp, "json");
            if path.exists() {
                if let Ok(c) = Calibration::load_file(&path, &self.model) {
                    out.push(TypeCalibrated {
                        name: ty.name.clone(),
                        calibration: c,
                        cached: true,
                    });
                    continue;
                }
            }
            let c = calibration.scaled(ty.perf_scale);
            c.to_json().write_file(&path)?;
            out.push(TypeCalibrated { name: ty.name.clone(), calibration: c, cached: false });
        }
        Ok(out)
    }

    // ------------------------------------------------------------------
    // Stages
    // ------------------------------------------------------------------

    /// Calibration stage: injected calibration, store hit, or a fresh
    /// profiling run on a backend loaded from the artifact directory.
    pub fn calibrate(&self) -> Result<Calibrated> {
        if let Some(hit) = self.calibrate_cached()? {
            return Ok(hit);
        }
        let mut rt = runtime::load_backend(&self.artifacts, &self.model)?;
        self.calibrate_fresh(rt.as_mut())
    }

    /// Calibration stage against an already-loaded backend (used by the
    /// experiment harness, which owns its backends).
    pub fn calibrate_with(&self, rt: &mut dyn Backend) -> Result<Calibrated> {
        if let Some(hit) = self.calibrate_cached()? {
            return Ok(hit);
        }
        self.calibrate_fresh(rt)
    }

    fn calibrate_cached(&self) -> Result<Option<Calibrated>> {
        if let Some(c) = &self.calibration {
            return Ok(Some(Calibrated {
                calibration: c.clone(),
                fingerprint: Self::calibration_fingerprint(c),
                cached: true,
            }));
        }
        let fp = self.calibrate_input_fingerprint();
        let path = self.store().path("calibration", &self.model, fp, "json");
        if path.exists() {
            if let Ok(c) = Calibration::load_file(&path, &self.model) {
                let content_fp = Self::calibration_fingerprint(&c);
                return Ok(Some(Calibrated {
                    calibration: c,
                    fingerprint: content_fp,
                    cached: true,
                }));
            }
        }
        Ok(None)
    }

    fn calibrate_fresh(&self, rt: &mut dyn Backend) -> Result<Calibrated> {
        eprintln!("[pipeline] calibrating {} ...", self.model);
        let calib = dt::calibrate(rt, &self.base_config(), self.fast_calibration)?;
        let fp = self.calibrate_input_fingerprint();
        let store = self.store();
        store.ensure_dir()?;
        calib.to_json().write_file(&store.path("calibration", &self.model, fp, "json"))?;
        let content_fp = Self::calibration_fingerprint(&calib);
        Ok(Calibrated { calibration: calib, fingerprint: content_fp, cached: false })
    }

    /// Dataset stage: sweep the Digital Twin over the grid (or load the
    /// stored sweep for identical inputs).
    pub fn dataset(&self, calibrated: &Calibrated) -> Result<Dataset> {
        let fp = self.dataset_fingerprint(calibrated);
        let path = self.store().path("dataset", &self.model, fp, "csv");
        if path.exists() {
            let samples = ml::dataset::load(&path)?;
            return Ok(Dataset {
                calibration: calibrated.calibration.clone(),
                samples,
                fingerprint: fp,
                cached: true,
            });
        }
        eprintln!("[pipeline] generating dataset for {} via the Digital Twin ...", self.model);
        let grid = self.grid_spec();
        let base = self.base_config();
        let samples = ml::dataset::generate(&calibrated.calibration, &base, &grid, self.workers);
        self.store().ensure_dir()?;
        ml::dataset::save(&samples, &path)?;
        Ok(Dataset {
            calibration: calibrated.calibration.clone(),
            samples,
            fingerprint: fp,
            cached: false,
        })
    }

    /// Training stage: fit the RF throughput/starvation pair on the
    /// dataset (or load the stored pair for identical inputs).
    pub fn train(&self, dataset: &Dataset) -> Result<Trained> {
        let fp = self.train_fingerprint(dataset.fingerprint);
        let path = self.store().path("models", &self.model, fp, "json");
        if path.exists() {
            if let Ok(models) = ml::load_models(&path) {
                return Ok(Trained {
                    calibration: dataset.calibration.clone(),
                    models,
                    fingerprint: fp,
                    cached: true,
                });
            }
        }
        eprintln!("[pipeline] training RF models for {} ...", self.model);
        let quick = self.scale.is_quick();
        let rf = ml::ModelType::RandomForest;
        let (thr, s1) = ml::train(&dataset.samples, ml::Task::Throughput, rf, quick, 7);
        let (st, s2) = ml::train(&dataset.samples, ml::Task::Starvation, rf, quick, 7);
        eprintln!("[pipeline] RF throughput cv-score {s1:.2}; starvation macro-F1 {s2:.3}");
        let models = MlModels { throughput: thr, starvation: st, scaler: None };
        self.store().ensure_dir()?;
        ml::save_models(&models, &path)?;
        Ok(Trained {
            calibration: dataset.calibration.clone(),
            models,
            fingerprint: fp,
            cached: false,
        })
    }

    /// Cache-only training lookup: the trained pair for this pipeline's
    /// inputs if it is already stored, without materializing the dataset.
    pub fn train_cached(&self, calibrated: &Calibrated) -> Result<Option<Trained>> {
        let fp = self.train_fingerprint(self.dataset_fingerprint(calibrated));
        let path = self.store().path("models", &self.model, fp, "json");
        if !path.exists() {
            return Ok(None);
        }
        match ml::load_models(&path) {
            Ok(models) => Ok(Some(Trained {
                calibration: calibrated.calibration.clone(),
                models,
                fingerprint: fp,
                cached: true,
            })),
            Err(_) => Ok(None),
        }
    }

    /// The DT-in-the-loop estimator, probe-cached and warm-started from
    /// this pipeline's store.  Returns the estimator and the store path
    /// its memos must be persisted back to
    /// ([`CachedEstimator::save_memos`]) once the caller's planning
    /// passes are done.  The one constructor for warm-started twin
    /// probing — [`Pipeline::place_on_twin`] and the drift experiment
    /// both use it, so the estimator configuration and the artifact
    /// fingerprint can never drift apart.
    pub fn probe_cached_twin(
        &self,
        calibration: &Calibration,
    ) -> Result<(CachedEstimator, PathBuf)> {
        let twin = TwinEstimator::new(calibration.clone(), self.base_config());
        let est = CachedEstimator::wrap(twin).capacity(PROBE_MEMO_CAPACITY);
        let path = self.probe_memo_path(calibration);
        if path.exists() {
            // A corrupt (or pre-fleet, gpu_type-less) artifact is a cold
            // start, not a failure.
            if let Ok(memos) = CachedEstimator::load_memos(&path, UNTYPED_GPU) {
                est.preload(memos);
            }
        }
        self.store().ensure_dir()?;
        Ok((est, path))
    }

    /// One fleet class's probe-cached twin estimator: the class's scaled
    /// calibration and memory config behind a [`TypedEstimator`] (memo
    /// keys gain the type ordinal) inside a [`CachedEstimator`] tagged
    /// with the class name, warm-started from the class's own store
    /// artifact.
    fn probe_cached_twin_typed(
        &self,
        tc: &TypeCalibrated,
        ty: &GpuTypeSpec,
        type_index: usize,
    ) -> Result<(CachedEstimator, PathBuf)> {
        let twin =
            TwinEstimator::new(tc.calibration.clone(), ty.engine_config(&self.base_config()));
        let est = CachedEstimator::wrap(TypedEstimator::new(twin, type_index))
            .capacity(PROBE_MEMO_CAPACITY)
            .memo_tag(ty.name.clone());
        let path = self.probe_memo_path_typed(&tc.calibration, ty, type_index);
        if path.exists() {
            // A corrupt, pre-fleet or foreign-type artifact is a cold
            // start, not a failure.
            if let Ok(memos) = CachedEstimator::load_memos(&path, &ty.name) {
                est.preload(memos);
            }
        }
        Ok((est, path))
    }

    fn plan_on_twin_fleet(
        &self,
        calibration: &Calibration,
        fleet: &FleetSpec,
        adapters: &[AdapterSpec],
    ) -> Result<Planned> {
        let calibrations = self.calibrate_fleet(calibration, fleet)?;
        let mut ests = Vec::with_capacity(fleet.types.len());
        let mut paths = Vec::with_capacity(fleet.types.len());
        for (t, (ty, tc)) in fleet.types.iter().zip(&calibrations).enumerate() {
            let (est, path) = self.probe_cached_twin_typed(tc, ty, t)?;
            ests.push(est);
            paths.push(path);
        }
        let est_refs: Vec<&dyn PerfEstimator> =
            ests.iter().map(|e| e as &dyn PerfEstimator).collect();
        let result = fleet_placement::place(adapters, fleet, &est_refs, self.objective.as_ref());
        // Persist every class's memos even when the planner declines the
        // workload (estimator state, not placement state), and report the
        // summed cache counters.
        let mut stats = CacheStats::default();
        for (est, path) in ests.iter().zip(&paths) {
            est.save_memos(path)?;
            let s = est.stats();
            stats.hits += s.hits;
            stats.misses += s.misses;
            stats.entries += s.entries;
            stats.warm += s.warm;
            stats.evictions += s.evictions;
        }
        let placed = result?;
        Ok(Planned {
            placement: placed.placement.clone(),
            objective: self.objective.name(),
            estimator: "twin",
            gpus: fleet.total_gpus(),
            probe_cache: Some(stats),
            fleet: Some(FleetPlan {
                spec: fleet.clone(),
                cost_per_hour: placed.cost_per_hour(fleet),
                used_by_type: placed.used_by_type(fleet),
                gpu_type: placed.gpu_type,
                calibrations,
            }),
        })
    }

    fn plan_on_twin(&self, calibration: &Calibration, adapters: &[AdapterSpec]) -> Result<Planned> {
        let (est, path) = self.probe_cached_twin(calibration)?;
        let placement = plan(adapters, self.gpus, &est, self.objective.as_ref());
        // Persist what was probed even when the planner declines the
        // workload: memos are estimator state, not placement state, and
        // warm-start the retry just the same.
        est.save_memos(&path)?;
        Ok(Planned {
            placement: placement?,
            objective: self.objective.name(),
            estimator: "twin",
            gpus: self.gpus,
            probe_cache: Some(est.stats()),
            fleet: None,
        })
    }

    /// Placement stage: plan `adapters` onto the GPU budget under the
    /// configured estimator and objective.  With a [`Pipeline::fleet`]
    /// configured the stage plans over the typed fleet instead
    /// (DT-in-the-loop under the per-type calibrations, whatever the
    /// estimator choice).
    pub fn place(&self, trained: &Trained, adapters: &[AdapterSpec]) -> Result<Planned> {
        if let Some(fleet) = &self.fleet {
            return self.plan_on_twin_fleet(&trained.calibration, fleet, adapters);
        }
        match self.estimator {
            EstimatorChoice::Ml => {
                let placement =
                    plan(adapters, self.gpus, &trained.models, self.objective.as_ref())?;
                Ok(Planned {
                    placement,
                    objective: self.objective.name(),
                    estimator: "ml",
                    gpus: self.gpus,
                    probe_cache: None,
                    fleet: None,
                })
            }
            EstimatorChoice::Twin => self.plan_on_twin(&trained.calibration, adapters),
        }
    }

    /// Placement directly from a calibration — the twin estimator never
    /// consults the ML models, so twin-only callers can skip the dataset
    /// and training stages entirely (ML pipelines go through
    /// [`Pipeline::train`] + [`Pipeline::place`]).
    pub fn place_on_twin(
        &self,
        calibrated: &Calibrated,
        adapters: &[AdapterSpec],
    ) -> Result<Planned> {
        if let Some(fleet) = &self.fleet {
            return self.plan_on_twin_fleet(&calibrated.calibration, fleet, adapters);
        }
        self.plan_on_twin(&calibrated.calibration, adapters)
    }

    /// Validation stage: serve the workload under the placement on the
    /// Digital Twin (default) or the real engine, one backend per GPU
    /// checked out of the pipeline's [`Pipeline::backend_pool`].
    pub fn validate(
        &self,
        trained: &Trained,
        planned: &Planned,
        spec: &WorkloadSpec,
    ) -> Result<Validated> {
        self.validate_with(&trained.calibration, planned, spec)
    }

    /// [`Pipeline::validate`] from a bare calibration (the twin-only
    /// path, which has no [`Trained`] stage).
    pub fn validate_with(
        &self,
        calibration: &Calibration,
        planned: &Planned,
        spec: &WorkloadSpec,
    ) -> Result<Validated> {
        let base = self.base_config();
        if let Some(fp) = &planned.fleet {
            // Fleet validation is twin-only: each GPU is simulated under
            // its class's scaled calibration and memory config.
            anyhow::ensure!(
                !self.validate_on_engine,
                "fleet validation runs on the Digital Twin (per-type engines unavailable)"
            );
            let calibs: Vec<Calibration> = fp
                .gpu_type
                .iter()
                .map(|&t| fp.calibrations[t].calibration.clone())
                .collect();
            let configs: Vec<EngineConfig> =
                fp.gpu_type.iter().map(|&t| fp.spec.types[t].engine_config(&base)).collect();
            let report = cluster::serve_on_twin_fleet(
                &calibs,
                &configs,
                &planned.placement,
                spec,
                LengthVariant::Original,
                cluster::RunOptions::new(),
            );
            return Ok(Validated { report, on_engine: false });
        }
        let report = if self.validate_on_engine {
            let opts = cluster::RunOptions::new().pool(self.backend_pool());
            cluster::serve_on_engine(&base, &planned.placement, spec, opts)?
        } else {
            cluster::serve_on_twin(
                calibration,
                &base,
                &planned.placement,
                spec,
                LengthVariant::Original,
                cluster::RunOptions::new(),
            )
        };
        Ok(Validated { report, on_engine: self.validate_on_engine })
    }

    /// The whole chain for one workload:
    /// calibrate → dataset → train → place → validate.
    ///
    /// `run` materializes every stage so [`PipelineRun`] is always
    /// complete; a twin-estimator caller that wants to skip the ML stages
    /// (they are planned around, not consulted) should drive
    /// [`Pipeline::calibrate`] → [`Pipeline::place_on_twin`] →
    /// [`Pipeline::validate_with`] instead, as `adapterd pipeline
    /// --estimator twin` does.
    pub fn run(&self, spec: &WorkloadSpec) -> Result<PipelineRun> {
        let calibrated = self.calibrate()?;
        let dataset = self.dataset(&calibrated)?;
        let trained = self.train(&dataset)?;
        let planned = self.place(&trained, &spec.adapters)?;
        let validated = self.validate(&trained, &planned, spec)?;
        Ok(PipelineRun { calibrated, dataset, trained, planned, validated })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_grid() -> GridSpec {
        GridSpec {
            sizes: vec![8],
            rates: vec![0.2, 0.05],
            adapter_counts: vec![8, 16],
            a_max_values: vec![8, 16],
            horizon_s: 3.0,
            max_scenarios: 24,
            seed: 3,
        }
    }

    fn pipe(dir: &std::path::Path) -> Pipeline {
        Pipeline::for_model("pico-llama")
            .out_dir(dir)
            .calibration(Calibration::default())
            .grid(tiny_grid())
            .gpus(2)
    }

    #[test]
    fn second_run_hits_the_artifact_cache() {
        let dir = std::env::temp_dir().join(format!("pipe_cache_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let p = pipe(&dir);
        let c1 = p.calibrate().unwrap();
        let d1 = p.dataset(&c1).unwrap();
        let t1 = p.train(&d1).unwrap();
        assert!(!d1.cached && !t1.cached, "first run must compute");
        // A fresh Pipeline value over the same inputs: everything hits.
        let p2 = pipe(&dir);
        let c2 = p2.calibrate().unwrap();
        assert_eq!(c1.fingerprint, c2.fingerprint, "content fingerprint is stable");
        let d2 = p2.dataset(&c2).unwrap();
        let t2 = p2.train(&d2).unwrap();
        assert!(d2.cached && t2.cached, "second run must reuse the store");
        assert_eq!(d1.fingerprint, d2.fingerprint);
        assert_eq!(d1.samples, d2.samples, "CSV round-trip must be exact");
        assert!(p2.train_cached(&c2).unwrap().is_some(), "cache-only lookup hits");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn grid_change_invalidates_the_dataset_cache() {
        let dir = std::env::temp_dir().join(format!("pipe_inval_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let p = pipe(&dir);
        let c = p.calibrate().unwrap();
        let d = p.dataset(&c).unwrap();
        let mut other_grid = tiny_grid();
        other_grid.max_scenarios = 12;
        let p2 = pipe(&dir).grid(other_grid);
        let d2 = p2.dataset(&p2.calibrate().unwrap()).unwrap();
        assert_ne!(d.fingerprint, d2.fingerprint, "grid change must re-key the stage");
        assert!(!d2.cached);
        assert!(p2.train_cached(&c).unwrap().is_none(), "trained pair re-keys too");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn twin_probe_memos_warm_start_a_second_pipeline_run() {
        let dir = std::env::temp_dir().join(format!("pipe_probes_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let spec = WorkloadSpec::sharegpt_like(WorkloadSpec::homogeneous(8, 8, 0.05), 5.0, 7);

        let p1 = pipe(&dir).estimator(EstimatorChoice::Twin);
        let c1 = p1.calibrate().unwrap();
        let run1 = p1.place_on_twin(&c1, &spec.adapters).unwrap();
        let s1 = run1.probe_cache.expect("twin path reports probe stats");
        assert!(s1.misses > 0, "cold run must simulate probes");
        assert_eq!(s1.warm, 0);
        assert!(p1.probe_memo_path(&c1.calibration).exists(), "memos persisted");

        // A fresh Pipeline value over the same store: every probe of the
        // identical planning pass is answered from the persisted memos.
        let p2 = pipe(&dir).estimator(EstimatorChoice::Twin);
        let c2 = p2.calibrate().unwrap();
        let run2 = p2.place_on_twin(&c2, &spec.adapters).unwrap();
        let s2 = run2.probe_cache.unwrap();
        assert_eq!(s2.misses, 0, "warm-started run must not re-simulate: {s2:?}");
        assert!(s2.warm > 0 && s2.hits == s1.total());
        assert_eq!(
            run1.placement,
            run2.placement,
            "warm-started placement is bit-identical to the cold one"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fleet_pipeline_per_type_artifacts_warm_start() {
        let dir = std::env::temp_dir().join(format!("pipe_fleet_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let fleet = FleetSpec::parse("a10g:2,a100:1").unwrap();
        let spec = WorkloadSpec::sharegpt_like(WorkloadSpec::homogeneous(8, 8, 0.05), 5.0, 7);

        let p1 = pipe(&dir).fleet(fleet.clone());
        let c1 = p1.calibrate().unwrap();
        let run1 = p1.place_on_twin(&c1, &spec.adapters).unwrap();
        let f1 = run1.fleet.as_ref().expect("fleet pipelines report fleet facets");
        assert!(
            f1.calibrations.iter().all(|tc| !tc.cached),
            "first run derives every per-type calibration"
        );
        let s1 = run1.probe_cache.unwrap();
        assert!(s1.misses > 0, "cold fleet run must simulate probes");

        // A fresh Pipeline over the same store: per-type calibrations and
        // probe memos are all served from their artifacts.
        let p2 = pipe(&dir).fleet(fleet);
        let c2 = p2.calibrate().unwrap();
        let run2 = p2.place_on_twin(&c2, &spec.adapters).unwrap();
        let f2 = run2.fleet.as_ref().unwrap();
        assert!(
            f2.calibrations.iter().all(|tc| tc.cached),
            "second run loads every per-type calibration"
        );
        let s2 = run2.probe_cache.unwrap();
        assert_eq!(s2.misses, 0, "warm-started fleet run must not re-simulate: {s2:?}");
        assert_eq!(run1.placement, run2.placement, "fleet plan is reproducible");
        assert_eq!(f1.cost_per_hour, f2.cost_per_hour);

        let v = p2.validate_with(&c2.calibration, &run2, &spec).unwrap();
        assert!(v.report.gpus_used >= 1);
        assert!(!v.on_engine);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn full_run_with_twin_estimator_places_and_validates() {
        let dir = std::env::temp_dir().join(format!("pipe_run_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let p = pipe(&dir).estimator(EstimatorChoice::Twin);
        let spec = WorkloadSpec::sharegpt_like(WorkloadSpec::homogeneous(8, 8, 0.05), 5.0, 7);
        let run = p.run(&spec).unwrap();
        assert_eq!(run.planned.objective, "min-gpus");
        assert_eq!(run.planned.estimator, "twin");
        assert_eq!(run.planned.placement.assignment.len(), 8);
        assert!(run.validated.report.gpus_used >= 1);
        assert!(!run.validated.on_engine);
        std::fs::remove_dir_all(&dir).ok();
    }
}
