//! Content-addressed on-disk store for pipeline stage artifacts.
//!
//! Every expensive pipeline stage (calibration, dataset generation, model
//! training) is keyed by a [`fingerprint`] of its inputs — backbone model,
//! grid, scale, and the upstream stage's *content* fingerprint — so
//! repeated [`crate::pipeline::Pipeline`] runs reuse artifacts instead of
//! recomputing them, and any input change (a different grid, a new
//! calibration) automatically misses the cache.
//!
//! Artifacts are plain files named `<stage>_<model>_<fingerprint>.<ext>`
//! under one root directory (default `results/store/`): calibrations as
//! JSON, datasets as CSV, model pairs as JSON — the same formats the
//! per-stage CLI commands export, so a store entry is always inspectable
//! with ordinary tools.

use std::path::{Path, PathBuf};

/// FNV-1a 64-bit hash over an ordered sequence of input strings.
///
/// A separator is folded in after every part so `["ab", "c"]` and
/// `["a", "bc"]` fingerprint differently.
///
/// ```
/// use adapter_serving::pipeline::fingerprint;
/// let a = fingerprint(["pico-llama", "quick"]);
/// assert_eq!(a, fingerprint(["pico-llama", "quick"])); // deterministic
/// assert_ne!(a, fingerprint(["pico-llama", "full"]));
/// assert_ne!(a, fingerprint(["pico-llamaquick"]));
/// ```
pub fn fingerprint<I, S>(parts: I) -> u64
where
    I: IntoIterator<Item = S>,
    S: AsRef<str>,
{
    const OFFSET: u64 = 0xcbf29ce484222325;
    const PRIME: u64 = 0x100000001b3;
    let mut h = OFFSET;
    for part in parts {
        for &b in part.as_ref().as_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(PRIME);
        }
        h ^= 0x1f; // unit separator
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// One directory of fingerprint-keyed pipeline artifacts.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactStore {
    root: PathBuf,
}

impl ArtifactStore {
    /// A store rooted at `root` (created lazily on first write).
    pub fn new(root: impl Into<PathBuf>) -> ArtifactStore {
        ArtifactStore { root: root.into() }
    }

    /// The store's root directory.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Path of the artifact for `stage` on `model` with input fingerprint
    /// `fp`: `<root>/<stage>_<model>_<fp>.<ext>`.
    pub fn path(&self, stage: &str, model: &str, fp: u64, ext: &str) -> PathBuf {
        self.root.join(format!("{stage}_{model}_{fp:016x}.{ext}"))
    }

    /// Whether the artifact exists (a cache hit).
    pub fn contains(&self, stage: &str, model: &str, fp: u64, ext: &str) -> bool {
        self.path(stage, model, fp, ext).exists()
    }

    /// Create the root directory (idempotent).
    pub fn ensure_dir(&self) -> anyhow::Result<()> {
        std::fs::create_dir_all(&self.root)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprint_is_order_and_boundary_sensitive() {
        assert_ne!(fingerprint(["a", "b"]), fingerprint(["b", "a"]));
        assert_ne!(fingerprint(["ab"]), fingerprint(["a", "b"]));
        assert_ne!(fingerprint::<_, &str>([]), fingerprint([""]));
        assert_eq!(fingerprint(["x", "y"]), fingerprint(["x".to_string(), "y".to_string()]));
    }

    #[test]
    fn store_paths_embed_stage_model_and_fingerprint() {
        let store = ArtifactStore::new("/tmp/store");
        let p = store.path("dataset", "pico-llama", 0xabcd, "csv");
        assert_eq!(p, PathBuf::from("/tmp/store/dataset_pico-llama_000000000000abcd.csv"));
        assert!(!store.contains("dataset", "pico-llama", 0xabcd, "csv"));
    }
}
