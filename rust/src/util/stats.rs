//! Small statistics toolkit: summary stats, percentiles, least squares.
//!
//! Used by the metrics collectors, the Digital-Twin calibration fits, and
//! the experiment reports.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation (0.0 for len < 2).
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Minimum (+∞ for empty).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

/// Maximum (−∞ for empty).
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Linear-interpolated percentile, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Ordinary least squares for y ~ X·beta, X given row-major with k columns.
/// Solves the normal equations with Gaussian elimination + partial pivoting.
/// Returns beta of length k.
pub fn least_squares(x_rows: &[Vec<f64>], y: &[f64]) -> Vec<f64> {
    assert!(!x_rows.is_empty());
    assert_eq!(x_rows.len(), y.len());
    let k = x_rows[0].len();
    // Build X'X (k×k) and X'y (k).
    let mut xtx = vec![vec![0.0; k]; k];
    let mut xty = vec![0.0; k];
    for (row, &yi) in x_rows.iter().zip(y) {
        assert_eq!(row.len(), k);
        for i in 0..k {
            xty[i] += row[i] * yi;
            for j in 0..k {
                xtx[i][j] += row[i] * row[j];
            }
        }
    }
    // Tiny ridge for numerical safety on near-singular designs.
    for (i, row) in xtx.iter_mut().enumerate() {
        row[i] += 1e-9;
    }
    solve_linear(xtx, xty)
}

/// Solve A·x = b by Gaussian elimination with partial pivoting.
pub fn solve_linear(mut a: Vec<Vec<f64>>, mut b: Vec<f64>) -> Vec<f64> {
    let n = b.len();
    for col in 0..n {
        // pivot
        let piv = (col..n)
            .max_by(|&i, &j| a[i][col].abs().partial_cmp(&a[j][col].abs()).unwrap())
            .unwrap();
        a.swap(col, piv);
        b.swap(col, piv);
        let pivot = a[col][col];
        if pivot.abs() < 1e-14 {
            continue; // singular direction; leave zero
        }
        for row in col + 1..n {
            let f = a[row][col] / pivot;
            for c in col..n {
                a[row][c] -= f * a[col][c];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = vec![0.0; n];
    for row in (0..n).rev() {
        let mut s = b[row];
        for c in row + 1..n {
            s -= a[row][c] * x[c];
        }
        x[row] = if a[row][row].abs() < 1e-14 { 0.0 } else { s / a[row][row] };
    }
    x
}

/// Simple linear regression y = a + b·x; returns (a, b).
pub fn linreg(x: &[f64], y: &[f64]) -> (f64, f64) {
    let rows: Vec<Vec<f64>> = x.iter().map(|&xi| vec![1.0, xi]).collect();
    let beta = least_squares(&rows, y);
    (beta[0], beta[1])
}

/// Symmetric Mean Absolute Percentage Error in percent, as used throughout
/// the paper's evaluation (Tables 1, 3, 4).
pub fn smape(actual: &[f64], predicted: &[f64]) -> f64 {
    assert_eq!(actual.len(), predicted.len());
    if actual.is_empty() {
        return 0.0;
    }
    let s: f64 = actual
        .iter()
        .zip(predicted)
        .map(|(&a, &p)| {
            let denom = (a.abs() + p.abs()) / 2.0;
            if denom < 1e-12 {
                0.0
            } else {
                (a - p).abs() / denom
            }
        })
        .sum();
    100.0 * s / actual.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn linreg_recovers_line() {
        let x: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|&xi| 3.0 + 2.0 * xi).collect();
        let (a, b) = linreg(&x, &y);
        assert!((a - 3.0).abs() < 1e-9, "{a}");
        assert!((b - 2.0).abs() < 1e-9, "{b}");
    }

    #[test]
    fn least_squares_multi() {
        // y = 1 + 2*x1 - 3*x2
        let rows: Vec<Vec<f64>> = (0..100)
            .map(|i| {
                let x1 = (i % 10) as f64;
                let x2 = (i / 10) as f64;
                vec![1.0, x1, x2]
            })
            .collect();
        let y: Vec<f64> = rows.iter().map(|r| 1.0 + 2.0 * r[1] - 3.0 * r[2]).collect();
        let beta = least_squares(&rows, &y);
        assert!((beta[0] - 1.0).abs() < 1e-8);
        assert!((beta[1] - 2.0).abs() < 1e-8);
        assert!((beta[2] + 3.0).abs() < 1e-8);
    }

    #[test]
    fn smape_zero_for_exact() {
        assert_eq!(smape(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
    }

    #[test]
    fn smape_symmetric() {
        let a = smape(&[100.0], &[110.0]);
        let b = smape(&[110.0], &[100.0]);
        assert!((a - b).abs() < 1e-12);
        assert!((a - 100.0 * 10.0 / 105.0).abs() < 1e-9);
    }

    #[test]
    fn solve_linear_identity() {
        let a = vec![vec![1.0, 0.0], vec![0.0, 1.0]];
        let x = solve_linear(a, vec![5.0, -2.0]);
        assert_eq!(x, vec![5.0, -2.0]);
    }
}
