//! Substrate utilities built from scratch (the offline environment provides
//! no serde/clap/rand/rayon/criterion/proptest — see DESIGN.md §4).

pub mod bench;
pub mod cli;
pub mod csv;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;
