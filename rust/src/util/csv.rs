//! CSV writer/reader for experiment outputs and the ML dataset.

use std::io::Write;
use std::path::Path;

/// Column-ordered CSV table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    /// Header names, in column order.
    pub columns: Vec<String>,
    /// Data rows (each matches the column arity).
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// An empty table with the given header.
    pub fn new(columns: &[&str]) -> Table {
        Table { columns: columns.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    /// Append a row (panics on arity mismatch).
    pub fn push(&mut self, row: Vec<String>) {
        assert_eq!(row.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(row);
    }

    /// Append a row of displayable values.
    pub fn push_display(&mut self, row: &[&dyn std::fmt::Display]) {
        self.push(row.iter().map(|v| v.to_string()).collect());
    }

    /// Write the table as CSV, creating parent directories.
    pub fn write_file(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "{}", self.columns.join(","))?;
        for row in &self.rows {
            let quoted: Vec<String> = row.iter().map(|c| quote(c)).collect();
            writeln!(f, "{}", quoted.join(","))?;
        }
        Ok(())
    }

    /// Read a CSV file written by [`Table::write_file`].
    pub fn read_file(path: &Path) -> anyhow::Result<Table> {
        let s = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        let mut lines = s.lines();
        let header = lines.next().ok_or_else(|| anyhow::anyhow!("empty csv"))?;
        let columns: Vec<String> = split_line(header);
        let mut rows = vec![];
        for line in lines {
            if line.trim().is_empty() {
                continue;
            }
            let row = split_line(line);
            if row.len() != columns.len() {
                anyhow::bail!("csv arity mismatch in {}", path.display());
            }
            rows.push(row);
        }
        Ok(Table { columns, rows })
    }

    /// Index of a named column.
    pub fn col_index(&self, name: &str) -> anyhow::Result<usize> {
        self.columns
            .iter()
            .position(|c| c == name)
            .ok_or_else(|| anyhow::anyhow!("no csv column '{name}'"))
    }

    /// Extract a numeric column.
    pub fn f64_col(&self, name: &str) -> anyhow::Result<Vec<f64>> {
        let i = self.col_index(name)?;
        self.rows
            .iter()
            .map(|r| {
                r[i].parse::<f64>()
                    .map_err(|_| anyhow::anyhow!("non-numeric value '{}' in column {name}", r[i]))
            })
            .collect()
    }
}

fn quote(c: &str) -> String {
    if c.contains(',') || c.contains('"') || c.contains('\n') {
        format!("\"{}\"", c.replace('"', "\"\""))
    } else {
        c.to_string()
    }
}

fn split_line(line: &str) -> Vec<String> {
    let mut out = vec![];
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes && chars.peek() == Some(&'"') => {
                cur.push('"');
                chars.next();
            }
            '"' => in_quotes = !in_quotes,
            ',' if !in_quotes => {
                out.push(std::mem::take(&mut cur));
            }
            c => cur.push(c),
        }
    }
    out.push(cur);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip(){
        let dir = std::env::temp_dir().join(format!("csv_test_{}", std::process::id()));
        let path = dir.join("t.csv");
        let mut t = Table::new(&["a", "b"]);
        t.push(vec!["1".into(), "x,y".into()]);
        t.push(vec!["2.5".into(), "he said \"hi\"".into()]);
        t.write_file(&path).unwrap();
        let r = Table::read_file(&path).unwrap();
        assert_eq!(r.columns, vec!["a", "b"]);
        assert_eq!(r.rows[0][1], "x,y");
        assert_eq!(r.rows[1][1], "he said \"hi\"");
        assert_eq!(r.f64_col("a").unwrap(), vec![1.0, 2.5]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn arity_check() {
        let mut t = Table::new(&["a"]);
        t.push(vec!["1".into()]);
        assert_eq!(t.rows.len(), 1);
    }
}
