//! Seeded property-testing harness (proptest is unavailable offline).
//!
//! `check` runs a property over `n` random cases; on failure it reports the
//! failing seed so the case can be replayed deterministically, and attempts
//! a simple shrink by re-running with "smaller" generator sizes.

use super::rng::Rng;

/// Configuration for a property run.
pub struct Prop {
    /// Number of random cases to run.
    pub cases: usize,
    /// Master seed (per-case seeds derive from it).
    pub seed: u64,
    /// Property name (shown in the failure report).
    pub name: &'static str,
}

impl Prop {
    /// A property with the default case count and seed.
    pub fn new(name: &'static str) -> Prop {
        Prop { cases: 64, seed: 0xC0FFEE, name }
    }

    /// Override the case count.
    pub fn cases(mut self, n: usize) -> Prop {
        self.cases = n;
        self
    }

    /// Override the master seed.
    pub fn seed(mut self, s: u64) -> Prop {
        self.seed = s;
        self
    }

    /// Run `property(rng, size)` for sizes ramping from small to large.
    /// `property` returns Err(description) on failure.
    pub fn check<F>(&self, property: F)
    where
        F: Fn(&mut Rng, usize) -> Result<(), String>,
    {
        for case in 0..self.cases {
            // Ramp sizes so early cases are small (cheap shrinking).
            let size = 1 + case * 4 / self.cases.max(1) * 8 + case % 8;
            let case_seed = self.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
            let mut rng = Rng::new(case_seed);
            if let Err(msg) = property(&mut rng, size) {
                // Try to find a smaller failing size for a friendlier report.
                let mut min_fail = (size, msg.clone());
                for s in 1..size {
                    let mut r2 = Rng::new(case_seed);
                    if let Err(m2) = property(&mut r2, s) {
                        min_fail = (s, m2);
                        break;
                    }
                }
                panic!(
                    "property '{}' failed (case {case}, seed {case_seed:#x}, size {}): {}",
                    self.name, min_fail.0, min_fail.1
                );
            }
        }
    }
}

/// Assert-like helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        Prop::new("reverse twice is identity").cases(32).check(|rng, size| {
            let v: Vec<u64> = (0..size).map(|_| rng.next_u64()).collect();
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            prop_assert!(v == w, "mismatch");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        Prop::new("always fails").cases(4).check(|_, _| Err("nope".into()));
    }
}
