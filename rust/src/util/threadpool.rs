//! Scoped parallel map over std::thread (rayon is unavailable offline).
//!
//! Used for Digital-Twin dataset generation and experiment sweeps, where
//! each work item is an independent simulation.

/// Run `f` over all items on up to `workers` threads, preserving order.
pub fn parallel_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        return items.into_iter().map(f).collect();
    }

    let work: Vec<std::sync::Mutex<Option<T>>> =
        items.into_iter().map(|t| std::sync::Mutex::new(Some(t))).collect();
    let results: Vec<std::sync::Mutex<Option<R>>> =
        (0..n).map(|_| std::sync::Mutex::new(None)).collect();
    let next = std::sync::atomic::AtomicUsize::new(0);

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = work[i].lock().unwrap().take().unwrap();
                let r = f(item);
                *results[i].lock().unwrap() = Some(r);
            });
        }
    });

    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().unwrap())
        .collect()
}

/// Number of worker threads to default to.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = parallel_map(items, 8, |i| i * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_path() {
        let out = parallel_map(vec![1, 2, 3], 1, |i| i + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn more_workers_than_items() {
        let out = parallel_map(vec![5], 16, |i| i);
        assert_eq!(out, vec![5]);
    }
}
