//! Deterministic pseudo-random number generation (splitmix64 + xoshiro256++).
//!
//! The `rand` crate is unavailable offline, and determinism across the whole
//! pipeline (workload traces, dataset generation, ML training, placement
//! baselines) is a feature: every experiment is reproducible from its seed.

/// xoshiro256++ PRNG seeded via splitmix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed a new generator (any seed value is fine, including 0).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in s.iter_mut() {
            *slot = splitmix64(&mut sm);
        }
        Rng { s }
    }

    /// Derive an independent stream (for per-adapter / per-worker RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our (non-crypto) purposes.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi].
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        lo + (self.next_u64() % ((hi - lo + 1) as u64)) as i64
    }

    /// Bernoulli draw with success probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal (Box–Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal draw with the given mean and standard deviation.  (Named
    /// without a `_ms` shorthand so the unit-suffix lint's dimension
    /// table — where `_ms` means milliseconds — stays truthful.)
    pub fn normal_mean_std(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal with given *underlying* mu/sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate lambda (mean 1/lambda).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let lambda = 2.5;
        let mean = (0..n).map(|_| r.exp(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / lambda).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(1);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
