//! Minimal benchmarking harness (criterion is unavailable offline).
//!
//! `cargo bench` binaries (harness = false) use this: warmup, timed
//! iterations, mean/std/p50/p95 reporting, and a uniform output format that
//! bench_output.txt captures.

// Timing IS this module's job: `util::bench` is on detlint's wall-clock
// allowlist, and the clippy disallow is lifted file-wide to match.
#![allow(clippy::disallowed_methods)]

use super::stats;
use std::time::Instant;

/// Timing summary of one benchmark.
pub struct BenchResult {
    /// Benchmark name.
    pub name: String,
    /// Measured iterations.
    pub iters: usize,
    /// Mean per-iteration time (s).
    pub mean_s: f64,
    /// Standard deviation (s).
    pub std_s: f64,
    /// Median (s).
    pub p50_s: f64,
    /// 95th percentile (s).
    pub p95_s: f64,
}

impl BenchResult {
    /// Print the uniform one-line report format.
    pub fn report(&self) {
        println!(
            "bench {:<44} iters={:<5} mean={:>12} p50={:>12} p95={:>12} std={:>12}",
            self.name,
            self.iters,
            fmt_dur(self.mean_s),
            fmt_dur(self.p50_s),
            fmt_dur(self.p95_s),
            fmt_dur(self.std_s),
        );
    }
}

/// Human-friendly duration (`1.5ms`, `3.2us`, ...).
pub fn fmt_dur(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}us", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

/// Time `f` with `warmup` unmeasured runs then `iters` measured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_s: stats::mean(&samples),
        std_s: stats::std(&samples),
        p50_s: stats::percentile(&samples, 50.0),
        p95_s: stats::percentile(&samples, 95.0),
    };
    r.report();
    r
}

/// Auto-calibrating variant: picks an iteration count so the total measured
/// time is roughly `target_s` seconds.
pub fn bench_auto<F: FnMut()>(name: &str, target_s: f64, mut f: F) -> BenchResult {
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target_s / once) as usize).clamp(3, 10_000);
    bench(name, (iters / 10).max(1), iters, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 1, 10, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert_eq!(r.iters, 10);
        assert!(r.mean_s >= 0.0);
    }

    #[test]
    fn fmt_dur_units() {
        assert!(fmt_dur(2.0).ends_with('s'));
        assert!(fmt_dur(2e-3).ends_with("ms"));
        assert!(fmt_dur(2e-6).ends_with("us"));
        assert!(fmt_dur(2e-9).ends_with("ns"));
    }
}
