//! Minimal JSON parser and writer (serde is unavailable offline).
//!
//! Supports the full JSON grammar we need: objects, arrays, strings with
//! escapes, numbers, booleans, null.  Used for the artifact manifest, the
//! config system, calibration files, trained-model serialization and
//! experiment reports.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always held as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys for stable output).
    Obj(BTreeMap<String, Json>),
}

/// A parse failure with its byte position.
#[derive(Debug)]
pub struct JsonError {
    /// Byte offset of the failure.
    pub pos: usize,
    /// Human-readable description.
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ------------------------------------------------------------------
    // Accessors
    // ------------------------------------------------------------------

    /// Object field access (`None` on non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field access that errors with the key name (for manifests).
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key '{key}'"))
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric value truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Array of numbers (non-numbers silently dropped).
    pub fn f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()
            .map(|v| v.iter().filter_map(Json::as_f64).collect())
    }

    /// Array of usize (non-numbers silently dropped).
    pub fn usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()
            .map(|v| v.iter().filter_map(Json::as_usize).collect())
    }

    // ------------------------------------------------------------------
    // Builders
    // ------------------------------------------------------------------

    /// Build an object from (key, value) pairs.
    pub fn obj(entries: Vec<(&str, Json)>) -> Json {
        Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build a numeric array.
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Build a string array.
    pub fn arr_str(xs: &[String]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Str(x.clone())).collect())
    }

    // ------------------------------------------------------------------
    // Parse / write
    // ------------------------------------------------------------------

    /// Parse a complete JSON document.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Read and parse a JSON file.
    pub fn read_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let s = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Ok(Json::parse(&s).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?)
    }

    /// Pretty-print to a file, creating parent directories.
    pub fn write_file(&self, path: &std::path::Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.pretty())?;
        Ok(())
    }

    /// Compact single-line rendering.
    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Two-space-indented rendering.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, level: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, level + 1);
                    item.write(out, indent, level + 1);
                }
                newline(out, indent, level);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, level + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, level + 1);
                }
                newline(out, indent, level);
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, level: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * level {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() && n == n.trunc() && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else if n.is_finite() {
        let _ = write!(out, "{n}");
    } else {
        out.push_str("null"); // JSON has no inf/nan
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs unsupported (not emitted by our writers).
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let rest = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let is_num_byte = |c: u8| {
            c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-'
        };
        while matches!(self.peek(), Some(c) if is_num_byte(c)) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn roundtrip() {
        let j = Json::obj(vec![
            ("name", Json::Str("pico".into())),
            ("dims", Json::arr_f64(&[1.0, 2.5, -3.0])),
            ("nested", Json::obj(vec![("ok", Json::Bool(true))])),
        ]);
        let s = j.pretty();
        assert_eq!(Json::parse(&s).unwrap(), j);
        let s2 = j.to_string();
        assert_eq!(Json::parse(&s2).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn reads_real_manifest_like_doc() {
        let doc = r#"{"version": 1, "models": {"pico-llama": {"decode": {"1": "a.hlo.txt"}}}}"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn integers_written_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }
}
