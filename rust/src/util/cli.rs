//! Tiny command-line argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, positional args and subcommands with
//! auto-generated usage text.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Non-option arguments, in order.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw args.  `flag_names` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, flag_names: &[&str]) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&name) {
                    out.flags.push(name.to_string());
                } else if let Some(v) = it.peek() {
                    if v.starts_with("--") {
                        out.flags.push(name.to_string());
                    } else {
                        out.options.insert(name.to_string(), it.next().unwrap());
                    }
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Whether `--name` was passed as a flag.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// The value of option `--name`, if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// The value of option `--name`, or `default`.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Integer option with a default; errors on unparsable input.
    pub fn usize_or(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    /// Float option with a default; errors on unparsable input.
    pub fn f64_or(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got '{v}'")),
        }
    }

    /// Required option; errors when absent.
    pub fn require(&self, name: &str) -> anyhow::Result<&str> {
        self.get(name)
            .ok_or_else(|| anyhow::anyhow!("missing required option --{name}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str, flags: &[&str]) -> Args {
        Args::parse(s.split_whitespace().map(String::from), flags)
    }

    #[test]
    fn positional_and_options() {
        let a = parse("experiment fig11 --scale quick --gpus 4", &[]);
        assert_eq!(a.positional, vec!["experiment", "fig11"]);
        assert_eq!(a.get("scale"), Some("quick"));
        assert_eq!(a.usize_or("gpus", 1).unwrap(), 4);
    }

    #[test]
    fn flags_and_eq_syntax() {
        let a = parse("--verbose --out=x.json --n 3", &["verbose"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.get("out"), Some("x.json"));
        assert_eq!(a.usize_or("n", 0).unwrap(), 3);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse("--check", &[]);
        assert!(a.flag("check"));
    }

    #[test]
    fn flag_followed_by_option() {
        let a = parse("--fast --out x", &[]);
        assert!(a.flag("fast"));
        assert_eq!(a.get("out"), Some("x"));
    }

    #[test]
    fn bad_number_errors() {
        let a = parse("--n abc", &[]);
        assert!(a.usize_or("n", 0).is_err());
    }
}
