//! Tiny command-line argument parser (clap is unavailable offline).
//!
//! Supports `--flag` (for names listed in `flag_names`), `--key value`,
//! `--key=value`, positional args and subcommands.  `--key=value` is the
//! documented escape for values that themselves start with `--`; a bare
//! `--name` that is not a registered flag is an error rather than a
//! silently-ignored option.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Non-option arguments, in order.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw args.  `flag_names` lists options that take no value.
    ///
    /// Errors on a bare `--name` that is not in `flag_names` — either the
    /// option is missing its value (if the next token starts with `--`,
    /// write `--name=VALUE`) or the flag is unknown.  This turns the
    /// historical silent misparse of `--key --value-looking-like-flag`
    /// (two bogus flags) into a diagnostic.
    pub fn parse<I: IntoIterator<Item = String>>(
        raw: I,
        flag_names: &[&str],
    ) -> anyhow::Result<Args> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&name) {
                    out.flags.push(name.to_string());
                } else {
                    match it.peek() {
                        Some(v) if !v.starts_with("--") => {
                            out.options.insert(name.to_string(), it.next().unwrap());
                        }
                        _ => anyhow::bail!(
                            "--{name} is not a flag and has no value; pass --{name} VALUE \
                             (or --{name}=VALUE if the value starts with '--'). Known flags: \
                             {flag_names:?}"
                        ),
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Whether `--name` was passed as a flag.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// The value of option `--name`, if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// The value of option `--name`, or `default`.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Integer option with a default; errors on unparsable input.
    pub fn usize_or(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    /// Float option with a default; errors on unparsable input.
    pub fn f64_or(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got '{v}'")),
        }
    }

    /// Required option; errors when absent.
    pub fn require(&self, name: &str) -> anyhow::Result<&str> {
        self.get(name)
            .ok_or_else(|| anyhow::anyhow!("missing required option --{name}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str, flags: &[&str]) -> anyhow::Result<Args> {
        Args::parse(s.split_whitespace().map(String::from), flags)
    }

    #[test]
    fn positional_and_options() {
        let a = parse("experiment fig11 --scale quick --gpus 4", &[]).unwrap();
        assert_eq!(a.positional, vec!["experiment", "fig11"]);
        assert_eq!(a.get("scale"), Some("quick"));
        assert_eq!(a.usize_or("gpus", 1).unwrap(), 4);
    }

    #[test]
    fn flags_and_eq_syntax() {
        let a = parse("--verbose --out=x.json --n 3", &["verbose"]).unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.get("out"), Some("x.json"));
        assert_eq!(a.usize_or("n", 0).unwrap(), 3);
    }

    #[test]
    fn registered_trailing_flag_parses() {
        let a = parse("--check", &["check"]).unwrap();
        assert!(a.flag("check"));
    }

    #[test]
    fn unknown_bare_flag_is_rejected() {
        // Historically this parsed as a silent flag; now it is an error.
        let err = parse("--check", &[]).unwrap_err();
        assert!(err.to_string().contains("--check"), "{err}");
    }

    #[test]
    fn option_followed_by_flag_like_value_is_rejected() {
        // `--out --weird` used to misparse into TWO flags; now it errors
        // and points at the `--out=VALUE` escape.
        let err = parse("--out --weird", &[]).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("--out"), "{msg}");
        assert!(msg.contains("--out=VALUE"), "{msg}");
    }

    #[test]
    fn eq_syntax_escapes_flag_like_values() {
        let a = parse("--out=--weird --n 3", &[]).unwrap();
        assert_eq!(a.get("out"), Some("--weird"));
        assert_eq!(a.usize_or("n", 0).unwrap(), 3);
    }

    #[test]
    fn registered_flag_followed_by_option() {
        let a = parse("--fast --out x", &["fast"]).unwrap();
        assert!(a.flag("fast"));
        assert_eq!(a.get("out"), Some("x"));
    }

    #[test]
    fn bad_number_errors() {
        let a = parse("--n abc", &[]).unwrap();
        assert!(a.usize_or("n", 0).is_err());
    }
}
