//! Baseline placement strategies (paper §8.4.1-2): MaxBase, MaxBase* and
//! Random.  MaxBase/MaxBase* fill GPUs up to the backbone's benchmarked
//! maximum throughput, blind to adapter overheads and memory dynamics —
//! which is exactly why they starve or OOM past `Max_pack`.

use super::{Placement, PlacementError, PlacementResult};
use crate::util::rng::Rng;
use crate::workload::AdapterSpec;

/// MaxBase: fill each GPU until the aggregate incoming token rate reaches
/// `backbone_max_tok_s`; set `A_max = A` (adapters on the GPU).
/// MaxBase* differs only in `A_max = A/2` (`halve_parallelism`).
pub fn max_base(
    adapters: &[AdapterSpec],
    gpus: usize,
    backbone_max_tok_s: f64,
    tokens_per_request: f64,
    halve_parallelism: bool,
) -> PlacementResult {
    let mut placement = Placement { assignment: Default::default(), a_max: vec![0; gpus] };
    let mut g = 0usize;
    let mut load = 0.0f64;
    let mut count = 0usize;
    for a in adapters {
        let demand = a.rate * tokens_per_request;
        if load + demand > backbone_max_tok_s && count > 0 {
            // GPU "full" by the backbone metric: move on.
            // detlint: allow(panic-path) — `a_max` sized to the fleet/group count at construction; ordinals in range
            placement.a_max[g] = if halve_parallelism { (count / 2).max(1) } else { count };
            g += 1;
            load = 0.0;
            count = 0;
            if g >= gpus {
                return Err(PlacementError::Starvation);
            }
        }
        placement.assignment.insert(a.id, g);
        load += demand;
        count += 1;
    }
    if count > 0 {
        // detlint: allow(panic-path) — `a_max` sized to the fleet/group count at construction; ordinals in range
        placement.a_max[g] = if halve_parallelism { (count / 2).max(1) } else { count };
    }
    Ok(placement)
}

/// Random: uniform GPU per adapter; `A_max[g]` uniform in [1, count(g)].
pub fn random(adapters: &[AdapterSpec], gpus: usize, seed: u64) -> PlacementResult {
    let mut rng = Rng::new(seed ^ 0x0DD5);
    let mut placement = Placement { assignment: Default::default(), a_max: vec![0; gpus] };
    let mut counts = vec![0usize; gpus];
    for a in adapters {
        let g = rng.below(gpus);
        placement.assignment.insert(a.id, g);
        // detlint: allow(panic-path) — `counts` sized to the fleet/group count at construction; ordinals in range
        counts[g] += 1;
    }
    for g in 0..gpus {
        // detlint: allow(panic-path) — `a_max`/`counts` sized to the fleet/group count at construction; ordinals in range
        if counts[g] > 0 {
            placement.a_max[g] = rng.range(1, counts[g] as i64) as usize;
        }
    }
    Ok(placement)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn adapters(n: usize, rate: f64) -> Vec<AdapterSpec> {
        (0..n).map(|id| AdapterSpec { id, rank: 8, rate }).collect()
    }

    #[test]
    fn max_base_fills_sequentially() {
        // capacity 500 tok/s, 96 tok/req, rate 1.0 → ~5 adapters per GPU.
        let p = max_base(&adapters(10, 1.0), 4, 500.0, 96.0, false).unwrap();
        assert!(p.gpus_used() == 2);
        // A_max equals the adapter count on each used GPU.
        for g in 0..2 {
            assert_eq!(p.a_max[g], p.adapters_on(g).len());
        }
    }

    #[test]
    fn max_base_star_halves_a_max() {
        let p = max_base(&adapters(10, 1.0), 4, 500.0, 96.0, true).unwrap();
        for g in 0..p.gpus_used() {
            let n = p.adapters_on(g).len();
            assert_eq!(p.a_max[g], (n / 2).max(1));
        }
    }

    #[test]
    fn max_base_overflow_is_starvation() {
        assert_eq!(
            max_base(&adapters(100, 1.0), 2, 300.0, 96.0, false).unwrap_err(),
            PlacementError::Starvation
        );
    }

    #[test]
    fn random_assigns_everyone_and_bounds_a_max() {
        let p = random(&adapters(50, 0.1), 4, 7).unwrap();
        assert_eq!(p.assignment.len(), 50);
        for g in 0..4 {
            let n = p.adapters_on(g).len();
            if n > 0 {
                assert!((1..=n).contains(&p.a_max[g]));
            }
        }
        // Random "almost always utilizes all available GPUs" (paper).
        assert_eq!(p.gpus_used(), 4);
    }
}
