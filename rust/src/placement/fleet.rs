//! Alg. 1 over a **typed fleet** — heterogeneous GPU classes with
//! per-class memory, price and calibrated performance (DESIGN.md §11).
//!
//! [`place`] generalizes [`super::greedy::place`] from "N identical
//! GPUs" to a [`FleetSpec`]: GPUs are *opened* lazily from the per-type
//! stock instead of pre-existing, and when a fresh GPU is needed the
//! [`Objective`] picks which class to open — [`crate::placement::MinCost`]
//! probes each in-stock class with the head adapter and opens the best
//! cost-normalized feasible throughput (the Mélange-style heterogeneity
//! lever), while [`crate::placement::MinGpus`] keeps fleet-declaration
//! order.  Everything else (provisional packing, the TestAllocation
//! commit/rollback at the testing points, leftover validation) is the
//! shared Alg. 1/Alg. 2 machinery from [`super::greedy`], so a
//! single-type fleet issues a **bit-identical probe sequence** and
//! reproduces the homogeneous plan exactly — cache stats included.
//!
//! [`TypedEstimator`] gives each class's estimator a gpu-type dimension
//! in its [`PerfEstimator::memo_key`], so one shared memo store can hold
//! several classes' probes without collisions.

use super::estimator::{Estimate, PerfEstimator, ProbeQuery};
use super::greedy::{self, GpuState};
use super::objective::{Objective, OpenCandidate};
use super::{MAX_TESTING_POINT, Placement, PlacementError, TESTING_POINTS};
use crate::config::FleetSpec;
use crate::workload::AdapterSpec;
use std::collections::VecDeque;

/// A placement onto a typed fleet: the assignment plus each GPU slot's
/// type index.  `placement.a_max` and `gpu_type` both have
/// `fleet.total_gpus()` entries — opened GPUs first (in open order),
/// then the unopened stock (a_max 0) in type order, so a single-type
/// fleet's `placement` is structurally identical to the homogeneous
/// planner's output.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetPlacement {
    /// The adapter→GPU assignment and per-GPU `A_max` configuration.
    pub placement: Placement,
    /// Type index (into [`FleetSpec::types`]) of every GPU slot.
    pub gpu_type: Vec<usize>,
}

impl FleetPlacement {
    /// GPUs actually serving adapters.
    pub fn gpus_used(&self) -> usize {
        self.placement.gpus_used()
    }

    /// Hourly rental cost of the used GPUs under the fleet's prices.
    pub fn cost_per_hour(&self, fleet: &FleetSpec) -> f64 {
        self.placement
            .a_max
            .iter()
            .zip(&self.gpu_type)
            .filter(|&(&a_max, _)| a_max > 0)
            // detlint: allow(panic-path) — `types` sized to the fleet/group count at construction; ordinals in range
            .map(|(_, &t)| fleet.types[t].cost_per_hour)
            .sum()
    }

    /// Used-GPU count per type, in type-index order.
    pub fn used_by_type(&self, fleet: &FleetSpec) -> Vec<usize> {
        let mut counts = vec![0usize; fleet.types.len()];
        for (&a_max, &t) in self.placement.a_max.iter().zip(&self.gpu_type) {
            if a_max > 0 {
                // detlint: allow(panic-path) — `counts` sized to the fleet/group count at construction; ordinals in range
                counts[t] += 1;
            }
        }
        counts
    }
}

/// A [`PerfEstimator`] wrapper that prefixes every
/// [`PerfEstimator::memo_key`] with a GPU-type ordinal, so per-class
/// probes of otherwise identical groups can never collide in a shared
/// memo ([`crate::placement::CachedEstimator`]).
pub struct TypedEstimator<E> {
    inner: E,
    type_index: u64,
}

impl<E: PerfEstimator> TypedEstimator<E> {
    /// Tag `inner`'s memo keys with the fleet `type_index`.
    pub fn new(inner: E, type_index: usize) -> TypedEstimator<E> {
        TypedEstimator { inner, type_index: type_index as u64 }
    }
}

impl<E: PerfEstimator> PerfEstimator for TypedEstimator<E> {
    fn estimate(&self, adapters: &[AdapterSpec], a_max: usize) -> Estimate {
        self.inner.estimate(adapters, a_max)
    }

    fn estimate_batch(&self, queries: &[ProbeQuery<'_>]) -> Vec<Estimate> {
        self.inner.estimate_batch(queries)
    }

    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn memo_key(&self, adapters: &[AdapterSpec], a_max: usize) -> Vec<u64> {
        let mut key = vec![self.type_index];
        key.extend(self.inner.memo_key(adapters, a_max));
        key
    }
}

/// Pick which GPU class to open for `head` (the adapter that needs a
/// fresh GPU).  With one in-stock class — or an objective that declines
/// probing — no probes are issued, which keeps single-type fleets
/// bit-identical to the homogeneous planner.
fn choose_open_type(
    head: &AdapterSpec,
    remaining: &[usize],
    fleet: &FleetSpec,
    ests: &[&dyn PerfEstimator],
    objective: &dyn Objective,
) -> Result<usize, PlacementError> {
    // detlint: allow(panic-path) — `remaining` sized to the fleet/group count at construction; ordinals in range
    let avail: Vec<usize> = (0..remaining.len()).filter(|&t| remaining[t] > 0).collect();
    let Some(&first) = avail.first() else {
        return Err(PlacementError::Starvation);
    };
    if avail.len() == 1 {
        return Ok(first);
    }
    let candidates: Vec<OpenCandidate> = if objective.probes_open_candidates() {
        let group = [head.clone()];
        avail
            .iter()
            .map(|&t| {
                // detlint: allow(panic-path) — `ests` sized to the fleet/group count at construction; ordinals in range
                let e = ests[t].estimate(&group, TESTING_POINTS[0]);
                OpenCandidate {
                    type_index: t,
                    // detlint: allow(panic-path) — `types` sized to the fleet/group count at construction; ordinals in range
                    cost_per_hour: fleet.types[t].cost_per_hour,
                    throughput_tok_s: e.throughput_tok_s,
                    feasible: e.feasible(),
                }
            })
            .collect()
    } else {
        avail
            .iter()
            .map(|&t| OpenCandidate {
                type_index: t,
                // detlint: allow(panic-path) — `types` sized to the fleet/group count at construction; ordinals in range
                cost_per_hour: fleet.types[t].cost_per_hour,
                throughput_tok_s: 0.0,
                feasible: true,
            })
            .collect()
    };
    let chosen = objective.open_type(&candidates);
    debug_assert!(avail.contains(&chosen), "objective chose an out-of-stock type");
    Ok(chosen)
}

/// Alg. 1 over a typed fleet.  `ests` holds one estimator per fleet
/// type, in [`FleetSpec::types`] order — each answering probes under
/// that class's calibration and memory config.  Returns
/// `Err(Starvation)` when no starvation-free allocation exists within
/// the fleet's stock.
pub fn place(
    adapters: &[AdapterSpec],
    fleet: &FleetSpec,
    ests: &[&dyn PerfEstimator],
    objective: &dyn Objective,
) -> Result<FleetPlacement, PlacementError> {
    assert_eq!(ests.len(), fleet.types.len(), "one estimator per fleet type");
    let sorted = greedy::priority_sorting(adapters);
    let mut a_q: VecDeque<AdapterSpec> = sorted.into();
    let mut remaining: Vec<usize> = fleet.counts.clone();
    // Opened GPUs, indexed in open order (these become GPU indices
    // 0..states.len() of the final placement — exactly the index order
    // the homogeneous planner assigns).
    let mut states: Vec<GpuState> = vec![];
    let mut gpu_type: Vec<usize> = vec![];
    let mut g_q: VecDeque<usize> = VecDeque::new();
    let testing: std::collections::BTreeSet<usize> = TESTING_POINTS.iter().copied().collect();

    while let Some(a) = a_q.pop_front() {
        let g = match g_q.pop_front() {
            Some(g) => g,
            None => {
                // Open a fresh GPU from the stock; the objective picks
                // the class.  A rolled-back (retired) GPU stays consumed,
                // mirroring the homogeneous planner's burned GPU index.
                let t = choose_open_type(&a, &remaining, fleet, ests, objective)?;
                // detlint: allow(panic-path) — `remaining` sized to the fleet/group count at construction; ordinals in range
                remaining[t] -= 1;
                states.push(GpuState::default());
                gpu_type.push(t);
                states.len() - 1
            }
        };
        // detlint: allow(panic-path) — `states` sized to the fleet/group count at construction; ordinals in range
        states[g].provisional.push(a); // ProvisionalInclude
        let at_testing_point = testing.contains(&states[g].count())
            // detlint: allow(panic-path) — `states` sized to the fleet/group count at construction; ordinals in range
            || states[g].count() >= MAX_TESTING_POINT;
        if at_testing_point {
            // detlint: allow(panic-path) — `ests`/`gpu_type`/`states` sized to the fleet/group count at construction; ordinals in range
            let (ok, p_new) = greedy::test_allocation(&states[g], ests[gpu_type[g]]);
            if ok {
                // CommitAllocation
                // detlint: allow(panic-path) — `states` sized to the fleet/group count at construction; ordinals in range
                let prov = std::mem::take(&mut states[g].provisional);
                states[g].committed.extend(prov);
                // detlint: allow(panic-path) — `states` sized to the fleet/group count at construction; ordinals in range
                states[g].a_max = p_new;
                g_q.push_front(g);
            } else {
                // RollbackAllocation + Merge: provisional adapters return
                // to the head of the queue and the GPU is retired with
                // what it already committed.
                // detlint: allow(panic-path) — `states` sized to the fleet/group count at construction; ordinals in range
                let un_alloc = std::mem::take(&mut states[g].provisional);
                for a in un_alloc.into_iter().rev() {
                    a_q.push_front(a);
                }
            }
        } else {
            g_q.push_front(g);
        }
    }

    // Validate any leftover provisional allocations (Alg. 1 lines 24-28).
    for (st, &t) in states.iter_mut().zip(&gpu_type) {
        if !st.provisional.is_empty() {
            // detlint: allow(panic-path) — `ests` sized to the fleet/group count at construction; ordinals in range
            let (ok, p_new) = greedy::test_allocation(st, ests[t]);
            if !ok {
                return Err(PlacementError::Starvation);
            }
            let prov = std::mem::take(&mut st.provisional);
            st.committed.extend(prov);
            st.a_max = p_new;
        } else if !st.committed.is_empty() && st.a_max == 0 {
            // detlint: allow(panic-path) — `ests` sized to the fleet/group count at construction; ordinals in range
            let (ok, p_new) = greedy::test_allocation(st, ests[t]);
            if !ok {
                return Err(PlacementError::Starvation);
            }
            st.a_max = p_new;
        }
    }

    // Pad to the full fleet size: unopened stock follows the opened GPUs,
    // in type order, with a_max 0 — structurally identical to the
    // homogeneous planner's `vec![0; gpus]` shape.
    let total = fleet.total_gpus();
    let mut placement = Placement { assignment: Default::default(), a_max: vec![0; total] };
    for (g, st) in states.iter().enumerate() {
        for a in &st.committed {
            placement.assignment.insert(a.id, g);
        }
        // detlint: allow(panic-path) — `a_max` sized to the fleet/group count at construction; ordinals in range
        placement.a_max[g] = st.a_max;
    }
    for (t, &left) in remaining.iter().enumerate() {
        gpu_type.extend(std::iter::repeat_n(t, left));
    }
    debug_assert_eq!(gpu_type.len(), total);
    if placement.assignment.len() != adapters.len() {
        return Err(PlacementError::Starvation);
    }
    Ok(FleetPlacement { placement, gpu_type })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FleetSpec, GpuTypeSpec};
    use crate::placement::{greedy, CachedEstimator, MinCost, MinGpus};

    /// The shared analytic stand-in models (capacity 1000 − 2·a_max,
    /// starved when demand exceeds it) — same family as the homogeneous
    /// planner tests, so parity is comparable probe-for-probe.
    fn models() -> crate::ml::MlModels {
        crate::placement::test_models::analytic_models(1)
    }

    fn adapters(n: usize, rate: f64) -> Vec<AdapterSpec> {
        (0..n).map(|id| AdapterSpec { id, rank: 8, rate }).collect()
    }

    fn single_fleet(count: usize) -> FleetSpec {
        FleetSpec::single(GpuTypeSpec::catalog("a10g").unwrap(), count)
    }

    #[test]
    fn single_type_fleet_is_bit_identical_to_homogeneous_including_cache_stats() {
        for (n, rate, gpus) in [(16, 0.1, 4), (64, 0.3, 4), (32, 0.1, 2)] {
            let ads = adapters(n, rate);
            let homog = CachedEstimator::wrap(models());
            let expected = greedy::place(&ads, gpus, &homog).unwrap();

            let typed = CachedEstimator::wrap(TypedEstimator::new(models(), 0));
            let fleet = single_fleet(gpus);
            let got = place(&ads, &fleet, &[&typed], &MinGpus).unwrap();
            assert_eq!(got.placement, expected, "plan diverged for n={n}");
            assert_eq!(got.gpu_type, vec![0; gpus]);
            // Identical probe sequence → identical hit/miss/entry counts.
            assert_eq!(typed.stats(), homog.stats(), "cache stats diverged for n={n}");

            // MinCost on a single-type fleet degenerates to MinGpus.
            let typed2 = CachedEstimator::wrap(TypedEstimator::new(models(), 0));
            let got2 = place(&ads, &fleet, &[&typed2], &MinCost).unwrap();
            assert_eq!(got2.placement, expected);
            assert_eq!(typed2.stats(), homog.stats());
        }
    }

    #[test]
    fn starvation_when_stock_runs_out() {
        let ads = adapters(384, 1.0);
        let est = models();
        let fleet = single_fleet(4);
        let err = place(&ads, &fleet, &[&est], &MinGpus).unwrap_err();
        assert_eq!(err, PlacementError::Starvation);
    }

    #[test]
    fn cost_accounting_uses_per_type_prices() {
        let ads = adapters(16, 0.1);
        let est0 = models();
        let est1 = models();
        let mut cheap = GpuTypeSpec::catalog("a10g").unwrap();
        cheap.cost_per_hour = 2.0;
        let mut exp = GpuTypeSpec::catalog("a100").unwrap();
        exp.cost_per_hour = 5.0;
        let fleet = FleetSpec::new(vec![(cheap, 2), (exp, 2)]);
        let fp = place(&ads, &fleet, &[&est0, &est1], &MinGpus).unwrap();
        assert_eq!(fp.placement.assignment.len(), 16);
        let by_type = fp.used_by_type(&fleet);
        assert_eq!(
            fp.cost_per_hour(&fleet),
            by_type[0] as f64 * 2.0 + by_type[1] as f64 * 5.0
        );
        assert_eq!(fp.gpu_type.len(), fleet.total_gpus());
    }

    #[test]
    fn typed_memo_keys_do_not_collide_across_types() {
        let a = TypedEstimator::new(models(), 0);
        let b = TypedEstimator::new(models(), 1);
        let ads = adapters(4, 0.1);
        assert_ne!(a.memo_key(&ads, 8), b.memo_key(&ads, 8));
        assert_eq!(a.memo_key(&ads, 8)[1..], b.memo_key(&ads, 8)[1..]);
    }
}
