//! ProposedLat (paper §8.4.4): the latency-oriented proof-of-concept
//! variant of the pipeline.  Assigns each adapter to the GPU with the
//! lowest aggregated arrival rate, sets `A_max` to the per-GPU adapter
//! count, and validates the resulting allocation with a pluggable
//! [`PerfEstimator`] (starvation / memory-error veto).  Spreading for
//! latency is this algorithm's built-in goal — it *is* the
//! [`crate::placement::MinLatency`] objective's planner.

use super::estimator::{PerfEstimator, ProbeQuery};
use super::{Placement, PlacementError, PlacementResult};
use crate::workload::AdapterSpec;

/// ProposedLat: least-loaded spreading with a post-hoc estimator veto.
///
/// Generic over the [`PerfEstimator`] seam; `&MlModels` coerces, so the
/// deployed ML path reads `place(&adapters, gpus, &models)` unchanged.
pub fn place(adapters: &[AdapterSpec], gpus: usize, est: &dyn PerfEstimator) -> PlacementResult {
    let mut placement = Placement { assignment: Default::default(), a_max: vec![0; gpus] };
    let mut loads = vec![0.0f64; gpus];
    let mut per_gpu: Vec<Vec<AdapterSpec>> = vec![Vec::new(); gpus];
    for a in adapters {
        // detlint: allow(panic-path) — `loads` sized to the fleet/group count at construction; ordinals in range
        let g = (0..gpus).min_by(|&x, &y| loads[x].total_cmp(&loads[y])).unwrap_or(0);
        placement.assignment.insert(a.id, g);
        // detlint: allow(panic-path) — `loads`/`per_gpu` sized to the fleet/group count at construction; ordinals in range
        loads[g] += a.rate;
        per_gpu[g].push(a.clone());
    }
    for g in 0..gpus {
        // detlint: allow(panic-path) — `a_max`/`per_gpu` sized to the fleet/group count at construction; ordinals in range
        placement.a_max[g] = per_gpu[g].len();
    }
    // Post-hoc validation: any predicted starvation or memory error makes
    // the whole allocation infeasible (the ML training data folds memory
    // errors into the starvation label; other estimators flag them apart).
    // All per-GPU vetoes go down as one batch — a parallel-capable
    // estimator probes them concurrently; the feasibility reduction stays
    // in GPU order, so the verdict is bit-identical to the serial loop.
    let queries: Vec<ProbeQuery<'_>> = (0..gpus)
        // detlint: allow(panic-path) — `a_max`/`per_gpu` sized to the fleet/group count at construction; ordinals in range
        .filter(|&g| !per_gpu[g].is_empty())
        .map(|g| ProbeQuery { adapters: &per_gpu[g], a_max: placement.a_max[g] })
        .collect();
    if est.estimate_batch(&queries).iter().any(|e| !e.feasible()) {
        return Err(PlacementError::Starvation);
    }
    Ok(placement)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::refine::FlatTree;
    use crate::ml::tree::{Criterion, Tree, TreeParams};
    use crate::ml::{MlModels, Predictor};

    fn models(starve_above_rate: f64) -> MlModels {
        let mut xs = vec![];
        let mut st = vec![];
        let mut rng = crate::util::rng::Rng::new(2);
        for _ in 0..500 {
            let sum_rate = rng.range_f64(0.0, 10.0);
            let mut x = vec![0.0; crate::ml::N_FEATURES];
            x[1] = sum_rate;
            xs.push(x);
            st.push((sum_rate > starve_above_rate) as i32 as f64);
        }
        let params = TreeParams { criterion: Criterion::Gini, ..Default::default() };
        let t = Tree::fit(&xs, &st, &params);
        let thr = Tree::fit(&xs, &[100.0; 500], &TreeParams::default());
        MlModels {
            throughput: Predictor::Tree(thr),
            starvation: Predictor::Flat(FlatTree::compile(&t)),
            scaler: None,
        }
    }

    fn adapters(n: usize, rate: f64) -> Vec<AdapterSpec> {
        (0..n).map(|id| AdapterSpec { id, rank: 8, rate }).collect()
    }

    #[test]
    fn spreads_over_all_gpus() {
        let p = place(&adapters(16, 0.1), 4, &models(100.0)).unwrap();
        assert_eq!(p.gpus_used(), 4);
        // Balanced: 4 adapters per GPU, A_max = count.
        for g in 0..4 {
            assert_eq!(p.adapters_on(g).len(), 4);
            assert_eq!(p.a_max[g], 4);
        }
    }

    #[test]
    fn rejects_predicted_starvation() {
        // 2.0 total rate per GPU > 1.5 threshold → infeasible.
        let err = place(&adapters(16, 0.5), 4, &models(1.5)).unwrap_err();
        assert_eq!(err, PlacementError::Starvation);
    }
}
