//! The optimization-objective seam of the placement layer.
//!
//! The paper's pipeline minimizes *GPU count* (Alg. 1); its §8.4.4
//! ProposedLat variant minimizes *inter-token latency* by spreading load.
//! [`Objective`] makes that choice a first-class trait so the one-shot
//! planners and the incremental replanner ([`crate::placement::replan`])
//! can serve either goal — and the drift control loop can compare them
//! over time (GPUs-over-time vs ITL-over-time, `experiment drift`).
//!
//! An objective answers three questions:
//!
//! 1. **ranking** — which feasible GPU candidate is best for the next
//!    adapter ([`Objective::cost`], lexicographic, smaller is better);
//! 2. **stickiness** — when should a replanned adapter stay on its
//!    previous GPU instead of migrating ([`Objective::keeps`]);
//! 3. **shape** — pack-and-consolidate or spread
//!    ([`Objective::consolidates`], which also selects the cold-start
//!    planner in the default [`Objective::plan`]).

use super::estimator::PerfEstimator;
use super::replan::ReplanParams;
use super::{greedy, latency, PlacementResult};
use crate::workload::AdapterSpec;

/// A feasible "place adapter X on GPU g" option scored by an [`Objective`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Candidate {
    /// Target GPU index.
    pub gpu: usize,
    /// Whether the GPU already serves adapters (before this candidate).
    pub used: bool,
    /// The `A_max` testing point the estimator validated for the group.
    pub a_max: usize,
    /// Predicted group throughput with the adapter included (tok/s).
    pub throughput_tok_s: f64,
    /// Aggregated arrival rate with the adapter included (req/s) — the
    /// load-balance signal latency objectives rank by.
    pub load_req_s: f64,
}

/// A "open a fresh GPU of type T" option scored by an [`Objective`] when
/// the fleet planner ([`crate::placement::fleet`]) must pick which GPU
/// class to provision next.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpenCandidate {
    /// Index into the fleet's type table ([`crate::config::FleetSpec`]).
    pub type_index: usize,
    /// The class's rental price ($/hr).
    pub cost_per_hour: f64,
    /// Probed throughput of the head adapter alone on this class (tok/s).
    /// Zero when the objective declined probing
    /// ([`Objective::probes_open_candidates`] is `false`).
    pub throughput_tok_s: f64,
    /// Whether the probe found the head adapter feasible on this class.
    /// `true` when probing was declined.
    pub feasible: bool,
}

/// What a placement optimizes.  Implementations must be stateless
/// policies; planners query them per candidate.
pub trait Objective {
    /// Tag used in reports, CSV rows and CLI flags.
    fn name(&self) -> &'static str;

    /// Lexicographic cost of a feasible candidate; the planner picks the
    /// smallest (ties resolve to the lowest GPU index).  Feasibility is
    /// the estimator's verdict — objectives only rank feasible options.
    fn cost(&self, c: &Candidate) -> (f64, f64);

    /// Replan sticky rule: keep `adapter` on its feasible previous GPU
    /// (`prev`) instead of migrating to the otherwise-best candidate
    /// (`best`)?  Objectives weigh their own notion of "close enough"
    /// against the migration cost model in `params`.
    fn keeps(
        &self,
        prev: &Candidate,
        best: &Candidate,
        adapter: &AdapterSpec,
        params: &ReplanParams,
    ) -> bool;

    /// Whether the objective packs onto few GPUs (enabling the replanner's
    /// drain pass) or spreads across all of them.
    fn consolidates(&self) -> bool;

    /// Whether the fleet planner should probe each candidate GPU class
    /// with the head adapter before asking [`Objective::open_type`] which
    /// one to open.  Defaults to `false` (open in fleet-declaration
    /// order), which also guarantees single-type fleets issue *exactly*
    /// the probe sequence of the homogeneous planner.
    fn probes_open_candidates(&self) -> bool {
        false
    }

    /// Pick which GPU class to open next from the non-empty candidate
    /// list (one entry per type with remaining stock, in type-index
    /// order).  Returns the chosen `type_index`.  The default takes the
    /// first candidate — fleet-declaration order.
    fn open_type(&self, candidates: &[OpenCandidate]) -> usize {
        candidates[0].type_index
    }

    /// One-shot planner for a cold start: Alg. 1 packing for
    /// consolidating objectives, least-loaded spreading otherwise.
    fn plan(
        &self,
        adapters: &[AdapterSpec],
        gpus: usize,
        est: &dyn PerfEstimator,
    ) -> PlacementResult {
        if self.consolidates() {
            greedy::place(adapters, gpus, est)
        } else {
            latency::place(adapters, gpus, est)
        }
    }
}

/// Strict "better than" under an objective's lexicographic cost.
pub fn better_than(obj: &dyn Objective, a: &Candidate, b: &Candidate) -> bool {
    let (a0, a1) = obj.cost(a);
    let (b0, b1) = obj.cost(b);
    a0 < b0 || (a0 == b0 && a1 < b1)
}

/// Plan `adapters` onto at most `gpus` GPUs under `objective` — the
/// objective-generic entry point of the one-shot placement layer.
///
/// `est` is any [`PerfEstimator`]; for the DT-in-the-loop path pass a
/// [`crate::placement::CachedEstimator`]-wrapped
/// [`crate::placement::TwinEstimator`] so the planners' duplicate probes
/// memoize (bit-identical results, ≥5x fewer DT simulations — the
/// pipeline does this and persists the memos, DESIGN.md §9).
pub fn plan(
    adapters: &[AdapterSpec],
    gpus: usize,
    est: &dyn PerfEstimator,
    objective: &dyn Objective,
) -> PlacementResult {
    objective.plan(adapters, gpus, est)
}

/// Minimize provisioned GPUs (the paper's Alg. 1 objective): prefer
/// already-used GPUs, rank by predicted throughput, consolidate.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MinGpus;

impl Objective for MinGpus {
    fn name(&self) -> &'static str {
        "min-gpus"
    }

    fn cost(&self, c: &Candidate) -> (f64, f64) {
        // Fresh GPUs only when no used GPU is feasible; then best
        // predicted throughput.
        (if c.used { 0.0 } else { 1.0 }, -c.throughput_tok_s)
    }

    fn keeps(
        &self,
        prev: &Candidate,
        best: &Candidate,
        adapter: &AdapterSpec,
        params: &ReplanParams,
    ) -> bool {
        let (t_prev, t_best) = (prev.throughput_tok_s, best.throughput_tok_s);
        // Stay within the throughput slack, or when the migration would
        // not amortize within one epoch under the fig6 load-time model.
        t_prev >= (1.0 - params.slack) * t_best
            || (t_best - t_prev) * params.epoch_s
                <= params.cost.load_s(adapter.rank) * t_best.max(0.0)
    }

    fn consolidates(&self) -> bool {
        true
    }
}

/// Minimize inter-token latency (the paper's §8.4.4 ProposedLat goal):
/// spread adapters onto the least-loaded GPU, never consolidate.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MinLatency;

impl Objective for MinLatency {
    fn name(&self) -> &'static str {
        "min-latency"
    }

    fn cost(&self, c: &Candidate) -> (f64, f64) {
        // Least aggregated load first; break ties by predicted throughput.
        (c.load_req_s, -c.throughput_tok_s)
    }

    fn keeps(
        &self,
        prev: &Candidate,
        best: &Candidate,
        _adapter: &AdapterSpec,
        params: &ReplanParams,
    ) -> bool {
        // Stay while the previous GPU's load is within the slack of the
        // least-loaded feasible candidate — rebalancing migrations below
        // that threshold buy latency the ITL model cannot resolve.
        prev.load_req_s <= best.load_req_s * (1.0 + params.slack) + f64::EPSILON
    }

    fn consolidates(&self) -> bool {
        false
    }
}

/// Minimize fleet rental cost ($/hr) on a heterogeneous fleet: pack like
/// [`MinGpus`], but when a fresh GPU must be opened, probe every GPU class
/// in stock and open the one with the best cost-normalized feasible
/// throughput (tok/s per $/hr) — the Mélange-style heterogeneity lever
/// (DESIGN.md §11).  On a single-type fleet this degenerates to `MinGpus`
/// bit-identically (one candidate → no choice probes).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MinCost;

impl Objective for MinCost {
    fn name(&self) -> &'static str {
        "min-cost"
    }

    fn cost(&self, c: &Candidate) -> (f64, f64) {
        // Within a fixed set of open GPUs the packing rule is MinGpus:
        // never open capacity an already-open GPU can absorb.  Which
        // capacity gets opened is decided in `open_type`.
        (if c.used { 0.0 } else { 1.0 }, -c.throughput_tok_s)
    }

    fn keeps(
        &self,
        prev: &Candidate,
        best: &Candidate,
        adapter: &AdapterSpec,
        params: &ReplanParams,
    ) -> bool {
        MinGpus.keeps(prev, best, adapter, params)
    }

    fn consolidates(&self) -> bool {
        true
    }

    fn probes_open_candidates(&self) -> bool {
        true
    }

    fn open_type(&self, candidates: &[OpenCandidate]) -> usize {
        // Best feasible throughput per dollar; ties (and the no-feasible
        // fallback, which opens the cheapest class and lets Alg. 1's veto
        // retire it if the probe was right) break to the lowest type
        // index — deterministic for the differential tests.
        let mut best: Option<(f64, usize)> = None;
        for c in candidates.iter().filter(|c| c.feasible) {
            let value = c.throughput_tok_s / c.cost_per_hour.max(f64::MIN_POSITIVE);
            if best.is_none_or(|(v, _)| value > v) {
                best = Some((value, c.type_index));
            }
        }
        if let Some((_, t)) = best {
            return t;
        }
        let mut cheapest = &candidates[0];
        // detlint: allow(panic-path) — `candidates` built with one entry per index of this very loop
        for c in &candidates[1..] {
            if c.cost_per_hour < cheapest.cost_per_hour {
                cheapest = c;
            }
        }
        cheapest.type_index
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(gpu: usize, used: bool, thr: f64, load: f64) -> Candidate {
        Candidate { gpu, used, a_max: 8, throughput_tok_s: thr, load_req_s: load }
    }

    #[test]
    fn min_gpus_prefers_used_gpus_then_throughput() {
        let obj = MinGpus;
        let fresh = cand(0, false, 900.0, 0.1);
        let used_low = cand(1, true, 500.0, 2.0);
        let used_high = cand(2, true, 700.0, 2.5);
        assert!(better_than(&obj, &used_low, &fresh));
        assert!(better_than(&obj, &used_high, &used_low));
    }

    #[test]
    fn min_latency_prefers_least_loaded() {
        let obj = MinLatency;
        let light = cand(0, true, 400.0, 0.5);
        let heavy = cand(1, true, 900.0, 2.0);
        let fresh = cand(2, false, 400.0, 0.1);
        assert!(better_than(&obj, &light, &heavy));
        // An empty GPU is the least-loaded candidate of all.
        assert!(better_than(&obj, &fresh, &light));
    }

    #[test]
    fn sticky_rules_differ_by_objective() {
        let params = ReplanParams::default(); // slack 0.05
        let a = AdapterSpec { id: 0, rank: 8, rate: 0.1 };
        let prev = cand(0, true, 960.0, 2.0);
        let best = cand(1, true, 1000.0, 1.0);
        // 4% throughput gap: within MinGpus slack.
        assert!(MinGpus.keeps(&prev, &best, &a, &params));
        // 2x load gap: far outside MinLatency slack.
        assert!(!MinLatency.keeps(&prev, &best, &a, &params));
        // Equal loads: MinLatency stays put.
        let best_eq = cand(1, true, 1000.0, 2.0);
        assert!(MinLatency.keeps(&prev, &best_eq, &a, &params));
    }

    #[test]
    fn min_cost_opens_best_throughput_per_dollar() {
        fn open(type_index: usize, cost: f64, thr: f64, feasible: bool) -> OpenCandidate {
            OpenCandidate { type_index, cost_per_hour: cost, throughput_tok_s: thr, feasible }
        }
        let obj = MinCost;
        let cands =
            vec![open(0, 1.0, 100.0, true), open(1, 2.0, 300.0, true), open(2, 0.5, 400.0, false)];
        // 150 tok/s/$ (type 1) beats 100 (type 0); infeasible type 2 ignored.
        assert_eq!(obj.open_type(&cands), 1);
        // No feasible candidate: fall back to the cheapest class.
        let none = vec![open(0, 1.0, 0.0, false), open(1, 0.4, 0.0, false)];
        assert_eq!(obj.open_type(&none), 1);
        // Equal value ties break to the lowest type index.
        let tie = vec![open(0, 1.0, 100.0, true), open(1, 2.0, 200.0, true)];
        assert_eq!(obj.open_type(&tie), 0);
        // MinGpus-style defaults elsewhere.
        assert!(obj.consolidates() && obj.probes_open_candidates());
        assert!(!MinGpus.probes_open_candidates());
        assert_eq!(MinGpus.open_type(&tie), 0);
    }

    #[test]
    fn plan_dispatches_by_shape() {
        use crate::placement::estimator::{Estimate, OracleEstimator};
        // An always-feasible estimator isolates the packing-vs-spreading
        // shape from any model behaviour.
        let est = OracleEstimator::with_fallback(Estimate {
            throughput_tok_s: 100.0,
            starved: false,
            memory_error: false,
        });
        let ads: Vec<AdapterSpec> =
            (0..16).map(|id| AdapterSpec { id, rank: 8, rate: 0.05 }).collect();
        let packed = plan(&ads, 4, &est, &MinGpus).unwrap();
        let spread = plan(&ads, 4, &est, &MinLatency).unwrap();
        assert_eq!(packed.gpus_used(), 1, "MinGpus packs a feasible workload");
        assert_eq!(spread.gpus_used(), 4, "MinLatency spreads over every GPU");
    }
}
