//! The optimization-objective seam of the placement layer.
//!
//! The paper's pipeline minimizes *GPU count* (Alg. 1); its §8.4.4
//! ProposedLat variant minimizes *inter-token latency* by spreading load.
//! [`Objective`] makes that choice a first-class trait so the one-shot
//! planners and the incremental replanner ([`crate::placement::replan`])
//! can serve either goal — and the drift control loop can compare them
//! over time (GPUs-over-time vs ITL-over-time, `experiment drift`).
//!
//! An objective answers three questions:
//!
//! 1. **ranking** — which feasible GPU candidate is best for the next
//!    adapter ([`Objective::cost`], lexicographic, smaller is better);
//! 2. **stickiness** — when should a replanned adapter stay on its
//!    previous GPU instead of migrating ([`Objective::keeps`]);
//! 3. **shape** — pack-and-consolidate or spread
//!    ([`Objective::consolidates`], which also selects the cold-start
//!    planner in the default [`Objective::plan`]).

use super::estimator::PerfEstimator;
use super::replan::ReplanParams;
use super::{greedy, latency, PlacementResult};
use crate::workload::AdapterSpec;

/// A feasible "place adapter X on GPU g" option scored by an [`Objective`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Candidate {
    /// Target GPU index.
    pub gpu: usize,
    /// Whether the GPU already serves adapters (before this candidate).
    pub used: bool,
    /// The `A_max` testing point the estimator validated for the group.
    pub a_max: usize,
    /// Predicted group throughput with the adapter included (tok/s).
    pub throughput_tok_s: f64,
    /// Aggregated arrival rate with the adapter included (req/s) — the
    /// load-balance signal latency objectives rank by.
    pub load_req_s: f64,
}

/// What a placement optimizes.  Implementations must be stateless
/// policies; planners query them per candidate.
pub trait Objective {
    /// Tag used in reports, CSV rows and CLI flags.
    fn name(&self) -> &'static str;

    /// Lexicographic cost of a feasible candidate; the planner picks the
    /// smallest (ties resolve to the lowest GPU index).  Feasibility is
    /// the estimator's verdict — objectives only rank feasible options.
    fn cost(&self, c: &Candidate) -> (f64, f64);

    /// Replan sticky rule: keep `adapter` on its feasible previous GPU
    /// (`prev`) instead of migrating to the otherwise-best candidate
    /// (`best`)?  Objectives weigh their own notion of "close enough"
    /// against the migration cost model in `params`.
    fn keeps(
        &self,
        prev: &Candidate,
        best: &Candidate,
        adapter: &AdapterSpec,
        params: &ReplanParams,
    ) -> bool;

    /// Whether the objective packs onto few GPUs (enabling the replanner's
    /// drain pass) or spreads across all of them.
    fn consolidates(&self) -> bool;

    /// One-shot planner for a cold start: Alg. 1 packing for
    /// consolidating objectives, least-loaded spreading otherwise.
    fn plan(
        &self,
        adapters: &[AdapterSpec],
        gpus: usize,
        est: &dyn PerfEstimator,
    ) -> PlacementResult {
        if self.consolidates() {
            greedy::place(adapters, gpus, est)
        } else {
            latency::place(adapters, gpus, est)
        }
    }
}

/// Strict "better than" under an objective's lexicographic cost.
pub fn better_than(obj: &dyn Objective, a: &Candidate, b: &Candidate) -> bool {
    let (a0, a1) = obj.cost(a);
    let (b0, b1) = obj.cost(b);
    a0 < b0 || (a0 == b0 && a1 < b1)
}

/// Plan `adapters` onto at most `gpus` GPUs under `objective` — the
/// objective-generic entry point of the one-shot placement layer.
///
/// `est` is any [`PerfEstimator`]; for the DT-in-the-loop path pass a
/// [`crate::placement::CachedEstimator`]-wrapped
/// [`crate::placement::TwinEstimator`] so the planners' duplicate probes
/// memoize (bit-identical results, ≥5x fewer DT simulations — the
/// pipeline does this and persists the memos, DESIGN.md §9).
pub fn plan(
    adapters: &[AdapterSpec],
    gpus: usize,
    est: &dyn PerfEstimator,
    objective: &dyn Objective,
) -> PlacementResult {
    objective.plan(adapters, gpus, est)
}

/// Minimize provisioned GPUs (the paper's Alg. 1 objective): prefer
/// already-used GPUs, rank by predicted throughput, consolidate.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MinGpus;

impl Objective for MinGpus {
    fn name(&self) -> &'static str {
        "min-gpus"
    }

    fn cost(&self, c: &Candidate) -> (f64, f64) {
        // Fresh GPUs only when no used GPU is feasible; then best
        // predicted throughput.
        (if c.used { 0.0 } else { 1.0 }, -c.throughput_tok_s)
    }

    fn keeps(
        &self,
        prev: &Candidate,
        best: &Candidate,
        adapter: &AdapterSpec,
        params: &ReplanParams,
    ) -> bool {
        let (t_prev, t_best) = (prev.throughput_tok_s, best.throughput_tok_s);
        // Stay within the throughput slack, or when the migration would
        // not amortize within one epoch under the fig6 load-time model.
        t_prev >= (1.0 - params.slack) * t_best
            || (t_best - t_prev) * params.epoch_s
                <= params.cost.load_s(adapter.rank) * t_best.max(0.0)
    }

    fn consolidates(&self) -> bool {
        true
    }
}

/// Minimize inter-token latency (the paper's §8.4.4 ProposedLat goal):
/// spread adapters onto the least-loaded GPU, never consolidate.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MinLatency;

impl Objective for MinLatency {
    fn name(&self) -> &'static str {
        "min-latency"
    }

    fn cost(&self, c: &Candidate) -> (f64, f64) {
        // Least aggregated load first; break ties by predicted throughput.
        (c.load_req_s, -c.throughput_tok_s)
    }

    fn keeps(
        &self,
        prev: &Candidate,
        best: &Candidate,
        _adapter: &AdapterSpec,
        params: &ReplanParams,
    ) -> bool {
        // Stay while the previous GPU's load is within the slack of the
        // least-loaded feasible candidate — rebalancing migrations below
        // that threshold buy latency the ITL model cannot resolve.
        prev.load_req_s <= best.load_req_s * (1.0 + params.slack) + f64::EPSILON
    }

    fn consolidates(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(gpu: usize, used: bool, thr: f64, load: f64) -> Candidate {
        Candidate { gpu, used, a_max: 8, throughput_tok_s: thr, load_req_s: load }
    }

    #[test]
    fn min_gpus_prefers_used_gpus_then_throughput() {
        let obj = MinGpus;
        let fresh = cand(0, false, 900.0, 0.1);
        let used_low = cand(1, true, 500.0, 2.0);
        let used_high = cand(2, true, 700.0, 2.5);
        assert!(better_than(&obj, &used_low, &fresh));
        assert!(better_than(&obj, &used_high, &used_low));
    }

    #[test]
    fn min_latency_prefers_least_loaded() {
        let obj = MinLatency;
        let light = cand(0, true, 400.0, 0.5);
        let heavy = cand(1, true, 900.0, 2.0);
        let fresh = cand(2, false, 400.0, 0.1);
        assert!(better_than(&obj, &light, &heavy));
        // An empty GPU is the least-loaded candidate of all.
        assert!(better_than(&obj, &fresh, &light));
    }

    #[test]
    fn sticky_rules_differ_by_objective() {
        let params = ReplanParams::default(); // slack 0.05
        let a = AdapterSpec { id: 0, rank: 8, rate: 0.1 };
        let prev = cand(0, true, 960.0, 2.0);
        let best = cand(1, true, 1000.0, 1.0);
        // 4% throughput gap: within MinGpus slack.
        assert!(MinGpus.keeps(&prev, &best, &a, &params));
        // 2x load gap: far outside MinLatency slack.
        assert!(!MinLatency.keeps(&prev, &best, &a, &params));
        // Equal loads: MinLatency stays put.
        let best_eq = cand(1, true, 1000.0, 2.0);
        assert!(MinLatency.keeps(&prev, &best_eq, &a, &params));
    }

    #[test]
    fn plan_dispatches_by_shape() {
        use crate::placement::estimator::{Estimate, OracleEstimator};
        // An always-feasible estimator isolates the packing-vs-spreading
        // shape from any model behaviour.
        let est = OracleEstimator::with_fallback(Estimate {
            throughput_tok_s: 100.0,
            starved: false,
            memory_error: false,
        });
        let ads: Vec<AdapterSpec> =
            (0..16).map(|id| AdapterSpec { id, rank: 8, rate: 0.05 }).collect();
        let packed = plan(&ads, 4, &est, &MinGpus).unwrap();
        let spread = plan(&ads, 4, &est, &MinLatency).unwrap();
        assert_eq!(packed.gpus_used(), 1, "MinGpus packs a feasible workload");
        assert_eq!(spread.gpus_used(), 4, "MinLatency spreads over every GPU");
    }
}
