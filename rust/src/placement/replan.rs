//! Migration-aware incremental re-placement (DESIGN.md §7).
//!
//! [`replan`] re-runs the caching greedy's ML-probe machinery (Alg. 1/2)
//! for the *next* epoch of a drifting workload, starting from the previous
//! epoch's [`Placement`] instead of from scratch:
//!
//! 1. **sticky grouping** — every adapter that survived the epoch boundary
//!    stays provisionally on its current GPU;
//! 2. **per-GPU repair** — each group is probed at the testing points; while
//!    starvation is predicted, the lowest-priority adapter is evicted back
//!    into the pending pool;
//! 3. **sticky packing** — pending adapters (newcomers + evictions) are
//!    placed in priority order.  An adapter keeps its previous GPU when
//!    that GPU is feasible and its predicted throughput is within
//!    [`ReplanParams::slack`] of the best candidate, or when the migration
//!    would not amortize within one epoch under the [`MigrationCost`]
//!    model (the fig6 adapter load-time profile); otherwise it moves to the
//!    best already-used feasible GPU, opening a fresh GPU only as a last
//!    resort;
//! 4. **drain** — the smallest surviving group is migrated onto the other
//!    used GPUs when every member fits, freeing whole GPUs as demand
//!    recedes.
//!
//! Migrations and their modeled cost are reported relative to the previous
//! placement, so the epoch runner ([`crate::cluster::epochs`]) can account
//! for them in the horizon aggregate.

use super::{greedy, Placement, PlacementError, TESTING_POINTS};
use crate::dt::Calibration;
use crate::ml::{features, MlModels};
use crate::workload::AdapterSpec;
use std::collections::HashSet;

/// Linear model of the cost of migrating (re-loading) one adapter:
/// `base_s + per_rank_s · rank` seconds, fitted to the calibration's
/// profiled per-rank load times (the fig6 measurement).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MigrationCost {
    /// Fixed per-migration cost (seconds).
    pub base_s: f64,
    /// Additional cost per unit of LoRA rank (seconds).
    pub per_rank_s: f64,
}

impl Default for MigrationCost {
    fn default() -> Self {
        // Ballpark of `Calibration::default().load_s_by_rank`.
        MigrationCost { base_s: 3e-3, per_rank_s: 3.75e-4 }
    }
}

impl MigrationCost {
    /// Least-squares fit over the calibration's profiled
    /// `load_s_by_rank` points; falls back to the default when the
    /// calibration has no load profile.
    pub fn from_calibration(c: &Calibration) -> MigrationCost {
        let pts: Vec<(f64, f64)> = c.load_s_by_rank.iter().map(|(&r, &s)| (r as f64, s)).collect();
        match pts.len() {
            0 => MigrationCost::default(),
            1 => MigrationCost { base_s: 0.0, per_rank_s: pts[0].1 / pts[0].0.max(1.0) },
            _ => {
                let n = pts.len() as f64;
                let sx: f64 = pts.iter().map(|p| p.0).sum();
                let sy: f64 = pts.iter().map(|p| p.1).sum();
                let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
                let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
                let denom = n * sxx - sx * sx;
                if denom.abs() < 1e-12 {
                    return MigrationCost::default();
                }
                let slope = (n * sxy - sx * sy) / denom;
                let base = (sy - slope * sx) / n;
                MigrationCost { base_s: base.max(0.0), per_rank_s: slope.max(0.0) }
            }
        }
    }

    /// Modeled load (= migration) latency for an adapter of `rank`.
    pub fn load_s(&self, rank: usize) -> f64 {
        (self.base_s + self.per_rank_s * rank as f64).max(0.0)
    }
}

/// Tuning knobs of the incremental replanner.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplanParams {
    /// Relative throughput slack within which an adapter stays on its
    /// current GPU (0.05 = stay unless moving is predicted to be >5%
    /// better).
    pub slack: f64,
    /// Epoch length used to amortize migration costs (seconds).
    pub epoch_s: f64,
    /// Adapter migration cost model (fig6 load-time profile).
    pub cost: MigrationCost,
}

impl Default for ReplanParams {
    fn default() -> Self {
        ReplanParams { slack: 0.05, epoch_s: 10.0, cost: MigrationCost::default() }
    }
}

impl ReplanParams {
    /// Params with the migration cost fitted from a calibration and the
    /// amortization window set to the epoch length.
    pub fn from_calibration(c: &Calibration, epoch_s: f64) -> ReplanParams {
        ReplanParams { slack: 0.05, epoch_s, cost: MigrationCost::from_calibration(c) }
    }
}

/// Result of one incremental replanning step.
#[derive(Debug, Clone)]
pub struct ReplanOutcome {
    /// The placement for the new epoch.
    pub placement: Placement,
    /// Adapters that moved to a different GPU than in the previous epoch.
    pub migrations: usize,
    /// Total modeled migration latency (seconds, [`MigrationCost`]).
    pub migration_cost_s: f64,
    /// Adapters that kept their previous GPU.
    pub stayed: usize,
    /// Adapters that did not exist in the previous placement.
    pub added: usize,
    /// Previous-placement adapters absent from the new workload.
    pub removed: usize,
}

/// Best non-starving `A_max` testing point for an adapter group:
/// `(a_max, predicted_throughput)`, or `None` when every testing point
/// predicts starvation (the group cannot be served by one GPU).
fn probe(group: &[AdapterSpec], models: &MlModels) -> Option<(usize, f64)> {
    let mut best: Option<(usize, f64)> = None;
    for &p in TESTING_POINTS.iter() {
        let x = features(group, p);
        if models.predict_starvation(&x) {
            continue;
        }
        let t = models.predict_throughput(&x);
        let better = match best {
            None => true,
            Some((_, bt)) => t > bt,
        };
        if better {
            best = Some((p, t));
        }
    }
    best
}

/// Incrementally re-place `adapters` on `gpus` GPUs starting from `prev`
/// (pass `None` for a cold start, which reduces to [`greedy::place`]).
///
/// Fails with [`PlacementError::Starvation`] when some pending adapter fits
/// on no GPU under the starvation model — the same criterion as Alg. 1.
pub fn replan(
    prev: Option<&Placement>,
    adapters: &[AdapterSpec],
    gpus: usize,
    models: &MlModels,
    params: &ReplanParams,
) -> Result<ReplanOutcome, PlacementError> {
    let Some(prev) = prev else {
        let placement = greedy::place(adapters, gpus, models)?;
        return Ok(ReplanOutcome {
            placement,
            migrations: 0,
            migration_cost_s: 0.0,
            stayed: 0,
            added: adapters.len(),
            removed: 0,
        });
    };

    let current_ids: HashSet<usize> = adapters.iter().map(|a| a.id).collect();
    let removed = prev.assignment.keys().filter(|id| !current_ids.contains(*id)).count();

    // 1. Sticky grouping: survivors keep their GPU, the rest go pending.
    let mut groups: Vec<Vec<AdapterSpec>> = vec![Vec::new(); gpus];
    let mut pending: Vec<AdapterSpec> = Vec::new();
    for a in adapters {
        match prev.assignment.get(&a.id) {
            Some(&g) if g < gpus => groups[g].push(a.clone()),
            _ => pending.push(a.clone()),
        }
    }

    // 2. Per-GPU repair: evict lowest-priority adapters while the group
    //    starves at every testing point.
    let mut a_max = vec![0usize; gpus];
    for g in 0..gpus {
        if groups[g].is_empty() {
            continue;
        }
        groups[g] = greedy::priority_sorting(&groups[g]);
        loop {
            match probe(&groups[g], models) {
                Some((p, _)) => {
                    a_max[g] = p;
                    break;
                }
                None => {
                    let evicted = groups[g].pop().expect("non-empty group");
                    pending.push(evicted);
                    if groups[g].is_empty() {
                        a_max[g] = 0;
                        break;
                    }
                }
            }
        }
    }

    // 3. Sticky packing of pending adapters in priority order.
    for a in greedy::priority_sorting(&pending) {
        // All empty GPUs are identical candidates: probe one representative.
        let empty_eval = probe(std::slice::from_ref(&a), models);
        let mut evals: Vec<Option<(usize, f64)>> = Vec::with_capacity(gpus);
        for g in 0..gpus {
            if groups[g].is_empty() {
                evals.push(empty_eval);
                continue;
            }
            let mut cand = groups[g].clone();
            cand.push(a.clone());
            evals.push(probe(&cand, models));
        }
        let t_best =
            evals.iter().flatten().map(|&(_, t)| t).fold(f64::NEG_INFINITY, f64::max);
        if t_best == f64::NEG_INFINITY {
            return Err(PlacementError::Starvation);
        }
        let prev_gpu = prev.assignment.get(&a.id).copied().filter(|&g| g < gpus);
        let sticky = prev_gpu.and_then(|g| evals[g].map(|e| (g, e)));
        let chosen = match sticky {
            Some((g, (_, t_prev)))
                if t_prev >= (1.0 - params.slack) * t_best
                    || (t_best - t_prev) * params.epoch_s
                        <= params.cost.load_s(a.rank) * t_best.max(0.0) =>
            {
                g
            }
            _ => {
                // Migrate: best already-used feasible GPU, else the first
                // fresh one (GPU-count minimization).
                let mut best_used: Option<(usize, f64)> = None;
                for g in 0..gpus {
                    if groups[g].is_empty() {
                        continue;
                    }
                    if let Some((_, t)) = evals[g] {
                        let better = match best_used {
                            None => true,
                            Some((_, bt)) => t > bt,
                        };
                        if better {
                            best_used = Some((g, t));
                        }
                    }
                }
                match best_used {
                    Some((g, _)) => g,
                    None => (0..gpus)
                        .find(|&g| groups[g].is_empty() && evals[g].is_some())
                        .ok_or(PlacementError::Starvation)?,
                }
            }
        };
        a_max[chosen] = evals[chosen].expect("chosen GPU is feasible").0;
        groups[chosen].push(a);
    }

    // 4. Drain: try to empty the smallest surviving group onto the other
    //    used GPUs, bounded by one epoch of *cumulative* migration time
    //    across all drains of this replan step.
    let mut total_drain_cost = 0.0f64;
    loop {
        let Some(src) = (0..gpus)
            .filter(|&g| !groups[g].is_empty())
            .min_by_key(|&g| groups[g].len())
        else {
            break;
        };
        let targets: Vec<usize> =
            (0..gpus).filter(|&g| g != src && !groups[g].is_empty()).collect();
        if targets.is_empty() {
            break;
        }
        let movers = greedy::priority_sorting(&groups[src]);
        let mut tentative = groups.clone();
        tentative[src].clear();
        let mut placed: Vec<(AdapterSpec, usize, usize)> = Vec::new();
        let mut drain_cost = 0.0;
        let mut ok = true;
        for a in movers {
            let mut best: Option<(usize, usize, f64)> = None;
            for &g in &targets {
                let mut cand = tentative[g].clone();
                cand.push(a.clone());
                if let Some((p, t)) = probe(&cand, models) {
                    let better = match best {
                        None => true,
                        Some((_, _, bt)) => t > bt,
                    };
                    if better {
                        best = Some((g, p, t));
                    }
                }
            }
            match best {
                Some((g, p, _)) => {
                    tentative[g].push(a.clone());
                    drain_cost += params.cost.load_s(a.rank);
                    placed.push((a, g, p));
                }
                None => {
                    ok = false;
                    break;
                }
            }
        }
        if !ok || total_drain_cost + drain_cost > params.epoch_s {
            break;
        }
        total_drain_cost += drain_cost;
        for (a, g, p) in placed {
            groups[g].push(a);
            a_max[g] = p;
        }
        groups[src].clear();
        a_max[src] = 0;
    }

    // Assemble and account against the previous placement.
    let mut placement = Placement { assignment: Default::default(), a_max: a_max.clone() };
    for (g, group) in groups.iter().enumerate() {
        for a in group {
            placement.assignment.insert(a.id, g);
        }
    }
    if placement.assignment.len() != adapters.len() {
        return Err(PlacementError::Starvation);
    }
    let mut migrations = 0;
    let mut migration_cost_s = 0.0;
    let mut stayed = 0;
    let mut added = 0;
    for a in adapters {
        match prev.assignment.get(&a.id) {
            None => added += 1,
            Some(&pg) => {
                if placement.assignment[&a.id] == pg {
                    stayed += 1;
                } else {
                    migrations += 1;
                    migration_cost_s += params.cost.load_s(a.rank);
                }
            }
        }
    }
    Ok(ReplanOutcome { placement, migrations, migration_cost_s, stayed, added, removed })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Shared analytic stand-in models (see `placement::test_models`).
    fn fake_models() -> MlModels {
        crate::placement::test_models::analytic_models(11)
    }

    fn adapters(n: usize, rate: f64) -> Vec<AdapterSpec> {
        (0..n).map(|id| AdapterSpec { id, rank: 8, rate }).collect()
    }

    #[test]
    fn cold_start_matches_greedy() {
        let models = fake_models();
        let ads = adapters(16, 0.1);
        let out = replan(None, &ads, 4, &models, &ReplanParams::default()).unwrap();
        let fresh = greedy::place(&ads, 4, &models).unwrap();
        assert_eq!(out.placement, fresh);
        assert_eq!(out.migrations, 0);
        assert_eq!(out.added, 16);
    }

    #[test]
    fn unchanged_workload_replans_with_zero_migrations() {
        let models = fake_models();
        let ads = adapters(32, 0.1);
        let p0 = greedy::place(&ads, 4, &models).unwrap();
        let out = replan(Some(&p0), &ads, 4, &models, &ReplanParams::default()).unwrap();
        assert_eq!(out.migrations, 0, "stable workload must not migrate");
        assert_eq!(out.stayed, 32);
        assert_eq!(out.migration_cost_s, 0.0);
        for a in &ads {
            assert_eq!(out.placement.assignment[&a.id], p0.assignment[&a.id]);
        }
    }

    #[test]
    fn retired_adapters_are_dropped_without_migrations() {
        let models = fake_models();
        let ads = adapters(32, 0.1);
        let p0 = greedy::place(&ads, 4, &models).unwrap();
        let survivors: Vec<AdapterSpec> = ads.iter().take(16).cloned().collect();
        let out = replan(Some(&p0), &survivors, 4, &models, &ReplanParams::default()).unwrap();
        assert_eq!(out.removed, 16);
        assert_eq!(out.placement.assignment.len(), 16);
        assert!(out.placement.gpus_used() <= p0.gpus_used());
    }

    #[test]
    fn overload_triggers_eviction_and_migration() {
        let models = fake_models();
        // Previous epoch: everything on GPU 0 (feasible at low rate).
        let low = adapters(48, 0.05);
        let p0 = greedy::place(&low, 4, &models).unwrap();
        assert_eq!(p0.gpus_used(), 1);
        // Rates sextuple: demand 48×0.3×96 ≈ 1382 > capacity at every
        // A_max, so the repair phase must evict and spill to a second GPU.
        let high = adapters(48, 0.3);
        let out = replan(Some(&p0), &high, 4, &models, &ReplanParams::default()).unwrap();
        assert!(out.placement.gpus_used() >= 2, "gpus={}", out.placement.gpus_used());
        assert!(out.migrations > 0, "overload must migrate someone");
        assert!(out.migration_cost_s > 0.0);
        assert_eq!(out.migrations + out.stayed, 48);
    }

    #[test]
    fn infeasible_workload_errors() {
        let models = fake_models();
        let p0 = greedy::place(&adapters(8, 0.1), 4, &models).unwrap();
        let impossible = adapters(384, 1.0);
        let err = replan(Some(&p0), &impossible, 4, &models, &ReplanParams::default()).unwrap_err();
        assert_eq!(err, PlacementError::Starvation);
    }

    #[test]
    fn a_max_valid_on_used_gpus() {
        let models = fake_models();
        let ads = adapters(64, 0.1);
        let p0 = greedy::place(&adapters(16, 0.1), 4, &models).unwrap();
        let out = replan(Some(&p0), &ads, 4, &models, &ReplanParams::default()).unwrap();
        for g in 0..4 {
            if !out.placement.adapters_on(g).is_empty() {
                assert!(TESTING_POINTS.contains(&out.placement.a_max[g]));
            }
        }
    }

    #[test]
    fn migration_cost_fits_calibration_profile() {
        let calib = Calibration::default();
        let cost = MigrationCost::from_calibration(&calib);
        for (&rank, &s) in &calib.load_s_by_rank {
            let err = (cost.load_s(rank) - s).abs();
            assert!(err < 0.005, "rank {rank}: fitted {} vs profiled {s}", cost.load_s(rank));
        }
        // Monotone in rank.
        assert!(cost.load_s(32) > cost.load_s(8));
    }
}
