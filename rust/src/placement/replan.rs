//! Migration-aware incremental re-placement (DESIGN.md §7), generic over
//! both placement seams ([`PerfEstimator`], [`Objective`]).
//!
//! [`replan`] re-runs the caching greedy's probe machinery (Alg. 1/2)
//! for the *next* epoch of a drifting workload, starting from the previous
//! epoch's [`Placement`] instead of from scratch:
//!
//! 1. **sticky grouping** — every adapter that survived the epoch boundary
//!    stays provisionally on its current GPU;
//! 2. **per-GPU repair** — each group is probed at the testing points; while
//!    every point is predicted infeasible, the lowest-priority adapter is
//!    evicted back into the pending pool;
//! 3. **sticky packing** — pending adapters (newcomers + evictions) are
//!    placed in priority order.  Each GPU yields a scored
//!    [`Candidate`]; the [`Objective`] ranks the feasible ones
//!    ([`Objective::cost`]) and decides whether the adapter keeps its
//!    feasible previous GPU ([`Objective::keeps`], weighing
//!    [`ReplanParams::slack`] and the [`MigrationCost`] amortization —
//!    the fig6 adapter load-time profile) or migrates to the best
//!    candidate;
//! 4. **drain / rebalance** — for consolidating objectives
//!    ([`Objective::consolidates`]), the smallest surviving group is
//!    migrated onto the other used GPUs when every member fits, freeing
//!    whole GPUs as demand recedes.  Spreading objectives instead run the
//!    spread-preserving analogue: while the most-loaded GPU exceeds the
//!    least-loaded alternative by more than the stickiness slack, one
//!    adapter migrates over, restoring balance (and ITL) as adapters
//!    retire or rates shift.  Both passes share the one-epoch cumulative
//!    migration budget.
//!
//! Migrations and their modeled cost are reported relative to the previous
//! placement, so the epoch runner ([`crate::cluster::epochs`]) can account
//! for them in the horizon aggregate.
//!
//! The sticky/repair/drain passes probe heavily overlapping groups — and
//! consecutive epochs of a drift horizon re-probe near-identical ones —
//! so DT-in-the-loop replanning should share one
//! [`crate::placement::CachedEstimator`] across the whole horizon;
//! results stay bit-identical to the uncached path.  Candidate probes go
//! down as [`PerfEstimator::estimate_batch`] batches (parallel under a
//! [`crate::placement::CachedEstimator`], with deterministic reduction),
//! and a [`ReplanLedger`] carried across epochs makes re-probing
//! *incremental*: only groups whose `(rank, rate)` composition actually
//! drifted since the last epoch pay estimator cost
//! ([`replan_with_ledger`]).

use super::estimator::{Estimate, PerfEstimator, ProbeQuery};
use super::objective::{better_than, Candidate, Objective};
use super::{greedy, Placement, PlacementError, TESTING_POINTS};
use crate::dt::Calibration;
use crate::workload::AdapterSpec;
use std::collections::BTreeSet;

/// Linear model of the cost of migrating (re-loading) one adapter:
/// `base_s + per_rank_s · rank` seconds, fitted to the calibration's
/// profiled per-rank load times (the fig6 measurement).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MigrationCost {
    /// Fixed per-migration cost (seconds).
    pub base_s: f64,
    /// Additional cost per unit of LoRA rank (seconds).
    pub per_rank_s: f64,
}

impl Default for MigrationCost {
    fn default() -> Self {
        // Ballpark of `Calibration::default().load_s_by_rank`.
        MigrationCost { base_s: 3e-3, per_rank_s: 3.75e-4 }
    }
}

impl MigrationCost {
    /// Least-squares fit over the calibration's profiled
    /// `load_s_by_rank` points; falls back to the default when the
    /// calibration has no load profile.
    pub fn from_calibration(c: &Calibration) -> MigrationCost {
        let pts: Vec<(f64, f64)> = c.load_s_by_rank.iter().map(|(&r, &s)| (r as f64, s)).collect();
        match pts.len() {
            0 => MigrationCost::default(),
            1 => MigrationCost { base_s: 0.0, per_rank_s: pts[0].1 / pts[0].0.max(1.0) },
            _ => {
                let n = pts.len() as f64;
                let sx: f64 = pts.iter().map(|p| p.0).sum();
                let sy: f64 = pts.iter().map(|p| p.1).sum();
                let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
                let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
                let denom = n * sxx - sx * sx;
                if denom.abs() < 1e-12 {
                    return MigrationCost::default();
                }
                let slope = (n * sxy - sx * sy) / denom;
                let base = (sy - slope * sx) / n;
                MigrationCost { base_s: base.max(0.0), per_rank_s: slope.max(0.0) }
            }
        }
    }

    /// Modeled load (= migration) latency for an adapter of `rank`.
    pub fn load_s(&self, rank: usize) -> f64 {
        (self.base_s + self.per_rank_s * rank as f64).max(0.0)
    }
}

/// Tuning knobs of the incremental replanner.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplanParams {
    /// Relative throughput slack within which an adapter stays on its
    /// current GPU (0.05 = stay unless moving is predicted to be >5%
    /// better).
    pub slack: f64,
    /// Epoch length used to amortize migration costs (seconds).
    pub epoch_s: f64,
    /// Adapter migration cost model (fig6 load-time profile).
    pub cost: MigrationCost,
}

impl Default for ReplanParams {
    fn default() -> Self {
        ReplanParams { slack: 0.05, epoch_s: 10.0, cost: MigrationCost::default() }
    }
}

impl ReplanParams {
    /// Params with the migration cost fitted from a calibration and the
    /// amortization window set to the epoch length.
    pub fn from_calibration(c: &Calibration, epoch_s: f64) -> ReplanParams {
        ReplanParams { slack: 0.05, epoch_s, cost: MigrationCost::from_calibration(c) }
    }
}

/// Result of one incremental replanning step.
#[derive(Debug, Clone)]
pub struct ReplanOutcome {
    /// The placement for the new epoch.
    pub placement: Placement,
    /// Adapters that moved to a different GPU than in the previous epoch.
    pub migrations: usize,
    /// Total modeled migration latency (seconds, [`MigrationCost`]).
    pub migration_cost_s: f64,
    /// Adapters that kept their previous GPU.
    pub stayed: usize,
    /// Adapters that did not exist in the previous placement.
    pub added: usize,
    /// Previous-placement adapters absent from the new workload.
    pub removed: usize,
    /// Non-empty sticky groups that paid estimator probes in the repair
    /// pass (their composition drifted, or no ledger was supplied).
    pub groups_reprobed: usize,
    /// Non-empty sticky groups whose composition matched the
    /// [`ReplanLedger`]: their `A_max` was reused with zero probes.
    pub groups_reused: usize,
}

/// Serial reduction of one group's estimates at every testing point (in
/// point order): best feasible `(a_max, predicted_throughput)`.
fn reduce_points(estimates: &[Estimate]) -> Option<(usize, f64)> {
    let mut best: Option<(usize, f64)> = None;
    for (&p, e) in TESTING_POINTS.iter().zip(estimates) {
        if !e.feasible() {
            continue;
        }
        let better = match best {
            None => true,
            Some((_, bt)) => e.throughput_tok_s > bt,
        };
        if better {
            best = Some((p, e.throughput_tok_s));
        }
    }
    best
}

/// Best feasible `A_max` testing point for an adapter group:
/// `(a_max, predicted_throughput)`, or `None` when every testing point
/// predicts starvation or a memory error (the group cannot be served by
/// one GPU).  All testing points go down as one
/// [`PerfEstimator::estimate_batch`], so a parallel-capable estimator
/// probes them concurrently; the reduction stays in point order.
fn probe(group: &[AdapterSpec], est: &dyn PerfEstimator) -> Option<(usize, f64)> {
    probe_batch(&[group], est).pop().flatten()
}

/// [`probe`] over many groups through a single estimator batch (the
/// repair/packing/drain passes fan whole candidate sets out at once).
/// Query order is `(group, testing point)` lexicographic, so cache hit
/// and miss counts match the equivalent serial probe sequence exactly.
fn probe_batch(groups: &[&[AdapterSpec]], est: &dyn PerfEstimator) -> Vec<Option<(usize, f64)>> {
    let mut queries = Vec::with_capacity(groups.len() * TESTING_POINTS.len());
    for group in groups {
        for &p in TESTING_POINTS.iter() {
            queries.push(ProbeQuery { adapters: group, a_max: p });
        }
    }
    let estimates = est.estimate_batch(&queries);
    estimates.chunks(TESTING_POINTS.len()).map(reduce_points).collect()
}

/// FNV-1a 64-bit hash over a sequence of `u64` words.
fn fnv_words<I: IntoIterator<Item = u64>>(words: I) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for w in words {
        for b in w.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// Fingerprint of one adapter group as the repair and drain passes see
/// it: the estimator's own memo key (pinning every probe answer) plus
/// the full sorted `(rank, rate)` multiset and member count (pinning
/// priority order, migration costs and drain budgets).  Equal
/// fingerprints therefore guarantee both passes behave identically on
/// the two groups.
fn group_fp(group: &[AdapterSpec], est: &dyn PerfEstimator) -> u64 {
    let mut pairs: Vec<(usize, u64)> = group.iter().map(|a| (a.rank, a.rate.to_bits())).collect();
    pairs.sort_unstable();
    let mut words = vec![group.len() as u64];
    words.extend(pairs.into_iter().flat_map(|(r, b)| [r as u64, b]));
    words.extend(est.memo_key(group, 0));
    fnv_words(words)
}

/// Fingerprint of a whole layout: per-GPU group fingerprints in GPU
/// order (the drain pass's full input).
fn layout_fp(groups: &[Vec<AdapterSpec>], est: &dyn PerfEstimator) -> u64 {
    fnv_words(groups.iter().map(|g| group_fp(g, est)))
}

/// Per-horizon memory of what the last successful [`replan_with_ledger`]
/// settled on, enabling incremental re-probing: sticky groups whose
/// composition did not drift since the previous epoch (same
/// fingerprint) reuse the recorded `A_max` without paying a single
/// estimator probe, and the drain/rebalance pass is skipped outright
/// when the pre-pass layout is one already known to be its fixed point.
///
/// Entries are self-validating — a fingerprint match *implies* the
/// recorded answer is the one re-probing would compute — so a ledger
/// can be carried across any sequence of replans (failed ones leave it
/// untouched) without going stale, as long as the estimator, params and
/// objective stay fixed across the horizon.
#[derive(Debug, Clone, Default)]
pub struct ReplanLedger {
    /// `(group fingerprint, settled A_max)` per GPU of the last success.
    groups: Vec<Option<(u64, usize)>>,
    /// Layout fingerprint of the last success, when that layout was a
    /// fixed point of the shape pass — drain for consolidating
    /// objectives, rebalance for spreading ones (`None` after a
    /// budget-limited pass: a fresh epoch budget could move further).
    layout: Option<u64>,
}

impl ReplanLedger {
    /// Fresh ledger: the first replan it feeds re-probes everything.
    pub fn new() -> ReplanLedger {
        ReplanLedger::default()
    }
}

/// Incrementally re-place `adapters` on `gpus` GPUs starting from `prev`
/// (pass `None` for a cold start, which reduces to the objective's
/// one-shot planner — [`greedy::place`] for
/// [`crate::placement::MinGpus`]).
///
/// Generic over both seams: `est` answers the feasibility/throughput
/// probes, `objective` ranks candidates, decides stickiness and gates the
/// drain pass.  Fails with [`PlacementError::Starvation`] when some
/// pending adapter fits on no GPU under the estimator — the same
/// criterion as Alg. 1.
pub fn replan(
    prev: Option<&Placement>,
    adapters: &[AdapterSpec],
    gpus: usize,
    est: &dyn PerfEstimator,
    params: &ReplanParams,
    objective: &dyn Objective,
) -> Result<ReplanOutcome, PlacementError> {
    replan_with_ledger(prev, adapters, gpus, est, params, objective, None)
}

/// [`replan`] with a cross-epoch [`ReplanLedger`]: sticky groups whose
/// composition matches the ledger skip the repair probes entirely, and
/// the drain/rebalance pass is skipped when the layout is a known fixed
/// point of it.  The outcome is bit-identical to [`replan`] — the ledger only
/// removes estimator calls whose answers are already pinned by a
/// fingerprint match.  On success the ledger is updated to describe the
/// returned placement; on failure it is left untouched.
pub fn replan_with_ledger(
    prev: Option<&Placement>,
    adapters: &[AdapterSpec],
    gpus: usize,
    est: &dyn PerfEstimator,
    params: &ReplanParams,
    objective: &dyn Objective,
    ledger: Option<&mut ReplanLedger>,
) -> Result<ReplanOutcome, PlacementError> {
    let Some(prev) = prev else {
        let placement = objective.plan(adapters, gpus, est)?;
        return Ok(ReplanOutcome {
            placement,
            migrations: 0,
            migration_cost_s: 0.0,
            stayed: 0,
            added: adapters.len(),
            removed: 0,
            groups_reprobed: 0,
            groups_reused: 0,
        });
    };

    let current_ids: BTreeSet<usize> = adapters.iter().map(|a| a.id).collect();
    let removed = prev.assignment.keys().filter(|id| !current_ids.contains(*id)).count();

    // 1. Sticky grouping: survivors keep their GPU, the rest go pending.
    let mut groups: Vec<Vec<AdapterSpec>> = vec![Vec::new(); gpus];
    let mut pending: Vec<AdapterSpec> = Vec::new();
    for a in adapters {
        match prev.assignment.get(&a.id) {
            // detlint: allow(panic-path) — `groups` sized to the fleet/group count at construction; ordinals in range
            Some(&g) if g < gpus => groups[g].push(a.clone()),
            _ => pending.push(a.clone()),
        }
    }

    // 2. Per-GPU repair.  Groups whose composition matches the ledger
    //    reuse their recorded A_max with zero probes (incremental
    //    re-probing); the rest are bulk-probed as one estimator batch —
    //    parallel under a batch-capable estimator — then evict
    //    lowest-priority adapters while predicted infeasible at every
    //    testing point.
    let mut a_max = vec![0usize; gpus];
    let mut groups_reused = 0usize;
    let mut to_probe: Vec<usize> = Vec::new();
    for g in 0..gpus {
        // detlint: allow(panic-path) — `groups` sized to the fleet/group count at construction; ordinals in range
        if groups[g].is_empty() {
            continue;
        }
        // detlint: allow(panic-path) — `groups` sized to the fleet/group count at construction; ordinals in range
        groups[g] = greedy::priority_sorting(&groups[g]);
        let known = ledger.as_ref().and_then(|l| l.groups.get(g).copied().flatten());
        match known {
            // detlint: allow(panic-path) — `a_max`/`groups` sized to the fleet/group count at construction; ordinals in range
            Some((fp, p)) if fp == group_fp(&groups[g], est) => {
                a_max[g] = p;
                groups_reused += 1;
            }
            _ => to_probe.push(g),
        }
    }
    let groups_reprobed = to_probe.len();
    let first_pass = {
        // detlint: allow(panic-path) — `groups` sized to the fleet/group count at construction; ordinals in range
        let refs: Vec<&[AdapterSpec]> = to_probe.iter().map(|&g| groups[g].as_slice()).collect();
        probe_batch(&refs, est)
    };
    for (&g, mut probed) in to_probe.iter().zip(first_pass) {
        loop {
            match probed {
                Some((p, _)) => {
                    // detlint: allow(panic-path) — `a_max` sized to the fleet/group count at construction; ordinals in range
                    a_max[g] = p;
                    break;
                }
                None => {
                    // detlint: allow(panic-path) — `a_max`/`groups` sized to the fleet/group count at construction; ordinals in range
                    let Some(evicted) = groups[g].pop() else {
                        a_max[g] = 0;
                        break;
                    };
                    pending.push(evicted);
                    // detlint: allow(panic-path) — `a_max`/`groups` sized to the fleet/group count at construction; ordinals in range
                    if groups[g].is_empty() {
                        a_max[g] = 0;
                        break;
                    }
                    // detlint: allow(panic-path) — `groups` sized to the fleet/group count at construction; ordinals in range
                    probed = probe(&groups[g], est);
                }
            }
        }
    }

    // 3. Sticky packing of pending adapters in priority order, scored by
    //    the objective.  Per adapter, the representative empty-GPU group
    //    and every used-GPU candidate go down as one batch (all empty
    //    GPUs are identical candidates, so one probe covers them).
    for a in greedy::priority_sorting(&pending) {
        let single = [a.clone()];
        let used_cands: Vec<(usize, Vec<AdapterSpec>)> = (0..gpus)
            // detlint: allow(panic-path) — `groups` sized to the fleet/group count at construction; ordinals in range
            .filter(|&g| !groups[g].is_empty())
            .map(|g| {
                // detlint: allow(panic-path) — `groups` sized to the fleet/group count at construction; ordinals in range
                let mut cand = groups[g].clone();
                cand.push(a.clone());
                (g, cand)
            })
            .collect();
        let evals = {
            let mut refs: Vec<&[AdapterSpec]> = vec![&single];
            refs.extend(used_cands.iter().map(|(_, c)| c.as_slice()));
            probe_batch(&refs, est)
        };
        let empty_eval = evals[0];
        let mut used_eval: Vec<Option<(usize, f64)>> = vec![None; gpus];
        // detlint: allow(panic-path) — `evals`/`used_eval` built with one entry per index of this very loop
        for ((g, _), eval) in used_cands.iter().zip(&evals[1..]) {
            used_eval[*g] = *eval;
        }
        let mut cands: Vec<Option<Candidate>> = Vec::with_capacity(gpus);
        for g in 0..gpus {
            // detlint: allow(panic-path) — `groups` sized to the fleet/group count at construction; ordinals in range
            let (eval, load, used) = if groups[g].is_empty() {
                (empty_eval, a.rate, false)
            } else {
                // detlint: allow(panic-path) — `groups`/`used_eval` and its index are constructed together; in range by construction
                let load = groups[g].iter().map(|x| x.rate).sum::<f64>() + a.rate;
                (used_eval[g], load, true)
            };
            cands.push(eval.map(|(p, t)| Candidate {
                gpu: g,
                used,
                a_max: p,
                throughput_tok_s: t,
                load_req_s: load,
            }));
        }
        let mut best: Option<Candidate> = None;
        for c in cands.iter().flatten() {
            let is_better = match &best {
                None => true,
                Some(b) => better_than(objective, c, b),
            };
            if is_better {
                best = Some(*c);
            }
        }
        let Some(best) = best else {
            return Err(PlacementError::Starvation);
        };
        let prev_cand =
            // detlint: allow(panic-path) — `cands` built with one entry per index of this very loop
            prev.assignment.get(&a.id).copied().filter(|&g| g < gpus).and_then(|g| cands[g]);
        let chosen = match prev_cand {
            Some(pc) if objective.keeps(&pc, &best, &a, params) => pc,
            _ => best,
        };
        // detlint: allow(panic-path) — `a_max`/`groups` sized to the fleet/group count at construction; ordinals in range
        a_max[chosen.gpu] = chosen.a_max;
        groups[chosen.gpu].push(a);
    }

    // 4. Drain (consolidating objectives only): try to empty the smallest
    //    surviving group onto the other used GPUs, bounded by one epoch of
    //    *cumulative* migration time across all drains of this replan step.
    //    Skipped outright when the ledger recorded this exact layout as a
    //    fixed point of the shape pass (drain or rebalance) — both passes
    //    are deterministic in the layout, so re-running could only
    //    terminate the same way.
    let pre_pass_fp = ledger.as_ref().map(|_| layout_fp(&groups, est));
    let settled = match (&ledger, pre_pass_fp) {
        (Some(l), Some(fp)) => l.layout == Some(fp),
        _ => false,
    };
    let mut total_drain_cost = 0.0f64;
    let mut budget_limited = false;
    while !settled && objective.consolidates() {
        let Some(src) = (0..gpus)
            // detlint: allow(panic-path) — `groups` sized to the fleet/group count at construction; ordinals in range
            .filter(|&g| !groups[g].is_empty())
            .min_by_key(|&g| groups[g].len())
        else {
            break;
        };
        let targets: Vec<usize> =
            // detlint: allow(panic-path) — `groups` sized to the fleet/group count at construction; ordinals in range
            (0..gpus).filter(|&g| g != src && !groups[g].is_empty()).collect();
        if targets.is_empty() {
            break;
        }
        // detlint: allow(panic-path) — `groups` sized to the fleet/group count at construction; ordinals in range
        let movers = greedy::priority_sorting(&groups[src]);
        let mut tentative = groups.clone();
        // detlint: allow(panic-path) — `tentative` sized to the fleet/group count at construction; ordinals in range
        tentative[src].clear();
        let mut placed: Vec<(AdapterSpec, usize, usize)> = Vec::new();
        let mut drain_cost = 0.0;
        let mut ok = true;
        for a in movers {
            // Every target candidate for this mover goes down as one
            // batch; the reduction stays in target order.
            let target_cands: Vec<(usize, Vec<AdapterSpec>)> = targets
                .iter()
                .map(|&g| {
                    // detlint: allow(panic-path) — `tentative` sized to the fleet/group count at construction; ordinals in range
                    let mut cand = tentative[g].clone();
                    cand.push(a.clone());
                    (g, cand)
                })
                .collect();
            let evals = {
                let refs: Vec<&[AdapterSpec]> =
                    target_cands.iter().map(|(_, c)| c.as_slice()).collect();
                probe_batch(&refs, est)
            };
            let mut best: Option<(usize, usize, f64)> = None;
            for ((g, _), eval) in target_cands.iter().zip(&evals) {
                if let Some((p, t)) = *eval {
                    let better = match best {
                        None => true,
                        Some((_, _, bt)) => t > bt,
                    };
                    if better {
                        best = Some((*g, p, t));
                    }
                }
            }
            match best {
                Some((g, p, _)) => {
                    // detlint: allow(panic-path) — `tentative` sized to the fleet/group count at construction; ordinals in range
                    tentative[g].push(a.clone());
                    drain_cost += params.cost.load_s(a.rank);
                    placed.push((a, g, p));
                }
                None => {
                    ok = false;
                    break;
                }
            }
        }
        if !ok {
            break;
        }
        if total_drain_cost + drain_cost > params.epoch_s {
            // Only a *cumulative* budget exhaustion is transient (a fresh
            // epoch budget could drain further); a first drain that alone
            // exceeds the budget is as layout-determined as `!ok`.
            budget_limited = total_drain_cost > 0.0;
            break;
        }
        total_drain_cost += drain_cost;
        for (a, g, p) in placed {
            // detlint: allow(panic-path) — `a_max`/`groups` sized to the fleet/group count at construction; ordinals in range
            groups[g].push(a);
            a_max[g] = p;
        }
        // detlint: allow(panic-path) — `a_max`/`groups` sized to the fleet/group count at construction; ordinals in range
        groups[src].clear();
        a_max[src] = 0;
    }

    // 5. Rebalance (spreading objectives only): the spread-preserving
    //    analogue of the drain.  While the most-loaded GPU exceeds the
    //    least-loaded alternative by more than the stickiness slack, the
    //    highest-priority movable adapter migrates over (both groups
    //    re-probed), restoring the balance the latency objective packs
    //    for.  Bounded by the same cumulative one-epoch migration budget;
    //    each adapter moves at most once per replan, so the loop
    //    terminates; a ledger-settled layout skips the pass outright.
    let mut total_rebalance_cost = 0.0f64;
    let mut rebalanced: BTreeSet<usize> = BTreeSet::new();
    'rebalance: while !settled && !objective.consolidates() {
        let load = |group: &[AdapterSpec]| group.iter().map(|a| a.rate).sum::<f64>();
        let mut heaviest: Option<(usize, f64)> = None;
        for g in 0..gpus {
            // detlint: allow(panic-path) — `groups` sized to the fleet/group count at construction; ordinals in range
            if groups[g].is_empty() {
                continue;
            }
            // detlint: allow(panic-path) — `groups` sized to the fleet/group count at construction; ordinals in range
            let l = load(&groups[g]);
            if heaviest.is_none_or(|(_, best)| l > best) {
                heaviest = Some((g, l));
            }
        }
        let Some((src, src_load)) = heaviest else { break };
        let mut lightest: Option<(usize, f64)> = None;
        for g in (0..gpus).filter(|&g| g != src) {
            // detlint: allow(panic-path) — `groups` sized to the fleet/group count at construction; ordinals in range
            let l = load(&groups[g]);
            if lightest.is_none_or(|(_, best)| l < best) {
                lightest = Some((g, l));
            }
        }
        let Some((tgt, tgt_load)) = lightest else { break };
        // Candidate movers in priority order: adapters whose move keeps
        // the target strictly below the source beyond the slack (the
        // inverse of the latency objective's sticky rule, so a move is
        // only made where `keeps` would have let the adapter migrate).
        // detlint: allow(panic-path) — `groups` sized to the fleet/group count at construction; ordinals in range
        let movers: Vec<AdapterSpec> = greedy::priority_sorting(&groups[src])
            .into_iter()
            .filter(|a| !rebalanced.contains(&a.id))
            .filter(|a| src_load > (tgt_load + a.rate) * (1.0 + params.slack) + f64::EPSILON)
            .collect();
        let mut moved = false;
        for a in movers {
            // detlint: allow(panic-path) — `groups` sized to the fleet/group count at construction; ordinals in range
            let mut grown = groups[tgt].clone();
            grown.push(a.clone());
            let Some((p_tgt, _)) = probe(&grown, est) else { continue };
            let rest: Vec<AdapterSpec> =
                // detlint: allow(panic-path) — `groups` sized to the fleet/group count at construction; ordinals in range
                groups[src].iter().filter(|x| x.id != a.id).cloned().collect();
            let p_src = if rest.is_empty() {
                0
            } else {
                match probe(&rest, est) {
                    Some((p, _)) => p,
                    None => continue,
                }
            };
            let move_cost = params.cost.load_s(a.rank);
            if total_rebalance_cost + move_cost > params.epoch_s {
                // Same transience rule as the drain budget above.
                budget_limited = total_rebalance_cost > 0.0;
                break 'rebalance;
            }
            total_rebalance_cost += move_cost;
            rebalanced.insert(a.id);
            // detlint: allow(panic-path) — `groups` sized to the fleet/group count at construction; ordinals in range
            groups[tgt] = grown;
            groups[src] = rest;
            // detlint: allow(panic-path) — `a_max` sized to the fleet/group count at construction; ordinals in range
            a_max[tgt] = p_tgt;
            a_max[src] = p_src;
            moved = true;
            break;
        }
        if !moved {
            break;
        }
    }

    // Assemble and account against the previous placement.
    let mut placement = Placement { assignment: Default::default(), a_max: a_max.clone() };
    for (g, group) in groups.iter().enumerate() {
        for a in group {
            placement.assignment.insert(a.id, g);
        }
    }
    if placement.assignment.len() != adapters.len() {
        return Err(PlacementError::Starvation);
    }

    // Commit the ledger (success only): per-GPU fingerprints of the final
    // groups with their settled A_max — every path above leaves
    // `a_max[g]` equal to `probe(&groups[g])`'s choice, which is exactly
    // what a no-drift repair would recompute next epoch — plus the layout
    // fingerprint when the shape pass (drain or rebalance) settled
    // structurally.
    if let Some(l) = ledger {
        l.groups = groups
            .iter()
            .enumerate()
            .map(|(g, grp)| {
                if grp.is_empty() {
                    None
                } else {
                    // detlint: allow(panic-path) — `a_max` sized to the fleet/group count at construction; ordinals in range
                    Some((group_fp(grp, est), a_max[g]))
                }
            })
            .collect();
        l.layout = if budget_limited { None } else { Some(layout_fp(&groups, est)) };
    }
    let mut migrations = 0;
    let mut migration_cost_s = 0.0;
    let mut stayed = 0;
    let mut added = 0;
    for a in adapters {
        match prev.assignment.get(&a.id) {
            None => added += 1,
            Some(&pg) => {
                // detlint: allow(panic-path) — `assignment` and its index are constructed together; in range by construction
                if placement.assignment[&a.id] == pg {
                    stayed += 1;
                } else {
                    migrations += 1;
                    migration_cost_s += params.cost.load_s(a.rank);
                }
            }
        }
    }
    Ok(ReplanOutcome {
        placement,
        migrations,
        migration_cost_s,
        stayed,
        added,
        removed,
        groups_reprobed,
        groups_reused,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::MlModels;
    use crate::placement::{latency, MinGpus, MinLatency};

    /// Shared analytic stand-in models (see `placement::test_models`).
    fn fake_models() -> MlModels {
        crate::placement::test_models::analytic_models(11)
    }

    fn adapters(n: usize, rate: f64) -> Vec<AdapterSpec> {
        (0..n).map(|id| AdapterSpec { id, rank: 8, rate }).collect()
    }

    #[test]
    fn cold_start_matches_greedy() {
        let models = fake_models();
        let ads = adapters(16, 0.1);
        let out = replan(None, &ads, 4, &models, &ReplanParams::default(), &MinGpus).unwrap();
        let fresh = greedy::place(&ads, 4, &models).unwrap();
        assert_eq!(out.placement, fresh);
        assert_eq!(out.migrations, 0);
        assert_eq!(out.added, 16);
    }

    #[test]
    fn unchanged_workload_replans_with_zero_migrations() {
        let models = fake_models();
        let ads = adapters(32, 0.1);
        let p0 = greedy::place(&ads, 4, &models).unwrap();
        let out = replan(Some(&p0), &ads, 4, &models, &ReplanParams::default(), &MinGpus).unwrap();
        assert_eq!(out.migrations, 0, "stable workload must not migrate");
        assert_eq!(out.stayed, 32);
        assert_eq!(out.migration_cost_s, 0.0);
        for a in &ads {
            assert_eq!(out.placement.assignment[&a.id], p0.assignment[&a.id]);
        }
    }

    #[test]
    fn retired_adapters_are_dropped_without_migrations() {
        let models = fake_models();
        let ads = adapters(32, 0.1);
        let p0 = greedy::place(&ads, 4, &models).unwrap();
        let survivors: Vec<AdapterSpec> = ads.iter().take(16).cloned().collect();
        let out =
            replan(Some(&p0), &survivors, 4, &models, &ReplanParams::default(), &MinGpus).unwrap();
        assert_eq!(out.removed, 16);
        assert_eq!(out.placement.assignment.len(), 16);
        assert!(out.placement.gpus_used() <= p0.gpus_used());
    }

    #[test]
    fn overload_triggers_eviction_and_migration() {
        let models = fake_models();
        // Previous epoch: everything on GPU 0 (feasible at low rate).
        let low = adapters(48, 0.05);
        let p0 = greedy::place(&low, 4, &models).unwrap();
        assert_eq!(p0.gpus_used(), 1);
        // Rates sextuple: demand 48×0.3×96 ≈ 1382 > capacity at every
        // A_max, so the repair phase must evict and spill to a second GPU.
        let high = adapters(48, 0.3);
        let out = replan(Some(&p0), &high, 4, &models, &ReplanParams::default(), &MinGpus).unwrap();
        assert!(out.placement.gpus_used() >= 2, "gpus={}", out.placement.gpus_used());
        assert!(out.migrations > 0, "overload must migrate someone");
        assert!(out.migration_cost_s > 0.0);
        assert_eq!(out.migrations + out.stayed, 48);
    }

    #[test]
    fn infeasible_workload_errors() {
        let models = fake_models();
        let p0 = greedy::place(&adapters(8, 0.1), 4, &models).unwrap();
        let impossible = adapters(384, 1.0);
        let err = replan(Some(&p0), &impossible, 4, &models, &ReplanParams::default(), &MinGpus)
            .unwrap_err();
        assert_eq!(err, PlacementError::Starvation);
    }

    #[test]
    fn a_max_valid_on_used_gpus() {
        let models = fake_models();
        let ads = adapters(64, 0.1);
        let p0 = greedy::place(&adapters(16, 0.1), 4, &models).unwrap();
        let out = replan(Some(&p0), &ads, 4, &models, &ReplanParams::default(), &MinGpus).unwrap();
        for g in 0..4 {
            if !out.placement.adapters_on(g).is_empty() {
                assert!(TESTING_POINTS.contains(&out.placement.a_max[g]));
            }
        }
    }

    #[test]
    fn min_latency_replan_respreads_survivors_instead_of_draining() {
        use crate::placement::estimator::{Estimate, OracleEstimator};
        // An always-feasible estimator isolates the objective's shape from
        // any model behaviour.
        let est = OracleEstimator::with_fallback(Estimate {
            throughput_tok_s: 500.0,
            starved: false,
            memory_error: false,
        });
        let ads = adapters(16, 0.1);
        let p0 = latency::place(&ads, 4, &est).unwrap();
        assert_eq!(p0.gpus_used(), 4);
        // Half the adapters retire; the survivors crowd two GPUs.  The
        // rebalance pass must re-spread them across the whole cluster
        // (2 per GPU is the only within-slack layout) — never drain it.
        let survivors: Vec<AdapterSpec> = ads.iter().filter(|a| a.id % 2 == 0).cloned().collect();
        let lat = replan(Some(&p0), &survivors, 4, &est, &ReplanParams::default(), &MinLatency)
            .unwrap();
        assert_eq!(lat.placement.gpus_used(), 4, "MinLatency must keep the cluster spread");
        for g in 0..4 {
            assert_eq!(lat.placement.adapters_on(g).len(), 2, "gpu {g} left unbalanced");
        }
        assert!(lat.migrations > 0, "re-spreading the survivors takes migrations");
        assert!(lat.migration_cost_s > 0.0);
        // The consolidating objective drains the same survivors together.
        let packed = replan(Some(&p0), &survivors, 4, &est, &ReplanParams::default(), &MinGpus)
            .unwrap();
        assert!(
            packed.placement.gpus_used() < lat.placement.gpus_used(),
            "MinGpus drain must shed GPUs: {} !< {}",
            packed.placement.gpus_used(),
            lat.placement.gpus_used()
        );
        assert!(packed.migrations > 0);
    }

    #[test]
    fn min_latency_rebalance_improves_twin_itl() {
        use crate::cluster::{serve_on_twin, RunOptions};
        use crate::config::EngineConfig;
        use crate::dt::LengthVariant;
        use crate::placement::estimator::{Estimate, OracleEstimator};
        use crate::workload::WorkloadSpec;
        let est = OracleEstimator::with_fallback(Estimate {
            throughput_tok_s: 500.0,
            starved: false,
            memory_error: false,
        });
        // A lopsided previous epoch: 7 of 8 adapters crowd GPU 0.
        let ads = adapters(8, 0.2);
        let mut prev = Placement { assignment: Default::default(), a_max: vec![8, 8] };
        for a in &ads {
            prev.assignment.insert(a.id, usize::from(a.id == 0));
        }
        let out =
            replan(Some(&prev), &ads, 2, &est, &ReplanParams::default(), &MinLatency).unwrap();
        assert_eq!(out.placement.adapters_on(0).len(), 4, "rebalance must split the load 4/4");
        assert_eq!(out.placement.adapters_on(1).len(), 4);
        assert!(out.migrations > 0);
        // Regression: the balanced placement strictly improves realized
        // mean ITL on the Digital Twin (smaller decode batches per GPU).
        let calib = Calibration::default();
        let base = EngineConfig::default();
        let spec = WorkloadSpec::sharegpt_like(ads, 30.0, 7);
        let lopsided =
            serve_on_twin(&calib, &base, &prev, &spec, LengthVariant::Original, RunOptions::new());
        let balanced = serve_on_twin(
            &calib,
            &base,
            &out.placement,
            &spec,
            LengthVariant::Original,
            RunOptions::new(),
        );
        assert!(lopsided.itl_mean_s > 0.0 && balanced.itl_mean_s > 0.0);
        assert!(
            balanced.itl_mean_s < lopsided.itl_mean_s,
            "rebalance must cut mean ITL: {} !< {}",
            balanced.itl_mean_s,
            lopsided.itl_mean_s
        );
    }

    #[test]
    fn min_latency_cold_start_spreads_like_proposed_lat() {
        use crate::placement::estimator::{Estimate, OracleEstimator};
        let est = OracleEstimator::with_fallback(Estimate {
            throughput_tok_s: 500.0,
            starved: false,
            memory_error: false,
        });
        let ads = adapters(12, 0.2);
        let out = replan(None, &ads, 4, &est, &ReplanParams::default(), &MinLatency).unwrap();
        let fresh = latency::place(&ads, 4, &est).unwrap();
        assert_eq!(out.placement, fresh);
        assert_eq!(out.placement.gpus_used(), 4);
    }

    #[test]
    fn cached_twin_replan_is_bit_identical_to_uncached() {
        use crate::config::EngineConfig;
        use crate::placement::estimator::{CachedEstimator, TwinEstimator};
        let calib = Calibration::default();
        let base = EngineConfig::default();
        let twin = || TwinEstimator::new(calib.clone(), base.clone()).horizon(5.0);
        let plain = twin();
        let cached = CachedEstimator::wrap(twin());
        let ads = adapters(12, 0.05);
        let p_plain = greedy::place(&ads, 4, &plain).unwrap();
        let p_cached = greedy::place(&ads, 4, &cached).unwrap();
        assert_eq!(p_plain, p_cached, "cold start must not change under the memo");
        // The workload doubles; replanning probes sticky/repair/packing
        // candidates through both paths.
        let grown = adapters(24, 0.08);
        let out_plain =
            replan(Some(&p_plain), &grown, 4, &plain, &ReplanParams::default(), &MinGpus)
                .unwrap();
        let out_cached =
            replan(Some(&p_cached), &grown, 4, &cached, &ReplanParams::default(), &MinGpus)
                .unwrap();
        assert_eq!(out_plain.placement, out_cached.placement);
        assert_eq!(out_plain.migrations, out_cached.migrations);
        assert_eq!(out_plain.migration_cost_s.to_bits(), out_cached.migration_cost_s.to_bits());
        let stats = cached.stats();
        assert!(stats.hits > 0, "adjacent probes must hit the memo: {stats:?}");
    }

    #[test]
    fn ledger_no_drift_horizon_reuses_every_group_with_zero_probes() {
        use crate::placement::estimator::CachedEstimator;
        // Two-GPU workload whose groups cannot be merged: the drain pass
        // terminates structurally, so its layout is a drain fixed point.
        let cached = CachedEstimator::wrap(fake_models());
        let ads = adapters(64, 0.3);
        let p0 = greedy::place(&ads, 4, &cached).unwrap();
        assert!(p0.gpus_used() >= 2);
        let params = ReplanParams::default();
        let mut ledger = ReplanLedger::new();
        // First ledger epoch: nothing recorded yet, every group re-probes.
        let out1 =
            replan_with_ledger(Some(&p0), &ads, 4, &cached, &params, &MinGpus, Some(&mut ledger))
                .unwrap();
        assert_eq!(out1.groups_reused, 0);
        assert_eq!(out1.groups_reprobed, p0.gpus_used());
        // Second ledger epoch, no drift: every group and the drain-settled
        // layout match the ledger — not a single estimator probe is paid.
        let before = cached.stats().total();
        let out2 = replan_with_ledger(
            Some(&out1.placement),
            &ads,
            4,
            &cached,
            &params,
            &MinGpus,
            Some(&mut ledger),
        )
        .unwrap();
        assert_eq!(out2.groups_reprobed, 0, "no drift must re-probe nothing");
        assert_eq!(out2.groups_reused, out1.placement.gpus_used());
        assert_eq!(cached.stats().total(), before, "zero estimator probes on a no-drift epoch");
        assert_eq!(out2.placement, out1.placement);
        assert_eq!(out2.migrations, 0);
    }

    #[test]
    fn ledger_replan_is_bit_identical_to_plain_replan_under_drift() {
        let models = fake_models();
        let p0 = greedy::place(&adapters(24, 0.1), 4, &models).unwrap();
        let params = ReplanParams::default();
        let mut ledger = ReplanLedger::new();
        // Epoch 1: workload grows; epoch 2: rates shift and some retire.
        let w1 = adapters(32, 0.12);
        let w2: Vec<AdapterSpec> =
            (0..28).map(|id| AdapterSpec { id, rank: 8, rate: 0.15 }).collect();
        let mut plain_prev: Option<Placement> = Some(p0.clone());
        let mut ledger_prev: Option<Placement> = Some(p0);
        for w in [&w1, &w2] {
            let plain = replan(plain_prev.as_ref(), w, 4, &models, &params, &MinGpus).unwrap();
            let with_ledger = replan_with_ledger(
                ledger_prev.as_ref(),
                w,
                4,
                &models,
                &params,
                &MinGpus,
                Some(&mut ledger),
            )
            .unwrap();
            assert_eq!(plain.placement, with_ledger.placement);
            assert_eq!(plain.migrations, with_ledger.migrations);
            assert_eq!(plain.migration_cost_s.to_bits(), with_ledger.migration_cost_s.to_bits());
            assert_eq!((plain.stayed, plain.added), (with_ledger.stayed, with_ledger.added));
            plain_prev = Some(plain.placement);
            ledger_prev = Some(with_ledger.placement);
        }
    }

    #[test]
    fn migration_cost_fits_calibration_profile() {
        let calib = Calibration::default();
        let cost = MigrationCost::from_calibration(&calib);
        for (&rank, &s) in &calib.load_s_by_rank {
            let err = (cost.load_s(rank) - s).abs();
            assert!(err < 0.005, "rank {rank}: fitted {} vs profiled {s}", cost.load_s(rank));
        }
        // Monotone in rank.
        assert!(cost.load_s(32) > cost.load_s(8));
    }
}
