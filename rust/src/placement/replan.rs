//! Migration-aware incremental re-placement (DESIGN.md §7), generic over
//! both placement seams ([`PerfEstimator`], [`Objective`]).
//!
//! [`replan`] re-runs the caching greedy's probe machinery (Alg. 1/2)
//! for the *next* epoch of a drifting workload, starting from the previous
//! epoch's [`Placement`] instead of from scratch:
//!
//! 1. **sticky grouping** — every adapter that survived the epoch boundary
//!    stays provisionally on its current GPU;
//! 2. **per-GPU repair** — each group is probed at the testing points; while
//!    every point is predicted infeasible, the lowest-priority adapter is
//!    evicted back into the pending pool;
//! 3. **sticky packing** — pending adapters (newcomers + evictions) are
//!    placed in priority order.  Each GPU yields a scored
//!    [`Candidate`]; the [`Objective`] ranks the feasible ones
//!    ([`Objective::cost`]) and decides whether the adapter keeps its
//!    feasible previous GPU ([`Objective::keeps`], weighing
//!    [`ReplanParams::slack`] and the [`MigrationCost`] amortization —
//!    the fig6 adapter load-time profile) or migrates to the best
//!    candidate;
//! 4. **drain** — for consolidating objectives
//!    ([`Objective::consolidates`]), the smallest surviving group is
//!    migrated onto the other used GPUs when every member fits, freeing
//!    whole GPUs as demand recedes.  Spreading objectives skip this pass.
//!
//! Migrations and their modeled cost are reported relative to the previous
//! placement, so the epoch runner ([`crate::cluster::epochs`]) can account
//! for them in the horizon aggregate.
//!
//! The sticky/repair/drain passes probe heavily overlapping groups — and
//! consecutive epochs of a drift horizon re-probe near-identical ones —
//! so DT-in-the-loop replanning should share one
//! [`crate::placement::CachedEstimator`] across the whole horizon;
//! results stay bit-identical to the uncached path.

use super::estimator::PerfEstimator;
use super::objective::{better_than, Candidate, Objective};
use super::{greedy, Placement, PlacementError, TESTING_POINTS};
use crate::dt::Calibration;
use crate::workload::AdapterSpec;
use std::collections::HashSet;

/// Linear model of the cost of migrating (re-loading) one adapter:
/// `base_s + per_rank_s · rank` seconds, fitted to the calibration's
/// profiled per-rank load times (the fig6 measurement).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MigrationCost {
    /// Fixed per-migration cost (seconds).
    pub base_s: f64,
    /// Additional cost per unit of LoRA rank (seconds).
    pub per_rank_s: f64,
}

impl Default for MigrationCost {
    fn default() -> Self {
        // Ballpark of `Calibration::default().load_s_by_rank`.
        MigrationCost { base_s: 3e-3, per_rank_s: 3.75e-4 }
    }
}

impl MigrationCost {
    /// Least-squares fit over the calibration's profiled
    /// `load_s_by_rank` points; falls back to the default when the
    /// calibration has no load profile.
    pub fn from_calibration(c: &Calibration) -> MigrationCost {
        let pts: Vec<(f64, f64)> = c.load_s_by_rank.iter().map(|(&r, &s)| (r as f64, s)).collect();
        match pts.len() {
            0 => MigrationCost::default(),
            1 => MigrationCost { base_s: 0.0, per_rank_s: pts[0].1 / pts[0].0.max(1.0) },
            _ => {
                let n = pts.len() as f64;
                let sx: f64 = pts.iter().map(|p| p.0).sum();
                let sy: f64 = pts.iter().map(|p| p.1).sum();
                let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
                let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
                let denom = n * sxx - sx * sx;
                if denom.abs() < 1e-12 {
                    return MigrationCost::default();
                }
                let slope = (n * sxy - sx * sy) / denom;
                let base = (sy - slope * sx) / n;
                MigrationCost { base_s: base.max(0.0), per_rank_s: slope.max(0.0) }
            }
        }
    }

    /// Modeled load (= migration) latency for an adapter of `rank`.
    pub fn load_s(&self, rank: usize) -> f64 {
        (self.base_s + self.per_rank_s * rank as f64).max(0.0)
    }
}

/// Tuning knobs of the incremental replanner.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplanParams {
    /// Relative throughput slack within which an adapter stays on its
    /// current GPU (0.05 = stay unless moving is predicted to be >5%
    /// better).
    pub slack: f64,
    /// Epoch length used to amortize migration costs (seconds).
    pub epoch_s: f64,
    /// Adapter migration cost model (fig6 load-time profile).
    pub cost: MigrationCost,
}

impl Default for ReplanParams {
    fn default() -> Self {
        ReplanParams { slack: 0.05, epoch_s: 10.0, cost: MigrationCost::default() }
    }
}

impl ReplanParams {
    /// Params with the migration cost fitted from a calibration and the
    /// amortization window set to the epoch length.
    pub fn from_calibration(c: &Calibration, epoch_s: f64) -> ReplanParams {
        ReplanParams { slack: 0.05, epoch_s, cost: MigrationCost::from_calibration(c) }
    }
}

/// Result of one incremental replanning step.
#[derive(Debug, Clone)]
pub struct ReplanOutcome {
    /// The placement for the new epoch.
    pub placement: Placement,
    /// Adapters that moved to a different GPU than in the previous epoch.
    pub migrations: usize,
    /// Total modeled migration latency (seconds, [`MigrationCost`]).
    pub migration_cost_s: f64,
    /// Adapters that kept their previous GPU.
    pub stayed: usize,
    /// Adapters that did not exist in the previous placement.
    pub added: usize,
    /// Previous-placement adapters absent from the new workload.
    pub removed: usize,
}

/// Best feasible `A_max` testing point for an adapter group:
/// `(a_max, predicted_throughput)`, or `None` when every testing point
/// predicts starvation or a memory error (the group cannot be served by
/// one GPU).
fn probe(group: &[AdapterSpec], est: &dyn PerfEstimator) -> Option<(usize, f64)> {
    let mut best: Option<(usize, f64)> = None;
    for &p in TESTING_POINTS.iter() {
        let e = est.estimate(group, p);
        if !e.feasible() {
            continue;
        }
        let better = match best {
            None => true,
            Some((_, bt)) => e.throughput_tok_s > bt,
        };
        if better {
            best = Some((p, e.throughput_tok_s));
        }
    }
    best
}

/// Incrementally re-place `adapters` on `gpus` GPUs starting from `prev`
/// (pass `None` for a cold start, which reduces to the objective's
/// one-shot planner — [`greedy::place`] for
/// [`crate::placement::MinGpus`]).
///
/// Generic over both seams: `est` answers the feasibility/throughput
/// probes, `objective` ranks candidates, decides stickiness and gates the
/// drain pass.  Fails with [`PlacementError::Starvation`] when some
/// pending adapter fits on no GPU under the estimator — the same
/// criterion as Alg. 1.
pub fn replan(
    prev: Option<&Placement>,
    adapters: &[AdapterSpec],
    gpus: usize,
    est: &dyn PerfEstimator,
    params: &ReplanParams,
    objective: &dyn Objective,
) -> Result<ReplanOutcome, PlacementError> {
    let Some(prev) = prev else {
        let placement = objective.plan(adapters, gpus, est)?;
        return Ok(ReplanOutcome {
            placement,
            migrations: 0,
            migration_cost_s: 0.0,
            stayed: 0,
            added: adapters.len(),
            removed: 0,
        });
    };

    let current_ids: HashSet<usize> = adapters.iter().map(|a| a.id).collect();
    let removed = prev.assignment.keys().filter(|id| !current_ids.contains(*id)).count();

    // 1. Sticky grouping: survivors keep their GPU, the rest go pending.
    let mut groups: Vec<Vec<AdapterSpec>> = vec![Vec::new(); gpus];
    let mut pending: Vec<AdapterSpec> = Vec::new();
    for a in adapters {
        match prev.assignment.get(&a.id) {
            Some(&g) if g < gpus => groups[g].push(a.clone()),
            _ => pending.push(a.clone()),
        }
    }

    // 2. Per-GPU repair: evict lowest-priority adapters while the group
    //    is predicted infeasible at every testing point.
    let mut a_max = vec![0usize; gpus];
    for g in 0..gpus {
        if groups[g].is_empty() {
            continue;
        }
        groups[g] = greedy::priority_sorting(&groups[g]);
        loop {
            match probe(&groups[g], est) {
                Some((p, _)) => {
                    a_max[g] = p;
                    break;
                }
                None => {
                    let evicted = groups[g].pop().expect("non-empty group");
                    pending.push(evicted);
                    if groups[g].is_empty() {
                        a_max[g] = 0;
                        break;
                    }
                }
            }
        }
    }

    // 3. Sticky packing of pending adapters in priority order, scored by
    //    the objective.
    for a in greedy::priority_sorting(&pending) {
        // All empty GPUs are identical candidates: probe one representative.
        let empty_eval = probe(std::slice::from_ref(&a), est);
        let mut cands: Vec<Option<Candidate>> = Vec::with_capacity(gpus);
        for g in 0..gpus {
            let (eval, load, used) = if groups[g].is_empty() {
                (empty_eval, a.rate, false)
            } else {
                let mut cand = groups[g].clone();
                cand.push(a.clone());
                let load = cand.iter().map(|x| x.rate).sum::<f64>();
                (probe(&cand, est), load, true)
            };
            cands.push(eval.map(|(p, t)| Candidate {
                gpu: g,
                used,
                a_max: p,
                throughput_tok_s: t,
                load_req_s: load,
            }));
        }
        let mut best: Option<Candidate> = None;
        for c in cands.iter().flatten() {
            let is_better = match &best {
                None => true,
                Some(b) => better_than(objective, c, b),
            };
            if is_better {
                best = Some(*c);
            }
        }
        let Some(best) = best else {
            return Err(PlacementError::Starvation);
        };
        let prev_cand =
            prev.assignment.get(&a.id).copied().filter(|&g| g < gpus).and_then(|g| cands[g]);
        let chosen = match prev_cand {
            Some(pc) if objective.keeps(&pc, &best, &a, params) => pc,
            _ => best,
        };
        a_max[chosen.gpu] = chosen.a_max;
        groups[chosen.gpu].push(a);
    }

    // 4. Drain (consolidating objectives only): try to empty the smallest
    //    surviving group onto the other used GPUs, bounded by one epoch of
    //    *cumulative* migration time across all drains of this replan step.
    let mut total_drain_cost = 0.0f64;
    while objective.consolidates() {
        let Some(src) = (0..gpus)
            .filter(|&g| !groups[g].is_empty())
            .min_by_key(|&g| groups[g].len())
        else {
            break;
        };
        let targets: Vec<usize> =
            (0..gpus).filter(|&g| g != src && !groups[g].is_empty()).collect();
        if targets.is_empty() {
            break;
        }
        let movers = greedy::priority_sorting(&groups[src]);
        let mut tentative = groups.clone();
        tentative[src].clear();
        let mut placed: Vec<(AdapterSpec, usize, usize)> = Vec::new();
        let mut drain_cost = 0.0;
        let mut ok = true;
        for a in movers {
            let mut best: Option<(usize, usize, f64)> = None;
            for &g in &targets {
                let mut cand = tentative[g].clone();
                cand.push(a.clone());
                if let Some((p, t)) = probe(&cand, est) {
                    let better = match best {
                        None => true,
                        Some((_, _, bt)) => t > bt,
                    };
                    if better {
                        best = Some((g, p, t));
                    }
                }
            }
            match best {
                Some((g, p, _)) => {
                    tentative[g].push(a.clone());
                    drain_cost += params.cost.load_s(a.rank);
                    placed.push((a, g, p));
                }
                None => {
                    ok = false;
                    break;
                }
            }
        }
        if !ok || total_drain_cost + drain_cost > params.epoch_s {
            break;
        }
        total_drain_cost += drain_cost;
        for (a, g, p) in placed {
            groups[g].push(a);
            a_max[g] = p;
        }
        groups[src].clear();
        a_max[src] = 0;
    }

    // Assemble and account against the previous placement.
    let mut placement = Placement { assignment: Default::default(), a_max: a_max.clone() };
    for (g, group) in groups.iter().enumerate() {
        for a in group {
            placement.assignment.insert(a.id, g);
        }
    }
    if placement.assignment.len() != adapters.len() {
        return Err(PlacementError::Starvation);
    }
    let mut migrations = 0;
    let mut migration_cost_s = 0.0;
    let mut stayed = 0;
    let mut added = 0;
    for a in adapters {
        match prev.assignment.get(&a.id) {
            None => added += 1,
            Some(&pg) => {
                if placement.assignment[&a.id] == pg {
                    stayed += 1;
                } else {
                    migrations += 1;
                    migration_cost_s += params.cost.load_s(a.rank);
                }
            }
        }
    }
    Ok(ReplanOutcome { placement, migrations, migration_cost_s, stayed, added, removed })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::MlModels;
    use crate::placement::{latency, MinGpus, MinLatency};

    /// Shared analytic stand-in models (see `placement::test_models`).
    fn fake_models() -> MlModels {
        crate::placement::test_models::analytic_models(11)
    }

    fn adapters(n: usize, rate: f64) -> Vec<AdapterSpec> {
        (0..n).map(|id| AdapterSpec { id, rank: 8, rate }).collect()
    }

    #[test]
    fn cold_start_matches_greedy() {
        let models = fake_models();
        let ads = adapters(16, 0.1);
        let out = replan(None, &ads, 4, &models, &ReplanParams::default(), &MinGpus).unwrap();
        let fresh = greedy::place(&ads, 4, &models).unwrap();
        assert_eq!(out.placement, fresh);
        assert_eq!(out.migrations, 0);
        assert_eq!(out.added, 16);
    }

    #[test]
    fn unchanged_workload_replans_with_zero_migrations() {
        let models = fake_models();
        let ads = adapters(32, 0.1);
        let p0 = greedy::place(&ads, 4, &models).unwrap();
        let out = replan(Some(&p0), &ads, 4, &models, &ReplanParams::default(), &MinGpus).unwrap();
        assert_eq!(out.migrations, 0, "stable workload must not migrate");
        assert_eq!(out.stayed, 32);
        assert_eq!(out.migration_cost_s, 0.0);
        for a in &ads {
            assert_eq!(out.placement.assignment[&a.id], p0.assignment[&a.id]);
        }
    }

    #[test]
    fn retired_adapters_are_dropped_without_migrations() {
        let models = fake_models();
        let ads = adapters(32, 0.1);
        let p0 = greedy::place(&ads, 4, &models).unwrap();
        let survivors: Vec<AdapterSpec> = ads.iter().take(16).cloned().collect();
        let out =
            replan(Some(&p0), &survivors, 4, &models, &ReplanParams::default(), &MinGpus).unwrap();
        assert_eq!(out.removed, 16);
        assert_eq!(out.placement.assignment.len(), 16);
        assert!(out.placement.gpus_used() <= p0.gpus_used());
    }

    #[test]
    fn overload_triggers_eviction_and_migration() {
        let models = fake_models();
        // Previous epoch: everything on GPU 0 (feasible at low rate).
        let low = adapters(48, 0.05);
        let p0 = greedy::place(&low, 4, &models).unwrap();
        assert_eq!(p0.gpus_used(), 1);
        // Rates sextuple: demand 48×0.3×96 ≈ 1382 > capacity at every
        // A_max, so the repair phase must evict and spill to a second GPU.
        let high = adapters(48, 0.3);
        let out = replan(Some(&p0), &high, 4, &models, &ReplanParams::default(), &MinGpus).unwrap();
        assert!(out.placement.gpus_used() >= 2, "gpus={}", out.placement.gpus_used());
        assert!(out.migrations > 0, "overload must migrate someone");
        assert!(out.migration_cost_s > 0.0);
        assert_eq!(out.migrations + out.stayed, 48);
    }

    #[test]
    fn infeasible_workload_errors() {
        let models = fake_models();
        let p0 = greedy::place(&adapters(8, 0.1), 4, &models).unwrap();
        let impossible = adapters(384, 1.0);
        let err = replan(Some(&p0), &impossible, 4, &models, &ReplanParams::default(), &MinGpus)
            .unwrap_err();
        assert_eq!(err, PlacementError::Starvation);
    }

    #[test]
    fn a_max_valid_on_used_gpus() {
        let models = fake_models();
        let ads = adapters(64, 0.1);
        let p0 = greedy::place(&adapters(16, 0.1), 4, &models).unwrap();
        let out = replan(Some(&p0), &ads, 4, &models, &ReplanParams::default(), &MinGpus).unwrap();
        for g in 0..4 {
            if !out.placement.adapters_on(g).is_empty() {
                assert!(TESTING_POINTS.contains(&out.placement.a_max[g]));
            }
        }
    }

    #[test]
    fn min_latency_replan_skips_drain_and_stays_spread() {
        use crate::placement::estimator::{Estimate, OracleEstimator};
        // An always-feasible estimator isolates the objective's shape from
        // any model behaviour.
        let est = OracleEstimator::with_fallback(Estimate {
            throughput_tok_s: 500.0,
            starved: false,
            memory_error: false,
        });
        let ads = adapters(16, 0.1);
        let p0 = latency::place(&ads, 4, &est).unwrap();
        assert_eq!(p0.gpus_used(), 4);
        // Half the adapters retire; the survivors sit on two GPUs.
        let survivors: Vec<AdapterSpec> = ads.iter().filter(|a| a.id % 2 == 0).cloned().collect();
        let lat = replan(Some(&p0), &survivors, 4, &est, &ReplanParams::default(), &MinLatency)
            .unwrap();
        assert_eq!(lat.migrations, 0, "MinLatency must not consolidate survivors");
        assert_eq!(lat.stayed, survivors.len());
        for a in &survivors {
            assert_eq!(lat.placement.assignment[&a.id], p0.assignment[&a.id]);
        }
        // The consolidating objective drains the same survivors together.
        let packed = replan(Some(&p0), &survivors, 4, &est, &ReplanParams::default(), &MinGpus)
            .unwrap();
        assert!(
            packed.placement.gpus_used() < lat.placement.gpus_used(),
            "MinGpus drain must shed GPUs: {} !< {}",
            packed.placement.gpus_used(),
            lat.placement.gpus_used()
        );
        assert!(packed.migrations > 0);
    }

    #[test]
    fn min_latency_cold_start_spreads_like_proposed_lat() {
        use crate::placement::estimator::{Estimate, OracleEstimator};
        let est = OracleEstimator::with_fallback(Estimate {
            throughput_tok_s: 500.0,
            starved: false,
            memory_error: false,
        });
        let ads = adapters(12, 0.2);
        let out = replan(None, &ads, 4, &est, &ReplanParams::default(), &MinLatency).unwrap();
        let fresh = latency::place(&ads, 4, &est).unwrap();
        assert_eq!(out.placement, fresh);
        assert_eq!(out.placement.gpus_used(), 4);
    }

    #[test]
    fn cached_twin_replan_is_bit_identical_to_uncached() {
        use crate::config::EngineConfig;
        use crate::placement::estimator::{CachedEstimator, TwinEstimator};
        let calib = Calibration::default();
        let base = EngineConfig::default();
        let twin = || TwinEstimator::new(calib.clone(), base.clone()).with_horizon(5.0);
        let plain = twin();
        let cached = CachedEstimator::wrap(twin());
        let ads = adapters(12, 0.05);
        let p_plain = greedy::place(&ads, 4, &plain).unwrap();
        let p_cached = greedy::place(&ads, 4, &cached).unwrap();
        assert_eq!(p_plain, p_cached, "cold start must not change under the memo");
        // The workload doubles; replanning probes sticky/repair/packing
        // candidates through both paths.
        let grown = adapters(24, 0.08);
        let out_plain =
            replan(Some(&p_plain), &grown, 4, &plain, &ReplanParams::default(), &MinGpus)
                .unwrap();
        let out_cached =
            replan(Some(&p_cached), &grown, 4, &cached, &ReplanParams::default(), &MinGpus)
                .unwrap();
        assert_eq!(out_plain.placement, out_cached.placement);
        assert_eq!(out_plain.migrations, out_cached.migrations);
        assert_eq!(out_plain.migration_cost_s.to_bits(), out_cached.migration_cost_s.to_bits());
        let stats = cached.stats();
        assert!(stats.hits > 0, "adjacent probes must hit the memo: {stats:?}");
    }

    #[test]
    fn migration_cost_fits_calibration_profile() {
        let calib = Calibration::default();
        let cost = MigrationCost::from_calibration(&calib);
        for (&rank, &s) in &calib.load_s_by_rank {
            let err = (cost.load_s(rank) - s).abs();
            assert!(err < 0.005, "rank {rank}: fitted {} vs profiled {s}", cost.load_s(rank));
        }
        // Monotone in rank.
        assert!(cost.load_s(32) > cost.load_s(8));
    }
}
