//! Exact branch-and-bound placement over a typed fleet — the
//! differential-testing oracle for the greedy planners (DESIGN.md §11).
//!
//! [`solve`] enumerates assignments of the priority-sorted adapters to
//! GPUs depth-first, using exactly the probe data the greedy sees (the
//! per-type [`PerfEstimator`]s and the [`TESTING_POINTS`] grid), and
//! returns a plan that **provably minimizes** `Σ unit_costs[type]` over
//! the opened GPUs:
//!
//! * all-ones `unit_costs` → minimum GPU count (the [`MinGpus`] goal);
//! * per-type $/hr prices → minimum fleet cost (the [`MinCost`] goal).
//!
//! Pruning rules (each documented in DESIGN.md §11):
//! * **feasibility** — a group no testing point can serve (starved or
//!   over the class's memory) prunes the branch immediately;
//! * **cost lower bound** — a branch is cut when its accumulated cost
//!   (plus the cheapest in-stock class, when a fresh GPU must be
//!   opened) cannot *strictly* beat the incumbent;
//! * **symmetry** — fresh GPUs are opened at most once per class per
//!   node, and only in class order.
//!
//! Tie-breaking is deterministic: the DFS explores open GPUs in open
//! order then classes in declaration order, and only strictly cheaper
//! completions replace the incumbent — the first optimum found in that
//! fixed order wins.
//!
//! Intended for small instances (≤ ~10 adapters, ≤ 3 classes); larger
//! searches abort with [`PlacementError::TimeLimit`] after `max_nodes`
//! nodes.
//!
//! [`MinGpus`]: crate::placement::MinGpus
//! [`MinCost`]: crate::placement::MinCost

use super::estimator::PerfEstimator;
use super::fleet::FleetPlacement;
use super::greedy::priority_sorting;
use super::{Placement, PlacementError, TESTING_POINTS};
use crate::config::FleetSpec;
use crate::workload::AdapterSpec;

/// Search limits for [`solve`].
#[derive(Debug, Clone, Copy)]
pub struct ExactLimits {
    /// DFS node budget before the search gives up with
    /// [`PlacementError::TimeLimit`].
    pub max_nodes: usize,
}

impl Default for ExactLimits {
    fn default() -> Self {
        ExactLimits { max_nodes: 2_000_000 }
    }
}

/// Best feasible `A_max` for a group on one class: the testing point
/// with the highest predicted throughput among the feasible ones
/// (ties → the smallest point).  `None` when no point serves the group.
fn best_feasible_a_max(
    group: &[AdapterSpec],
    est: &dyn PerfEstimator,
) -> Option<(usize, f64)> {
    let mut best: Option<(usize, f64)> = None;
    for &p in TESTING_POINTS.iter() {
        let e = est.estimate(group, p);
        if e.feasible() && best.is_none_or(|(_, t)| e.throughput_tok_s > t) {
            best = Some((p, e.throughput_tok_s));
        }
    }
    best
}

struct Search<'a> {
    order: Vec<AdapterSpec>,
    fleet: &'a FleetSpec,
    ests: &'a [&'a dyn PerfEstimator],
    unit_costs: &'a [f64],
    limits: ExactLimits,
    nodes: usize,
    best_cost: f64,
    best: Option<Vec<(usize, Vec<AdapterSpec>)>>, // (type, group) per open GPU
}

impl Search<'_> {
    /// DFS over assignments of `order[i..]`.  `open` holds the opened
    /// GPUs as (type, group); `remaining` the unopened stock per type.
    fn dfs(
        &mut self,
        i: usize,
        open: &mut Vec<(usize, Vec<AdapterSpec>)>,
        remaining: &mut [usize],
        cost: f64,
    ) -> Result<(), PlacementError> {
        self.nodes += 1;
        if self.nodes > self.limits.max_nodes {
            return Err(PlacementError::TimeLimit);
        }
        if i == self.order.len() {
            // Strict improvement only → first optimum in DFS order wins.
            if cost < self.best_cost {
                self.best_cost = cost;
                self.best = Some(open.clone());
            }
            return Ok(());
        }
        // Cost lower bound: completions only add GPUs, never remove them.
        if cost >= self.best_cost {
            return Ok(());
        }
        // detlint: allow(panic-path) — `order` and its index are constructed together; in range by construction
        let a = self.order[i].clone();
        // Branch 1: join an already-open GPU, in open order.
        for g in 0..open.len() {
            // detlint: allow(panic-path) — `open` sized to the fleet/group count at construction; ordinals in range
            open[g].1.push(a.clone());
            let t = open[g].0;
            // detlint: allow(panic-path) — `ests`/`open` sized to the fleet/group count at construction; ordinals in range
            if best_feasible_a_max(&open[g].1, self.ests[t]).is_some() {
                self.dfs(i + 1, open, remaining, cost)?;
            }
            // detlint: allow(panic-path) — `open` sized to the fleet/group count at construction; ordinals in range
            open[g].1.pop();
        }
        // Branch 2: open a fresh GPU — once per in-stock class, in class
        // order (symmetry breaking: fresh GPUs of one class are
        // interchangeable).  The cost bound prunes classes that cannot
        // strictly beat the incumbent.
        for t in 0..self.fleet.types.len() {
            // detlint: allow(panic-path) — `remaining`/`unit_costs` sized to the fleet/group count at construction; ordinals in range
            if remaining[t] == 0 || cost + self.unit_costs[t] >= self.best_cost {
                continue;
            }
            let group = vec![a.clone()];
            // detlint: allow(panic-path) — `ests` sized to the fleet/group count at construction; ordinals in range
            if best_feasible_a_max(&group, self.ests[t]).is_none() {
                continue; // memory/starvation pruning
            }
            // detlint: allow(panic-path) — `remaining` sized to the fleet/group count at construction; ordinals in range
            remaining[t] -= 1;
            open.push((t, group));
            // detlint: allow(panic-path) — `unit_costs` sized to the fleet/group count at construction; ordinals in range
            self.dfs(i + 1, open, remaining, cost + self.unit_costs[t])?;
            open.pop();
            // detlint: allow(panic-path) — `remaining` sized to the fleet/group count at construction; ordinals in range
            remaining[t] += 1;
        }
        Ok(())
    }
}

/// Exactly minimize `Σ unit_costs[type]` over opened GPUs (see the
/// module docs).  `ests` holds one estimator per fleet type; pass the
/// same (cached) estimators the greedy used and the oracle consumes the
/// identical probe data.  Returns [`PlacementError::Starvation`] when no
/// feasible assignment exists within the fleet's stock and
/// [`PlacementError::TimeLimit`] when the node budget runs out.
pub fn solve(
    adapters: &[AdapterSpec],
    fleet: &FleetSpec,
    ests: &[&dyn PerfEstimator],
    unit_costs: &[f64],
    limits: ExactLimits,
) -> Result<FleetPlacement, PlacementError> {
    assert_eq!(ests.len(), fleet.types.len(), "one estimator per fleet type");
    assert_eq!(unit_costs.len(), fleet.types.len(), "one unit cost per fleet type");
    let mut search = Search {
        order: priority_sorting(adapters),
        fleet,
        ests,
        unit_costs,
        limits,
        nodes: 0,
        best_cost: f64::INFINITY,
        best: None,
    };
    let mut remaining = fleet.counts.clone();
    search.dfs(0, &mut Vec::new(), &mut remaining, 0.0)?;
    let Some(groups) = search.best else {
        return Err(PlacementError::Starvation);
    };

    // Materialize: opened GPUs in DFS open order, padded with the
    // unopened stock (a_max 0) in class order — same layout as
    // `fleet::place`.
    let total = fleet.total_gpus();
    let mut placement = Placement { assignment: Default::default(), a_max: vec![0; total] };
    let mut gpu_type = Vec::with_capacity(total);
    let mut used = vec![0usize; fleet.types.len()];
    for (g, (t, group)) in groups.iter().enumerate() {
        // Accepted solutions contain only feasible groups, so the probe
        // is always `Some`; 0 is the degenerate unopened-GPU fallback.
        // detlint: allow(panic-path) — `a_max`/`ests` sized to the fleet/group count at construction; ordinals in range
        let a_max = best_feasible_a_max(group, ests[*t]).map_or(0, |(a, _)| a);
        placement.a_max[g] = a_max;
        for a in group {
            placement.assignment.insert(a.id, g);
        }
        gpu_type.push(*t);
        // detlint: allow(panic-path) — `used` sized to the fleet/group count at construction; ordinals in range
        used[*t] += 1;
    }
    for (t, &count) in fleet.counts.iter().enumerate() {
        // detlint: allow(panic-path) — `used` sized to the fleet/group count at construction; ordinals in range
        gpu_type.extend(std::iter::repeat_n(t, count - used[t]));
    }
    Ok(FleetPlacement { placement, gpu_type })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuTypeSpec;
    use crate::placement::MinGpus;

    fn models() -> crate::ml::MlModels {
        crate::placement::test_models::analytic_models(1)
    }

    fn adapters(n: usize, rate: f64) -> Vec<AdapterSpec> {
        (0..n).map(|id| AdapterSpec { id, rank: 8, rate }).collect()
    }

    #[test]
    fn exact_packs_feasible_workload_onto_one_gpu() {
        let est = models();
        let fleet = FleetSpec::single(GpuTypeSpec::catalog("a10g").unwrap(), 3);
        let fp = solve(&adapters(6, 0.1), &fleet, &[&est], &[1.0], ExactLimits::default())
            .unwrap();
        assert_eq!(fp.gpus_used(), 1);
        assert_eq!(fp.placement.assignment.len(), 6);
    }

    #[test]
    fn exact_matches_or_beats_greedy_gpu_count() {
        let est = models();
        let fleet = FleetSpec::single(GpuTypeSpec::catalog("a10g").unwrap(), 4);
        // 8 × 1.4 req/s × 96 tok ≈ 1075 tok/s demand > one analytic
        // GPU's capacity — the optimum needs at least two GPUs.
        let ads = adapters(8, 1.4);
        let exact =
            solve(&ads, &fleet, &[&est], &[1.0], ExactLimits::default()).unwrap();
        let greedy = crate::placement::fleet::place(&ads, &fleet, &[&est], &MinGpus).unwrap();
        assert!(exact.gpus_used() <= greedy.gpus_used());
        assert!(exact.gpus_used() >= 2, "demand exceeds one GPU");
    }

    #[test]
    fn exact_prefers_cheap_capacity_when_prices_differ() {
        // Two classes, identical performance, different prices: the
        // optimum must use only the cheap class when stock allows.
        let est0 = models();
        let est1 = models();
        let mut cheap = GpuTypeSpec::catalog("a10g").unwrap();
        cheap.cost_per_hour = 1.0;
        let mut exp = GpuTypeSpec::catalog("a10g").unwrap();
        exp.name = "a10g-spot".into();
        exp.cost_per_hour = 9.0;
        let fleet = FleetSpec::new(vec![(exp, 4), (cheap, 4)]);
        let ads = adapters(8, 0.9);
        let prices = fleet.prices();
        let fp = solve(&ads, &fleet, &[&est0, &est1], &prices, ExactLimits::default())
            .unwrap();
        let by_type = fp.used_by_type(&fleet);
        assert_eq!(by_type[0], 0, "expensive class must stay unused, got {by_type:?}");
        assert!(by_type[1] >= 1);
    }

    #[test]
    fn infeasible_instance_reports_starvation_and_node_cap_reports_time_limit() {
        let est = models();
        let fleet = FleetSpec::single(GpuTypeSpec::catalog("a10g").unwrap(), 1);
        let ads = adapters(8, 2.0); // 8 × 2.0 × 96 ≫ capacity
        assert_eq!(
            solve(&ads, &fleet, &[&est], &[1.0], ExactLimits::default()).unwrap_err(),
            PlacementError::Starvation
        );
        let easy = adapters(6, 0.1);
        assert_eq!(
            solve(&easy, &fleet, &[&est], &[1.0], ExactLimits { max_nodes: 2 }).unwrap_err(),
            PlacementError::TimeLimit
        );
    }
}
