//! The performance-estimation seam of the placement layer.
//!
//! Every placement algorithm in this crate asks one question of a
//! candidate allocation: *"if this adapter group shares one GPU under this
//! `A_max`, what throughput does it get, and does it starve or OOM?"*
//! [`PerfEstimator`] makes that question an explicit trait so the answer
//! can come from different oracles:
//!
//! - [`MlEstimator`] — the paper's deployed path: the distilled ML model
//!   pair ([`MlModels`]) trained on Digital-Twin data (µs per query);
//! - [`TwinEstimator`] — the Digital Twin queried directly, skipping the
//!   ML stage (ms per query; the "DT-in-the-loop" ablation);
//! - [`OracleEstimator`] — recorded estimates replayed exactly, for
//!   deterministic tests of the planners themselves;
//! - [`CachedEstimator`] — a memoizing wrapper over any of the above,
//!   keyed at the granularity each estimator declares sound
//!   ([`PerfEstimator::memo_key`]: feature bits for the ML path, the
//!   `(rank, rate)` multiset for the canonicalizing twin), shared via
//!   interior mutability across every probe of a planning pass (Alg. 1's
//!   adjacent testing points, `replan`'s sticky/repair/drain passes, a
//!   whole epoch horizon) and persistable into the pipeline artifact
//!   store.
//!
//! [`MlModels`] implements the trait directly, so existing call sites that
//! pass `&models` keep working unchanged.

use crate::config::EngineConfig;
use crate::dt::{self, Calibration, LengthVariant};
use crate::ml::{features, MlModels};
use crate::util::csv::Table;
use crate::util::threadpool::{default_workers, parallel_map};
use crate::workload::{AdapterSpec, WorkloadSpec};
use std::collections::BTreeMap;
// Hot-path memo + within-batch dedup tables; never iterated unsorted
// (see `LruMemo` and `probe_batched`).
#[allow(clippy::disallowed_types)]
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// A performance estimate for one adapter group under one `A_max`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Estimate {
    /// Predicted served throughput (tok/s).
    pub throughput_tok_s: f64,
    /// Predicted starvation (throughput below incoming demand).
    pub starved: bool,
    /// Predicted static-reservation memory error.  Estimators that fold
    /// memory errors into the starvation verdict (the ML training labels
    /// do) leave this `false`.
    pub memory_error: bool,
}

impl Estimate {
    /// Neither starved nor out of memory — the paper's feasibility test.
    pub fn feasible(&self) -> bool {
        !self.starved && !self.memory_error
    }
}

/// One candidate probe in a batched estimator query ([`PerfEstimator::
/// estimate_batch`]): an adapter group plus the `A_max` to test it under.
#[derive(Debug, Clone, Copy)]
pub struct ProbeQuery<'a> {
    /// The adapter group sharing one GPU.
    pub adapters: &'a [AdapterSpec],
    /// The `A_max` slot count to probe the group at.
    pub a_max: usize,
}

/// Predicts serving performance for an adapter group under a given `A_max`
/// — the seam between the placement algorithms and whatever model backs
/// them (learned, simulated, or recorded).
///
/// `Send + Sync` is a supertrait so one shared `&dyn PerfEstimator` can
/// serve concurrent probes ([`PerfEstimator::estimate_batch`] fans out
/// over the crate thread pool); every implementation is either plain data
/// or already synchronizes internally.
pub trait PerfEstimator: Send + Sync {
    /// Estimate throughput and feasibility for `adapters` sharing one GPU
    /// configured with `a_max` slots.
    fn estimate(&self, adapters: &[AdapterSpec], a_max: usize) -> Estimate;

    /// Estimate a batch of candidate probes, returning one [`Estimate`]
    /// per query **in query order**.  Must be observationally equivalent
    /// to calling [`PerfEstimator::estimate`] on each query in order —
    /// planners rely on that to keep parallel probing bit-identical to
    /// serial.  The default does exactly that; [`CachedEstimator`]
    /// overrides it to fan unique cache misses out over worker threads.
    fn estimate_batch(&self, queries: &[ProbeQuery<'_>]) -> Vec<Estimate> {
        queries.iter().map(|q| self.estimate(q.adapters, q.a_max)).collect()
    }

    /// Short tag for reports and artifacts.
    fn name(&self) -> &'static str;

    /// The key under which this estimator's answers may be memoized
    /// ([`CachedEstimator`]): queries with equal keys **must** produce
    /// bit-identical estimates.  The default is the full group identity —
    /// sorted `(id, rank, rate)` members plus `a_max` — which is sound
    /// for any estimator.  Implementations whose answer provably depends
    /// on less override with a coarser key for more reuse: the ML path
    /// is a pure function of the feature vector ([`probe_key`]), the
    /// canonicalizing twin of the `(rank, rate)` multiset.
    fn memo_key(&self, adapters: &[AdapterSpec], a_max: usize) -> Vec<u64> {
        let mut members: Vec<[u64; 3]> = adapters
            .iter()
            .map(|a| [a.id as u64, a.rank as u64, normalized_bits(a.rate)])
            .collect();
        members.sort_unstable();
        let mut key = vec![a_max as u64];
        key.extend(members.into_iter().flatten());
        key
    }
}

impl PerfEstimator for MlModels {
    fn estimate(&self, adapters: &[AdapterSpec], a_max: usize) -> Estimate {
        let x = features(adapters, a_max);
        Estimate {
            throughput_tok_s: self.predict_throughput(&x),
            starved: self.predict_starvation(&x),
            memory_error: false,
        }
    }

    fn name(&self) -> &'static str {
        "ml"
    }

    fn memo_key(&self, adapters: &[AdapterSpec], a_max: usize) -> Vec<u64> {
        // The prediction is a pure function of the feature vector.
        probe_key(adapters, a_max)
    }
}

/// [`PerfEstimator`] backed by the trained ML model pair — the paper's
/// deployed pipeline configuration (the owning flavour of the direct
/// [`MlModels`] impl, for pipeline stages that hand the models over).
pub struct MlEstimator {
    /// The trained throughput/starvation model pair.
    pub models: MlModels,
}

impl MlEstimator {
    /// Wrap a trained model pair.
    pub fn new(models: MlModels) -> MlEstimator {
        MlEstimator { models }
    }
}

impl From<MlModels> for MlEstimator {
    fn from(models: MlModels) -> MlEstimator {
        MlEstimator::new(models)
    }
}

impl PerfEstimator for MlEstimator {
    fn estimate(&self, adapters: &[AdapterSpec], a_max: usize) -> Estimate {
        self.models.estimate(adapters, a_max)
    }

    fn name(&self) -> &'static str {
        "ml"
    }

    fn memo_key(&self, adapters: &[AdapterSpec], a_max: usize) -> Vec<u64> {
        self.models.memo_key(adapters, a_max)
    }
}

/// [`PerfEstimator`] that runs the Digital Twin per query — the placement
/// pipeline with the ML stage skipped.  ~1000x slower per probe than
/// [`MlEstimator`] but free of learning error; scenarios are built the
/// way the training-set generator ([`crate::ml::dataset`]) builds its
/// samples — ids `0..n-1`, a ShareGPT-like workload with mean request
/// lengths over a short horizon — but over a *canonical* copy of the
/// group: members sorted by `(rank, rate)` before the `0..n-1` re-idding
/// (the generator assigns ranks/rates to ids in RNG order and seeds per
/// scenario, so the match is the construction shape, not scenario
/// identity).  The canonicalization makes the estimate a pure function
/// of the group's `(rank, rate)` multiset and `a_max` — which is what
/// makes the twin's [`PerfEstimator::memo_key`] (the sorted multiset)
/// sound.
pub struct TwinEstimator {
    /// Calibrated twin constants.
    pub calibration: Calibration,
    /// Per-GPU engine configuration template (`a_max`/`s_max_rank` are
    /// overridden per query).
    pub base: EngineConfig,
    /// Simulated horizon per query (seconds).
    pub horizon_s: f64,
    /// Workload seed shared by every query.
    pub seed: u64,
}

impl TwinEstimator {
    /// Default simulated horizon per probe (the dataset generator's).
    pub const DEFAULT_HORIZON_S: f64 = 20.0;
    /// Default workload seed shared by every probe.
    pub const DEFAULT_SEED: u64 = 0xDA7A;

    /// Estimator with the dataset generator's defaults (20 s horizon).
    pub fn new(calibration: Calibration, base: EngineConfig) -> TwinEstimator {
        TwinEstimator {
            calibration,
            base,
            horizon_s: Self::DEFAULT_HORIZON_S,
            seed: Self::DEFAULT_SEED,
        }
    }

    /// Override the simulated horizon (shorter = faster, noisier).
    ///
    /// Bare setter, matching the [`crate::pipeline::Pipeline`] builder
    /// convention.
    pub fn horizon(mut self, horizon_s: f64) -> TwinEstimator {
        self.horizon_s = horizon_s;
        self
    }

    /// Override the workload seed (bare setter, see [`TwinEstimator::horizon`]).
    pub fn seed(mut self, seed: u64) -> TwinEstimator {
        self.seed = seed;
        self
    }
}

/// The group's `(rank, normalized rate bits)` pairs in canonical
/// (sorted) order — what the twin actually simulates and memoizes on.
fn canonical_pairs(adapters: &[AdapterSpec]) -> Vec<(usize, u64)> {
    let mut pairs: Vec<(usize, u64)> =
        adapters.iter().map(|a| (a.rank, normalized_bits(a.rate))).collect();
    pairs.sort_unstable();
    pairs
}

impl PerfEstimator for TwinEstimator {
    fn estimate(&self, adapters: &[AdapterSpec], a_max: usize) -> Estimate {
        let s_max = adapters.iter().map(|a| a.rank).max().unwrap_or(8);
        let mut cfg = self.base.clone();
        cfg.a_max = a_max;
        cfg.s_max_rank = s_max;
        // Canonical scenario: ids 0..n-1 (the dataset generator's id
        // scheme) over the sorted (rank, rate) members.  Per-adapter
        // arrival streams are seeded by id (`WorkloadSpec::trace`), so
        // without the re-idding two groups with identical compositions
        // but different member ids would simulate to different bits —
        // and the memoized twin could then replay one group's estimate
        // for the other.
        let canonical: Vec<AdapterSpec> = canonical_pairs(adapters)
            .into_iter()
            .enumerate()
            .map(|(id, (rank, bits))| AdapterSpec { id, rank, rate: f64::from_bits(bits) })
            .collect();
        let spec = WorkloadSpec::sharegpt_like(canonical, self.horizon_s, self.seed);
        let res = dt::run_twin(&cfg, &self.calibration, &spec, LengthVariant::Mean);
        match res.report {
            Some(rep) => Estimate {
                throughput_tok_s: rep.throughput_tok_s,
                starved: rep.starved,
                memory_error: false,
            },
            None => Estimate { throughput_tok_s: 0.0, starved: true, memory_error: true },
        }
    }

    fn name(&self) -> &'static str {
        "twin"
    }

    fn memo_key(&self, adapters: &[AdapterSpec], a_max: usize) -> Vec<u64> {
        // The canonical scenario above depends on exactly this multiset
        // (plus the estimator's own horizon/seed/config, which are fixed
        // per instance and fingerprinted into persisted artifacts).
        let mut key = vec![a_max as u64];
        key.extend(canonical_pairs(adapters).into_iter().flat_map(|(r, b)| [r as u64, b]));
        key
    }
}

/// The feature-level key: the bit patterns of the placement feature
/// vector ([`crate::ml::features`], which already folds in `a_max` as
/// its last component).  This is [`OracleEstimator`]'s replay key and
/// the [`PerfEstimator::memo_key`] of the ML path — sound there because
/// those answers are pure functions of the features; simulating
/// estimators key on more (see [`TwinEstimator`]).
///
/// Negative zero is normalized to `+0.0` before the bits are taken:
/// `-0.0` and `0.0` are numerically equal inputs to every estimator, so
/// letting their bit patterns differ would only manufacture spurious
/// misses (e.g. a rate std that comes out as `-0.0` on one code path).
pub fn probe_key(adapters: &[AdapterSpec], a_max: usize) -> Vec<u64> {
    features(adapters, a_max).iter().map(|&v| normalized_bits(v)).collect()
}

/// `f64::to_bits` with `-0.0` collapsed onto `+0.0` (see [`probe_key`]).
fn normalized_bits(v: f64) -> u64 {
    // detlint: allow(float-key) — this comparison IS the -0.0 → +0.0 normalization feeding to_bits()
    (if v == 0.0 { 0.0f64 } else { v }).to_bits()
}

/// Test-support [`PerfEstimator`] replaying recorded estimates exactly.
///
/// Keys are the normalized feature-vector bits ([`probe_key`]), so any
/// group with identical features — the only information the ML path ever
/// sees — replays the same estimate.
/// A query with no recorded estimate returns the fallback when one is set
/// and panics otherwise (a miss in a test is a bug in the test).
#[derive(Debug, Clone, Default)]
pub struct OracleEstimator {
    records: BTreeMap<Vec<u64>, Estimate>,
    fallback: Option<Estimate>,
}

impl OracleEstimator {
    /// Empty oracle (every query must be recorded first).
    pub fn new() -> OracleEstimator {
        OracleEstimator::default()
    }

    /// Oracle that answers unrecorded queries with `fallback`.
    pub fn with_fallback(fallback: Estimate) -> OracleEstimator {
        OracleEstimator { records: BTreeMap::new(), fallback: Some(fallback) }
    }

    /// Record the estimate to replay for this group/`A_max`.
    ///
    /// Keys go through [`PerfEstimator::memo_key`] — the *same* path
    /// [`OracleEstimator::estimate`] looks up and [`CachedEstimator`]
    /// memoizes on — so a future key change cannot desync recording from
    /// replay.
    pub fn record(&mut self, adapters: &[AdapterSpec], a_max: usize, estimate: Estimate) {
        let key = self.memo_key(adapters, a_max);
        self.records.insert(key, estimate);
    }

    /// Record by querying another estimator (returns the recorded value).
    pub fn record_from(
        &mut self,
        src: &dyn PerfEstimator,
        adapters: &[AdapterSpec],
        a_max: usize,
    ) -> Estimate {
        let est = src.estimate(adapters, a_max);
        self.record(adapters, a_max, est);
        est
    }

    /// Number of recorded estimates.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// Whether no estimates are recorded.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }
}

impl PerfEstimator for OracleEstimator {
    fn estimate(&self, adapters: &[AdapterSpec], a_max: usize) -> Estimate {
        let key = self.memo_key(adapters, a_max);
        self.records.get(&key).copied().or(self.fallback).unwrap_or_else(|| {
            // detlint: allow(panic-path) — an oracle miss is a harness programming error, not a serving condition; the loud panic is the diagnostic
            panic!(
                "OracleEstimator miss: no recorded estimate for {} adapters at A_max {a_max}",
                adapters.len()
            )
        })
    }

    fn name(&self) -> &'static str {
        "oracle"
    }

    fn memo_key(&self, adapters: &[AdapterSpec], a_max: usize) -> Vec<u64> {
        // Replay is by feature key by construction, so memoizing at the
        // same granularity is exact.
        probe_key(adapters, a_max)
    }
}

/// Hit/miss snapshot of a [`CachedEstimator`] (reports and CI gates).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheStats {
    /// Probes answered from the memo.
    pub hits: u64,
    /// Probes that fell through to the wrapped estimator.
    pub misses: u64,
    /// Memo entries present (warm-started + missed, minus evicted).
    pub entries: usize,
    /// Entries preloaded from persisted memos before any probe ran.
    pub warm: usize,
    /// Entries dropped by the LRU capacity bound ([`CachedEstimator::capacity`]).
    pub evictions: u64,
}

impl CacheStats {
    /// Total probes answered.
    pub fn total(&self) -> u64 {
        self.hits + self.misses
    }

    /// Fraction of probes answered from the memo (0 when nothing ran).
    pub fn hit_rate(&self) -> f64 {
        if self.total() == 0 {
            0.0
        } else {
            self.hits as f64 / self.total() as f64
        }
    }
}

/// The GPU-type tag of probe caches that are not bound to a fleet type
/// (the homogeneous pipeline and ad-hoc callers).
pub const UNTYPED_GPU: &str = "-";

/// Memoizing [`PerfEstimator`] wrapper: every query is answered by the
/// wrapped estimator exactly once per [`PerfEstimator::memo_key`] — the
/// granularity each estimator declares sound for itself — and replayed
/// bit-identically afterwards.
///
/// This is the caching layer that makes the DT-in-the-loop path usable:
/// Alg. 1 probes the same group at adjacent testing points, `replan`'s
/// sticky/repair/drain passes re-probe surviving groups every epoch, and
/// a drift horizon replans near-identical workloads back to back — with
/// a [`TwinEstimator`] behind it each duplicate probe is a full DT
/// simulation.  Interior mutability (a [`Mutex`]-guarded memo and atomic
/// counters) lets one shared `&CachedEstimator` serve a whole planning
/// pass or epoch horizon through the `&dyn PerfEstimator` seam.
///
/// Memos serialize to CSV ([`CachedEstimator::save_memos`] /
/// [`CachedEstimator::load_memos`]) with throughputs stored as f64 bit
/// patterns, so a warm-started cache replays *bit-identical* estimates
/// across processes; the pipeline persists them in its artifact store
/// keyed by the calibration's content fingerprint (DESIGN.md §8).
///
/// ```
/// use adapter_serving::placement::{CachedEstimator, Estimate, OracleEstimator, PerfEstimator};
/// use adapter_serving::workload::AdapterSpec;
/// let inner = OracleEstimator::with_fallback(Estimate {
///     throughput_tok_s: 100.0,
///     starved: false,
///     memory_error: false,
/// });
/// let cached = CachedEstimator::wrap(inner);
/// let ads = vec![AdapterSpec { id: 0, rank: 8, rate: 0.1 }];
/// let a = cached.estimate(&ads, 8); // miss: consults the oracle
/// let b = cached.estimate(&ads, 8); // hit: replayed from the memo
/// assert_eq!(a, b);
/// assert_eq!(cached.stats().hits, 1);
/// assert_eq!(cached.stats().misses, 1);
/// ```
pub struct CachedEstimator {
    inner: Box<dyn PerfEstimator>,
    memo: Mutex<LruMemo>,
    probe_workers: usize,
    memo_tag: String,
    hits: AtomicU64,
    misses: AtomicU64,
    warm: AtomicUsize,
    evictions: AtomicU64,
}

/// The memo map with an optional LRU capacity bound: entries carry a
/// last-touch tick, a tick-ordered index finds the least-recently-used
/// entry to evict when an insert exceeds capacity.
#[derive(Default)]
struct LruMemo {
    /// Hash map on the probe hot path (bench-trajectory-gated); the only
    /// iteration is the sorted snapshot in `CachedEstimator::memos`.
    #[allow(clippy::disallowed_types)]
    entries: HashMap<Vec<u64>, (Estimate, u64)>,
    order: BTreeMap<u64, Vec<u64>>,
    tick: u64,
    capacity: Option<usize>,
}

impl LruMemo {
    fn len(&self) -> usize {
        self.entries.len()
    }

    /// Look up and touch (refresh recency) on hit.
    fn get(&mut self, key: &[u64]) -> Option<Estimate> {
        self.tick += 1;
        let tick = self.tick;
        let (est, last) = self.entries.get_mut(key)?;
        self.order.remove(&std::mem::replace(last, tick));
        self.order.insert(tick, key.to_vec());
        Some(*est)
    }

    /// Insert (or refresh) an entry; returns how many entries the
    /// capacity bound evicted to make room.
    fn insert(&mut self, key: Vec<u64>, est: Estimate) -> u64 {
        self.tick += 1;
        let tick = self.tick;
        match self.entries.entry(key.clone()) {
            std::collections::hash_map::Entry::Occupied(mut o) => {
                let (slot, last) = o.get_mut();
                *slot = est;
                self.order.remove(&std::mem::replace(last, tick));
                self.order.insert(tick, key);
                0
            }
            std::collections::hash_map::Entry::Vacant(v) => {
                v.insert((est, tick));
                self.order.insert(tick, key);
                let cap = self.capacity.unwrap_or(usize::MAX).max(1);
                let mut evicted = 0;
                while self.entries.len() > cap {
                    // The tick-ordered index's first entry is the LRU one;
                    // it can never be the entry just inserted (newest tick).
                    let Some((&t, _)) = self.order.iter().next() else { break };
                    if let Some(victim) = self.order.remove(&t) {
                        self.entries.remove(&victim);
                    }
                    evicted += 1;
                }
                evicted
            }
        }
    }
}

impl CachedEstimator {
    /// Wrap an already-boxed estimator (e.g. one picked from a CLI flag).
    ///
    /// Unbounded by default ([`CachedEstimator::capacity`] adds the LRU
    /// bound); batched probes fan misses out over
    /// [`crate::util::threadpool::default_workers`] threads
    /// ([`CachedEstimator::probe_workers`] overrides).
    pub fn new(inner: Box<dyn PerfEstimator>) -> CachedEstimator {
        CachedEstimator {
            inner,
            memo: Mutex::new(LruMemo::default()),
            probe_workers: default_workers(),
            memo_tag: UNTYPED_GPU.to_string(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            warm: AtomicUsize::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Wrap any estimator value.
    pub fn wrap(inner: impl PerfEstimator + 'static) -> CachedEstimator {
        CachedEstimator::new(Box::new(inner))
    }

    /// Lock the memo table, recovering from mutex poisoning: the memo
    /// holds plain estimate data whose worst post-panic state is an
    /// absent entry, so a probe worker's panic must not cascade into
    /// every later planning pass.
    fn memo_table(&self) -> std::sync::MutexGuard<'_, LruMemo> {
        self.memo.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Bound the memo to `entries` entries, evicting least-recently-used
    /// beyond that (bare-setter builder; evictions show up in
    /// [`CacheStats::evictions`]).  Full-scale sweeps use this so the
    /// probe cache cannot outgrow memory; the default is unbounded.
    pub fn capacity(self, entries: usize) -> CachedEstimator {
        self.memo_table().capacity = Some(entries);
        self
    }

    /// Worker threads for fanning out batched cache misses (bare-setter
    /// builder).  `1` forces serial probing — useful as the baseline when
    /// measuring parallel speedup.
    pub fn probe_workers(mut self, workers: usize) -> CachedEstimator {
        self.probe_workers = workers.max(1);
        self
    }

    /// Tag the cache with the GPU type its probes answer for (bare-setter
    /// builder; defaults to [`UNTYPED_GPU`]).  Persisted memo CSVs carry
    /// the tag in every row and [`CachedEstimator::load_memos`] refuses
    /// rows from a different type — a fleet's per-type probe caches can
    /// never silently replay each other's estimates.
    pub fn memo_tag(mut self, tag: impl Into<String>) -> CachedEstimator {
        self.memo_tag = tag.into();
        self
    }

    /// Hit/miss/size counters since construction.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.memo_table().len(),
            warm: self.warm.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
        }
    }

    /// Preload memos (e.g. loaded from a previous run's artifact); later
    /// probes with these keys are hits, counted as warm-started entries.
    pub fn preload(&self, memos: Vec<(Vec<u64>, Estimate)>) {
        let mut memo = self.memo_table();
        let before = memo.len();
        let mut evicted = 0;
        for (k, e) in memos {
            evicted += memo.insert(k, e);
        }
        // Warm entries are the *new* keys the preload inserted; under a
        // tight capacity bound the LRU may immediately drop some again,
        // which shows up in the eviction counter.
        let inserted = (memo.len() + evicted as usize).saturating_sub(before);
        self.warm.fetch_add(inserted, Ordering::Relaxed);
        self.evictions.fetch_add(evicted, Ordering::Relaxed);
    }

    /// Snapshot of the memo, in deterministic key order.
    pub fn memos(&self) -> Vec<(Vec<u64>, Estimate)> {
        let memo = self.memo_table();
        // detlint: allow(unordered-iter) — hash-order snapshot is sorted by key on the next line
        let mut out: Vec<(Vec<u64>, Estimate)> =
            memo.entries.iter().map(|(k, (v, _))| (k.clone(), *v)).collect();
        out.sort_unstable_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// Persist the memo as CSV (throughputs as f64 bit patterns, so a
    /// reload replays bit-identically).  Every row carries the cache's
    /// GPU-type tag ([`CachedEstimator::memo_tag`]).
    pub fn save_memos(&self, path: &Path) -> anyhow::Result<()> {
        let mut t = Table::new(&["gpu_type", "key", "throughput_bits", "starved", "memory_error"]);
        for (key, e) in self.memos() {
            let k: Vec<String> = key.iter().map(|b| format!("{b:016x}")).collect();
            t.push(vec![
                self.memo_tag.clone(),
                k.join(" "),
                format!("{:016x}", e.throughput_tok_s.to_bits()),
                (e.starved as i32).to_string(),
                (e.memory_error as i32).to_string(),
            ]);
        }
        t.write_file(path)
    }

    /// Load memos persisted by [`CachedEstimator::save_memos`] for a
    /// cache tagged `gpu_type`.  Errs on the pre-fleet schema (no
    /// `gpu_type` column) and on rows tagged for a different GPU type —
    /// stale or foreign memo artifacts are invalidated loudly, never
    /// silently replayed (callers treat the error as a cold start).
    pub fn load_memos(path: &Path, gpu_type: &str) -> anyhow::Result<Vec<(Vec<u64>, Estimate)>> {
        let t = Table::read_file(path)?;
        let expect = ["gpu_type", "key", "throughput_bits", "starved", "memory_error"];
        anyhow::ensure!(
            t.columns == expect,
            "probe memo schema mismatch in {} (expected columns {:?}, found {:?}); \
             pre-fleet memos lack the gpu_type column and must be re-probed",
            path.display(),
            expect,
            t.columns
        );
        let mut out = Vec::with_capacity(t.rows.len());
        for row in &t.rows {
            anyhow::ensure!(
                row[0] == gpu_type,
                "probe memo {} is tagged for GPU type '{}', not '{}'",
                path.display(),
                row[0],
                gpu_type
            );
            let key: Vec<u64> = row[1]
                .split_whitespace()
                .map(|h| u64::from_str_radix(h, 16))
                .collect::<Result<_, _>>()?;
            out.push((
                key,
                Estimate {
                    throughput_tok_s: f64::from_bits(u64::from_str_radix(&row[2], 16)?),
                    starved: row[3].parse::<i32>()? != 0,
                    memory_error: row[4].parse::<i32>()? != 0,
                },
            ));
        }
        Ok(out)
    }
}

impl PerfEstimator for CachedEstimator {
    fn estimate(&self, adapters: &[AdapterSpec], a_max: usize) -> Estimate {
        let key = self.inner.memo_key(adapters, a_max);
        if let Some(e) = self.memo_table().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return e;
        }
        // The lock is not held across the inner call: a twin probe is a
        // full DT simulation and concurrent probers of *different* keys
        // must not serialize behind it (duplicate concurrent misses of
        // the same key are benign — the estimate is deterministic).
        let e = self.inner.estimate(adapters, a_max);
        self.misses.fetch_add(1, Ordering::Relaxed);
        let evicted = self.memo_table().insert(key, e);
        self.evictions.fetch_add(evicted, Ordering::Relaxed);
        e
    }

    /// Parallel fan-out of the batch's cache *misses*: keys resolve
    /// against the memo in query order (hits and in-batch duplicates
    /// count exactly as a serial pass would), then the unique misses run
    /// on the wrapped estimator over up to
    /// [`CachedEstimator::probe_workers`] threads and land in the memo in
    /// first-occurrence order.  Estimates are deterministic per key, so
    /// the returned vector is bit-identical to the serial default.
    fn estimate_batch(&self, queries: &[ProbeQuery<'_>]) -> Vec<Estimate> {
        let keys: Vec<Vec<u64>> =
            queries.iter().map(|q| self.inner.memo_key(q.adapters, q.a_max)).collect();
        // Resolution per query: either an answer from the memo, or the
        // index of the pending (first-occurrence) miss that computes it.
        enum Slot {
            Ready(Estimate),
            Pending(usize),
        }
        let mut slots: Vec<Slot> = Vec::with_capacity(queries.len());
        let mut pending: Vec<usize> = Vec::new(); // query index of each unique miss
        // key -> pending slot; lookup-only within-batch dedup, never iterated.
        #[allow(clippy::disallowed_types)]
        let mut first_seen: HashMap<&[u64], usize> = HashMap::new();
        {
            let mut memo = self.memo_table();
            for (i, key) in keys.iter().enumerate() {
                if let Some(e) = memo.get(key) {
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    slots.push(Slot::Ready(e));
                } else if let Some(&p) = first_seen.get(key.as_slice()) {
                    // Duplicate within the batch: serially this query
                    // would hit the entry its first occurrence inserted.
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    slots.push(Slot::Pending(p));
                } else {
                    self.misses.fetch_add(1, Ordering::Relaxed);
                    first_seen.insert(key.as_slice(), pending.len());
                    slots.push(Slot::Pending(pending.len()));
                    pending.push(i);
                }
            }
        }
        // Fan the unique misses out; the reduction below is in query
        // order regardless of which worker finishes first.
        // detlint: allow(panic-path) — `queries` built with one entry per index of this very loop
        let computed: Vec<Estimate> = parallel_map(pending.clone(), self.probe_workers, |i| {
            self.inner.estimate(queries[i].adapters, queries[i].a_max)
        });
        if !pending.is_empty() {
            let mut memo = self.memo_table();
            let mut evicted = 0;
            // detlint: allow(panic-path) — `keys` built with one entry per index of this very loop
            for (slot, &i) in computed.iter().zip(&pending) {
                evicted += memo.insert(keys[i].clone(), *slot);
            }
            self.evictions.fetch_add(evicted, Ordering::Relaxed);
        }
        slots
            .into_iter()
            .map(|s| match s {
                // detlint: allow(panic-path) — `computed` built with one entry per index of this very loop
                Slot::Ready(e) => e,
                Slot::Pending(p) => computed[p],
            })
            .collect()
    }

    fn name(&self) -> &'static str {
        // The memo changes cost, never answers: reports should attribute
        // estimates to the wrapped estimator.
        self.inner.name()
    }

    fn memo_key(&self, adapters: &[AdapterSpec], a_max: usize) -> Vec<u64> {
        self.inner.memo_key(adapters, a_max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn adapters(n: usize, rank: usize, rate: f64) -> Vec<AdapterSpec> {
        (0..n).map(|id| AdapterSpec { id, rank, rate }).collect()
    }

    #[test]
    fn ml_models_implement_the_trait() {
        let models = crate::placement::test_models::analytic_models(3);
        let ads = adapters(8, 8, 0.05);
        let e = models.estimate(&ads, 16);
        let x = features(&ads, 16);
        assert_eq!(e.throughput_tok_s, models.predict_throughput(&x));
        assert_eq!(e.starved, models.predict_starvation(&x));
        assert!(!e.memory_error);
    }

    #[test]
    fn twin_estimator_is_deterministic_and_flags_oom() {
        let twin = TwinEstimator::new(Calibration::default(), EngineConfig::default())
            .horizon(5.0);
        let ads = adapters(8, 8, 0.1);
        let a = twin.estimate(&ads, 8);
        let b = twin.estimate(&ads, 8);
        assert_eq!(a.throughput_tok_s.to_bits(), b.throughput_tok_s.to_bits());
        assert!(a.throughput_tok_s > 0.0);
        assert!(a.feasible());
        // 384 slots × rank 32 over-reserves the default 8192-token GPU.
        let oom = twin.estimate(&adapters(8, 32, 0.1), 384);
        assert!(oom.memory_error);
        assert!(!oom.feasible());
        assert_eq!(oom.throughput_tok_s, 0.0);
    }

    #[test]
    fn oracle_replays_exactly_and_panics_on_miss() {
        let twin = TwinEstimator::new(Calibration::default(), EngineConfig::default())
            .horizon(3.0);
        let ads = adapters(4, 8, 0.2);
        let mut oracle = OracleEstimator::new();
        let recorded = oracle.record_from(&twin, &ads, 8);
        assert_eq!(oracle.len(), 1);
        let replayed = oracle.estimate(&ads, 8);
        assert_eq!(replayed.throughput_tok_s.to_bits(), recorded.throughput_tok_s.to_bits());
        assert_eq!(replayed, twin.estimate(&ads, 8));
        let res = std::panic::catch_unwind(|| oracle.estimate(&ads, 16));
        assert!(res.is_err(), "unrecorded query must panic without a fallback");
    }

    #[test]
    fn oracle_fallback_answers_misses() {
        let fb = Estimate { throughput_tok_s: 42.0, starved: false, memory_error: false };
        let oracle = OracleEstimator::with_fallback(fb);
        assert_eq!(oracle.estimate(&adapters(2, 8, 0.1), 8), fb);
        assert!(oracle.is_empty());
    }

    #[test]
    fn probe_key_normalizes_negative_zero() {
        // The raw bit patterns differ — keying on them (as `key` once
        // did) would treat numerically equal feature vectors as distinct
        // and manufacture spurious misses.
        assert_ne!((-0.0f64).to_bits(), (0.0f64).to_bits());
        assert_eq!(normalized_bits(-0.0), normalized_bits(0.0));
        assert_eq!(normalized_bits(1.5), (1.5f64).to_bits(), "non-zero bits pass through");
        // End to end: groups whose features are numerically equal (zero
        // spelled either way) share one key, so the oracle replays across
        // the spellings.
        let neg = vec![AdapterSpec { id: 0, rank: 8, rate: -0.0 }];
        let pos = vec![AdapterSpec { id: 0, rank: 8, rate: 0.0 }];
        assert_eq!(probe_key(&neg, 8), probe_key(&pos, 8));
        let mut oracle = OracleEstimator::new();
        let e = Estimate { throughput_tok_s: 7.0, starved: false, memory_error: false };
        oracle.record(&neg, 8, e);
        assert_eq!(oracle.estimate(&pos, 8), e);
    }

    /// A counting estimator: how many probes actually reach the backing
    /// model (misses, for a cached wrapper).
    struct Counting<E> {
        inner: E,
        calls: std::sync::atomic::AtomicU64,
    }

    impl<E: PerfEstimator> Counting<E> {
        fn new(inner: E) -> Counting<E> {
            Counting { inner, calls: std::sync::atomic::AtomicU64::new(0) }
        }
    }

    impl<E: PerfEstimator> PerfEstimator for Counting<E> {
        fn estimate(&self, adapters: &[AdapterSpec], a_max: usize) -> Estimate {
            self.calls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            self.inner.estimate(adapters, a_max)
        }

        fn name(&self) -> &'static str {
            self.inner.name()
        }

        fn memo_key(&self, adapters: &[AdapterSpec], a_max: usize) -> Vec<u64> {
            self.inner.memo_key(adapters, a_max)
        }
    }

    /// The twin simulates a canonical re-idded copy of the group, so its
    /// estimate — and therefore a memo hit — cannot depend on member ids
    /// or order: the collision that would otherwise replay one group's
    /// estimate for a different same-composition group cannot happen.
    #[test]
    fn twin_is_invariant_to_member_ids_and_order_so_memo_hits_are_exact() {
        let twin = TwinEstimator::new(Calibration::default(), EngineConfig::default())
            .horizon(3.0);
        // Same composition, disjoint ids, shuffled order.
        let a: Vec<AdapterSpec> = (0..4).map(|id| AdapterSpec { id, rank: 8, rate: 0.2 }).collect();
        let b: Vec<AdapterSpec> =
            (10..14).rev().map(|id| AdapterSpec { id, rank: 8, rate: 0.2 }).collect();
        assert_eq!(twin.memo_key(&a, 8), twin.memo_key(&b, 8));
        assert_eq!(
            twin.estimate(&a, 8).throughput_tok_s.to_bits(),
            twin.estimate(&b, 8).throughput_tok_s.to_bits(),
            "same composition must simulate to the same bits"
        );
        // Memoized replay for group b equals the uncached twin on b.
        let cached = CachedEstimator::wrap(
            TwinEstimator::new(Calibration::default(), EngineConfig::default()).horizon(3.0),
        );
        cached.estimate(&a, 8);
        let replayed = cached.estimate(&b, 8);
        assert_eq!(cached.stats().hits, 1, "same composition is one memo entry");
        assert_eq!(
            replayed.throughput_tok_s.to_bits(),
            twin.estimate(&b, 8).throughput_tok_s.to_bits()
        );
        // Different composition must NOT collide even when the feature
        // vector coincides: the key carries the full multiset.
        let c: Vec<AdapterSpec> = (0..4).map(|id| AdapterSpec { id, rank: 8, rate: 0.1 }).collect();
        assert_ne!(twin.memo_key(&a, 8), twin.memo_key(&c, 8));
    }

    #[test]
    fn cached_estimator_memoizes_bit_identically() {
        let twin = TwinEstimator::new(Calibration::default(), EngineConfig::default())
            .horizon(3.0);
        let uncached = TwinEstimator::new(Calibration::default(), EngineConfig::default())
            .horizon(3.0);
        let cached = CachedEstimator::wrap(Counting::new(twin));
        let ads = adapters(4, 8, 0.2);
        let miss = cached.estimate(&ads, 8);
        let hit = cached.estimate(&ads, 8);
        assert_eq!(miss.throughput_tok_s.to_bits(), hit.throughput_tok_s.to_bits());
        assert_eq!(
            miss.throughput_tok_s.to_bits(),
            uncached.estimate(&ads, 8).throughput_tok_s.to_bits(),
            "memoized estimate must be bit-identical to the uncached twin"
        );
        let stats = cached.stats();
        assert_eq!((stats.hits, stats.misses, stats.entries), (1, 1, 1));
        assert_eq!(cached.name(), "twin", "reports attribute to the wrapped estimator");
    }

    #[test]
    fn cached_estimator_memos_round_trip_and_warm_start() {
        let twin = TwinEstimator::new(Calibration::default(), EngineConfig::default())
            .horizon(3.0);
        let cached = CachedEstimator::wrap(twin);
        let groups = [adapters(4, 8, 0.2), adapters(8, 16, 0.1), adapters(2, 32, 0.05)];
        for g in &groups {
            cached.estimate(g, 8);
            cached.estimate(g, 16);
        }
        let dir = std::env::temp_dir().join(format!("probe_memos_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("memos.csv");
        cached.save_memos(&path).unwrap();

        // A fresh cache warm-started from disk answers every probe
        // without touching the backing estimator, bit-identically.
        let counting = Counting::new(
            TwinEstimator::new(Calibration::default(), EngineConfig::default()).horizon(3.0),
        );
        let warm = CachedEstimator::wrap(counting);
        warm.preload(CachedEstimator::load_memos(&path, UNTYPED_GPU).unwrap());
        assert_eq!(warm.stats().warm, 6);
        // A cache tagged for a different GPU type refuses these memos
        // (invalidated loudly, not silently replayed).
        assert!(CachedEstimator::load_memos(&path, "a100").is_err());
        for g in &groups {
            for a_max in [8usize, 16] {
                assert_eq!(
                    warm.estimate(g, a_max).throughput_tok_s.to_bits(),
                    cached.estimate(g, a_max).throughput_tok_s.to_bits()
                );
            }
        }
        let stats = warm.stats();
        assert_eq!(stats.misses, 0, "warm-started probes must not re-simulate");
        assert_eq!(stats.hits, 6);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn oracle_record_and_probe_key_share_one_normalization_path() {
        // Satellite fix: `record`/`record_from` key through `memo_key`,
        // which for the oracle *is* `probe_key` — a future key change
        // cannot desync recording from replay.
        let oracle = OracleEstimator::new();
        let ads = adapters(5, 16, 0.07);
        for a_max in [8usize, 64, 384] {
            assert_eq!(oracle.memo_key(&ads, a_max), probe_key(&ads, a_max));
        }
        // And record_from lands on exactly that key: replay answers both
        // the original group and any group with the same features.
        let fb = Estimate { throughput_tok_s: 9.0, starved: false, memory_error: false };
        let mut rec = OracleEstimator::new();
        rec.record_from(&OracleEstimator::with_fallback(fb), &ads, 64);
        assert_eq!(rec.len(), 1);
        assert_eq!(rec.estimate(&ads, 64), fb);
    }

    #[test]
    fn old_schema_memo_csv_is_rejected_not_misread() {
        // Pre-fleet memo CSVs (no gpu_type column) must fail the load —
        // the pipeline treats the error as a cold start and re-probes.
        let dir = std::env::temp_dir().join(format!("probe_memos_old_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("old.csv");
        std::fs::write(
            &path,
            "key,throughput_bits,starved,memory_error\n0000000000000008,4059000000000000,0,0\n",
        )
        .unwrap();
        let err = CachedEstimator::load_memos(&path, UNTYPED_GPU).unwrap_err();
        assert!(err.to_string().contains("gpu_type"), "unexpected error: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn batch_is_bit_identical_to_serial_with_serial_count_semantics() {
        let ads_a = adapters(4, 8, 0.2);
        let ads_b = adapters(8, 16, 0.1);
        let ads_c = adapters(2, 32, 0.05);
        // Duplicate of ads_a's key inside the batch: serially the second
        // occurrence is a hit on the entry the first inserted.
        let queries = [
            ProbeQuery { adapters: &ads_a, a_max: 8 },
            ProbeQuery { adapters: &ads_b, a_max: 8 },
            ProbeQuery { adapters: &ads_a, a_max: 8 },
            ProbeQuery { adapters: &ads_c, a_max: 16 },
        ];
        let serial = CachedEstimator::wrap(Counting::new(
            TwinEstimator::new(Calibration::default(), EngineConfig::default()).horizon(3.0),
        ))
        .probe_workers(1);
        let parallel = CachedEstimator::wrap(Counting::new(
            TwinEstimator::new(Calibration::default(), EngineConfig::default()).horizon(3.0),
        ))
        .probe_workers(4);
        let out_s: Vec<Estimate> =
            queries.iter().map(|q| serial.estimate(q.adapters, q.a_max)).collect();
        let out_p = parallel.estimate_batch(&queries);
        for (s, p) in out_s.iter().zip(&out_p) {
            assert_eq!(s.throughput_tok_s.to_bits(), p.throughput_tok_s.to_bits());
            assert_eq!((s.starved, s.memory_error), (p.starved, p.memory_error));
        }
        // Hit/miss/entry counts match the serial pass exactly, so every
        // downstream cache-efficiency gate is invariant to batching.
        assert_eq!(serial.stats(), parallel.stats());
        let stats = parallel.stats();
        assert_eq!((stats.hits, stats.misses, stats.entries), (1, 3, 3));
        // A second identical batch is all hits on both.
        parallel.estimate_batch(&queries);
        assert_eq!(parallel.stats().hits, 1 + queries.len() as u64);
    }

    #[test]
    fn lru_capacity_bound_evicts_and_recomputes() {
        let fb = Estimate { throughput_tok_s: 11.0, starved: false, memory_error: false };
        let counting = Counting::new(OracleEstimator::with_fallback(fb));
        let cached = CachedEstimator::wrap(counting).capacity(2);
        let groups: Vec<Vec<AdapterSpec>> = (1..=3).map(|n| adapters(n, 8, 0.1)).collect();
        for g in &groups {
            cached.estimate(g, 8); // 3 distinct keys through a 2-entry memo
        }
        let stats = cached.stats();
        assert_eq!(stats.misses, 3);
        assert_eq!(stats.entries, 2, "capacity bound holds");
        assert_eq!(stats.evictions, 1, "inserting the 3rd key evicts the LRU 1st");
        // The evicted (oldest) key recomputes; the resident ones hit.
        cached.estimate(&groups[0], 8);
        assert_eq!(cached.stats().misses, 4, "evicted key falls through again");
        cached.estimate(&groups[2], 8);
        assert_eq!(cached.stats().hits, 1, "resident key still hits");
        // Recency matters: touch the older resident, then insert — the
        // untouched one is evicted instead.
        let fresh = CachedEstimator::wrap(OracleEstimator::with_fallback(fb)).capacity(2);
        fresh.estimate(&groups[0], 8);
        fresh.estimate(&groups[1], 8);
        fresh.estimate(&groups[0], 8); // touch: groups[1] is now LRU
        fresh.estimate(&groups[2], 8); // evicts groups[1]
        fresh.estimate(&groups[0], 8);
        assert_eq!(fresh.stats().evictions, 1);
        assert_eq!(fresh.stats().hits, 2, "touched key survived the eviction");
    }

    #[test]
    fn lru_eviction_then_warm_start_round_trip() {
        // Satellite test: a bounded cache's surviving memos persist and
        // warm-start a fresh cache bit-identically; the evicted entry is
        // simply absent (a later probe recomputes it deterministically).
        fn twin() -> TwinEstimator {
            TwinEstimator::new(Calibration::default(), EngineConfig::default()).horizon(3.0)
        }
        let bounded = CachedEstimator::wrap(twin()).capacity(2);
        let groups = [adapters(4, 8, 0.2), adapters(8, 16, 0.1), adapters(2, 32, 0.05)];
        for g in &groups {
            bounded.estimate(g, 8);
        }
        assert_eq!(bounded.stats().evictions, 1);
        assert_eq!(bounded.stats().entries, 2);
        let dir = std::env::temp_dir().join(format!("lru_memos_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("memos.csv");
        bounded.save_memos(&path).unwrap();

        let counting = Counting::new(twin());
        let warm = CachedEstimator::wrap(counting);
        warm.preload(CachedEstimator::load_memos(&path, UNTYPED_GPU).unwrap());
        assert_eq!(warm.stats().warm, 2, "only the surviving entries persist");
        // Survivors replay without re-simulating; the evicted group (the
        // oldest, groups[0]) recomputes to the same bits as a fresh twin.
        for g in &groups[1..] {
            assert_eq!(
                warm.estimate(g, 8).throughput_tok_s.to_bits(),
                bounded.estimate(g, 8).throughput_tok_s.to_bits()
            );
        }
        assert_eq!(warm.stats().misses, 0);
        assert_eq!(
            warm.estimate(&groups[0], 8).throughput_tok_s.to_bits(),
            twin().estimate(&groups[0], 8).throughput_tok_s.to_bits()
        );
        assert_eq!(warm.stats().misses, 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
