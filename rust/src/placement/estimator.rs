//! The performance-estimation seam of the placement layer.
//!
//! Every placement algorithm in this crate asks one question of a
//! candidate allocation: *"if this adapter group shares one GPU under this
//! `A_max`, what throughput does it get, and does it starve or OOM?"*
//! [`PerfEstimator`] makes that question an explicit trait so the answer
//! can come from different oracles:
//!
//! - [`MlEstimator`] — the paper's deployed path: the distilled ML model
//!   pair ([`MlModels`]) trained on Digital-Twin data (µs per query);
//! - [`TwinEstimator`] — the Digital Twin queried directly, skipping the
//!   ML stage (ms per query; the "DT-in-the-loop" ablation);
//! - [`OracleEstimator`] — recorded estimates replayed exactly, for
//!   deterministic tests of the planners themselves.
//!
//! [`MlModels`] implements the trait directly, so existing call sites that
//! pass `&models` keep working unchanged.

use crate::config::EngineConfig;
use crate::dt::{self, Calibration, LengthVariant};
use crate::ml::{features, MlModels};
use crate::workload::{AdapterSpec, WorkloadSpec};
use std::collections::BTreeMap;

/// A performance estimate for one adapter group under one `A_max`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Estimate {
    /// Predicted served throughput (tok/s).
    pub throughput_tok_s: f64,
    /// Predicted starvation (throughput below incoming demand).
    pub starved: bool,
    /// Predicted static-reservation memory error.  Estimators that fold
    /// memory errors into the starvation verdict (the ML training labels
    /// do) leave this `false`.
    pub memory_error: bool,
}

impl Estimate {
    /// Neither starved nor out of memory — the paper's feasibility test.
    pub fn feasible(&self) -> bool {
        !self.starved && !self.memory_error
    }
}

/// Predicts serving performance for an adapter group under a given `A_max`
/// — the seam between the placement algorithms and whatever model backs
/// them (learned, simulated, or recorded).
pub trait PerfEstimator {
    /// Estimate throughput and feasibility for `adapters` sharing one GPU
    /// configured with `a_max` slots.
    fn estimate(&self, adapters: &[AdapterSpec], a_max: usize) -> Estimate;

    /// Short tag for reports and artifacts.
    fn name(&self) -> &'static str;
}

impl PerfEstimator for MlModels {
    fn estimate(&self, adapters: &[AdapterSpec], a_max: usize) -> Estimate {
        let x = features(adapters, a_max);
        Estimate {
            throughput_tok_s: self.predict_throughput(&x),
            starved: self.predict_starvation(&x),
            memory_error: false,
        }
    }

    fn name(&self) -> &'static str {
        "ml"
    }
}

/// [`PerfEstimator`] backed by the trained ML model pair — the paper's
/// deployed pipeline configuration (the owning flavour of the direct
/// [`MlModels`] impl, for pipeline stages that hand the models over).
pub struct MlEstimator {
    /// The trained throughput/starvation model pair.
    pub models: MlModels,
}

impl MlEstimator {
    /// Wrap a trained model pair.
    pub fn new(models: MlModels) -> MlEstimator {
        MlEstimator { models }
    }
}

impl From<MlModels> for MlEstimator {
    fn from(models: MlModels) -> MlEstimator {
        MlEstimator::new(models)
    }
}

impl PerfEstimator for MlEstimator {
    fn estimate(&self, adapters: &[AdapterSpec], a_max: usize) -> Estimate {
        self.models.estimate(adapters, a_max)
    }

    fn name(&self) -> &'static str {
        "ml"
    }
}

/// [`PerfEstimator`] that runs the Digital Twin per query — the placement
/// pipeline with the ML stage skipped.  ~1000x slower per probe than
/// [`MlEstimator`] but free of learning error; scenarios are constructed
/// exactly like the training-set generator ([`crate::ml::dataset`]): a
/// ShareGPT-like workload with mean request lengths over a short horizon.
pub struct TwinEstimator {
    /// Calibrated twin constants.
    pub calibration: Calibration,
    /// Per-GPU engine configuration template (`a_max`/`s_max_rank` are
    /// overridden per query).
    pub base: EngineConfig,
    /// Simulated horizon per query (seconds).
    pub horizon_s: f64,
    /// Workload seed shared by every query.
    pub seed: u64,
}

impl TwinEstimator {
    /// Estimator with the dataset generator's defaults (20 s horizon).
    pub fn new(calibration: Calibration, base: EngineConfig) -> TwinEstimator {
        TwinEstimator { calibration, base, horizon_s: 20.0, seed: 0xDA7A }
    }

    /// Override the simulated horizon (shorter = faster, noisier).
    pub fn with_horizon(mut self, horizon_s: f64) -> TwinEstimator {
        self.horizon_s = horizon_s;
        self
    }

    /// Override the workload seed.
    pub fn with_seed(mut self, seed: u64) -> TwinEstimator {
        self.seed = seed;
        self
    }
}

impl PerfEstimator for TwinEstimator {
    fn estimate(&self, adapters: &[AdapterSpec], a_max: usize) -> Estimate {
        let s_max = adapters.iter().map(|a| a.rank).max().unwrap_or(8);
        let mut cfg = self.base.clone();
        cfg.a_max = a_max;
        cfg.s_max_rank = s_max;
        let spec = WorkloadSpec::sharegpt_like(adapters.to_vec(), self.horizon_s, self.seed);
        let res = dt::run_twin(&cfg, &self.calibration, &spec, LengthVariant::Mean);
        match res.report {
            Some(rep) => Estimate {
                throughput_tok_s: rep.throughput_tok_s,
                starved: rep.starved,
                memory_error: false,
            },
            None => Estimate { throughput_tok_s: 0.0, starved: true, memory_error: true },
        }
    }

    fn name(&self) -> &'static str {
        "twin"
    }
}

/// Test-support [`PerfEstimator`] replaying recorded estimates exactly.
///
/// Keys are the bit patterns of the placement feature vector
/// ([`crate::ml::features`]), so any group with identical features — the
/// only information the ML path ever sees — replays the same estimate.
/// A query with no recorded estimate returns the fallback when one is set
/// and panics otherwise (a miss in a test is a bug in the test).
#[derive(Debug, Clone, Default)]
pub struct OracleEstimator {
    records: BTreeMap<Vec<u64>, Estimate>,
    fallback: Option<Estimate>,
}

impl OracleEstimator {
    /// Empty oracle (every query must be recorded first).
    pub fn new() -> OracleEstimator {
        OracleEstimator::default()
    }

    /// Oracle that answers unrecorded queries with `fallback`.
    pub fn with_fallback(fallback: Estimate) -> OracleEstimator {
        OracleEstimator { records: BTreeMap::new(), fallback: Some(fallback) }
    }

    fn key(adapters: &[AdapterSpec], a_max: usize) -> Vec<u64> {
        features(adapters, a_max).iter().map(|v| v.to_bits()).collect()
    }

    /// Record the estimate to replay for this group/`A_max`.
    pub fn record(&mut self, adapters: &[AdapterSpec], a_max: usize, estimate: Estimate) {
        self.records.insert(Self::key(adapters, a_max), estimate);
    }

    /// Record by querying another estimator (returns the recorded value).
    pub fn record_from(
        &mut self,
        src: &dyn PerfEstimator,
        adapters: &[AdapterSpec],
        a_max: usize,
    ) -> Estimate {
        let est = src.estimate(adapters, a_max);
        self.record(adapters, a_max, est);
        est
    }

    /// Number of recorded estimates.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// Whether no estimates are recorded.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }
}

impl PerfEstimator for OracleEstimator {
    fn estimate(&self, adapters: &[AdapterSpec], a_max: usize) -> Estimate {
        self.records.get(&Self::key(adapters, a_max)).copied().or(self.fallback).unwrap_or_else(
            || {
                panic!(
                    "OracleEstimator miss: no recorded estimate for {} adapters at A_max {a_max}",
                    adapters.len()
                )
            },
        )
    }

    fn name(&self) -> &'static str {
        "oracle"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn adapters(n: usize, rank: usize, rate: f64) -> Vec<AdapterSpec> {
        (0..n).map(|id| AdapterSpec { id, rank, rate }).collect()
    }

    #[test]
    fn ml_models_implement_the_trait() {
        let models = crate::placement::test_models::analytic_models(3);
        let ads = adapters(8, 8, 0.05);
        let e = models.estimate(&ads, 16);
        let x = features(&ads, 16);
        assert_eq!(e.throughput_tok_s, models.predict_throughput(&x));
        assert_eq!(e.starved, models.predict_starvation(&x));
        assert!(!e.memory_error);
    }

    #[test]
    fn twin_estimator_is_deterministic_and_flags_oom() {
        let twin = TwinEstimator::new(Calibration::default(), EngineConfig::default())
            .with_horizon(5.0);
        let ads = adapters(8, 8, 0.1);
        let a = twin.estimate(&ads, 8);
        let b = twin.estimate(&ads, 8);
        assert_eq!(a.throughput_tok_s.to_bits(), b.throughput_tok_s.to_bits());
        assert!(a.throughput_tok_s > 0.0);
        assert!(a.feasible());
        // 384 slots × rank 32 over-reserves the default 8192-token GPU.
        let oom = twin.estimate(&adapters(8, 32, 0.1), 384);
        assert!(oom.memory_error);
        assert!(!oom.feasible());
        assert_eq!(oom.throughput_tok_s, 0.0);
    }

    #[test]
    fn oracle_replays_exactly_and_panics_on_miss() {
        let twin = TwinEstimator::new(Calibration::default(), EngineConfig::default())
            .with_horizon(3.0);
        let ads = adapters(4, 8, 0.2);
        let mut oracle = OracleEstimator::new();
        let recorded = oracle.record_from(&twin, &ads, 8);
        assert_eq!(oracle.len(), 1);
        let replayed = oracle.estimate(&ads, 8);
        assert_eq!(replayed.throughput_tok_s.to_bits(), recorded.throughput_tok_s.to_bits());
        assert_eq!(replayed, twin.estimate(&ads, 8));
        let res = std::panic::catch_unwind(|| oracle.estimate(&ads, 16));
        assert!(res.is_err(), "unrecorded query must panic without a fallback");
    }

    #[test]
    fn oracle_fallback_answers_misses() {
        let fb = Estimate { throughput_tok_s: 42.0, starved: false, memory_error: false };
        let oracle = OracleEstimator::with_fallback(fb);
        assert_eq!(oracle.estimate(&adapters(2, 8, 0.1), 8), fb);
        assert!(oracle.is_empty());
    }
}
