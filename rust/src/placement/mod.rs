//! The adapter caching problem (paper §7): place adapters on GPUs,
//! choosing a per-GPU `A_max`, without starvation or memory errors.
//!
//! Two trait seams make the layer pluggable (DESIGN.md §8):
//! [`PerfEstimator`] supplies the per-group throughput/feasibility
//! predictions (learned ML models, the Digital Twin directly, or recorded
//! test oracles) and [`Objective`] defines what the planner minimizes
//! ([`MinGpus`] — the paper's Alg. 1 goal — or [`MinLatency`], §8.4.4).
//! [`plan`] is the objective-generic one-shot entry point.
//!
//! - [`estimator`] — the [`PerfEstimator`] seam and its implementations,
//!   including the memoizing [`CachedEstimator`] that makes the
//!   DT-in-the-loop path affordable (probe memos persist via the
//!   pipeline artifact store);
//! - [`objective`] — the [`Objective`] seam
//!   ([`MinGpus`]/[`MinLatency`]/[`MinCost`]);
//! - [`greedy`] — the paper's contribution (Algorithms 1 & 2);
//! - [`fleet`] — Alg. 1 over a typed heterogeneous fleet
//!   ([`crate::config::FleetSpec`], DESIGN.md §11);
//! - [`exact`] — branch-and-bound oracle that provably minimizes GPU
//!   count / fleet cost on small instances (differential testing);
//! - [`baselines`] — MaxBase, MaxBase*, Random (§8.4);
//! - [`dlora`] — the dLoRA proactive placement reimplementation (§8.4.3);
//! - [`latency`] — the ProposedLat latency-oriented variant (§8.4.4);
//! - [`replan`] — migration-aware incremental re-placement for drifting
//!   workloads, generic over both seams (DESIGN.md §7/§8).

pub mod baselines;
pub mod dlora;
pub mod estimator;
pub mod exact;
pub mod fleet;
pub mod greedy;
pub mod latency;
pub mod objective;
pub mod replan;

pub use estimator::{
    probe_key, CacheStats, CachedEstimator, Estimate, MlEstimator, OracleEstimator,
    PerfEstimator, ProbeQuery, TwinEstimator, UNTYPED_GPU,
};
pub use exact::ExactLimits;
pub use fleet::{FleetPlacement, TypedEstimator};
pub use objective::{plan, Candidate, MinCost, MinGpus, MinLatency, Objective, OpenCandidate};
pub use replan::{replan_with_ledger, ReplanLedger};

use crate::workload::AdapterSpec;
use std::collections::BTreeMap;

/// The paper's testing-point array, reused as the `A_max` candidate set.
pub const TESTING_POINTS: [usize; 11] = [8, 16, 32, 64, 96, 128, 160, 192, 256, 320, 384];

/// The largest testing point — the `A_max` planners saturate at.  A
/// literal (not `TESTING_POINTS.last().unwrap()`) so planner hot paths
/// stay panic-free; pinned to the table's last entry by a unit test.
pub const MAX_TESTING_POINT: usize = 384;

/// A complete placement decision.
///
/// ```
/// use adapter_serving::placement::Placement;
/// let mut p = Placement { assignment: Default::default(), a_max: vec![8, 8, 0, 0] };
/// p.assignment.insert(0, 0); // adapter 0 → GPU 0
/// p.assignment.insert(1, 0);
/// p.assignment.insert(2, 1);
/// assert_eq!(p.gpus_used(), 2);
/// assert_eq!(p.adapters_on(0), vec![0, 1]);
/// ```
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Placement {
    /// adapter id → GPU index.  Ordered map: plans are iterated when
    /// deriving per-GPU groups and diffing replans, and that order must
    /// be a function of the plan alone (determinism contract, DESIGN §13).
    pub assignment: BTreeMap<usize, usize>,
    /// Per-GPU `A_max` configuration (0 = GPU unused).
    pub a_max: Vec<usize>,
}

impl Placement {
    /// Number of GPUs with at least one adapter assigned.
    pub fn gpus_used(&self) -> usize {
        let mut used: Vec<bool> = vec![false; self.a_max.len()];
        for &g in self.assignment.values() {
            // detlint: allow(panic-path) — `used` sized to the fleet/group count at construction; ordinals in range
            used[g] = true;
        }
        used.iter().filter(|&&u| u).count()
    }

    /// Adapter ids assigned to GPU `g`.
    pub fn adapters_on(&self, g: usize) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .assignment
            .iter()
            .filter(|(_, &gpu)| gpu == g)
            .map(|(&a, _)| a)
            .collect();
        v.sort();
        v
    }

    /// The adapter subsets per GPU.
    pub fn per_gpu<'a>(&self, adapters: &'a [AdapterSpec]) -> Vec<Vec<&'a AdapterSpec>> {
        let mut out: Vec<Vec<&AdapterSpec>> = vec![Vec::new(); self.a_max.len()];
        for a in adapters {
            if let Some(&g) = self.assignment.get(&a.id) {
                // detlint: allow(panic-path) — `out` built with one entry per index of this very loop
                out[g].push(a);
            }
        }
        out
    }
}

/// Why a placement attempt failed.
#[derive(Debug, Clone, PartialEq)]
pub enum PlacementError {
    /// No starvation-free allocation exists within the available GPUs.
    Starvation,
    /// The algorithm exceeded its wall-clock budget (dLoRA reproduction).
    TimeLimit,
}

impl std::fmt::Display for PlacementError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlacementError::Starvation => {
                write!(f, "starvation: no feasible allocation within the available GPUs")
            }
            PlacementError::TimeLimit => {
                write!(f, "placement algorithm exceeded its time limit")
            }
        }
    }
}

impl std::error::Error for PlacementError {}

/// Alias returned by every placement algorithm in this module.
pub type PlacementResult = Result<Placement, PlacementError>;

/// Shared test support: the analytic stand-in ML models used by the
/// greedy, replan and epoch-runner tests.
#[cfg(test)]
pub(crate) mod test_models {
    use crate::ml::refine::FlatTree;
    use crate::ml::tree::{Criterion, Tree, TreeParams};
    use crate::ml::{MlModels, Predictor};
    use crate::util::rng::Rng;

    /// Analytic stand-in models fitted on synthetic data: capacity
    /// 1000 − 2·A_max tok/s; starvation when demand (sum_rate × 96 tok)
    /// exceeds capacity or `A_max` is under-provisioned for the adapter
    /// count.  Trees are trained so the real `Predictor` machinery is
    /// exercised.
    pub(crate) fn analytic_models(seed: u64) -> MlModels {
        let mut xs = vec![];
        let mut thr = vec![];
        let mut st = vec![];
        let mut rng = Rng::new(seed);
        for _ in 0..4000 {
            let sum_rate = rng.range_f64(0.0, 30.0);
            let a_max = *rng.choose(&[8.0, 16.0, 32.0, 64.0, 96.0, 128.0, 160.0, 192.0, 256.0]);
            let n = rng.range(1, 384) as f64;
            let demand = sum_rate * 96.0;
            let capacity = 1000.0 - a_max * 2.0;
            let mut x = vec![0.0; crate::ml::N_FEATURES];
            x[0] = n;
            x[1] = sum_rate;
            x[3] = 8.0;
            x[4] = 8.0;
            x[6] = a_max;
            xs.push(x);
            thr.push(demand.min(capacity));
            st.push((demand > capacity || a_max < (n / 8.0).min(64.0)) as i32 as f64);
        }
        let t_thr = Tree::fit(&xs, &thr, &TreeParams::default());
        let t_st =
            Tree::fit(&xs, &st, &TreeParams { criterion: Criterion::Gini, ..Default::default() });
        MlModels {
            throughput: Predictor::Flat(FlatTree::compile(&t_thr)),
            starvation: Predictor::Flat(FlatTree::compile(&t_st)),
            scaler: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn max_testing_point_is_the_tables_last_entry() {
        assert_eq!(TESTING_POINTS.last(), Some(&MAX_TESTING_POINT));
    }

    #[test]
    fn gpus_used_counts_distinct() {
        let mut p = Placement { assignment: BTreeMap::new(), a_max: vec![8, 8, 0, 0] };
        p.assignment.insert(0, 0);
        p.assignment.insert(1, 0);
        p.assignment.insert(2, 1);
        assert_eq!(p.gpus_used(), 2);
        assert_eq!(p.adapters_on(0), vec![0, 1]);
    }
}
