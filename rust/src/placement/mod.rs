//! The adapter caching problem (paper §7): place adapters on the minimum
//! number of GPUs, choosing a per-GPU `A_max`, without starvation or
//! memory errors.
//!
//! - [`greedy`] — the paper's contribution (Algorithms 1 & 2);
//! - [`baselines`] — MaxBase, MaxBase*, Random (§8.4);
//! - [`dlora`] — the dLoRA proactive placement reimplementation (§8.4.3);
//! - [`latency`] — the ProposedLat latency-oriented variant (§8.4.4).

pub mod baselines;
pub mod dlora;
pub mod greedy;
pub mod latency;

use crate::workload::AdapterSpec;
use std::collections::HashMap;

/// The paper's testing-point array, reused as the `A_max` candidate set.
pub const TESTING_POINTS: [usize; 11] = [8, 16, 32, 64, 96, 128, 160, 192, 256, 320, 384];

/// A complete placement decision.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Placement {
    /// adapter id → GPU index.
    pub assignment: HashMap<usize, usize>,
    /// Per-GPU `A_max` configuration (0 = GPU unused).
    pub a_max: Vec<usize>,
}

impl Placement {
    pub fn gpus_used(&self) -> usize {
        let mut used: Vec<bool> = vec![false; self.a_max.len()];
        for &g in self.assignment.values() {
            used[g] = true;
        }
        used.iter().filter(|&&u| u).count()
    }

    /// Adapter ids assigned to GPU `g`.
    pub fn adapters_on(&self, g: usize) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .assignment
            .iter()
            .filter(|(_, &gpu)| gpu == g)
            .map(|(&a, _)| a)
            .collect();
        v.sort();
        v
    }

    /// The adapter subsets per GPU.
    pub fn per_gpu<'a>(&self, adapters: &'a [AdapterSpec]) -> Vec<Vec<&'a AdapterSpec>> {
        let mut out: Vec<Vec<&AdapterSpec>> = vec![Vec::new(); self.a_max.len()];
        for a in adapters {
            if let Some(&g) = self.assignment.get(&a.id) {
                out[g].push(a);
            }
        }
        out
    }
}

/// Why a placement attempt failed.
#[derive(Debug, Clone, PartialEq)]
pub enum PlacementError {
    Starvation,
    TimeLimit,
}

impl std::fmt::Display for PlacementError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlacementError::Starvation => {
                write!(f, "starvation: no feasible allocation within the available GPUs")
            }
            PlacementError::TimeLimit => {
                write!(f, "placement algorithm exceeded its time limit")
            }
        }
    }
}

impl std::error::Error for PlacementError {}

pub type PlacementResult = Result<Placement, PlacementError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpus_used_counts_distinct() {
        let mut p = Placement { assignment: HashMap::new(), a_max: vec![8, 8, 0, 0] };
        p.assignment.insert(0, 0);
        p.assignment.insert(1, 0);
        p.assignment.insert(2, 1);
        assert_eq!(p.gpus_used(), 2);
        assert_eq!(p.adapters_on(0), vec![0, 1]);
    }
}
