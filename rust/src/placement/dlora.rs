//! Reimplementation of dLoRA's *proactive* placement (Wu et al., OSDI'24)
//! as described in the paper's §8.4.3 comparison.
//!
//! dLoRA's long-term algorithm is latency-oriented: it spreads load over
//! *all* available GPUs (minimizing the maximum per-GPU load) rather than
//! packing a minimum number of them.  The original code is not available
//! offline; we implement the described behaviour as greedy balanced
//! assignment followed by an iterative best-swap refinement whose cost
//! grows as O(A²·G) per pass — which faithfully reproduces the time-limit
//! failure the paper observes at large adapter counts (Fig. 12, "the
//! placement algorithm does not complete within one hour"; our budget is
//! scaled to the testbed).

use super::{Placement, PlacementError, PlacementResult};
use crate::workload::AdapterSpec;
use std::time::Instant;

/// dLoRA reproduction knobs.
pub struct DloraParams {
    /// Wall-clock budget for the refinement (the paper's 1 h, scaled).
    pub time_limit_s: f64,
    /// Convergence threshold on the balance objective.
    pub tol: f64,
}

impl Default for DloraParams {
    fn default() -> Self {
        DloraParams { time_limit_s: 2.0, tol: 1e-9 }
    }
}

/// Objective: the maximum per-GPU aggregate rate, with a mild variance
/// term (dLoRA balances both adapter load and memory pressure).
fn objective(loads: &[f64], mem: &[f64]) -> f64 {
    let max_load = loads.iter().cloned().fold(0.0, f64::max);
    let mean = loads.iter().sum::<f64>() / loads.len() as f64;
    let var = loads.iter().map(|l| (l - mean) * (l - mean)).sum::<f64>() / loads.len() as f64;
    let max_mem = mem.iter().cloned().fold(0.0, f64::max);
    max_load + 0.1 * var.sqrt() + 1e-4 * max_mem
}

/// dLoRA proactive placement: balanced greedy assignment + best-swap local
/// search under a wall-clock budget.
pub fn place(adapters: &[AdapterSpec], gpus: usize, params: &DloraParams) -> PlacementResult {
    // detlint: allow(wall-clock) — dLoRA reproduces the baseline's wall-clock swap budget (`TimeLimit`); time-boxed by design
    #[allow(clippy::disallowed_methods)]
    let t0 = Instant::now();
    // Phase 1: greedy balanced assignment (rate-descending, least-loaded).
    let mut order: Vec<&AdapterSpec> = adapters.iter().collect();
    order.sort_by(|a, b| b.rate.total_cmp(&a.rate));
    let mut assign: Vec<usize> = vec![0; adapters.len()];
    let mut loads = vec![0.0f64; gpus];
    let mut mem = vec![0.0f64; gpus];
    let mut idx_of: std::collections::BTreeMap<usize, usize> = Default::default();
    for (i, a) in adapters.iter().enumerate() {
        idx_of.insert(a.id, i);
    }
    for a in &order {
        // detlint: allow(panic-path) — `assign`/`idx_of`/`loads` and its index are constructed together; in range by construction
        let g = (0..gpus).min_by(|&x, &y| loads[x].total_cmp(&loads[y])).unwrap_or(0);
        assign[idx_of[&a.id]] = g;
        // detlint: allow(panic-path) — `loads`/`mem` sized to the fleet/group count at construction; ordinals in range
        loads[g] += a.rate;
        mem[g] += a.rank as f64;
    }

    // Phase 2: best-swap local search until converged or out of budget.
    let n = adapters.len();
    loop {
        if t0.elapsed().as_secs_f64() > params.time_limit_s {
            return Err(PlacementError::TimeLimit);
        }
        let current = objective(&loads, &mem);
        let mut best: Option<(usize, usize, f64)> = None; // (adapter idx, new gpu, obj)
        for i in 0..n {
            // Periodic budget check inside the O(A²)-ish scan.
            if i % 64 == 0 && t0.elapsed().as_secs_f64() > params.time_limit_s {
                return Err(PlacementError::TimeLimit);
            }
            // detlint: allow(panic-path) — `assign` sized to the fleet/group count at construction; ordinals in range
            let from = assign[i];
            for to in 0..gpus {
                if to == from {
                    continue;
                }
                let mut l2 = loads.clone();
                let mut m2 = mem.clone();
                // detlint: allow(panic-path) — `adapters`/`l2` and its index are constructed together; in range by construction
                l2[from] -= adapters[i].rate;
                l2[to] += adapters[i].rate;
                // detlint: allow(panic-path) — `adapters`/`m2` and its index are constructed together; in range by construction
                m2[from] -= adapters[i].rank as f64;
                m2[to] += adapters[i].rank as f64;
                let obj = objective(&l2, &m2);
                if obj < best.map_or(current - params.tol, |(_, _, b)| b) {
                    best = Some((i, to, obj));
                }
            }
        }
        match best {
            Some((i, to, _)) => {
                // detlint: allow(panic-path) — `adapters`/`assign`/`loads` and its index are constructed together; in range by construction
                let from = assign[i];
                loads[from] -= adapters[i].rate;
                // detlint: allow(panic-path) — `adapters`/`loads`/`mem` and its index are constructed together; in range by construction
                loads[to] += adapters[i].rate;
                mem[from] -= adapters[i].rank as f64;
                // detlint: allow(panic-path) — `adapters`/`assign`/`mem` and its index are constructed together; in range by construction
                mem[to] += adapters[i].rank as f64;
                assign[i] = to;
            }
            None => break,
        }
    }

    // dLoRA sets parallelism to everything it placed (latency first).
    let mut placement = Placement { assignment: Default::default(), a_max: vec![0; gpus] };
    let mut counts = vec![0usize; gpus];
    for (i, a) in adapters.iter().enumerate() {
        // detlint: allow(panic-path) — `assign`/`counts` sized to the fleet/group count at construction; ordinals in range
        placement.assignment.insert(a.id, assign[i]);
        counts[assign[i]] += 1;
    }
    for g in 0..gpus {
        // detlint: allow(panic-path) — `a_max`/`counts` sized to the fleet/group count at construction; ordinals in range
        placement.a_max[g] = counts[g];
    }
    Ok(placement)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn adapters(n: usize) -> Vec<AdapterSpec> {
        (0..n)
            .map(|id| AdapterSpec { id, rank: 8 + 8 * (id % 3), rate: 0.1 * ((id % 5) + 1) as f64 })
            .collect()
    }

    #[test]
    fn balances_load_across_all_gpus() {
        let ads = adapters(40);
        let p = place(&ads, 4, &DloraParams::default()).unwrap();
        assert_eq!(p.gpus_used(), 4); // latency-oriented: uses everything
        let mut loads = vec![0.0; 4];
        for a in &ads {
            loads[p.assignment[&a.id]] += a.rate;
        }
        let max = loads.iter().cloned().fold(0.0, f64::max);
        let min = loads.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max - min < 0.35, "imbalance {max}-{min}");
    }

    #[test]
    fn a_max_equals_per_gpu_count() {
        let ads = adapters(20);
        let p = place(&ads, 4, &DloraParams::default()).unwrap();
        for g in 0..4 {
            assert_eq!(p.a_max[g], p.adapters_on(g).len());
        }
    }

    #[test]
    fn time_limit_fires_when_budget_exhausted() {
        let ads = adapters(3000);
        let err = place(&ads, 4, &DloraParams { time_limit_s: 0.0, tol: 0.0 }).unwrap_err();
        assert_eq!(err, PlacementError::TimeLimit);
    }
}
