//! The caching greedy algorithm — faithful implementation of the paper's
//! Algorithm 1 (main loop) and Algorithm 2 (TestAllocation).
//!
//! First-Fit-Decreasing flavour: adapters are priority-sorted (size
//! descending, zigzag by arrival rate inside size groups), provisionally
//! packed onto the current GPU, and validated at the testing points via a
//! pluggable [`PerfEstimator`] (throughput probe over the current and next
//! `A_max` candidates, then a feasibility veto).  Packing onto the fewest
//! GPUs is this algorithm's built-in goal — it *is* the
//! [`crate::placement::MinGpus`] objective's planner.
//!
//! TestAllocation probes the same group at adjacent testing points (and
//! re-probes the winner), so an expensive estimator behind the seam —
//! the DT-in-the-loop [`crate::placement::TwinEstimator`] — should be
//! wrapped in a [`crate::placement::CachedEstimator`]: results are
//! bit-identical, duplicate probes are memo hits.

use super::estimator::{PerfEstimator, ProbeQuery};
use super::{MAX_TESTING_POINT, Placement, PlacementError, PlacementResult, TESTING_POINTS};
use crate::workload::AdapterSpec;
use std::collections::VecDeque;

/// PrioritySorting (Alg. 1 line 2): sort by size (largest first), then
/// zigzag by rate within each size group (high, low, next-high, ...),
/// preserving the size-based ordering.
pub fn priority_sorting(adapters: &[AdapterSpec]) -> Vec<AdapterSpec> {
    let mut by_size: std::collections::BTreeMap<usize, Vec<AdapterSpec>> = Default::default();
    for a in adapters {
        by_size.entry(a.rank).or_default().push(a.clone());
    }
    let mut out = Vec::with_capacity(adapters.len());
    for (_, mut group) in by_size.into_iter().rev() {
        group.sort_by(|a, b| b.rate.total_cmp(&a.rate));
        // Zigzag: alternate highest / lowest remaining.
        let mut dq: VecDeque<AdapterSpec> = group.into();
        let mut take_front = true;
        while let Some(a) = if take_front { dq.pop_front() } else { dq.pop_back() } {
            out.push(a);
            take_front = !take_front;
        }
    }
    out
}

/// Per-GPU packing state.  `pub(super)` so the typed-fleet planner
/// ([`super::fleet`]) shares the exact same commit/rollback bookkeeping —
/// single-type fleet parity depends on it.
#[derive(Debug, Clone, Default)]
pub(super) struct GpuState {
    pub(super) committed: Vec<AdapterSpec>,
    pub(super) provisional: Vec<AdapterSpec>,
    pub(super) a_max: usize,
}

impl GpuState {
    pub(super) fn count(&self) -> usize {
        self.committed.len() + self.provisional.len()
    }

    pub(super) fn all(&self) -> Vec<AdapterSpec> {
        let mut v = self.committed.clone();
        v.extend(self.provisional.iter().cloned());
        v
    }
}

/// TestAllocation (Algorithm 2): probe the current and the next `A_max`
/// candidate with the estimator's throughput prediction, keep the better,
/// veto on predicted infeasibility.  Returns `(ok, chosen_a_max)`.
/// Shared with [`super::fleet`] so both planners issue bit-identical
/// probe sequences.
pub(super) fn test_allocation(g: &GpuState, est: &dyn PerfEstimator) -> (bool, usize) {
    let all = g.all();
    let p = if g.a_max == 0 { TESTING_POINTS[0] } else { g.a_max };
    let p_next = next_gpu_config(p);
    // Both candidate points go down as one batch — a parallel-capable
    // estimator (CachedEstimator) probes them concurrently; the reduction
    // below stays in candidate order, so the choice is bit-identical to
    // the serial two-call sequence.
    let mut queries = vec![ProbeQuery { adapters: &all, a_max: p }];
    if let Some(pn) = p_next {
        queries.push(ProbeQuery { adapters: &all, a_max: pn });
    }
    let probed = est.estimate_batch(&queries);
    let p_best = match p_next {
        Some(pn) => {
            if probed[0].throughput_tok_s > probed[1].throughput_tok_s {
                p
            } else {
                pn
            }
        }
        None => p,
    };
    (est.estimate(&all, p_best).feasible(), p_best)
}

/// NextGPUConfig: the next candidate in the testing-point array.
fn next_gpu_config(current: usize) -> Option<usize> {
    TESTING_POINTS.iter().copied().find(|&p| p > current)
}

/// Algorithm 1.  Returns the placement or `Err(Starvation)` when no
/// starvation-free allocation exists within `gpus`.
///
/// Generic over the [`PerfEstimator`] seam; `&MlModels` coerces, so the
/// deployed ML path reads `place(&adapters, gpus, &models)` unchanged.
pub fn place(adapters: &[AdapterSpec], gpus: usize, est: &dyn PerfEstimator) -> PlacementResult {
    let sorted = priority_sorting(adapters);
    let mut a_q: VecDeque<AdapterSpec> = sorted.into();
    let mut g_q: VecDeque<usize> = (0..gpus).collect();
    let mut states: Vec<GpuState> = vec![GpuState::default(); gpus];
    let testing: std::collections::BTreeSet<usize> = TESTING_POINTS.iter().copied().collect();

    while let Some(a) = a_q.pop_front() {
        let Some(g) = g_q.pop_front() else {
            return Err(PlacementError::Starvation);
        };
        // detlint: allow(panic-path) — `states` sized to the fleet/group count at construction; ordinals in range
        states[g].provisional.push(a); // ProvisionalInclude
        let at_testing_point = testing.contains(&states[g].count())
            // detlint: allow(panic-path) — `states` sized to the fleet/group count at construction; ordinals in range
            || states[g].count() >= MAX_TESTING_POINT;
        if at_testing_point {
            // detlint: allow(panic-path) — `states` sized to the fleet/group count at construction; ordinals in range
            let (ok, p_new) = test_allocation(&states[g], est);
            if ok {
                // CommitAllocation
                // detlint: allow(panic-path) — `states` sized to the fleet/group count at construction; ordinals in range
                let prov = std::mem::take(&mut states[g].provisional);
                states[g].committed.extend(prov);
                // detlint: allow(panic-path) — `states` sized to the fleet/group count at construction; ordinals in range
                states[g].a_max = p_new;
                g_q.push_front(g);
            } else {
                // RollbackAllocation + Merge: provisional adapters return
                // to the head of the queue (they keep priority) and the
                // GPU is retired with what it already committed.
                // detlint: allow(panic-path) — `states` sized to the fleet/group count at construction; ordinals in range
                let un_alloc = std::mem::take(&mut states[g].provisional);
                for a in un_alloc.into_iter().rev() {
                    a_q.push_front(a);
                }
                // If the GPU has no committed adapters it cannot make
                // progress on this workload at all: fail fast (otherwise
                // the same head adapter would starve every GPU).
                // detlint: allow(panic-path) — `states` sized to the fleet/group count at construction; ordinals in range
                if states[g].committed.is_empty() && a_q.len() >= gpus {
                    // GPU unusable for the head adapter; continue with the
                    // remaining GPUs.
                }
            }
        } else {
            g_q.push_front(g);
        }
    }

    // Validate any leftover provisional allocations (Alg. 1 lines 24-28).
    for g in 0..gpus {
        // detlint: allow(panic-path) — `states` sized to the fleet/group count at construction; ordinals in range
        if !states[g].provisional.is_empty() {
            let (ok, p_new) = test_allocation(&states[g], est);
            if !ok {
                return Err(PlacementError::Starvation);
            }
            // detlint: allow(panic-path) — `states` sized to the fleet/group count at construction; ordinals in range
            let prov = std::mem::take(&mut states[g].provisional);
            states[g].committed.extend(prov);
            // detlint: allow(panic-path) — `states` sized to the fleet/group count at construction; ordinals in range
            states[g].a_max = p_new;
        } else if !states[g].committed.is_empty() && states[g].a_max == 0 {
            // detlint: allow(panic-path) — `states` sized to the fleet/group count at construction; ordinals in range
            let (ok, p_new) = test_allocation(&states[g], est);
            if !ok {
                return Err(PlacementError::Starvation);
            }
            // detlint: allow(panic-path) — `states` sized to the fleet/group count at construction; ordinals in range
            states[g].a_max = p_new;
        }
    }

    let mut placement = Placement { assignment: Default::default(), a_max: vec![0; gpus] };
    for (g, st) in states.iter().enumerate() {
        for a in &st.committed {
            placement.assignment.insert(a.id, g);
        }
        // detlint: allow(panic-path) — `a_max` sized to the fleet/group count at construction; ordinals in range
        placement.a_max[g] = st.a_max;
    }
    if placement.assignment.len() != adapters.len() {
        return Err(PlacementError::Starvation);
    }
    Ok(placement)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::MlModels;

    /// Shared analytic stand-in models (see `placement::test_models`):
    /// capacity 1000 tok/s minus an A_max tax; starvation when demand
    /// (sum_rate × 96 tok) exceeds capacity.
    fn fake_models() -> MlModels {
        crate::placement::test_models::analytic_models(1)
    }

    fn adapters(n: usize, rate: f64) -> Vec<AdapterSpec> {
        (0..n).map(|id| AdapterSpec { id, rank: 8, rate }).collect()
    }

    #[test]
    fn priority_sorting_size_then_zigzag() {
        let ads = vec![
            AdapterSpec { id: 0, rank: 8, rate: 0.1 },
            AdapterSpec { id: 1, rank: 32, rate: 0.5 },
            AdapterSpec { id: 2, rank: 32, rate: 0.1 },
            AdapterSpec { id: 3, rank: 32, rate: 0.3 },
            AdapterSpec { id: 4, rank: 8, rate: 0.9 },
        ];
        let s = priority_sorting(&ads);
        // Size 32 group first, zigzag by rate: 0.5, 0.1, 0.3.
        assert_eq!(s[0].id, 1);
        assert_eq!(s[1].id, 2);
        assert_eq!(s[2].id, 3);
        // Then size 8: zigzag 0.9, 0.1.
        assert_eq!(s[3].id, 4);
        assert_eq!(s[4].id, 0);
    }

    #[test]
    fn small_workload_packs_one_gpu() {
        let models = fake_models();
        let p = place(&adapters(16, 0.1), 4, &models).unwrap();
        assert_eq!(p.gpus_used(), 1);
        assert_eq!(p.assignment.len(), 16);
    }

    #[test]
    fn larger_workload_spills_to_more_gpus() {
        let models = fake_models();
        // 64 adapters × 0.3 req/s × 96 tok = 1843 tok/s demand > 1 GPU.
        let p = place(&adapters(64, 0.3), 4, &models).unwrap();
        assert!(p.gpus_used() >= 2, "used {}", p.gpus_used());
        assert_eq!(p.assignment.len(), 64);
    }

    #[test]
    fn impossible_workload_errors_starvation() {
        let models = fake_models();
        // 384 adapters × 1.0 req/s: demand far beyond 4 GPUs.
        let err = place(&adapters(384, 1.0), 4, &models).unwrap_err();
        assert_eq!(err, PlacementError::Starvation);
    }

    #[test]
    fn a_max_is_configured_for_used_gpus() {
        let models = fake_models();
        let p = place(&adapters(32, 0.1), 4, &models).unwrap();
        for g in 0..4 {
            if !p.adapters_on(g).is_empty() {
                assert!(p.a_max[g] > 0);
                assert!(TESTING_POINTS.contains(&p.a_max[g]));
            }
        }
    }
}
