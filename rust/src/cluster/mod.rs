//! Multi-GPU cluster runner: executes a placement decision across N
//! virtual GPUs and aggregates serving metrics.
//!
//! Deployment model (paper §8.1): one engine instance per GPU, requests
//! routed statically by the placement's adapter→GPU assignment (the vLLM-
//! router pattern).  Because routing is static, per-GPU serving is
//! independent and the cluster run is the composition of per-GPU runs over
//! the workload subsets.

use crate::config::EngineConfig;
use crate::dt::{Calibration, LengthVariant};
use crate::engine::metrics::Report;
use crate::engine::Engine;
use crate::placement::Placement;
use crate::runtime::ModelRuntime;
use crate::workload::WorkloadSpec;
use anyhow::Result;

/// Aggregated result of serving one workload under one placement.
#[derive(Debug, Clone)]
pub struct ClusterReport {
    pub per_gpu: Vec<Option<Report>>,
    /// Any GPU hit the static-reservation memory error.
    pub memory_error: bool,
    /// Any GPU starved (paper: allocations are validated per GPU).
    pub starved: bool,
    pub total_throughput_tok_s: f64,
    /// Request-weighted mean ITL across GPUs (s).
    pub itl_mean_s: f64,
    pub ttft_mean_s: f64,
    pub gpus_used: usize,
    /// Total wall-clock of the validation runs.
    pub wall_s: f64,
}

impl ClusterReport {
    pub fn feasible(&self) -> bool {
        !self.memory_error && !self.starved
    }

    fn aggregate(per_gpu: Vec<Option<Report>>, wall_s: f64, gpus_used: usize) -> ClusterReport {
        let memory_error = per_gpu.iter().any(|r| r.is_none());
        let reports: Vec<&Report> = per_gpu.iter().flatten().collect();
        let starved = reports.iter().any(|r| r.starved);
        let total = reports.iter().map(|r| r.throughput_tok_s).sum();
        let weights: Vec<f64> = reports.iter().map(|r| r.completed.max(1) as f64).collect();
        let wsum: f64 = weights.iter().sum();
        let itl = reports
            .iter()
            .zip(&weights)
            .map(|(r, w)| r.itl_mean_s * w)
            .sum::<f64>()
            / wsum.max(1.0);
        let ttft = reports
            .iter()
            .zip(&weights)
            .map(|(r, w)| r.ttft_mean_s * w)
            .sum::<f64>()
            / wsum.max(1.0);
        ClusterReport {
            per_gpu,
            memory_error,
            starved,
            total_throughput_tok_s: total,
            itl_mean_s: itl,
            ttft_mean_s: ttft,
            gpus_used,
            wall_s,
        }
    }
}

/// Per-GPU engine config for a placement (paper: S_max is the max adapter
/// size of the scenario; A_max comes from the placement).
fn gpu_config(base: &EngineConfig, placement: &Placement, g: usize, spec: &WorkloadSpec) -> EngineConfig {
    let s_max = spec.adapters.iter().map(|a| a.rank).max().unwrap_or(8);
    let mut cfg = base.clone();
    cfg.a_max = placement.a_max[g].max(1);
    cfg.s_max_rank = s_max;
    cfg.seed = base.seed ^ (g as u64 + 1);
    cfg
}

/// Validate a placement on the real engine (the paper's methodology: "the
/// pipeline output is validated by executing the real LLM-adapter serving
/// system").
pub fn run_on_engine(
    rt: &mut ModelRuntime,
    base: &EngineConfig,
    placement: &Placement,
    spec: &WorkloadSpec,
) -> Result<ClusterReport> {
    let t0 = std::time::Instant::now();
    let gpus = placement.a_max.len();
    let mut per_gpu: Vec<Option<Report>> = Vec::with_capacity(gpus);
    for g in 0..gpus {
        let ids = placement.adapters_on(g);
        if ids.is_empty() {
            continue;
        }
        let sub = spec.subset(&ids, spec.seed ^ (g as u64) << 8);
        let cfg = gpu_config(base, placement, g, spec);
        let mut engine = Engine::new(cfg, rt);
        let res = engine.run(&sub)?;
        per_gpu.push(res.report);
    }
    let used = placement.gpus_used();
    Ok(ClusterReport::aggregate(per_gpu, t0.elapsed().as_secs_f64(), used))
}

/// Validate a placement on the Digital Twin (fast path for sweeps).
pub fn run_on_twin(
    calib: &Calibration,
    base: &EngineConfig,
    placement: &Placement,
    spec: &WorkloadSpec,
    variant: LengthVariant,
) -> ClusterReport {
    let t0 = std::time::Instant::now();
    let gpus = placement.a_max.len();
    let mut per_gpu: Vec<Option<Report>> = Vec::with_capacity(gpus);
    for g in 0..gpus {
        let ids = placement.adapters_on(g);
        if ids.is_empty() {
            continue;
        }
        let sub = spec.subset(&ids, spec.seed ^ (g as u64) << 8);
        let cfg = gpu_config(base, placement, g, spec);
        let res = crate::dt::run_twin(&cfg, calib, &sub, variant);
        per_gpu.push(res.report);
    }
    let used = placement.gpus_used();
    ClusterReport::aggregate(per_gpu, t0.elapsed().as_secs_f64(), used)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::Placement;
    use crate::workload::WorkloadSpec;

    #[test]
    fn twin_cluster_aggregates_two_gpus() {
        let adapters = WorkloadSpec::homogeneous(8, 8, 0.2);
        let spec = WorkloadSpec::fixed_len(adapters, 64, 32, 15.0, 3);
        let mut placement = Placement { assignment: Default::default(), a_max: vec![4, 4, 0, 0] };
        for a in &spec.adapters {
            placement.assignment.insert(a.id, a.id % 2);
        }
        let rep = run_on_twin(
            &Calibration::default(),
            &EngineConfig::default(),
            &placement,
            &spec,
            LengthVariant::Original,
        );
        assert_eq!(rep.gpus_used, 2);
        assert!(rep.feasible(), "starved={} mem={}", rep.starved, rep.memory_error);
        assert!(rep.total_throughput_tok_s > 0.0);
    }

    #[test]
    fn memory_error_detected_per_gpu() {
        let adapters = WorkloadSpec::homogeneous(4, 32, 0.05);
        let spec = WorkloadSpec::fixed_len(adapters, 64, 32, 10.0, 3);
        // a_max 384 at rank 32 over-reserves the default pool → OOM.
        let mut placement = Placement { assignment: Default::default(), a_max: vec![384] };
        for a in &spec.adapters {
            placement.assignment.insert(a.id, 0);
        }
        let rep = run_on_twin(
            &Calibration::default(),
            &EngineConfig::default(),
            &placement,
            &spec,
            LengthVariant::Original,
        );
        assert!(rep.memory_error);
        assert!(!rep.feasible());
    }
}
