//! Multi-GPU cluster runner: executes a placement decision across N
//! virtual GPUs and aggregates serving metrics.
//!
//! Deployment model (paper §8.1): one engine instance per GPU, requests
//! routed statically by the placement's adapter→GPU assignment (the vLLM-
//! router pattern).  Because routing is static, per-GPU serving is
//! independent *by construction*, so validation fans the per-GPU runs out
//! over [`parallel_map`]: each GPU gets its own backend instance (engine
//! path) or its own twin simulation, with the same deterministic per-GPU
//! seeds and the same `per_gpu` report ordering as a serial sweep.
//! Engine-path backends are checked out of a model-keyed
//! [`BackendPool`], so repeated validations (and the epoch runner's
//! per-epoch serving) reuse loaded model state instead of constructing
//! one backend per GPU per call.
//!
//! [`epochs`] lifts these one-shot runners into a rolling-horizon control
//! loop that replans placements as the workload drifts (DESIGN.md §7);
//! [`events`] replaces that loop's lockstep serving with an event-driven
//! continuous-batching core in which epoch boundaries are replan events
//! and in-flight requests persist across them (DESIGN.md §12).

pub mod epochs;
pub mod events;

pub use events::Core;

use crate::config::EngineConfig;
use crate::dt::{Calibration, LengthVariant};
use crate::engine::metrics::Report;
use crate::engine::Engine;
use crate::placement::Placement;
use crate::runtime::BackendPool;
use crate::util::threadpool::{default_workers, parallel_map};
use crate::workload::WorkloadSpec;
use anyhow::{anyhow, Result};

/// Options for the one-shot cluster runners [`serve_on_engine`] and
/// [`serve_on_twin`]: worker-thread count, engine backend pool, and an
/// optional workload-seed override.
///
/// `Default` is [`default_workers`] threads, no pool (the engine path
/// requires one via [`RunOptions::pool`]), and the workload's own seed.
/// Bare builder setters follow the house convention (see
/// `TwinEstimator::horizon`).
///
/// ```
/// use adapter_serving::cluster::RunOptions;
/// let opts = RunOptions::new().workers(1).seed(42);
/// assert_eq!(opts.workers, 1);
/// assert_eq!(opts.seed, Some(42));
/// assert!(opts.pool.is_none());
/// ```
#[derive(Debug, Clone, Copy)]
pub struct RunOptions<'a> {
    /// Worker threads for the per-GPU fan-out.  `1` recovers the serial
    /// path; twin results are identical for any count, engine latencies
    /// are measured wall time and may time-share cores when parallel.
    pub workers: usize,
    /// Backend pool for the engine path ([`serve_on_engine`] fails
    /// without one; the twin path ignores it).
    pub pool: Option<&'a BackendPool>,
    /// Override for the workload seed used to derive per-GPU subset
    /// seeds; `None` uses `spec.seed` (the historical behavior).
    pub seed: Option<u64>,
}

impl Default for RunOptions<'_> {
    fn default() -> Self {
        RunOptions { workers: default_workers(), pool: None, seed: None }
    }
}

impl<'a> RunOptions<'a> {
    /// Alias for [`RunOptions::default`], reading better in call chains.
    pub fn new() -> Self {
        Self::default()
    }

    /// Set the worker-thread count (clamped to at least 1).
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    /// Provide the backend pool the engine path checks GPUs out of.
    pub fn pool(mut self, pool: &'a BackendPool) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Override the workload seed for per-GPU subset derivation.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }
}

/// Aggregated result of serving one workload under one placement.
#[derive(Debug, Clone)]
pub struct ClusterReport {
    /// Per-GPU serving reports in GPU order (`None` = memory error).
    pub per_gpu: Vec<Option<Report>>,
    /// Any GPU hit the static-reservation memory error.
    pub memory_error: bool,
    /// Any GPU starved (paper: allocations are validated per GPU).
    pub starved: bool,
    /// Sum of per-GPU throughputs (tok/s).
    pub total_throughput_tok_s: f64,
    /// Request-weighted mean ITL across GPUs (s).
    pub itl_mean_s: f64,
    /// Request-weighted mean TTFT across GPUs (s).
    pub ttft_mean_s: f64,
    /// Sum of per-GPU goodputs: completed requests that met both
    /// [`crate::engine::metrics::SloSpec`] deadlines, per second.
    pub goodput_req_s: f64,
    /// Request-weighted SLO attainment across GPUs (fraction of
    /// completed requests that met the deadlines).
    pub slo_attainment: f64,
    /// KV-cache bytes shipped between GPUs by migrations (event-driven
    /// core only; lockstep serving re-prefills instead, reporting 0).
    pub kv_handoff_bytes: u64,
    /// GPUs the placement actually provisioned.
    pub gpus_used: usize,
    /// Total wall-clock of the validation runs.
    pub wall_s: f64,
}

impl ClusterReport {
    /// Neither starved nor out of memory — the paper's feasibility test.
    pub fn feasible(&self) -> bool {
        !self.memory_error && !self.starved
    }

    /// Requests completed across all GPUs — the weight of this run's
    /// latency means in horizon-level aggregates.
    pub fn completed_requests(&self) -> usize {
        self.per_gpu.iter().flatten().map(|r| r.completed).sum()
    }

    fn aggregate(per_gpu: Vec<Option<Report>>, wall_s: f64, gpus_used: usize) -> ClusterReport {
        let memory_error = per_gpu.iter().any(|r| r.is_none());
        let reports: Vec<&Report> = per_gpu.iter().flatten().collect();
        let starved = reports.iter().any(|r| r.starved);
        let total = reports.iter().map(|r| r.throughput_tok_s).sum();
        let weights: Vec<f64> = reports.iter().map(|r| r.completed.max(1) as f64).collect();
        let wsum: f64 = weights.iter().sum();
        let itl = reports
            .iter()
            .zip(&weights)
            .map(|(r, w)| r.itl_mean_s * w)
            .sum::<f64>()
            / wsum.max(1.0);
        let ttft = reports
            .iter()
            .zip(&weights)
            .map(|(r, w)| r.ttft_mean_s * w)
            .sum::<f64>()
            / wsum.max(1.0);
        let goodput = reports.iter().map(|r| r.goodput_req_s).sum();
        let attainment = reports
            .iter()
            .zip(&weights)
            .map(|(r, w)| r.slo_attainment * w)
            .sum::<f64>()
            / wsum.max(1.0);
        let handoff = reports.iter().map(|r| r.kv_handoff_bytes).sum();
        ClusterReport {
            per_gpu,
            memory_error,
            starved,
            total_throughput_tok_s: total,
            itl_mean_s: itl,
            ttft_mean_s: ttft,
            goodput_req_s: goodput,
            slo_attainment: attainment,
            kv_handoff_bytes: handoff,
            gpus_used,
            wall_s,
        }
    }
}

/// Per-GPU engine config for a placement (paper: S_max is the max adapter
/// size of the scenario; A_max comes from the placement).
fn gpu_config(
    base: &EngineConfig,
    placement: &Placement,
    g: usize,
    spec: &WorkloadSpec,
) -> EngineConfig {
    let s_max = spec.adapters.iter().map(|a| a.rank).max().unwrap_or(8);
    let mut cfg = base.clone();
    // detlint: allow(panic-path) — `a_max` sized to the fleet/group count at construction; ordinals in range
    cfg.a_max = placement.a_max[g].max(1);
    cfg.s_max_rank = s_max;
    cfg.seed = base.seed ^ (g as u64 + 1);
    cfg
}

/// The non-empty GPUs of a placement, in GPU order (the report order).
fn gpu_jobs(placement: &Placement) -> Vec<(usize, Vec<usize>)> {
    (0..placement.a_max.len())
        .map(|g| (g, placement.adapters_on(g)))
        .filter(|(_, ids)| !ids.is_empty())
        .collect()
}

/// Validate a placement on the real engine (the paper's methodology: "the
/// pipeline output is validated by executing the real LLM-adapter serving
/// system").  Per-GPU engines are independent, so the runs execute in
/// parallel; each worker checks a backend for `base.model` out of the
/// pool in `opts` and returns it when its GPU finishes, so one pool
/// serves any number of validations (and epoch horizons) with at most
/// max-concurrent-GPUs constructions.  Errors when `opts` carries no
/// pool.
///
/// ```no_run
/// use adapter_serving::cluster::{serve_on_engine, RunOptions};
/// use adapter_serving::config::EngineConfig;
/// use adapter_serving::placement::Placement;
/// use adapter_serving::runtime::{BackendPool, Manifest};
/// use adapter_serving::workload::WorkloadSpec;
/// # fn main() -> anyhow::Result<()> {
/// let spec = WorkloadSpec::sharegpt_like(WorkloadSpec::homogeneous(4, 8, 0.2), 5.0, 3);
/// let mut p = Placement { assignment: Default::default(), a_max: vec![4] };
/// for a in &spec.adapters {
///     p.assignment.insert(a.id, 0);
/// }
/// let pool = BackendPool::new(Manifest::default_dir());
/// let opts = RunOptions::new().pool(&pool);
/// let rep = serve_on_engine(&EngineConfig::default(), &p, &spec, opts)?;
/// println!("served {:.0} tok/s on {} GPU(s)", rep.total_throughput_tok_s, rep.gpus_used);
/// # Ok(())
/// # }
/// ```
pub fn serve_on_engine(
    base: &EngineConfig,
    placement: &Placement,
    spec: &WorkloadSpec,
    opts: RunOptions<'_>,
) -> Result<ClusterReport> {
    let pool = opts.pool.ok_or_else(|| anyhow!("serve_on_engine needs RunOptions::pool(&pool)"))?;
    // detlint: allow(wall-clock) — aggregate `wall_s` reporting only; simulated time is virtual
    #[allow(clippy::disallowed_methods)]
    let t0 = std::time::Instant::now();
    let jobs = gpu_jobs(placement);
    let workers = opts.workers.min(jobs.len().max(1));
    let seed_base = opts.seed.unwrap_or(spec.seed);
    let results: Vec<Result<Option<Report>>> = parallel_map(jobs, workers, |(g, ids)| {
        let mut rt = pool.checkout(&base.model)?;
        let sub = spec.subset(&ids, seed_base ^ (g as u64) << 8);
        let cfg = gpu_config(base, placement, g, spec);
        let mut engine = Engine::new(cfg, &mut *rt);
        let res = engine.run(&sub)?;
        Ok(res.report)
    });
    let mut per_gpu: Vec<Option<Report>> = Vec::with_capacity(results.len());
    for r in results {
        per_gpu.push(r?);
    }
    let used = placement.gpus_used();
    Ok(ClusterReport::aggregate(per_gpu, t0.elapsed().as_secs_f64(), used))
}

/// Validate a placement on the Digital Twin (fast path for sweeps).
/// Results are identical for any [`RunOptions::workers`] count — twin
/// runs are deterministic and [`parallel_map`] preserves order and
/// per-GPU seeds.
///
/// ```
/// use adapter_serving::cluster::{serve_on_twin, RunOptions};
/// use adapter_serving::config::EngineConfig;
/// use adapter_serving::dt::{Calibration, LengthVariant};
/// use adapter_serving::placement::Placement;
/// use adapter_serving::workload::WorkloadSpec;
/// let spec = WorkloadSpec::fixed_len(WorkloadSpec::homogeneous(4, 8, 0.2), 64, 16, 5.0, 3);
/// let mut p = Placement { assignment: Default::default(), a_max: vec![2, 2] };
/// for a in &spec.adapters {
///     p.assignment.insert(a.id, a.id % 2);
/// }
/// let rep = serve_on_twin(&Calibration::default(), &EngineConfig::default(), &p, &spec,
///                         LengthVariant::Original, RunOptions::new());
/// assert_eq!(rep.gpus_used, 2);
/// assert!(rep.total_throughput_tok_s > 0.0);
/// ```
pub fn serve_on_twin(
    calib: &Calibration,
    base: &EngineConfig,
    placement: &Placement,
    spec: &WorkloadSpec,
    variant: LengthVariant,
    opts: RunOptions<'_>,
) -> ClusterReport {
    // detlint: allow(wall-clock) — aggregate `wall_s` reporting only; simulated time is virtual
    #[allow(clippy::disallowed_methods)]
    let t0 = std::time::Instant::now();
    let jobs = gpu_jobs(placement);
    let workers = opts.workers.min(jobs.len().max(1));
    let seed_base = opts.seed.unwrap_or(spec.seed);
    let per_gpu: Vec<Option<Report>> = parallel_map(jobs, workers, |(g, ids)| {
        let sub = spec.subset(&ids, seed_base ^ (g as u64) << 8);
        let cfg = gpu_config(base, placement, g, spec);
        crate::dt::run_twin(&cfg, calib, &sub, variant).report
    });
    let used = placement.gpus_used();
    ClusterReport::aggregate(per_gpu, t0.elapsed().as_secs_f64(), used)
}

/// [`serve_on_twin`] over a typed fleet: each GPU is simulated under its
/// *own* calibration and engine config (`calibs[g]`/`configs[g]`, both
/// `placement.a_max.len()` entries — DESIGN.md §11).  With every slot
/// sharing one calibration and config this is exactly [`serve_on_twin`];
/// per-GPU seeds, subset derivation and report order are identical.
pub fn serve_on_twin_fleet(
    calibs: &[Calibration],
    configs: &[EngineConfig],
    placement: &Placement,
    spec: &WorkloadSpec,
    variant: LengthVariant,
    opts: RunOptions<'_>,
) -> ClusterReport {
    assert_eq!(calibs.len(), placement.a_max.len(), "one calibration per GPU slot");
    assert_eq!(configs.len(), placement.a_max.len(), "one engine config per GPU slot");
    // detlint: allow(wall-clock) — aggregate `wall_s` reporting only; simulated time is virtual
    #[allow(clippy::disallowed_methods)]
    let t0 = std::time::Instant::now();
    let jobs = gpu_jobs(placement);
    let workers = opts.workers.min(jobs.len().max(1));
    let seed_base = opts.seed.unwrap_or(spec.seed);
    let per_gpu: Vec<Option<Report>> = parallel_map(jobs, workers, |(g, ids)| {
        let sub = spec.subset(&ids, seed_base ^ (g as u64) << 8);
        // detlint: allow(panic-path) — `calibs`/`configs` sized to the fleet/group count at construction; ordinals in range
        let cfg = gpu_config(&configs[g], placement, g, spec);
        crate::dt::run_twin(&cfg, &calibs[g], &sub, variant).report
    });
    let used = placement.gpus_used();
    ClusterReport::aggregate(per_gpu, t0.elapsed().as_secs_f64(), used)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn twin_cluster_aggregates_two_gpus() {
        let adapters = WorkloadSpec::homogeneous(8, 8, 0.2);
        let spec = WorkloadSpec::fixed_len(adapters, 64, 32, 15.0, 3);
        let mut placement = Placement { assignment: Default::default(), a_max: vec![4, 4, 0, 0] };
        for a in &spec.adapters {
            placement.assignment.insert(a.id, a.id % 2);
        }
        let rep = serve_on_twin(
            &Calibration::default(),
            &EngineConfig::default(),
            &placement,
            &spec,
            LengthVariant::Original,
            RunOptions::new(),
        );
        assert_eq!(rep.gpus_used, 2);
        assert!(rep.feasible(), "starved={} mem={}", rep.starved, rep.memory_error);
        assert!(rep.total_throughput_tok_s > 0.0);
    }

    /// Satellite gate: the parallel twin sweep must be *byte-identical*
    /// to the serial path — same per-GPU reports, same aggregates (the
    /// only permitted difference is `wall_s`, which measures real time).
    #[test]
    fn parallel_twin_matches_serial_byte_identically() {
        let adapters = WorkloadSpec::heterogeneous(32, &[8, 16], &[0.2, 0.1], 5);
        let spec = WorkloadSpec::sharegpt_like(adapters.clone(), 10.0, 6);
        let mut placement =
            Placement { assignment: Default::default(), a_max: vec![8, 8, 8, 8] };
        for a in &adapters {
            placement.assignment.insert(a.id, a.id % 4);
        }
        let calib = Calibration::default();
        let base = EngineConfig::default();
        let o1 = RunOptions::new().workers(1);
        let o4 = RunOptions::new().workers(4);
        let serial = serve_on_twin(&calib, &base, &placement, &spec, LengthVariant::Original, o1);
        let parallel =
            serve_on_twin(&calib, &base, &placement, &spec, LengthVariant::Original, o4);
        assert_eq!(serial.gpus_used, parallel.gpus_used);
        assert_eq!(serial.memory_error, parallel.memory_error);
        assert_eq!(serial.starved, parallel.starved);
        assert_eq!(
            serial.total_throughput_tok_s.to_bits(),
            parallel.total_throughput_tok_s.to_bits()
        );
        assert_eq!(serial.itl_mean_s.to_bits(), parallel.itl_mean_s.to_bits());
        assert_eq!(serial.ttft_mean_s.to_bits(), parallel.ttft_mean_s.to_bits());
        assert_eq!(serial.per_gpu.len(), parallel.per_gpu.len());
        for (s, p) in serial.per_gpu.iter().zip(&parallel.per_gpu) {
            match (s, p) {
                (Some(s), Some(p)) => {
                    assert_eq!(s.throughput_tok_s.to_bits(), p.throughput_tok_s.to_bits());
                    assert_eq!(s.itl_mean_s.to_bits(), p.itl_mean_s.to_bits());
                    assert_eq!(s.ttft_mean_s.to_bits(), p.ttft_mean_s.to_bits());
                    assert_eq!(s.completed, p.completed);
                    assert_eq!(s.input_tokens, p.input_tokens);
                    assert_eq!(s.output_tokens, p.output_tokens);
                    assert_eq!(s.preemptions, p.preemptions);
                    assert_eq!(s.swap_ins, p.swap_ins);
                    assert_eq!(s.starved, p.starved);
                }
                (None, None) => {}
                _ => panic!("per-GPU feasibility diverged between serial and parallel"),
            }
        }
    }

    #[test]
    fn engine_cluster_runs_from_the_backend_pool() {
        let adapters = WorkloadSpec::homogeneous(6, 8, 0.5);
        let spec = WorkloadSpec::fixed_len(adapters.clone(), 24, 6, 2.0, 3);
        let mut placement =
            Placement { assignment: Default::default(), a_max: vec![3, 3] };
        for a in &adapters {
            placement.assignment.insert(a.id, a.id % 2);
        }
        let base = EngineConfig { a_max: 3, s_max_rank: 8, ..Default::default() };
        let pool = BackendPool::new(std::path::Path::new("/nonexistent"));
        let opts = RunOptions::new().pool(&pool);
        let rep = serve_on_engine(&base, &placement, &spec, opts).expect("cluster run");
        assert_eq!(rep.per_gpu.len(), 2);
        assert_eq!(rep.gpus_used, 2);
        assert!(!rep.memory_error);
        assert_eq!(pool.created(), 2, "one backend per concurrent GPU");
        // A second validation through the same pool constructs nothing.
        let rep2 = serve_on_engine(&base, &placement, &spec, opts).expect("cluster rerun");
        assert_eq!(rep2.gpus_used, 2);
        assert_eq!(pool.created(), 2, "second validation reuses pooled backends");
        assert!(pool.reused() >= 2);
    }

    #[test]
    fn memory_error_detected_per_gpu() {
        let adapters = WorkloadSpec::homogeneous(4, 32, 0.05);
        let spec = WorkloadSpec::fixed_len(adapters, 64, 32, 10.0, 3);
        // a_max 384 at rank 32 over-reserves the default pool → OOM.
        let mut placement = Placement { assignment: Default::default(), a_max: vec![384] };
        for a in &spec.adapters {
            placement.assignment.insert(a.id, 0);
        }
        let rep = serve_on_twin(
            &Calibration::default(),
            &EngineConfig::default(),
            &placement,
            &spec,
            LengthVariant::Original,
            RunOptions::new(),
        );
        assert!(rep.memory_error);
        assert!(!rep.feasible());
    }

    /// The `RunOptions::seed` override must land exactly where `spec.seed`
    /// used to: serving `spec` with `.seed(s)` is bit-identical to serving
    /// a copy of `spec` whose own seed is `s`.
    #[test]
    fn seed_override_matches_a_spec_with_that_seed() {
        let adapters = WorkloadSpec::homogeneous(8, 8, 0.2);
        let spec = WorkloadSpec::fixed_len(adapters, 64, 32, 10.0, 3);
        let mut reseeded = spec.clone();
        reseeded.seed = 99;
        let mut placement = Placement { assignment: Default::default(), a_max: vec![4, 4] };
        for a in &spec.adapters {
            placement.assignment.insert(a.id, a.id % 2);
        }
        let calib = Calibration::default();
        let base = EngineConfig::default();
        let with_override = RunOptions::new().workers(1).seed(99);
        let a =
            serve_on_twin(&calib, &base, &placement, &spec, LengthVariant::Original, with_override);
        let b = serve_on_twin(
            &calib,
            &base,
            &placement,
            &reseeded,
            LengthVariant::Original,
            RunOptions::new().workers(1),
        );
        assert_eq!(a.total_throughput_tok_s.to_bits(), b.total_throughput_tok_s.to_bits());
        assert_eq!(a.itl_mean_s.to_bits(), b.itl_mean_s.to_bits());
        assert_eq!(a.completed_requests(), b.completed_requests());
    }

    /// A uniform fleet (every slot the same calibration and config) must
    /// reproduce [`serve_on_twin`] bit-for-bit, and a faster class's
    /// calibration must actually change what its GPU reports.
    #[test]
    fn twin_fleet_degenerates_to_serve_on_twin_and_scales_per_slot() {
        let adapters = WorkloadSpec::homogeneous(8, 8, 0.2);
        let spec = WorkloadSpec::fixed_len(adapters, 64, 32, 10.0, 3);
        let mut placement = Placement { assignment: Default::default(), a_max: vec![4, 4] };
        for a in &spec.adapters {
            placement.assignment.insert(a.id, a.id % 2);
        }
        let calib = Calibration::default();
        let base = EngineConfig::default();
        let o1 = RunOptions::new().workers(1);
        let uniform = serve_on_twin_fleet(
            &[calib.clone(), calib.clone()],
            &[base.clone(), base.clone()],
            &placement,
            &spec,
            LengthVariant::Original,
            o1,
        );
        let plain = serve_on_twin(&calib, &base, &placement, &spec, LengthVariant::Original, o1);
        assert_eq!(
            uniform.total_throughput_tok_s.to_bits(),
            plain.total_throughput_tok_s.to_bits()
        );
        assert_eq!(uniform.itl_mean_s.to_bits(), plain.itl_mean_s.to_bits());
        assert_eq!(uniform.gpus_used, plain.gpus_used);

        // GPU 1 twice as fast: its ITL drops, GPU 0's report is untouched.
        let fast = calib.scaled(2.0);
        let mixed = serve_on_twin_fleet(
            &[calib.clone(), fast],
            &[base.clone(), base.clone()],
            &placement,
            &spec,
            LengthVariant::Original,
            o1,
        );
        let (u0, m0) = (uniform.per_gpu[0].as_ref().unwrap(), mixed.per_gpu[0].as_ref().unwrap());
        assert_eq!(u0.itl_mean_s.to_bits(), m0.itl_mean_s.to_bits());
        let (u1, m1) = (uniform.per_gpu[1].as_ref().unwrap(), mixed.per_gpu[1].as_ref().unwrap());
        assert!(m1.itl_mean_s < u1.itl_mean_s, "faster calibration must lower ITL");
    }
}
