//! Rolling-horizon epoch runner (DESIGN.md §7): drives the cluster layer
//! epoch-by-epoch over a drifting workload, re-planning placements online.
//!
//! Each epoch of a [`DriftSpec`] is planned under a [`ReplanPolicy`]
//! (plan-once static, migration-aware incremental replan, or an oracle
//! that re-runs Alg. 1 from scratch with free migrations) and served by
//! one of two cores behind [`serve_horizon`]:
//!
//! - [`Core::Lockstep`] serves each epoch as an independent per-GPU run
//!   through the parallel cluster runners.  Queues start empty every
//!   epoch; requests in flight at a boundary are abandoned, and migrated
//!   requests re-prefill (KV is never shipped).  Backlog is *modeled*
//!   from rates: the signed per-epoch deficit
//!   `(incoming − served)·epoch_s` accumulates across the horizon,
//!   clamped at zero *after* accumulation —
//!   `backlog' = max(0, backlog + (incoming − served)·epoch_s)` — so a
//!   starved epoch leaves a visible deficit in every later record and an
//!   epoch that serves more than its own arrivals works carried backlog
//!   off.  (Clamping the per-epoch deficit before accumulating, as this
//!   runner once did, silently forced backlog monotone non-decreasing
//!   for *any* serve implementation.)  The lockstep serve paths never
//!   re-inject unserved work, so they report served ≤ arrived and the
//!   modeled backlog never actually drains.
//! - [`Core::EventDriven`] ([`super::events`], DESIGN.md §12) runs one
//!   continuous simulation of the whole horizon in which epoch
//!   boundaries are replan events: in-flight requests persist, migrated
//!   KV is shipped or recomputed by a cost model, backlog is *realized*
//!   (arrived − served tokens) and genuinely drains in quiet epochs.
//!
//! Planning state carried across epoch boundaries (shared by both cores
//! through [`PolicyDriver`]): the **previous placement** — the
//! incremental replanner's starting point and the migration baseline for
//! every policy's accounting — and the **replan ledger** of probe
//! fingerprints.  `final_backlog_tokens` is the unserved demand still
//! outstanding when the horizon ends.
//!
//! When planning fails for an epoch (predicted starvation), the runner
//! keeps serving on the stale placement — what a production control loop
//! would do — and flags the epoch infeasible if demand goes unserved.

use super::events::run_event_horizon;
use super::{serve_on_engine, serve_on_twin, ClusterReport, Core, RunOptions};
use crate::config::EngineConfig;
use crate::dt::{Calibration, LengthVariant};
use crate::engine::metrics::ReportSchema;
use crate::placement::replan::{replan_with_ledger, MigrationCost, ReplanLedger, ReplanParams};
use crate::placement::{Objective, PerfEstimator, Placement};
use crate::workload::drift::DriftSpec;
use crate::workload::{AdapterSpec, WorkloadSpec};
use anyhow::{anyhow, Result};
use std::time::Instant;

/// How each epoch's placement is derived from the previous one.  Every
/// policy plans through the estimator/objective seams passed to the
/// runner, so the same policy can minimize GPUs or latency.
#[derive(Debug, Clone, PartialEq)]
pub enum ReplanPolicy {
    /// Plan once for the union workload (every adapter that ever appears,
    /// at its peak rate) and hold that placement for the whole horizon —
    /// the static-provisioning baseline.
    Static,
    /// Migration-aware incremental replanning per epoch
    /// ([`crate::placement::replan`]).
    Replan(ReplanParams),
    /// Fresh one-shot plan per epoch (the objective's cold-start planner
    /// — Alg. 1 for `MinGpus`), ignoring the previous placement when
    /// planning (migrations are free): the per-epoch cost lower bound.
    /// The [`MigrationCost`] model is still used to *report* the
    /// migration burden this policy silently incurs, comparably to
    /// `Replan`.
    Oracle(MigrationCost),
}

/// One epoch's outcome.
#[derive(Debug, Clone)]
pub struct EpochRecord {
    /// Epoch index within the horizon.
    pub epoch: usize,
    /// Adapters active in this epoch.
    pub adapters: usize,
    /// Whether any placement (fresh or carried-over) was available.
    pub planned: bool,
    /// Whether a *fresh* plan was produced this epoch (false when serving
    /// continued on a stale placement after a planning failure).
    pub replanned: bool,
    /// GPUs provisioned by the active placement.
    pub gpus_used: usize,
    /// Adapters that changed GPU relative to the previous epoch.
    pub migrations: usize,
    /// Modeled migration latency this epoch (seconds).
    pub migration_cost_s: f64,
    /// Wall-clock spent planning this epoch (seconds).
    pub plan_wall_s: f64,
    /// Aggregate served throughput (tok/s).
    pub throughput_tok_s: f64,
    /// Aggregate incoming token rate, including demand for adapters the
    /// active placement does not cover (tok/s).  Modeled from rates under
    /// the lockstep core, realized arrivals under the event core.
    pub incoming_tok_s: f64,
    /// Request-weighted mean inter-token latency of the epoch's serving
    /// run (seconds; 0 when nothing was served).
    pub itl_mean_s: f64,
    /// Requests completed across the epoch's GPUs — the weight of
    /// `itl_mean_s` in the horizon aggregate.
    pub served_requests: usize,
    /// Any GPU starved, or some active adapter had no GPU at all.
    pub starved: bool,
    /// Any GPU hit the static-reservation memory error.
    pub memory_error: bool,
    /// Cumulative unserved demand carried *into* this epoch (tokens).
    pub carried_in_backlog_tokens: f64,
    /// Cumulative unserved demand at the end of this epoch (tokens).
    pub backlog_tokens: f64,
    /// Sticky groups that paid estimator probes in the replan repair pass
    /// (`Replan` policy only; 0 for `Static`/`Oracle` and cold starts).
    pub groups_reprobed: usize,
    /// Sticky groups answered from the cross-epoch [`ReplanLedger`]
    /// fingerprints with zero probes (`Replan` policy only).
    pub groups_reused: usize,
    /// Good completed requests (met both SLO deadlines) per second — the
    /// EconoServe goodput of this epoch's serving run.
    pub goodput_req_s: f64,
    /// Fraction of completed requests that met the SLO deadlines
    /// (request-weighted across GPUs; 0 when nothing completed).
    pub slo_attainment: f64,
    /// Request-weighted mean time-to-first-token (seconds).
    pub ttft_mean_s: f64,
    /// KV-cache bytes shipped between GPUs by migrations this epoch
    /// (event-driven core only; the lockstep core re-prefills, so 0).
    pub kv_handoff_bytes: u64,
}

impl EpochRecord {
    /// An epoch is feasible when it had a placement and served its demand
    /// without starvation or memory errors.
    pub fn feasible(&self) -> bool {
        self.planned && !self.starved && !self.memory_error
    }

    /// The CSV cells between the experiment's leading label columns and
    /// the trailing status cell, in [`ReportSchema::drift_header`] order —
    /// the row-shape half of the header↔struct drift guard (the header
    /// half lives in [`ReportSchema`]).
    pub fn csv_cells(&self) -> Vec<String> {
        let mut cells = vec![
            self.epoch.to_string(),
            self.adapters.to_string(),
            self.gpus_used.to_string(),
            self.migrations.to_string(),
            format!("{:.3}", ReportSchema::ms_from_s(self.migration_cost_s)),
            format!("{:.3}", ReportSchema::ms_from_s(self.plan_wall_s)),
            format!("{:.1}", self.throughput_tok_s),
            format!("{:.1}", self.incoming_tok_s),
            format!("{:.3}", ReportSchema::ms_from_s(self.itl_mean_s)),
            format!("{:.0}", self.backlog_tokens),
            self.groups_reprobed.to_string(),
            self.groups_reused.to_string(),
        ];
        cells.extend(ReportSchema::slo_cells(
            self.goodput_req_s,
            self.slo_attainment,
            self.ttft_mean_s,
            self.kv_handoff_bytes,
        ));
        cells
    }
}

/// Horizon-level aggregate over all epochs.
#[derive(Debug, Clone)]
pub struct DriftReport {
    /// Per-epoch records, in epoch order.
    pub per_epoch: Vec<EpochRecord>,
    /// Σ provisioned GPUs over epochs — the cost metric the drift
    /// experiment compares across policies.
    pub gpu_epochs: usize,
    /// Σ migrations over epochs.
    pub total_migrations: usize,
    /// Σ modeled migration latency (seconds).
    pub total_migration_cost_s: f64,
    /// Number of infeasible epochs (see [`EpochRecord::feasible`]).
    pub infeasible_epochs: usize,
    /// Mean served throughput across epochs (tok/s).
    pub mean_throughput_tok_s: f64,
    /// Served-request-weighted mean of the per-epoch mean inter-token
    /// latencies (seconds) — the cost metric the latency objective
    /// targets over time.  Weighting by `served_requests` makes epochs
    /// that served nothing (unplanned, or planned but fully starved)
    /// carry zero weight: averaging their `0.0` ITL in — as an earlier
    /// per-epoch mean did — flattered starved-but-planned policies on a
    /// lower-is-better metric.  `0.0` when the whole horizon served
    /// nothing.
    pub mean_itl_s: f64,
    /// Unserved demand still outstanding at the end of the horizon
    /// (tokens) — burst deficits net of later spare capacity.
    pub final_backlog_tokens: f64,
    /// Σ sticky groups re-probed across epochs (the incremental
    /// re-probing cost actually paid over the horizon).
    pub total_groups_reprobed: usize,
    /// Σ sticky groups answered from ledger fingerprints across epochs
    /// (the probes incremental re-probing avoided).
    pub total_groups_reused: usize,
    /// Mean goodput across epochs (good requests per second).
    pub mean_goodput_req_s: f64,
    /// Served-request-weighted SLO attainment over the horizon (same
    /// weighting rationale as `mean_itl_s`; 0 when nothing was served).
    pub slo_attainment: f64,
    /// Σ KV-cache bytes shipped between GPUs by migrations over the
    /// horizon (event-driven core only).
    pub total_kv_handoff_bytes: u64,
}

impl DriftReport {
    /// True when every epoch was feasible.
    pub fn feasible(&self) -> bool {
        self.infeasible_epochs == 0
    }

    pub(crate) fn from_records(per_epoch: Vec<EpochRecord>) -> DriftReport {
        let n = per_epoch.len().max(1) as f64;
        let served: f64 = per_epoch.iter().map(|r| r.served_requests as f64).sum();
        let itl_sum: f64 =
            per_epoch.iter().map(|r| r.itl_mean_s * r.served_requests as f64).sum();
        let slo_sum: f64 =
            per_epoch.iter().map(|r| r.slo_attainment * r.served_requests as f64).sum();
        DriftReport {
            gpu_epochs: per_epoch.iter().map(|r| r.gpus_used).sum(),
            total_migrations: per_epoch.iter().map(|r| r.migrations).sum(),
            total_migration_cost_s: per_epoch.iter().map(|r| r.migration_cost_s).sum(),
            infeasible_epochs: per_epoch.iter().filter(|r| !r.feasible()).count(),
            mean_throughput_tok_s: per_epoch.iter().map(|r| r.throughput_tok_s).sum::<f64>() / n,
            mean_itl_s: if served > 0.0 { itl_sum / served } else { 0.0 },
            final_backlog_tokens: per_epoch.last().map(|r| r.backlog_tokens).unwrap_or(0.0),
            total_groups_reprobed: per_epoch.iter().map(|r| r.groups_reprobed).sum(),
            total_groups_reused: per_epoch.iter().map(|r| r.groups_reused).sum(),
            mean_goodput_req_s: per_epoch.iter().map(|r| r.goodput_req_s).sum::<f64>() / n,
            slo_attainment: if served > 0.0 { slo_sum / served } else { 0.0 },
            total_kv_handoff_bytes: per_epoch.iter().map(|r| r.kv_handoff_bytes).sum(),
            per_epoch,
        }
    }
}

/// Migrations of `next` relative to `prev` over the epoch's adapter set,
/// costed with the fig6 load-time model.
fn migration_diff(
    prev: Option<&Placement>,
    next: &Placement,
    adapters: &[AdapterSpec],
    cost: &MigrationCost,
) -> (usize, f64) {
    let Some(prev) = prev else {
        return (0, 0.0);
    };
    let mut migrations = 0;
    let mut total = 0.0;
    for a in adapters {
        if let (Some(&pg), Some(&ng)) = (prev.assignment.get(&a.id), next.assignment.get(&a.id)) {
            if pg != ng {
                migrations += 1;
                total += cost.load_s(a.rank);
            }
        }
    }
    (migrations, total)
}

/// One epoch's planning outcome — the placement half of an
/// [`EpochRecord`], produced by [`PolicyDriver::plan_epoch`].
pub(crate) struct PlanStep {
    /// The placement to serve on (fresh, or stale after a plan failure;
    /// `None` when no placement has ever been available).
    pub(crate) active: Option<Placement>,
    /// Whether a fresh plan was produced this epoch.
    pub(crate) replanned: bool,
    /// Wall-clock spent planning (epoch 0 carries the plan-once cost).
    pub(crate) plan_wall_s: f64,
    /// Adapters that changed GPU relative to the previous epoch.
    pub(crate) migrations: usize,
    /// Modeled migration latency (seconds).
    pub(crate) migration_cost_s: f64,
    /// Sticky groups that paid estimator probes (`Replan` only).
    pub(crate) groups_reprobed: usize,
    /// Sticky groups answered from ledger fingerprints (`Replan` only).
    pub(crate) groups_reused: usize,
}

/// Cross-epoch planning state shared by the lockstep and the
/// event-driven serving cores: the policy dispatch, the previous
/// placement (migration baseline and replan starting point), the
/// [`ReplanLedger`] of probe fingerprints, and the plan-once static
/// placement with its timing.  Both cores replan through this one state
/// machine, so policies behave identically regardless of serving core.
pub(crate) struct PolicyDriver<'a> {
    policy: &'a ReplanPolicy,
    objective: &'a dyn Objective,
    est: &'a dyn PerfEstimator,
    gpus: usize,
    cost_model: MigrationCost,
    static_placement: Option<Placement>,
    static_plan_s: f64,
    ledger: ReplanLedger,
    prev: Option<Placement>,
}

impl<'a> PolicyDriver<'a> {
    /// Set up the horizon's planning state; `Static` pays its plan-once
    /// cost here (charged to epoch 0 by [`PolicyDriver::plan_epoch`]).
    pub(crate) fn new(
        drift: &DriftSpec,
        gpus: usize,
        est: &'a dyn PerfEstimator,
        objective: &'a dyn Objective,
        policy: &'a ReplanPolicy,
    ) -> PolicyDriver<'a> {
        let cost_model = match policy {
            ReplanPolicy::Replan(p) => p.cost,
            ReplanPolicy::Oracle(c) => *c,
            ReplanPolicy::Static => MigrationCost::default(), // never charged: 0 migrations
        };
        // detlint: allow(wall-clock) — static_plan_s accounting column; excluded from bit-identity checks
        #[allow(clippy::disallowed_methods)]
        let t_static = Instant::now();
        let static_placement: Option<Placement> = match policy {
            ReplanPolicy::Static => objective.plan(&drift.union_adapters(), gpus, est).ok(),
            _ => None,
        };
        let static_plan_s = if matches!(policy, ReplanPolicy::Static) {
            t_static.elapsed().as_secs_f64()
        } else {
            0.0
        };
        PolicyDriver {
            policy,
            objective,
            est,
            gpus,
            cost_model,
            static_placement,
            static_plan_s,
            ledger: ReplanLedger::new(),
            prev: None,
        }
    }

    /// Plan one epoch under the policy.  On planning failure the previous
    /// placement is kept (stale serving); the returned step's `active`
    /// becomes the next epoch's migration baseline.
    pub(crate) fn plan_epoch(&mut self, epoch: usize, adapters: &[AdapterSpec]) -> PlanStep {
        // detlint: allow(wall-clock) — plan_wall_s accounting column; excluded from bit-identity checks
        #[allow(clippy::disallowed_methods)]
        let t_plan = Instant::now();
        let (fresh, migrations, migration_cost_s, groups_reprobed, groups_reused) = match self
            .policy
        {
            ReplanPolicy::Static => (self.static_placement.clone(), 0, 0.0, 0, 0),
            ReplanPolicy::Oracle(_) => match self.objective.plan(adapters, self.gpus, self.est) {
                Ok(p) => {
                    let (m, c) = migration_diff(self.prev.as_ref(), &p, adapters, &self.cost_model);
                    (Some(p), m, c, 0, 0)
                }
                Err(_) => (None, 0, 0.0, 0, 0),
            },
            ReplanPolicy::Replan(params) => {
                let out = replan_with_ledger(
                    self.prev.as_ref(),
                    adapters,
                    self.gpus,
                    self.est,
                    params,
                    self.objective,
                    Some(&mut self.ledger),
                );
                match out {
                    Ok(o) => (
                        Some(o.placement),
                        o.migrations,
                        o.migration_cost_s,
                        o.groups_reprobed,
                        o.groups_reused,
                    ),
                    Err(_) => (None, 0, 0.0, 0, 0),
                }
            }
        };
        // The plan-once cost is real planning work: charge it to epoch 0.
        let plan_wall_s =
            t_plan.elapsed().as_secs_f64() + if epoch == 0 { self.static_plan_s } else { 0.0 };
        // Static merely clones its plan-once placement after epoch 0 —
        // that is not a fresh planner invocation.
        let replanned = match self.policy {
            ReplanPolicy::Static => epoch == 0 && fresh.is_some(),
            _ => fresh.is_some(),
        };
        // Planning failure: keep serving on the stale placement.
        let active: Option<Placement> = fresh.or_else(|| self.prev.clone());
        self.prev = active.clone();
        PlanStep {
            active,
            replanned,
            plan_wall_s,
            migrations,
            migration_cost_s,
            groups_reprobed,
            groups_reused,
        }
    }
}

/// Run the lockstep rolling horizon, serving each epoch with `serve`
/// (engine or twin — both delegate to the per-GPU parallel cluster
/// runners).  Planning — one-shot, incremental and oracle alike — goes
/// through [`PolicyDriver`], the same state machine the event-driven
/// core replans with.
fn run_epochs_with<F>(
    drift: &DriftSpec,
    gpus: usize,
    est: &dyn PerfEstimator,
    objective: &dyn Objective,
    policy: &ReplanPolicy,
    mut serve: F,
) -> Result<DriftReport>
where
    F: FnMut(&Placement, &WorkloadSpec) -> Result<ClusterReport>,
{
    let mut driver = PolicyDriver::new(drift, gpus, est, objective, policy);
    let mut backlog = 0.0f64;
    let mut records: Vec<EpochRecord> = Vec::with_capacity(drift.epochs);

    for epoch in 0..drift.epochs {
        let spec = drift.epoch_spec(epoch);
        let step = driver.plan_epoch(epoch, &spec.adapters);
        let active = step.active;

        let mut throughput = 0.0;
        let mut incoming = 0.0;
        let mut itl_mean_s = 0.0;
        let mut served_requests = 0;
        let mut starved = false;
        let mut memory_error = false;
        let mut gpus_used = 0;
        let mut goodput_req_s = 0.0;
        let mut slo_attainment = 0.0;
        let mut ttft_mean_s = 0.0;
        let mut kv_handoff_bytes = 0;
        if let Some(p) = &active {
            let rep = serve(p, &spec)?;
            gpus_used = p.gpus_used();
            throughput = rep.total_throughput_tok_s;
            itl_mean_s = rep.itl_mean_s;
            served_requests = rep.completed_requests();
            starved = rep.starved;
            memory_error = rep.memory_error;
            goodput_req_s = rep.goodput_req_s;
            slo_attainment = rep.slo_attainment;
            ttft_mean_s = rep.ttft_mean_s;
            kv_handoff_bytes = rep.kv_handoff_bytes;
            // Incoming demand: realized rate per healthy GPU; for a GPU
            // that hit the memory error (report None) charge its assigned
            // adapters' expected demand — it served nothing, but its load
            // must still enter the backlog.  `gpu_jobs` is the same
            // ordering the cluster runners built `per_gpu` from.
            for ((_, ids), r) in super::gpu_jobs(p).iter().zip(&rep.per_gpu) {
                match r {
                    Some(r) => incoming += r.incoming_token_rate,
                    None => incoming += spec.subset(ids, 0).incoming_token_rate(),
                }
            }
            // Demand for adapters the placement does not cover is unserved
            // by definition: count it as incoming and flag starvation.
            let missing: Vec<usize> = spec
                .adapters
                .iter()
                .map(|a| a.id)
                .filter(|id| !p.assignment.contains_key(id))
                .collect();
            if !missing.is_empty() {
                incoming += spec.subset(&missing, 0).incoming_token_rate();
                starved = true;
            }
        } else {
            incoming = spec.incoming_token_rate();
            starved = !spec.adapters.is_empty();
        }

        let carried_in = backlog;
        // Signed deficit, clamped only after accumulating: an epoch that
        // serves more than its own arrivals (a backlog-replaying serve
        // path) works carried backlog off, while backlog itself never
        // goes negative (there is no demand to borrow from the future).
        // Clamping the per-epoch deficit first would force backlog
        // monotone non-decreasing for any serve implementation.
        backlog = (backlog + (incoming - throughput) * drift.epoch_s).max(0.0);
        records.push(EpochRecord {
            epoch,
            adapters: spec.adapters.len(),
            planned: active.is_some(),
            replanned: step.replanned,
            gpus_used,
            migrations: step.migrations,
            migration_cost_s: step.migration_cost_s,
            plan_wall_s: step.plan_wall_s,
            throughput_tok_s: throughput,
            incoming_tok_s: incoming,
            itl_mean_s,
            served_requests,
            starved,
            memory_error,
            carried_in_backlog_tokens: carried_in,
            backlog_tokens: backlog,
            groups_reprobed: step.groups_reprobed,
            groups_reused: step.groups_reused,
            goodput_req_s,
            slo_attainment,
            ttft_mean_s,
            kv_handoff_bytes,
        });
    }
    Ok(DriftReport::from_records(records))
}

/// What executes each epoch's serving under [`serve_horizon`].
#[derive(Debug, Clone, Copy)]
pub enum HorizonBackend<'a> {
    /// The Digital Twin (fast path: sweeps, quick-scale experiments).
    Twin {
        /// Calibrated latency models driving the simulation.
        calib: &'a Calibration,
        /// Which request lengths the twin receives (Table 1 variants).
        variant: LengthVariant,
    },
    /// The real engine; per-GPU backends are checked out of
    /// [`RunOptions::pool`] each epoch and returned afterwards (see
    /// [`serve_on_engine`]), so a whole horizon constructs at most `gpus`
    /// backends — not `gpus` per epoch, which on PJRT would recompile
    /// every HLO bucket each epoch.
    Engine,
}

/// Serve a rolling drift horizon: the unified entry point for horizon
/// serving (mirroring the `serve_on_*` collapse into [`RunOptions`]).
/// `backend` picks what serves (twin or engine), `core` picks how time
/// advances
/// ([`Core::Lockstep`] per-epoch runs vs [`Core::EventDriven`]
/// continuous simulation), and `opts` carries the worker/pool/seed seam
/// of the one-shot runners — [`RunOptions::seed`] overrides the drift's
/// master seed, [`RunOptions::pool`] is required for
/// [`HorizonBackend::Engine`].
///
/// The event-driven core is a twin-side simulation:
/// `(EventDriven, Engine)` is rejected rather than silently served
/// lockstep.
///
/// ```
/// use adapter_serving::cluster::epochs::{serve_horizon, HorizonBackend, ReplanPolicy};
/// use adapter_serving::cluster::{Core, RunOptions};
/// use adapter_serving::config::EngineConfig;
/// use adapter_serving::dt::{Calibration, LengthVariant};
/// use adapter_serving::placement::{Estimate, MinGpus, OracleEstimator};
/// use adapter_serving::workload::drift::DriftSpec;
/// use adapter_serving::workload::WorkloadSpec;
/// let calib = Calibration::default();
/// let drift = DriftSpec::steady(WorkloadSpec::homogeneous(4, 8, 0.1), 2, 5.0, 7);
/// let est = OracleEstimator::with_fallback(Estimate {
///     throughput_tok_s: 500.0,
///     starved: false,
///     memory_error: false,
/// });
/// let rep = serve_horizon(
///     HorizonBackend::Twin { calib: &calib, variant: LengthVariant::Original },
///     &EngineConfig::default(),
///     &drift,
///     2,
///     &est,
///     &MinGpus,
///     &ReplanPolicy::Static,
///     Core::EventDriven,
///     RunOptions::new(),
/// )
/// .unwrap();
/// assert_eq!(rep.per_epoch.len(), 2);
/// ```
#[allow(clippy::too_many_arguments)]
pub fn serve_horizon(
    backend: HorizonBackend<'_>,
    base: &EngineConfig,
    drift: &DriftSpec,
    gpus: usize,
    est: &dyn PerfEstimator,
    objective: &dyn Objective,
    policy: &ReplanPolicy,
    core: Core,
    opts: RunOptions<'_>,
) -> Result<DriftReport> {
    match (core, backend) {
        (Core::EventDriven, HorizonBackend::Twin { calib, variant }) => {
            run_event_horizon(calib, base, drift, gpus, est, objective, policy, variant, opts)
        }
        (Core::EventDriven, HorizonBackend::Engine) => Err(anyhow!(
            "the event-driven core is a twin-side simulation; engine horizons run lockstep"
        )),
        (Core::Lockstep, backend) => {
            // The seed override lands on the drift's master seed — every
            // epoch derives from it exactly as it would from the spec's
            // own, matching the one-shot runners' seed semantics.
            let drift = match opts.seed {
                Some(seed) => DriftSpec { seed, ..drift.clone() },
                None => drift.clone(),
            };
            let serve_opts = RunOptions { seed: None, ..opts };
            match backend {
                HorizonBackend::Twin { calib, variant } => {
                    run_epochs_with(&drift, gpus, est, objective, policy, |p, spec| {
                        Ok(serve_on_twin(calib, base, p, spec, variant, serve_opts))
                    })
                }
                HorizonBackend::Engine => {
                    run_epochs_with(&drift, gpus, est, objective, policy, |p, spec| {
                        serve_on_engine(base, p, spec, serve_opts)
                    })
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::MlModels;
    use crate::placement::{MinGpus, MinLatency};
    use crate::workload::drift::{AdapterPhase, RateDrift};
    use crate::workload::{AdapterSpec, WorkloadSpec};

    /// Shared analytic stand-in models (see `placement::test_models`).
    fn fake_models() -> MlModels {
        crate::placement::test_models::analytic_models(21)
    }

    /// Lockstep twin horizon with default options — keeps the tests
    /// terse.
    fn twin_horizon(
        calib: &Calibration,
        base: &EngineConfig,
        drift: &DriftSpec,
        gpus: usize,
        est: &dyn PerfEstimator,
        objective: &dyn Objective,
        policy: &ReplanPolicy,
    ) -> DriftReport {
        serve_horizon(
            HorizonBackend::Twin { calib, variant: LengthVariant::Original },
            base,
            drift,
            gpus,
            est,
            objective,
            policy,
            Core::Lockstep,
            RunOptions::new(),
        )
        .unwrap()
    }

    /// Same horizon on the event-driven core.
    fn event_horizon(
        calib: &Calibration,
        base: &EngineConfig,
        drift: &DriftSpec,
        gpus: usize,
        est: &dyn PerfEstimator,
        objective: &dyn Objective,
        policy: &ReplanPolicy,
    ) -> DriftReport {
        serve_horizon(
            HorizonBackend::Twin { calib, variant: LengthVariant::Original },
            base,
            drift,
            gpus,
            est,
            objective,
            policy,
            Core::EventDriven,
            RunOptions::new(),
        )
        .unwrap()
    }

    /// A burst-then-quiet churn: heavy burst adapters in epochs [0, 2),
    /// light base adapters for the whole 4-epoch horizon.
    fn burst_drift() -> DriftSpec {
        let mut phases: Vec<AdapterPhase> = (0..8)
            .map(|id| AdapterPhase {
                adapter: AdapterSpec { id, rank: 8, rate: 0.05 },
                arrive_epoch: 0,
                retire_epoch: usize::MAX,
            })
            .collect();
        for i in 0..80 {
            phases.push(AdapterPhase {
                adapter: AdapterSpec { id: 8 + i, rank: 8, rate: 0.2 },
                arrive_epoch: 0,
                retire_epoch: 2,
            });
        }
        DriftSpec { phases, drift: RateDrift::None, epochs: 4, epoch_s: 5.0, seed: 77 }
    }

    /// An always-feasible recorded estimator (isolates the accounting
    /// under test from any model behaviour).
    fn feasible_oracle() -> crate::placement::OracleEstimator {
        use crate::placement::{Estimate, OracleEstimator};
        OracleEstimator::with_fallback(Estimate {
            throughput_tok_s: 500.0,
            starved: false,
            memory_error: false,
        })
    }

    #[test]
    fn steady_workload_replans_without_migrations() {
        let models = fake_models();
        let drift = DriftSpec::steady(WorkloadSpec::homogeneous(16, 8, 0.05), 3, 5.0, 5);
        let rep = twin_horizon(
            &Calibration::default(),
            &EngineConfig::default(),
            &drift,
            4,
            &models,
            &MinGpus,
            &ReplanPolicy::Replan(ReplanParams::default()),
        );
        assert_eq!(rep.per_epoch.len(), 3);
        assert_eq!(rep.total_migrations, 0);
        let g0 = rep.per_epoch[0].gpus_used;
        assert!(rep.per_epoch.iter().all(|r| r.gpus_used == g0));
        assert!(rep.per_epoch.iter().all(|r| r.replanned));
        // Incremental re-probing: epoch 1's repair pass seeds the ledger,
        // so every later steady epoch reuses every group fingerprint.
        assert!(rep.per_epoch[2..].iter().all(|r| r.groups_reprobed == 0), "{:?}", rep.per_epoch);
        assert!(rep.per_epoch[2..].iter().all(|r| r.groups_reused == r.gpus_used));
        // 3 epochs: cold start, ledger-seeding repair, one reusing epoch.
        assert_eq!(rep.total_groups_reused, g0);
        assert_eq!(rep.total_groups_reprobed, g0);
    }

    /// Satellite gate: the parallel probe fan-out must leave a whole
    /// epoch horizon bit-identical to the serial probe path — including
    /// the cache-stat trajectory (batch hit/miss counting is serial).
    #[test]
    fn parallel_probe_horizon_is_bit_identical_to_serial() {
        use crate::placement::CachedEstimator;
        let calib = Calibration::default();
        let base = EngineConfig::default();
        let drift = burst_drift();
        let policy = ReplanPolicy::Replan(ReplanParams::default());
        let serial = CachedEstimator::wrap(fake_models()).probe_workers(1);
        let parallel = CachedEstimator::wrap(fake_models()).probe_workers(4);
        let rep_s = twin_horizon(&calib, &base, &drift, 4, &serial, &MinGpus, &policy);
        let rep_p = twin_horizon(&calib, &base, &drift, 4, &parallel, &MinGpus, &policy);
        assert_eq!(rep_s.per_epoch.len(), rep_p.per_epoch.len());
        for (s, p) in rep_s.per_epoch.iter().zip(&rep_p.per_epoch) {
            assert_eq!(s.gpus_used, p.gpus_used);
            assert_eq!(s.migrations, p.migrations);
            assert_eq!(s.migration_cost_s.to_bits(), p.migration_cost_s.to_bits());
            assert_eq!(s.throughput_tok_s.to_bits(), p.throughput_tok_s.to_bits());
            assert_eq!(s.backlog_tokens.to_bits(), p.backlog_tokens.to_bits());
            assert_eq!(s.groups_reprobed, p.groups_reprobed);
            assert_eq!(s.groups_reused, p.groups_reused);
        }
        assert_eq!(serial.stats(), parallel.stats(), "stat trajectories must match bit-for-bit");
    }

    #[test]
    fn static_policy_holds_one_placement() {
        let models = fake_models();
        let rep = twin_horizon(
            &Calibration::default(),
            &EngineConfig::default(),
            &burst_drift(),
            4,
            &models,
            &MinGpus,
            &ReplanPolicy::Static,
        );
        assert_eq!(rep.total_migrations, 0);
        let g0 = rep.per_epoch[0].gpus_used;
        assert!(g0 >= 2, "union burst workload must need >1 GPU, got {g0}");
        assert!(rep.per_epoch.iter().all(|r| r.gpus_used == g0));
    }

    #[test]
    fn replan_uses_fewer_gpu_epochs_than_static_under_churn() {
        let models = fake_models();
        let calib = Calibration::default();
        let base = EngineConfig::default();
        let drift = burst_drift();
        let stat =
            twin_horizon(&calib, &base, &drift, 4, &models, &MinGpus, &ReplanPolicy::Static);
        let repl = twin_horizon(
            &calib,
            &base,
            &drift,
            4,
            &models,
            &MinGpus,
            &ReplanPolicy::Replan(ReplanParams::default()),
        );
        let orac = twin_horizon(
            &calib,
            &base,
            &drift,
            4,
            &models,
            &MinGpus,
            &ReplanPolicy::Oracle(MigrationCost::default()),
        );
        // The burst retires after epoch 2: replanning must shed GPUs.
        assert!(
            repl.gpu_epochs < stat.gpu_epochs,
            "replan {} !< static {}",
            repl.gpu_epochs,
            stat.gpu_epochs
        );
        // The oracle is the per-epoch lower bound.
        assert!(orac.gpu_epochs <= repl.gpu_epochs);
        // Quiet epochs shrink to fewer GPUs than the burst epochs.
        assert!(repl.per_epoch[3].gpus_used < repl.per_epoch[0].gpus_used);
    }

    #[test]
    fn backlog_accounting_carries_across_epochs() {
        let models = fake_models();
        let rep = twin_horizon(
            &Calibration::default(),
            &EngineConfig::default(),
            &burst_drift(),
            4,
            &models,
            &MinGpus,
            &ReplanPolicy::Replan(ReplanParams::default()),
        );
        for w in rep.per_epoch.windows(2) {
            assert_eq!(
                w[1].carried_in_backlog_tokens.to_bits(),
                w[0].backlog_tokens.to_bits(),
                "backlog must be carried verbatim across the boundary"
            );
        }
        assert!(rep.per_epoch.iter().all(|r| r.backlog_tokens >= 0.0));
        assert_eq!(rep.final_backlog_tokens.to_bits(), rep.per_epoch[3].backlog_tokens.to_bits());
    }

    #[test]
    fn epoch_runner_constructs_at_most_gpus_backends_per_horizon() {
        let models = fake_models();
        let drift = DriftSpec::steady(WorkloadSpec::homogeneous(4, 8, 0.2), 3, 2.0, 9);
        let base = EngineConfig::default();
        let pool = crate::runtime::BackendPool::new(std::path::Path::new("/nonexistent"));
        let rep = serve_horizon(
            HorizonBackend::Engine,
            &base,
            &drift,
            2,
            &models,
            &MinGpus,
            &ReplanPolicy::Replan(ReplanParams::default()),
            Core::Lockstep,
            RunOptions::new().pool(&pool),
        )
        .unwrap();
        assert_eq!(rep.per_epoch.len(), 3);
        assert!(rep.per_epoch.iter().all(|r| r.planned));
        // The pre-pool runner constructed gpus × epochs backends; the
        // pool bounds the whole horizon by the GPU budget.
        assert!(pool.created() <= 2, "created {} backends > 2 GPUs", pool.created());
        assert!(pool.reused() > 0, "later epochs must reuse pooled backends");
    }

    /// The engine backend needs a pool, and the event core is twin-only —
    /// both misuses must fail loudly, not silently fall back.
    #[test]
    fn serve_horizon_rejects_unsupported_combinations() {
        let models = fake_models();
        let drift = DriftSpec::steady(WorkloadSpec::homogeneous(4, 8, 0.1), 2, 2.0, 9);
        let err = serve_horizon(
            HorizonBackend::Engine,
            &EngineConfig::default(),
            &drift,
            2,
            &models,
            &MinGpus,
            &ReplanPolicy::Static,
            Core::EventDriven,
            RunOptions::new(),
        );
        assert!(err.is_err(), "event core on the engine backend must be rejected");
        let err = serve_horizon(
            HorizonBackend::Engine,
            &EngineConfig::default(),
            &drift,
            2,
            &models,
            &MinGpus,
            &ReplanPolicy::Static,
            Core::Lockstep,
            RunOptions::new(), // no pool
        );
        assert!(err.is_err(), "engine backend without a pool must be rejected");
    }

    /// Row-shape half of the header↔struct drift guard (the header half
    /// lives in `engine::metrics`): label columns + [`EpochRecord`] cells
    /// + status must tile [`ReportSchema::drift_header`] exactly.
    #[test]
    fn epoch_record_cells_tile_the_drift_header() {
        let r = EpochRecord {
            epoch: 1,
            adapters: 4,
            planned: true,
            replanned: true,
            gpus_used: 2,
            migrations: 1,
            migration_cost_s: 0.5,
            plan_wall_s: 0.1,
            throughput_tok_s: 100.0,
            incoming_tok_s: 90.0,
            itl_mean_s: 0.01,
            served_requests: 10,
            starved: false,
            memory_error: false,
            carried_in_backlog_tokens: 0.0,
            backlog_tokens: 0.0,
            groups_reprobed: 0,
            groups_reused: 2,
            goodput_req_s: 1.5,
            slo_attainment: 0.9,
            ttft_mean_s: 0.2,
            kv_handoff_bytes: 1024,
        };
        let header = ReportSchema::drift_header();
        // objective + policy lead, status trails: the record owns the rest.
        assert_eq!(2 + r.csv_cells().len() + 1, header.len());
        let cells = r.csv_cells();
        assert_eq!(cells[0], "1", "first record cell is the epoch index");
        let slo_at = header.len() - 3 - ReportSchema::SLO.len();
        assert_eq!(cells[slo_at], "1.500", "goodput cell sits where the header says");
        assert_eq!(cells.last().unwrap(), "1024", "handoff bytes are the last record cell");
    }

    /// A hand-built [`ClusterReport`] for exercising the backlog and ITL
    /// accounting with exact numbers (the serve seam accepts any closure).
    fn synthetic_report(
        p: &Placement,
        incoming: f64,
        throughput: f64,
        completed: usize,
        itl_s: f64,
    ) -> ClusterReport {
        let starved = throughput < 0.9 * incoming;
        let jobs = super::super::gpu_jobs(p);
        let n = jobs.len().max(1) as f64;
        let per_gpu: Vec<Option<crate::engine::metrics::Report>> = jobs
            .iter()
            .map(|_| {
                Some(crate::engine::metrics::Report {
                    throughput_tok_s: throughput / n,
                    incoming_token_rate: incoming / n,
                    completed,
                    itl_mean_s: itl_s,
                    starved,
                    ..Default::default()
                })
            })
            .collect();
        ClusterReport {
            per_gpu,
            memory_error: false,
            starved,
            total_throughput_tok_s: throughput,
            itl_mean_s: itl_s,
            ttft_mean_s: 0.0,
            goodput_req_s: 0.0,
            slo_attainment: 0.0,
            kv_handoff_bytes: 0,
            gpus_used: p.gpus_used(),
            wall_s: 0.0,
        }
    }

    /// Satellite gate: backlog built during a burst must *drain* once
    /// spare capacity appears — the max(0) clamp may not floor the signed
    /// per-epoch deficit before accumulation.
    #[test]
    fn backlog_drains_in_quiet_epochs_after_a_burst() {
        let est = feasible_oracle();
        // 120 tok/s of capacity: the burst (200 tok/s incoming) builds
        // 80 tokens of backlog per epoch; in the quiet epochs the serve
        // closure reports the full 120 served — incoming 40 plus 80 of
        // replayed backlog — until the deficit is gone, then 40.
        let drift = DriftSpec::steady(WorkloadSpec::homogeneous(4, 8, 0.1), 5, 1.0, 3);
        let profile =
            [(200.0, 120.0), (200.0, 120.0), (40.0, 120.0), (40.0, 120.0), (40.0, 40.0)];
        let epoch = std::cell::Cell::new(0usize);
        let rep = run_epochs_with(
            &drift,
            2,
            &est,
            &MinGpus,
            &ReplanPolicy::Replan(ReplanParams::default()),
            |p, _spec| {
                let (incoming, served) = profile[epoch.get()];
                epoch.set(epoch.get() + 1);
                Ok(synthetic_report(p, incoming, served, 10, 5e-3))
            },
        )
        .unwrap();
        let backlog: Vec<f64> = rep.per_epoch.iter().map(|r| r.backlog_tokens).collect();
        // Burst builds 80 tokens per epoch; quiet epochs retire 80 each.
        assert_eq!(backlog, vec![80.0, 160.0, 80.0, 0.0, 0.0]);
        assert!(
            backlog[2] < backlog[1],
            "backlog must decrease once the burst retires: {backlog:?}"
        );
        assert_eq!(rep.final_backlog_tokens, 0.0, "spare capacity retires the whole deficit");
        assert!(rep.per_epoch.iter().all(|r| r.backlog_tokens >= 0.0));
    }

    /// Regression for the ITL accounting bug: a planned epoch that served
    /// zero requests used to enter the horizon mean as a flattering 0.0.
    #[test]
    fn mean_itl_weights_epochs_by_served_requests() {
        let est = feasible_oracle();
        let drift = DriftSpec::steady(WorkloadSpec::homogeneous(4, 8, 0.1), 2, 1.0, 3);
        let policy = ReplanPolicy::Replan(ReplanParams::default());
        // Epoch 0 serves 100 requests at 10 ms ITL; epoch 1 is planned
        // but fully starved (0 served, ITL reported as 0).
        let epoch = std::cell::Cell::new(0usize);
        let rep = run_epochs_with(&drift, 2, &est, &MinGpus, &policy, |p, _spec| {
            let e = epoch.get();
            epoch.set(e + 1);
            let (completed, itl_s) = [(100usize, 10e-3), (0, 0.0)][e];
            Ok(synthetic_report(p, 100.0, 100.0, completed, itl_s))
        })
        .unwrap();
        assert_eq!(rep.per_epoch[0].served_requests, 100);
        assert_eq!(rep.per_epoch[1].served_requests, 0);
        // Both epochs are planned: an unweighted per-planned-epoch mean
        // would report 5 ms; the served-request weighting reports 10 ms.
        assert_eq!(rep.mean_itl_s.to_bits(), (10e-3f64).to_bits());

        // A horizon that serves nothing reports 0, not NaN.
        let epoch0 = std::cell::Cell::new(0usize);
        let none = run_epochs_with(&drift, 2, &est, &MinGpus, &policy, |p, _spec| {
            epoch0.set(epoch0.get() + 1);
            Ok(synthetic_report(p, 100.0, 100.0, 0, 0.0))
        })
        .unwrap();
        assert_eq!(none.mean_itl_s, 0.0);
    }

    /// The PR-5 tentpole gate: a DT-in-the-loop horizon through a shared
    /// [`CachedEstimator`] must be bit-identical to the uncached twin
    /// path, the memo must absorb duplicate probes, and the replan
    /// ledger must make steady epochs past the first repair probe-free.
    #[test]
    fn cached_twin_horizon_is_bit_identical_and_cheaper() {
        use crate::placement::{CachedEstimator, TwinEstimator};
        let calib = Calibration::default();
        let base = EngineConfig::default();
        // A steady 8-epoch horizon: epochs 2+ re-probe exactly the groups
        // epoch 1 repaired, so the memo answers nearly everything.
        let drift = DriftSpec::steady(WorkloadSpec::homogeneous(16, 8, 0.05), 8, 2.0, 5);
        let policy = ReplanPolicy::Replan(ReplanParams::default());
        let twin = || TwinEstimator::new(calib.clone(), base.clone()).horizon(5.0);
        let uncached =
            twin_horizon(&calib, &base, &drift, 4, &twin(), &MinGpus, &policy);
        let est = CachedEstimator::wrap(twin());
        let cached = twin_horizon(&calib, &base, &drift, 4, &est, &MinGpus, &policy);
        assert_eq!(uncached.per_epoch.len(), cached.per_epoch.len());
        for (u, c) in uncached.per_epoch.iter().zip(&cached.per_epoch) {
            assert_eq!(u.gpus_used, c.gpus_used);
            assert_eq!(u.migrations, c.migrations);
            assert_eq!(u.throughput_tok_s.to_bits(), c.throughput_tok_s.to_bits());
            assert_eq!(u.itl_mean_s.to_bits(), c.itl_mean_s.to_bits());
            assert_eq!(u.backlog_tokens.to_bits(), c.backlog_tokens.to_bits());
        }
        assert_eq!(uncached.mean_itl_s.to_bits(), cached.mean_itl_s.to_bits());
        let stats = est.stats();
        // The memo answers the probes epochs 0 and 1 share (Alg. 1's
        // winner re-probes and the repair pass re-visiting epoch-0 keys).
        assert!(stats.hits > 0, "epoch-1 repair must re-hit epoch-0 probe memos: {stats:?}");
        // The replan ledger moved the bulk of the savings upstream of the
        // cache: steady epochs 2+ issue no probes at all, so the 8-epoch
        // horizon costs exactly as many estimator calls — and as many DT
        // simulations (misses) — as a 2-epoch one.
        let short = DriftSpec { epochs: 2, ..drift.clone() };
        let est2 = CachedEstimator::wrap(twin());
        twin_horizon(&calib, &base, &short, 4, &est2, &MinGpus, &policy);
        assert_eq!(est2.stats().total(), stats.total(), "epochs 2+ must be probe-free");
        assert_eq!(est2.stats().misses, stats.misses);
    }

    /// The latency objective must keep the cluster spread across epochs
    /// (and cost more GPU-epochs than the consolidating objective).
    #[test]
    fn min_latency_objective_keeps_the_cluster_spread() {
        let est = feasible_oracle();
        let calib = Calibration::default();
        let base = EngineConfig::default();
        let drift = DriftSpec::steady(WorkloadSpec::homogeneous(16, 8, 0.05), 3, 5.0, 5);
        let policy = ReplanPolicy::Replan(ReplanParams::default());
        let spread = twin_horizon(&calib, &base, &drift, 4, &est, &MinLatency, &policy);
        assert!(spread.per_epoch.iter().all(|r| r.gpus_used == 4), "MinLatency spreads");
        assert_eq!(spread.total_migrations, 0, "steady workload must not migrate");
        assert!(spread.mean_itl_s >= 0.0);
        let packed = twin_horizon(&calib, &base, &drift, 4, &est, &MinGpus, &policy);
        assert!(
            packed.gpu_epochs < spread.gpu_epochs,
            "MinGpus must provision fewer GPU-epochs: {} !< {}",
            packed.gpu_epochs,
            spread.gpu_epochs
        );
    }

    /// Satellite gate (tentpole acceptance): on a steady workload the
    /// event-driven core must match the lockstep runner within 5%
    /// served-throughput.  A single-GPU placement makes the comparison
    /// sharp: the lockstep per-GPU subset seed for GPU 0 equals the
    /// epoch spec's own seed, so both cores serve the *identical* arrival
    /// realization and the only differences are boundary effects (the
    /// lockstep core abandons requests in flight at each epoch boundary;
    /// the event core finishes them).
    #[test]
    fn event_core_matches_lockstep_on_steady_workload() {
        let est = feasible_oracle();
        let calib = Calibration::default();
        let base = EngineConfig::default();
        let drift = DriftSpec::steady(WorkloadSpec::homogeneous(8, 8, 0.1), 3, 30.0, 41);
        let policy = ReplanPolicy::Static;
        let lock = twin_horizon(&calib, &base, &drift, 1, &est, &MinGpus, &policy);
        let event = event_horizon(&calib, &base, &drift, 1, &est, &MinGpus, &policy);
        assert_eq!(event.per_epoch.len(), lock.per_epoch.len());
        assert!(lock.mean_throughput_tok_s > 0.0);
        let thr_rel = (event.mean_throughput_tok_s - lock.mean_throughput_tok_s).abs()
            / lock.mean_throughput_tok_s;
        assert!(
            thr_rel < 0.05,
            "served throughput diverged {:.1}%: event {:.1} vs lockstep {:.1} tok/s",
            thr_rel * 100.0,
            event.mean_throughput_tok_s,
            lock.mean_throughput_tok_s
        );
        let served = |r: &DriftReport| r.per_epoch.iter().map(|e| e.served_requests).sum::<usize>();
        let (es, ls) = (served(&event) as f64, served(&lock) as f64);
        assert!(ls > 0.0);
        assert!(
            (es - ls).abs() / ls < 0.10,
            "served request counts diverged: event {es} vs lockstep {ls}"
        );
        assert!(lock.mean_itl_s > 0.0);
        let itl_rel = (event.mean_itl_s - lock.mean_itl_s).abs() / lock.mean_itl_s;
        assert!(
            itl_rel < 0.20,
            "mean ITL diverged {:.1}%: event {:.4} vs lockstep {:.4} s",
            itl_rel * 100.0,
            event.mean_itl_s,
            lock.mean_itl_s
        );
        // Feasible steady load on one placement: nothing migrates, so no
        // KV crosses GPUs; goodput is reported on both cores.
        assert_eq!(event.total_kv_handoff_bytes, 0);
        assert!(event.mean_goodput_req_s > 0.0);
        assert!(lock.mean_goodput_req_s > 0.0);
    }

    /// Satellite gate (tentpole acceptance): two event-driven runs under
    /// the same seed must be bit-identical — the calendar queue's
    /// (time, class, seq) ordering leaves no room for nondeterminism even
    /// across a churn horizon with migrations and retirements.
    #[test]
    fn event_core_is_bit_deterministic_across_runs() {
        let models = fake_models();
        let calib = Calibration::default();
        let base = EngineConfig::default();
        let drift = DriftSpec::churn(6, 10, &[8, 16], &[0.1, 0.2], 4, 5.0, 11);
        let policy = ReplanPolicy::Replan(ReplanParams::default());
        let a = event_horizon(&calib, &base, &drift, 3, &models, &MinGpus, &policy);
        let b = event_horizon(&calib, &base, &drift, 3, &models, &MinGpus, &policy);
        assert_eq!(a.per_epoch.len(), b.per_epoch.len());
        for (x, y) in a.per_epoch.iter().zip(&b.per_epoch) {
            assert_eq!(x.gpus_used, y.gpus_used);
            assert_eq!(x.migrations, y.migrations);
            assert_eq!(x.served_requests, y.served_requests);
            assert_eq!(x.throughput_tok_s.to_bits(), y.throughput_tok_s.to_bits());
            assert_eq!(x.incoming_tok_s.to_bits(), y.incoming_tok_s.to_bits());
            assert_eq!(x.itl_mean_s.to_bits(), y.itl_mean_s.to_bits());
            assert_eq!(x.ttft_mean_s.to_bits(), y.ttft_mean_s.to_bits());
            assert_eq!(x.backlog_tokens.to_bits(), y.backlog_tokens.to_bits());
            assert_eq!(x.goodput_req_s.to_bits(), y.goodput_req_s.to_bits());
            assert_eq!(x.slo_attainment.to_bits(), y.slo_attainment.to_bits());
            assert_eq!(x.kv_handoff_bytes, y.kv_handoff_bytes);
            assert_eq!(x.starved, y.starved);
        }
        assert_eq!(a.total_kv_handoff_bytes, b.total_kv_handoff_bytes);
        assert_eq!(a.final_backlog_tokens.to_bits(), b.final_backlog_tokens.to_bits());
    }

    /// Satellite gate: a burst fixture whose tail epochs have *zero*
    /// arrivals.  The event core keeps serving carried requests through
    /// the replan boundaries — without re-prefilling them (no migrations
    /// under `Static`, so no recompute-preemption at boundaries) — and
    /// realizes backlog drain; the lockstep core serves nothing in the
    /// quiet epochs because each epoch only ever sees its own arrivals.
    #[test]
    fn event_core_drains_burst_backlog_through_replan_boundaries() {
        let est = feasible_oracle();
        let calib = Calibration::default();
        let base = EngineConfig::default();
        // Ramp 8 → −8 over 4 epochs: factors 6, 2, 0 (clamped), 0 — a
        // crushing burst, a moderate epoch, then two silent epochs.
        let drift =
            DriftSpec::ramp(WorkloadSpec::homogeneous(8, 8, 1.0), 8.0, -8.0, 4, 10.0, 23);
        let policy = ReplanPolicy::Static;
        let event = event_horizon(&calib, &base, &drift, 1, &est, &MinGpus, &policy);
        let lock = twin_horizon(&calib, &base, &drift, 1, &est, &MinGpus, &policy);
        // The burst overloads the single GPU: realized backlog builds.
        assert!(
            event.per_epoch[0].backlog_tokens > 0.0,
            "burst epoch must leave realized backlog: {:?}",
            event.per_epoch[0]
        );
        // The silent epochs have no arrivals at all...
        assert_eq!(event.per_epoch[3].incoming_tok_s, 0.0);
        // ...yet the event core still serves carried work through the
        // boundary (the lockstep core cannot: its epochs start empty).
        assert!(
            event.per_epoch[2].throughput_tok_s > 0.0,
            "carried backlog must drain in the quiet epoch: {:?}",
            event.per_epoch[2]
        );
        assert_eq!(lock.per_epoch[2].throughput_tok_s, 0.0);
        assert_eq!(lock.per_epoch[3].throughput_tok_s, 0.0);
        // Drain is visible in the realized backlog trajectory...
        assert!(
            event.per_epoch[3].backlog_tokens < event.per_epoch[1].backlog_tokens,
            "backlog must decrease across the quiet epochs: {:?}",
            event.per_epoch.iter().map(|r| r.backlog_tokens).collect::<Vec<_>>()
        );
        // ...and in the horizon total: the event core ends with less
        // unserved demand than the lockstep model of the same horizon.
        assert!(event.final_backlog_tokens < lock.final_backlog_tokens);
        // Static single-GPU placement: nothing migrates, no KV handoff.
        assert_eq!(event.total_kv_handoff_bytes, 0);
    }
}
