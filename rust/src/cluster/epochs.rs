//! Rolling-horizon epoch runner (DESIGN.md §7): drives the cluster layer
//! epoch-by-epoch over a drifting workload, re-planning placements online.
//!
//! Each epoch of a [`DriftSpec`] is planned under a [`ReplanPolicy`]
//! (plan-once static, migration-aware incremental replan, or an oracle
//! that re-runs Alg. 1 from scratch with free migrations), then served on
//! the engine or the Digital Twin through the existing per-GPU parallel
//! cluster runners.  State carried across epoch boundaries:
//!
//! - the **previous placement** — the incremental replanner's starting
//!   point, and the migration baseline for every policy's accounting;
//! - the **queue backlog** (tokens): each epoch's unserved demand,
//!   `max(0, incoming − served)·epoch_s`, accumulates across the horizon
//!   instead of being dropped, so a starved epoch leaves a visible
//!   deficit in every later record and `final_backlog_tokens` is the
//!   horizon's total unserved demand.  Unserved *requests* are accounted,
//!   not re-injected into later epochs (re-injection with a KV-handoff
//!   cost model is a ROADMAP item); KV state itself is never shipped
//!   between epochs — migrated requests re-prefill, matching the engine's
//!   recompute-preemption semantics (§3.2).
//!
//! When planning fails for an epoch (predicted starvation), the runner
//! keeps serving on the stale placement — what a production control loop
//! would do — and flags the epoch infeasible if demand goes unserved.

use super::{run_on_engine, run_on_twin, ClusterReport};
use crate::config::EngineConfig;
use crate::dt::{Calibration, LengthVariant};
use crate::placement::replan::{replan, MigrationCost, ReplanParams};
use crate::placement::{Objective, PerfEstimator, Placement};
use crate::runtime::Backend;
use crate::workload::drift::DriftSpec;
use crate::workload::WorkloadSpec;
use anyhow::Result;
use std::time::Instant;

/// How each epoch's placement is derived from the previous one.  Every
/// policy plans through the estimator/objective seams passed to the
/// runner, so the same policy can minimize GPUs or latency.
#[derive(Debug, Clone, PartialEq)]
pub enum ReplanPolicy {
    /// Plan once for the union workload (every adapter that ever appears,
    /// at its peak rate) and hold that placement for the whole horizon —
    /// the static-provisioning baseline.
    Static,
    /// Migration-aware incremental replanning per epoch
    /// ([`crate::placement::replan`]).
    Replan(ReplanParams),
    /// Fresh one-shot plan per epoch (the objective's cold-start planner
    /// — Alg. 1 for `MinGpus`), ignoring the previous placement when
    /// planning (migrations are free): the per-epoch cost lower bound.
    /// The [`MigrationCost`] model is still used to *report* the
    /// migration burden this policy silently incurs, comparably to
    /// `Replan`.
    Oracle(MigrationCost),
}

/// One epoch's outcome.
#[derive(Debug, Clone)]
pub struct EpochRecord {
    /// Epoch index within the horizon.
    pub epoch: usize,
    /// Adapters active in this epoch.
    pub adapters: usize,
    /// Whether any placement (fresh or carried-over) was available.
    pub planned: bool,
    /// Whether a *fresh* plan was produced this epoch (false when serving
    /// continued on a stale placement after a planning failure).
    pub replanned: bool,
    /// GPUs provisioned by the active placement.
    pub gpus_used: usize,
    /// Adapters that changed GPU relative to the previous epoch.
    pub migrations: usize,
    /// Modeled migration latency this epoch (seconds).
    pub migration_cost_s: f64,
    /// Wall-clock spent planning this epoch (seconds).
    pub plan_wall_s: f64,
    /// Aggregate served throughput (tok/s).
    pub throughput_tok_s: f64,
    /// Aggregate incoming token rate, including demand for adapters the
    /// active placement does not cover (tok/s).
    pub incoming_tok_s: f64,
    /// Request-weighted mean inter-token latency of the epoch's serving
    /// run (seconds; 0 when nothing was served).
    pub itl_mean_s: f64,
    /// Any GPU starved, or some active adapter had no GPU at all.
    pub starved: bool,
    /// Any GPU hit the static-reservation memory error.
    pub memory_error: bool,
    /// Cumulative unserved demand carried *into* this epoch (tokens).
    pub carried_in_backlog_tokens: f64,
    /// Cumulative unserved demand at the end of this epoch (tokens).
    pub backlog_tokens: f64,
}

impl EpochRecord {
    /// An epoch is feasible when it had a placement and served its demand
    /// without starvation or memory errors.
    pub fn feasible(&self) -> bool {
        self.planned && !self.starved && !self.memory_error
    }
}

/// Horizon-level aggregate over all epochs.
#[derive(Debug, Clone)]
pub struct DriftReport {
    /// Per-epoch records, in epoch order.
    pub per_epoch: Vec<EpochRecord>,
    /// Σ provisioned GPUs over epochs — the cost metric the drift
    /// experiment compares across policies.
    pub gpu_epochs: usize,
    /// Σ migrations over epochs.
    pub total_migrations: usize,
    /// Σ modeled migration latency (seconds).
    pub total_migration_cost_s: f64,
    /// Number of infeasible epochs (see [`EpochRecord::feasible`]).
    pub infeasible_epochs: usize,
    /// Mean served throughput across epochs (tok/s).
    pub mean_throughput_tok_s: f64,
    /// Mean of the per-epoch mean inter-token latencies over *planned*
    /// epochs (seconds) — the cost metric the latency objective targets
    /// over time.  Unplanned epochs serve nothing and are excluded: a
    /// zero ITL for a failed epoch would flatter the failing policy on a
    /// lower-is-better metric.
    pub mean_itl_s: f64,
    /// Total unserved demand over the whole horizon (tokens).
    pub final_backlog_tokens: f64,
}

impl DriftReport {
    /// True when every epoch was feasible.
    pub fn feasible(&self) -> bool {
        self.infeasible_epochs == 0
    }

    fn from_records(per_epoch: Vec<EpochRecord>) -> DriftReport {
        let n = per_epoch.len().max(1) as f64;
        let planned = per_epoch.iter().filter(|r| r.planned).count().max(1) as f64;
        let itl_sum: f64 = per_epoch.iter().filter(|r| r.planned).map(|r| r.itl_mean_s).sum();
        DriftReport {
            gpu_epochs: per_epoch.iter().map(|r| r.gpus_used).sum(),
            total_migrations: per_epoch.iter().map(|r| r.migrations).sum(),
            total_migration_cost_s: per_epoch.iter().map(|r| r.migration_cost_s).sum(),
            infeasible_epochs: per_epoch.iter().filter(|r| !r.feasible()).count(),
            mean_throughput_tok_s: per_epoch.iter().map(|r| r.throughput_tok_s).sum::<f64>() / n,
            mean_itl_s: itl_sum / planned,
            final_backlog_tokens: per_epoch.last().map(|r| r.backlog_tokens).unwrap_or(0.0),
            per_epoch,
        }
    }
}

/// Migrations of `next` relative to `prev` over the epoch's adapter set,
/// costed with the fig6 load-time model.
fn migration_diff(
    prev: Option<&Placement>,
    next: &Placement,
    adapters: &[crate::workload::AdapterSpec],
    cost: &MigrationCost,
) -> (usize, f64) {
    let Some(prev) = prev else {
        return (0, 0.0);
    };
    let mut migrations = 0;
    let mut total = 0.0;
    for a in adapters {
        if let (Some(&pg), Some(&ng)) = (prev.assignment.get(&a.id), next.assignment.get(&a.id)) {
            if pg != ng {
                migrations += 1;
                total += cost.load_s(a.rank);
            }
        }
    }
    (migrations, total)
}

/// Run the rolling horizon, serving each epoch with `serve` (engine or
/// twin — both delegate to the per-GPU parallel cluster runners).
/// Planning — one-shot, incremental and oracle alike — goes through the
/// `est`/`objective` seams, so the same control loop can minimize GPUs or
/// latency with any estimator behind it.
fn run_epochs_with<F>(
    drift: &DriftSpec,
    gpus: usize,
    est: &dyn PerfEstimator,
    objective: &dyn Objective,
    policy: &ReplanPolicy,
    mut serve: F,
) -> Result<DriftReport>
where
    F: FnMut(&Placement, &WorkloadSpec) -> Result<ClusterReport>,
{
    let cost_model = match policy {
        ReplanPolicy::Replan(p) => p.cost,
        ReplanPolicy::Oracle(c) => *c,
        ReplanPolicy::Static => MigrationCost::default(), // never charged: 0 migrations
    };
    let t_static = Instant::now();
    let static_placement: Option<Placement> = match policy {
        ReplanPolicy::Static => objective.plan(&drift.union_adapters(), gpus, est).ok(),
        _ => None,
    };
    // The plan-once cost is real planning work: charge it to epoch 0.
    let static_plan_s =
        if matches!(policy, ReplanPolicy::Static) { t_static.elapsed().as_secs_f64() } else { 0.0 };

    let mut prev: Option<Placement> = None;
    let mut backlog = 0.0f64;
    let mut records: Vec<EpochRecord> = Vec::with_capacity(drift.epochs);

    for epoch in 0..drift.epochs {
        let spec = drift.epoch_spec(epoch);
        let t_plan = Instant::now();
        let (fresh, migrations, migration_cost_s) = match policy {
            ReplanPolicy::Static => (static_placement.clone(), 0, 0.0),
            ReplanPolicy::Oracle(_) => match objective.plan(&spec.adapters, gpus, est) {
                Ok(p) => {
                    let (m, c) = migration_diff(prev.as_ref(), &p, &spec.adapters, &cost_model);
                    (Some(p), m, c)
                }
                Err(_) => (None, 0, 0.0),
            },
            ReplanPolicy::Replan(params) => {
                match replan(prev.as_ref(), &spec.adapters, gpus, est, params, objective) {
                    Ok(out) => (Some(out.placement), out.migrations, out.migration_cost_s),
                    Err(_) => (None, 0, 0.0),
                }
            }
        };
        let plan_wall_s =
            t_plan.elapsed().as_secs_f64() + if epoch == 0 { static_plan_s } else { 0.0 };
        // Static merely clones its plan-once placement after epoch 0 —
        // that is not a fresh planner invocation.
        let replanned = match policy {
            ReplanPolicy::Static => epoch == 0 && fresh.is_some(),
            _ => fresh.is_some(),
        };
        // Planning failure: keep serving on the stale placement.
        let active: Option<Placement> = fresh.or_else(|| prev.clone());

        let mut throughput = 0.0;
        let mut incoming = 0.0;
        let mut itl_mean_s = 0.0;
        let mut starved = false;
        let mut memory_error = false;
        let mut gpus_used = 0;
        if let Some(p) = &active {
            let rep = serve(p, &spec)?;
            gpus_used = p.gpus_used();
            throughput = rep.total_throughput_tok_s;
            itl_mean_s = rep.itl_mean_s;
            starved = rep.starved;
            memory_error = rep.memory_error;
            // Incoming demand: realized rate per healthy GPU; for a GPU
            // that hit the memory error (report None) charge its assigned
            // adapters' expected demand — it served nothing, but its load
            // must still enter the backlog.  `gpu_jobs` is the same
            // ordering the cluster runners built `per_gpu` from.
            for ((_, ids), r) in super::gpu_jobs(p).iter().zip(&rep.per_gpu) {
                match r {
                    Some(r) => incoming += r.incoming_token_rate,
                    None => incoming += spec.subset(ids, 0).incoming_token_rate(),
                }
            }
            // Demand for adapters the placement does not cover is unserved
            // by definition: count it as incoming and flag starvation.
            let missing: Vec<usize> = spec
                .adapters
                .iter()
                .map(|a| a.id)
                .filter(|id| !p.assignment.contains_key(id))
                .collect();
            if !missing.is_empty() {
                incoming += spec.subset(&missing, 0).incoming_token_rate();
                starved = true;
            }
        } else {
            incoming = spec.incoming_token_rate();
            starved = !spec.adapters.is_empty();
        }

        let carried_in = backlog;
        backlog += (incoming - throughput).max(0.0) * drift.epoch_s;
        records.push(EpochRecord {
            epoch,
            adapters: spec.adapters.len(),
            planned: active.is_some(),
            replanned,
            gpus_used,
            migrations,
            migration_cost_s,
            plan_wall_s,
            throughput_tok_s: throughput,
            incoming_tok_s: incoming,
            itl_mean_s,
            starved,
            memory_error,
            carried_in_backlog_tokens: carried_in,
            backlog_tokens: backlog,
        });
        prev = active;
    }
    Ok(DriftReport::from_records(records))
}

/// Serve the rolling horizon on the Digital Twin (fast path: sweeps and
/// the quick-scale drift experiment).
pub fn run_epochs_on_twin(
    calib: &Calibration,
    base: &EngineConfig,
    drift: &DriftSpec,
    gpus: usize,
    est: &dyn PerfEstimator,
    objective: &dyn Objective,
    policy: &ReplanPolicy,
    variant: LengthVariant,
) -> Result<DriftReport> {
    run_epochs_with(drift, gpus, est, objective, policy, |p, spec| {
        Ok(run_on_twin(calib, base, p, spec, variant))
    })
}

/// Serve the rolling horizon on the real engine (one backend per GPU per
/// epoch, created inside the worker threads — see [`run_on_engine`]).
pub fn run_epochs_on_engine<F>(
    make_backend: &F,
    base: &EngineConfig,
    drift: &DriftSpec,
    gpus: usize,
    est: &dyn PerfEstimator,
    objective: &dyn Objective,
    policy: &ReplanPolicy,
) -> Result<DriftReport>
where
    F: Fn() -> Result<Box<dyn Backend>> + Sync,
{
    run_epochs_with(drift, gpus, est, objective, policy, |p, spec| {
        run_on_engine(make_backend, base, p, spec)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::MlModels;
    use crate::placement::{MinGpus, MinLatency};
    use crate::workload::drift::{AdapterPhase, RateDrift};
    use crate::workload::{AdapterSpec, WorkloadSpec};

    /// Shared analytic stand-in models (see `placement::test_models`).
    fn fake_models() -> MlModels {
        crate::placement::test_models::analytic_models(21)
    }

    /// A burst-then-quiet churn: heavy burst adapters in epochs [0, 2),
    /// light base adapters for the whole 4-epoch horizon.
    fn burst_drift() -> DriftSpec {
        let mut phases: Vec<AdapterPhase> = (0..8)
            .map(|id| AdapterPhase {
                adapter: AdapterSpec { id, rank: 8, rate: 0.05 },
                arrive_epoch: 0,
                retire_epoch: usize::MAX,
            })
            .collect();
        for i in 0..80 {
            phases.push(AdapterPhase {
                adapter: AdapterSpec { id: 8 + i, rank: 8, rate: 0.2 },
                arrive_epoch: 0,
                retire_epoch: 2,
            });
        }
        DriftSpec { phases, drift: RateDrift::None, epochs: 4, epoch_s: 5.0, seed: 77 }
    }

    #[test]
    fn steady_workload_replans_without_migrations() {
        let models = fake_models();
        let drift = DriftSpec::steady(WorkloadSpec::homogeneous(16, 8, 0.05), 3, 5.0, 5);
        let rep = run_epochs_on_twin(
            &Calibration::default(),
            &EngineConfig::default(),
            &drift,
            4,
            &models,
            &MinGpus,
            &ReplanPolicy::Replan(ReplanParams::default()),
            LengthVariant::Original,
        )
        .unwrap();
        assert_eq!(rep.per_epoch.len(), 3);
        assert_eq!(rep.total_migrations, 0);
        let g0 = rep.per_epoch[0].gpus_used;
        assert!(rep.per_epoch.iter().all(|r| r.gpus_used == g0));
        assert!(rep.per_epoch.iter().all(|r| r.replanned));
    }

    #[test]
    fn static_policy_holds_one_placement() {
        let models = fake_models();
        let rep = run_epochs_on_twin(
            &Calibration::default(),
            &EngineConfig::default(),
            &burst_drift(),
            4,
            &models,
            &MinGpus,
            &ReplanPolicy::Static,
            LengthVariant::Original,
        )
        .unwrap();
        assert_eq!(rep.total_migrations, 0);
        let g0 = rep.per_epoch[0].gpus_used;
        assert!(g0 >= 2, "union burst workload must need >1 GPU, got {g0}");
        assert!(rep.per_epoch.iter().all(|r| r.gpus_used == g0));
    }

    #[test]
    fn replan_uses_fewer_gpu_epochs_than_static_under_churn() {
        let models = fake_models();
        let calib = Calibration::default();
        let base = EngineConfig::default();
        let drift = burst_drift();
        let stat = run_epochs_on_twin(
            &calib,
            &base,
            &drift,
            4,
            &models,
            &MinGpus,
            &ReplanPolicy::Static,
            LengthVariant::Original,
        )
        .unwrap();
        let repl = run_epochs_on_twin(
            &calib,
            &base,
            &drift,
            4,
            &models,
            &MinGpus,
            &ReplanPolicy::Replan(ReplanParams::default()),
            LengthVariant::Original,
        )
        .unwrap();
        let orac = run_epochs_on_twin(
            &calib,
            &base,
            &drift,
            4,
            &models,
            &MinGpus,
            &ReplanPolicy::Oracle(MigrationCost::default()),
            LengthVariant::Original,
        )
        .unwrap();
        // The burst retires after epoch 2: replanning must shed GPUs.
        assert!(
            repl.gpu_epochs < stat.gpu_epochs,
            "replan {} !< static {}",
            repl.gpu_epochs,
            stat.gpu_epochs
        );
        // The oracle is the per-epoch lower bound.
        assert!(orac.gpu_epochs <= repl.gpu_epochs);
        // Quiet epochs shrink to fewer GPUs than the burst epochs.
        assert!(repl.per_epoch[3].gpus_used < repl.per_epoch[0].gpus_used);
    }

    #[test]
    fn backlog_accounting_carries_across_epochs() {
        let models = fake_models();
        let rep = run_epochs_on_twin(
            &Calibration::default(),
            &EngineConfig::default(),
            &burst_drift(),
            4,
            &models,
            &MinGpus,
            &ReplanPolicy::Replan(ReplanParams::default()),
            LengthVariant::Original,
        )
        .unwrap();
        for w in rep.per_epoch.windows(2) {
            assert_eq!(
                w[1].carried_in_backlog_tokens.to_bits(),
                w[0].backlog_tokens.to_bits(),
                "backlog must be carried verbatim across the boundary"
            );
        }
        assert!(rep.per_epoch.iter().all(|r| r.backlog_tokens >= 0.0));
        assert_eq!(rep.final_backlog_tokens.to_bits(), rep.per_epoch[3].backlog_tokens.to_bits());
    }

    #[test]
    fn epoch_runner_works_on_engine_backend() {
        let models = fake_models();
        let drift = DriftSpec::steady(WorkloadSpec::homogeneous(4, 8, 0.2), 2, 2.0, 9);
        let base = EngineConfig::default();
        let missing = std::path::Path::new("/nonexistent");
        let make = || crate::runtime::load_backend(missing, "pico-llama");
        let rep = run_epochs_on_engine(
            &make,
            &base,
            &drift,
            2,
            &models,
            &MinGpus,
            &ReplanPolicy::Replan(ReplanParams::default()),
        )
        .unwrap();
        assert_eq!(rep.per_epoch.len(), 2);
        assert!(rep.per_epoch.iter().all(|r| r.planned));
    }

    #[test]
    fn min_latency_objective_keeps_the_cluster_spread() {
        use crate::placement::{Estimate, OracleEstimator};
        // An always-feasible estimator isolates the objective's shape from
        // any model behaviour; serving still runs on the real twin.
        let est = OracleEstimator::with_fallback(Estimate {
            throughput_tok_s: 500.0,
            starved: false,
            memory_error: false,
        });
        let calib = Calibration::default();
        let base = EngineConfig::default();
        let drift = DriftSpec::steady(WorkloadSpec::homogeneous(16, 8, 0.05), 3, 5.0, 5);
        let policy = ReplanPolicy::Replan(ReplanParams::default());
        let spread = run_epochs_on_twin(
            &calib,
            &base,
            &drift,
            4,
            &est,
            &MinLatency,
            &policy,
            LengthVariant::Original,
        )
        .unwrap();
        assert!(spread.per_epoch.iter().all(|r| r.gpus_used == 4), "MinLatency spreads");
        assert_eq!(spread.total_migrations, 0, "steady workload must not migrate");
        assert!(spread.mean_itl_s >= 0.0);
        let packed = run_epochs_on_twin(
            &calib,
            &base,
            &drift,
            4,
            &est,
            &MinGpus,
            &policy,
            LengthVariant::Original,
        )
        .unwrap();
        assert!(
            packed.gpu_epochs < spread.gpu_epochs,
            "MinGpus must provision fewer GPU-epochs: {} !< {}",
            packed.gpu_epochs,
            spread.gpu_epochs
        );
    }
}
