//! `adapterd` — CLI for the adapter-serving reproduction.
//!
//! Subcommands:
//!   serve            run one engine over a synthetic workload, print report
//!   twin             run the Digital Twin over the same kind of workload
//!   pipeline         the full typed pipeline in one shot:
//!                    calibrate → dataset → train → place → validate,
//!                    with per-stage artifact-cache status
//!   calibrate        run the DT parameterization suite, write calibration
//!   dataset          generate the DT training set
//!   train            train + persist the RF model pair
//!   place            compute a placement for a workload
//!   drift            rolling-horizon replanning demo (= `experiment drift`)
//!   experiment <id>  regenerate a paper table/figure (or `all`)
//!   list-experiments list experiment ids
//!   artifacts-info   show the AOT artifact manifest
//!
//! The per-stage subcommands (`calibrate`/`dataset`/`train`/`place`) are
//! thin wrappers over [`adapter_serving::pipeline::Pipeline`] and share
//! its content-hashed artifact store (`results/store/`), so any order of
//! invocation reuses whatever stages are already cached.

use adapter_serving::config::{EngineConfig, FleetSpec};
use adapter_serving::dt::{self, Calibration};
use adapter_serving::engine::Engine;
use adapter_serving::engine::metrics::ReportSchema;
use adapter_serving::experiments::{self, ExpContext};
use adapter_serving::ml;
use adapter_serving::pipeline::{EstimatorChoice, Pipeline, Scale};
use adapter_serving::placement::{plan, MinCost, MinGpus, MinLatency, Objective, Placement};
use adapter_serving::runtime::{self, Manifest};
use adapter_serving::util::cli::Args;
use adapter_serving::workload::WorkloadSpec;
use anyhow::{anyhow, Result};
use std::path::{Path, PathBuf};
use std::time::Instant;

const USAGE: &str = "usage: adapterd <serve|twin|pipeline|calibrate|dataset|train|place|drift|experiment|list-experiments|artifacts-info> [options]
common options:
  --model <pico-llama|pico-qwen>   backbone (default pico-llama)
  --adapters N --rank R --rate X   synthetic workload shape
  --a-max N --s-max-rank R         engine configuration
  --horizon S                      simulated seconds (default 15)
  --scale <quick|full>             pipeline/experiment scale (default quick)
  --gpus N                         GPU budget for place/pipeline (default 4)
  --fleet T:N[@$/hr],...           typed GPU fleet for pipeline (catalog types
                                   a10g|a100|h100, e.g. a10g:4,a100:2@3.50;
                                   implies DT-in-the-loop placement)
  --objective <min-gpus|min-latency|min-cost>  placement objective
                                   (default min-gpus; min-cost picks which
                                   fleet type to open by throughput per $)
  --estimator <ml|twin>            placement estimator for pipeline/place/
                                   drift (default ml; twin = DT-in-the-loop
                                   with a persistent probe cache)
  --core <lockstep|event>          serving core for drift horizons (default
                                   lockstep; event = continuous-batching
                                   event loop with SLO goodput + KV handoff)
  --out PATH                       output file/directory
values that start with '--' need the --key=VALUE form
environment:
  ADAPTER_SERVING_BACKEND=reference|pjrt   execution backend override
  ADAPTER_SERVING_ARTIFACTS=DIR            AOT artifact dir (default ./artifacts)";

fn main() -> Result<()> {
    let mut raw: Vec<String> = std::env::args().skip(1).collect();
    if raw.is_empty() {
        println!("{USAGE}");
        return Ok(());
    }
    let cmd = raw.remove(0);
    let args = Args::parse(raw, &["full", "unified", "fast"])?;
    match cmd.as_str() {
        "serve" => serve(&args, false),
        "twin" => serve(&args, true),
        "pipeline" => pipeline_cmd(&args),
        "calibrate" => calibrate_cmd(&args),
        "dataset" => dataset_cmd(&args),
        "train" => train_cmd(&args),
        "place" => place_cmd(&args),
        "drift" => drift_cmd(&args),
        "experiment" => experiment_cmd(&args),
        "list-experiments" => {
            for (id, desc, _) in experiments::REGISTRY {
                println!("{id:>8}  {desc}");
            }
            Ok(())
        }
        "artifacts-info" => artifacts_info(&args),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(anyhow!("unknown command '{other}'\n{USAGE}")),
    }
}

fn engine_config(args: &Args) -> Result<EngineConfig> {
    let mut cfg = EngineConfig {
        model: args.get_or("model", "pico-llama").to_string(),
        a_max: args.usize_or("a-max", 32)?,
        s_max_rank: args.usize_or("s-max-rank", 32)?,
        ..Default::default()
    };
    cfg.mem.unified = args.flag("unified");
    Ok(cfg)
}

fn workload(args: &Args) -> Result<WorkloadSpec> {
    let n = args.usize_or("adapters", 16)?;
    let rank = args.usize_or("rank", 8)?;
    let rate = args.f64_or("rate", 0.1)?;
    let horizon = args.f64_or("horizon", 15.0)?;
    let seed = args.usize_or("seed", 42)? as u64;
    Ok(WorkloadSpec::sharegpt_like(WorkloadSpec::homogeneous(n, rank, rate), horizon, seed))
}

/// The typed pipeline configured from the common CLI options.
fn pipeline_from(args: &Args) -> Result<Pipeline> {
    let model = args.get_or("model", "pico-llama").to_string();
    let scale =
        if args.flag("full") { Scale::Full } else { Scale::parse(args.get_or("scale", "quick")) };
    let mut pipe = Pipeline::for_model(&model)
        .scale(scale)
        .gpus(args.usize_or("gpus", 4)?)
        .fast_calibration(args.flag("fast") || scale.is_quick())
        .boxed_objective(objective_from(args)?);
    pipe = pipe.estimator(EstimatorChoice::parse(args.get_or("estimator", "ml"))?);
    if let Some(spec) = args.get("fleet") {
        pipe = pipe.fleet(FleetSpec::parse(spec)?);
    }
    // An explicit calibration file (e.g. a previous `calibrate --out`)
    // is injected and keys the downstream stages by content.
    if let Some(path) = args.get("calibration") {
        let model = args.get_or("model", "pico-llama");
        pipe = pipe.calibration(Calibration::load_file(Path::new(path), model)?);
    }
    Ok(pipe)
}

fn objective_from(args: &Args) -> Result<Box<dyn Objective>> {
    match args.get_or("objective", "min-gpus") {
        "min-gpus" | "min_gpus" => Ok(Box::new(MinGpus)),
        "min-latency" | "min_latency" => Ok(Box::new(MinLatency)),
        "min-cost" | "min_cost" => Ok(Box::new(MinCost)),
        other => Err(anyhow!("unknown --objective '{other}' (min-gpus|min-latency|min-cost)")),
    }
}

fn stage_line(name: &str, cached: bool) {
    println!("{name}: {}", if cached { "cache hit" } else { "computed" });
}

fn serve(args: &Args, twin: bool) -> Result<()> {
    let cfg = engine_config(args)?;
    let spec = workload(args)?;
    println!(
        "workload: {} adapters, {:.2} req/s total, {:.0} tok/s incoming; horizon {:.0}s",
        spec.adapters.len(),
        spec.total_rate(),
        spec.incoming_token_rate(),
        spec.horizon_s
    );
    if twin {
        let calib = load_or_default_calibration(args, &cfg.model)?;
        let res = dt::run_twin(&cfg, &calib, &spec, dt::LengthVariant::Original);
        match res.report {
            Some(r) => {
                let (iters, wall) = (res.iterations, res.wall_s);
                println!("twin: {} ({iters} iterations in {wall:.4}s)", r.summary())
            }
            None => println!("twin: MEMORY ERROR (A_max×S_max exceeds GPU memory)"),
        }
    } else {
        let mut rt = runtime::load_backend(&Manifest::default_dir(), &cfg.model)?;
        let mut engine = Engine::new(cfg, rt.as_mut());
        let res = engine.run(&spec)?;
        match res.report {
            Some(r) => println!("engine: {} (wall {:.2}s)", r.summary(), res.wall_s),
            None => println!("engine: MEMORY ERROR (A_max×S_max exceeds GPU memory)"),
        }
    }
    Ok(())
}

fn load_or_default_calibration(args: &Args, model: &str) -> Result<Calibration> {
    let path = PathBuf::from(
        args.get_or("calibration", &format!("results/calibration_{model}.json")),
    );
    if path.exists() {
        Calibration::load_file(&path, model)
    } else {
        eprintln!("note: {} not found; using built-in default calibration", path.display());
        Ok(Calibration::default())
    }
}

/// `adapterd pipeline` — the whole chain in one shot, with per-stage
/// artifact-cache status (the CI smoke asserts a second run is all
/// cache hits).
fn pipeline_cmd(args: &Args) -> Result<()> {
    // CLI progress timing only (detlint allowlists `main` for wall-clock).
    #[allow(clippy::disallowed_methods)]
    let t0 = Instant::now();
    let pipe = pipeline_from(args)?;
    let spec = workload(args)?;
    let fleet_mode = args.get("fleet").is_some();
    println!(
        "pipeline: {} adapters, {:.2} req/s total, {}, objective {}, estimator {}",
        spec.adapters.len(),
        spec.total_rate(),
        match args.get("fleet") {
            Some(f) => format!("fleet {f}"),
            None => format!("{} GPUs", args.usize_or("gpus", 4)?),
        },
        args.get_or("objective", "min-gpus"),
        if fleet_mode { "twin (fleet)" } else { args.get_or("estimator", "ml") },
    );
    let calibrated = pipe.calibrate()?;
    stage_line("calibrate", calibrated.cached);
    let placed = if fleet_mode || args.get_or("estimator", "ml") == "twin" {
        // The twin estimator consults the DT directly: the dataset and
        // training stages would be computed but never read, so skip them.
        let calibration = calibrated.calibration.clone();
        pipe.place_on_twin(&calibrated, &spec.adapters).map(|planned| (planned, calibration))
    } else {
        let dataset = pipe.dataset(&calibrated)?;
        stage_line("dataset", dataset.cached);
        let trained = pipe.train(&dataset)?;
        stage_line("train", trained.cached);
        pipe.place(&trained, &spec.adapters).map(|planned| (planned, trained.calibration))
    };
    match placed {
        Ok((planned, calibration)) => {
            // Per-type calibration status (the fleet CI smoke requires a
            // second run to hit every class's artifact).
            if let Some(f) = &planned.fleet {
                for tc in &f.calibrations {
                    stage_line(&format!("calibrate[{}]", tc.name), tc.cached);
                }
            }
            // DT-in-the-loop probe cache status (mirrors the per-stage
            // lines; the CI smoke requires a second run to warm-start).
            if let Some(s) = planned.probe_cache {
                if s.misses == 0 {
                    println!("probes: cache hit ({} memos warm-started, {} hits)", s.warm, s.hits);
                } else {
                    println!(
                        "probes: computed ({} DT simulations, {} hits, {} warm-started)",
                        s.misses, s.hits, s.warm
                    );
                }
            }
            println!(
                "place: {} / {} GPUs (objective {}, estimator {})",
                planned.placement.gpus_used(),
                planned.gpus,
                planned.objective,
                planned.estimator
            );
            if let Some(f) = &planned.fleet {
                let mix: Vec<String> = f
                    .spec
                    .types
                    .iter()
                    .zip(&f.used_by_type)
                    .filter(|&(_, &n)| n > 0)
                    .map(|(ty, &n)| format!("{}x{n}", ty.name))
                    .collect();
                println!("fleet: {} at ${:.2}/hr", mix.join(" + "), f.cost_per_hour);
            }
            let validated = pipe.validate_with(&calibration, &planned, &spec)?;
            let backend = if validated.on_engine { "engine" } else { "twin" };
            println!(
                "validate ({backend}): {:.0} tok/s, itl {:.2} ms, goodput {:.2} req/s \
                 ({:.0}% SLO), feasible={}",
                validated.report.total_throughput_tok_s,
                ReportSchema::ms_from_s(validated.report.itl_mean_s),
                validated.report.goodput_req_s,
                100.0 * validated.report.slo_attainment,
                validated.report.feasible()
            );
        }
        Err(e) => println!("place: infeasible ({e})"),
    }
    println!("pipeline done in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}

fn calibrate_cmd(args: &Args) -> Result<()> {
    // The fast/full choice follows the shared rule in `pipeline_from`
    // (quick scale or --fast ⇒ fast suite), so a calibration produced
    // here is keyed identically to what `dataset`/`train`/`pipeline`
    // will look up — any order of invocation reuses the store.  The
    // full suite runs under `--scale full`.
    let pipe = pipeline_from(args)?;
    let calibrated = pipe.calibrate()?;
    stage_line("calibrate", calibrated.cached);
    let model = args.get_or("model", "pico-llama");
    let out = PathBuf::from(args.get_or("out", &format!("results/calibration_{model}.json")));
    calibrated.calibration.to_json().write_file(&out)?;
    println!("wrote {}", out.display());
    Ok(())
}

fn dataset_cmd(args: &Args) -> Result<()> {
    let pipe = pipeline_from(args)?;
    let calibrated = pipe.calibrate()?;
    stage_line("calibrate", calibrated.cached);
    let dataset = pipe.dataset(&calibrated)?;
    stage_line("dataset", dataset.cached);
    let model = args.get_or("model", "pico-llama");
    let out = PathBuf::from(args.get_or("out", &format!("results/dataset_{model}.csv")));
    ml::dataset::save(&dataset.samples, &out)?;
    let starved = dataset.samples.iter().filter(|s| s.starved).count();
    println!("wrote {} samples ({starved} starved) to {}", dataset.samples.len(), out.display());
    Ok(())
}

fn train_cmd(args: &Args) -> Result<()> {
    let model = args.get_or("model", "pico-llama").to_string();
    let out = PathBuf::from(args.get_or("out", &format!("results/models_{model}.json")));
    if let Some(ds) = args.get("dataset") {
        // Explicit dataset file: train on it directly, bypassing the store.
        let samples = ml::dataset::load(Path::new(ds))?;
        let quick = !args.flag("full");
        let rf = ml::ModelType::RandomForest;
        let (thr, s1) = ml::train(&samples, ml::Task::Throughput, rf, quick, 7);
        let (st, s2) = ml::train(&samples, ml::Task::Starvation, rf, quick, 7);
        println!("RF throughput cv-score {s1:.2}; starvation macro-F1 {s2:.3}");
        ml::save_models(&ml::MlModels { throughput: thr, starvation: st, scaler: None }, &out)?;
        println!("wrote {}", out.display());
        return Ok(());
    }
    let pipe = pipeline_from(args)?;
    let calibrated = pipe.calibrate()?;
    stage_line("calibrate", calibrated.cached);
    let dataset = pipe.dataset(&calibrated)?;
    stage_line("dataset", dataset.cached);
    let trained = pipe.train(&dataset)?;
    stage_line("train", trained.cached);
    ml::save_models(&trained.models, &out)?;
    println!("wrote {}", out.display());
    Ok(())
}

fn place_cmd(args: &Args) -> Result<()> {
    let spec = workload(args)?;
    let gpus = args.usize_or("gpus", 4)?;
    let result: Result<Placement> = if let Some(mp) = args.get("models") {
        // An explicit pre-trained pair (e.g. exported by `adapterd train`);
        // a missing file is an error, not a silent pipeline run.  This
        // path is the ML estimator by definition (the file *is* the ML
        // model pair), so --estimator is rejected rather than ignored.
        if args.get("estimator").is_some() {
            return Err(anyhow!("--models and --estimator are mutually exclusive"));
        }
        let models = ml::load_models(Path::new(mp))?;
        let objective = objective_from(args)?;
        plan(&spec.adapters, gpus, &models, objective.as_ref()).map_err(anyhow::Error::from)
    } else {
        // Otherwise drive the pipeline; cached stages are reused, and the
        // twin estimator skips the ML stages it never consults.
        let pipe = pipeline_from(args)?;
        let calibrated = pipe.calibrate()?;
        if args.get_or("estimator", "ml") == "twin" {
            pipe.place_on_twin(&calibrated, &spec.adapters).map(|p| p.placement)
        } else {
            let dataset = pipe.dataset(&calibrated)?;
            let trained = pipe.train(&dataset)?;
            pipe.place(&trained, &spec.adapters).map(|p| p.placement)
        }
    };
    match result {
        Ok(p) => {
            println!("placement uses {} / {gpus} GPUs", p.gpus_used());
            for g in 0..gpus {
                let on = p.adapters_on(g);
                if !on.is_empty() {
                    println!("  gpu{g}: {} adapters, A_max={}", on.len(), p.a_max[g]);
                }
            }
        }
        Err(e) => println!("placement failed: {e}"),
    }
    Ok(())
}

/// `adapterd drift` — the rolling-horizon re-placement loop on a churn
/// workload (shorthand for `adapterd experiment drift`); `--estimator
/// twin` plans DT-in-the-loop through the persistent probe cache.
fn drift_cmd(args: &Args) -> Result<()> {
    experiments::run("drift", &ExpContext::from_args(args)?)
}

fn experiment_cmd(args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .ok_or_else(|| anyhow!("experiment id required (or 'all')"))?;
    experiments::run(id, &ExpContext::from_args(args)?)
}

fn artifacts_info(args: &Args) -> Result<()> {
    let dir = args.get("artifacts").map(PathBuf::from).unwrap_or_else(Manifest::default_dir);
    let m = Manifest::load(Path::new(&dir))?;
    for (name, meta) in &m.models {
        println!(
            "{name}: d={} L={} heads={} window={} slots={} decode buckets {:?} prefill {:?} (pallas={})",
            meta.d_model,
            meta.n_layers,
            meta.n_heads,
            meta.window,
            meta.slots,
            meta.decode_buckets,
            meta.prefill_buckets,
            meta.use_pallas
        );
    }
    Ok(())
}
