//! `adapterd` — CLI for the adapter-serving reproduction.
//!
//! Subcommands:
//!   serve            run one engine over a synthetic workload, print report
//!   twin             run the Digital Twin over the same kind of workload
//!   calibrate        run the DT parameterization suite, write calibration
//!   dataset          generate the DT training set
//!   train            train + persist the RF model pair
//!   place            compute a placement for a workload (greedy pipeline)
//!   drift            rolling-horizon replanning demo (= `experiment drift`)
//!   experiment <id>  regenerate a paper table/figure (or `all`)
//!   list-experiments list experiment ids
//!   artifacts-info   show the AOT artifact manifest

use adapter_serving::config::EngineConfig;
use adapter_serving::dt::{self, Calibration};
use adapter_serving::engine::Engine;
use adapter_serving::experiments::{self, ExpContext, Scale};
use adapter_serving::ml;
use adapter_serving::placement::greedy;
use adapter_serving::runtime::{self, Manifest};
use adapter_serving::util::cli::Args;
use adapter_serving::workload::WorkloadSpec;
use anyhow::{anyhow, Result};
use std::path::{Path, PathBuf};

const USAGE: &str = "usage: adapterd <serve|twin|calibrate|dataset|train|place|drift|experiment|list-experiments|artifacts-info> [options]
common options:
  --model <pico-llama|pico-qwen>   backbone (default pico-llama)
  --adapters N --rank R --rate X   synthetic workload shape
  --a-max N --s-max-rank R         engine configuration
  --horizon S                      simulated seconds (default 15)
  --scale <quick|full>             experiment scale (default quick)
  --out PATH                       output file/directory
environment:
  ADAPTER_SERVING_BACKEND=reference|pjrt   execution backend override
  ADAPTER_SERVING_ARTIFACTS=DIR            AOT artifact dir (default ./artifacts)";

fn main() -> Result<()> {
    let mut raw: Vec<String> = std::env::args().skip(1).collect();
    if raw.is_empty() {
        println!("{USAGE}");
        return Ok(());
    }
    let cmd = raw.remove(0);
    let args = Args::parse(raw, &["full", "unified", "fast"]);
    match cmd.as_str() {
        "serve" => serve(&args, false),
        "twin" => serve(&args, true),
        "calibrate" => calibrate_cmd(&args),
        "dataset" => dataset_cmd(&args),
        "train" => train_cmd(&args),
        "place" => place_cmd(&args),
        "drift" => drift_cmd(&args),
        "experiment" => experiment_cmd(&args),
        "list-experiments" => {
            for (id, desc, _) in experiments::REGISTRY {
                println!("{id:>8}  {desc}");
            }
            Ok(())
        }
        "artifacts-info" => artifacts_info(&args),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(anyhow!("unknown command '{other}'\n{USAGE}")),
    }
}

fn engine_config(args: &Args) -> Result<EngineConfig> {
    let mut cfg = EngineConfig {
        model: args.get_or("model", "pico-llama").to_string(),
        a_max: args.usize_or("a-max", 32)?,
        s_max_rank: args.usize_or("s-max-rank", 32)?,
        ..Default::default()
    };
    cfg.mem.unified = args.flag("unified");
    Ok(cfg)
}

fn workload(args: &Args) -> Result<WorkloadSpec> {
    let n = args.usize_or("adapters", 16)?;
    let rank = args.usize_or("rank", 8)?;
    let rate = args.f64_or("rate", 0.1)?;
    let horizon = args.f64_or("horizon", 15.0)?;
    let seed = args.usize_or("seed", 42)? as u64;
    Ok(WorkloadSpec::sharegpt_like(WorkloadSpec::homogeneous(n, rank, rate), horizon, seed))
}

fn serve(args: &Args, twin: bool) -> Result<()> {
    let cfg = engine_config(args)?;
    let spec = workload(args)?;
    println!(
        "workload: {} adapters, {:.2} req/s total, {:.0} tok/s incoming; horizon {:.0}s",
        spec.adapters.len(),
        spec.total_rate(),
        spec.incoming_token_rate(),
        spec.horizon_s
    );
    if twin {
        let calib = load_or_default_calibration(args, &cfg.model)?;
        let res = dt::run_twin(&cfg, &calib, &spec, dt::LengthVariant::Original);
        match res.report {
            Some(r) => {
                let (iters, wall) = (res.iterations, res.wall_s);
                println!("twin: {} ({iters} iterations in {wall:.4}s)", r.summary())
            }
            None => println!("twin: MEMORY ERROR (A_max×S_max exceeds GPU memory)"),
        }
    } else {
        let mut rt = runtime::load_backend(&Manifest::default_dir(), &cfg.model)?;
        let mut engine = Engine::new(cfg, rt.as_mut());
        let res = engine.run(&spec)?;
        match res.report {
            Some(r) => println!("engine: {} (wall {:.2}s)", r.summary(), res.wall_s),
            None => println!("engine: MEMORY ERROR (A_max×S_max exceeds GPU memory)"),
        }
    }
    Ok(())
}

fn load_or_default_calibration(args: &Args, model: &str) -> Result<Calibration> {
    let path = PathBuf::from(
        args.get_or("calibration", &format!("results/calibration_{model}.json")),
    );
    if path.exists() {
        Calibration::load_file(&path, model)
    } else {
        eprintln!("note: {} not found; using built-in default calibration", path.display());
        Ok(Calibration::default())
    }
}

fn calibrate_cmd(args: &Args) -> Result<()> {
    let model = args.get_or("model", "pico-llama").to_string();
    let out = PathBuf::from(args.get_or("out", &format!("results/calibration_{model}.json")));
    let mut rt = runtime::load_backend(&Manifest::default_dir(), &model)?;
    let cfg = EngineConfig { model: model.clone(), ..Default::default() };
    let calib = dt::calibrate(rt.as_mut(), &cfg, args.flag("fast"))?;
    calib.to_json().write_file(&out)?;
    println!("wrote {}", out.display());
    Ok(())
}

fn dataset_cmd(args: &Args) -> Result<()> {
    let model = args.get_or("model", "pico-llama").to_string();
    let calib = load_or_default_calibration(args, &model)?;
    let out = PathBuf::from(args.get_or("out", &format!("results/dataset_{model}.csv")));
    let quick = !args.flag("full");
    let grid = ml::GridSpec::paper(quick);
    let base = EngineConfig { model, ..Default::default() };
    let samples = ml::dataset::generate(
        &calib,
        &base,
        &grid,
        adapter_serving::util::threadpool::default_workers(),
    );
    ml::dataset::save(&samples, &out)?;
    let starved = samples.iter().filter(|s| s.starved).count();
    println!("wrote {} samples ({starved} starved) to {}", samples.len(), out.display());
    Ok(())
}

fn train_cmd(args: &Args) -> Result<()> {
    let model = args.get_or("model", "pico-llama").to_string();
    let ds_path = PathBuf::from(args.get_or("dataset", &format!("results/dataset_{model}.csv")));
    let out = PathBuf::from(args.get_or("out", &format!("results/models_{model}.json")));
    let samples = ml::dataset::load(&ds_path)?;
    let quick = !args.flag("full");
    let (thr, s1) =
        ml::train(&samples, ml::Task::Throughput, ml::ModelType::RandomForest, quick, 7);
    let (st, s2) = ml::train(&samples, ml::Task::Starvation, ml::ModelType::RandomForest, quick, 7);
    println!("RF throughput cv-score {s1:.2}; starvation macro-F1 {s2:.3}");
    ml::save_models(&ml::MlModels { throughput: thr, starvation: st, scaler: None }, &out)?;
    println!("wrote {}", out.display());
    Ok(())
}

fn place_cmd(args: &Args) -> Result<()> {
    let model = args.get_or("model", "pico-llama").to_string();
    let models_path =
        PathBuf::from(args.get_or("models", &format!("results/models_{model}.json")));
    let models = ml::load_models(&models_path)?;
    let gpus = args.usize_or("gpus", 4)?;
    let spec = workload(args)?;
    match greedy::place(&spec.adapters, gpus, &models) {
        Ok(p) => {
            println!("placement uses {} / {gpus} GPUs", p.gpus_used());
            for g in 0..gpus {
                let on = p.adapters_on(g);
                if !on.is_empty() {
                    println!("  gpu{g}: {} adapters, A_max={}", on.len(), p.a_max[g]);
                }
            }
        }
        Err(e) => println!("placement failed: {e}"),
    }
    Ok(())
}

/// `adapterd drift` — the rolling-horizon re-placement loop on a churn
/// workload (shorthand for `adapterd experiment drift`).
fn drift_cmd(args: &Args) -> Result<()> {
    let mut ctx = ExpContext::new(Scale::parse(args.get_or("scale", "quick")));
    if let Some(out) = args.get("out") {
        ctx.out_dir = PathBuf::from(out);
    }
    if let Some(m) = args.get("model") {
        ctx.models = vec![m.to_string()];
    }
    experiments::run("drift", &ctx)
}

fn experiment_cmd(args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .ok_or_else(|| anyhow!("experiment id required (or 'all')"))?;
    let mut ctx = ExpContext::new(Scale::parse(args.get_or("scale", "quick")));
    if let Some(out) = args.get("out") {
        ctx.out_dir = PathBuf::from(out);
    }
    if let Some(m) = args.get("model") {
        ctx.models = vec![m.to_string()];
    }
    experiments::run(id, &ctx)
}

fn artifacts_info(args: &Args) -> Result<()> {
    let dir = args.get("artifacts").map(PathBuf::from).unwrap_or_else(Manifest::default_dir);
    let m = Manifest::load(Path::new(&dir))?;
    for (name, meta) in &m.models {
        println!(
            "{name}: d={} L={} heads={} window={} slots={} decode buckets {:?} prefill {:?} (pallas={})",
            meta.d_model,
            meta.n_layers,
            meta.n_heads,
            meta.window,
            meta.slots,
            meta.decode_buckets,
            meta.prefill_buckets,
            meta.use_pallas
        );
    }
    Ok(())
}
