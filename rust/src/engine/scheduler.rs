//! vLLM-style continuous-batching scheduler: FCFS admission scan with
//! adapter-awareness, greedy KV reservation and latest-first preemption.
//!
//! This module is *pure policy* over the simulated state ([`KvLedger`] +
//! [`SimAdapterCache`] + request table), shared verbatim by the serving
//! engine and the Digital Twin: the paper's DT reproduces vLLM's scheduler
//! logic structurally, and fidelity error comes from latency prediction,
//! not divergent policies (§5, Fig. 3).
//!
//! The admission scan mirrors the vLLM behaviour the paper profiles in
//! §5.1.4 / Fig. 7: the scheduler walks the *entire* pending queue looking
//! for requests whose adapters are loaded (or loadable under `A_max`),
//! so its cost grows with the pending count and with the fraction of
//! pending requests whose adapters are not resident.

use super::adapter_cache::{LoadEvent, SimAdapterCache};
use super::kv::KvLedger;
use super::request::{ReqState, Request};
use std::collections::VecDeque;

/// Limits for one admission round.
#[derive(Debug, Clone, Copy)]
pub struct AdmissionLimits {
    /// Cap on requests in the running batch (min of max_num_seqs and the
    /// largest compiled decode bucket).
    pub max_running: usize,
    /// Cap on prompt tokens admitted per iteration (vLLM
    /// max_num_batched_tokens analog).
    pub max_prefill_tokens: usize,
    /// S-LoRA unified memory mode: adapter loads charge the KV pool.
    pub unified: bool,
}

/// Result of one admission scan.
#[derive(Debug, Default)]
pub struct AdmissionResult {
    /// Request ids admitted this round (now Prefilling, KV reserved).
    pub admitted: Vec<usize>,
    /// Swap-ins triggered by admissions.
    pub loads: Vec<LoadEvent>,
    /// How many waiting entries the scan visited (scheduler-cost model
    /// input: the paper's R_P · A_B/A term).
    pub scanned: usize,
}

/// Scan the waiting queue in FCFS order, admitting every eligible request
/// until the running cap or the prefill-token budget is hit.  Ineligible
/// requests (adapter not admissible, or KV blocks unavailable) are skipped
/// but remain queued in order — this is the scan vLLM pays for (§5.1.4).
pub fn scan_admissions(
    waiting: &mut VecDeque<usize>,
    requests: &mut [Request],
    ledger: &mut KvLedger,
    cache: &mut SimAdapterCache,
    active_now: usize,
    limits: AdmissionLimits,
) -> AdmissionResult {
    let mut res = AdmissionResult::default();
    let mut active = active_now;
    let mut prefill_tokens = 0usize;
    let mut keep: VecDeque<usize> = VecDeque::with_capacity(waiting.len());

    while let Some(id) = waiting.pop_front() {
        res.scanned += 1;
        // detlint: allow(panic-path) — `requests` is the request arena; ids are indices it issued itself
        let r = &requests[id];
        debug_assert_eq!(r.state, ReqState::Waiting);
        if active >= limits.max_running
            || prefill_tokens + r.input_len + r.generated > limits.max_prefill_tokens
        {
            keep.push_back(id);
            continue;
        }
        // Adapter admissibility under A_max (rank 0 = backbone-only).
        let mut evicted = Vec::new();
        let load = if r.rank == 0 {
            Some(None)
        } else {
            cache.acquire(r.adapter_id, r.rank, &mut evicted)
        };
        let Some(load) = load else {
            keep.push_back(id);
            continue;
        };
        // Unified mode: eviction releases pool; load charges it.
        if limits.unified {
            for (_, rank) in &evicted {
                ledger.release_adapter(*rank);
            }
            if load.is_some() && !ledger.charge_adapter(r.rank) {
                // Cannot fit adapter weights: back out the acquire.
                cache.release(r.adapter_id);
                keep.push_back(id);
                continue;
            }
        }
        // Greedy KV reservation for the prompt (+ regenerated suffix).
        let tokens = r.input_len + r.generated;
        if !ledger.grow_to(id, tokens.max(1)) {
            if r.rank > 0 {
                cache.release(r.adapter_id);
            }
            if limits.unified && load.is_some() {
                ledger.release_adapter(r.rank);
            }
            keep.push_back(id);
            continue;
        }
        // Admitted.
        // detlint: allow(panic-path) — `requests` is the request arena; ids are indices it issued itself
        requests[id].state = ReqState::Prefilling;
        requests[id].context_len = tokens;
        prefill_tokens += tokens;
        active += 1;
        if let Some(ev) = load {
            res.loads.push(ev);
        }
        res.admitted.push(id);
    }
    *waiting = keep;
    res
}

/// Ensure every running request can grow by one token, preempting
/// latest-admitted requests (vLLM recompute preemption) until it fits.
/// Returns the preempted ids (moved back to Waiting, KV released).
pub fn grow_or_preempt(
    running: &mut Vec<usize>,
    requests: &mut [Request],
    ledger: &mut KvLedger,
    cache: &mut SimAdapterCache,
    unified: bool,
) -> Vec<usize> {
    let mut preempted = Vec::new();
    let mut i = 0;
    while i < running.len() {
        // detlint: allow(panic-path) — `requests`/`running` and its index are constructed together; in range by construction
        let id = running[i];
        let need = requests[id].context_len + 1;
        if ledger.grow_to(id, need) {
            i += 1;
            continue;
        }
        // Preempt the most recently admitted *other* request; if this
        // request is the only one left, preempt it instead.
        let victim_pos = match running.last() {
            Some(&last) if running.len() > 1 && last != id => running.len() - 1,
            _ => i,
        };
        let victim = running.remove(victim_pos);
        // detlint: allow(panic-path) — `requests` is the request arena; ids are indices it issued itself
        let v = &mut requests[victim];
        v.state = ReqState::Waiting;
        v.preemptions += 1;
        v.kv.clear();
        ledger.release(victim);
        if v.rank > 0 {
            cache.release(v.adapter_id);
        }
        let _ = unified; // adapter weights stay resident until evicted by LRU
        preempted.push(victim);
        if victim_pos == i {
            // We removed the current request; don't advance.
            continue;
        }
    }
    preempted
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MemoryConfig;

    fn mk_requests(n: usize, input: usize, rank: usize) -> Vec<Request> {
        (0..n).map(|i| Request::new(i, i, rank, 0.0, input, 8)).collect()
    }

    fn mk_ledger(tokens: usize) -> KvLedger {
        KvLedger::new(MemoryConfig { total_tokens: tokens, ..Default::default() }, tokens)
    }

    fn limits(max_running: usize) -> AdmissionLimits {
        AdmissionLimits { max_running, max_prefill_tokens: 10_000, unified: false }
    }

    #[test]
    fn admits_fcfs_until_batch_full() {
        let mut reqs = mk_requests(5, 16, 8);
        let mut waiting: VecDeque<usize> = (0..5).collect();
        let mut ledger = mk_ledger(10_000);
        let mut cache = SimAdapterCache::new(100);
        let res = scan_admissions(&mut waiting, &mut reqs, &mut ledger, &mut cache, 0, limits(3));
        assert_eq!(res.admitted, vec![0, 1, 2]);
        assert_eq!(res.scanned, 5);
        assert_eq!(waiting, VecDeque::from(vec![3, 4]));
        assert_eq!(res.loads.len(), 3);
    }

    #[test]
    fn skips_requests_with_inadmissible_adapters() {
        // A_max = 1 and adapter 0 busy → requests for other adapters skipped,
        // but later requests for adapter 0 still admitted (the Fig. 7 scan).
        let mut reqs = mk_requests(4, 16, 8);
        reqs[3].adapter_id = 0;
        let mut waiting: VecDeque<usize> = (0..4).collect();
        let mut ledger = mk_ledger(10_000);
        let mut cache = SimAdapterCache::new(1);
        let res = scan_admissions(&mut waiting, &mut reqs, &mut ledger, &mut cache, 0, limits(8));
        assert_eq!(res.admitted, vec![0, 3]);
        assert_eq!(waiting, VecDeque::from(vec![1, 2]));
        assert_eq!(res.scanned, 4);
    }

    #[test]
    fn kv_exhaustion_blocks_admission() {
        let mut reqs = mk_requests(3, 64, 8);
        let mut waiting: VecDeque<usize> = (0..3).collect();
        let mut ledger = mk_ledger(128); // 8 blocks; each prompt needs 4
        let mut cache = SimAdapterCache::new(10);
        let res = scan_admissions(&mut waiting, &mut reqs, &mut ledger, &mut cache, 0, limits(8));
        assert_eq!(res.admitted, vec![0, 1]);
        assert_eq!(waiting, VecDeque::from(vec![2]));
        // The blocked request's adapter acquire must have been rolled back.
        assert_eq!(cache.active_count(2), 0);
    }

    #[test]
    fn backbone_only_requests_skip_adapter_cache() {
        let mut reqs = mk_requests(2, 16, 0);
        let mut waiting: VecDeque<usize> = (0..2).collect();
        let mut ledger = mk_ledger(10_000);
        let mut cache = SimAdapterCache::new(0); // no adapters allowed at all
        let res = scan_admissions(&mut waiting, &mut reqs, &mut ledger, &mut cache, 0, limits(8));
        assert_eq!(res.admitted, vec![0, 1]);
        assert!(res.loads.is_empty());
    }

    #[test]
    fn preempts_latest_first() {
        let mut reqs = mk_requests(3, 16, 8);
        for r in reqs.iter_mut() {
            r.state = ReqState::Running;
        }
        // Pool of 3 blocks of 16; all three at one block each, full.
        let mut ledger = mk_ledger(48);
        for id in 0..3 {
            assert!(ledger.grow_to(id, 16));
        }
        let mut cache = SimAdapterCache::new(10);
        let mut evicted = Vec::new();
        for id in 0..3 {
            cache.acquire(id, 8, &mut evicted);
        }
        let mut running = vec![0, 1, 2];
        // Everyone wants one more token → needs a new block each; only
        // preemption can free space.
        for r in reqs.iter_mut() {
            r.context_len = 16;
        }
        let pre = grow_or_preempt(&mut running, &mut reqs, &mut ledger, &mut cache, false);
        // 3 blocks for 3 requests that now need 2 each: preempting 2 frees a
        // block for 0, then 1 must preempt itself — only 0 survives.
        assert_eq!(pre, vec![2, 1]);
        assert_eq!(running, vec![0]);
        assert_eq!(reqs[2].state, ReqState::Waiting);
        assert_eq!(reqs[2].preemptions, 1);
        assert_eq!(ledger.held_blocks(2), 0);
        assert_eq!(ledger.held_blocks(0), 2);
    }

    #[test]
    fn unified_mode_charges_pool_for_loads() {
        let mut reqs = mk_requests(2, 16, 32);
        let mut waiting: VecDeque<usize> = (0..2).collect();
        // 160 tokens = 10 blocks; one rank-32 adapter charges 8 blocks.
        let mut ledger = mk_ledger(160);
        let mut cache = SimAdapterCache::new(10);
        let lims = AdmissionLimits { max_running: 8, max_prefill_tokens: 10_000, unified: true };
        let res = scan_admissions(&mut waiting, &mut reqs, &mut ledger, &mut cache, 0, lims);
        // First adapter: 8 blocks + 1 block prompt = 9; second can't fit.
        assert_eq!(res.admitted, vec![0]);
        assert_eq!(waiting.len(), 1);
    }
}
