//! Request state machine for the continuous-batching loop.

use super::kv::RequestKv;

/// Lifecycle state of one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReqState {
    /// In the waiting queue (never scheduled, or preempted).
    Waiting,
    /// Admitted; prompt not yet processed.
    Prefilling,
    /// In the running batch, generating tokens.
    Running,
    /// Generation budget reached; metrics recorded.
    Finished,
}

/// One request flowing through the continuous-batching loop.
#[derive(Debug, Clone)]
pub struct Request {
    /// Request id (= position in the arrival trace).
    pub id: usize,
    /// The adapter this request targets.
    pub adapter_id: usize,
    /// The adapter's LoRA rank (0 = backbone-only request).
    pub rank: usize,
    /// Arrival time (simulated seconds).
    pub arrival_s: f64,
    /// Prompt length (tokens).
    pub input_len: usize,
    /// Target number of generated tokens (benchmark-style fixed budget,
    /// vLLM `ignore_eos`; the paper's traces fix output lengths the same way).
    pub output_len: usize,
    /// Current lifecycle state.
    pub state: ReqState,
    /// Tokens currently represented in (simulated and host) KV.
    pub context_len: usize,
    /// Tokens generated so far.
    pub generated: usize,
    /// Most recent token (decode input for the next step).
    pub last_token: i32,
    /// Simulated time the first token was produced, once known.
    pub first_token_s: Option<f64>,
    /// Sim-time stamps of generated tokens (ITL = successive diffs).
    pub token_times: Vec<f64>,
    /// Simulated finish time, once finished.
    pub finish_s: Option<f64>,
    /// Times this request was preempted.
    pub preemptions: usize,
    /// The request's real host-side KV pages.
    pub kv: RequestKv,
}

impl Request {
    /// A fresh request in the `Waiting` state.
    pub fn new(
        id: usize,
        adapter_id: usize,
        rank: usize,
        arrival_s: f64,
        input_len: usize,
        output_len: usize,
    ) -> Request {
        Request {
            id,
            adapter_id,
            rank,
            arrival_s,
            input_len,
            output_len,
            state: ReqState::Waiting,
            context_len: 0,
            generated: 0,
            last_token: 0,
            first_token_s: None,
            token_times: Vec::new(),
            finish_s: None,
            preemptions: 0,
            kv: RequestKv::default(),
        }
    }

    /// Prompt tokens for (re-)prefill: deterministic pseudo-tokens derived
    /// from the request id.  On re-prefill after preemption this includes
    /// the already-generated tokens (vLLM recompute semantics).
    pub fn prompt_tokens(&self, vocab: usize, max_len: usize) -> Vec<i32> {
        let total = self.input_len + self.generated;
        let take = total.min(max_len);
        let start = total - take;
        (start..total)
            .map(|i| ((self.id.wrapping_mul(1_000_003) + i * 7919) % vocab) as i32)
            .collect()
    }

    /// Whether the generation budget has been reached.
    pub fn is_done(&self) -> bool {
        self.generated >= self.output_len
    }

    /// Mean inter-token latency over generated tokens (s).
    pub fn itl_mean(&self) -> Option<f64> {
        if self.token_times.len() < 2 {
            return None;
        }
        let d: f64 = self.token_times.windows(2).map(|w| w[1] - w[0]).sum();
        Some(d / (self.token_times.len() - 1) as f64)
    }

    /// Time to first token (s), once the first token exists.
    pub fn ttft(&self) -> Option<f64> {
        self.first_token_s.map(|t| t - self.arrival_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prompt_tokens_deterministic_and_bounded() {
        let r = Request::new(7, 1, 8, 0.0, 50, 10);
        let a = r.prompt_tokens(512, 256);
        let b = r.prompt_tokens(512, 256);
        assert_eq!(a, b);
        assert_eq!(a.len(), 50);
        assert!(a.iter().all(|&t| (0..512).contains(&t)));
    }

    #[test]
    fn preempted_prompt_includes_generated_suffix() {
        let mut r = Request::new(7, 1, 8, 0.0, 50, 10);
        r.generated = 5;
        assert_eq!(r.prompt_tokens(512, 256).len(), 55);
        // Clipped to max_len keeping the *last* tokens (window semantics).
        assert_eq!(r.prompt_tokens(512, 32).len(), 32);
    }

    #[test]
    fn itl_and_ttft() {
        let mut r = Request::new(1, 0, 8, 10.0, 4, 3);
        r.first_token_s = Some(10.5);
        r.token_times = vec![10.5, 10.7, 11.1];
        assert!((r.ttft().unwrap() - 0.5).abs() < 1e-12);
        assert!((r.itl_mean().unwrap() - 0.3).abs() < 1e-12);
    }
}
