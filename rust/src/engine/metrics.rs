//! Metrics collection shared by the engine and the Digital Twin so reports
//! are directly comparable (Table 1 / Figs. 8-9).

use crate::util::stats;

/// A periodic sample of queue state (Fig. 9 right panel).
#[derive(Debug, Clone, Copy)]
pub struct QueueSample {
    pub time_s: f64,
    pub running: usize,
    pub waiting: usize,
}

/// Accumulates serving metrics over one run.
#[derive(Debug, Clone, Default)]
pub struct MetricsCollector {
    /// Tokens that *arrived* (input + expected output of injected requests).
    /// The starvation criterion compares throughput against the realized
    /// incoming rate, not the configured one, so short horizons with
    /// Poisson variance do not mislabel feasible workloads.
    pub arrived_tokens: usize,
    pub input_tokens: usize,
    pub output_tokens: usize,
    pub completed: usize,
    pub preemptions: usize,
    pub swap_ins: usize,
    pub ttfts: Vec<f64>,
    pub itls: Vec<f64>,
    pub queue_trace: Vec<QueueSample>,
    /// Throughput measured per time bucket (for time-series plots).
    pub token_stamps: Vec<(f64, usize)>,
}

impl MetricsCollector {
    pub fn on_arrival(&mut self, input_len: usize, output_len: usize) {
        self.arrived_tokens += input_len + output_len;
    }

    pub fn on_prefill(&mut self, input_len: usize, time_s: f64) {
        self.input_tokens += input_len;
        self.token_stamps.push((time_s, input_len));
    }

    pub fn on_decode_tokens(&mut self, n: usize, time_s: f64) {
        self.output_tokens += n;
        self.token_stamps.push((time_s, n));
    }

    pub fn on_finish(&mut self, ttft: Option<f64>, itl: Option<f64>) {
        self.completed += 1;
        if let Some(t) = ttft {
            self.ttfts.push(t);
        }
        if let Some(i) = itl {
            self.itls.push(i);
        }
    }

    pub fn sample_queues(&mut self, time_s: f64, running: usize, waiting: usize) {
        self.queue_trace.push(QueueSample { time_s, running, waiting });
    }

    pub fn report(&self, horizon_s: f64, configured_rate: f64) -> Report {
        let total = self.input_tokens + self.output_tokens;
        let throughput = total as f64 / horizon_s;
        let realized = self.arrived_tokens as f64 / horizon_s;
        // Fall back to the configured rate when arrivals were not recorded.
        let incoming_token_rate = if self.arrived_tokens > 0 { realized } else { configured_rate };
        Report {
            throughput_tok_s: throughput,
            input_tokens: self.input_tokens,
            output_tokens: self.output_tokens,
            completed: self.completed,
            preemptions: self.preemptions,
            swap_ins: self.swap_ins,
            ttft_mean_s: stats::mean(&self.ttfts),
            ttft_p95_s: stats::percentile(&self.ttfts, 95.0),
            itl_mean_s: stats::mean(&self.itls),
            itl_p95_s: stats::percentile(&self.itls, 95.0),
            incoming_token_rate,
            starved: throughput < 0.9 * incoming_token_rate,
            queue_trace: self.queue_trace.clone(),
        }
    }
}

/// Final run report.  `starved` follows the paper's criterion: measured
/// throughput below 90% of the incoming token rate.
#[derive(Debug, Clone)]
pub struct Report {
    pub throughput_tok_s: f64,
    pub input_tokens: usize,
    pub output_tokens: usize,
    pub completed: usize,
    pub preemptions: usize,
    pub swap_ins: usize,
    pub ttft_mean_s: f64,
    pub ttft_p95_s: f64,
    pub itl_mean_s: f64,
    pub itl_p95_s: f64,
    pub incoming_token_rate: f64,
    pub starved: bool,
    /// Periodic (time, running, waiting) samples (Fig. 9).
    pub queue_trace: Vec<QueueSample>,
}

impl Report {
    pub fn summary(&self) -> String {
        format!(
            "thr={:.1} tok/s (in={} out={}) done={} ttft={:.1}ms itl={:.2}ms preempt={} swaps={}{}",
            self.throughput_tok_s,
            self.input_tokens,
            self.output_tokens,
            self.completed,
            self.ttft_mean_s * 1e3,
            self.itl_mean_s * 1e3,
            self.preemptions,
            self.swap_ins,
            if self.starved { " STARVED" } else { "" }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_and_starvation() {
        let mut m = MetricsCollector::default();
        m.on_prefill(100, 1.0);
        m.on_decode_tokens(50, 2.0);
        let r = m.report(10.0, 20.0);
        assert!((r.throughput_tok_s - 15.0).abs() < 1e-12);
        assert!(r.starved); // 15 < 0.9*20
        let r2 = m.report(10.0, 16.0);
        assert!(!r2.starved); // 15 > 0.9*16=14.4
    }

    #[test]
    fn finish_records_latencies() {
        let mut m = MetricsCollector::default();
        m.on_finish(Some(0.5), Some(0.01));
        m.on_finish(None, None);
        let r = m.report(1.0, 0.0);
        assert_eq!(r.completed, 2);
        assert!((r.ttft_mean_s - 0.5).abs() < 1e-12);
    }
}
