//! Adapter caching, split like the KV side into simulated and physical:
//!
//! - [`SimAdapterCache`] — the *simulated GPU* resident set, bounded by the
//!   paper's `A_max`, with LRU swap of idle adapters.  Shared engine/DT, so
//!   swap behaviour (and therefore modeled PCIe load latency) is identical.
//! - [`PhysBank`] — engine-only mapping of adapter → physical device bank
//!   slot backing the actual SGMV compute (slot 0 is the reserved zero
//!   adapter for backbone-only rows).

use std::collections::BTreeMap;
// Lookup-only table; never iterated (see PhysBank::map).
#[allow(clippy::disallowed_types)]
use std::collections::HashMap;

/// A swap-in event (for load-latency accounting).
#[derive(Debug, Clone, PartialEq)]
pub struct LoadEvent {
    /// The adapter that was swapped in.
    pub adapter_id: usize,
    /// Its LoRA rank (drives the modeled PCIe transfer latency).
    pub rank: usize,
}

/// Simulated resident adapter set with LRU eviction of idle adapters.
#[derive(Debug, Clone)]
pub struct SimAdapterCache {
    a_max: usize,
    /// adapter -> (rank, last-use tick, active request count).  Ordered
    /// map: the LRU eviction scan in `acquire` iterates it, and ties on
    /// `last_use` must break by adapter id, not hash order.
    resident: BTreeMap<usize, AdapterState>,
    tick: u64,
}

#[derive(Debug, Clone)]
struct AdapterState {
    rank: usize,
    last_use: u64,
    active: usize,
}

impl SimAdapterCache {
    /// An empty cache bounded by `a_max` resident adapters.
    pub fn new(a_max: usize) -> SimAdapterCache {
        SimAdapterCache { a_max, resident: BTreeMap::new(), tick: 0 }
    }

    /// The configured residency bound (the paper's `A_max`).
    pub fn a_max(&self) -> usize {
        self.a_max
    }

    /// Whether `adapter` is currently resident.
    pub fn loaded(&self, adapter: usize) -> bool {
        self.resident.contains_key(&adapter)
    }

    /// Number of resident adapters.
    pub fn resident_count(&self) -> usize {
        self.resident.len()
    }

    /// Can `adapter` start a request now — i.e. is it loaded, or is there
    /// room (possibly after evicting an idle adapter)?
    pub fn admissible(&self, adapter: usize) -> bool {
        self.loaded(adapter)
            || self.resident.len() < self.a_max
            || self.resident.values().any(|s| s.active == 0)
    }

    /// Acquire the adapter for a starting request.  Returns
    /// `Some(Some(load))` if a swap-in occurred, `Some(None)` if already
    /// resident, `None` if not admissible (A_max reached, all busy).
    /// `evicted` receives the ranks of evicted adapters (unified-memory
    /// callers release their KV charge).
    pub fn acquire(
        &mut self,
        adapter: usize,
        rank: usize,
        evicted: &mut Vec<(usize, usize)>,
    ) -> Option<Option<LoadEvent>> {
        self.tick += 1;
        if let Some(s) = self.resident.get_mut(&adapter) {
            s.active += 1;
            s.last_use = self.tick;
            return Some(None);
        }
        if self.resident.len() >= self.a_max {
            // Evict the least-recently-used idle adapter.
            let victim = self
                .resident
                .iter()
                .filter(|(_, s)| s.active == 0)
                .min_by_key(|(_, s)| s.last_use)
                .map(|(&id, s)| (id, s.rank));
            match victim {
                Some((id, r)) => {
                    self.resident.remove(&id);
                    evicted.push((id, r));
                }
                None => return None,
            }
        }
        self.resident
            .insert(adapter, AdapterState { rank, last_use: self.tick, active: 1 });
        Some(Some(LoadEvent { adapter_id: adapter, rank }))
    }

    /// Release one active use (request finished or preempted).  The adapter
    /// stays resident (LRU candidate) until evicted by a later acquire.
    pub fn release(&mut self, adapter: usize) {
        if let Some(s) = self.resident.get_mut(&adapter) {
            s.active = s.active.saturating_sub(1);
        }
    }

    /// Number of in-flight requests currently using `adapter`.
    pub fn active_count(&self, adapter: usize) -> usize {
        self.resident.get(&adapter).map(|s| s.active).unwrap_or(0)
    }
}

/// Physical device-bank slot allocator (engine-only).  Slot 0 is reserved
/// for the zero adapter; the rest are LRU-managed.
#[derive(Debug)]
pub struct PhysBank {
    slots: usize,
    /// adapter -> slot.  Lookup-only (get/insert/remove — the LRU scan
    /// walks `owner`, a Vec), so hash order is never observable.
    #[allow(clippy::disallowed_types)]
    map: HashMap<usize, usize>,
    /// slot -> (adapter, last-use tick); index 0 unused.
    owner: Vec<Option<(usize, u64)>>,
    tick: u64,
}

/// Result of a physical slot acquisition.
#[derive(Debug, PartialEq)]
pub enum PhysSlot {
    /// Adapter already resident in this slot.
    Hit(usize),
    /// Adapter must be written into this (newly assigned) slot.
    Miss(usize),
    /// No slot free (all pinned by the current batch).
    Full,
}

impl PhysBank {
    /// A bank with `slots` physical slots (slot 0 reserved for the zero
    /// adapter).
    #[allow(clippy::disallowed_types)]
    pub fn new(slots: usize) -> PhysBank {
        PhysBank { slots, map: HashMap::new(), owner: vec![None; slots], tick: 0 }
    }

    /// The reserved all-zero adapter slot (backbone-only batch rows).
    pub fn zero_slot() -> usize {
        0
    }

    /// Get the slot for `adapter`, assigning (and possibly evicting an
    /// adapter not in `pinned`) on miss.
    pub fn acquire(&mut self, adapter: usize, pinned: &dyn Fn(usize) -> bool) -> PhysSlot {
        self.tick += 1;
        if let Some(&slot) = self.map.get(&adapter) {
            // detlint: allow(panic-path) — `owner` sized to the cache's slot count at construction; slot ids in range
            self.owner[slot] = Some((adapter, self.tick));
            return PhysSlot::Hit(slot);
        }
        // Free slot?
        for slot in 1..self.slots {
            // detlint: allow(panic-path) — `owner` sized to the cache's slot count at construction; slot ids in range
            if self.owner[slot].is_none() {
                self.map.insert(adapter, slot);
                // detlint: allow(panic-path) — `owner` sized to the cache's slot count at construction; slot ids in range
                self.owner[slot] = Some((adapter, self.tick));
                return PhysSlot::Miss(slot);
            }
        }
        // LRU-evict an unpinned resident.
        let victim = (1..self.slots)
            // detlint: allow(panic-path) — `owner` sized to the cache's slot count at construction; slot ids in range
            .filter_map(|s| self.owner[s].map(|(a, t)| (s, a, t)))
            .filter(|&(_, a, _)| !pinned(a))
            .min_by_key(|&(_, _, t)| t);
        match victim {
            Some((slot, old, _)) => {
                self.map.remove(&old);
                self.map.insert(adapter, slot);
                // detlint: allow(panic-path) — `owner` sized to the cache's slot count at construction; slot ids in range
                self.owner[slot] = Some((adapter, self.tick));
                PhysSlot::Miss(slot)
            }
            None => PhysSlot::Full,
        }
    }

    /// The physical slot currently holding `adapter`, if resident.
    pub fn slot_of(&self, adapter: usize) -> Option<usize> {
        self.map.get(&adapter).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_cache_loads_up_to_a_max() {
        let mut c = SimAdapterCache::new(2);
        let mut ev = vec![];
        assert_eq!(
            c.acquire(1, 8, &mut ev),
            Some(Some(LoadEvent { adapter_id: 1, rank: 8 }))
        );
        assert_eq!(c.acquire(2, 16, &mut ev).unwrap().unwrap().adapter_id, 2);
        // Both busy: a third adapter is not admissible.
        assert!(c.acquire(3, 8, &mut ev).is_none());
        assert!(!c.admissible(3));
        assert!(ev.is_empty());
    }

    #[test]
    fn sim_cache_evicts_lru_idle() {
        let mut c = SimAdapterCache::new(2);
        let mut ev = vec![];
        c.acquire(1, 8, &mut ev);
        c.acquire(2, 16, &mut ev);
        c.release(1); // 1 idle now
        assert!(c.admissible(3));
        let load = c.acquire(3, 32, &mut ev).unwrap().unwrap();
        assert_eq!(load.adapter_id, 3);
        assert_eq!(ev, vec![(1, 8)]);
        assert!(!c.loaded(1));
        assert!(c.loaded(2) && c.loaded(3));
    }

    #[test]
    fn sim_cache_hit_costs_nothing() {
        let mut c = SimAdapterCache::new(2);
        let mut ev = vec![];
        c.acquire(1, 8, &mut ev);
        assert_eq!(c.acquire(1, 8, &mut ev), Some(None));
        assert_eq!(c.active_count(1), 2);
    }

    #[test]
    fn phys_bank_hit_miss_full() {
        let mut b = PhysBank::new(3); // slots 1, 2 usable
        assert_eq!(b.acquire(10, &|_| false), PhysSlot::Miss(1));
        assert_eq!(b.acquire(10, &|_| false), PhysSlot::Hit(1));
        assert_eq!(b.acquire(11, &|_| false), PhysSlot::Miss(2));
        // All pinned → Full.
        assert_eq!(b.acquire(12, &|_| true), PhysSlot::Full);
        // Unpinned → LRU eviction of adapter 10 (slot 1 older).
        assert_eq!(b.acquire(12, &|a| a == 11), PhysSlot::Miss(1));
        assert_eq!(b.slot_of(10), None);
        assert_eq!(b.slot_of(12), Some(1));
    }
}
