//! Per-iteration component profiling.
//!
//! Feeds (a) the §5.1-style overhead analysis experiments (Figs. 4-7) and
//! (b) the Digital-Twin calibration fits: every engine iteration records
//! the state the paper's predictive models condition on, together with the
//! measured wall time of each component.

/// One engine iteration's profile record.
#[derive(Debug, Clone, Default)]
pub struct IterRecord {
    /// Simulated time at the end of the iteration (s).
    pub sim_time_s: f64,
    /// Batch size fed to the decode step (0 for prefill iterations).
    pub batch: usize,
    /// Pending (waiting) requests at scheduling time (R_P).
    pub pending: usize,
    /// Distinct adapters in the executed batch (A_B).
    pub adapters_in_batch: usize,
    /// Total adapters being served (A).
    pub adapters_total: usize,
    /// Measured scheduler wall time (s).
    pub sched_s: f64,
    /// Measured execute wall time (s) — decode or prefill PJRT call
    /// including window gather and readback.
    pub exec_s: f64,
    /// Window-gather / marshalling share of exec (s).
    pub gather_s: f64,
    /// Swap-in cost charged this iteration (s): modeled PCIe + measured
    /// bank re-upload.
    pub load_s: f64,
    /// Number of swap-ins this iteration.
    pub loads: usize,
    /// True for prefill iterations.
    pub prefill: bool,
    /// Prefill bucket (padded length) when `prefill`.
    pub prefill_bucket: usize,
}

/// Collects iteration records; cheap to keep always-on.
#[derive(Debug, Default)]
pub struct Profiler {
    /// One record per engine iteration, in execution order.
    pub iters: Vec<IterRecord>,
    /// (rank, modeled_s, measured_upload_s) per swap-in.
    pub load_events: Vec<(usize, f64, f64)>,
}

impl Profiler {
    /// Append one iteration record.
    pub fn record(&mut self, rec: IterRecord) {
        self.iters.push(rec);
    }

    /// Append one swap-in event (modeled PCIe + measured upload time).
    pub fn record_load(&mut self, rank: usize, modeled_s: f64, upload_s: f64) {
        self.load_events.push((rank, modeled_s, upload_s));
    }

    /// Decode iterations only (the calibration fits exclude prefill).
    pub fn decode_iters(&self) -> impl Iterator<Item = &IterRecord> {
        self.iters.iter().filter(|r| !r.prefill && r.batch > 0)
    }

    /// Total measured scheduler time (s).
    pub fn total_sched_s(&self) -> f64 {
        self.iters.iter().map(|r| r.sched_s).sum()
    }

    /// Total measured execute time (s).
    pub fn total_exec_s(&self) -> f64 {
        self.iters.iter().map(|r| r.exec_s).sum()
    }

    /// Total swap-in cost charged (s).
    pub fn total_load_s(&self) -> f64 {
        self.iters.iter().map(|r| r.load_s).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_filters() {
        let mut p = Profiler::default();
        p.record(IterRecord { batch: 4, sched_s: 0.1, exec_s: 1.0, ..Default::default() });
        p.record(IterRecord {
            prefill: true,
            batch: 0,
            sched_s: 0.2,
            exec_s: 2.0,
            ..Default::default()
        });
        assert_eq!(p.decode_iters().count(), 1);
        assert!((p.total_sched_s() - 0.3).abs() < 1e-12);
        assert!((p.total_exec_s() - 3.0).abs() < 1e-12);
    }
}
