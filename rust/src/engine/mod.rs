//! The serving engine (`adapterd`): a vLLM-like multi-LoRA continuous-
//! batching server and the repository's stand-in for the paper's "real
//! system" (vLLM v0.8.5 on H100 — see DESIGN.md §1 for the substitution
//! argument).
//!
//! Per iteration: inject arrivals → scheduler (admission scan, shared with
//! the Digital Twin) → adapter swap-ins → execute (prefill or decode on
//! the pico model through the pluggable [`Backend`]) → bookkeeping.  Time
//! is a **virtual clock**: simulated time advances by the *measured wall
//! time* of each component, so saturation dynamics match a real deployment
//! without idle waiting, and a 60 s horizon plays back in however long the
//! compute takes.

// Wall-clock reads here are sanctioned: they measure component cost to
// advance the *virtual* clock (see module docs above and DESIGN.md §13).
// This clippy allow blankets the engine submodules too; detlint's
// `wall-clock` rule still polices them individually (its allowlist entry
// `engine` covers this file only).
#![allow(clippy::disallowed_methods)]

pub mod adapter_cache;
pub mod kv;
pub mod metrics;
pub mod profiler;
pub mod request;
pub mod scheduler;

use crate::config::EngineConfig;
use crate::runtime::Backend;
use crate::util::rng::Rng;
use crate::workload::{Arrival, WorkloadSpec};
use adapter_cache::{PhysBank, PhysSlot, SimAdapterCache};
use anyhow::Result;
use kv::KvLedger;
use metrics::{MetricsCollector, Report};
use profiler::{IterRecord, Profiler};
use request::{ReqState, Request};
use scheduler::{scan_admissions, AdmissionLimits};
use std::collections::VecDeque;
use std::time::Instant;

/// Outcome of one engine run.
pub struct RunResult {
    /// None on memory error (the paper's infeasible configurations).
    pub report: Option<Report>,
    /// Static reservation exceeded GPU memory before serving started.
    pub memory_error: bool,
    /// Per-iteration component profile of the run.
    pub profiler: Profiler,
    /// Wall-clock time the run took (Table 2 compares DT time against this).
    pub wall_s: f64,
}

impl RunResult {
    /// The result of a run that failed the static reservation check.
    pub fn memory_error(wall_s: f64) -> RunResult {
        RunResult { report: None, memory_error: true, profiler: Profiler::default(), wall_s }
    }
}

/// One simulated GPU running the pico model through a [`Backend`].
pub struct Engine<'rt> {
    /// The engine configuration this instance serves under.
    pub cfg: EngineConfig,
    rt: &'rt mut dyn Backend,
    phys_bank: Option<PhysBank>,
    /// Bucket used by the previous decode step.  Stale window content is
    /// harmless (the attention kernel masks positions >= ctx per row), so
    /// buffers are only re-zeroed when the bucket changes (hygiene for the
    /// shifted row offsets); see the §Perf log in EXPERIMENTS.md.
    last_bucket: usize,
}

impl<'rt> Engine<'rt> {
    /// Create an engine over a backend ("one GPU" — the backend instance
    /// is exclusively owned for the engine's lifetime).
    pub fn new(cfg: EngineConfig, rt: &'rt mut dyn Backend) -> Engine<'rt> {
        Engine { cfg, rt, phys_bank: None, last_bucket: 0 }
    }

    /// Serve the workload to completion of the horizon.
    pub fn run(&mut self, spec: &WorkloadSpec) -> Result<RunResult> {
        let trace = spec.trace();
        self.run_trace(spec, &trace)
    }

    /// Serve an explicit arrival trace (used by calibration and by the
    /// Digital-Twin fidelity experiments so engine and twin consume the
    /// *same* arrivals).
    pub fn run_trace(&mut self, spec: &WorkloadSpec, trace: &[Arrival]) -> Result<RunResult> {
        let wall0 = Instant::now();
        // Static reservation check — the paper's "GPU memory error".
        let Some(pool) = self.cfg.kv_pool_tokens() else {
            return Ok(RunResult::memory_error(wall0.elapsed().as_secs_f64()));
        };
        let mut st = SimState::new(&self.cfg, pool, trace, spec);
        let meta = self.rt.meta().clone();
        let max_running = self.cfg.max_num_seqs.min(self.rt.max_decode_bucket());
        let limits = AdmissionLimits {
            max_running,
            max_prefill_tokens: 1024,
            unified: self.cfg.mem.unified,
        };
        let max_prefill = self.rt.max_prefill_bucket();

        // Reusable window buffers sized for the largest decode bucket.
        // do_decode overwrites exactly the valid prefix of each row and
        // zeroes only the stale tail (perf pass: a full `fill(0.0)` of the
        // 2·L·B·W·d buffer dominated small-batch decode latency).
        let max_bucket = self.rt.max_decode_bucket();
        let win_elems = meta.n_layers * max_bucket * meta.window * meta.d_model;
        let mut k_win = vec![0f32; win_elems];
        let mut v_win = vec![0f32; win_elems];
        self.last_bucket = 0;

        while st.sim_time < spec.horizon_s {
            st.inject_arrivals();

            // ---- Scheduler (measured) -----------------------------------
            let t0 = Instant::now();
            let active = st.active_count();
            let adm = scan_admissions(
                &mut st.waiting,
                &mut st.requests,
                &mut st.ledger,
                &mut st.cache,
                active,
                limits,
            );
            let sched_s = t0.elapsed().as_secs_f64();

            // ---- Adapter swap-ins ---------------------------------------
            let mut load_s = 0.0;
            let n_loads = adm.loads.len();
            for ev in &adm.loads {
                let modeled = self.modeled_load_s(ev.rank);
                let upload_s = self.physical_load(ev.adapter_id, ev.rank)?;
                st.profiler.record_load(ev.rank, modeled, upload_s);
                st.metrics.swap_ins += 1;
                load_s += modeled + upload_s;
            }
            st.prefill_queue.extend(adm.admitted.iter().copied());

            // ---- Execute -------------------------------------------------
            if let Some(id) = st.prefill_queue.pop_front() {
                // Prefill one request per iteration (vLLM v0.5 alternates
                // prefill-priority iterations).
                let t1 = Instant::now();
                let exec_s = self.do_prefill(id, &mut st, max_prefill)?;
                let wall = t1.elapsed().as_secs_f64().max(exec_s);
                st.advance(sched_s + load_s + wall);
                // detlint: allow(panic-path) — `requests` is the request arena; ids are indices it issued itself
                let r = &st.requests[id];
                st.profiler.record(IterRecord {
                    sim_time_s: st.sim_time,
                    batch: 0,
                    pending: st.waiting.len(),
                    adapters_in_batch: 1,
                    adapters_total: st.adapters_total,
                    sched_s,
                    exec_s: wall,
                    gather_s: 0.0,
                    load_s,
                    loads: n_loads,
                    prefill: true,
                    prefill_bucket: self
                        .rt
                        .prefill_bucket(r.kv.tokens.max(1))
                        .unwrap_or(max_prefill),
                });
                // First token was produced by the prefill.
                st.finish_or_continue(id);
            } else if !st.running.is_empty() {
                let preempted = scheduler::grow_or_preempt(
                    &mut st.running,
                    &mut st.requests,
                    &mut st.ledger,
                    &mut st.cache,
                    limits.unified,
                );
                for id in preempted {
                    st.metrics.preemptions += 1;
                    st.waiting.push_front(id);
                }
                if st.running.is_empty() {
                    st.advance(sched_s + load_s + 1e-4);
                    continue;
                }
                let (exec_s, gather_s, batch, a_b) =
                    self.do_decode(&mut st, &mut k_win, &mut v_win)?;
                st.advance(sched_s + load_s + exec_s);
                st.profiler.record(IterRecord {
                    sim_time_s: st.sim_time,
                    batch,
                    pending: st.waiting.len(),
                    adapters_in_batch: a_b,
                    adapters_total: st.adapters_total,
                    sched_s,
                    exec_s,
                    gather_s,
                    load_s,
                    loads: n_loads,
                    prefill: false,
                    prefill_bucket: 0,
                });
            } else {
                // Idle: jump to the next arrival (or finish).
                match st.next_arrival_time() {
                    Some(t) if t < spec.horizon_s => {
                        st.advance((t - st.sim_time).max(0.0) + 1e-6)
                    }
                    _ => break,
                }
            }
            let active = st.running.len() + st.prefill_queue.len();
            st.metrics.sample_queues(st.sim_time, active, st.waiting.len());
        }

        let report = st.metrics.report(spec.horizon_s, spec.incoming_token_rate());
        Ok(RunResult {
            report: Some(report),
            memory_error: false,
            profiler: st.profiler,
            wall_s: wall0.elapsed().as_secs_f64(),
        })
    }

    /// Modeled CPU(or disk)→GPU transfer latency for an adapter of `rank`.
    fn modeled_load_s(&self, rank: usize) -> f64 {
        let base = metrics::ReportSchema::s_from_ms(rank as f64 * self.cfg.load_ms_per_rank);
        if self.cfg.preload_cpu {
            base
        } else {
            base * self.cfg.load_disk_mult
        }
    }

    /// Write the adapter's (synthetic, deterministic) weights into the
    /// physical bank and re-upload.  Returns the measured upload seconds.
    fn physical_load(&mut self, adapter_id: usize, rank: usize) -> Result<f64> {
        let t0 = Instant::now();
        // Pinning is resolved at batch-build time; during load any
        // non-resident slot may be evicted.
        if let PhysSlot::Miss(slot) = self.phys().acquire(adapter_id, &|_| false) {
            self.rewrite_slot(adapter_id, rank, slot)?;
        }
        Ok(t0.elapsed().as_secs_f64())
    }

    fn phys(&mut self) -> &mut PhysBank {
        // The physical bank lives alongside the runtime (one per engine).
        // Lazily initialized to the runtime's slot count.
        let slots = self.rt.meta().slots;
        self.phys_bank.get_or_insert_with(|| PhysBank::new(slots))
    }

    fn do_prefill(&mut self, id: usize, st: &mut SimState, max_prefill: usize) -> Result<f64> {
        let meta = self.rt.meta().clone();
        // detlint: allow(panic-path) — `requests` is the request arena; ids are indices it issued itself
        let r = &st.requests[id];
        let prompt = r.prompt_tokens(meta.vocab, max_prefill);
        let true_len = prompt.len();
        let bucket = self
            .rt
            .prefill_bucket(true_len)
            .ok_or_else(|| anyhow::anyhow!("prompt {true_len} exceeds prefill buckets"))?;
        let mut padded = prompt;
        padded.resize(bucket, 0);
        let slot = if r.rank == 0 {
            PhysBank::zero_slot() as i32
        } else {
            self.phys().slot_of(r.adapter_id).unwrap_or(PhysBank::zero_slot()) as i32
        };
        let t0 = Instant::now();
        let out = self.rt.prefill(bucket, &padded, true_len, slot)?;
        let exec_s = t0.elapsed().as_secs_f64();
        // detlint: allow(panic-path) — `requests` is the request arena; ids are indices it issued itself
        let r = &mut st.requests[id];
        r.kv.load_prefill(meta.n_layers, meta.d_model, bucket, true_len, &out.k, &out.v);
        r.last_token = out.next_token;
        r.generated += 1;
        r.context_len += 1;
        r.state = ReqState::Running;
        // Input tokens count toward throughput only on the first prefill;
        // recompute after preemption is overhead, not progress.
        let first_time = r.first_token_s.is_none();
        r.first_token_s.get_or_insert(st.sim_time + exec_s);
        r.token_times.push(st.sim_time + exec_s);
        let input_len = r.input_len;
        if first_time {
            st.metrics.on_prefill(input_len, st.sim_time + exec_s);
        }
        st.metrics.on_decode_tokens(1, st.sim_time + exec_s);
        st.running.push(id);
        Ok(exec_s)
    }

    /// Run one decode step over the running batch.  Returns
    /// (exec_s, gather_s, batch, adapters_in_batch).
    fn do_decode(
        &mut self,
        st: &mut SimState,
        k_win: &mut [f32],
        v_win: &mut [f32],
    ) -> Result<(f64, f64, usize, usize)> {
        let meta = self.rt.meta().clone();
        let (nl, d, w) = (meta.n_layers, meta.d_model, meta.window);
        let batch = st.running.len();
        let bucket = self
            .rt
            .decode_bucket(batch)
            .ok_or_else(|| anyhow::anyhow!("batch {batch} exceeds decode buckets"))?;

        let t_gather = Instant::now();
        let mut tokens = vec![0i32; bucket];
        let mut ctx = vec![0i32; bucket];
        let mut slots = vec![0i32; bucket];
        // detlint: allow(panic-path) — `k_win`/`v_win` rows are allocated to the exact loop bounds indexing them
        let k_sl = &mut k_win[..nl * bucket * w * d];
        let v_sl = &mut v_win[..nl * bucket * w * d];
        if bucket != self.last_bucket {
            // Bucket changed: row offsets shifted, all previous content is
            // misplaced — zero everything once.
            k_sl.fill(0.0);
            v_sl.fill(0.0);
            self.last_bucket = bucket;
        }
        let mut adapters = std::collections::BTreeSet::new();
        // Resolve physical slots (pinning all adapters in this batch).
        let batch_adapters: std::collections::BTreeSet<usize> = st
            .running
            .iter()
            // detlint: allow(panic-path) — `requests` is the request arena; ids are indices it issued itself
            .filter(|&&id| st.requests[id].rank > 0)
            .map(|&id| st.requests[id].adapter_id)
            .collect();
        for (row, &id) in st.running.iter().enumerate() {
            // detlint: allow(panic-path) — `requests`/`tokens` and its index are constructed together; in range by construction
            let r = &st.requests[id];
            tokens[row] = r.last_token;
            let n = r.kv.tokens.min(w - 1);
            // detlint: allow(panic-path) — `ctx` built with one entry per index of this very loop
            ctx[row] = n as i32;
            if r.rank > 0 {
                adapters.insert(r.adapter_id);
                let pinned = |a: usize| batch_adapters.contains(&a);
                match self.phys().acquire(r.adapter_id, &pinned) {
                    // detlint: allow(panic-path) — `slots` built with one entry per index of this very loop
                    PhysSlot::Hit(s) => slots[row] = s as i32,
                    PhysSlot::Miss(s) => {
                        // Re-materialize evicted weights (counts as gather
                        // overhead; sim-side load already accounted at
                        // admission).
                        let (adapter_id, rank) = (r.adapter_id, r.rank);
                        self.rewrite_slot(adapter_id, rank, s)?;
                        // detlint: allow(panic-path) — `slots` built with one entry per index of this very loop
                        slots[row] = s as i32;
                    }
                    // detlint: allow(panic-path) — `slots` built with one entry per index of this very loop
                    PhysSlot::Full => slots[row] = PhysBank::zero_slot() as i32,
                }
            }
            // detlint: allow(panic-path) — `requests` is the request arena; ids are indices it issued itself
            let r = &st.requests[id];
            for l in 0..nl {
                let off = (l * bucket + row) * w * d;
                r.kv.gather_window(
                    l,
                    nl,
                    d,
                    n,
                    // detlint: allow(panic-path) — `k_sl`/`v_sl` rows are allocated to the exact loop bounds indexing them
                    &mut k_sl[off..off + n * d],
                    &mut v_sl[off..off + n * d],
                );
            }
        }
        let gather_s = t_gather.elapsed().as_secs_f64();

        let t0 = Instant::now();
        let out = self.rt.decode(bucket, &tokens, k_sl, v_sl, &ctx, &slots)?;
        let exec_s = t0.elapsed().as_secs_f64() + gather_s;
        let t_done = st.sim_time + exec_s;

        // Write back new K/V rows; layout [L, bucket, d].
        let ids: Vec<usize> = st.running.clone();
        let mut new_row_k = vec![0f32; nl * d];
        let mut new_row_v = vec![0f32; nl * d];
        for (row, &id) in ids.iter().enumerate() {
            for l in 0..nl {
                let src = (l * bucket + row) * d;
                // detlint: allow(panic-path) — `new_k`/`new_row_k`/`new_row_v`/`new_v` rows are allocated to the exact loop bounds indexing them
                new_row_k[l * d..(l + 1) * d].copy_from_slice(&out.new_k[src..src + d]);
                new_row_v[l * d..(l + 1) * d].copy_from_slice(&out.new_v[src..src + d]);
            }
            // detlint: allow(panic-path) — `requests` is the request arena; ids are indices it issued itself
            let r = &mut st.requests[id];
            r.kv.append(nl, d, &new_row_k, &new_row_v);
            // detlint: allow(panic-path) — `next_tokens` rows are allocated to the exact loop bounds indexing them
            r.last_token = out.next_tokens[row];
            r.generated += 1;
            r.context_len += 1;
            r.token_times.push(t_done);
        }
        st.metrics.on_decode_tokens(ids.len(), t_done);
        for id in ids {
            st.finish_or_continue_at(id, t_done);
        }
        Ok((exec_s, gather_s, batch, adapters.len()))
    }

    fn rewrite_slot(&mut self, adapter_id: usize, rank: usize, slot: usize) -> Result<()> {
        let m = self.rt.meta();
        let (l, d, rmax) = (m.n_layers, m.d_model, m.max_rank);
        let mut wrng = Rng::new(0xA0A0_0000 ^ adapter_id as u64);
        let gen = |rng: &mut Rng, n: usize, active: usize, stride: usize| -> Vec<f32> {
            let mut v = vec![0f32; n];
            for (i, x) in v.iter_mut().enumerate() {
                if i % stride < active {
                    *x = (rng.normal() * 0.02) as f32;
                }
            }
            v
        };
        let a_q = gen(&mut wrng, l * d * rmax, rank, rmax);
        let b_q = gen(&mut wrng, l * rmax * d, rank * d, rmax * d);
        let a_v = gen(&mut wrng, l * d * rmax, rank, rmax);
        let b_v = gen(&mut wrng, l * rmax * d, rank * d, rmax * d);
        self.rt.write_bank_slot(slot, &a_q, &b_q, &a_v, &b_v)?;
        self.rt.upload_bank()?;
        Ok(())
    }
}

/// Mutable per-run simulation state.
struct SimState {
    requests: Vec<Request>,
    waiting: VecDeque<usize>,
    prefill_queue: VecDeque<usize>,
    running: Vec<usize>,
    ledger: KvLedger,
    cache: SimAdapterCache,
    sim_time: f64,
    trace: Vec<Arrival>,
    next_arrival: usize,
    adapters_total: usize,
    metrics: MetricsCollector,
    profiler: Profiler,
    /// Lookup-only (never iterated), so hash order is not observable.
    #[allow(clippy::disallowed_types)]
    rank_of: std::collections::HashMap<usize, usize>,
}

impl SimState {
    #[allow(clippy::disallowed_types)]
    fn new(cfg: &EngineConfig, pool: usize, trace: &[Arrival], spec: &WorkloadSpec) -> SimState {
        let rank_of: std::collections::HashMap<usize, usize> =
            spec.adapters.iter().map(|a| (a.id, a.rank)).collect();
        let requests = trace
            .iter()
            .map(|a| {
                Request::new(
                    a.request_id,
                    a.adapter_id,
                    rank_of.get(&a.adapter_id).copied().unwrap_or(0),
                    a.time_s,
                    a.input_len,
                    a.output_len,
                )
            })
            .collect();
        SimState {
            requests,
            waiting: VecDeque::new(),
            prefill_queue: VecDeque::new(),
            running: Vec::new(),
            ledger: KvLedger::new(cfg.mem.clone(), pool),
            cache: SimAdapterCache::new(cfg.a_max),
            sim_time: 0.0,
            trace: trace.to_vec(),
            next_arrival: 0,
            adapters_total: spec.adapters.len(),
            metrics: MetricsCollector::default(),
            profiler: Profiler::default(),
            rank_of,
        }
    }

    fn inject_arrivals(&mut self) {
        while self.next_arrival < self.trace.len()
            // detlint: allow(panic-path) — `trace` is indexed within its own recorded length
            && self.trace[self.next_arrival].time_s <= self.sim_time
        {
            // detlint: allow(panic-path) — `trace` is indexed within its own recorded length
            let a = &self.trace[self.next_arrival];
            self.metrics.on_arrival(a.input_len, a.output_len);
            self.waiting.push_back(a.request_id);
            self.next_arrival += 1;
        }
    }

    fn next_arrival_time(&self) -> Option<f64> {
        self.trace.get(self.next_arrival).map(|a| a.time_s)
    }

    fn active_count(&self) -> usize {
        self.running.len() + self.prefill_queue.len()
    }

    fn advance(&mut self, dt: f64) {
        self.sim_time += dt;
    }

    fn finish_or_continue(&mut self, id: usize) {
        self.finish_or_continue_at(id, self.sim_time)
    }

    fn finish_or_continue_at(&mut self, id: usize, t: f64) {
        // detlint: allow(panic-path) — `requests` is the request arena; ids are indices it issued itself
        if !self.requests[id].is_done() {
            return;
        }
        // detlint: allow(panic-path) — `requests` is the request arena; ids are indices it issued itself
        let r = &mut self.requests[id];
        r.state = ReqState::Finished;
        r.finish_s = Some(t);
        let (ttft, itl) = (r.ttft(), r.itl_mean());
        let (adapter, rank) = (r.adapter_id, r.rank);
        r.kv.clear();
        self.ledger.release(id);
        if rank > 0 {
            self.cache.release(adapter);
        }
        self.running.retain(|&x| x != id);
        self.metrics.on_finish(ttft, itl);
        let _ = &self.rank_of;
    }
}
