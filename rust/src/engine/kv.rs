//! KV-cache management.
//!
//! Two distinct concerns, deliberately separated:
//!
//! - [`KvLedger`] — the *simulated GPU memory* ledger in token/block units
//!   (vLLM paged-attention semantics: greedy block allocation, preemption
//!   when the pool is exhausted).  Shared by the engine and the Digital
//!   Twin, so starvation/OOM dynamics are identical by construction and
//!   only *timing* differs.
//! - [`HostKv`] — the *real* host-side KV data backing the PJRT compute
//!   (per-request pages of f32 keys/values, gathered into dense window
//!   tiles per decode step).  Engine-only.

use crate::config::MemoryConfig;

/// Simulated paged KV allocator.
#[derive(Debug, Clone)]
pub struct KvLedger {
    mem: MemoryConfig,
    /// Total pool size in blocks (after the static adapter reservation).
    total_blocks: usize,
    /// Blocks currently held, keyed by request id.  Lookup-only
    /// (get/entry/remove); never iterated, so hash order is invisible.
    #[allow(clippy::disallowed_types)]
    held: std::collections::HashMap<usize, usize>,
    free_blocks: usize,
    /// Dynamic adapter charge in unified (S-LoRA) mode, in tokens.
    unified_adapter_tokens: f64,
}

impl KvLedger {
    /// `kv_pool_tokens` is the pool after static reservation (engine config
    /// already subtracted the A_max·S_max region in vLLM mode).
    pub fn new(mem: MemoryConfig, kv_pool_tokens: usize) -> KvLedger {
        let total_blocks = kv_pool_tokens / mem.block_tokens;
        KvLedger {
            mem,
            total_blocks,
            held: Default::default(),
            free_blocks: total_blocks,
            unified_adapter_tokens: 0.0,
        }
    }

    /// Tokens per KV block (vLLM paged-attention granularity).
    pub fn block_tokens(&self) -> usize {
        self.mem.block_tokens
    }

    /// Total pool size in blocks.
    pub fn total_blocks(&self) -> usize {
        self.total_blocks
    }

    /// Blocks currently unallocated.
    pub fn free_blocks(&self) -> usize {
        self.free_blocks
    }

    /// Blocks needed to hold `tokens` tokens.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.mem.block_tokens)
    }

    /// In unified (S-LoRA) mode, loading/unloading adapters consumes pool
    /// dynamically.  Returns false if the charge cannot fit.
    pub fn charge_adapter(&mut self, rank: usize) -> bool {
        let blocks = self.blocks_for(self.mem.adapter_tokens(rank).ceil() as usize);
        if blocks > self.free_blocks {
            return false;
        }
        self.free_blocks -= blocks;
        self.unified_adapter_tokens += self.mem.adapter_tokens(rank);
        true
    }

    /// Release a unified-mode adapter charge (eviction).
    pub fn release_adapter(&mut self, rank: usize) {
        let blocks = self.blocks_for(self.mem.adapter_tokens(rank).ceil() as usize);
        self.free_blocks = (self.free_blocks + blocks).min(self.total_blocks);
        self.unified_adapter_tokens =
            (self.unified_adapter_tokens - self.mem.adapter_tokens(rank)).max(0.0);
    }

    /// Grow request `id` to `tokens` total tokens.  Greedy: allocates only
    /// the missing blocks.  Returns false (no change) if the pool cannot
    /// satisfy the growth — the caller must preempt someone and retry.
    pub fn grow_to(&mut self, id: usize, tokens: usize) -> bool {
        let need = self.blocks_for(tokens);
        let have = self.held.get(&id).copied().unwrap_or(0);
        if need <= have {
            return true;
        }
        let delta = need - have;
        if delta > self.free_blocks {
            return false;
        }
        self.free_blocks -= delta;
        *self.held.entry(id).or_insert(0) = need;
        true
    }

    /// Free all blocks of request `id` (finish or preemption).
    pub fn release(&mut self, id: usize) {
        if let Some(b) = self.held.remove(&id) {
            self.free_blocks += b;
        }
    }

    /// Blocks currently held by request `id`.
    pub fn held_blocks(&self, id: usize) -> usize {
        self.held.get(&id).copied().unwrap_or(0)
    }

    /// Used blocks across all requests.
    pub fn used_blocks(&self) -> usize {
        self.total_blocks - self.free_blocks
    }
}

/// Real host-side KV pages for one request: `[token, layer, d]` layout for
/// keys and values separately (append-friendly; gathered per layer when
/// building the decode window).
#[derive(Debug, Default, Clone)]
pub struct RequestKv {
    /// Key pages, `[token, layer, d]` flattened.
    pub k: Vec<f32>,
    /// Value pages, `[token, layer, d]` flattened.
    pub v: Vec<f32>,
    /// Tokens currently stored.
    pub tokens: usize,
}

impl RequestKv {
    /// Append one token's K/V rows given `[L, d]`-flattened new rows.
    pub fn append(&mut self, n_layers: usize, d: usize, new_k: &[f32], new_v: &[f32]) {
        debug_assert_eq!(new_k.len(), n_layers * d);
        self.k.extend_from_slice(new_k);
        self.v.extend_from_slice(new_v);
        self.tokens += 1;
    }

    /// Bulk-load from a prefill output with layout `[L, S, d]` (only the
    /// first `true_len` positions are valid).
    pub fn load_prefill(
        &mut self,
        n_layers: usize,
        d: usize,
        bucket: usize,
        true_len: usize,
        k: &[f32],
        v: &[f32],
    ) {
        self.k.clear();
        self.v.clear();
        self.k.resize(true_len * n_layers * d, 0.0);
        self.v.resize(true_len * n_layers * d, 0.0);
        for t in 0..true_len {
            for l in 0..n_layers {
                let src = (l * bucket + t) * d;
                let dst = (t * n_layers + l) * d;
                // detlint: allow(panic-path) — `k`/`v` rows are allocated to the exact loop bounds indexing them
                self.k[dst..dst + d].copy_from_slice(&k[src..src + d]);
                self.v[dst..dst + d].copy_from_slice(&v[src..src + d]);
            }
        }
        self.tokens = true_len;
    }

    /// Drop all stored KV (request finished or preempted; vLLM recompute
    /// semantics re-prefill on resume).
    pub fn clear(&mut self) {
        self.k.clear();
        self.v.clear();
        self.tokens = 0;
    }

    /// Copy the last `n` tokens of layer `l` into `dst` (length `n * d`),
    /// the dense window tile for the decode kernel.
    pub fn gather_window(
        &self,
        layer: usize,
        n_layers: usize,
        d: usize,
        n: usize,
        dst_k: &mut [f32],
        dst_v: &mut [f32],
    ) {
        debug_assert!(n <= self.tokens);
        let start = self.tokens - n;
        for (i, t) in (start..self.tokens).enumerate() {
            let src = (t * n_layers + layer) * d;
            // detlint: allow(panic-path) — `dst_k`/`dst_v`/`k`/`v` rows are allocated to the exact loop bounds indexing them
            dst_k[i * d..(i + 1) * d].copy_from_slice(&self.k[src..src + d]);
            dst_v[i * d..(i + 1) * d].copy_from_slice(&self.v[src..src + d]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ledger(pool_tokens: usize) -> KvLedger {
        KvLedger::new(MemoryConfig { total_tokens: pool_tokens, ..Default::default() }, pool_tokens)
    }

    #[test]
    fn grow_allocates_incrementally() {
        let mut l = ledger(160); // 10 blocks of 16
        assert!(l.grow_to(1, 10)); // 1 block
        assert_eq!(l.free_blocks(), 9);
        assert!(l.grow_to(1, 16)); // still 1 block
        assert_eq!(l.free_blocks(), 9);
        assert!(l.grow_to(1, 17)); // 2 blocks
        assert_eq!(l.free_blocks(), 8);
    }

    #[test]
    fn exhaustion_refuses_without_change() {
        let mut l = ledger(32); // 2 blocks
        assert!(l.grow_to(1, 32));
        assert_eq!(l.free_blocks(), 0);
        assert!(!l.grow_to(2, 1));
        assert_eq!(l.held_blocks(2), 0);
        l.release(1);
        assert_eq!(l.free_blocks(), 2);
        assert!(l.grow_to(2, 1));
    }

    #[test]
    fn unified_adapter_charge() {
        let mut l = ledger(160);
        assert!(l.charge_adapter(32)); // 128 tokens = 8 blocks
        assert_eq!(l.free_blocks(), 2);
        assert!(!l.charge_adapter(32));
        l.release_adapter(32);
        assert_eq!(l.free_blocks(), 10);
    }

    #[test]
    fn request_kv_append_and_gather() {
        let (nl, d) = (2, 3);
        let mut kv = RequestKv::default();
        for t in 0..5 {
            let row_k: Vec<f32> = (0..nl * d).map(|i| (t * 100 + i) as f32).collect();
            let row_v: Vec<f32> = row_k.iter().map(|x| -x).collect();
            kv.append(nl, d, &row_k, &row_v);
        }
        assert_eq!(kv.tokens, 5);
        let mut wk = vec![0.0; 2 * d];
        let mut wv = vec![0.0; 2 * d];
        kv.gather_window(1, nl, d, 2, &mut wk, &mut wv);
        // last two tokens (3, 4), layer 1 → values 3xx+3.., 4xx+3..
        assert_eq!(wk[0], 303.0);
        assert_eq!(wk[d], 403.0);
        assert_eq!(wv[0], -303.0);
    }

    #[test]
    fn prefill_layout_conversion() {
        let (nl, d, bucket, tl) = (2, 2, 4, 3);
        // k[l][s][d] = l*1000 + s*10 + d
        let mut k = vec![0.0; nl * bucket * d];
        for l in 0..nl {
            for s in 0..bucket {
                for x in 0..d {
                    k[(l * bucket + s) * d + x] = (l * 1000 + s * 10 + x) as f32;
                }
            }
        }
        let v: Vec<f32> = k.iter().map(|x| x + 0.5).collect();
        let mut kv = RequestKv::default();
        kv.load_prefill(nl, d, bucket, tl, &k, &v);
        assert_eq!(kv.tokens, 3);
        // token 1, layer 1 starts at (1*nl+1)*d
        assert_eq!(kv.k[(1 * nl + 1) * d], 1010.0);
        let mut wk = vec![0.0; 3 * d];
        let mut wv = vec![0.0; 3 * d];
        kv.gather_window(0, nl, d, 3, &mut wk, &mut wv);
        assert_eq!(wk[0], 0.0); // token0 layer0 x0
        assert_eq!(wk[d], 10.0); // token1 layer0
        assert_eq!(wk[2 * d], 20.0);
    }
}
